"""Binary artifact writers (little-endian), mirrored by rust/src/formats/.

weights.bin  (magic MCMW, v1)
  u32 n_methods
  per method:
    str   name                     (u32 byte-len + utf8)
    u8    cascade flag
    u32   clf_classes              (2 for binary, n+1 for multiclass)
    u32   n_classifiers            (1; MCCA: one per cascade pair)
    mlp[] classifiers
    u32   n_approximators
    mlp[] approximators
  mlp:
    u32 n_layers
    per layer: u32 rows, u32 cols, f32[rows*cols] W (row-major),
               u32 blen, f32[blen] b

dataset.bin  (magic MCMD, v1)
  u32 n, u32 d_in, u32 d_out
  f32[n*d_in]  X_raw   (row-major, un-normalised inputs)
  f32[n*d_out] Y_norm  (row-major, normalised precise outputs)
"""

from __future__ import annotations

import struct
from typing import List, Sequence, Tuple

import numpy as np

from .train import MethodResult

MAGIC_WEIGHTS = b"MCMW"
MAGIC_DATASET = b"MCMD"
VERSION = 1


def _w_u32(f, v: int) -> None:
    f.write(struct.pack("<I", v))


def _w_str(f, s: str) -> None:
    b = s.encode("utf-8")
    _w_u32(f, len(b))
    f.write(b)


def _w_f32s(f, a: np.ndarray) -> None:
    f.write(np.ascontiguousarray(a, dtype="<f4").tobytes())


def _w_mlp(f, params: Sequence[Tuple[np.ndarray, np.ndarray]]) -> None:
    _w_u32(f, len(params))
    for w, b in params:
        w = np.asarray(w, np.float32)
        b = np.asarray(b, np.float32)
        assert w.ndim == 2 and b.ndim == 1 and b.shape[0] == w.shape[1]
        _w_u32(f, w.shape[0])
        _w_u32(f, w.shape[1])
        _w_f32s(f, w)
        _w_u32(f, b.shape[0])
        _w_f32s(f, b)


def write_weights(path: str, methods: List[MethodResult]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC_WEIGHTS)
        _w_u32(f, VERSION)
        _w_u32(f, len(methods))
        for m in methods:
            _w_str(f, m.method)
            f.write(struct.pack("<B", 1 if m.cascade else 0))
            _w_u32(f, m.clf_classes)
            clfs = m.cascade_classifiers if m.cascade else [m.classifier]
            _w_u32(f, len(clfs))
            for c in clfs:
                _w_mlp(f, _np(c))
            _w_u32(f, len(m.approximators))
            for a in m.approximators:
                _w_mlp(f, _np(a))


def _np(params):
    return [(np.asarray(w, np.float32), np.asarray(b, np.float32)) for w, b in params]


def write_dataset(path: str, X_raw: np.ndarray, Y_norm: np.ndarray) -> None:
    n, d_in = X_raw.shape
    n2, d_out = Y_norm.shape
    assert n == n2
    with open(path, "wb") as f:
        f.write(MAGIC_DATASET)
        _w_u32(f, VERSION)
        _w_u32(f, n)
        _w_u32(f, d_in)
        _w_u32(f, d_out)
        _w_f32s(f, X_raw)
        _w_f32s(f, Y_norm)


# Readers (used by the pytest round-trip tests only; Rust has its own).

def read_weights(path: str):
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC_WEIGHTS
        (ver,) = struct.unpack("<I", f.read(4))
        assert ver == VERSION
        (nm,) = struct.unpack("<I", f.read(4))
        out = {}
        for _ in range(nm):
            (ln,) = struct.unpack("<I", f.read(4))
            name = f.read(ln).decode()
            (casc,) = struct.unpack("<B", f.read(1))
            (ncls,) = struct.unpack("<I", f.read(4))
            (nclf,) = struct.unpack("<I", f.read(4))
            clfs = [_r_mlp(f) for _ in range(nclf)]
            (na,) = struct.unpack("<I", f.read(4))
            apps = [_r_mlp(f) for _ in range(na)]
            out[name] = dict(cascade=bool(casc), clf_classes=ncls,
                             classifiers=clfs, approximators=apps)
        return out


def _r_mlp(f):
    (nl,) = struct.unpack("<I", f.read(4))
    layers = []
    for _ in range(nl):
        r, c = struct.unpack("<II", f.read(8))
        w = np.frombuffer(f.read(4 * r * c), dtype="<f4").reshape(r, c)
        (bl,) = struct.unpack("<I", f.read(4))
        b = np.frombuffer(f.read(4 * bl), dtype="<f4")
        layers.append((w, b))
    return layers


def read_dataset(path: str):
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC_DATASET
        (ver,) = struct.unpack("<I", f.read(4))
        assert ver == VERSION
        n, d_in, d_out = struct.unpack("<III", f.read(12))
        X = np.frombuffer(f.read(4 * n * d_in), dtype="<f4").reshape(n, d_in)
        Y = np.frombuffer(f.read(4 * n * d_out), dtype="<f4").reshape(n, d_out)
        return X, Y
