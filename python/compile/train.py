"""The five training schemes of the paper (§II.B, §III).

  one_pass            Mahajan et al. [18]: train A once on everything, then
                      train a binary classifier on A's safe/unsafe labels.
  iterative           Xu et al. [19]: alternate retraining A on the samples
                      both nets agree are safe ("AC") and retraining C on
                      A's fresh labels.
  mcca                §III.B: cascade of (C_k, A_k) pairs; pair k trains on
                      whatever pair k-1's classifier rejected.
  mcma_complementary  §III.C: approximators initialised on the *residual*
                      of their predecessors (AdaBoost-like), then iterate
                      { label complementarily -> train multiclass C ->
                        re-partition by C -> retrain each A on its territory }.
  mcma_competitive    §III.C: all approximators initialised on all data with
                      different seeds/lr; labels go to the approximator with
                      the LOWEST error (if under the bound); same loop.

Every scheme returns a ``MethodResult`` with the trained nets plus a
per-iteration history (invocation / RMSE on the held-out test set) that the
Fig. 9 bench consumes.  Invocation/error semantics here mirror the Rust
runtime's (rust/src/coordinator/metrics.rs) so build-time trajectories and
run-time endpoints are comparable.

Implementation note: subsets (territories, cascade remainders) are always
expressed as ROW INDICES into the full train/test arrays, never as sliced
copies — every jitted function then sees one shape per benchmark and
compiles exactly once (§Perf L2).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .benchmarks import Benchmark

Params = M.Params


@dataclass
class TrainConfig:
    epochs: int = 120
    clf_epochs: int = 120
    iterations: int = 4          # paper: 5 training iterations
    n_approx: int = 3            # paper Fig. 10 uses 3 approximators
    lr: float = 3e-3
    batch_size: int = 512
    seed: int = 0
    mcca_max_pairs: int = 3
    mcca_min_gain: float = 0.04  # stop cascading when a pair recognises <4%
    min_territory: int = 32      # keep old weights if a territory collapses


@dataclass
class IterStats:
    iteration: int
    invocation: float            # fraction of TEST samples routed to any A
    rmse: float                  # RMSE over the invoked test samples (norm.)
    true_invocation: float       # fraction invoked AND actually under bound
    class_counts: List[int] = field(default_factory=list)


@dataclass
class MethodResult:
    method: str
    approximators: List[Params]
    classifier: Params           # binary (2 classes) or multiclass (n+1)
    clf_classes: int
    cascade: bool = False        # MCCA: classifiers live in cascade_classifiers
    cascade_classifiers: List[Params] = field(default_factory=list)
    history: List[IterStats] = field(default_factory=list)


# ---------------------------------------------------------------------------
# Shared primitives (all jit boundaries take FULL arrays; subsets are rows)
# ---------------------------------------------------------------------------

def _train_approx(bench: Benchmark, X, Y, cfg: TrainConfig, seed: int,
                  rows: Optional[np.ndarray] = None,
                  lr: Optional[float] = None,
                  init: Optional[Params] = None) -> Params:
    epochs = int(cfg.epochs * bench.epochs_mult)
    if init is not None:
        epochs = max(1, epochs // 2)  # warm-started refinement converges fast
    return M.train_mlp(bench.approx_topology, X, Y, loss="mse",
                       epochs=epochs, seed=seed,
                       rows=rows, lr=lr if lr is not None else cfg.lr,
                       batch_size=cfg.batch_size, init=init)


def _train_clf(bench: Benchmark, X, labels, n_classes: int, cfg: TrainConfig,
               seed: int, rows: Optional[np.ndarray] = None) -> Params:
    # Balanced xent: a dominant safe (or unsafe) majority otherwise drowns
    # out the minority class and the classifier degenerates to all-accept /
    # all-reject.  Guard: when a class is essentially ABSENT (<2% — e.g.
    # fft, where nothing is safe to approximate), balancing would invert
    # the problem and force the classifier to hallucinate that class; fall
    # back to unweighted loss there.
    sel = labels if rows is None else labels[rows]
    counts = np.bincount(sel.astype(np.int64), minlength=n_classes).astype(np.float64)
    present = counts / max(sel.size, 1)
    if present[present > 0].min(initial=1.0) < 0.02:
        weights = np.ones(n_classes)
    else:
        weights = sel.size / (n_classes * np.maximum(counts, 1.0))
        weights = np.clip(weights, 0.25, 4.0)
    return M.train_mlp(bench.clf_topology(n_classes), X,
                       labels.astype(np.int32), loss="xent",
                       epochs=int(cfg.clf_epochs * bench.epochs_mult),
                       seed=seed, rows=rows,
                       lr=cfg.lr, batch_size=cfg.batch_size,
                       class_weights=weights)


def _train_true_inv_single(clf: Params, approx: Params, X, Y, bound: float) -> float:
    """Train-set true invocation for binary systems (model-selection score)."""
    safe_c = _predict(clf, X) == 0
    err = _errors(approx, X, Y)
    return float((safe_c & (err <= bound)).mean())


def _train_true_inv_mcma(clf: Params, approxs: List[Params], X, Y, bound: float) -> float:
    n = len(approxs)
    cls = _predict(clf, X)
    invoked = cls < n
    errs = np.stack([_errors(a, X, Y) for a in approxs])
    chosen = np.where(invoked, cls, 0)
    err_sel = errs[chosen, np.arange(X.shape[0])]
    return float((invoked & (err_sel <= bound)).mean())


def _errors(params: Params, X, Y) -> np.ndarray:
    return np.asarray(M.per_sample_error(params, jnp.asarray(X), jnp.asarray(Y)))


def _predict(params: Params, X) -> np.ndarray:
    return np.asarray(M.predict_class(params, jnp.asarray(X)))


def _eval_single(clf: Params, approx: Params, Xt, Yt, bound: float,
                 iteration: int) -> IterStats:
    """Test-set stats for a binary-classifier + one-approximator system."""
    pred_safe = _predict(clf, Xt) == 0  # class 0 = safe by convention
    inv = float(pred_safe.mean())
    err = _errors(approx, Xt, Yt)
    invoked_err = err[pred_safe]
    rmse = float(np.sqrt(np.mean(invoked_err**2))) if invoked_err.size else 0.0
    true_inv = float((pred_safe & (err <= bound)).mean())
    return IterStats(iteration, inv, rmse, true_inv,
                     [int(pred_safe.sum()), int((~pred_safe).sum())])


def _eval_mcma(clf: Params, approxs: List[Params], Xt, Yt, bound: float,
               iteration: int) -> IterStats:
    n = len(approxs)
    cls = _predict(clf, Xt)
    invoked = cls < n
    inv = float(invoked.mean())
    errs = np.stack([_errors(a, Xt, Yt) for a in approxs])  # (n, B)
    chosen = np.where(invoked, cls, 0)
    err_sel = errs[chosen, np.arange(Xt.shape[0])]
    invoked_err = err_sel[invoked]
    rmse = float(np.sqrt(np.mean(invoked_err**2))) if invoked_err.size else 0.0
    true_inv = float((invoked & (err_sel <= bound)).mean())
    counts = [int((cls == k).sum()) for k in range(n + 1)]
    return IterStats(iteration, inv, rmse, true_inv, counts)


# ---------------------------------------------------------------------------
# one-pass [18]
# ---------------------------------------------------------------------------

def one_pass(bench: Benchmark, X, Y, Xt, Yt, cfg: TrainConfig) -> MethodResult:
    bound = bench.error_bound
    A = _train_approx(bench, X, Y, cfg, seed=cfg.seed)
    labels = (_errors(A, X, Y) > bound).astype(np.int32)  # 0 safe, 1 unsafe
    C = _train_clf(bench, X, labels, 2, cfg, seed=cfg.seed + 1)
    res = MethodResult("one_pass", [A], C, 2)
    res.history.append(_eval_single(C, A, Xt, Yt, bound, 0))
    return res


# ---------------------------------------------------------------------------
# iterative [19]
# ---------------------------------------------------------------------------

def iterative(bench: Benchmark, X, Y, Xt, Yt, cfg: TrainConfig) -> MethodResult:
    bound = bench.error_bound
    A = _train_approx(bench, X, Y, cfg, seed=cfg.seed)
    labels = (_errors(A, X, Y) > bound).astype(np.int32)
    C = _train_clf(bench, X, labels, 2, cfg, seed=cfg.seed + 1)
    res = MethodResult("iterative", [A], C, 2)
    res.history.append(_eval_single(C, A, Xt, Yt, bound, 0))
    best = (_train_true_inv_single(C, A, X, Y, bound), A, C)
    for it in range(1, cfg.iterations):
        # "AC": samples the classifier accepts AND the approximator really
        # fits — the agreement set of [19].
        safe_a = _errors(A, X, Y) <= bound
        safe_c = _predict(C, X) == 0
        sel = safe_a & safe_c
        if sel.sum() < cfg.min_territory:
            sel = safe_a  # degenerate classifier; fall back to category A
        A = _train_approx(bench, X, Y, cfg, seed=cfg.seed + 10 + it,
                          rows=np.where(sel)[0], init=A)
        labels = (_errors(A, X, Y) > bound).astype(np.int32)
        C = _train_clf(bench, X, labels, 2, cfg, seed=cfg.seed + 20 + it)
        res.history.append(_eval_single(C, A, Xt, Yt, bound, it))
        score = _train_true_inv_single(C, A, X, Y, bound)
        if score > best[0]:
            best = (score, A, C)
    # Keep the best iteration's nets (iteration-level early stopping; the
    # paper trains a fixed 5 iterations but reports converged behaviour).
    _, A, C = best
    res.approximators = [A]
    res.classifier = C
    return res


# ---------------------------------------------------------------------------
# MCCA (§III.B)
# ---------------------------------------------------------------------------

def mcca(bench: Benchmark, X, Y, Xt, Yt, cfg: TrainConfig) -> MethodResult:
    bound = bench.error_bound
    approxs: List[Params] = []
    clfs: List[Params] = []
    remain = np.ones(X.shape[0], bool)
    for k in range(cfg.mcca_max_pairs):
        rows = np.where(remain)[0]
        if rows.size < cfg.min_territory:
            break
        A = _train_approx(bench, X, Y, cfg, seed=cfg.seed + 100 * k, rows=rows)
        labels = (_errors(A, X, Y) > bound).astype(np.int32)
        C = _train_clf(bench, X, labels, 2, cfg, seed=cfg.seed + 100 * k + 1,
                       rows=rows)
        # One refinement pass per pair: retrain A on category C (what the
        # classifier accepts), per §III.B "select the training samples using
        # category C in the second iteration".
        acc = _predict(C, X) == 0
        sel = np.where(remain & acc)[0]
        if sel.size >= cfg.min_territory:
            A = _train_approx(bench, X, Y, cfg, seed=cfg.seed + 100 * k + 2,
                              rows=sel)
            labels = (_errors(A, X, Y) > bound).astype(np.int32)
            C = _train_clf(bench, X, labels, 2, cfg,
                           seed=cfg.seed + 100 * k + 3, rows=rows)
        accept = remain & (_predict(C, X) == 0)
        gain = accept.sum() / X.shape[0]
        if gain < cfg.mcca_min_gain and k > 0:
            break  # pair does not converge onto anything useful (§III.B stop)
        approxs.append(A)
        clfs.append(C)
        remain &= ~accept
    res = MethodResult("mcca", approxs, clfs[0] if clfs else [], 2,
                       cascade=True, cascade_classifiers=clfs)
    res.history.append(_eval_cascade(clfs, approxs, Xt, Yt, bound, 0))
    return res


def _eval_cascade(clfs: List[Params], approxs: List[Params], Xt, Yt,
                  bound: float, iteration: int) -> IterStats:
    n = Xt.shape[0]
    assigned = np.full(n, -1)
    remain = np.ones(n, bool)
    for k, C in enumerate(clfs):
        acc = (_predict(C, Xt) == 0) & remain
        assigned[acc] = k
        remain &= ~acc
    invoked = assigned >= 0
    inv = float(invoked.mean())
    errs_all = np.stack([_errors(A, Xt, Yt) for A in approxs]) if approxs else np.zeros((1, n))
    chosen = np.where(invoked, assigned, 0)
    err_sel = errs_all[chosen, np.arange(n)]
    invoked_err = err_sel[invoked]
    rmse = float(np.sqrt(np.mean(invoked_err**2))) if invoked_err.size else 0.0
    true_inv = float((invoked & (err_sel <= bound)).mean())
    counts = [int((assigned == k).sum()) for k in range(len(approxs))] + [int(remain.sum())]
    return IterStats(iteration, inv, rmse, true_inv, counts)


# ---------------------------------------------------------------------------
# MCMA (§III.C)
# ---------------------------------------------------------------------------

def _complementary_labels(approxs: List[Params], X, Y, bound: float) -> np.ndarray:
    """Priority labelling: first approximator that fits a sample claims it."""
    n = X.shape[0]
    labels = np.full(n, len(approxs), np.int32)  # default nC
    unclaimed = np.ones(n, bool)
    for k, A in enumerate(approxs):
        ok = (_errors(A, X, Y) <= bound) & unclaimed
        labels[ok] = k
        unclaimed &= ~ok
    return labels


def _competitive_labels(approxs: List[Params], X, Y, bound: float) -> np.ndarray:
    """Lowest-error-wins labelling."""
    errs = np.stack([_errors(A, X, Y) for A in approxs])  # (n_approx, n)
    best = errs.argmin(axis=0).astype(np.int32)
    best_err = errs.min(axis=0)
    return np.where(best_err <= bound, best, len(approxs)).astype(np.int32)


def _mcma(bench: Benchmark, X, Y, Xt, Yt, cfg: TrainConfig,
          scheme: str) -> MethodResult:
    bound = bench.error_bound
    n = cfg.n_approx
    approxs: List[Params] = []

    if scheme == "complementary":
        # Serial residual initialisation (AdaBoost-flavoured).
        unclaimed = np.ones(X.shape[0], bool)
        for k in range(n):
            rows = np.where(unclaimed)[0]
            if rows.size < cfg.min_territory:
                rows = None  # residual exhausted; train on everything
            A = _train_approx(bench, X, Y, cfg, seed=cfg.seed + 1000 + k,
                              rows=rows)
            approxs.append(A)
            ok = (_errors(A, X, Y) <= bound) & unclaimed
            unclaimed &= ~ok
        label_fn = _complementary_labels
    elif scheme == "competitive":
        # All approximators see all data; different seeds and lr jitter push
        # them to different local minima (§III.C).
        for k in range(n):
            A = _train_approx(bench, X, Y, cfg, seed=cfg.seed + 2000 + 37 * k,
                              lr=cfg.lr * (0.5 + 0.5 * (k + 1)))
            approxs.append(A)
        label_fn = _competitive_labels
    else:
        raise ValueError(scheme)

    labels = label_fn(approxs, X, Y, bound)
    C = _train_clf(bench, X, labels, n + 1, cfg, seed=cfg.seed + 3000)
    res = MethodResult(f"mcma_{scheme}", approxs, C, n + 1)
    res.history.append(_eval_mcma(C, approxs, Xt, Yt, bound, 0))
    best = (_train_true_inv_mcma(C, approxs, X, Y, bound), approxs, C)

    for it in range(1, cfg.iterations):
        # Classifier partitions the input space into n+1 territories; each
        # approximator retrains (warm-started) on its own territory.
        assign = _predict(C, X)
        new_approxs: List[Params] = []
        for k in range(n):
            rows = np.where(assign == k)[0]
            if rows.size >= cfg.min_territory:
                new_approxs.append(_train_approx(
                    bench, X, Y, cfg, seed=cfg.seed + 1000 + 97 * it + k,
                    rows=rows, init=approxs[k]))
            else:
                new_approxs.append(approxs[k])  # territory collapsed; keep
        approxs = new_approxs
        labels = label_fn(approxs, X, Y, bound)
        C = _train_clf(bench, X, labels, n + 1, cfg, seed=cfg.seed + 3000 + it)
        res.history.append(_eval_mcma(C, approxs, Xt, Yt, bound, it))
        score = _train_true_inv_mcma(C, approxs, X, Y, bound)
        if score > best[0]:
            best = (score, approxs, C)

    # Ship the best iteration's compound structure (see `iterative`).
    _, approxs, C = best
    res.approximators = approxs
    res.classifier = C
    return res


def mcma_complementary(bench, X, Y, Xt, Yt, cfg):
    return _mcma(bench, X, Y, Xt, Yt, cfg, "complementary")


def mcma_competitive(bench, X, Y, Xt, Yt, cfg):
    return _mcma(bench, X, Y, Xt, Yt, cfg, "competitive")


METHODS = {
    "one_pass": one_pass,
    "iterative": iterative,
    "mcca": mcca,
    "mcma_complementary": mcma_complementary,
    "mcma_competitive": mcma_competitive,
}


def train_all(bench: Benchmark, X, Y, Xt, Yt, cfg: TrainConfig,
              methods: Optional[Sequence[str]] = None) -> Dict[str, MethodResult]:
    out: Dict[str, MethodResult] = {}
    for name in (methods or METHODS):
        out[name] = METHODS[name](bench, X, Y, Xt, Yt, cfg)
    return out
