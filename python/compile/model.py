"""Layer-2 JAX model: MLP init / forward / losses / RMSprop training.

Matches the paper's setup (§IV.A): multilayer perceptrons trained with
backpropagation and the RMSprop optimizer.  The forward pass has two
numerically-identical implementations: the Pallas kernel chain
(``kernels.mlp``) used for the AOT export, and the pure-jnp oracle
(``kernels.ref``) used inside the jitted training loop (interpret-mode
Pallas is orders of magnitude slower on CPU; pytest asserts the two agree).
"""

from __future__ import annotations

import functools
import math
from typing import Callable, List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import mlp as kmlp
from .kernels import ref as kref

Params = List[Tuple[jnp.ndarray, jnp.ndarray]]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_mlp(topology: Sequence[int], key: jax.Array) -> Params:
    """Xavier/Glorot-uniform init, zero bias."""
    params: Params = []
    keys = jax.random.split(key, len(topology) - 1)
    for k, (fan_in, fan_out) in zip(keys, zip(topology[:-1], topology[1:])):
        lim = math.sqrt(6.0 / (fan_in + fan_out))
        w = jax.random.uniform(k, (fan_in, fan_out), jnp.float32, -lim, lim)
        b = jnp.zeros((fan_out,), jnp.float32)
        params.append((w, b))
    return params


def forward(x: jnp.ndarray, params: Params, *, pallas: bool = False) -> jnp.ndarray:
    return kmlp.mlp_forward(x, params) if pallas else kref.mlp_forward_ref(x, params)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def mse_loss(params: Params, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    pred = kref.mlp_forward_ref(x, params)
    return jnp.mean((pred - y) ** 2)


def softmax_xent_loss(params: Params, x: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """labels: int32 class ids."""
    logits = kref.mlp_forward_ref(x, params)
    logz = jax.nn.logsumexp(logits, axis=1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0] - logz
    return -jnp.mean(ll)


def make_weighted_xent(class_weights: jnp.ndarray):
    """Class-balanced cross-entropy: rare classes are not drowned out by a
    dominant safe/unsafe majority (stabilises the one-pass classifier on
    imbalanced label sets)."""

    def loss(params: Params, x: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
        logits = kref.mlp_forward_ref(x, params)
        logz = jax.nn.logsumexp(logits, axis=1)
        ll = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0] - logz
        w = class_weights[labels]
        return -jnp.sum(w * ll) / jnp.sum(w)

    return loss


# ---------------------------------------------------------------------------
# RMSprop (hand-rolled; optax is not in the image)
# ---------------------------------------------------------------------------

class RmsState(NamedTuple):
    sq: List[Tuple[jnp.ndarray, jnp.ndarray]]


def rms_init(params: Params) -> RmsState:
    return RmsState(sq=[(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in params])


def rms_update(params: Params, grads: Params, state: RmsState,
               lr: float, rho: float = 0.9, eps: float = 1e-8):
    new_params: Params = []
    new_sq = []
    for (w, b), (gw, gb), (sw, sb) in zip(params, grads, state.sq):
        sw = rho * sw + (1.0 - rho) * gw * gw
        sb = rho * sb + (1.0 - rho) * gb * gb
        new_params.append((w - lr * gw / jnp.sqrt(sw + eps),
                           b - lr * gb / jnp.sqrt(sb + eps)))
        new_sq.append((sw, sb))
    return new_params, RmsState(sq=new_sq)


# ---------------------------------------------------------------------------
# Training loops (jitted, minibatched)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("loss_name",), donate_argnums=(0, 1))
def _train_scan(params, state, Xd, Yd, idx, lr, cw, loss_name: str):
    """Whole training run as one lax.scan over minibatch index rows.

    §Perf L2: a per-step Python dispatch loop costs ~0.2 ms/step in overhead
    alone; scanning the full run inside a single jit is ~20x faster end to
    end and compiles once per (topology, loss) because the minibatch indices
    address the FULL dataset (territory subsets only change `idx` values,
    never shapes).  `cw` is the per-class weight vector for xent (ones for
    the unweighted case; ignored for mse).
    """
    loss_fn = mse_loss if loss_name == "mse" else make_weighted_xent(cw)

    def step(carry, ib):
        params, state = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, Xd[ib], Yd[ib])
        params, state = rms_update(params, grads, state, lr)
        return (params, state), loss

    (params, state), losses = jax.lax.scan(step, (params, state), idx)
    return params, state, losses


def train_mlp(topology: Sequence[int], X: np.ndarray, Y: np.ndarray, *,
              loss: str, epochs: int, seed: int, lr: float = 1e-3,
              batch_size: int = 512,
              rows: Optional[np.ndarray] = None,
              total_steps: Optional[int] = None,
              init: Optional[Params] = None,
              class_weights: Optional[np.ndarray] = None) -> Params:
    """Train an MLP; Y is float targets for mse, int32 labels for xent.

    ``rows`` restricts training to a subset (an approximator's territory)
    without changing any array shape — minibatches are sampled (with
    replacement) from those row indices of the full X/Y.  ``init`` warm-
    starts from existing params (territory refinement in the MCMA loop);
    ``class_weights`` enables balanced xent.
    """
    n = X.shape[0]
    if rows is None:
        rows = np.arange(n)
    if rows.size == 0:
        # Degenerate territory (an approximator can end up with no samples
        # mid-iteration); return a fresh init so downstream code stays total.
        return init if init is not None else init_mlp(topology, jax.random.PRNGKey(seed))
    # NB: _train_scan donates its params argument; copy warm-start weights
    # so the caller's arrays stay alive (it may keep them on collapse).
    params = ([(jnp.array(w, copy=True), jnp.array(b, copy=True)) for w, b in init]
              if init is not None else init_mlp(topology, jax.random.PRNGKey(seed)))
    state = rms_init(params)
    bs = min(batch_size, n)
    if total_steps is None:
        total_steps = epochs * max(1, n // bs)
    rng = np.random.RandomState(seed)
    idx = rng.choice(rows, size=(total_steps, bs), replace=True).astype(np.int32)
    Xd = jnp.asarray(X, jnp.float32)
    Yd = jnp.asarray(Y, jnp.int32 if loss == "xent" else jnp.float32)
    n_classes = topology[-1]
    cw = (jnp.asarray(class_weights, jnp.float32) if class_weights is not None
          else jnp.ones((n_classes,), jnp.float32))
    params, _, _ = _train_scan(params, state, Xd, Yd, jnp.asarray(idx),
                               jnp.float32(lr), cw, loss)
    return params


# ---------------------------------------------------------------------------
# Evaluation helpers shared by the training schemes
# ---------------------------------------------------------------------------

@jax.jit
def per_sample_error(params, X, Y) -> jnp.ndarray:
    """Per-sample RMSE across output dims, in normalised output space."""
    pred = kref.mlp_forward_ref(X, params)
    return jnp.sqrt(jnp.mean((pred - Y) ** 2, axis=1))


@jax.jit
def predict_class(params, X) -> jnp.ndarray:
    return jnp.argmax(kref.mlp_forward_ref(X, params), axis=1)


def params_to_numpy(params: Params) -> List[Tuple[np.ndarray, np.ndarray]]:
    return [(np.asarray(w, np.float32), np.asarray(b, np.float32)) for w, b in params]
