"""Benchmark suite (paper Fig. 6): eight target functions + workload generators.

Each benchmark defines
  * ``gen(n, seed)``   — raw input samples drawn from the paper's domain
  * ``fn(X)``          — the PRECISE target function (the "CPU" path)
  * ``norm_x/norm_y``  — fixed (not data-dependent) min/max normalisation
                         bounds, so the Rust side can agree *statically*
  * topologies for the approximator and classifiers (paper Fig. 6)

IMPORTANT cross-language contract: every ``fn`` here is re-implemented
verbatim in ``rust/src/benchmarks/``; the two must agree to ~1e-5 on the
golden vectors exported by aot.py.  For functions whose "true" value needs a
special function (erf, Bessel J_nu) both sides implement the *same*
deterministic approximation (Abramowitz–Stegun erf, fixed-node Simpson
quadrature) — that approximation IS the target function being approximated,
so there is no cross-library drift.

The paper's corpora (512x512 images, 70K option batches from AxBench) are
proprietary-ish inputs we do not have; the generators below synthesise the
same dimensionality and distribution family (see DESIGN.md "Substitutions").
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Shared deterministic special functions (mirrored in rust/src/benchmarks/).
# ---------------------------------------------------------------------------

_ERF_A = (0.254829592, -0.284496736, 1.421413741, -1.453152027, 1.061405429)
_ERF_P = 0.3275911


def erf_as(x: np.ndarray) -> np.ndarray:
    """Abramowitz–Stegun 7.1.26 rational erf approximation (|err| < 1.5e-7).

    Used instead of math.erf so the Rust precise path computes the *identical*
    function.
    """
    x = np.asarray(x, dtype=np.float64)
    sign = np.sign(x)
    ax = np.abs(x)
    t = 1.0 / (1.0 + _ERF_P * ax)
    poly = t * (_ERF_A[0] + t * (_ERF_A[1] + t * (_ERF_A[2] + t * (_ERF_A[3] + t * _ERF_A[4]))))
    return sign * (1.0 - poly * np.exp(-ax * ax))


def norm_cdf(x: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + erf_as(x / math.sqrt(2.0)))


# Fixed-node Simpson quadrature for J_nu(x), nu in [0,4], x in [0.5, 15].
# J_nu(x) = (1/pi) \int_0^pi cos(nu*t - x*sin t) dt
#           - sin(nu*pi)/pi \int_0^INF exp(-x*sinh s - nu*s) ds
# Second integral truncated at s=6 (x >= 0.5 -> e^{-x sinh 6} < e^{-100}).
_BESSEL_N1 = 96   # Simpson intervals on [0, pi]
_BESSEL_N2 = 120  # Simpson intervals on [0, 6]
_BESSEL_S_MAX = 6.0


def bessel_j(nu: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Deterministic J_nu(x) via Simpson quadrature (the shared target fn)."""
    nu = np.asarray(nu, dtype=np.float64)[..., None]
    x = np.asarray(x, dtype=np.float64)[..., None]

    t = np.linspace(0.0, math.pi, _BESSEL_N1 + 1)
    f1 = np.cos(nu * t - x * np.sin(t))
    w1 = _simpson_weights(_BESSEL_N1, math.pi / _BESSEL_N1)
    term1 = (f1 * w1).sum(-1) / math.pi

    s = np.linspace(0.0, _BESSEL_S_MAX, _BESSEL_N2 + 1)
    f2 = np.exp(-x * np.sinh(s) - nu * s)
    w2 = _simpson_weights(_BESSEL_N2, _BESSEL_S_MAX / _BESSEL_N2)
    term2 = np.sin(nu[..., 0] * math.pi) / math.pi * (f2 * w2).sum(-1)

    return term1 - term2


def _simpson_weights(n_intervals: int, h: float) -> np.ndarray:
    w = np.ones(n_intervals + 1)
    w[1:-1:2] = 4.0
    w[2:-1:2] = 2.0
    return w * (h / 3.0)


# ---------------------------------------------------------------------------
# 8x8 DCT machinery for the jpeg benchmark (mirrored in Rust).
# ---------------------------------------------------------------------------

# Standard JPEG luminance quantisation table (quality 50), row-major.
JPEG_QTABLE = np.array(
    [
        16, 11, 10, 16, 24, 40, 51, 61,
        12, 12, 14, 19, 26, 58, 60, 55,
        14, 13, 16, 24, 40, 57, 69, 56,
        14, 17, 22, 29, 51, 87, 80, 62,
        18, 22, 37, 56, 68, 109, 103, 77,
        24, 35, 55, 64, 81, 104, 113, 92,
        49, 64, 78, 87, 103, 121, 120, 101,
        72, 92, 95, 98, 112, 100, 103, 99,
    ],
    dtype=np.float64,
).reshape(8, 8)


def _dct8_matrix() -> np.ndarray:
    """Orthonormal DCT-II basis matrix C (8x8): X = C @ x @ C.T."""
    c = np.zeros((8, 8))
    for k in range(8):
        alpha = math.sqrt(1.0 / 8.0) if k == 0 else math.sqrt(2.0 / 8.0)
        for n in range(8):
            c[k, n] = alpha * math.cos(math.pi * (2 * n + 1) * k / 16.0)
    return c


DCT8 = _dct8_matrix()


def jpeg_roundtrip(blocks: np.ndarray) -> np.ndarray:
    """Encode+decode 8x8 blocks: DCT -> quantise -> dequantise -> IDCT.

    blocks: (n, 64) pixels in [0, 1].  Returns reconstructed pixels in [0,1].
    """
    n = blocks.shape[0]
    b = blocks.reshape(n, 8, 8) * 255.0 - 128.0
    coef = np.einsum("ij,njk,lk->nil", DCT8, b, DCT8)
    q = np.round(coef / JPEG_QTABLE) * JPEG_QTABLE
    rec = np.einsum("ji,njk,kl->nil", DCT8, q, DCT8)
    rec = np.clip((rec + 128.0) / 255.0, 0.0, 1.0)
    return rec.reshape(n, 64)


# ---------------------------------------------------------------------------
# Triangle-triangle intersection (jmeint), separating-axis test.
# ---------------------------------------------------------------------------

def _tri_tri_overlap_one(p: np.ndarray, q: np.ndarray) -> bool:
    """SAT 3-D triangle intersection. p, q: (3,3) vertex rows (float64)."""
    axes: List[np.ndarray] = []
    e_p = [p[1] - p[0], p[2] - p[1], p[0] - p[2]]
    e_q = [q[1] - q[0], q[2] - q[1], q[0] - q[2]]
    n_p = np.cross(e_p[0], e_p[1])
    n_q = np.cross(e_q[0], e_q[1])
    axes.append(n_p)
    axes.append(n_q)
    for a in e_p:
        for b in e_q:
            axes.append(np.cross(a, b))
    for ax in axes:
        norm2 = float(ax @ ax)
        if norm2 < 1e-12:
            continue
        dp = p @ ax
        dq = q @ ax
        if dp.max() < dq.min() - 1e-12 or dq.max() < dp.min() - 1e-12:
            return False
    return True


def tri_tri_intersect(X: np.ndarray) -> np.ndarray:
    """X: (n, 18) = two triangles' 9+9 coords. Returns (n, 2) one-hot."""
    n = X.shape[0]
    out = np.zeros((n, 2))
    for i in range(n):
        p = X[i, :9].reshape(3, 3)
        q = X[i, 9:].reshape(3, 3)
        hit = _tri_tri_overlap_one(p, q)
        out[i, 0] = 1.0 if hit else 0.0
        out[i, 1] = 0.0 if hit else 1.0
    return out


# ---------------------------------------------------------------------------
# Benchmark definitions.
# ---------------------------------------------------------------------------


@dataclass
class Benchmark:
    name: str
    domain: str
    n_in: int
    n_out: int
    approx_topology: List[int]
    clf_hidden: List[int]          # hidden layers of the classifier
    gen: Callable[[int, int], np.ndarray]
    fn: Callable[[np.ndarray], np.ndarray]
    x_lo: np.ndarray
    x_hi: np.ndarray
    y_lo: np.ndarray
    y_hi: np.ndarray
    error_bound: float             # default bound on normalised per-sample RMSE
    train_n: int = 12_000
    test_n: int = 4_000
    epochs_mult: float = 1.0       # hard targets (oscillatory Bessel) need more

    def normalize_x(self, X: np.ndarray) -> np.ndarray:
        return (X - self.x_lo) / (self.x_hi - self.x_lo)

    def normalize_y(self, Y: np.ndarray) -> np.ndarray:
        return (Y - self.y_lo) / (self.y_hi - self.y_lo)

    def clf_topology(self, n_classes: int) -> List[int]:
        return [self.n_in] + list(self.clf_hidden) + [n_classes]


def _gen_blackscholes(n: int, seed: int) -> np.ndarray:
    r = np.random.RandomState(seed)
    s = r.lognormal(mean=math.log(50.0), sigma=0.35, size=n).clip(10.0, 150.0)
    k = s * r.uniform(0.6, 1.4, size=n)
    rate = r.uniform(0.01, 0.08, size=n)
    vol = r.uniform(0.05, 0.65, size=n)
    t = r.uniform(0.1, 2.0, size=n)
    otype = r.randint(0, 2, size=n).astype(np.float64)
    return np.stack([s, k, rate, vol, t, otype], axis=1)


def _fn_blackscholes(X: np.ndarray) -> np.ndarray:
    s, k, r, v, t, otype = (X[:, i] for i in range(6))
    sqrt_t = np.sqrt(t)
    d1 = (np.log(s / k) + (r + 0.5 * v * v) * t) / (v * sqrt_t)
    d2 = d1 - v * sqrt_t
    disc = k * np.exp(-r * t)
    call = s * norm_cdf(d1) - disc * norm_cdf(d2)
    put = call - s + disc  # put-call parity
    price = np.where(otype < 0.5, call, put)
    return price[:, None]


def _gen_fft(n: int, seed: int) -> np.ndarray:
    r = np.random.RandomState(seed)
    return r.uniform(0.0, 2.0 * math.pi, size=(n, 1))


def _fn_fft(X: np.ndarray) -> np.ndarray:
    x = X[:, 0]
    return np.stack([np.cos(x), np.sin(x)], axis=1)


_IK_L1, _IK_L2 = 0.5, 0.5


def _gen_inversek2j(n: int, seed: int) -> np.ndarray:
    r = np.random.RandomState(seed)
    th1 = r.uniform(0.05, math.pi / 2 - 0.05, size=n)
    th2 = r.uniform(0.05, math.pi / 2 - 0.05, size=n)
    x = _IK_L1 * np.cos(th1) + _IK_L2 * np.cos(th1 + th2)
    y = _IK_L1 * np.sin(th1) + _IK_L2 * np.sin(th1 + th2)
    return np.stack([x, y], axis=1)


def _fn_inversek2j(X: np.ndarray) -> np.ndarray:
    x, y = X[:, 0], X[:, 1]
    d2 = x * x + y * y
    c2 = ((d2 - _IK_L1**2 - _IK_L2**2) / (2.0 * _IK_L1 * _IK_L2)).clip(-1.0, 1.0)
    th2 = np.arccos(c2)
    th1 = np.arctan2(y, x) - np.arctan2(_IK_L2 * np.sin(th2), _IK_L1 + _IK_L2 * np.cos(th2))
    return np.stack([th1, th2], axis=1)


def _gen_jmeint(n: int, seed: int) -> np.ndarray:
    r = np.random.RandomState(seed)
    # Two triangles: random center offset keeps ~50/50 hit rate.
    base = r.uniform(0.0, 1.0, size=(n, 18))
    offset = r.uniform(-0.4, 0.4, size=(n, 3))
    base[:, 9:] = (base[:, 9:].reshape(n, 3, 3) * 0.8 + offset[:, None, :]).reshape(n, 9)
    return base


def _gen_jpeg(n: int, seed: int) -> np.ndarray:
    r = np.random.RandomState(seed)
    yy, xx = np.meshgrid(np.arange(8.0), np.arange(8.0), indexing="ij")
    blocks = np.zeros((n, 8, 8))
    g = r.uniform(-1.0, 1.0, size=(n, 2))
    phase = r.uniform(0.0, 2 * math.pi, size=(n, 2))
    freq = r.uniform(0.2, 1.4, size=(n, 2))
    amp = r.uniform(0.0, 0.4, size=(n, 1, 1))
    level = r.uniform(0.2, 0.8, size=(n, 1, 1))
    blocks = (
        level
        + g[:, 0, None, None] * (xx - 3.5) / 14.0
        + g[:, 1, None, None] * (yy - 3.5) / 14.0
        + amp * np.sin(freq[:, 0, None, None] * xx + phase[:, 0, None, None])
        * np.sin(freq[:, 1, None, None] * yy + phase[:, 1, None, None])
    )
    blocks += r.normal(0.0, 0.02, size=(n, 8, 8))
    return blocks.clip(0.0, 1.0).reshape(n, 64)


def _gen_kmeans(n: int, seed: int) -> np.ndarray:
    r = np.random.RandomState(seed)
    px = r.uniform(0.0, 1.0, size=(n, 3))
    centers = r.uniform(0.0, 1.0, size=(8, 3))
    cidx = r.randint(0, 8, size=n)
    c = centers[cidx] + r.normal(0.0, 0.05, size=(n, 3))
    return np.concatenate([px, c.clip(0.0, 1.0)], axis=1)


def _fn_kmeans(X: np.ndarray) -> np.ndarray:
    d = np.sqrt(((X[:, :3] - X[:, 3:]) ** 2).sum(axis=1))
    return d[:, None]


_SOBEL_GX = np.array([[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]], dtype=np.float64)
_SOBEL_GY = _SOBEL_GX.T


def _gen_sobel(n: int, seed: int) -> np.ndarray:
    r = np.random.RandomState(seed)
    # 3x3 luminance windows sampled from gradient+edge synthetic patches.
    yy, xx = np.meshgrid(np.arange(3.0), np.arange(3.0), indexing="ij")
    g = r.uniform(-0.5, 0.5, size=(n, 2))
    level = r.uniform(0.1, 0.9, size=(n, 1, 1))
    edge_pos = r.uniform(-0.5, 2.5, size=(n, 1, 1))
    edge_amp = r.uniform(-0.6, 0.6, size=(n, 1, 1))
    w = (
        level
        + g[:, 0, None, None] * (xx - 1.0) / 4.0
        + g[:, 1, None, None] * (yy - 1.0) / 4.0
        + edge_amp * (xx > edge_pos)
    )
    w += r.normal(0.0, 0.02, size=(n, 3, 3))
    return w.clip(0.0, 1.0).reshape(n, 9)


def _fn_sobel(X: np.ndarray) -> np.ndarray:
    w = X.reshape(-1, 3, 3)
    gx = (w * _SOBEL_GX).sum(axis=(1, 2))
    gy = (w * _SOBEL_GY).sum(axis=(1, 2))
    mag = np.sqrt(gx * gx + gy * gy) / (4.0 * math.sqrt(2.0))
    return mag.clip(0.0, 1.0)[:, None]


def _gen_bessel(n: int, seed: int) -> np.ndarray:
    r = np.random.RandomState(seed)
    nu = r.uniform(0.0, 4.0, size=n)
    x = r.uniform(0.5, 15.0, size=n)
    return np.stack([nu, x], axis=1)


def _fn_bessel(X: np.ndarray) -> np.ndarray:
    return bessel_j(X[:, 0], X[:, 1])[:, None]


def _bm(name, domain, topo, clf_hidden, gen, fn, x_lo, x_hi, y_lo, y_hi, bound, **kw):
    x_lo = np.asarray(x_lo, dtype=np.float64)
    x_hi = np.asarray(x_hi, dtype=np.float64)
    y_lo = np.asarray(y_lo, dtype=np.float64)
    y_hi = np.asarray(y_hi, dtype=np.float64)
    return Benchmark(
        name=name, domain=domain, n_in=topo[0], n_out=topo[-1],
        approx_topology=list(topo), clf_hidden=list(clf_hidden),
        gen=gen, fn=fn, x_lo=x_lo, x_hi=x_hi, y_lo=y_lo, y_hi=y_hi,
        error_bound=bound, **kw,
    )


BENCHMARKS: Dict[str, Benchmark] = {
    b.name: b
    for b in [
        _bm("blackscholes", "Financial Analysis", [6, 8, 1], [8],
            _gen_blackscholes, _fn_blackscholes,
            [10.0, 6.0, 0.01, 0.05, 0.1, 0.0], [150.0, 210.0, 0.08, 0.65, 2.0, 1.0],
            [0.0], [120.0], 0.035),
        _bm("fft", "Signal Processing", [1, 2, 2, 2], [2],
            _gen_fft, _fn_fft,
            [0.0], [2.0 * math.pi], [-1.0, -1.0], [1.0, 1.0], 0.05,
            train_n=8_000, test_n=3_000, epochs_mult=3.0),
        _bm("inversek2j", "Robotics", [2, 8, 2], [8],
            _gen_inversek2j, _fn_inversek2j,
            [-0.55, 0.0], [1.0, 1.0], [-0.8, 0.0], [1.65, 1.65], 0.035,
            epochs_mult=4.0),
        _bm("jmeint", "3D Gaming", [18, 32, 16, 2], [16],
            _gen_jmeint, tri_tri_intersect,
            [-0.5] * 18, [1.5] * 18, [0.0, 0.0], [1.0, 1.0], 0.30),
        _bm("jpeg", "Compression", [64, 16, 64], [16],
            _gen_jpeg, jpeg_roundtrip,
            [0.0] * 64, [1.0] * 64, [0.0] * 64, [1.0] * 64, 0.06,
            train_n=8_000, test_n=3_000),
        _bm("kmeans", "Machine Learning", [6, 8, 4, 1], [8, 4],
            _gen_kmeans, _fn_kmeans,
            [0.0] * 6, [1.0] * 6, [0.0], [math.sqrt(3.0)], 0.025,
            epochs_mult=4.0),
        _bm("sobel", "Image Processing", [9, 8, 1], [8],
            _gen_sobel, _fn_sobel,
            [0.0] * 9, [1.0] * 9, [0.0], [1.0], 0.035),
        _bm("bessel", "Scientific Computing", [2, 4, 4, 1], [4],
            _gen_bessel, _fn_bessel,
            [0.0, 0.5], [4.0, 15.0], [-0.45, ], [1.1], 0.04, epochs_mult=6.0),
    ]
}

BENCH_ORDER = ["blackscholes", "fft", "inversek2j", "jmeint", "jpeg", "kmeans", "sobel", "bessel"]


def make_dataset(bench: Benchmark, n: int, seed: int) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (X_norm, Y_norm) float32 in [0,1]-ish normalised space."""
    X_raw = bench.gen(n, seed)
    Y_raw = bench.fn(X_raw)
    X = bench.normalize_x(X_raw).astype(np.float32)
    Y = bench.normalize_y(Y_raw).astype(np.float32)
    return X, Y
