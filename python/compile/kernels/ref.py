"""Pure-jnp oracle for the Pallas kernels.

This is the numerical ground truth: ``mlp.dense_act`` and
``mlp.mlp_forward`` must match these to float32 tolerance (pytest +
hypothesis sweeps in python/tests/test_kernels.py).  Training also runs
through this path (it is faster under CPU interpret mode); the AOT export
runs through the Pallas path so the lowered HLO contains the kernel.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = List[Tuple[jnp.ndarray, jnp.ndarray]]


def dense_act_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, act: str) -> jnp.ndarray:
    """y = act(x @ w + b); act in {"sigmoid", "linear"}."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if act == "sigmoid":
        y = jax.nn.sigmoid(y)
    elif act != "linear":
        raise ValueError(f"unknown activation {act!r}")
    return y


def mlp_forward_ref(x: jnp.ndarray, params: Params) -> jnp.ndarray:
    """Sigmoid hidden layers, linear output — the NPU PE activation scheme."""
    h = x
    n = len(params)
    for i, (w, b) in enumerate(params):
        h = dense_act_ref(h, w, b, "sigmoid" if i < n - 1 else "linear")
    return h
