"""Layer-1 Pallas kernel: fused dense layer (matmul + bias + activation).

This is the compute hot-spot of the whole system: every classifier and every
approximator inference is a chain of these layers.  On the paper's NPU each
PE runs a multiply-add-accumulate loop over one neuron's fan-in and then the
activation unit; the TPU analogue is one MXU-shaped tile of this kernel with
the weight block stationary in VMEM (see DESIGN.md §Hardware-Adaptation).

Block schedule
  grid  = (ceil(B / bm),)                     — batch-parallel grid
  x     : (bm, K)  block, index (i) -> (i, 0) — streamed HBM->VMEM per step
  w     : (K, N)   block, index (i) -> (0, 0) — stationary (the paper's
                                                 "weights in the buffer near
                                                 the MAC")
  b     : (N,)     block, stationary
  out   : (bm, N)  block, index (i) -> (i, 0)

All eight topologies have K, N <= 64, so one (K, N) weight block always fits
VMEM; batch is the only tiled dimension.  ``interpret=True`` everywhere —
the CPU PJRT plugin cannot execute Mosaic custom-calls; real-TPU numbers are
estimated from the block schedule in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Params = List[Tuple[jnp.ndarray, jnp.ndarray]]

# Batch tile: multiple of the float32 sublane tile (8) and big enough to
# amortise grid-step overhead; 128 matches the MXU systolic edge.
DEFAULT_BM = 128


def _dense_act_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if act == "sigmoid":
        y = jax.nn.sigmoid(y)
    o_ref[...] = y


def dense_act(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, act: str,
              bm: int = DEFAULT_BM) -> jnp.ndarray:
    """Fused ``act(x @ w + b)`` as a Pallas kernel.

    x: (B, K) float32; w: (K, N); b: (N,).  B is padded up to a multiple of
    the batch tile and sliced back, so any B works (hypothesis sweeps this).
    """
    if act not in ("sigmoid", "linear"):
        raise ValueError(f"unknown activation {act!r}")
    B, K = x.shape
    K2, N = w.shape
    assert K == K2, (K, K2)
    assert b.shape == (N,), b.shape

    bm_eff = min(bm, max(B, 1))
    pad = (-B) % bm_eff
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, K), x.dtype)], axis=0)
    bp = x.shape[0]
    grid = (bp // bm_eff,)

    out = pl.pallas_call(
        functools.partial(_dense_act_kernel, act=act),
        out_shape=jax.ShapeDtypeStruct((bp, N), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_eff, K), lambda i: (i, 0)),
            pl.BlockSpec((K, N), lambda i: (0, 0)),
            pl.BlockSpec((N,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm_eff, N), lambda i: (i, 0)),
        interpret=True,
    )(x, w, b)
    return out[:B] if pad else out


def mlp_forward(x: jnp.ndarray, params: Params, bm: int = DEFAULT_BM) -> jnp.ndarray:
    """Full MLP inference through the Pallas kernel chain.

    Sigmoid on hidden layers, linear output — matching the NPU PE's
    activation unit and the paper's MLP topologies (Fig. 6).
    """
    h = x
    n = len(params)
    for i, (w, b) in enumerate(params):
        h = dense_act(h, w, b, "sigmoid" if i < n - 1 else "linear", bm=bm)
    return h


def vmem_footprint_bytes(topology: Sequence[int], bm: int = DEFAULT_BM) -> int:
    """Estimated peak VMEM bytes for one grid step of the deepest layer.

    Used by DESIGN.md §Perf and the L1 structure checks: x block + w block +
    b block + out block, float32.
    """
    worst = 0
    for k, n in zip(topology[:-1], topology[1:]):
        step = 4 * (bm * k + k * n + n + bm * n)
        worst = max(worst, step)
    return worst
