"""AOT build entrypoint: train everything, lower the forwards to HLO text,
emit the artifact tree consumed by the Rust runtime.

    cd python && python -m compile.aot --out ../artifacts [--full|--smoke]

Per benchmark:
    artifacts/<bench>/weights.bin          all five methods' trained nets
    artifacts/<bench>/test.bin             held-out test set (X_raw, Y_norm)
    artifacts/<bench>/approx_b{1,256}.hlo.txt   batched approximator forward
    artifacts/<bench>/clf2_b{1,256}.hlo.txt     binary-classifier forward
    artifacts/<bench>/clfN_b{1,256}.hlo.txt     multiclass-classifier forward
Global:
    artifacts/manifest.json                topologies, norm bounds, bounds
    artifacts/train_stats.json             per-iteration trajectories (Fig 9)
    artifacts/golden.json                  cross-language golden vectors

HLO is exported as TEXT, not a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 (the version the
Rust `xla` crate binds) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

The exported modules take the MLP **weights as runtime parameters**
(f(x, W1, b1, ...) -> y), so ONE compiled executable per topology serves all
n approximators — the XLA-level analogue of the paper's NPU weight-buffer
swap (§III.D): switching approximators ships new weights, not new programs.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import formats
from . import model as M
from . import train as T
from .benchmarks import BENCH_ORDER, BENCHMARKS, Benchmark, make_dataset
from .kernels import mlp as kmlp

BATCH_SIZES = (1, 256)
N_GOLDEN = 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_forward_hlo(topology: Sequence[int], batch: int) -> str:
    """Lower the Pallas-kernel MLP forward with weights as parameters."""

    n_layers = len(topology) - 1

    def f(x, *flat):
        params = [(flat[2 * i], flat[2 * i + 1]) for i in range(n_layers)]
        return (kmlp.mlp_forward(x, params),)

    specs = [jax.ShapeDtypeStruct((batch, topology[0]), jnp.float32)]
    for fan_in, fan_out in zip(topology[:-1], topology[1:]):
        specs.append(jax.ShapeDtypeStruct((fan_in, fan_out), jnp.float32))
        specs.append(jax.ShapeDtypeStruct((fan_out,), jnp.float32))
    lowered = jax.jit(f).lower(*specs)
    return to_hlo_text(lowered)


def build_bench(bench: Benchmark, out_dir: str, cfg: T.TrainConfig,
                methods: Sequence[str]) -> Dict:
    bdir = os.path.join(out_dir, bench.name)
    os.makedirs(bdir, exist_ok=True)
    t0 = time.time()

    X_raw = bench.gen(bench.train_n, seed=1000 + hash(bench.name) % 1000)
    Xt_raw = bench.gen(bench.test_n, seed=2000 + hash(bench.name) % 1000)
    X = bench.normalize_x(X_raw).astype(np.float32)
    Y = bench.normalize_y(bench.fn(X_raw)).astype(np.float32)
    Xt = bench.normalize_x(Xt_raw).astype(np.float32)
    Yt = bench.normalize_y(bench.fn(Xt_raw)).astype(np.float32)

    results = T.train_all(bench, X, Y, Xt, Yt, cfg, methods)
    formats.write_weights(os.path.join(bdir, "weights.bin"), list(results.values()))
    formats.write_dataset(os.path.join(bdir, "test.bin"),
                          Xt_raw.astype(np.float32), Yt)

    for b in BATCH_SIZES:
        for role, topo in (
            ("approx", bench.approx_topology),
            ("clf2", bench.clf_topology(2)),
            ("clfN", bench.clf_topology(cfg.n_approx + 1)),
        ):
            path = os.path.join(bdir, f"{role}_b{b}.hlo.txt")
            with open(path, "w") as f:
                f.write(export_forward_hlo(topo, b))

    stats = {name: [dataclasses.asdict(s) for s in r.history]
             for name, r in results.items()}

    # Fig. 7c: blackscholes is re-trained at scaled error bounds (the
    # classifier's labels depend on the bound, so a runtime-only sweep
    # would be meaningless).
    bound_scales = []
    if bench.name == "blackscholes":
        bound_scales = [0.5, 0.75, 1.5, 2.0]  # 1.0 == the default weights.bin
        for scale in bound_scales:
            bb = dataclasses.replace(bench, error_bound=bench.error_bound * scale)
            res_b = T.train_all(bb, X, Y, Xt, Yt, cfg, methods)
            tag = f"{scale:g}".replace(".", "p")
            formats.write_weights(os.path.join(bdir, f"weights_bound_{tag}.bin"),
                                  list(res_b.values()))

    # Golden vectors: target-function agreement + MLP forward agreement.
    any_approx = results[methods[0]].approximators[0]
    fwd = np.asarray(M.forward(jnp.asarray(Xt[:8]), any_approx, pallas=True))
    golden = {
        "x_raw": Xt_raw[:N_GOLDEN].astype(np.float64).tolist(),
        "y_norm": Yt[:N_GOLDEN].astype(np.float64).tolist(),
        "mlp_method": results[methods[0]].method,
        "mlp_forward_in": Xt[:8].astype(np.float64).tolist(),
        "mlp_forward_out": fwd.astype(np.float64).tolist(),
    }

    manifest_entry = {
        "domain": bench.domain,
        "n_in": bench.n_in,
        "n_out": bench.n_out,
        "approx_topology": bench.approx_topology,
        "clf2_topology": bench.clf_topology(2),
        "clfN_topology": bench.clf_topology(cfg.n_approx + 1),
        "x_lo": bench.x_lo.tolist(),
        "x_hi": bench.x_hi.tolist(),
        "y_lo": bench.y_lo.tolist(),
        "y_hi": bench.y_hi.tolist(),
        "error_bound": bench.error_bound,
        "train_n": int(X.shape[0]),
        "test_n": int(Xt.shape[0]),
        "methods": list(results.keys()),
        "mcca_pairs": len(results["mcca"].approximators) if "mcca" in results else 0,
        "bound_scales": bound_scales,
    }
    print(f"  {bench.name}: {time.time() - t0:.1f}s "
          f"(train {X.shape[0]}, test {Xt.shape[0]})")
    return {"manifest": manifest_entry, "stats": stats, "golden": golden}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--benches", default="all",
                    help="comma-separated subset of benchmarks")
    ap.add_argument("--methods", default="all")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale: 70K samples, 1500 epochs, 5 iterations")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny profile for CI: 1.5K samples, 30 epochs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-approx", type=int, default=3)
    args = ap.parse_args()

    cfg = T.TrainConfig(seed=args.seed, n_approx=args.n_approx)
    scale = 1.0
    if args.full:
        cfg.epochs = cfg.clf_epochs = 1500
        cfg.iterations = 5
        scale = 70_000 / 12_000
    elif args.smoke:
        cfg.epochs = cfg.clf_epochs = 30
        cfg.iterations = 2
        scale = 1_500 / 12_000

    benches = BENCH_ORDER if args.benches == "all" else args.benches.split(",")
    methods = (list(T.METHODS) if args.methods == "all"
               else args.methods.split(","))

    os.makedirs(args.out, exist_ok=True)
    # Merge-on-rebuild: a subset run (--benches x,y) must not clobber the
    # other benchmarks' entries in the global JSON files.
    def _load_existing(name):
        path = os.path.join(args.out, name)
        if os.path.exists(path):
            with open(path) as f:
                return json.load(f)
        return None

    manifest = _load_existing("manifest.json") or {
        "version": 1,
        "n_approx": cfg.n_approx,
        "batch_sizes": list(BATCH_SIZES),
        "train_config": dataclasses.asdict(cfg),
        "benchmarks": {},
    }
    all_stats: Dict[str, Dict] = _load_existing("train_stats.json") or {}
    all_golden: Dict[str, Dict] = _load_existing("golden.json") or {}

    t0 = time.time()
    for name in benches:
        bench = dataclasses.replace(
            BENCHMARKS[name],
            train_n=max(256, int(BENCHMARKS[name].train_n * scale)),
            test_n=max(128, int(BENCHMARKS[name].test_n * scale)),
        )
        out = build_bench(bench, args.out, cfg, methods)
        manifest["benchmarks"][name] = out["manifest"]
        all_stats[name] = out["stats"]
        all_golden[name] = out["golden"]

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(args.out, "train_stats.json"), "w") as f:
        json.dump(all_stats, f, indent=1)
    with open(os.path.join(args.out, "golden.json"), "w") as f:
        json.dump(all_golden, f, indent=1)
    print(f"artifacts written to {args.out} in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
