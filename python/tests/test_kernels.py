"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compute hot path — hypothesis
sweeps shapes, batch sizes (including ones that do not divide the batch
tile), activations and value ranges, and asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mlp as kmlp
from compile.kernels import ref as kref
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed, lo=-3.0, hi=3.0):
    r = np.random.RandomState(seed)
    return jnp.asarray(r.uniform(lo, hi, size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# dense_act
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 300),
    k=st.integers(1, 64),
    n=st.integers(1, 64),
    act=st.sampled_from(["sigmoid", "linear"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_act_matches_ref(b, k, n, act, seed):
    x = _rand((b, k), seed)
    w = _rand((k, n), seed + 1)
    bias = _rand((n,), seed + 2)
    got = kmlp.dense_act(x, w, bias, act)
    want = kref.dense_act_ref(x, w, bias, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    bm=st.sampled_from([8, 32, 128, 256]),
    b=st.integers(1, 500),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_act_any_batch_tile(bm, b, seed):
    """Batch tile never changes the numbers, only the schedule."""
    x = _rand((b, 6), seed)
    w = _rand((6, 8), seed + 1)
    bias = _rand((8,), seed + 2)
    base = kmlp.dense_act(x, w, bias, "sigmoid", bm=kmlp.DEFAULT_BM)
    got = kmlp.dense_act(x, w, bias, "sigmoid", bm=bm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-6, atol=1e-6)


def test_dense_act_rejects_bad_activation():
    x = _rand((4, 3), 0)
    w = _rand((3, 2), 1)
    b = _rand((2,), 2)
    with pytest.raises(ValueError):
        kmlp.dense_act(x, w, b, "relu")


@settings(max_examples=10, deadline=None)
@given(scale=st.sampled_from([1e-3, 1.0, 10.0, 100.0]),
       seed=st.integers(0, 2**31 - 1))
def test_dense_act_value_ranges(scale, seed):
    """Numerics hold across magnitudes (sigmoid saturation included)."""
    x = _rand((17, 9), seed, -scale, scale)
    w = _rand((9, 8), seed + 1, -scale, scale)
    b = _rand((8,), seed + 2, -scale, scale)
    for act in ("sigmoid", "linear"):
        got = kmlp.dense_act(x, w, b, act)
        want = kref.dense_act_ref(x, w, b, act)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Full MLP forward — every paper topology
# ---------------------------------------------------------------------------

PAPER_TOPOLOGIES = [
    [6, 8, 1], [1, 2, 2, 2], [2, 8, 2], [18, 32, 16, 2],
    [64, 16, 64], [6, 8, 4, 1], [9, 8, 1], [2, 4, 4, 1],
    # classifier variants
    [6, 8, 2], [6, 8, 4], [18, 16, 2], [18, 16, 4], [2, 4, 2], [2, 4, 4],
]


@pytest.mark.parametrize("topo", PAPER_TOPOLOGIES, ids=lambda t: "-".join(map(str, t)))
def test_mlp_forward_topologies(topo):
    params = M.init_mlp(topo, jax.random.PRNGKey(42))
    x = _rand((53, topo[0]), 7, 0.0, 1.0)
    got = kmlp.mlp_forward(x, params)
    want = kref.mlp_forward_ref(x, params)
    assert got.shape == (53, topo[-1])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    depth=st.integers(1, 4),
    widths=st.lists(st.integers(1, 48), min_size=5, max_size=5),
    b=st.integers(1, 130),
    seed=st.integers(0, 2**31 - 1),
)
def test_mlp_forward_random_topologies(depth, widths, b, seed):
    topo = widths[: depth + 1]
    params = M.init_mlp(topo, jax.random.PRNGKey(seed))
    x = _rand((b, topo[0]), seed)
    got = kmlp.mlp_forward(x, params)
    want = kref.mlp_forward_ref(x, params)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_vmem_footprint_monotone_in_bm():
    small = kmlp.vmem_footprint_bytes([64, 16, 64], bm=8)
    big = kmlp.vmem_footprint_bytes([64, 16, 64], bm=256)
    assert small < big
    # All paper topologies fit comfortably in 16 MiB VMEM at the default tile.
    for topo in PAPER_TOPOLOGIES:
        assert kmlp.vmem_footprint_bytes(topo) < 16 * 2**20
