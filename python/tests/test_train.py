"""Training schemes: structure, invariants, and the paper's qualitative
claims on a tractable benchmark (tiny profile to keep CI fast)."""

import dataclasses

import numpy as np
import pytest

from compile import train as T
from compile.benchmarks import BENCHMARKS, make_dataset

CFG = T.TrainConfig(epochs=40, clf_epochs=40, iterations=2, n_approx=2,
                    lr=3e-3, seed=0)


@pytest.fixture(scope="module")
def sobel_data():
    b = dataclasses.replace(BENCHMARKS["sobel"], epochs_mult=1.0)
    X, Y = make_dataset(b, 2000, seed=1)
    Xt, Yt = make_dataset(b, 600, seed=2)
    return b, X, Y, Xt, Yt


@pytest.fixture(scope="module")
def all_results(sobel_data):
    b, X, Y, Xt, Yt = sobel_data
    return T.train_all(b, X, Y, Xt, Yt, CFG)


def test_one_pass_structure(all_results):
    r = all_results["one_pass"]
    assert len(r.approximators) == 1
    assert r.clf_classes == 2
    assert len(r.history) == 1
    assert 0.0 <= r.history[0].invocation <= 1.0


def test_iterative_runs_all_iterations(all_results):
    r = all_results["iterative"]
    assert len(r.history) == CFG.iterations
    assert len(r.approximators) == 1


def test_mcca_cascade_structure(all_results):
    r = all_results["mcca"]
    assert r.cascade
    assert 1 <= len(r.approximators) <= CFG.mcca_max_pairs
    assert len(r.cascade_classifiers) == len(r.approximators)


@pytest.mark.parametrize("scheme", ["mcma_complementary", "mcma_competitive"])
def test_mcma_structure(all_results, scheme):
    r = all_results[scheme]
    assert len(r.approximators) == CFG.n_approx
    assert r.clf_classes == CFG.n_approx + 1
    assert len(r.history) == CFG.iterations
    for h in r.history:
        assert len(h.class_counts) == CFG.n_approx + 1
        assert sum(h.class_counts) == 600  # every test sample gets a class
        assert 0.0 <= h.invocation <= 1.0
        assert h.true_invocation <= h.invocation + 1e-9


def test_history_invocation_consistent_with_counts(all_results):
    r = all_results["mcma_competitive"]
    for h in r.history:
        inv_from_counts = sum(h.class_counts[:-1]) / sum(h.class_counts)
        assert abs(inv_from_counts - h.invocation) < 1e-9


def test_complementary_labels_priority():
    """A sample fit by A1 must be labelled 1 even if A2 also fits it."""
    import jax
    from compile import model as M
    # Two identical perfect approximators for y = x.
    p = [(np.eye(1, dtype=np.float32), np.zeros(1, np.float32))]
    X = np.random.RandomState(0).rand(50, 1).astype(np.float32)
    labels = T._complementary_labels([p, p], X, X, bound=0.01)
    assert (labels == 0).all()


def test_competitive_labels_lowest_error_wins():
    # A0 predicts y=0, A1 predicts y=1; targets near 1 must pick A1.
    a0 = [(np.zeros((1, 1), np.float32), np.zeros(1, np.float32))]
    a1 = [(np.zeros((1, 1), np.float32), np.ones(1, np.float32))]
    X = np.ones((20, 1), np.float32)
    Y = np.ones((20, 1), np.float32)
    labels = T._competitive_labels([a0, a1], X, Y, bound=0.5)
    assert (labels == 1).all()
    # Bound violation -> nC class (=2).
    Yfar = np.full((20, 1), 5.0, np.float32)
    labels2 = T._competitive_labels([a0, a1], X, Yfar, bound=0.5)
    assert (labels2 == 2).all()


def test_mcma_beats_one_pass_on_invocation(all_results):
    """The paper's headline direction: MCMA invokes at least as much as
    one-pass (on an approximable benchmark, with margin)."""
    one = all_results["one_pass"].history[-1].true_invocation
    best_mcma = max(all_results["mcma_complementary"].history[-1].true_invocation,
                    all_results["mcma_competitive"].history[-1].true_invocation)
    assert best_mcma >= one - 0.05  # direction, with slack for tiny profile
