"""L2 model: init, losses, RMSprop, training convergence, eval helpers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def test_init_shapes():
    topo = [6, 8, 4, 2]
    p = M.init_mlp(topo, jax.random.PRNGKey(0))
    assert len(p) == 3
    for (w, b), (fi, fo) in zip(p, zip(topo[:-1], topo[1:])):
        assert w.shape == (fi, fo)
        assert b.shape == (fo,)
        assert np.all(np.asarray(b) == 0.0)


def test_init_is_deterministic_per_seed():
    a = M.init_mlp([4, 4, 1], jax.random.PRNGKey(7))
    b = M.init_mlp([4, 4, 1], jax.random.PRNGKey(7))
    c = M.init_mlp([4, 4, 1], jax.random.PRNGKey(8))
    assert all(np.array_equal(x[0], y[0]) for x, y in zip(a, b))
    assert not all(np.array_equal(x[0], y[0]) for x, y in zip(a, c))


def test_mse_loss_zero_on_perfect_fit():
    p = [(jnp.eye(2, dtype=jnp.float32), jnp.zeros(2, jnp.float32))]
    x = jnp.asarray(np.random.RandomState(0).rand(10, 2), jnp.float32)
    assert float(M.mse_loss(p, x, x)) < 1e-12


def test_softmax_xent_decreases_with_correct_logits():
    x = jnp.asarray(np.random.RandomState(0).rand(32, 3), jnp.float32)
    labels = jnp.zeros(32, jnp.int32)
    good = [(jnp.asarray([[5.0, -5.0], [5.0, -5.0], [5.0, -5.0]], jnp.float32),
             jnp.zeros(2, jnp.float32))]
    bad = [(jnp.asarray([[-5.0, 5.0], [-5.0, 5.0], [-5.0, 5.0]], jnp.float32),
            jnp.zeros(2, jnp.float32))]
    assert float(M.softmax_xent_loss(good, x, labels)) < \
        float(M.softmax_xent_loss(bad, x, labels))


def test_rmsprop_step_moves_against_gradient():
    p = [(jnp.ones((1, 1), jnp.float32), jnp.zeros(1, jnp.float32))]
    g = [(jnp.ones((1, 1), jnp.float32), jnp.ones(1, jnp.float32))]
    s = M.rms_init(p)
    p2, s2 = M.rms_update(p, g, s, lr=0.1)
    assert float(p2[0][0][0, 0]) < 1.0
    assert float(p2[0][1][0]) < 0.0
    assert float(s2.sq[0][0][0, 0]) > 0.0


def test_train_regression_converges():
    """y = mean(x) is easily fit; loss must drop well below init."""
    r = np.random.RandomState(0)
    X = r.rand(2000, 4).astype(np.float32)
    Y = X.mean(axis=1, keepdims=True).astype(np.float32)
    p = M.train_mlp([4, 8, 1], X, Y, loss="mse", epochs=80, seed=0, lr=3e-3)
    err = np.asarray(M.per_sample_error(p, jnp.asarray(X), jnp.asarray(Y)))
    assert float(np.median(err)) < 0.02


def test_train_classifier_converges():
    """Linearly separable labels reach high accuracy."""
    r = np.random.RandomState(1)
    X = r.rand(2000, 2).astype(np.float32)
    labels = (X[:, 0] + X[:, 1] > 1.0).astype(np.int32)
    p = M.train_mlp([2, 8, 2], X, labels, loss="xent", epochs=200, seed=0, lr=3e-3)
    pred = np.asarray(M.predict_class(p, jnp.asarray(X)))
    assert (pred == labels).mean() > 0.95


def test_train_rows_subset_ignores_other_rows():
    """Territory training must not look outside its rows: poison the rest."""
    r = np.random.RandomState(2)
    X = r.rand(1000, 3).astype(np.float32)
    Y = X.sum(axis=1, keepdims=True).astype(np.float32) / 3.0
    Ypoison = Y.copy()
    rows = np.arange(500)
    Ypoison[500:] = 1e3  # absurd targets outside the territory
    p_clean = M.train_mlp([3, 8, 1], X[:500], Y[:500], loss="mse",
                          epochs=60, seed=3, lr=3e-3)
    p_rows = M.train_mlp([3, 8, 1], X, Ypoison, loss="mse", epochs=60,
                         seed=3, lr=3e-3, rows=rows)
    e_clean = np.asarray(M.per_sample_error(p_clean, jnp.asarray(X[:500]),
                                            jnp.asarray(Y[:500])))
    e_rows = np.asarray(M.per_sample_error(p_rows, jnp.asarray(X[:500]),
                                           jnp.asarray(Y[:500])))
    # Poisoned rows never sampled => comparable quality on the territory.
    assert float(np.median(e_rows)) < max(0.05, 3.0 * float(np.median(e_clean)))


def test_train_empty_rows_returns_fresh_init():
    X = np.zeros((10, 2), np.float32)
    Y = np.zeros((10, 1), np.float32)
    p = M.train_mlp([2, 4, 1], X, Y, loss="mse", epochs=5, seed=11,
                    rows=np.array([], dtype=np.int64))
    q = M.init_mlp([2, 4, 1], jax.random.PRNGKey(11))
    assert all(np.array_equal(a[0], b[0]) for a, b in zip(p, q))


def test_per_sample_error_is_rmse_over_outputs():
    p = [(jnp.zeros((2, 2), jnp.float32), jnp.zeros(2, jnp.float32))]
    x = jnp.ones((3, 2), jnp.float32)
    y = jnp.asarray([[0.0, 0.0], [1.0, 1.0], [3.0, 4.0]], jnp.float32)
    err = np.asarray(M.per_sample_error(p, x, y))
    np.testing.assert_allclose(err, [0.0, 1.0, np.sqrt(12.5)], rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_predict_class_matches_argmax(seed):
    p = M.init_mlp([3, 4], jax.random.PRNGKey(seed))
    x = jnp.asarray(np.random.RandomState(seed).rand(20, 3), jnp.float32)
    pred = np.asarray(M.predict_class(p, x))
    logits = np.asarray(M.forward(x, p))
    np.testing.assert_array_equal(pred, logits.argmax(axis=1))
