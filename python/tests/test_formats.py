"""Artifact formats: binary round-trips and HLO export sanity."""

import os

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import formats as F
from compile import model as M
from compile.aot import export_forward_hlo
from compile.train import MethodResult


def _mk_mlp(topo, seed):
    return M.params_to_numpy(M.init_mlp(topo, jax.random.PRNGKey(seed)))


def test_weights_roundtrip(tmp_path):
    m1 = MethodResult("one_pass", [_mk_mlp([6, 8, 1], 0)], _mk_mlp([6, 8, 2], 1), 2)
    m2 = MethodResult("mcma_competitive",
                      [_mk_mlp([6, 8, 1], i) for i in range(3)],
                      _mk_mlp([6, 8, 4], 9), 4)
    m3 = MethodResult("mcca", [_mk_mlp([6, 8, 1], 5)], [], 2, cascade=True,
                      cascade_classifiers=[_mk_mlp([6, 8, 2], 6),
                                           _mk_mlp([6, 8, 2], 7)])
    path = str(tmp_path / "w.bin")
    F.write_weights(path, [m1, m2, m3])
    got = F.read_weights(path)
    assert set(got) == {"one_pass", "mcma_competitive", "mcca"}
    assert got["mcma_competitive"]["clf_classes"] == 4
    assert len(got["mcma_competitive"]["approximators"]) == 3
    assert got["mcca"]["cascade"] is True
    assert len(got["mcca"]["classifiers"]) == 2
    for (w, b), (w0, b0) in zip(got["one_pass"]["approximators"][0],
                                m1.approximators[0]):
        np.testing.assert_array_equal(w, np.asarray(w0, np.float32))
        np.testing.assert_array_equal(b, np.asarray(b0, np.float32))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 200), d_in=st.integers(1, 32), d_out=st.integers(1, 8),
       seed=st.integers(0, 1 << 30))
def test_dataset_roundtrip(tmp_path_factory, n, d_in, d_out, seed):
    r = np.random.RandomState(seed)
    X = r.rand(n, d_in).astype(np.float32)
    Y = r.rand(n, d_out).astype(np.float32)
    path = str(tmp_path_factory.mktemp("ds") / "d.bin")
    F.write_dataset(path, X, Y)
    X2, Y2 = F.read_dataset(path)
    np.testing.assert_array_equal(X, X2)
    np.testing.assert_array_equal(Y, Y2)


def test_weights_magic_rejected(tmp_path):
    path = str(tmp_path / "bad.bin")
    with open(path, "wb") as f:
        f.write(b"NOPE" + b"\x00" * 16)
    with pytest.raises(AssertionError):
        F.read_weights(path)


# ---------------------------------------------------------------------------
# HLO export
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo,batch", [([2, 4, 1], 1), ([6, 8, 2], 256),
                                        ([2, 4, 4, 1], 16)])
def test_export_forward_hlo_structure(topo, batch):
    text = export_forward_hlo(topo, batch)
    assert text.startswith("HloModule")
    # Entry layout mentions the input batch and every weight/bias parameter.
    assert f"f32[{batch},{topo[0]}]" in text
    for fi, fo in zip(topo[:-1], topo[1:]):
        assert f"f32[{fi},{fo}]" in text
    # Output is a 1-tuple of the batched output (return_tuple=True).
    assert f"f32[{batch},{topo[-1]}]" in text


def test_export_contains_no_custom_calls():
    """interpret=True must lower to plain HLO the CPU PJRT client can run —
    a Mosaic custom-call here would break the Rust runtime."""
    text = export_forward_hlo([2, 4, 1], 8)
    assert "custom-call" not in text.lower()
