"""Benchmark target functions: determinism, domains, normalisation, and the
mathematical identities the Rust re-implementations rely on."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import benchmarks as B


@pytest.mark.parametrize("name", B.BENCH_ORDER)
def test_generator_deterministic_and_in_domain(name):
    b = B.BENCHMARKS[name]
    X1 = b.gen(200, seed=5)
    X2 = b.gen(200, seed=5)
    X3 = b.gen(200, seed=6)
    np.testing.assert_array_equal(X1, X2)
    assert not np.array_equal(X1, X3)
    assert X1.shape == (200, b.n_in)
    Xn = b.normalize_x(X1)
    assert Xn.min() >= -1e-9 and Xn.max() <= 1.0 + 1e-9


@pytest.mark.parametrize("name", B.BENCH_ORDER)
def test_fn_shape_and_normalised_range(name):
    b = B.BENCHMARKS[name]
    X = b.gen(500, seed=7)
    Y = b.fn(X)
    assert Y.shape == (500, b.n_out)
    Yn = b.normalize_y(Y)
    # Fixed normalisation bounds must actually cover the output range.
    assert Yn.min() >= -0.05, f"{name}: y_lo too high ({Yn.min()})"
    assert Yn.max() <= 1.05, f"{name}: y_hi too low ({Yn.max()})"


def test_erf_as_known_values():
    # vs table values of erf
    np.testing.assert_allclose(B.erf_as(np.array([0.0])), [0.0], atol=1e-7)
    np.testing.assert_allclose(B.erf_as(np.array([1.0])), [0.8427007], atol=1e-5)
    np.testing.assert_allclose(B.erf_as(np.array([-1.0])), [-0.8427007], atol=1e-5)
    np.testing.assert_allclose(B.erf_as(np.array([3.0])), [0.99997791], atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(x=st.floats(-5, 5))
def test_erf_as_odd_and_bounded(x):
    v = float(B.erf_as(np.array([x]))[0])
    mv = float(B.erf_as(np.array([-x]))[0])
    assert abs(v + mv) < 1e-12
    assert -1.0 <= v <= 1.0


def test_blackscholes_put_call_parity():
    X = B.BENCHMARKS["blackscholes"].gen(100, seed=1)
    Xc, Xp = X.copy(), X.copy()
    Xc[:, 5] = 0.0
    Xp[:, 5] = 1.0
    call = B._fn_blackscholes(Xc)[:, 0]
    put = B._fn_blackscholes(Xp)[:, 0]
    s, k, r, t = X[:, 0], X[:, 1], X[:, 2], X[:, 4]
    np.testing.assert_allclose(call - put, s - k * np.exp(-r * t), rtol=1e-8)


def test_blackscholes_intrinsic_value_bound():
    X = B.BENCHMARKS["blackscholes"].gen(500, seed=2)
    X[:, 5] = 0.0
    c = B._fn_blackscholes(X)[:, 0]
    s, k, r, t = X[:, 0], X[:, 1], X[:, 2], X[:, 4]
    assert np.all(c >= s - k * np.exp(-r * t) - 1e-6)
    assert np.all(c <= s + 1e-9)


def test_inversek2j_roundtrip():
    """fn is the exact inverse of the arm's forward kinematics."""
    b = B.BENCHMARKS["inversek2j"]
    X = b.gen(300, seed=3)
    TH = b.fn(X)
    th1, th2 = TH[:, 0], TH[:, 1]
    x = B._IK_L1 * np.cos(th1) + B._IK_L2 * np.cos(th1 + th2)
    y = B._IK_L1 * np.sin(th1) + B._IK_L2 * np.sin(th1 + th2)
    np.testing.assert_allclose(np.stack([x, y], 1), X, atol=1e-8)


def test_fft_twiddle_unit_circle():
    b = B.BENCHMARKS["fft"]
    X = b.gen(100, seed=4)
    Y = b.fn(X)
    np.testing.assert_allclose((Y**2).sum(1), 1.0, atol=1e-12)


def test_kmeans_distance():
    X = np.array([[0, 0, 0, 1, 1, 1], [0.5, 0.5, 0.5, 0.5, 0.5, 0.5]])
    d = B._fn_kmeans(X)[:, 0]
    np.testing.assert_allclose(d, [math.sqrt(3), 0.0], atol=1e-12)


def test_sobel_flat_window_zero():
    X = np.full((1, 9), 0.7)
    assert abs(B._fn_sobel(X)[0, 0]) < 1e-12


def test_sobel_vertical_edge():
    w = np.array([[0, 0, 1], [0, 0, 1], [0, 0, 1]], float).reshape(1, 9)
    v = B._fn_sobel(w)[0, 0]
    assert v > 0.5  # strong edge


def test_jpeg_roundtrip_identity_on_dc_block():
    """A flat block quantises exactly (DC quant step divides the level)."""
    level = 128.0 / 255.0  # DC coefficient = 0 after centering
    X = np.full((1, 64), level)
    Y = B.jpeg_roundtrip(X)
    np.testing.assert_allclose(Y, X, atol=1e-6)


def test_jpeg_dct_matrix_orthonormal():
    np.testing.assert_allclose(B.DCT8 @ B.DCT8.T, np.eye(8), atol=1e-12)


def test_jpeg_roundtrip_bounded_error():
    b = B.BENCHMARKS["jpeg"]
    X = b.gen(64, seed=8)
    Y = B.jpeg_roundtrip(X)
    assert np.all(Y >= 0.0) and np.all(Y <= 1.0)
    # Quantisation error is bounded: q-table max 121 over 255 scale, but
    # typical blocks reconstruct closely.
    assert float(np.sqrt(((X - Y) ** 2).mean())) < 0.2


def test_bessel_integer_orders_match_series():
    """J_n for integer n from our quadrature vs numpy's polynomial series
    evaluation via trig identities at sampled points (loose but real)."""
    # J_0(2.404825557695773) ~ 0 (first zero)
    v = B.bessel_j(np.array([0.0]), np.array([2.404825557695773]))[0]
    assert abs(v) < 1e-6
    # J_0(1) = 0.7651976866, J_1(1) = 0.4400505857
    np.testing.assert_allclose(
        B.bessel_j(np.array([0.0, 1.0]), np.array([1.0, 1.0])),
        [0.7651976866, 0.4400505857], atol=1e-7)
    # J_2(5) = 0.04656511628
    np.testing.assert_allclose(
        B.bessel_j(np.array([2.0]), np.array([5.0])), [0.04656511628], atol=1e-7)


def test_tri_tri_intersect_known_cases():
    # Identical triangles intersect.
    t = np.array([0, 0, 0, 1, 0, 0, 0, 1, 0], float)
    X = np.concatenate([t, t])[None, :]
    np.testing.assert_array_equal(B.tri_tri_intersect(X)[0], [1.0, 0.0])
    # Far-apart triangles do not.
    t2 = t + np.tile([10.0, 10.0, 10.0], 3)
    X2 = np.concatenate([t, t2])[None, :]
    np.testing.assert_array_equal(B.tri_tri_intersect(X2)[0], [0.0, 1.0])
    # Piercing triangle (crosses the plane through the middle).
    p = np.array([0.25, 0.25, -1, 0.25, 0.25, 1, 1, 1, 1], float)
    X3 = np.concatenate([t, p])[None, :]
    np.testing.assert_array_equal(B.tri_tri_intersect(X3)[0], [1.0, 0.0])


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_tri_tri_symmetry(seed):
    """intersect(P, Q) == intersect(Q, P)."""
    r = np.random.RandomState(seed)
    x = r.rand(18)
    a = B.tri_tri_intersect(x[None, :])[0]
    b = B.tri_tri_intersect(np.concatenate([x[9:], x[:9]])[None, :])[0]
    np.testing.assert_array_equal(a, b)


def test_make_dataset_float32_and_shapes():
    b = B.BENCHMARKS["sobel"]
    X, Y = B.make_dataset(b, 128, seed=9)
    assert X.dtype == np.float32 and Y.dtype == np.float32
    assert X.shape == (128, 9) and Y.shape == (128, 1)
