//! Quickstart: load the Bessel artifacts, run the MCMA coordinator over the
//! held-out test set, and print the paper's core metrics.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the smallest end-to-end use of the public API: manifest ->
//! model bank (PJRT-compiled HLO + device weights) -> dispatcher ->
//! metrics.  Python is not involved: the MLPs run from the AOT artifacts.

use mcma::config::{ExecMode, Method, RunConfig};
use mcma::coordinator::Dispatcher;
use mcma::eval::Context;

fn main() -> mcma::Result<()> {
    // 1. Load the artifact tree (manifest + PJRT runtime).
    let ctx = Context::load(RunConfig::default())?;
    let bench = ctx.man.bench("bessel")?.clone();
    println!(
        "benchmark: {} ({}), approximator {:?}, error bound {}",
        bench.name, bench.domain, bench.approx_topology, bench.error_bound
    );

    // 2. Compile the AOT HLO and upload the trained weights once.
    let methods = [Method::OnePass, Method::McmaCompetitive];
    let bank = ctx.bank(&bench, &methods)?;

    // 3. Run the coordinator: classify -> route -> approximate / CPU.
    let ds = ctx.dataset(&bench.name)?;
    for method in methods {
        let dispatcher = Dispatcher::new(&bench, &bank, method, ExecMode::Pjrt)?;
        let out = dispatcher.run_dataset(&ds)?;
        let m = &out.metrics;
        println!(
            "\n[{}] invocation {:.1}%  true invocation {:.1}%  rmse/bound {:.2}  recall {:.2}",
            method.label(),
            100.0 * m.invocation(),
            100.0 * m.true_invocation(),
            m.rmse_over_bound,
            m.recall(),
        );
        println!(
            "  routed per approximator: {:?}, CPU fallback: {}",
            m.per_class, m.cpu_count
        );
    }
    println!("\nMCMA's extra approximators salvage samples one-pass rejects — the paper's Fig. 1(c).");
    Ok(())
}
