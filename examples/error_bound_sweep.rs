//! Error-bound sweep on Black-Scholes (paper Fig. 7c as a runnable
//! example): every method is evaluated with the weights retrained at each
//! bound, showing MCMA's invocation degrades the least as the quality
//! requirement tightens.
//!
//!     cargo run --release --example error_bound_sweep

use mcma::config::RunConfig;
use mcma::eval::{fig7c, Context};

fn main() -> mcma::Result<()> {
    let ctx = Context::load(RunConfig::default())?;
    let f = fig7c::run(&ctx)?;
    f.table().print();

    println!("\nInvocation drop from the loosest (2.0x) to the tightest (0.5x) bound:");
    let mut drops = f.drop_per_method();
    drops.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    for (m, d) in &drops {
        println!("  {:<12} {:+.1} pp", m.label(), 100.0 * d);
    }
    if let Some((best, _)) = drops.first() {
        println!(
            "\nsmallest drop: {} — \"the proposed architecture is more desired for \
             those approximate critical applications\" (paper §IV.B)",
            best.label()
        );
    }
    Ok(())
}
