//! NPU design-space exploration: sweep PE count and weight-buffer capacity
//! on a fixed MCMA routing trace and report speedup / energy / weight-switch
//! behaviour — the hardware-design companion to paper §III.D.
//!
//!     cargo run --release --example npu_design_space [bench]
//!
//! The routing trace is computed once (native engine: this example explores
//! the NPU model, not PJRT), then re-simulated under each configuration.

use mcma::bench_harness::Table;
use mcma::config::{ExecMode, Method, NpuConfig, RunConfig};
use mcma::coordinator::BufferCase;
use mcma::eval::{self, Context};
use mcma::npu::NpuSim;

fn main() -> mcma::Result<()> {
    let bench_name = std::env::args().nth(1).unwrap_or_else(|| "jpeg".to_string());
    let cfg = RunConfig { exec: ExecMode::Native, ..Default::default() };
    let ctx = Context::load(cfg)?;
    let bench = ctx.man.bench(&bench_name)?.clone();
    let method = Method::McmaCompetitive;
    let bank = ctx.bank(&bench, &[method])?;
    let e = eval::eval_one(&ctx, &bench, &bank, method)?;
    let routes = &e.out.plan.routes;
    let benchfn = mcma::benchmarks::by_name(&bench_name)?;
    println!(
        "bench {}, {} samples, invocation {:.1}%",
        bench_name,
        routes.len(),
        100.0 * e.out.metrics.invocation()
    );

    // --- Sweep 1: PEs per tile ---
    let mut t = Table::new(
        "PE sweep (weight buffer 2048 words/PE)",
        &["PEs/tile", "approx cycles/sample", "speedup vs cpu", "energy red."],
    );
    for pes in [2usize, 4, 8, 16, 32] {
        let npu = NpuConfig { pes_per_tile: pes, ..Default::default() };
        let sim = mk_sim(npu, &bench, bank.n_approx(method), benchfn.cpu_cycles());
        let r = sim.simulate(routes, None);
        t.row(vec![
            pes.to_string(),
            format!("{:.1}", r.cycles_approx / (e.out.metrics.invoked.max(1)) as f64),
            format!("{:.2}x", r.speedup_vs_cpu()),
            format!("{:.2}x", r.energy_reduction_vs_cpu()),
        ]);
    }
    t.print();

    // --- Sweep 2: weight buffer capacity (drives §III.D cases) ---
    let mut t2 = Table::new(
        "Weight-buffer sweep (8 PEs/tile)",
        &["words/PE", "case", "switches", "switch cycles", "speedup vs cpu"],
    );
    for words in [8usize, 64, 256, 1024, 4096] {
        let npu = NpuConfig { weight_buffer_words: words, ..Default::default() };
        let sim = mk_sim(npu, &bench, bank.n_approx(method), benchfn.cpu_cycles());
        let r = sim.simulate(routes, None);
        let case = mcma::coordinator::WeightCache::new(
            &npu,
            (0..bank.n_approx(method))
                .map(|k| bank.host_mlp(method, mcma::runtime::Role::Approx, k).unwrap().n_params())
                .collect(),
        )
        .case();
        t2.row(vec![
            words.to_string(),
            format!("{case:?}"),
            r.weight_switches.to_string(),
            format!("{:.0}", r.cycles_weight_switch),
            format!("{:.2}x", r.speedup_vs_cpu()),
        ]);
    }
    t2.print();

    // --- Sweep 3: forced buffer cases on the default config ---
    let mut t3 = Table::new(
        "Forced §III.D cases (default NPU)",
        &["case", "cycles", "speedup vs cpu", "energy red."],
    );
    for (name, case) in [
        ("1: all resident", BufferCase::AllResident),
        ("2: stream always", BufferCase::StreamAlways),
        ("3: one resident", BufferCase::OneResident),
    ] {
        let sim = mk_sim(NpuConfig::default(), &bench, bank.n_approx(method), benchfn.cpu_cycles());
        let r = sim.simulate(routes, Some(case));
        t3.row(vec![
            name.to_string(),
            format!("{:.0}", r.cycles),
            format!("{:.2}x", r.speedup_vs_cpu()),
            format!("{:.2}x", r.energy_reduction_vs_cpu()),
        ]);
    }
    t3.print();
    Ok(())
}

fn mk_sim(
    npu: NpuConfig,
    bench: &mcma::formats::BenchManifest,
    n_approx: usize,
    cpu_cycles: u64,
) -> NpuSim {
    let approx: Vec<Vec<usize>> = (0..n_approx).map(|_| bench.approx_topology.clone()).collect();
    NpuSim::new(npu, &bench.clfn_topology, &approx, cpu_cycles)
}
