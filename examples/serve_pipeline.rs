//! End-to-end serving driver (the DESIGN.md "end-to-end validation" run):
//! spin up the threaded pipeline (batcher thread + dispatch worker), push an
//! open-loop stream of Black-Scholes pricing requests through it, and report
//! throughput, latency percentiles and routing statistics.
//!
//!     cargo run --release --example serve_pipeline [n_requests]
//!
//! All inference on the request path is the AOT-lowered Pallas/JAX HLO
//! running under PJRT inside the dispatch worker; rejected samples fall
//! back to the precise Rust implementation of Black-Scholes.

use std::sync::Arc;
use std::time::{Duration, Instant};

use mcma::benchmarks;
use mcma::config::{BatchPolicy, ExecMode, Method};
use mcma::coordinator::{Server, ServerConfig};
use mcma::formats::Manifest;
use mcma::util::rng::Rng;

fn main() -> mcma::Result<()> {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000);

    let man = Arc::new(Manifest::load(&mcma::artifacts_dir())?);
    let bench = Arc::new(man.bench("blackscholes")?.clone());
    let benchfn = benchmarks::by_name("blackscholes")?;

    let cfg = ServerConfig {
        policy: BatchPolicy { max_batch: 256, max_wait_us: 2_000 },
        method: Method::McmaCompetitive,
        exec: ExecMode::Pjrt,
        workers: 2,
    };
    println!(
        "serving {} blackscholes requests, batch<= {}, wait<= {} µs, method {}",
        n_requests, cfg.policy.max_batch, cfg.policy.max_wait_us, cfg.method.label()
    );

    let server = Server::spawn(Arc::clone(&man), Arc::clone(&bench), cfg)?;

    // Warmup handshake: the dispatch worker compiles the HLO lazily inside
    // its thread; wait for one round trip so queueing measurements below
    // exclude compilation.
    let mut rng = Rng::new(7);
    let mut x = vec![0.0f32; bench.n_in];
    benchfn.gen_into(&mut rng, &mut x);
    server.submit(u64::MAX, x.clone())?;
    let warmup = server
        .recv_timeout(Duration::from_secs(30))
        .ok_or_else(|| anyhow::anyhow!("warmup request timed out"))?;
    println!("warmup round trip: {:.1} ms (includes PJRT compile)", warmup.latency_us / 1e3);

    // Phase 1 — saturation: open-loop burst with small gaps; reported
    // latency is dominated by queueing, the interesting number is
    // throughput.
    let mut collected = vec![warmup];
    let t0 = Instant::now();
    for id in 0..n_requests as u64 {
        benchfn.gen_into(&mut rng, &mut x);
        server.submit(id, x.clone())?;
        if id % 1024 == 1023 {
            std::thread::sleep(Duration::from_micros(300));
        }
    }
    let submit_wall = t0.elapsed();

    // Drain phase 1 completely so paced measurements don't queue behind
    // the saturation backlog.
    while collected.len() < n_requests + 1 {
        match server.recv_timeout(Duration::from_secs(10)) {
            Some(r) => collected.push(r),
            None => anyhow::bail!("saturation phase stalled"),
        }
    }

    // Phase 2 — paced: arrival rate well under capacity; latency now
    // reflects batching wait + service time, not queue depth.
    let mut paced = Vec::new();
    for id in 0..512u64 {
        benchfn.gen_into(&mut rng, &mut x);
        server.submit(u64::MAX - 1 - id, x.clone())?;
        while let Some(r) = server.recv_timeout(Duration::from_micros(50)) {
            collected.push(r);
        }
        std::thread::sleep(Duration::from_micros(40));
    }
    std::thread::sleep(Duration::from_millis(20));
    while let Some(r) = server.recv_timeout(Duration::from_millis(5)) {
        collected.push(r);
    }
    for r in &collected {
        if r.id > u64::MAX - 600 && r.id != u64::MAX {
            paced.push(r.latency_us);
        }
    }

    let report = server.shutdown(collected)?;
    println!("\n--- serve_pipeline report ---");
    println!("served            : {}", report.served);
    println!("submit wall       : {:.1} ms", submit_wall.as_secs_f64() * 1e3);
    println!("total wall        : {:.1} ms", report.wall.as_secs_f64() * 1e3);
    println!("throughput        : {:.0} req/s", report.throughput_rps());
    println!("invocation        : {:.1}%", 100.0 * report.invocation());
    println!(
        "batches           : {} (full {}, timeout {})",
        report.batches, report.flushes_full, report.flushes_timeout
    );
    println!(
        "latency (saturation, queue-dominated) p50/p95/p99: {:.0} / {:.0} / {:.0} µs",
        report.latency.p50(),
        report.latency.p95(),
        report.latency.p99()
    );
    if !paced.is_empty() {
        println!(
            "latency (paced, service+batch wait)  p50/p95/p99: {:.0} / {:.0} / {:.0} µs",
            mcma::util::stats::percentile(&paced, 50.0),
            mcma::util::stats::percentile(&paced, 95.0),
            mcma::util::stats::percentile(&paced, 99.0),
        );
    }
    assert_eq!(
        report.served as usize,
        n_requests + 1 + 512,
        "no request may be dropped"
    );
    println!("\nOK — all {} requests served.", n_requests);
    Ok(())
}
