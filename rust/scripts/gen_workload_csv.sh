#!/usr/bin/env bash
# Generate a small synthetic CSV workload for the workload-smoke CI job
# (and local experiments): two input columns, one label column whose
# slope in x1 flips sign across x0 = 0.5 — a function one tiny net
# struggles with but two specialised approximators cover, i.e. the
# smallest workload where MCMA visibly wins.
#
# Usage: gen_workload_csv.sh OUT.csv [ROWS=1500] [SEED=7]
#
# awk's srand(SEED) stream is implementation-defined but stable within a
# runner image; nothing downstream depends on the exact rows, only on the
# CSV contract (header + finite numeric cells).
set -euo pipefail

out="${1:?usage: gen_workload_csv.sh OUT.csv [ROWS] [SEED]}"
rows="${2:-1500}"
seed="${3:-7}"

awk -v n="$rows" -v seed="$seed" 'BEGIN {
    srand(seed)
    print "x0,x1,y"
    for (i = 0; i < n; i++) {
        x0 = rand(); x1 = rand()
        y = (x0 < 0.5) ? 0.15 + 0.3 * x1 : 0.85 - 0.3 * x1
        printf "%.6f,%.6f,%.6f\n", x0, x1, y
    }
}' > "$out"

echo "wrote $rows rows to $out" >&2
