#!/usr/bin/env bash
# Validate an OpenMetrics/Prometheus text exposition dump (as served by
# `mcma serve --metrics-listen` on GET /metrics) without any external
# tooling — awk only, so the CI expo-smoke job and local runs share one
# format gate.
#
# Checks:
#   1. the document ends with the `# EOF` terminator;
#   2. every sample line's metric name has a `# TYPE` header for its
#      family (histogram `_bucket`/`_sum`/`_count` map to the family);
#   3. every family declared `counter` only has samples ending in
#      `_total` (modulo labels);
#   4. within each histogram (family, label set), the `le` bucket values
#      are cumulative (non-decreasing in file order) and the `+Inf`
#      bucket equals the matching `_count` sample;
#   5. every sample value parses as a number.
#
# Usage: check_openmetrics.sh METRICS.txt
set -euo pipefail

file="${1:?usage: check_openmetrics.sh METRICS.txt}"

[ -s "$file" ] || { echo "FAIL: $file is empty or missing" >&2; exit 1; }

tail -n 1 "$file" | grep -qx '# EOF' || {
    echo "FAIL: missing '# EOF' terminator" >&2
    exit 1
}

awk '
function fail(msg) { print "FAIL: line " NR ": " msg > "/dev/stderr"; bad = 1 }
# Family-plus-labels key shared by a histogram group: the series with
# its `le` pair and the _bucket/_sum/_count suffix stripped.
#   mcma_stage_queue_us_bucket{le="7"}              -> mcma_stage_queue_us
#   mcma_route_execute_us_bucket{class="1",le="7"}  -> mcma_route_execute_us{class="1"}
#   mcma_route_execute_us_count{class="1"}          -> mcma_route_execute_us{class="1"}
function histkey(series) {
    sub(/le="[^"]*",?/, "", series)
    sub(/,}/, "}", series)
    sub(/{}/, "", series)
    sub(/_(bucket|sum|count)/, "", series)
    return series
}
/^# TYPE / { type[$3] = $4; next }
/^#/ { next }
/^$/ { next }
{
    # series = everything before the LAST space; value = the rest
    if (!match($0, / [^ ]+$/)) { fail("no value field"); next }
    series = substr($0, 1, RSTART - 1)
    value = substr($0, RSTART + 1)
    if (value !~ /^[+-]?([0-9]*\.?[0-9]+([eE][+-]?[0-9]+)?|Inf|NaN)$/)
        fail("unparseable value \"" value "\"")

    name = series
    sub(/{.*/, "", name)
    fam = name
    if (!(fam in type)) { sub(/_(bucket|sum|count)$/, "", fam) }
    if (!(fam in type)) { fail("no # TYPE for " series); next }

    if (type[fam] == "counter" && name !~ /_total$/)
        fail("counter sample " name " does not end in _total")

    if (type[fam] == "histogram") {
        key = histkey(series)
        if (name ~ /_bucket$/) {
            if (series ~ /le="\+Inf"/) {
                inf[key] = value
            } else {
                if ((key in cum) && value + 0 < cum[key] + 0)
                    fail("bucket series " series " not cumulative")
                cum[key] = value
            }
        }
        if (name ~ /_count$/) count[key] = value
    }
    next
}
END {
    for (k in inf) {
        if (!(k in count)) { fail("no _count for histogram " k); continue }
        if (inf[k] + 0 != count[k] + 0)
            fail("+Inf bucket " inf[k] " != _count " count[k] " for " k)
        if ((k in cum) && cum[k] + 0 > count[k] + 0)
            fail("finite buckets exceed _count for " k)
    }
    for (k in count)
        if (!(k in inf)) fail("histogram " k " has _count but no +Inf bucket")
    exit bad
}
' "$file" || { echo "FAIL: $file violates the OpenMetrics contract" >&2; exit 1; }

samples=$(grep -cv '^#' "$file" || true)
echo "ok: $file ($samples samples) passes the OpenMetrics format checks" >&2
