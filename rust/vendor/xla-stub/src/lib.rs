//! Stub of the `xla-rs` API surface the coordinator uses.
//!
//! Lets `--features pjrt` typecheck in environments without the XLA PJRT
//! extension; every constructor fails at runtime with a clear error, and
//! `mcma::runtime::Runtime::cpu()` surfaces it before anything else runs.
//! Point `[patch]` at the real `xla` crate to get working PJRT execution.

use std::fmt;

/// Error type mirroring xla-rs (only `Debug` is relied upon).
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error("PJRT unavailable (stub crate; patch in real xla-rs)".into()))
}

pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unavailable()
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

pub struct Literal(());

impl Literal {
    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        unavailable()
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}
