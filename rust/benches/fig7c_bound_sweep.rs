//! Regenerates paper Fig. 7(c): invocation vs error bound on Black-Scholes
//! (per-bound retrained weights from the Python build).

use mcma::config::RunConfig;
use mcma::eval::{fig7c, Context};

fn main() -> mcma::Result<()> {
    let ctx = Context::load(RunConfig::default())?;
    let f = fig7c::run(&ctx)?;
    f.table().print();
    println!("\ninvocation drop (2.0x -> 0.5x bound), smaller is better:");
    for (m, d) in f.drop_per_method() {
        println!("  {:<12} {:+.1} pp", m.label(), 100.0 * d);
    }
    Ok(())
}
