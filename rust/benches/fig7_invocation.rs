//! Regenerates paper Fig. 7(a) (invocation) and Fig. 7(b) (normalised
//! approximation error) across the full benchmark suite and all five
//! methods, on the real PJRT path.  Run via `cargo bench`.

use mcma::config::RunConfig;
use mcma::eval::{fig7, fig8, Context};

fn main() -> mcma::Result<()> {
    let ctx = Context::load(RunConfig::default())?;
    let t0 = std::time::Instant::now();
    let f7 = fig7::run(&ctx)?;
    f7.table_a(&ctx).print();
    f7.table_b(&ctx).print();

    let (inv_gain, err_red) = f7.mcma_gain_over_one_pass(&ctx);
    println!(
        "\nheadline: best-MCMA invocation {:+.0}% vs one-pass (paper: +27%), \
         error {:+.0}% (paper: -10%)",
        100.0 * inv_gain,
        -100.0 * err_red
    );

    // Also print the Fig. 8 views from the same traces so the bench is the
    // one-stop regeneration for the main result table.
    let f8 = fig8::run(&ctx, &f7)?;
    f8.table_a(&ctx).print();
    f8.table_b(&ctx).print();
    println!(
        "\nregenerated Fig 7(a,b) + Fig 8(a,b) in {:.1} s",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
