//! L3 hot-path microbenchmarks (§Perf): where does a request's time go?
//!
//! * native MLP forward (single / batched, packed GEMM vs scalar GEMV,
//!   f32 vs the int8 quantized engine — both precisions report rows/sec
//!   so the speedup ratio is machine-readable in `BENCH_hotpath.json`)
//! * PJRT executable run at B=1 and B=256 — dispatch + execute cost
//! * classify -> route -> execute for one full batch (the serving unit),
//!   through the zero-allocation scratch-arena path, f32 and int8
//! * batcher push/flush overhead
//!
//! Criterion is unavailable offline; `mcma::bench_harness` provides
//! warm-up, calibration and percentile reporting.  Results are also
//! written to `BENCH_hotpath.json` at the repo root (override the
//! directory with `MCMA_BENCH_JSON_DIR`) so the perf trajectory is
//! tracked across PRs.  Without artifacts the suite falls back to
//! synthetic blackscholes-shaped nets so the native kernel numbers are
//! always measurable (CI smoke: set `MCMA_BENCH_BUDGET_MS=5`).

use std::collections::HashMap;
use std::time::Duration;

use mcma::bench_harness::{bench_json_path, Recorder};
use mcma::config::{BatchPolicy, ExecMode, Method, RunConfig};
use mcma::coordinator::{Batcher, Dispatcher, RoutePlan, Scratch};
use mcma::eval::Context;
use mcma::formats::weights::{MethodWeights, WeightsFile};
use mcma::formats::BenchManifest;
use mcma::nn::{GemmScratch, Kernel, QGemmScratch};
use mcma::runtime::{ModelBank, Role};
use mcma::util::rng::Rng;

fn budget() -> Duration {
    let ms = std::env::var("MCMA_BENCH_BUDGET_MS")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(400);
    Duration::from_millis(ms.max(1))
}

fn main() -> mcma::Result<()> {
    let mut rec = Recorder::new();
    let b = budget();
    println!("SIMD kernel: {}", Kernel::detect().name());

    // Prefer real artifacts (PJRT if compiled in, else native-only); fall
    // back to synthetic nets so the kernel numbers are always measurable.
    if let Ok(ctx) = Context::load(RunConfig::default()) {
        artifact_suite(&mut rec, &ctx, b, true)?;
    } else if let Ok(ctx) =
        Context::load(RunConfig { exec: ExecMode::Native, ..Default::default() })
    {
        println!("--- PJRT unavailable: native-only artifact suite ---");
        artifact_suite(&mut rec, &ctx, b, false)?;
    } else {
        println!("--- artifacts not built: synthetic blackscholes-shaped suite ---");
        synthetic_suite(&mut rec, b)?;
    }

    // QoS control plane overhead (artifact-independent): the per-request
    // hot-path cost is ONE hash pick; observe/tick/publish run off-path
    // on the controller thread, but their cost bounds how fast the loop
    // can react, so it is tracked here too.
    qos_benches(&mut rec, b);

    rec.write_json("hotpath", &bench_json_path("BENCH_hotpath.json"))
}

/// Overhead of the QoS subsystem pieces (see `rust/src/qos/`).
fn qos_benches(rec: &mut Recorder, budget: Duration) {
    use mcma::qos::{Controller, QosConfig, ShadowSampler};
    println!("--- QoS control plane ---");
    let sampler = ShadowSampler::new(0x5AD0, 0.05);
    let mut picks = 0u64;
    rec.bench_rows("qos shadow-sampler pick x256", budget, 256, || {
        for id in 0..256u64 {
            picks += sampler.pick(id) as u64;
        }
        std::hint::black_box(picks);
    });

    // A controller with warm windows: 64 observations + one control tick,
    // the unit of work the mcma-qos thread performs per tick interval.
    let mut ctrl = Controller::new(
        QosConfig { window: 256, tick_every: 64, ..QosConfig::default() },
        4,
    );
    let mut e = 0.01f64;
    rec.bench("qos controller observe x64 + tick (K=4, win 256)", budget, || {
        for i in 0..64usize {
            e = if e > 0.2 { 0.01 } else { e + 1e-4 };
            ctrl.observe(i % 4, e);
        }
        ctrl.tick();
        std::hint::black_box(ctrl.ticks());
    });

    // Controller-side margin snapshot (what the mcma-qos thread does
    // after a tick before publishing).  The worker-side read is 4
    // relaxed atomic loads + from_bits, private to the server — strictly
    // cheaper than this copy.
    let mut margins: Vec<f32> = Vec::new();
    rec.bench("qos controller margins_into (K=4)", budget, || {
        ctrl.margins_into(&mut margins);
        std::hint::black_box(&margins);
    });
}

/// The full suite over real artifacts (blackscholes, MCMA-competitive).
fn artifact_suite(
    rec: &mut Recorder,
    ctx: &Context,
    budget: Duration,
    pjrt: bool,
) -> mcma::Result<()> {
    let bench_man = ctx.man.bench("blackscholes")?.clone();
    let method = Method::McmaCompetitive;
    let bank = ctx.bank(&bench_man, &[method])?;
    let ds = ctx.dataset("blackscholes")?;
    let d_native = Dispatcher::new(&bench_man, &bank, method, ExecMode::Native)?;
    let d_q8 = Dispatcher::new(&bench_man, &bank, method, ExecMode::NativeQ8)?;

    let x_norm = d_native.normalize(&ds.x_raw, ds.n);
    let raw256 = &ds.x_raw[..256 * bench_man.n_in];
    let batch256 = &x_norm[..256 * bench_man.n_in];
    let one = &x_norm[..bench_man.n_in];

    println!("--- L3 hot path (blackscholes, {}) ---", method.label());
    native_benches(rec, budget, &bank, &d_native, &d_q8, method, one, batch256, raw256);

    if pjrt {
        let d_pjrt = Dispatcher::new(&bench_man, &bank, method, ExecMode::Pjrt)?;
        rec.bench("pjrt approx run B=1", budget, || {
            std::hint::black_box(d_pjrt.forward(Role::Approx, 0, one, 1).unwrap());
        });
        rec.bench("pjrt approx run B=256", budget, || {
            std::hint::black_box(d_pjrt.forward(Role::Approx, 0, batch256, 256).unwrap());
        });
        rec.bench("pjrt clfN run B=256", budget, || {
            std::hint::black_box(d_pjrt.forward(Role::ClfN, 0, batch256, 256).unwrap());
        });
        rec.bench("dispatch unit (classify+route+exec) pjrt B=256", budget, || {
            let plan = d_pjrt.plan(batch256, 256).unwrap();
            std::hint::black_box(d_pjrt.execute_plan(&plan, batch256, raw256, 256).unwrap());
        });
    }

    common_tail(rec, budget, &bench_man, &ds.x_raw[..bench_man.n_in]);
    Ok(())
}

/// Synthetic fallback: blackscholes-shaped manifest + random nets.  Keeps
/// the acceptance-tracked native bench names measurable with no artifacts.
fn synthetic_suite(rec: &mut Recorder, budget: Duration) -> mcma::Result<()> {
    let man = synthetic_manifest();
    let method = Method::McmaCompetitive;
    let mut rng = Rng::new(0xB00C);
    let host = synthetic_weights(&mut rng);
    let bank = ModelBank::from_host("blackscholes", host);
    let d_native = Dispatcher::new(&man, &bank, method, ExecMode::Native)?;
    let d_q8 = Dispatcher::new(&man, &bank, method, ExecMode::NativeQ8)?;

    // Raw inputs from the precise function's own generator (valid domain).
    let benchfn = mcma::benchmarks::by_name("blackscholes")?;
    let mut x_raw = vec![0.0f32; 256 * man.n_in];
    for i in 0..256 {
        benchfn.gen_into(&mut rng, &mut x_raw[i * man.n_in..(i + 1) * man.n_in]);
    }
    let x_norm = d_native.normalize(&x_raw, 256);

    println!("--- L3 hot path (synthetic blackscholes, {}) ---", method.label());
    native_benches(
        rec,
        budget,
        &bank,
        &d_native,
        &d_q8,
        method,
        &x_norm[..man.n_in],
        &x_norm,
        &x_raw,
    );
    common_tail(rec, budget, &man, &x_raw[..man.n_in]);
    Ok(())
}

/// Native engine floor (f32 packed, int8 quantized, scalar GEMV baseline)
/// + the serving unit through the scratch arena in both precisions.
#[allow(clippy::too_many_arguments)]
fn native_benches(
    rec: &mut Recorder,
    budget: Duration,
    bank: &ModelBank,
    d_native: &Dispatcher,
    d_q8: &Dispatcher,
    method: Method,
    one: &[f32],
    batch256: &[f32],
    raw256: &[f32],
) {
    let mlp = bank.host_mlp(method, Role::Approx, 0).unwrap();
    let packed = bank.host_packed(method, Role::Approx, 0).unwrap();
    let packed_q8 = bank.host_packed_q8(method, Role::Approx, 0).unwrap();
    let mut gemm = GemmScratch::new();
    let mut qgemm = QGemmScratch::new();
    let mut out256 = vec![0.0f32; 256 * packed.n_out()];

    rec.bench("native mlp forward x1", budget, || {
        std::hint::black_box(mlp.forward1(one));
    });
    rec.bench_rows("native mlp forward x256", budget, 256, || {
        packed.forward_batch_to(batch256, 256, &mut gemm, &mut out256);
        std::hint::black_box(&out256);
    });
    rec.bench_rows("native mlp forward x256 (int8)", budget, 256, || {
        packed_q8.forward_batch_to(batch256, 256, &mut qgemm, &mut out256);
        std::hint::black_box(&out256);
    });
    // The PR 1 kernel exactly: the packed tiled f32 path forced onto the
    // scalar micro-kernel (no explicit SIMD).  The int8 acceptance bar is
    // >= 2x this case's rows/sec.
    let packed_scalar = packed.clone().with_kernel(Kernel::Scalar);
    let mut gemm_s = GemmScratch::new();
    rec.bench_rows("native mlp forward x256 (f32 scalar-tiled)", budget, 256, || {
        packed_scalar.forward_batch_to(batch256, 256, &mut gemm_s, &mut out256);
        std::hint::black_box(&out256);
    });
    // The pre-PR 1 streaming GEMV, kept for the long-run ratio.
    rec.bench_rows("native mlp forward x256 (scalar gemv)", budget, 256, || {
        std::hint::black_box(mlp.forward_batch(batch256, 256));
    });

    let mut plan = RoutePlan::default();
    let mut scratch = Scratch::new();
    let mut y = Vec::new();
    rec.bench_rows("dispatch unit native B=256", budget, 256, || {
        d_native.plan_into(batch256, 256, &mut plan, &mut scratch).unwrap();
        d_native
            .execute_plan_into(&plan, batch256, raw256, 256, &mut y, &mut scratch)
            .unwrap();
        std::hint::black_box(&y);
    });
    rec.bench_rows("dispatch unit native-q8 B=256", budget, 256, || {
        d_q8.plan_into(batch256, 256, &mut plan, &mut scratch).unwrap();
        d_q8.execute_plan_into(&plan, batch256, raw256, 256, &mut y, &mut scratch)
            .unwrap();
        std::hint::black_box(&y);
    });
}

/// Batcher + precise-CPU + lookup-index benches shared by both suites.
fn common_tail(rec: &mut Recorder, budget: Duration, bench: &BenchManifest, one_raw: &[f32]) {
    let d_in = one_raw.len();
    let mut rng = Rng::new(3);
    let reqs: Vec<Vec<f32>> = (0..256)
        .map(|_| (0..d_in).map(|_| rng.uniform(0.0, 1.0) as f32).collect())
        .collect();
    rec.bench("batcher push+flush 256 reqs", budget, || {
        let mut b = Batcher::new(BatchPolicy { max_batch: 256, max_wait_us: 10_000 }, d_in);
        for (i, r) in reqs.iter().enumerate() {
            std::hint::black_box(b.push(i as u64, r.clone(), std::time::Instant::now()));
        }
    });

    // Precise CPU path cost (the thing approximation avoids).
    let benchfn = mcma::benchmarks::by_name("blackscholes").unwrap();
    let mut out = vec![0.0f64; 1];
    rec.bench("precise cpu eval x1", budget, || {
        benchfn.eval(one_raw, &mut out);
        std::hint::black_box(out[0]);
    });

    // Precise-fallback lookup index: the k-d tree vs the linear scan it
    // replaced, over a synthetic bench-shaped 4096-row store (the table-
    // workload store is the held-out split; this keeps the ratio
    // measurable without artifacts).
    let n_store = 4096;
    let d_out = bench.n_out.max(1);
    let mut store = mcma::formats::Dataset {
        n: n_store,
        d_in,
        d_out,
        x_raw: Vec::with_capacity(n_store * d_in),
        y_norm: vec![0.0; n_store * d_out],
    };
    for _ in 0..n_store {
        for d in 0..d_in {
            store
                .x_raw
                .push(rng.uniform(bench.x_lo[d] as f64, bench.x_hi[d] as f64) as f32);
        }
    }
    let lookup = mcma::workload::NearestLookup::from_dataset(bench, &store);
    let queries: Vec<Vec<f32>> = (0..64)
        .map(|_| {
            (0..d_in)
                .map(|d| rng.uniform(bench.x_lo[d] as f64, bench.x_hi[d] as f64) as f32)
                .collect()
        })
        .collect();
    for q in &queries {
        assert_eq!(lookup.nearest(q), lookup.nearest_scan(q), "kd-tree/scan disagreement");
    }
    let (q0, v0) = lookup.query_stats();
    rec.bench_rows("precise lookup kd-tree x64 (4096-row store)", budget, 64, || {
        for q in &queries {
            std::hint::black_box(lookup.nearest(q));
        }
    });
    let (q1, v1) = lookup.query_stats();
    rec.bench_rows("precise lookup linear scan x64 (4096-row store)", budget, 64, || {
        for q in &queries {
            std::hint::black_box(lookup.nearest_scan(q));
        }
    });
    if q1 > q0 {
        rec.extra("lookup_visits_per_query", (v1 - v0) as f64 / (q1 - q0) as f64);
    }
}

fn synthetic_manifest() -> BenchManifest {
    BenchManifest {
        name: "blackscholes".into(),
        domain: "synthetic".into(),
        kind: mcma::formats::WorkloadKind::Synthetic,
        source_digest: String::new(),
        n_in: 6,
        n_out: 1,
        approx_topology: vec![6, 8, 8, 1],
        clf2_topology: vec![6, 8, 2],
        clfn_topology: vec![6, 8, 4],
        x_lo: vec![0.0; 6],
        x_hi: vec![1.0; 6],
        y_lo: vec![0.0],
        y_hi: vec![1.0],
        error_bound: 0.05,
        train_n: 0,
        test_n: 0,
        methods: vec!["mcma_competitive".into()],
        mcca_pairs: 0,
    }
}

fn synthetic_weights(rng: &mut Rng) -> WeightsFile {
    use mcma::util::prop::gens;
    let mw = MethodWeights {
        method: "mcma_competitive".into(),
        cascade: false,
        clf_classes: 4,
        classifiers: vec![gens::mlp(rng, &[6, 8, 4], 1.0, 0.5)],
        approximators: (0..3).map(|_| gens::mlp(rng, &[6, 8, 8, 1], 1.0, 0.5)).collect(),
    };
    let mut methods = HashMap::new();
    methods.insert("mcma_competitive".to_string(), mw);
    WeightsFile { methods }
}
