//! L3 hot-path microbenchmarks (§Perf): where does a request's time go?
//!
//! * native MLP forward (single / batched) — the floor for L3 logic
//! * PJRT executable run at B=1 and B=256 — dispatch + execute cost
//! * classify -> route -> execute for one full batch (the serving unit)
//! * batcher push/flush overhead
//!
//! Criterion is unavailable offline; `mcma::bench_harness` provides
//! warm-up, calibration and percentile reporting.

use std::time::Duration;

use mcma::bench_harness::bench;
use mcma::config::{BatchPolicy, ExecMode, Method, RunConfig};
use mcma::coordinator::{Batcher, Dispatcher};
use mcma::eval::Context;
use mcma::runtime::Role;
use mcma::util::rng::Rng;

fn main() -> mcma::Result<()> {
    let budget = Duration::from_millis(400);
    let ctx = Context::load(RunConfig::default())?;
    let bench_man = ctx.man.bench("blackscholes")?.clone();
    let method = Method::McmaCompetitive;
    let bank = ctx.bank(&bench_man, &[method])?;
    let ds = ctx.dataset("blackscholes")?;
    let d_pjrt = Dispatcher::new(&bench_man, &bank, method, ExecMode::Pjrt)?;
    let d_native = Dispatcher::new(&bench_man, &bank, method, ExecMode::Native)?;

    let x_norm = d_pjrt.normalize(&ds.x_raw, ds.n);
    let one = &x_norm[..bench_man.n_in];
    let batch256 = &x_norm[..256 * bench_man.n_in];

    println!("--- L3 hot path (blackscholes, {}) ---", method.label());

    // Native engine floor.
    let mlp = bank.host_mlp(method, Role::Approx, 0)?;
    bench("native mlp forward x1", budget, || {
        std::hint::black_box(mlp.forward1(one));
    });
    bench("native mlp forward x256", budget, || {
        std::hint::black_box(mlp.forward_batch(batch256, 256));
    });

    // PJRT execute cost at both compiled batch sizes.
    bench("pjrt approx run B=1", budget, || {
        std::hint::black_box(d_pjrt.forward(Role::Approx, 0, one, 1).unwrap());
    });
    bench("pjrt approx run B=256", budget, || {
        std::hint::black_box(d_pjrt.forward(Role::Approx, 0, batch256, 256).unwrap());
    });
    bench("pjrt clfN run B=256", budget, || {
        std::hint::black_box(d_pjrt.forward(Role::ClfN, 0, batch256, 256).unwrap());
    });

    // The serving unit: classify + route + execute one 256-batch.
    let raw256 = &ds.x_raw[..256 * bench_man.n_in];
    bench("dispatch unit (classify+route+exec) pjrt B=256", budget, || {
        let plan = d_pjrt.plan(batch256, 256).unwrap();
        std::hint::black_box(d_pjrt.execute_plan(&plan, batch256, raw256, 256).unwrap());
    });
    bench("dispatch unit native B=256", budget, || {
        let plan = d_native.plan(batch256, 256).unwrap();
        std::hint::black_box(d_native.execute_plan(&plan, batch256, raw256, 256).unwrap());
    });

    // Batcher overhead per request.
    let mut rng = Rng::new(3);
    let reqs: Vec<Vec<f32>> =
        (0..256).map(|_| (0..6).map(|_| rng.uniform(0.0, 1.0) as f32).collect()).collect();
    bench("batcher push+flush 256 reqs", budget, || {
        let mut b = Batcher::new(BatchPolicy { max_batch: 256, max_wait_us: 10_000 }, 6);
        for (i, r) in reqs.iter().enumerate() {
            std::hint::black_box(b.push(i as u64, r.clone()));
        }
    });

    // Precise CPU path cost (the thing approximation avoids).
    let benchfn = mcma::benchmarks::by_name("blackscholes")?;
    let mut out = vec![0.0f64; 1];
    bench("precise cpu eval x1", budget, || {
        benchfn.eval(&ds.x_raw[..6], &mut out);
        std::hint::black_box(out[0]);
    });
    Ok(())
}
