//! Regenerates paper Fig. 10: per-approximator territories and error
//! fields over the Bessel (nu, x) input plane under MCMA.

use mcma::config::{Method, RunConfig};
use mcma::eval::{fig10, Context};

fn main() -> mcma::Result<()> {
    let ctx = Context::load(RunConfig::default())?;
    let f = fig10::run(&ctx, Method::McmaCompetitive)?;
    f.stats_table().print();
    println!("\n{}", f.territory_map());
    let bound = ctx.man.bench(fig10::BENCH)?.error_bound;
    for k in 0..f.grids.len() {
        println!("{}", f.error_map(k, bound));
    }
    println!(
        "each approximator specialises on a cluster of the input space; together \
         they cover what a single approximator cannot (paper Fig. 10)"
    );
    Ok(())
}
