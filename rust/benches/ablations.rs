//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. number of approximators n (MCMA uses all / first k of its nets)
//! 2. §III.D weight-buffer cases forced 1/2/3
//! 3. batch size sweep on the PJRT dispatch unit
//! 4. routing-policy extensions (confidence threshold, oracle bound)
//! 5. route-sorted execution: arrival-order vs class-sorted weight-switch
//!    traces under forced Case 3
//!
//! These go beyond the paper's figures: they quantify WHY the defaults
//! (n = 3, Case 1-sized buffers, B = 256) were chosen.

use std::time::Duration;

use mcma::bench_harness::{bench, pct, Table};
use mcma::config::{ExecMode, Method, NpuConfig, RunConfig};
use mcma::coordinator::{BufferCase, Dispatcher, Route};
use mcma::eval::Context;
use mcma::npu::NpuSim;

fn main() -> mcma::Result<()> {
    let ctx = Context::load(RunConfig::default())?;

    ablation_n_approx(&ctx)?;
    ablation_buffer_cases(&ctx)?;
    ablation_batch_size(&ctx)?;
    ablation_router_policy(&ctx)?;
    ablation_route_sort(&ctx)?;
    Ok(())
}

/// 5. Route-sorted group execution: replay the same routed trace through a
/// forced Case-3 weight cache in arrival order vs class-sorted order (the
/// order the dispatcher's grouped execution actually runs).  Sorting
/// collapses refills to at most one per approximator per batch; the switch
/// -rate delta is the whole point.
fn ablation_route_sort(ctx: &Context) -> mcma::Result<()> {
    let bench_man = ctx.man.bench("jpeg")?.clone();
    let method = Method::McmaCompetitive;
    let bank = ctx.bank(&bench_man, &[method])?;
    let d = Dispatcher::new(&bench_man, &bank, method, ExecMode::Pjrt)?;
    let ds = ctx.dataset("jpeg")?;
    let out = d.run_dataset(&ds)?;
    let benchfn = mcma::benchmarks::by_name("jpeg")?;
    let approx: Vec<Vec<usize>> =
        (0..d.n_approx()).map(|_| bench_man.approx_topology.clone()).collect();
    let sim = NpuSim::new(NpuConfig::default(), &bench_man.clfn_topology, &approx,
                          benchfn.cpu_cycles());

    let mut t = Table::new(
        "Ablation: route-sorted execution, forced Case 3 (jpeg, MCMA-compet)",
        &["order", "switches", "switch rate", "switch cycles", "speedup vs cpu"],
    );
    let arrival = sim.simulate(&out.plan.routes, Some(BufferCase::OneResident));
    let sorted =
        sim.simulate(&out.plan.execution_order_routes(), Some(BufferCase::OneResident));
    let invoked = out.plan.routes.iter().filter(|r| r.is_approx()).count().max(1);
    for (name, r) in [("arrival (unsorted)", &arrival), ("class-sorted", &sorted)] {
        t.row(vec![
            name.to_string(),
            r.weight_switches.to_string(),
            pct(r.weight_switches as f64 / invoked as f64),
            format!("{:.0}", r.cycles_weight_switch),
            format!("{:.3}x", r.speedup_vs_cpu()),
        ]);
    }
    t.print();
    println!(
        "  switch-rate delta: {} -> {} switches ({} approximators: sorted pays <= one refill each)",
        arrival.weight_switches, sorted.weight_switches, d.n_approx()
    );
    Ok(())
}

/// 4. Routing-policy extension: confidence-threshold sweep + the oracle
/// upper bound.  Quantifies remaining classifier headroom (oracle - argmax)
/// and the invocation/quality trade of a runtime confidence knob.
fn ablation_router_policy(ctx: &Context) -> mcma::Result<()> {
    use mcma::coordinator::RouterPolicy;
    let bench_man = ctx.man.bench("bessel")?.clone();
    let method = Method::McmaCompetitive;
    let bank = ctx.bank(&bench_man, &[method])?;
    let ds = ctx.dataset("bessel")?;
    let mut t = Table::new(
        "Ablation: routing policy (bessel, MCMA-compet)",
        &["policy", "invocation", "true invocation", "rmse/bound"],
    );
    let policies = [
        ("argmax (paper)".to_string(), RouterPolicy::Argmax),
        ("confidence 0.50".to_string(), RouterPolicy::Confidence(0.5)),
        ("confidence 0.80".to_string(), RouterPolicy::Confidence(0.8)),
        ("confidence 0.95".to_string(), RouterPolicy::Confidence(0.95)),
        ("oracle (upper bound)".to_string(), RouterPolicy::Oracle),
    ];
    for (name, policy) in policies {
        let d = Dispatcher::new(&bench_man, &bank, method, ExecMode::Pjrt)?
            .with_policy(policy);
        let out = d.run_dataset(&ds)?;
        t.row(vec![
            name,
            pct(out.metrics.invocation()),
            pct(out.metrics.true_invocation()),
            format!("{:.2}", out.metrics.rmse_over_bound),
        ]);
    }
    t.print();
    println!("  headroom = oracle true-invocation - argmax true-invocation");
    Ok(())
}

/// 1. How much does each extra approximator buy?  Evaluate MCMA-competitive
/// on bessel but only allow the first k approximators (classifier classes
/// >= k are treated as nC).
fn ablation_n_approx(ctx: &Context) -> mcma::Result<()> {
    let bench_man = ctx.man.bench("bessel")?.clone();
    let method = Method::McmaCompetitive;
    let bank = ctx.bank(&bench_man, &[method])?;
    let d = Dispatcher::new(&bench_man, &bank, method, ExecMode::Pjrt)?;
    let ds = ctx.dataset("bessel")?;
    let out = d.run_dataset(&ds)?;
    let n_total = d.n_approx();

    let mut t = Table::new(
        "Ablation: approximators allowed (bessel, MCMA-compet)",
        &["k", "invocation", "true invocation"],
    );
    for k in 1..=n_total {
        // Truncate routing: classes >= k fall back to CPU.
        let mut invoked = 0usize;
        let mut true_inv = 0usize;
        for (i, r) in out.plan.routes.iter().enumerate() {
            if let Route::Approx(a) = r {
                if *a < k {
                    invoked += 1;
                    if out.err[i] <= bench_man.error_bound {
                        true_inv += 1;
                    }
                }
            }
        }
        t.row(vec![
            k.to_string(),
            pct(invoked as f64 / ds.n as f64),
            pct(true_inv as f64 / ds.n as f64),
        ]);
    }
    t.print();
    Ok(())
}

/// 2. Forced weight-buffer cases on the jpeg trace (largest weights).
fn ablation_buffer_cases(ctx: &Context) -> mcma::Result<()> {
    let bench_man = ctx.man.bench("jpeg")?.clone();
    let method = Method::McmaCompetitive;
    let bank = ctx.bank(&bench_man, &[method])?;
    let d = Dispatcher::new(&bench_man, &bank, method, ExecMode::Pjrt)?;
    let ds = ctx.dataset("jpeg")?;
    let out = d.run_dataset(&ds)?;
    let benchfn = mcma::benchmarks::by_name("jpeg")?;
    let approx: Vec<Vec<usize>> =
        (0..d.n_approx()).map(|_| bench_man.approx_topology.clone()).collect();
    let sim = NpuSim::new(NpuConfig::default(), &bench_man.clfn_topology, &approx,
                          benchfn.cpu_cycles());

    let mut t = Table::new(
        "Ablation: forced §III.D buffer cases (jpeg, MCMA-compet)",
        &["case", "switches", "switch cycles", "speedup vs cpu", "energy red."],
    );
    for (name, case) in [
        ("1 all-resident", BufferCase::AllResident),
        ("2 stream-always", BufferCase::StreamAlways),
        ("3 one-resident", BufferCase::OneResident),
    ] {
        let r = sim.simulate(&out.plan.routes, Some(case));
        t.row(vec![
            name.to_string(),
            r.weight_switches.to_string(),
            format!("{:.0}", r.cycles_weight_switch),
            format!("{:.3}x", r.speedup_vs_cpu()),
            format!("{:.3}x", r.energy_reduction_vs_cpu()),
        ]);
    }
    t.print();
    Ok(())
}

/// 3. PJRT dispatch-unit latency vs batch size (B=1 vs B=256 compiled).
fn ablation_batch_size(ctx: &Context) -> mcma::Result<()> {
    let bench_man = ctx.man.bench("blackscholes")?.clone();
    let method = Method::McmaCompetitive;
    let bank = ctx.bank(&bench_man, &[method])?;
    let d = Dispatcher::new(&bench_man, &bank, method, ExecMode::Pjrt)?;
    let ds = ctx.dataset("blackscholes")?;
    let x_norm = d.normalize(&ds.x_raw, ds.n);

    println!("\nAblation: per-sample cost vs batch size (blackscholes, PJRT)");
    for n in [1usize, 16, 64, 256, 1024] {
        let chunk = &x_norm[..n * bench_man.n_in];
        let timing = bench(
            &format!("approx forward n={n}"),
            Duration::from_millis(300),
            || {
                std::hint::black_box(
                    d.forward(mcma::runtime::Role::Approx, 0, chunk, n).unwrap(),
                );
            },
        );
        println!("    -> {:.2} µs/sample", timing.mean_ns / 1e3 / n as f64);
    }
    Ok(())
}
