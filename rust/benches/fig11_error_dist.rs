//! Regenerates paper Fig. 11: sample distribution along the approximation
//! error with AC / nAC / AnC / nAnC quadrants, for one-pass vs iterative
//! vs MCMA on Bessel.

use mcma::config::RunConfig;
use mcma::eval::{fig11, Context};

fn main() -> mcma::Result<()> {
    let ctx = Context::load(RunConfig::default())?;
    let f = fig11::run(&ctx)?;
    f.quadrant_table().print();
    println!("{}", f.render());
    if let Some(mcma) = f.methods.last() {
        println!(
            "MCMA recall {:.3}: \"almost recognises all the safe-to-approximate \
             samples (low false negative rate)\" (paper §IV.B)",
            mcma.recall
        );
    }
    Ok(())
}
