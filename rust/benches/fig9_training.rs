//! Regenerates paper Fig. 9: invocation across training iterations for the
//! complementary vs competitive MCMA allocation schemes (Bessel), from the
//! per-iteration trajectories the Python trainer recorded at build time.

use mcma::config::{ExecMode, RunConfig};
use mcma::eval::{fig9, Context};

fn main() -> mcma::Result<()> {
    // Pure artifact read: no PJRT needed.
    let ctx = Context::load(RunConfig { exec: ExecMode::Native, ..Default::default() })?;
    let f = fig9::run(&ctx, "bessel")?;
    f.table().print();

    for (name, series) in &f.series {
        if series.len() >= 2 && series[1] < series[0] {
            println!(
                "note: {name} drops at iteration 1->2 — the paper observes the same \
                 (\"the classifier shuffles the partition ... dramatically\")"
            );
        }
    }
    Ok(())
}
