//! Regenerates paper Fig. 8: (a) speedup and (b) energy reduction
//! normalised to the one-pass method, via the cycle-level NPU simulator
//! over real routing traces.

use mcma::config::RunConfig;
use mcma::eval::{fig7, fig8, Context};

fn main() -> mcma::Result<()> {
    let ctx = Context::load(RunConfig::default())?;
    let f7 = fig7::run(&ctx)?;
    let f8 = fig8::run(&ctx, &f7)?;
    f8.table_a(&ctx).print();
    f8.table_b(&ctx).print();
    let (s, e) = f8.mcma_mean_gains(&ctx);
    println!(
        "\nheadline: best-MCMA mean speedup {:.2}x (paper ~1.23x), \
         energy reduction {:.2}x (paper ~1.15x) vs one-pass",
        s, e
    );
    Ok(())
}
