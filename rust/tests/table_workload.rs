//! End-to-end tests for data-defined (table) workloads: train from a CSV
//! with no registered precise function, export a servable artifact tree,
//! load it through `ModelBank`, serve it through the `Dispatcher` (f32 AND
//! int8) and the threaded `Server` (held-out lookup fallback + oracle-less
//! QoS with warm-started margins), and pin the determinism of the
//! train/held-out split across thread counts.

use std::sync::Arc;

use mcma::config::{BatchPolicy, ExecMode, Method};
use mcma::coordinator::{
    plan_routes, Dispatcher, Scratch, Server, ServerConfig, TableFallback,
};
use mcma::formats::{Dataset, Manifest, WeightsFile, WorkloadKind};
use mcma::qos::QosConfig;
use mcma::runtime::ModelBank;
use mcma::train::{train_bench, Scheme, TrainOptions};
use mcma::util::rng::Rng;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("mcma_table_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Two-cluster CSV (the cotrain test function, as a file): the output
/// slope in x1 flips sign across x0 = 0.5, so K=2 specialisation wins.
fn write_two_cluster_csv(dir: &std::path::Path, n: usize, seed: u64) -> std::path::PathBuf {
    let mut rng = Rng::new(seed);
    let mut text = String::from("x0,x1,y\n");
    for _ in 0..n {
        let x0 = rng.uniform(0.0, 1.0);
        let x1 = rng.uniform(0.0, 1.0);
        let y = if x0 < 0.5 { 0.15 + 0.3 * x1 } else { 0.85 - 0.3 * x1 };
        text.push_str(&format!("{x0:.6},{x1:.6},{y:.6}\n"));
    }
    let path = dir.join("twocluster.csv");
    std::fs::write(&path, text).unwrap();
    path
}

fn table_opts(csv: &std::path::Path, out_dir: &std::path::Path, threads: usize) -> TrainOptions {
    TrainOptions {
        data: Some(csv.to_path_buf()),
        d_out: 1,
        k: 2,
        samples: 400,
        rounds: 2,
        epochs: 6,
        lr: 0.02,
        seed: 11,
        out_dir: out_dir.to_path_buf(),
        threads,
        perf_json: None,
        ..TrainOptions::default()
    }
}

/// The acceptance path: `mcma train --data foo.csv --d-out 1 --k 2` must
/// build a fully servable artifact tree from nothing, with a v2 manifest
/// entry (`kind: table`, source digest) that `ModelBank` and the
/// dispatcher open exactly like a paper benchmark — in f32 AND int8.
#[test]
fn table_train_export_model_bank_serve_roundtrip() {
    let dir = tmp_dir("e2e");
    let csv = write_two_cluster_csv(&dir, 600, 0xDA7A);
    let out_dir = dir.join("artifacts");
    let report = train_bench(&table_opts(&csv, &out_dir, 2)).unwrap();
    assert_eq!(report.bench, "twocluster");
    assert_eq!(report.method, Method::McmaCompetitive);
    assert!((0.0..=1.0).contains(&report.invocation_k));

    // Artifact tree is complete.
    let bdir = out_dir.join("twocluster");
    for f in ["weights_rust.bin", "weights.bin", "test.bin"] {
        assert!(bdir.join(f).exists(), "{f} missing");
    }

    // Manifest entry is table-kind with the CSV's content digest.
    let man = Manifest::load(&out_dir).unwrap();
    let bench = man.bench("twocluster").unwrap().clone();
    assert_eq!(bench.kind, WorkloadKind::Table);
    assert_eq!(bench.source_digest.len(), 16, "digest: {:?}", bench.source_digest);
    assert_eq!((bench.n_in, bench.n_out), (2, 1));
    assert!(bench.methods.iter().any(|m| m == "mcma_competitive"));
    assert!(bench.methods.iter().any(|m| m == "one_pass"));
    assert!(bench.train_n > 0 && bench.test_n > 0);

    // ModelBank + dispatcher serve the held-out set with NO registered
    // precise function — rejected samples come from the held-out labels.
    let bank = ModelBank::load(None, &man, &bench, &[Method::McmaCompetitive], &[]).unwrap();
    assert_eq!(bank.n_approx(Method::McmaCompetitive), 2);
    let ds = Dataset::load(&man.dataset_path("twocluster")).unwrap();
    assert_eq!(ds.n, bench.test_n);
    let d = Dispatcher::new(&bench, &bank, Method::McmaCompetitive, ExecMode::Native).unwrap();
    assert!(!d.has_runtime_oracle(), "table workloads must have no oracle");
    let out = d.run_dataset(&ds).unwrap();
    assert_eq!(out.plan.routes.len(), ds.n);
    assert!(
        (out.metrics.invocation() - report.invocation_k).abs() < 1e-9,
        "served invocation drifted from the training report"
    );

    // The int8 twin serves the same tree.
    let d8 =
        Dispatcher::new(&bench, &bank, Method::McmaCompetitive, ExecMode::NativeQ8).unwrap();
    let out8 = d8.run_dataset(&ds).unwrap();
    assert_eq!(out8.plan.routes.len(), ds.n);

    // Weight bytes round-trip (weights.bin is the rust tree's own copy).
    let wf = WeightsFile::load(&bdir.join("weights_rust.bin")).unwrap();
    let back = WeightsFile::load(&bdir.join("weights.bin")).unwrap();
    assert_eq!(wf.to_bytes(), back.to_bytes());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Thread-count determinism of the whole table pipeline: the split is a
/// pure function of (file, holdout, seed) and the cotrain loop carries
/// per-job RNG streams, so 1-thread and 4-thread runs must export
/// bit-identical weights.
#[test]
fn table_split_and_training_deterministic_across_threads() {
    let dir = tmp_dir("det");
    let csv = write_two_cluster_csv(&dir, 300, 0x5EED);
    let out1 = dir.join("a1");
    let out4 = dir.join("a4");
    let mut o1 = table_opts(&csv, &out1, 1);
    let mut o4 = table_opts(&csv, &out4, 4);
    o1.samples = 200;
    o4.samples = 200;
    o1.epochs = 2;
    o4.epochs = 2;
    train_bench(&o1).unwrap();
    train_bench(&o4).unwrap();
    let w1 = std::fs::read(out1.join("twocluster/weights_rust.bin")).unwrap();
    let w4 = std::fs::read(out4.join("twocluster/weights_rust.bin")).unwrap();
    assert_eq!(w1, w4, "trained weights depend on thread count");
    let t1 = std::fs::read(out1.join("twocluster/test.bin")).unwrap();
    let t4 = std::fs::read(out4.join("twocluster/test.bin")).unwrap();
    assert_eq!(t1, t4, "held-out split depends on thread count");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A retrain against a CHANGED data file must re-derive the entry (new
/// digest) and rewrite the tree's weights/labels instead of silently
/// serving stale nets.
#[test]
fn table_retrain_tracks_source_digest() {
    let dir = tmp_dir("digest");
    let csv = write_two_cluster_csv(&dir, 300, 1);
    let out_dir = dir.join("artifacts");
    let mut opts = table_opts(&csv, &out_dir, 1);
    opts.samples = 200;
    opts.epochs = 2;
    train_bench(&opts).unwrap();
    let d1 = Manifest::load(&out_dir).unwrap().bench("twocluster").unwrap().source_digest.clone();

    // Append rows — the digest must move and the retrain must accept it.
    let mut text = std::fs::read_to_string(&csv).unwrap();
    text.push_str("0.5,0.5,0.5\n0.1,0.9,0.42\n");
    std::fs::write(&csv, text).unwrap();
    train_bench(&opts).unwrap();
    let d2 = Manifest::load(&out_dir).unwrap().bench("twocluster").unwrap().source_digest.clone();
    assert_ne!(d1, d2, "digest must track the source content");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Oracle-less serving through the threaded pipeline: traffic replays
/// held-out rows, rejected requests are served from the nearest held-out
/// record, the QoS loop verifies against held-out labels, and
/// `--qos-warm` seeds margins from the offline replay.
#[test]
fn table_serve_with_qos_warm_start() {
    let dir = tmp_dir("serve");
    let csv = write_two_cluster_csv(&dir, 600, 0xFEED);
    let out_dir = dir.join("artifacts");
    train_bench(&table_opts(&csv, &out_dir, 2)).unwrap();

    let man = Arc::new(Manifest::load(&out_dir).unwrap());
    let bench = Arc::new(man.bench("twocluster").unwrap().clone());
    let ds = Dataset::load(&man.dataset_path("twocluster")).unwrap();
    let qos = QosConfig {
        target: 10.0, // generous: the trained workload must show 0 violations
        shadow_rate: 0.5,
        window: 64,
        min_obs: 8,
        tick_every: 16,
        warm_start: true,
        ..QosConfig::default()
    };
    let server = Server::spawn(
        Arc::clone(&man),
        Arc::clone(&bench),
        ServerConfig {
            policy: BatchPolicy { max_batch: 64, max_wait_us: 500 },
            method: Method::McmaCompetitive,
            exec: ExecMode::Native,
            workers: 1,
            qos: Some(qos),
            table_fallback: TableFallback::Lookup,
        },
    )
    .unwrap();
    let mut rng = Rng::new(42);
    let n = 500u64;
    for id in 0..n {
        let row = ds.x_row(rng.below(ds.n as u64) as usize);
        server.submit(id, row.to_vec()).unwrap();
    }
    let report = server.shutdown(Vec::new()).unwrap();
    assert_eq!(report.served, n, "requests lost");
    let q = report.qos.as_ref().expect("qos report missing");
    assert!(q.warm_started, "--qos-warm must seed from the offline replay");
    assert_eq!(q.classes.len(), 2);
    assert_eq!(q.total_violations(), 0, "loose target must show zero violations");
    assert!(
        report.invoked > 0,
        "classifier rejected everything — two-cluster training budget too small"
    );
    assert!(
        q.total_shadow() > 0,
        "shadow verification from held-out labels never fired"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The strict fallback: with no lookup proxy installed, a CPU-routed
/// sample is a hard error naming the workload — and installing the
/// held-out lookup makes the identical plan servable.
#[test]
fn table_reject_fallback_is_hard_error() {
    let dir = tmp_dir("reject");
    let csv = write_two_cluster_csv(&dir, 300, 3);
    let out_dir = dir.join("artifacts");
    let mut opts = table_opts(&csv, &out_dir, 1);
    opts.samples = 200;
    opts.epochs = 2;
    train_bench(&opts).unwrap();

    let man = Manifest::load(&out_dir).unwrap();
    let bench = man.bench("twocluster").unwrap().clone();
    let bank = ModelBank::load(None, &man, &bench, &[], &[]).unwrap();
    let ds = Dataset::load(&man.dataset_path("twocluster")).unwrap();
    let d = Dispatcher::new(&bench, &bank, Method::McmaCompetitive, ExecMode::Native).unwrap();

    // Force every sample onto the precise path.
    let n = 4usize;
    let classes = vec![d.n_approx(); n];
    let plan = plan_routes(&classes, d.n_approx());
    let x_norm = d.normalize(&ds.x_raw[..n * bench.n_in], n);
    let mut y = Vec::new();
    let mut scratch = Scratch::new();
    let err = d
        .execute_plan_into(&plan, &x_norm, &ds.x_raw[..n * bench.n_in], n, &mut y, &mut scratch)
        .unwrap_err()
        .to_string();
    assert!(err.contains("no runtime oracle"), "{err}");
    assert!(err.contains("twocluster"), "{err}");

    // Same plan with the held-out lookup installed: exact labels back.
    let d = d.with_precise_proxy(mcma::workload::PreciseProxy::lookup_from(&bench, &ds));
    d.execute_plan_into(&plan, &x_norm, &ds.x_raw[..n * bench.n_in], n, &mut y, &mut scratch)
        .unwrap();
    assert_eq!(&y[..], &ds.y_norm[..n], "lookup must serve the held-out labels");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The complementary allocation scheme exports under the paper's
/// `mcma_complementary` key and serves through the same pipeline
/// (satellite: `--scheme complementary`).
#[test]
fn complementary_scheme_exports_and_serves() {
    let dir = tmp_dir("compl");
    let csv = write_two_cluster_csv(&dir, 400, 7);
    let out_dir = dir.join("artifacts");
    let mut opts = table_opts(&csv, &out_dir, 2);
    opts.scheme = Scheme::Complementary;
    opts.samples = 250;
    let report = train_bench(&opts).unwrap();
    assert_eq!(report.method, Method::McmaComplementary);

    let man = Manifest::load(&out_dir).unwrap();
    let bench = man.bench("twocluster").unwrap().clone();
    assert!(bench.methods.iter().any(|m| m == "mcma_complementary"));
    let bank = ModelBank::load(None, &man, &bench, &[], &[]).unwrap();
    assert!(bank.has_method(Method::McmaComplementary));
    let ds = Dataset::load(&man.dataset_path("twocluster")).unwrap();
    let out = Dispatcher::new(&bench, &bank, Method::McmaComplementary, ExecMode::Native)
        .unwrap()
        .run_dataset(&ds)
        .unwrap();
    assert!(
        (out.metrics.invocation() - report.invocation_k).abs() < 1e-9,
        "complementary serving drifted from the training report"
    );

    // The fig9 fallback trajectory is keyed by the scheme's method.
    let stats = mcma::util::json::parse_file(&out_dir.join("train_stats_rust.json")).unwrap();
    assert!(stats.req("twocluster").unwrap().req("mcma_complementary").is_ok());
    let _ = std::fs::remove_dir_all(&dir);
}
