//! Scratch-arena refactor guarantees, pinned with crafted nets (no
//! artifacts, no PJRT):
//!
//! * the allocating wrappers and the `*_into` hot path produce bitwise
//!   identical plans and served outputs;
//! * `run_dataset` equals the manual normalize -> plan -> execute
//!   composition bitwise (golden stability across the refactor);
//! * repeated `process_batch_into` calls reach a steady state with zero
//!   heap allocations, observed as buffer capacities going flat.

use std::collections::HashMap;

use mcma::config::{ExecMode, Method};
use mcma::coordinator::{Batch, Dispatcher, RoutePlan, Scratch};
use mcma::formats::weights::{MethodWeights, WeightsFile};
use mcma::formats::{BenchManifest, Dataset};
use mcma::runtime::ModelBank;
use mcma::util::rng::Rng;

/// sobel-shaped manifest (9 -> 1) with trivial normalisation.
fn manifest() -> BenchManifest {
    BenchManifest {
        name: "sobel".into(),
        domain: "test".into(),
        kind: mcma::formats::WorkloadKind::Synthetic,
        source_digest: String::new(),
        n_in: 9,
        n_out: 1,
        approx_topology: vec![9, 8, 1],
        clf2_topology: vec![9, 2],
        clfn_topology: vec![9, 4],
        x_lo: vec![0.0; 9],
        x_hi: vec![1.0; 9],
        y_lo: vec![0.0],
        y_hi: vec![1.0],
        error_bound: 0.05,
        train_n: 0,
        test_n: 0,
        methods: vec!["mcma_competitive".into()],
        mcca_pairs: 0,
    }
}

fn random_mlp(rng: &mut Rng, topo: &[usize]) -> mcma::nn::Mlp {
    mcma::util::prop::gens::mlp(rng, topo, 1.5, 0.5)
}

/// Random MCMA bank: 4-class classifier (3 approximators + nC) so batches
/// exercise every route group and the CPU path.
fn bank(rng: &mut Rng) -> ModelBank {
    let mw = MethodWeights {
        method: "mcma_competitive".into(),
        cascade: false,
        clf_classes: 4,
        classifiers: vec![random_mlp(rng, &[9, 6, 4])],
        approximators: (0..3).map(|_| random_mlp(rng, &[9, 8, 1])).collect(),
    };
    let mut methods = HashMap::new();
    methods.insert("mcma_competitive".to_string(), mw);
    ModelBank::from_host("sobel", WeightsFile { methods })
}

fn random_batch(rng: &mut Rng, n: usize) -> Batch {
    let now = std::time::Instant::now();
    Batch {
        ids: (0..n as u64).collect(),
        x_raw: (0..n * 9).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
        n,
        submitted: vec![now; n],
        enqueued: vec![now; n],
    }
}

#[test]
fn process_batch_into_matches_allocating_wrapper_bitwise() {
    let man = manifest();
    let mut rng = Rng::new(0xA11C);
    let bank = bank(&mut rng);
    let d = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::Native).unwrap();

    let mut plan = RoutePlan::default();
    let mut y = Vec::new();
    let mut scratch = Scratch::new();
    for n in [1usize, 7, 64, 256] {
        let batch = random_batch(&mut rng, n);
        let (plan_alloc, y_alloc) = d.process_batch(&batch).unwrap();
        d.process_batch_into(&batch, &mut plan, &mut y, &mut scratch).unwrap();
        assert_eq!(plan.routes, plan_alloc.routes, "n={n} routes diverge");
        assert_eq!(plan.groups, plan_alloc.groups, "n={n} groups diverge");
        assert_eq!(plan.cpu, plan_alloc.cpu, "n={n} cpu group diverges");
        // Bitwise: both paths run the identical packed-GEMM engine.
        assert_eq!(y, y_alloc, "n={n} served outputs diverge");
    }
}

#[test]
fn run_dataset_matches_manual_composition_bitwise() {
    let man = manifest();
    let mut rng = Rng::new(0x5EED);
    let bank = bank(&mut rng);
    let d = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::Native).unwrap();

    let n = 200;
    let ds = Dataset {
        n,
        d_in: 9,
        d_out: 1,
        x_raw: (0..n * 9).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
        y_norm: (0..n).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    };

    let out = d.run_dataset(&ds).unwrap();
    let x_norm = d.normalize(&ds.x_raw, ds.n);
    let plan = d.plan(&x_norm, ds.n).unwrap();
    let y = d.execute_plan(&plan, &x_norm, &ds.x_raw, ds.n).unwrap();
    assert_eq!(out.plan.routes, plan.routes);
    assert_eq!(out.y_served, y);

    // error_matrix over pre-normalised inputs is the same computation
    // run_dataset now shares (no second normalisation pass).
    let m1 = d.error_matrix(&ds).unwrap();
    let m2 = d.error_matrix_norm(&ds, &x_norm).unwrap();
    assert_eq!(m1, m2);
}

#[test]
fn steady_state_process_batch_stops_allocating() {
    let man = manifest();
    let mut rng = Rng::new(0xCAFE);
    let bank = bank(&mut rng);
    let d = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::Native).unwrap();

    let mut plan = RoutePlan::default();
    let mut y = Vec::new();
    let mut scratch = Scratch::new();

    // Two fixed 256-row batches with different route mixes; alternating
    // them models a steady request stream with a stable size envelope.
    let batches = [random_batch(&mut rng, 256), random_batch(&mut rng, 256)];

    // Warm-up: let every buffer reach its high-water mark.
    for i in 0..4 {
        d.process_batch_into(&batches[i % 2], &mut plan, &mut y, &mut scratch).unwrap();
    }
    let warm_caps = scratch.capacity_signature();
    let warm_y = y.capacity();
    let warm_routes = plan.routes.capacity();
    let warm_cpu = plan.cpu.capacity();
    let warm_groups: Vec<usize> = plan.groups.iter().map(|g| g.capacity()).collect();

    // Steady state: equal-sized batches must not grow ANY buffer.
    for i in 0..10 {
        d.process_batch_into(&batches[i % 2], &mut plan, &mut y, &mut scratch).unwrap();
        assert_eq!(scratch.capacity_signature(), warm_caps, "scratch grew");
        assert_eq!(y.capacity(), warm_y, "output buffer grew");
        assert_eq!(plan.routes.capacity(), warm_routes, "routes grew");
        assert_eq!(plan.cpu.capacity(), warm_cpu, "cpu group grew");
        let groups: Vec<usize> = plan.groups.iter().map(|g| g.capacity()).collect();
        assert_eq!(groups, warm_groups, "route groups grew");
    }
}

/// The int8 engine runs through the same arena path with the same
/// guarantees: wrapper-vs-arena bitwise equality and a zero-allocation
/// steady state (the quantized panel buffer included).
#[test]
fn q8_process_batch_into_matches_wrapper_and_stops_allocating() {
    let man = manifest();
    let mut rng = Rng::new(0xA8C8);
    let bank = bank(&mut rng);
    let d = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::NativeQ8).unwrap();

    let mut plan = RoutePlan::default();
    let mut y = Vec::new();
    let mut scratch = Scratch::new();
    for n in [1usize, 7, 64, 256] {
        let batch = random_batch(&mut rng, n);
        let (plan_alloc, y_alloc) = d.process_batch(&batch).unwrap();
        d.process_batch_into(&batch, &mut plan, &mut y, &mut scratch).unwrap();
        assert_eq!(plan.routes, plan_alloc.routes, "n={n} q8 routes diverge");
        assert_eq!(y, y_alloc, "n={n} q8 served outputs diverge");
    }

    let batches = [random_batch(&mut rng, 256), random_batch(&mut rng, 256)];
    for i in 0..4 {
        d.process_batch_into(&batches[i % 2], &mut plan, &mut y, &mut scratch).unwrap();
    }
    let warm_caps = scratch.capacity_signature();
    for i in 0..10 {
        d.process_batch_into(&batches[i % 2], &mut plan, &mut y, &mut scratch).unwrap();
        assert_eq!(scratch.capacity_signature(), warm_caps, "q8 scratch grew");
    }
}

/// The quantized engine serves outputs close to the f32 engine (routing
/// may legitimately differ near argmax ties, so compare forwards, not
/// plans): int8 quantization error on these small nets stays well under
/// a generous absolute envelope.
#[test]
fn q8_forward_close_to_f32_forward() {
    let man = manifest();
    let mut rng = Rng::new(0xD16);
    let bank = bank(&mut rng);
    let d32 = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::Native).unwrap();
    let d8 = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::NativeQ8).unwrap();

    let n = 64;
    let x: Vec<f32> = (0..n * 9).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let f = d32.forward(mcma::runtime::Role::Approx, 0, &x, n).unwrap();
    let q = d8.forward(mcma::runtime::Role::Approx, 0, &x, n).unwrap();
    assert_eq!(f.len(), q.len());
    for (i, (a, b)) in f.iter().zip(&q).enumerate() {
        assert!((a - b).abs() < 0.3, "sample {i}: f32 {a} vs int8 {b}");
    }
}

/// Route-sorted accounting only reorders the weight-switch trace: served
/// outputs and routes are identical, switches can only go down.
#[test]
fn route_sorted_only_changes_switch_accounting() {
    let man = manifest();
    let mut rng = Rng::new(0x50FA);
    let bank = bank(&mut rng);
    let ds = mcma::formats::Dataset {
        n: 300,
        d_in: 9,
        d_out: 1,
        x_raw: (0..300 * 9).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
        y_norm: (0..300).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    };
    // Force §III.D Case 3 on these tiny nets: one approximator fits the
    // buffer (89 <= 96 words), all three do not.
    let npu = mcma::config::NpuConfig {
        weight_buffer_words: 12,
        ..Default::default()
    };

    let mut d = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::Native).unwrap();
    d.npu_cfg = npu;
    let unsorted = d.run_dataset(&ds).unwrap();
    let mut d_sorted = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::Native)
        .unwrap()
        .with_route_sorted(true);
    d_sorted.npu_cfg = npu;
    let sorted = d_sorted.run_dataset(&ds).unwrap();

    assert_eq!(unsorted.plan.routes, sorted.plan.routes);
    assert_eq!(unsorted.y_served, sorted.y_served);
    assert!(
        sorted.metrics.weight_switches <= unsorted.metrics.weight_switches,
        "sorting increased switches: {} > {}",
        sorted.metrics.weight_switches,
        unsorted.metrics.weight_switches
    );
    // Class-sorted Case-3 refills: at most one per approximator.
    assert!(sorted.metrics.weight_switches <= 3);
}

#[test]
fn forward_native_agrees_with_scalar_reference() {
    let man = manifest();
    let mut rng = Rng::new(0xD15);
    let bank = bank(&mut rng);
    let d = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::Native).unwrap();
    let host = bank.host_mlp(Method::McmaCompetitive, mcma::runtime::Role::Approx, 1).unwrap();

    let n = 50;
    let x: Vec<f32> = (0..n * 9).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let fast = d.forward(mcma::runtime::Role::Approx, 1, &x, n).unwrap();
    let slow = host.forward_batch(&x, n);
    assert_eq!(fast.len(), slow.len());
    for (a, b) in fast.iter().zip(&slow) {
        assert!((a - b).abs() < 1e-5 + 1e-5 * b.abs(), "{a} vs {b}");
    }
}
