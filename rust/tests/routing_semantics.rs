//! Coordinator routing semantics under CRAFTED nets (no artifacts, no
//! PJRT): hand-built classifiers with known outputs pin the exact
//! contract between classifier logits and destinations, including the
//! Confidence and Oracle policy extensions.

use std::collections::HashMap;

use mcma::config::{ExecMode, Method};
use mcma::coordinator::{Dispatcher, Route, RouterPolicy};
use mcma::formats::weights::{MethodWeights, WeightsFile};
use mcma::formats::{BenchManifest, Dataset};
use mcma::nn::{Layer, Matrix, Mlp};
use mcma::runtime::ModelBank;

/// sobel-shaped manifest (9 -> 1) with trivial normalisation.
fn manifest() -> BenchManifest {
    BenchManifest {
        name: "sobel".into(),
        domain: "test".into(),
        kind: mcma::formats::WorkloadKind::Synthetic,
        source_digest: String::new(),
        n_in: 9,
        n_out: 1,
        approx_topology: vec![9, 1],
        clf2_topology: vec![9, 2],
        clfn_topology: vec![9, 4],
        x_lo: vec![0.0; 9],
        x_hi: vec![1.0; 9],
        y_lo: vec![0.0],
        y_hi: vec![1.0],
        error_bound: 0.05,
        train_n: 0,
        test_n: 0,
        methods: vec!["one_pass".into(), "mcma_competitive".into()],
        mcca_pairs: 0,
    }
}

/// Single linear layer whose output `c` is `bias[c] + sum(w_col_c * x)`.
fn linear(n_in: usize, out_bias: Vec<f32>, w: Vec<f32>) -> Mlp {
    let n_out = out_bias.len();
    assert_eq!(w.len(), n_in * n_out);
    Mlp::new(vec![Layer { w: Matrix::new(n_in, n_out, w), b: out_bias }])
}

/// Classifier that ALWAYS emits fixed logits (zero weights, bias = logits).
fn const_clf(n_in: usize, logits: Vec<f32>) -> Mlp {
    let n_out = logits.len();
    linear(n_in, logits, vec![0.0; n_in * n_out])
}

/// Approximator that always outputs the constant `v`.
fn const_approx(n_in: usize, v: f32) -> Mlp {
    linear(n_in, vec![v], vec![0.0; n_in])
}

fn bank(clf_classes: usize, clf: Mlp, approxs: Vec<Mlp>, method: &str) -> ModelBank {
    let mw = MethodWeights {
        method: method.to_string(),
        cascade: false,
        clf_classes,
        classifiers: vec![clf],
        approximators: approxs,
    };
    let mut methods = HashMap::new();
    methods.insert(method.to_string(), mw);
    ModelBank::from_host("sobel", WeightsFile { methods })
}

fn dataset(n: usize) -> Dataset {
    // Flat windows: the sobel precise output is exactly 0.
    Dataset {
        n,
        d_in: 9,
        d_out: 1,
        x_raw: vec![0.5; n * 9],
        y_norm: vec![0.0; n],
    }
}

#[test]
fn mcma_argmax_routes_to_highest_logit() {
    let man = manifest();
    // 4-class classifier preferring class 2 (approximator 3 of 3).
    let bank = bank(
        4,
        const_clf(9, vec![0.0, 1.0, 3.0, 2.0]),
        vec![const_approx(9, 0.0), const_approx(9, 0.0), const_approx(9, 0.0)],
        "mcma_competitive",
    );
    let d = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::Native).unwrap();
    let out = d.run_dataset(&dataset(16)).unwrap();
    assert!(out.plan.routes.iter().all(|r| *r == Route::Approx(2)));
    // Approximator outputs 0 and the truth is 0 -> perfect invocation.
    assert_eq!(out.metrics.invocation(), 1.0);
    assert_eq!(out.metrics.true_invocation(), 1.0);
}

#[test]
fn mcma_nc_class_goes_to_cpu() {
    let man = manifest();
    let bank = bank(
        4,
        const_clf(9, vec![0.0, 1.0, 2.0, 9.0]), // class 3 = nC wins
        vec![const_approx(9, 0.0); 3],
        "mcma_competitive",
    );
    let d = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::Native).unwrap();
    let out = d.run_dataset(&dataset(8)).unwrap();
    assert!(out.plan.routes.iter().all(|r| *r == Route::Cpu));
    assert_eq!(out.metrics.invocation(), 0.0);
    // CPU path computed the precise value -> zero served error.
    assert!(out.err.iter().all(|&e| e == 0.0));
    // And the served outputs equal the normalised truth (sobel(flat)=0).
    assert!(out.y_served.iter().all(|&y| y.abs() < 1e-6));
}

#[test]
fn binary_class0_is_safe_convention() {
    let man = manifest();
    let bank = bank(
        2,
        const_clf(9, vec![1.0, 0.0]), // class 0 (safe) wins
        vec![const_approx(9, 0.0)],
        "one_pass",
    );
    let d = Dispatcher::new(&man, &bank, Method::OnePass, ExecMode::Native).unwrap();
    let out = d.run_dataset(&dataset(8)).unwrap();
    assert!(out.plan.routes.iter().all(|r| *r == Route::Approx(0)));
}

#[test]
fn confidence_policy_demotes_marginal_accepts() {
    let man = manifest();
    // Logit gap 0.2 over 4 classes -> softmax confidence ~0.29 for the
    // winning class.
    let bank = bank(
        4,
        const_clf(9, vec![0.2, 0.0, 0.0, 0.0]),
        vec![const_approx(9, 0.0); 3],
        "mcma_competitive",
    );
    let d_loose = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::Native)
        .unwrap()
        .with_policy(RouterPolicy::Confidence(0.25));
    let d_tight = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::Native)
        .unwrap()
        .with_policy(RouterPolicy::Confidence(0.90));
    let ds = dataset(8);
    let loose = d_loose.run_dataset(&ds).unwrap();
    let tight = d_tight.run_dataset(&ds).unwrap();
    assert_eq!(loose.metrics.invocation(), 1.0, "tau below confidence keeps accepts");
    assert_eq!(tight.metrics.invocation(), 0.0, "tau above confidence demotes to CPU");
}

#[test]
fn oracle_policy_routes_to_lowest_error_approx() {
    let man = manifest();
    // A0 predicts 0.3 (err 0.3), A1 predicts 0.02 (err 0.02 <= bound 0.05),
    // A2 predicts 0.9.  Classifier is adversarial (prefers A2) — oracle
    // must ignore it.
    let bank = bank(
        4,
        const_clf(9, vec![0.0, 0.0, 5.0, 0.0]),
        vec![const_approx(9, 0.3), const_approx(9, 0.02), const_approx(9, 0.9)],
        "mcma_competitive",
    );
    let d = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::Native)
        .unwrap()
        .with_policy(RouterPolicy::Oracle);
    let out = d.run_dataset(&dataset(8)).unwrap();
    assert!(out.plan.routes.iter().all(|r| *r == Route::Approx(1)));
    assert_eq!(out.metrics.true_invocation(), 1.0);
}

#[test]
fn oracle_rejects_when_no_approximator_fits() {
    let man = manifest();
    let bank = bank(
        4,
        const_clf(9, vec![5.0, 0.0, 0.0, 0.0]), // classifier would accept
        vec![const_approx(9, 0.5); 3],          // all violate the bound
        "mcma_competitive",
    );
    let d = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::Native)
        .unwrap()
        .with_policy(RouterPolicy::Oracle);
    let out = d.run_dataset(&dataset(8)).unwrap();
    assert!(out.plan.routes.iter().all(|r| *r == Route::Cpu));
}

#[test]
fn served_error_matches_approximator_constant() {
    let man = manifest();
    let bank = bank(
        2,
        const_clf(9, vec![1.0, 0.0]),
        vec![const_approx(9, 0.25)],
        "one_pass",
    );
    let d = Dispatcher::new(&man, &bank, Method::OnePass, ExecMode::Native).unwrap();
    let out = d.run_dataset(&dataset(4)).unwrap();
    // Truth is 0, approximator says 0.25 -> per-sample RMSE 0.25 exactly.
    for e in &out.err {
        assert!((e - 0.25).abs() < 1e-6);
    }
    assert_eq!(out.metrics.quadrants.n_ac, 4); // all false positives
    assert!((out.metrics.rmse_over_bound - 5.0).abs() < 1e-6);
}
