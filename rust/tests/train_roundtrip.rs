//! End-to-end native training round trip, artifact-free: `train_bench` on
//! a real benchmark with a tiny budget must (1) export an MCMW/MCQW/MCMD
//! artifact tree, (2) produce a manifest `Manifest::load` accepts, (3)
//! yield weights `ModelBank` loads and the `Dispatcher` serves, and (4)
//! round-trip the weight bytes exactly.  Budget is deliberately tiny —
//! quality is covered by `train::cotrain`'s unit tests; this pins the
//! plumbing.

use mcma::config::{ExecMode, Method};
use mcma::coordinator::Dispatcher;
use mcma::formats::{Dataset, Manifest, QuantizedMlpFile, WeightsFile};
use mcma::runtime::ModelBank;
use mcma::train::{train_bench, TrainOptions};

fn tmp_out(tag: &str) -> std::path::PathBuf {
    // Tests in one binary share a process: key the dir by test tag too.
    let dir = std::env::temp_dir().join(format!("mcma_train_rt_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn train_export_serves_through_model_bank() {
    let out_dir = tmp_out("serve");
    let opts = TrainOptions {
        bench: "blackscholes".into(),
        k: 2,
        samples: 400,
        rounds: 2,
        epochs: 3,
        seed: 11,
        out_dir: out_dir.clone(),
        threads: 2,
        perf_json: Some(out_dir.join("BENCH_train.json")),
        ..TrainOptions::default()
    };
    let report = train_bench(&opts).unwrap();
    assert_eq!(report.k, 2);
    assert!((0.0..=1.0).contains(&report.invocation_k));
    assert!((0.0..=1.0).contains(&report.invocation_base));
    assert!(!report.history.is_empty());
    assert!(report.history.iter().all(|h| h.wall_ms > 0.0), "rounds must carry wall-clock");

    // (0) the perf report landed where asked, with forward AND backward
    // samples/sec plus the lookup-index side-measurements.
    let perf = mcma::util::json::parse_file(&out_dir.join("BENCH_train.json")).unwrap();
    let results = perf.get("results").unwrap().as_arr().unwrap();
    for needle in ["train forward x", "train forward+backward x", "cotrain round wall x"] {
        let t = results
            .iter()
            .find(|r| r.get("name").unwrap().as_str().unwrap().starts_with(needle))
            .unwrap_or_else(|| panic!("missing perf case {needle:?}"));
        assert!(t.get("rows_per_sec").unwrap().as_f64().unwrap() > 0.0, "{needle} rows/sec");
    }
    let extras = perf.get("extras").expect("perf extras object");
    assert_eq!(extras.get("lookup_scan_agree").unwrap().as_f64().unwrap(), 1.0);
    assert!(extras.get("lookup_visits_per_query").unwrap().as_f64().unwrap() >= 1.0);

    // (1) every promised artifact exists.
    let bdir = out_dir.join("blackscholes");
    for f in ["weights_rust.bin", "weights.bin", "test.bin"] {
        assert!(bdir.join(f).exists(), "{f} missing");
    }
    assert!(out_dir.join("manifest.json").exists());

    // (2) the manifest loads and validates.
    let man = Manifest::load(&out_dir).unwrap();
    let bench = man.bench("blackscholes").unwrap().clone();
    assert_eq!(*bench.clfn_topology.last().unwrap(), 3, "clfN must have k+1 classes");
    assert!(bench.methods.iter().any(|m| m == "mcma_competitive"));

    // (3) the exported weights serve through the real bank + dispatcher.
    let bank = ModelBank::load(None, &man, &bench, &[Method::McmaCompetitive], &[]).unwrap();
    assert_eq!(bank.n_approx(Method::McmaCompetitive), 2);
    assert!(bank.has_method(Method::OnePass));
    let ds = Dataset::load(&man.dataset_path("blackscholes")).unwrap();
    let d = Dispatcher::new(&bench, &bank, Method::McmaCompetitive, ExecMode::Native).unwrap();
    let out = d.run_dataset(&ds).unwrap();
    assert_eq!(out.plan.routes.len(), ds.n);
    assert!((out.metrics.invocation() - report.invocation_k).abs() < 1e-9,
        "served invocation drifted from the training report");

    // The int8 twins pack straight from the exported nets, so the
    // quantized engine serves the same tree.
    let d8 = Dispatcher::new(&bench, &bank, Method::McmaCompetitive, ExecMode::NativeQ8).unwrap();
    let out8 = d8.run_dataset(&ds).unwrap();
    assert_eq!(out8.plan.routes.len(), ds.n);

    // (4) weight bytes round-trip exactly, and the MCQW sidecars load.
    let wf = WeightsFile::load(&bdir.join("weights_rust.bin")).unwrap();
    let reloaded = WeightsFile::load(&bdir.join("weights.bin")).unwrap();
    assert_eq!(wf.to_bytes(), reloaded.to_bytes());
    for i in 0..2 {
        let q = QuantizedMlpFile::load(&bdir.join(format!("approx_rust_k2_{i}.mcqw"))).unwrap();
        let twin = q.to_mlp();
        assert_eq!(
            twin.topology(),
            wf.get("mcma_competitive").unwrap().approximators[i].topology()
        );
    }

    let _ = std::fs::remove_dir_all(&out_dir);
}

/// Re-training into an EXISTING tree must reuse its manifest entry and not
/// clobber unrelated benchmarks.
#[test]
fn train_merges_into_existing_tree() {
    let out_dir = tmp_out("merge");
    let mk = |bench: &str, seed: u64| TrainOptions {
        bench: bench.into(),
        k: 2,
        samples: 256,
        rounds: 1,
        epochs: 2,
        seed,
        out_dir: out_dir.clone(),
        threads: 1,
        perf_json: None,
        ..TrainOptions::default()
    };
    train_bench(&mk("sobel", 1)).unwrap();
    train_bench(&mk("kmeans", 2)).unwrap();
    let man = Manifest::load(&out_dir).unwrap();
    assert!(man.bench("sobel").is_ok());
    assert!(man.bench("kmeans").is_ok());
    assert!(out_dir.join("sobel/weights_rust.bin").exists());
    assert!(out_dir.join("kmeans/weights_rust.bin").exists());
    let _ = std::fs::remove_dir_all(&out_dir);
}
