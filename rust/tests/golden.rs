//! Cross-language golden tests: the Rust precise implementations and the
//! Rust MLP engine must agree with what the Python build computed.
//!
//! Requires `make artifacts` (skips with a message otherwise, so unit test
//! runs don't hard-depend on the build step).

use mcma::benchmarks;
use mcma::formats::Manifest;
use mcma::util::json;

fn artifacts() -> Option<Manifest> {
    Manifest::load(&mcma::artifacts_dir()).ok()
}

fn golden() -> Option<json::Value> {
    json::parse_file(&mcma::artifacts_dir().join("golden.json")).ok()
}

#[test]
fn precise_functions_match_python_golden_vectors() {
    let (Some(man), Some(g)) = (artifacts(), golden()) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut checked = 0;
    for (bench_name, entry) in g.as_obj().unwrap() {
        let bench = man.bench(bench_name).unwrap();
        let benchfn = benchmarks::by_name(bench_name).unwrap();
        let xs = entry.req("x_raw").unwrap().as_arr().unwrap();
        let ys = entry.req("y_norm").unwrap().as_arr().unwrap();
        for (x, y_want) in xs.iter().zip(ys) {
            let x: Vec<f32> = x.as_f32_vec().unwrap();
            let y_want: Vec<f64> = y_want.as_f64_vec().unwrap();
            let mut raw = vec![0.0f64; bench.n_out];
            benchfn.eval(&x, &mut raw);
            let mut norm = vec![0.0f32; bench.n_out];
            bench.normalize_y_into(&raw, &mut norm);
            for (j, (&got, &want)) in norm.iter().zip(&y_want).enumerate() {
                // Inputs pass through f32; tolerate small drift but catch
                // any real formula divergence.
                assert!(
                    (got as f64 - want).abs() < 2e-3,
                    "{bench_name} golden mismatch at out[{j}]: {got} vs {want} (x={x:?})"
                );
            }
            checked += 1;
        }
    }
    assert!(checked >= 8, "golden vectors missing ({checked} checked)");
}

#[test]
fn native_mlp_matches_python_pallas_forward() {
    let (Some(man), Some(g)) = (artifacts(), golden()) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    for (bench_name, entry) in g.as_obj().unwrap() {
        let bench = man.bench(bench_name).unwrap();
        let method = entry.req("mlp_method").unwrap().as_str().unwrap();
        let wf = mcma::formats::WeightsFile::load(&man.weights_path(bench_name)).unwrap();
        let mlp = &wf.get(method).unwrap().approximators[0];

        let xin = entry.req("mlp_forward_in").unwrap().as_arr().unwrap();
        let want = entry.req("mlp_forward_out").unwrap().as_arr().unwrap();
        for (x, w) in xin.iter().zip(want) {
            let x: Vec<f32> = x.as_f32_vec().unwrap();
            let w: Vec<f64> = w.as_f64_vec().unwrap();
            let got = mlp.forward1(&x);
            assert_eq!(got.len(), bench.n_out);
            for (j, (&g_, &w_)) in got.iter().zip(&w).enumerate() {
                assert!(
                    (g_ as f64 - w_).abs() < 1e-4,
                    "{bench_name}/{method} forward mismatch out[{j}]: {g_} vs {w_}"
                );
            }
        }
    }
}

#[test]
fn dataset_precise_outputs_reproducible_from_raw_inputs() {
    let Some(man) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // The dataset's stored Y_norm must equal our precise path applied to
    // its stored raw inputs — the strongest cross-language contract.
    for name in man.bench_names_ordered() {
        let bench = man.bench(&name).unwrap();
        let ds = mcma::formats::Dataset::load(&man.dataset_path(&name)).unwrap();
        let benchfn = benchmarks::by_name(&name).unwrap();
        let check_n = ds.n.min(200);
        let mut raw = vec![0.0f64; bench.n_out];
        let mut norm = vec![0.0f32; bench.n_out];
        let mut worst = 0.0f64;
        for i in 0..check_n {
            benchfn.eval(ds.x_row(i), &mut raw);
            bench.normalize_y_into(&raw, &mut norm);
            for (a, b) in norm.iter().zip(ds.y_row(i)) {
                worst = worst.max((*a as f64 - *b as f64).abs());
            }
        }
        assert!(worst < 2e-3, "{name}: precise-path drift {worst}");
    }
}
