//! End-to-end tests for the online QoS subsystem: offline-replay
//! determinism, margin monotonicity (tighter target ⇒ invocation never
//! increases), circuit-breaker behaviour against a genuinely bad
//! approximator set, and the serve-with-QoS pipeline next to
//! `tests/train_roundtrip.rs`.  Synthetic banks keep everything
//! artifact-free; the serve test trains a tiny real tree first.

use std::collections::HashMap;
use std::sync::Arc;

use mcma::config::{BatchPolicy, ExecMode, Method};
use mcma::coordinator::{Dispatcher, Route, RoutePlan, Scratch, Server, ServerConfig};
use mcma::formats::weights::{MethodWeights, WeightsFile};
use mcma::formats::{BenchManifest, Dataset};
use mcma::qos::{self, Controller, QosConfig, MARGIN_PRECISE};
use mcma::runtime::ModelBank;
use mcma::train::{train_bench, TrainOptions};
use mcma::util::prop::gens;
use mcma::util::rng::Rng;

const K: usize = 3;

/// Blackscholes-shaped synthetic manifest (mirrors `benches/hotpath.rs`).
fn synthetic_manifest() -> BenchManifest {
    BenchManifest {
        name: "blackscholes".into(),
        domain: "synthetic".into(),
        kind: mcma::formats::WorkloadKind::Synthetic,
        source_digest: String::new(),
        n_in: 6,
        n_out: 1,
        approx_topology: vec![6, 8, 8, 1],
        clf2_topology: vec![6, 8, 2],
        clfn_topology: vec![6, 8, K + 1],
        x_lo: vec![0.0; 6],
        x_hi: vec![1.0; 6],
        y_lo: vec![0.0],
        y_hi: vec![1.0],
        error_bound: 0.05,
        train_n: 0,
        test_n: 0,
        methods: vec!["mcma_competitive".into()],
        mcca_pairs: 0,
    }
}

fn synthetic_bank(rng: &mut Rng) -> ModelBank {
    let mw = MethodWeights {
        method: "mcma_competitive".into(),
        cascade: false,
        clf_classes: K + 1,
        classifiers: vec![gens::mlp(rng, &[6, 8, K + 1], 1.0, 0.5)],
        approximators: (0..K).map(|_| gens::mlp(rng, &[6, 8, 8, 1], 1.0, 0.5)).collect(),
    };
    let mut methods = HashMap::new();
    methods.insert("mcma_competitive".to_string(), mw);
    ModelBank::from_host("blackscholes", WeightsFile { methods })
}

/// Pick a synthetic-net seed whose random classifier actually spreads
/// traffic onto the approximators (a degenerate draw could argmax every
/// sample into one class or straight to reject).  Deterministic: the
/// first qualifying seed of a fixed candidate list.
fn spread_seed(man: &BenchManifest, ds: &Dataset) -> u64 {
    for seed in [0xB00C, 7, 99, 12345, 0xACE5, 31337] {
        let mut rng = Rng::new(seed);
        let bank = synthetic_bank(&mut rng);
        let d =
            Dispatcher::new(man, &bank, Method::McmaCompetitive, ExecMode::Native).unwrap();
        let x = d.normalize(&ds.x_raw, ds.n);
        let mut plan = RoutePlan::default();
        let mut scratch = Scratch::new();
        d.plan_into(&x, ds.n, &mut plan, &mut scratch).unwrap();
        if plan.invocation() > 0.2 {
            return seed;
        }
    }
    panic!("no synthetic seed routes traffic to the approximators");
}

/// Dataset with ground truth from the real precise function, inputs from
/// its generator.
fn synthetic_dataset(man: &BenchManifest, n: usize, seed: u64) -> Dataset {
    let benchfn = mcma::benchmarks::by_name(&man.name).unwrap();
    let mut rng = Rng::new(seed);
    let mut x_raw = vec![0.0f32; n * man.n_in];
    for row in x_raw.chunks_exact_mut(man.n_in) {
        benchfn.gen_into(&mut rng, row);
    }
    let y_norm = mcma::benchmarks::eval_batch_normalized(benchfn.as_ref(), man, &x_raw, n);
    Dataset { n, d_in: man.n_in, d_out: man.n_out, x_raw, y_norm }
}

/// The offline replay is deterministic for a fixed seed: identical
/// margins, invocations and counters on every run, and the headroom
/// inequality `invocation_adaptive >= invocation_fixed` holds (it is the
/// `mcma summary` acceptance row).  The dataset is tall enough that the
/// baseline plans take the sharded parallel forward, so this also pins
/// the replay against the machine's thread count.
#[test]
fn sim_deterministic_and_adaptive_beats_fixed() {
    let man = synthetic_manifest();
    let ds = synthetic_dataset(&man, 4096, 0x7E57);
    let bank = synthetic_bank(&mut Rng::new(spread_seed(&man, &ds)));
    let d = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::Native).unwrap();
    let qos = QosConfig {
        target: 0.2,
        shadow_rate: 0.5,
        window: 64,
        min_obs: 16,
        tick_every: 32,
        ..QosConfig::default()
    };
    let a = qos::simulate(&d, &ds, &qos, 256).unwrap();
    let b = qos::simulate(&d, &ds, &qos, 256).unwrap();
    assert_eq!(a.final_margins, b.final_margins, "margins must be bit-identical");
    assert_eq!(a.invocation_adaptive, b.invocation_adaptive);
    assert_eq!(a.invocation_fixed, b.invocation_fixed);
    assert_eq!(a.invocation_argmax, b.invocation_argmax);
    assert_eq!(a.report.ticks, b.report.ticks);
    assert_eq!(a.report.total_shadow(), b.report.total_shadow());
    assert_eq!(a.report.total_violations(), b.report.total_violations());

    assert!(a.report.total_shadow() > 0, "shadow sampling never fired");
    assert!(
        a.invocation_adaptive >= a.invocation_fixed,
        "adaptive {} must be >= fixed {}",
        a.invocation_adaptive,
        a.invocation_fixed
    );
    assert!(a.invocation_argmax >= a.invocation_fixed);
    // Per-class invoked counters in the report partition the invoked set.
    let invoked: u64 = a.report.classes.iter().map(|c| c.invoked).sum();
    assert_eq!(invoked as f64 / ds.n as f64, a.invocation_adaptive);
}

/// Margin monotonicity end to end: feed the SAME shadow-observation
/// stream (from one argmax pass) to controllers at tightening targets,
/// then apply each controller's final margins to the same dataset — the
/// invocation must never increase as the target tightens.
#[test]
fn tighter_target_never_increases_invocation() {
    let man = synthetic_manifest();
    let ds = synthetic_dataset(&man, 1500, 0x51EE);
    let bank = synthetic_bank(&mut Rng::new(spread_seed(&man, &ds)));
    let d = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::Native).unwrap();

    // One argmax pass gives the (class, served-error) stream.
    let out = d.run_dataset(&ds).unwrap();
    let stream: Vec<(usize, f64)> = out
        .plan
        .routes
        .iter()
        .zip(&out.err)
        .filter_map(|(r, &e)| match r {
            Route::Approx(k) => Some((*k, e)),
            Route::Cpu => None,
        })
        .collect();
    assert!(stream.len() > 100, "synthetic classifier rejects everything");

    let x_norm = d.normalize(&ds.x_raw, ds.n);
    let mut invocations = Vec::new();
    // Ascending targets = loosening; breaker disabled so the shared
    // stream keeps both controllers' evidence identical (see the
    // controller's open-loop monotonicity unit test).
    // The last target is unreachably loose (random-net errors are O(1)),
    // so its controller must never move a margin.
    for target in [0.005, 0.02, 0.1, 0.5, 1e9] {
        let mut ctrl = Controller::new(
            QosConfig {
                target,
                window: 64,
                min_obs: 8,
                tick_every: 16,
                breaker_trip: u32::MAX,
                ..QosConfig::default()
            },
            K,
        );
        for &(k, e) in &stream {
            ctrl.observe(k, e);
            ctrl.maybe_tick();
        }
        let mut margins = Vec::new();
        ctrl.margins_into(&mut margins);
        let mut plan = RoutePlan::default();
        let mut scratch = Scratch::new();
        d.plan_with_margins_into(&x_norm, ds.n, Some(&margins), &mut plan, &mut scratch)
            .unwrap();
        invocations.push(plan.invocation());
    }
    for w in invocations.windows(2) {
        assert!(
            w[0] <= w[1] + 1e-12,
            "tighter target increased invocation: {invocations:?}"
        );
    }
    // The loosest target must reduce to pure argmax routing.
    let argmax_inv = out.plan.invocation();
    assert!((invocations.last().unwrap() - argmax_inv).abs() < 1e-12);
}

/// A hopeless approximator set under a tight target must trip the
/// circuit breaker: sustained violation forces classes precise, adaptive
/// invocation collapses below argmax, and the conservative global
/// threshold goes fully precise.
#[test]
fn breaker_trips_on_hopeless_approximators() {
    let man = synthetic_manifest();
    let ds = synthetic_dataset(&man, 2000, 0xFEED);
    // Random nets: served error is O(1), hopeless under a 1e-4 target.
    let bank = synthetic_bank(&mut Rng::new(spread_seed(&man, &ds)));
    let d = Dispatcher::new(&man, &bank, Method::McmaCompetitive, ExecMode::Native).unwrap();
    let qos = QosConfig {
        target: 1e-4, // unreachable for a random net
        shadow_rate: 1.0,
        window: 64,
        min_obs: 8,
        tick_every: 16,
        breaker_trip: 2,
        breaker_cooldown: 2,
        ..QosConfig::default()
    };
    let sim = qos::simulate(&d, &ds, &qos, 128).unwrap();
    assert!(sim.report.total_violations() > 0);
    assert!(sim.report.total_trips() > 0, "breaker never tripped");
    assert!(
        sim.global_margin >= MARGIN_PRECISE,
        "a tripped class must force the global threshold precise"
    );
    assert_eq!(sim.invocation_fixed, 0.0);
    assert!(
        sim.invocation_adaptive < sim.invocation_argmax,
        "sustained violation must shed invocation"
    );
    assert!(sim.invocation_adaptive >= sim.invocation_fixed);
}

fn tmp_out(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("mcma_qos_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Serve-with-QoS end to end: train a tiny real tree, run the threaded
/// pipeline with the QoS loop enabled at a loose target, and check the
/// report's per-class QoS counters.  With a loose target margins stay at
/// zero, routing is per-sample deterministic on the f32 engine, and the
/// shadow pick is a pure id hash — so invocation AND the shadow count
/// must be identical across worker counts.
#[test]
fn serve_with_qos_end_to_end() {
    let out_dir = tmp_out("serve");
    train_bench(&TrainOptions {
        bench: "blackscholes".into(),
        k: 2,
        samples: 400,
        rounds: 2,
        epochs: 3,
        seed: 11,
        out_dir: out_dir.clone(),
        threads: 2,
        perf_json: None,
        ..TrainOptions::default()
    })
    .unwrap();

    let man = Arc::new(mcma::formats::Manifest::load(&out_dir).unwrap());
    let bench = Arc::new(man.bench("blackscholes").unwrap().clone());
    let benchfn = mcma::benchmarks::by_name("blackscholes").unwrap();
    let qos = QosConfig {
        target: 10.0, // generous: the trained workload must show 0 violations
        shadow_rate: 0.5,
        window: 64,
        min_obs: 8,
        tick_every: 16,
        ..QosConfig::default()
    };

    let run = |workers: usize| {
        let server = Server::spawn(
            Arc::clone(&man),
            Arc::clone(&bench),
            ServerConfig {
                policy: BatchPolicy { max_batch: 64, max_wait_us: 500 },
                method: Method::McmaCompetitive,
                exec: ExecMode::Native,
                workers,
                qos: Some(qos),
                table_fallback: Default::default(),
            },
        )
        .unwrap();
        let mut rng = Rng::new(42);
        let mut x = vec![0.0f32; bench.n_in];
        let n = 600u64;
        for id in 0..n {
            benchfn.gen_into(&mut rng, &mut x);
            server.submit(id, x.clone()).unwrap();
        }
        let report = server.shutdown(Vec::new()).unwrap();
        assert_eq!(report.served, n, "requests lost (workers={workers})");
        report
    };

    let r1 = run(1);
    let q1 = r1.qos.as_ref().expect("qos report missing");
    assert_eq!(q1.classes.len(), 2, "one QoS row per approximator class");
    assert_eq!(q1.total_violations(), 0, "loose target must show zero violations");
    assert_eq!(q1.total_trips(), 0);
    // The controller's per-class invoked counters agree with the
    // per-route report aggregated from the responses.
    for c in &q1.classes {
        assert_eq!(
            c.invoked,
            r1.per_route.classes.get(c.class).map(|s| s.count).unwrap_or(0),
            "class {} counter drift",
            c.class
        );
        assert!(c.shadow_n <= c.invoked, "shadowed more than served");
        assert!(c.margin == 0.0, "loose target must not move margins");
    }
    assert_eq!(r1.per_route.total(), r1.served);
    assert_eq!(r1.per_route.invoked(), r1.invoked);

    // Thread-count determinism of routing + shadow selection.
    let r2 = run(2);
    let q2 = r2.qos.as_ref().unwrap();
    assert_eq!(r1.invoked, r2.invoked, "routing drifted across worker counts");
    assert_eq!(
        q1.total_shadow(),
        q2.total_shadow(),
        "shadow sampling drifted across worker counts"
    );
    let _ = std::fs::remove_dir_all(&out_dir);
}

/// The native trainer's trajectory file round-trips through the fig9
/// fallback schema (ROADMAP open item: fig9 from native history).
#[test]
fn fig9_reads_native_round_stats() {
    let out_dir = tmp_out("fig9");
    train_bench(&TrainOptions {
        bench: "sobel".into(),
        k: 2,
        samples: 256,
        rounds: 2,
        epochs: 2,
        seed: 3,
        out_dir: out_dir.clone(),
        threads: 1,
        perf_json: None,
        ..TrainOptions::default()
    })
    .unwrap();
    let stats = out_dir.join("train_stats_rust.json");
    assert!(stats.exists(), "trainer must write train_stats_rust.json");
    let v = mcma::util::json::parse_file(&stats).unwrap();
    let hist = v
        .req("sobel")
        .unwrap()
        .req("mcma_competitive")
        .unwrap()
        .as_arr()
        .unwrap();
    assert!(!hist.is_empty());
    for it in hist {
        let inv = it.req("invocation").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&inv));
    }
    let _ = std::fs::remove_dir_all(&out_dir);
}
