//! End-to-end tests for the TCP serving front-end: socket responses
//! bitwise-identical to in-process dispatch, seed-deterministic load
//! sequences, malformed frames killing exactly one connection, the
//! dead-client drain regression (a client that sends and vanishes must
//! not stall `shutdown`), micro-batch coalescing under closed-loop load
//! next to sub-wait idle latency, and the QoS controller running
//! unchanged over socket traffic.
//!
//! One tiny real tree is trained once per process (`trained_dir`) and
//! shared by every test; each test runs its own `Server` + `NetServer`
//! on an ephemeral loopback port.

use std::io::Write;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use mcma::config::{BatchPolicy, ExecMode, Method};
use mcma::coordinator::{Route, Server, ServerConfig};
use mcma::formats::{BenchManifest, Dataset, Manifest};
use mcma::net::frame::{decode_response, encode_request, FramePoll, FrameReader};
use mcma::net::load::{run_load, scrape_stats};
use mcma::net::{http_get, Arrival, LoadConfig, MetricsServer, NetServer};
use mcma::obs::{expo, SloConfig, SloMonitor};
use mcma::qos::QosConfig;
use mcma::train::{train_bench, TrainOptions};

const BENCH: &str = "blackscholes";

/// Train the shared tiny tree exactly once per test process.
fn trained_dir() -> &'static Path {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("mcma_net_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        train_bench(&TrainOptions {
            bench: BENCH.into(),
            k: 2,
            samples: 400,
            rounds: 2,
            epochs: 3,
            seed: 11,
            out_dir: dir.clone(),
            threads: 2,
            perf_json: None,
            ..TrainOptions::default()
        })
        .unwrap();
        dir
    })
}

fn artifacts() -> (Arc<Manifest>, Arc<BenchManifest>, Arc<Dataset>) {
    let man = Arc::new(Manifest::load(trained_dir()).unwrap());
    let bench = Arc::new(man.bench(BENCH).unwrap().clone());
    let ds = Arc::new(Dataset::load(&man.dataset_path(BENCH)).unwrap());
    (man, bench, ds)
}

fn spawn_server(policy: BatchPolicy, qos: Option<QosConfig>) -> Server {
    let (man, bench, _) = artifacts();
    Server::spawn(
        man,
        Arc::clone(&bench),
        ServerConfig {
            policy,
            method: Method::McmaCompetitive,
            exec: ExecMode::Native,
            workers: 2,
            qos,
            table_fallback: Default::default(),
        },
    )
    .unwrap()
}

fn spawn_net(policy: BatchPolicy, qos: Option<QosConfig>) -> NetServer {
    let (_, bench, _) = artifacts();
    let server = spawn_server(policy, qos);
    NetServer::spawn(server, "127.0.0.1:0", 0, bench.n_in).unwrap()
}

/// Raw client: send `rows` as request frames (`id` = row index), read
/// until every response arrived, return `(y, route)` indexed by id.
fn roundtrip_rows(
    addr: std::net::SocketAddr,
    ds: &Dataset,
    rows: usize,
) -> Vec<(Vec<f32>, u16)> {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_millis(25)))
        .unwrap();
    let mut buf = Vec::new();
    for i in 0..rows {
        encode_request(&mut buf, 0, i as u64, ds.x_row(i));
        stream.write_all(&buf).unwrap();
    }
    let mut out: Vec<Option<(Vec<f32>, u16)>> = vec![None; rows];
    let mut got = 0usize;
    let mut fr = FrameReader::new();
    let mut y = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(30);
    while got < rows {
        assert!(Instant::now() < deadline, "responses stalled at {got}/{rows}");
        match fr.poll(&mut stream).unwrap() {
            FramePoll::Frame => {
                let head = decode_response(fr.payload(), &mut y).unwrap();
                let slot = &mut out[head.id as usize];
                assert!(slot.is_none(), "duplicate response id {}", head.id);
                *slot = Some((y.clone(), head.route));
                got += 1;
            }
            FramePoll::Pending => continue,
            FramePoll::Closed => panic!("server closed with {got}/{rows} answered"),
        }
    }
    out.into_iter().map(Option::unwrap).collect()
}

/// The acceptance bar: what a socket client reads back is bitwise
/// identical (f32 for f32) to what the in-process pipeline hands the
/// same rows, routes included.  `ExecMode::Native` serves rows
/// independently of batch shape, so micro-batching cannot perturb this.
#[test]
fn socket_responses_bitwise_match_in_process() {
    let (_, _, ds) = artifacts();
    let n = ds.n.min(96);
    let policy = BatchPolicy { max_batch: 32, max_wait_us: 2_000 };

    // In-process reference through the identical pipeline.
    let server = spawn_server(policy, None);
    for i in 0..n {
        server.submit(i as u64, ds.x_row(i).to_vec()).unwrap();
    }
    let mut reference: Vec<Option<(Vec<f32>, Route)>> = vec![None; n];
    let mut collected = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(30);
    while collected.len() < n {
        assert!(Instant::now() < deadline, "in-process run stalled");
        if let Some(resp) = server.recv_timeout(Duration::from_millis(50)) {
            reference[resp.id as usize] = Some((resp.y.clone(), resp.route));
            collected.push(resp);
        }
    }
    server.shutdown(collected).unwrap();

    // Same rows over the wire.
    let net = spawn_net(policy, None);
    let served = roundtrip_rows(net.local_addr(), &ds, n);
    let report = net.shutdown().unwrap();
    assert_eq!(report.server.served, n as u64);
    assert_eq!(report.malformed, 0);

    for (i, (y, route)) in served.iter().enumerate() {
        let (ref_y, ref_route) = reference[i].as_ref().unwrap();
        assert_eq!(y, ref_y, "row {i}: socket y diverged from in-process y");
        assert_eq!(*route, mcma::net::frame::route_to_wire(*ref_route), "row {i} route");
    }
}

/// Same seed ⇒ identical (class, row) request sequence and identical
/// CSV row count; different seed ⇒ different sequence.  The cap (not
/// the wall clock) ends the runs, so this holds on any machine.
#[test]
fn same_seed_runs_identical_request_sequences() {
    let (_, _, ds) = artifacts();
    let net = spawn_net(BatchPolicy { max_batch: 32, max_wait_us: 2_000 }, None);
    let cfg = |seed: u64| LoadConfig {
        addr: net.local_addr().to_string(),
        seed,
        duration: Duration::from_secs(60),
        max_requests: Some(120),
        arrival: Arrival::ClosedLoop { inflight: 8 },
        mix: vec![3.0, 1.0],
        tag: 0,
        qos_target: 10.0,
    };
    let a = run_load(&cfg(7), &ds).unwrap();
    let b = run_load(&cfg(7), &ds).unwrap();
    let c = run_load(&cfg(8), &ds).unwrap();
    net.shutdown().unwrap();

    let seq = |r: &mcma::net::LoadReport| -> Vec<(usize, usize)> {
        r.records.iter().map(|rec| (rec.class, rec.row)).collect()
    };
    assert_eq!(a.sent, 120);
    assert_eq!(a.received, 120, "closed-loop run lost responses");
    assert_eq!(seq(&a), seq(&b), "same seed must replay the same sequence");
    assert_ne!(seq(&a), seq(&c), "different seeds drew identical sequences");
    assert_eq!(a.per_class_sent, b.per_class_sent);

    // CSV artifacts agree row-for-row on the deterministic columns.
    let dir = std::env::temp_dir();
    let pa = dir.join(format!("mcma_net_csv_a_{}.csv", std::process::id()));
    let pb = dir.join(format!("mcma_net_csv_b_{}.csv", std::process::id()));
    a.write_csv(&pa).unwrap();
    b.write_csv(&pb).unwrap();
    let col_cr = |p: &Path| -> Vec<String> {
        std::fs::read_to_string(p)
            .unwrap()
            .lines()
            .map(|l| l.split(',').take(3).collect::<Vec<_>>().join(","))
            .collect()
    };
    assert_eq!(col_cr(&pa).len(), 121, "header + one line per request");
    assert_eq!(col_cr(&pa), col_cr(&pb));
    let _ = std::fs::remove_file(&pa);
    let _ = std::fs::remove_file(&pb);
}

/// A malformed (oversized) frame kills exactly its own connection; a
/// well-behaved neighbour keeps being served by the same process.
#[test]
fn malformed_frame_kills_only_its_connection() {
    let (_, _, ds) = artifacts();
    let net = spawn_net(BatchPolicy { max_batch: 16, max_wait_us: 1_000 }, None);

    // Hostile client: length prefix far beyond MAX_FRAME_BYTES.
    let mut evil = TcpStream::connect(net.local_addr()).unwrap();
    evil.write_all(&(u32::MAX).to_le_bytes()).unwrap();
    evil.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
    let deadline = Instant::now() + Duration::from_secs(5);
    let mut probe = [0u8; 16];
    loop {
        assert!(
            Instant::now() < deadline,
            "server never closed the malformed connection"
        );
        match std::io::Read::read(&mut evil, &mut probe) {
            Ok(0) => break,          // clean close
            Ok(_) => panic!("server answered a malformed frame"),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => continue,
            Err(_) => break,         // reset also counts as closed
        }
    }

    // A good client on the same server is unaffected.
    let served = roundtrip_rows(net.local_addr(), &ds, 8);
    assert_eq!(served.len(), 8);
    let report = net.shutdown().unwrap();
    assert!(report.malformed >= 1, "violation not counted");
    assert!(report.accepted >= 2);
    assert_eq!(report.server.served, 8);
}

/// Satellite regression: a client that submits a burst and disconnects
/// without reading anything must not stall the drain — shutdown
/// completes well under the pipeline's 2 s last-resort timeout, with
/// every response accounted for.
#[test]
fn dead_client_mid_flight_does_not_stall_shutdown() {
    let (_, _, ds) = artifacts();
    let net = spawn_net(BatchPolicy { max_batch: 64, max_wait_us: 20_000 }, None);
    let n = 32usize;
    {
        let mut stream = TcpStream::connect(net.local_addr()).unwrap();
        let mut buf = Vec::new();
        for i in 0..n {
            encode_request(&mut buf, 0, i as u64, ds.x_row(i));
            stream.write_all(&buf).unwrap();
        }
        // Drop without reading a single response.
    }
    // Let the reader ingest the burst before tearing down.
    std::thread::sleep(Duration::from_millis(300));
    let started = Instant::now();
    let report = net.shutdown().unwrap();
    let elapsed = started.elapsed();
    assert_eq!(
        report.server.served, n as u64,
        "responses owed to the dead client were lost, not collected"
    );
    assert!(
        elapsed < Duration::from_millis(1_500),
        "drain stalled {elapsed:?} on a dead client (2 s safety net territory)"
    );
}

/// The adaptive micro-batcher: closed-loop pressure produces multi-row
/// batches, while a single idle request is answered far sooner than the
/// full `--batch-wait-us` bound (the idle regime divides the wait).
#[test]
fn batches_coalesce_under_load_but_idle_stays_low_latency() {
    let (_, _, ds) = artifacts();
    // An enormous full-load wait: if the idle path waited it out, the
    // single-request probe below would take ≥ half a second.  max_batch
    // equals the closed-loop depth so the load phase flushes on FILL,
    // not on the (huge) age budget.
    let net = spawn_net(BatchPolicy { max_batch: 16, max_wait_us: 500_000 }, None);

    // Idle probe FIRST (fresh server is in the idle regime by
    // construction: the size EWMA starts at 1.0).
    let t0 = Instant::now();
    let one = roundtrip_rows(net.local_addr(), &ds, 1);
    let idle_latency = t0.elapsed();
    assert_eq!(one.len(), 1);
    assert!(
        idle_latency < Duration::from_millis(250),
        "idle request waited out the full batch window: {idle_latency:?}"
    );

    // Now sustained closed-loop pressure must coalesce.
    let report = run_load(
        &LoadConfig {
            addr: net.local_addr().to_string(),
            seed: 7,
            duration: Duration::from_secs(60),
            max_requests: Some(320),
            arrival: Arrival::ClosedLoop { inflight: 16 },
            mix: vec![1.0],
            tag: 0,
            qos_target: 10.0,
        },
        &ds,
    )
    .unwrap();
    net.shutdown().unwrap();
    assert_eq!(report.received, 320);
    assert!(
        report.multi_row_responses() > 0,
        "closed-loop load never produced a multi-row batch: {:?}",
        report.batch_hist
    );
}

/// The in-band STATS scrape: after real traffic, a KIND_STATS frame on
/// a second connection returns a JSON snapshot whose pipeline counters
/// and stage waterfall account for every row just served, with the QoS
/// margin/breaker section present.  The percentile checks use the
/// documented log2-bucket error bound: a reported percentile is within
/// a factor of 2 of the true value, and stage quantiles are pointwise
/// below e2e quantiles, so reported stage p50 <= 4 x reported e2e p50.
#[test]
fn stats_scrape_reports_stage_waterfall_and_qos() {
    let (_, _, ds) = artifacts();
    let qos = QosConfig {
        target: 10.0,
        shadow_rate: 0.5,
        window: 64,
        min_obs: 8,
        tick_every: 16,
        ..QosConfig::default()
    };
    let net = spawn_net(BatchPolicy { max_batch: 32, max_wait_us: 2_000 }, Some(qos));
    let n = 64usize;
    let served = roundtrip_rows(net.local_addr(), &ds, n);
    assert_eq!(served.len(), n);

    let snap = scrape_stats(&net.local_addr().to_string(), 0).expect("live scrape failed");
    net.shutdown().unwrap();

    let num = |path: &[&str]| -> f64 {
        let mut cur = &snap;
        for k in path {
            cur = cur.get(k).unwrap_or_else(|| panic!("snapshot missing {path:?}"));
        }
        cur.as_f64().unwrap_or_else(|| panic!("{path:?} is not a number"))
    };
    assert_eq!(num(&["counters", "submitted"]), n as f64);
    assert_eq!(num(&["counters", "dispatched"]), n as f64);
    // The client read every response before scraping, so the pump had
    // already recorded each delivery (same thread that answers STATS).
    assert_eq!(num(&["counters", "delivered"]), n as f64);
    assert_eq!(num(&["counters", "delivery_failures"]), 0.0);
    assert!(num(&["counters", "stats_requests"]) >= 1.0);
    assert_eq!(
        num(&["counters", "route_invoked_rows"]) + num(&["counters", "route_cpu_rows"]),
        n as f64,
        "route split must account for every row"
    );
    for stage in ["decode", "queue", "batch", "execute", "pump", "e2e_dispatch", "e2e_delivered"] {
        assert_eq!(
            num(&["stages", stage, "count"]),
            n as f64,
            "stage {stage} lost rows"
        );
    }
    // Waterfall consistency within the bucket error bound.
    let e2e_p50 = num(&["stages", "e2e_dispatch", "p50_us"]);
    assert!(e2e_p50 > 0.0, "e2e dispatch p50 cannot be zero for a TCP roundtrip");
    for stage in ["queue", "batch", "execute"] {
        let p50 = num(&["stages", stage, "p50_us"]);
        assert!(
            p50 <= 4.0 * e2e_p50 + 2.0,
            "stage {stage} p50 {p50} inconsistent with e2e p50 {e2e_p50}"
        );
    }
    // e2e_delivered >= e2e_dispatch pointwise, so within bucket error:
    assert!(
        num(&["stages", "e2e_delivered", "p50_us"]) >= e2e_p50 / 4.0 - 2.0,
        "delivered e2e collapsed below dispatch e2e"
    );
    // QoS margins/breakers surface through the scrape.
    assert_eq!(num(&["gauges", "qos_enabled"]), 1.0);
    let margins = snap.get("qos_margins").and_then(|v| v.as_arr()).expect("qos_margins");
    assert_eq!(margins.len(), 8, "fixed gauge slots");
    assert!(num(&["gauges", "open_breakers"]) >= 0.0);
    assert!(num(&["trace", "buffered"]) >= 0.0);
}

/// A malformed STATS frame (frame kind 3 with the wrong payload size)
/// is a protocol violation that kills exactly its own connection — the
/// scrape path reuses the reader's fatal-on-malformed discipline — while
/// a healthy client and a healthy scrape keep working on the server.
#[test]
fn malformed_stats_frame_kills_only_its_connection() {
    let (_, _, ds) = artifacts();
    let net = spawn_net(BatchPolicy { max_batch: 16, max_wait_us: 1_000 }, None);

    // Hostile scrape: valid envelope + version + KIND_STATS, but 13
    // payload bytes where the stats request header is exactly 12.
    let mut evil = TcpStream::connect(net.local_addr()).unwrap();
    let mut frame = Vec::new();
    frame.extend_from_slice(&13u32.to_le_bytes());
    frame.push(mcma::net::FRAME_VERSION);
    frame.push(mcma::net::KIND_STATS);
    frame.extend_from_slice(&[0u8; 11]);
    evil.write_all(&frame).unwrap();
    evil.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
    let deadline = Instant::now() + Duration::from_secs(5);
    let mut probe = [0u8; 16];
    loop {
        assert!(
            Instant::now() < deadline,
            "server never closed the malformed STATS connection"
        );
        match std::io::Read::read(&mut evil, &mut probe) {
            Ok(0) => break,          // clean close
            Ok(_) => panic!("server answered a malformed STATS frame"),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => continue,
            Err(_) => break,         // reset also counts as closed
        }
    }

    // The same server still serves rows and answers a healthy scrape.
    let served = roundtrip_rows(net.local_addr(), &ds, 8);
    assert_eq!(served.len(), 8);
    let snap = scrape_stats(&net.local_addr().to_string(), 0).unwrap();
    let malformed = snap
        .get("counters")
        .and_then(|c| c.get("malformed_frames"))
        .and_then(|v| v.as_f64());
    assert_eq!(malformed, Some(1.0), "exactly the hostile frame counted");
    let report = net.shutdown().unwrap();
    assert!(report.malformed >= 1, "violation not counted");
    assert_eq!(report.server.served, 8);
}

/// Tentpole consistency e2e: the HTTP OpenMetrics exposition and the
/// in-band KIND_STATS scrape are two read paths over the same registry
/// atomics.  After real socket traffic has fully drained, the
/// request-plane counters must agree exactly between the two; the
/// connection-plane counters (which our own scrapes keep moving) may
/// only run ahead in the later HTTP view, never behind.  The exposition
/// itself must be well-formed: `# EOF` terminator, `+Inf` bucket equal
/// to `_count` per stage family.
#[test]
fn http_metrics_agree_with_inband_stats() {
    let (_, bench, ds) = artifacts();
    let server = spawn_server(BatchPolicy { max_batch: 32, max_wait_us: 2_000 }, None);
    let obs = server.obs();
    let net = NetServer::spawn(server, "127.0.0.1:0", 0, bench.n_in).unwrap();
    let http = MetricsServer::spawn(obs, None, "127.0.0.1:0").unwrap();

    let n = 48usize;
    let served = roundtrip_rows(net.local_addr(), &ds, n);
    assert_eq!(served.len(), n);

    // STATS first, then HTTP: the only traffic between the two scrapes
    // is the scrapes themselves (connection-plane counters only).
    let snap = scrape_stats(&net.local_addr().to_string(), 0).expect("live scrape failed");
    let (status, body) =
        http_get(&http.local_addr().to_string(), "/metrics").expect("HTTP scrape failed");
    assert_eq!(status, 200);
    assert!(body.ends_with("# EOF\n"), "missing OpenMetrics terminator");

    let parsed = expo::parse_text(&body);
    let exp = |series: &str| {
        expo::series_value(&parsed, series)
            .unwrap_or_else(|| panic!("/metrics missing series {series}\n{body}"))
    };
    let stat = |key: &str| {
        snap.get("counters")
            .and_then(|v| v.get(key))
            .and_then(|v| v.as_f64())
            .unwrap_or_else(|| panic!("STATS snapshot missing counter {key}"))
    };
    for key in [
        "submitted",
        "dispatched",
        "delivered",
        "delivery_failures",
        "route_invoked_rows",
        "route_cpu_rows",
        "malformed_frames",
    ] {
        assert_eq!(
            exp(&format!("mcma_{key}_total")),
            stat(key),
            "/metrics and KIND_STATS disagree on {key}"
        );
    }
    assert_eq!(exp("mcma_submitted_total"), n as f64);
    // Stage histogram family: `+Inf` bucket and `_count` both equal the
    // in-band stage count.
    let stage_n = snap
        .get("stages")
        .and_then(|s| s.get("execute"))
        .and_then(|h| h.get("count"))
        .and_then(|v| v.as_f64())
        .expect("STATS execute stage");
    assert!(stage_n > 0.0);
    assert_eq!(exp("mcma_stage_execute_us_bucket{le=\"+Inf\"}"), stage_n);
    assert_eq!(exp("mcma_stage_execute_us_count"), stage_n);
    // Connection plane: the HTTP view is the later read.
    assert!(exp("mcma_accepted_conns_total") >= stat("accepted_conns"));
    assert!(exp("mcma_frames_in_total") >= stat("frames_in"));
    assert!(exp("mcma_stats_requests_total") >= 1.0);

    http.shutdown();
    net.shutdown().unwrap();
}

/// Acceptance e2e: an induced SLO breach flips `/healthz` from 200 to
/// 503 on the live exposition endpoint (and back after the windows
/// drain), with the breach visible in the `mcma_slo_*` families — the
/// full serve wiring minus the wall-clock tick thread, which the test
/// replaces with injected-clock ticks fed from the real delivered
/// histogram.
#[test]
fn slo_breach_flips_healthz_on_live_endpoint() {
    let (_, bench, ds) = artifacts();
    let server = spawn_server(BatchPolicy { max_batch: 32, max_wait_us: 2_000 }, None);
    let obs = server.obs();
    let net = NetServer::spawn(server, "127.0.0.1:0", 0, bench.n_in).unwrap();
    let slo = Arc::new(SloMonitor::new(SloConfig {
        short_window_us: 10_000_000,
        long_window_us: 60_000_000,
        // 1 µs target: every TCP roundtrip is over budget by design.
        ..SloConfig::new(1, 0.01)
    }));
    let http = MetricsServer::spawn(obs.clone(), Some(Arc::clone(&slo)), "127.0.0.1:0").unwrap();
    let addr = http.local_addr().to_string();

    let (code, _) = http_get(&addr, "/healthz").unwrap();
    assert_eq!(code, 200, "healthy before any tick");

    // Real traffic, then one tick off the real delivered histogram: at
    // a 1 µs target effectively every delivery is bad, so the warm-up
    // window burns at ~100x the 1% budget and breaches immediately.
    let n = 32usize;
    let served = roundtrip_rows(net.local_addr(), &ds, n);
    assert_eq!(served.len(), n);
    // The pump may record a delivery just after the client reads the
    // bytes; poll briefly rather than racing it.
    let deadline = Instant::now() + Duration::from_secs(5);
    let delivered = loop {
        let s = obs.metrics.e2e_delivered.snapshot();
        if s.count >= n as u64 || Instant::now() > deadline {
            break s;
        }
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!(delivered.count, n as u64);
    let bad = delivered.count_over(slo.config().p99_target_us);
    let tick = slo.tick(1_000_000, delivered.count, bad);
    assert!(tick.breached, "all-bad traffic must breach: {tick:?}");

    let (code, body) = http_get(&addr, "/healthz").unwrap();
    assert_eq!(code, 503, "breach must flip /healthz");
    assert_eq!(body, "slo breach\n");
    let (_, metrics) = http_get(&addr, "/metrics").unwrap();
    let parsed = expo::parse_text(&metrics);
    assert_eq!(expo::series_value(&parsed, "mcma_slo_healthy"), Some(0.0));
    assert!(
        expo::series_value(&parsed, "mcma_slo_burn_rate{window=\"short\"}").unwrap_or(0.0)
            >= 14.0,
        "{metrics}"
    );

    // Two clean minutes later both windows difference against the
    // breach sample itself: zero new bad, the breach clears.
    let tick = slo.tick(121_000_000, delivered.count + 1_000, bad);
    assert!(!tick.breached);
    let (code, _) = http_get(&addr, "/healthz").unwrap();
    assert_eq!(code, 200, "clean windows must recover /healthz");

    http.shutdown();
    net.shutdown().unwrap();
}

/// The QoS controller runs unchanged under socket traffic: the report
/// carries per-class rows and a generous target shows zero violations,
/// client-side and server-side alike.
#[test]
fn qos_controller_runs_over_socket_traffic() {
    let (_, _, ds) = artifacts();
    let qos = QosConfig {
        target: 10.0,
        shadow_rate: 0.5,
        window: 64,
        min_obs: 8,
        tick_every: 16,
        ..QosConfig::default()
    };
    let net = spawn_net(BatchPolicy { max_batch: 32, max_wait_us: 2_000 }, Some(qos));
    let report = run_load(
        &LoadConfig {
            addr: net.local_addr().to_string(),
            seed: 7,
            duration: Duration::from_secs(60),
            max_requests: Some(300),
            arrival: Arrival::ClosedLoop { inflight: 8 },
            mix: vec![1.0],
            tag: 0,
            qos_target: 10.0,
        },
        &ds,
    )
    .unwrap();
    let net_report = net.shutdown().unwrap();
    assert_eq!(report.received, 300);
    assert_eq!(report.violations, 0, "generous client-side target violated");
    let q = net_report.server.qos.as_ref().expect("qos report missing over socket");
    assert_eq!(q.total_violations(), 0);
    assert_eq!(q.classes.len(), 2, "one QoS row per approximator class");
}
