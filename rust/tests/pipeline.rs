//! Integration tests over real artifacts: PJRT-vs-native numerics, the
//! whole coordinator, the MCCA cascade, the online server, and the NPU
//! simulation consistency.  Skip (with a message) when artifacts are absent.

use std::sync::Arc;

use mcma::config::{BatchPolicy, ExecMode, Method, RunConfig};
use mcma::coordinator::{Dispatcher, Route, Server, ServerConfig};
use mcma::eval::{self, Context};
use mcma::runtime::Role;
use mcma::util::rng::Rng;

fn ctx(exec: ExecMode) -> Option<Context> {
    let cfg = RunConfig { exec, max_samples: 512, ..Default::default() };
    Context::load(cfg).ok()
}

#[test]
fn pjrt_matches_native_forward() {
    let Some(ctx) = ctx(ExecMode::Pjrt) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // The PJRT path runs the Pallas-lowered HLO; the native path is an
    // independent Rust implementation.  They must agree to f32 tolerance
    // on every benchmark topology and both compiled batch sizes.
    for name in ["bessel", "jpeg", "jmeint"] {
        let bench = ctx.man.bench(name).unwrap().clone();
        let method = Method::McmaCompetitive;
        let bank = ctx.bank(&bench, &[method]).unwrap();
        let dp = Dispatcher::new(&bench, &bank, method, ExecMode::Pjrt).unwrap();
        let dn = Dispatcher::new(&bench, &bank, method, ExecMode::Native).unwrap();
        let ds = ctx.dataset(name).unwrap();
        let x = dp.normalize(&ds.x_raw, ds.n);
        for role in [Role::Approx, Role::ClfN] {
            for n in [1usize, 7, 256, ds.n.min(400)] {
                let chunk = &x[..n * bench.n_in];
                let a = dp.forward(role, 0, chunk, n).unwrap();
                let b = dn.forward(role, 0, chunk, n).unwrap();
                assert_eq!(a.len(), b.len());
                for (i, (x_, y_)) in a.iter().zip(&b).enumerate() {
                    assert!(
                        (x_ - y_).abs() < 1e-4 + 1e-4 * y_.abs(),
                        "{name} {role:?} n={n} elem {i}: pjrt {x_} vs native {y_}"
                    );
                }
            }
        }
    }
}

#[test]
fn coordinator_end_to_end_all_methods() {
    let Some(ctx) = ctx(ExecMode::Pjrt) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let bench = ctx.man.bench("blackscholes").unwrap().clone();
    let methods = Method::ALL;
    let bank = ctx.bank(&bench, &methods).unwrap();
    let ds = ctx.dataset("blackscholes").unwrap();
    for m in methods {
        let d = Dispatcher::new(&bench, &bank, m, ExecMode::Pjrt).unwrap();
        let out = d.run_dataset(&ds).unwrap();
        // Invariants: routing is total, outputs are filled, CPU samples
        // carry zero served error (they are computed precisely).
        assert_eq!(out.plan.routes.len(), ds.n);
        assert_eq!(out.y_served.len(), ds.n * bench.n_out);
        assert_eq!(out.err.len(), ds.n);
        for (i, r) in out.plan.routes.iter().enumerate() {
            match r {
                Route::Cpu => assert_eq!(out.err[i], 0.0, "{} cpu err", m.key()),
                Route::Approx(k) => assert!(*k < d.n_approx(), "{} class oob", m.key()),
            }
        }
        let inv = out.metrics.invocation();
        assert!((0.0..=1.0).contains(&inv));
        assert!(out.metrics.true_invocation() <= inv + 1e-12);
        // Quadrants partition the dataset.
        let q = out.metrics.quadrants;
        assert_eq!(q.ac + q.n_ac + q.a_nc + q.nanc, ds.n);
    }
}

#[test]
fn mcca_cascade_routes_by_stage_priority() {
    let Some(ctx) = ctx(ExecMode::Native) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let bench = ctx.man.bench("bessel").unwrap().clone();
    let bank = ctx.bank(&bench, &[Method::Mcca]).unwrap();
    let d = Dispatcher::new(&bench, &bank, Method::Mcca, ExecMode::Native).unwrap();
    let ds = ctx.dataset("bessel").unwrap();
    let out = d.run_dataset(&ds).unwrap();
    let stages = bank.host.get("mcca").unwrap().classifiers.len();
    assert!(stages >= 1);
    // A sample accepted by stage 0's classifier must be routed to stage 0.
    let x_norm = d.normalize(&ds.x_raw, ds.n);
    let logits = d.forward(Role::Clf2, 0, &x_norm, ds.n).unwrap();
    let accept0 = mcma::nn::argmax_rows(&logits, ds.n, 2);
    for i in 0..ds.n {
        if accept0[i] == 0 {
            assert_eq!(out.plan.routes[i], Route::Approx(0), "stage priority at {i}");
        }
    }
}

#[test]
fn eval_and_npu_sim_consistent() {
    let Some(ctx) = ctx(ExecMode::Native) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let bench = ctx.man.bench("sobel").unwrap().clone();
    let bank = ctx.bank(&bench, &[Method::OnePass]).unwrap();
    let e = eval::eval_one(&ctx, &bench, &bank, Method::OnePass).unwrap();
    // CPU-only cycle total must equal n * per-sample CPU cycles.
    let benchfn = mcma::benchmarks::by_name("sobel").unwrap();
    let want = (e.out.plan.routes.len() as f64) * benchfn.cpu_cycles() as f64;
    assert!((e.sim.cycles_cpu_only - want).abs() < 1e-6);
    // Invoking nothing or everything bounds the mixed cycle count.
    assert!(e.sim.cycles > 0.0);
}

#[test]
fn server_round_trip_no_losses() {
    let Some(_probe) = ctx(ExecMode::Native) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let man = Arc::new(mcma::formats::Manifest::load(&mcma::artifacts_dir()).unwrap());
    let bench = Arc::new(man.bench("kmeans").unwrap().clone());
    let benchfn = mcma::benchmarks::by_name("kmeans").unwrap();
    let server = Server::spawn(
        Arc::clone(&man),
        Arc::clone(&bench),
        ServerConfig {
            policy: BatchPolicy { max_batch: 64, max_wait_us: 500 },
            method: Method::McmaCompetitive,
            exec: ExecMode::Native,
            workers: 2, // exercise the multi-worker shared-queue path
            qos: None,
            table_fallback: Default::default(),
        },
    )
    .unwrap();
    let mut rng = Rng::new(11);
    let mut x = vec![0.0f32; bench.n_in];
    let n = 1000;
    for id in 0..n {
        benchfn.gen_into(&mut rng, &mut x);
        server.submit(id, x.clone()).unwrap();
    }
    let report = server.shutdown(Vec::new()).unwrap();
    assert_eq!(report.served, n, "requests lost");
    assert!(report.latency.p50() > 0.0);
    assert!(report.batches >= (n as usize / 64) as u64);
    // Per-route counters partition the served set; no QoS was configured.
    assert_eq!(report.per_route.total(), report.served);
    assert_eq!(report.per_route.invoked(), report.invoked);
    assert_eq!(report.per_route.cpu.count, report.cpu);
    assert!(report.qos.is_none());
}

#[test]
fn truncated_dataset_respected() {
    let Some(ctx) = ctx(ExecMode::Native) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let ds = ctx.dataset("fft").unwrap();
    assert!(ds.n <= 512, "max_samples cap ignored: {}", ds.n);
}
