//! `cargo run -p xtask -- audit [--root DIR] [--json PATH]`
//!
//! Exit status: 0 when the tree is clean, 1 when any finding survives
//! suppression, 2 on usage / IO errors.

use std::path::PathBuf;
use std::process::exit;

const HELP: &str = "\
mcma-audit — repo-invariant static analysis for rust/src

USAGE:
  cargo run -p xtask -- audit [--root DIR] [--json PATH]

OPTIONS:
  --root DIR    single tree to scan (default: the whole rust/ crate —
                src, xtask/src, tests, benches; fixture trees excluded)
  --json PATH   also write the machine-readable report (schema 1)

RULES:
  cli-registry     USAGE text, option/positional lookups, and the
                   VALUE_KEYS/FLAG_KEYS/POSITIONAL_KEYS registries agree
  panic-free-net   no unwrap/expect/panic!/indexing in connection-facing code
  determinism      no wall clock / hash order / thread identity in
                   audit:deterministic modules
  safety-comments  every `unsafe` carries a // SAFETY: rationale
  atomics          every Ordering::Relaxed outside the counter module is
                   individually justified
  lock-ordering    audit:lock-ordered files take the Server/NetServer
                   mutexes in the fixed order batch_rx -> registry ->
                   reader_threads

Suppress a finding with `// audit:allow(<rule>) — <reason>` on the same
or the preceding line; allows without a reason or without a matching
finding are themselves findings.
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.first().map(String::as_str) != Some("audit") {
        eprint!("{HELP}");
        exit(2);
    }
    let mut root: Option<PathBuf> = None;
    let mut json: Option<PathBuf> = None;
    let mut it = argv.iter().skip(1);
    while let Some(tok) = it.next() {
        match tok.as_str() {
            "--root" => root = it.next().map(PathBuf::from),
            "--json" => json = it.next().map(PathBuf::from),
            "--help" | "-h" => {
                print!("{HELP}");
                exit(0);
            }
            other => {
                eprintln!("unknown argument {other:?}\n\n{HELP}");
                exit(2);
            }
        }
    }
    // `--root` pins a single tree (fixtures, experiments); the default
    // is the combined src + xtask/src + tests + benches sweep.
    let report = match &root {
        Some(dir) => xtask::audit_dir(dir),
        None => xtask::audit_tree(&PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..")),
    };
    let report = match report {
        Ok(r) => r,
        Err(e) => {
            let shown = root.unwrap_or_else(|| {
                PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..")
            });
            eprintln!("mcma-audit: cannot scan {}: {e}", shown.display());
            exit(2);
        }
    };

    for f in &report.findings {
        println!("{}/{}:{}: [{}] {}", report.root, f.file, f.line, f.rule, f.message);
    }
    println!(
        "mcma-audit: {} files scanned, {} finding(s), {} justified allow(s)",
        report.files_scanned,
        report.findings.len(),
        report.allows.len()
    );

    if let Some(path) = json {
        if let Err(e) = std::fs::write(&path, xtask::to_json(&report)) {
            eprintln!("mcma-audit: cannot write {}: {e}", path.display());
            exit(2);
        }
        println!("mcma-audit: wrote {}", path.display());
    }

    exit(if report.clean() { 0 } else { 1 });
}
