//! mcma-audit: the repo-invariant static-analysis pass.
//!
//! `cargo run -p xtask -- audit` walks the whole `rust/` tree (`src`,
//! `xtask/src`, `tests`, `benches` — see [`TREE_ROOTS`]), lexes every
//! file with the hand-rolled lexer in [`lex`], applies the five repo
//! rules in [`rules`], and reports `file:line` diagnostics plus a
//! machine-readable JSON document for CI.  Zero dependencies by design:
//! the pass must run in the offline build container with nothing but
//! std.

pub mod lex;
pub mod rules;

use std::fs;
use std::io;
use std::path::Path;

pub use rules::{Allow, Finding};

/// One complete audit run.
#[derive(Debug)]
pub struct Report {
    pub root: String,
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    pub allows: Vec<Allow>,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Audit every `*.rs` file under `root` (recursively, sorted, skipping
/// `target/` and dot-directories so the walk order — and therefore the
/// report — is deterministic).
pub fn audit_dir(root: &Path) -> io::Result<Report> {
    let mut rels = Vec::new();
    walk(root, Path::new(""), &mut rels)?;
    rels.sort();
    let mut files = Vec::new();
    for rel in &rels {
        let src = fs::read_to_string(root.join(rel))?;
        files.push(lex::lex(rel, &src));
    }
    let (findings, allows) = rules::audit(&files);
    Ok(Report {
        root: root.display().to_string(),
        files_scanned: files.len(),
        findings,
        allows,
    })
}

/// Roots scanned by [`audit_tree`], as `(subdir, rel-prefix)` relative
/// to the `rust/` crate directory.  `src` keeps unprefixed rels so the
/// REQUIRED_* / ATOMICS_COUNTER_MODULES path lists in [`rules`] keep
/// matching; the other trees are prefixed so findings print a usable
/// path.  `xtask/tests` is deliberately absent: its fixtures seed the
/// very violations the rules exist to catch.
pub const TREE_ROOTS: [(&str, &str); 4] = [
    ("src", ""),
    ("xtask/src", "xtask/src/"),
    ("tests", "tests/"),
    ("benches", "benches/"),
];

/// Audit the whole `rust/` tree in one pass: the library, the analyzer's
/// own source, and the integration-test / bench trees.  One combined
/// pass (rather than four [`audit_dir`] calls) so cross-file rules like
/// `cli-registry` see lookups in every tree against the one registry.
/// Missing roots are skipped, so partial checkouts still scan.
pub fn audit_tree(rust_dir: &Path) -> io::Result<Report> {
    let mut files = Vec::new();
    for (sub, prefix) in TREE_ROOTS {
        let root = rust_dir.join(sub);
        if !root.is_dir() {
            continue;
        }
        let mut rels = Vec::new();
        walk(&root, Path::new(""), &mut rels)?;
        rels.sort();
        for rel in &rels {
            let src = fs::read_to_string(root.join(rel))?;
            files.push(lex::lex(&format!("{prefix}{rel}"), &src));
        }
    }
    let (findings, allows) = rules::audit(&files);
    Ok(Report {
        root: rust_dir.display().to_string(),
        files_scanned: files.len(),
        findings,
        allows,
    })
}

fn walk(root: &Path, rel: &Path, out: &mut Vec<String>) -> io::Result<()> {
    for entry in fs::read_dir(root.join(rel))? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with('.') || name == "target" {
            continue;
        }
        let sub = rel.join(name.as_ref());
        let ty = entry.file_type()?;
        if ty.is_dir() {
            walk(root, &sub, out)?;
        } else if name.ends_with(".rs") {
            // `/`-separated rel paths keep rule path-matching portable.
            out.push(sub.to_string_lossy().replace('\\', "/"));
        }
    }
    Ok(())
}

/// Serialize the report (schema 1) — hand-rolled, like everything else
/// here, so the analyzer stays dependency-free.
pub fn to_json(r: &Report) -> String {
    let mut s = String::with_capacity(4096);
    s.push_str("{\"schema\":1,\"root\":");
    json_str(&r.root, &mut s);
    s.push_str(&format!(",\"files_scanned\":{}", r.files_scanned));
    s.push_str(",\"findings\":[");
    for (i, f) in r.findings.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str("{\"rule\":");
        json_str(&f.rule, &mut s);
        s.push_str(",\"file\":");
        json_str(&f.file, &mut s);
        s.push_str(&format!(",\"line\":{},\"message\":", f.line));
        json_str(&f.message, &mut s);
        s.push('}');
    }
    s.push_str("],\"allows\":[");
    for (i, a) in r.allows.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str("{\"rule\":");
        json_str(&a.rule, &mut s);
        s.push_str(",\"file\":");
        json_str(&a.file, &mut s);
        s.push_str(&format!(",\"line\":{},\"reason\":", a.line));
        json_str(&a.reason, &mut s);
        s.push('}');
    }
    s.push_str("]}\n");
    s
}

fn json_str(v: &str, out: &mut String) {
    out.push('"');
    for c in v.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escapes() {
        let mut s = String::new();
        json_str("a\"b\\c\nd", &mut s);
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn json_shape() {
        let r = Report {
            root: "src".into(),
            files_scanned: 2,
            findings: vec![Finding {
                rule: "atomics".into(),
                file: "a.rs".into(),
                line: 3,
                message: "m".into(),
            }],
            allows: vec![],
        };
        let j = to_json(&r);
        assert!(j.contains("\"schema\":1"));
        assert!(j.contains("\"rule\":\"atomics\",\"file\":\"a.rs\",\"line\":3"));
        assert!(j.contains("\"allows\":[]"));
    }
}
