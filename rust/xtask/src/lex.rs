//! Hand-rolled lexical pass over one Rust source file.
//!
//! The audit rules do not need a parse tree — they need to know, per
//! line, WHICH characters are code, which are comment text, and which
//! are string-literal content.  This module produces exactly that
//! three-way split, plus the `#[cfg(test)]` region map, so rules can
//! match tokens in code without tripping over the same words inside
//! strings, docs, or tests.
//!
//! Handled: line comments (`//`, `///`, `//!`), nested block comments,
//! normal strings with escapes (including multi-line), raw strings
//! `r"…"` / `r#"…"#` (any hash count, `b`/`br` prefixes), char
//! literals vs. lifetimes.  That is the entire lexical surface the
//! `rust/src` tree uses.

/// One source line, split into three aligned views.  Each view has the
/// same length as the original line; characters that do not belong to
/// the view are blanked to spaces, so column positions line up.
#[derive(Debug, Default)]
pub struct Line {
    /// Code only: comment text and string/char contents blanked
    /// (string DELIMITERS are kept so quotes remain visible).
    pub code: String,
    /// Code plus string literals verbatim (comments blanked) — used by
    /// scans that need literal values next to calls, e.g. `.opt("key")`.
    pub code_strings: String,
    /// String-literal CONTENT only (everything else blanked) — used by
    /// the USAGE `--key` token scan.
    pub strings: String,
    /// Comment text on this line (concatenated, `//` / `/*` markers
    /// stripped), trimmed.
    pub comment: String,
}

/// A fully lexed file.
#[derive(Debug)]
pub struct LexedFile {
    /// Path relative to the scan root, `/`-separated.
    pub rel: String,
    pub lines: Vec<Line>,
    /// `is_test[i]` — line `i` (0-based) is inside a `#[cfg(test)]`
    /// item (attribute line included).
    pub is_test: Vec<bool>,
}

impl LexedFile {
    /// 1-based line count convenience.
    pub fn n_lines(&self) -> usize {
        self.lines.len()
    }
}

#[derive(Clone, Copy, PartialEq)]
enum State {
    Code,
    LineComment,
    /// Nested depth.
    Block(u32),
    /// Escape-aware normal string.
    Str,
    /// Raw string with `n` hashes (`r"…"` is 0).
    RawStr(u32),
}

/// Character classes routed to the three views.
#[derive(Clone, Copy)]
enum Class {
    Code,
    Comment,
    StrContent,
    /// Quotes / raw-string hashes: visible in both code views.
    StrDelim,
}

struct Sink {
    lines: Vec<Line>,
    code: String,
    code_strings: String,
    strings: String,
    comment: String,
}

impl Sink {
    fn new() -> Self {
        Sink {
            lines: Vec::new(),
            code: String::new(),
            code_strings: String::new(),
            strings: String::new(),
            comment: String::new(),
        }
    }

    fn put(&mut self, c: char, class: Class) {
        match class {
            Class::Code => {
                self.code.push(c);
                self.code_strings.push(c);
                self.strings.push(' ');
            }
            Class::Comment => {
                self.code.push(' ');
                self.code_strings.push(' ');
                self.strings.push(' ');
                self.comment.push(c);
            }
            Class::StrContent => {
                self.code.push(' ');
                self.code_strings.push(c);
                self.strings.push(c);
            }
            Class::StrDelim => {
                self.code.push(c);
                self.code_strings.push(c);
                self.strings.push(' ');
            }
        }
    }

    fn newline(&mut self) {
        self.lines.push(Line {
            code: std::mem::take(&mut self.code),
            code_strings: std::mem::take(&mut self.code_strings),
            strings: std::mem::take(&mut self.strings),
            comment: std::mem::take(&mut self.comment).trim().to_string(),
        });
    }
}

fn ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into per-line views.  `rel` is stored verbatim.
pub fn lex(rel: &str, src: &str) -> LexedFile {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut sink = Sink::new();
    let mut state = State::Code;
    let mut i = 0usize;
    // Previous CODE character (for raw-string prefix detection).
    let mut prev_code: char = ' ';

    while i < n {
        let c = chars[i];
        if c == '\n' {
            if state == State::LineComment {
                state = State::Code;
            }
            sink.newline();
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                // Comment openers.
                if c == '/' && i + 1 < n && chars[i + 1] == '/' {
                    sink.put(' ', Class::Comment);
                    sink.put(' ', Class::Comment);
                    i += 2;
                    state = State::LineComment;
                    continue;
                }
                if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    sink.put(' ', Class::Comment);
                    sink.put(' ', Class::Comment);
                    i += 2;
                    state = State::Block(1);
                    continue;
                }
                // Raw / byte string prefixes.  Only when `r`/`b` does not
                // continue an identifier (`for`, `b2b`, …).
                if (c == 'r' || c == 'b') && !ident_char(prev_code) {
                    if let Some((pre, hashes)) = raw_prefix(&chars, i) {
                        for _ in 0..pre {
                            sink.put(chars[i], Class::StrDelim);
                            i += 1;
                        }
                        state = State::RawStr(hashes);
                        prev_code = ' ';
                        continue;
                    }
                    if c == 'b' && i + 1 < n && chars[i + 1] == '"' {
                        sink.put(c, Class::StrDelim);
                        sink.put('"', Class::StrDelim);
                        i += 2;
                        state = State::Str;
                        prev_code = ' ';
                        continue;
                    }
                }
                if c == '"' {
                    sink.put(c, Class::StrDelim);
                    i += 1;
                    state = State::Str;
                    prev_code = ' ';
                    continue;
                }
                // Char literal vs lifetime.
                if c == '\'' && !ident_char(prev_code) {
                    if let Some(len) = char_literal_len(&chars, i) {
                        sink.put('\'', Class::StrDelim);
                        for k in 1..len - 1 {
                            // Escapes/content blanked like string content.
                            let _ = k;
                            sink.put(' ', Class::StrContent);
                        }
                        sink.put('\'', Class::StrDelim);
                        i += len;
                        prev_code = ' ';
                        continue;
                    }
                }
                sink.put(c, Class::Code);
                if !c.is_whitespace() {
                    prev_code = c;
                }
                i += 1;
            }
            State::LineComment => {
                sink.put(c, Class::Comment);
                i += 1;
            }
            State::Block(depth) => {
                if c == '*' && i + 1 < n && chars[i + 1] == '/' {
                    sink.put(' ', Class::Comment);
                    sink.put(' ', Class::Comment);
                    i += 2;
                    state = if depth == 1 { State::Code } else { State::Block(depth - 1) };
                } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    sink.put(' ', Class::Comment);
                    sink.put(' ', Class::Comment);
                    i += 2;
                    state = State::Block(depth + 1);
                } else {
                    sink.put(c, Class::Comment);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' && i + 1 < n {
                    sink.put(c, Class::StrContent);
                    if chars[i + 1] != '\n' {
                        sink.put(chars[i + 1], Class::StrContent);
                        i += 2;
                    } else {
                        // Line-continuation escape: newline handled above.
                        i += 1;
                    }
                } else if c == '"' {
                    sink.put(c, Class::StrDelim);
                    i += 1;
                    state = State::Code;
                } else {
                    sink.put(c, Class::StrContent);
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && raw_closes(&chars, i, hashes) {
                    sink.put(c, Class::StrDelim);
                    i += 1;
                    for _ in 0..hashes {
                        if i < n {
                            sink.put(chars[i], Class::StrDelim);
                            i += 1;
                        }
                    }
                    state = State::Code;
                } else {
                    sink.put(c, Class::StrContent);
                    i += 1;
                }
            }
        }
    }
    // Final line without trailing newline.
    if !sink.code.is_empty()
        || !sink.comment.is_empty()
        || !sink.code_strings.is_empty()
    {
        sink.newline();
    }

    let is_test = mark_test_regions(&sink.lines);
    LexedFile { rel: rel.to_string(), lines: sink.lines, is_test }
}

/// If `chars[i..]` starts a raw-string literal (`r"`, `r#"`, `br##"`,
/// …), return (prefix length up to and including the opening quote,
/// hash count).
fn raw_prefix(chars: &[char], i: usize) -> Option<(usize, u32)> {
    let n = chars.len();
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
        if j >= n || chars[j] != 'r' {
            return None;
        }
    }
    if j >= n || chars[j] != 'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while j < n && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < n && chars[j] == '"' {
        Some((j - i + 1, hashes))
    } else {
        None
    }
}

/// Does the `"` at `i` close a raw string with `hashes` trailing `#`s?
fn raw_closes(chars: &[char], i: usize, hashes: u32) -> bool {
    let n = chars.len();
    for k in 0..hashes as usize {
        if i + 1 + k >= n || chars[i + 1 + k] != '#' {
            return false;
        }
    }
    true
}

/// Length (in chars, including both quotes) of a char literal starting
/// at `i`, or `None` if this `'` is a lifetime.
fn char_literal_len(chars: &[char], i: usize) -> Option<usize> {
    let n = chars.len();
    if i + 1 >= n {
        return None;
    }
    if chars[i + 1] == '\\' {
        // Escaped literal: scan (bounded) for the closing quote.
        let mut j = i + 2;
        let mut steps = 0;
        while j < n && steps < 12 {
            if chars[j] == '\'' {
                return Some(j - i + 1);
            }
            if chars[j] == '\n' {
                return None;
            }
            j += 1;
            steps += 1;
        }
        return None;
    }
    // Plain one-char literal: 'x'.
    if chars[i + 1] != '\'' && i + 2 < n && chars[i + 2] == '\'' {
        return Some(3);
    }
    None
}

/// Mark every line belonging to a `#[cfg(test)]` item: the attribute
/// line(s), any further attributes/comments, and the brace-matched body
/// of the item that follows.
fn mark_test_regions(lines: &[Line]) -> Vec<bool> {
    let mut is_test = vec![false; lines.len()];
    let mut i = 0usize;
    while i < lines.len() {
        let code = lines[i].code.replace(' ', "");
        if !code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        // From the attribute, find the opening brace of the item.
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut j = i;
        while j < lines.len() {
            is_test[j] = true;
            for c in lines[j].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    ';' if !opened && depth == 0 => {
                        // `#[cfg(test)] mod tests;` — declaration only.
                        opened = true;
                        depth = 0;
                    }
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    is_test
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_code_comments_strings() {
        let f = lex(
            "t.rs",
            "let x = \"a[0].unwrap()\"; // c.unwrap()\nlet y = v[0];\n",
        );
        assert_eq!(f.n_lines(), 2);
        assert!(!f.lines[0].code.contains("unwrap"));
        assert!(f.lines[0].code_strings.contains("a[0].unwrap()"));
        assert!(f.lines[0].strings.contains("a[0].unwrap()"));
        assert!(f.lines[0].comment.contains("c.unwrap()"));
        assert!(f.lines[1].code.contains("v[0]"));
    }

    #[test]
    fn multiline_and_raw_strings() {
        let f = lex(
            "t.rs",
            "const U: &str = \"line one --key\nline two --other\";\nlet r = r#\"raw \"quoted\" [x]\"#;\n",
        );
        assert!(f.lines[0].strings.contains("--key"));
        assert!(f.lines[1].strings.contains("--other"));
        assert!(f.lines[1].code.contains(';'));
        assert!(f.lines[2].strings.contains("raw"));
        assert!(!f.lines[2].code.contains("[x]"));
    }

    #[test]
    fn nested_block_comments_and_lifetimes() {
        let f = lex(
            "t.rs",
            "/* a /* b */ still */ fn f<'a>(x: &'a str) -> char { 'x' }\n",
        );
        assert!(f.lines[0].comment.contains("still"));
        assert!(f.lines[0].code.contains("fn f<'a>"));
        // Char literal content blanked to a space; quotes kept.
        assert!(f.lines[0].code.contains("' '"));
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() { v[0].unwrap(); }\n}\nfn c() {}\n";
        let f = lex("t.rs", src);
        assert_eq!(f.is_test, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn escaped_quotes_stay_inside_strings() {
        let f = lex("t.rs", "let s = \"a\\\"b\"; let t = 1;\n");
        assert!(f.lines[0].strings.contains("a\\\"b"));
        assert!(f.lines[0].code.contains("let t = 1;"));
    }
}
