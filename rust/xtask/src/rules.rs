//! The six mcma-audit rules plus the `audit:allow` annotation grammar.
//!
//! Every rule is grounded in a bug class this repo has actually hit or
//! a promise the README actually makes:
//!
//! | rule              | invariant                                              |
//! |-------------------|--------------------------------------------------------|
//! | `cli-registry`    | USAGE text, option lookups, and the key registries in  |
//! |                   | `cli/mod.rs` agree (the PR 7 `--perf-json` class);     |
//! |                   | positional args (`Args::pos` / POSITIONAL_KEYS /       |
//! |                   | UPPERCASE usage placeholders) are held to the same     |
//! |                   | two-direction contract                                 |
//! | `panic-free-net`  | connection-facing code never panics on hostile input   |
//! | `determinism`     | `audit:deterministic` modules use no wall clock, no    |
//! |                   | hash-order iteration, no thread identity               |
//! | `safety-comments` | every `unsafe` carries a `// SAFETY:` rationale        |
//! | `atomics`         | every `Ordering::Relaxed` outside the counter module   |
//! |                   | is individually justified                              |
//! | `lock-ordering`   | files marked `audit:lock-ordered` acquire the shared   |
//! |                   | `Server`/`NetServer` mutexes in one fixed order        |
//! |                   | (batch_rx, then registry, then reader_threads), so a   |
//! |                   | new nested acquisition cannot introduce an ABBA        |
//! |                   | deadlock                                               |
//!
//! Scope markers (`// audit:connection-facing`, `// audit:deterministic`,
//! `// audit:lock-ordered`) opt a file into rules 2, 3 and 6.  The
//! REQUIRED_* path lists below pin the files that must carry each marker,
//! so removing a marker from a core file is itself a finding — markers
//! cannot silently rot.
//!
//! Suppression grammar: `// audit:allow(<rule>) — <reason>` (also `-` or
//! `--` as the separator).  An allow covers its own line and the next
//! line, must name a known rule, must give a non-empty reason, and must
//! actually match a finding — otherwise it is reported as `bad-allow` /
//! `unused-allow`.

use crate::lex::{LexedFile, Line};

/// The six enforceable rule identifiers (valid targets for
/// `audit:allow(...)`).
pub const RULE_IDS: [&str; 6] = [
    "cli-registry",
    "panic-free-net",
    "determinism",
    "safety-comments",
    "atomics",
    "lock-ordering",
];

/// Files that MUST declare `// audit:connection-facing`.
pub const REQUIRED_CONNECTION_FACING: [&str; 4] = [
    "net/frame.rs",
    "net/http.rs",
    "net/listener.rs",
    "coordinator/server.rs",
];

/// Files that MUST declare `// audit:deterministic`.
pub const REQUIRED_DETERMINISTIC: [&str; 7] = [
    "train/backprop.rs",
    "train/cotrain.rs",
    "train/data.rs",
    "train/mod.rs",
    "qos/sim.rs",
    "nn/gemm.rs",
    "coordinator/batcher.rs",
];

/// Modules whose `Ordering::Relaxed` uses are monotonic counters read
/// only after the writing threads are joined (or where one-interval
/// staleness is explicitly tolerated); the atomics rule skips them.
pub const ATOMICS_COUNTER_MODULES: [&str; 2] =
    ["coordinator/metrics.rs", "obs/metrics.rs"];

/// The fixed acquisition order for the `Server`/`NetServer` shared
/// mutexes.  In files marked `// audit:lock-ordered`, taking a lock
/// while holding one at the same or a later position in this list is a
/// finding (the ABBA deadlock shape).
pub const LOCK_ORDER: [&str; 3] = ["batch_rx", "registry", "reader_threads"];

/// Files that MUST declare `// audit:lock-ordered`.
pub const REQUIRED_LOCK_ORDERED: [&str; 2] =
    ["net/listener.rs", "coordinator/server.rs"];

const MARKER_CONNECTION_FACING: &str = "audit:connection-facing";
const MARKER_DETERMINISTIC: &str = "audit:deterministic";
const MARKER_LOCK_ORDERED: &str = "audit:lock-ordered";

#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    pub rule: String,
    pub file: String,
    /// 1-based.
    pub line: usize,
    pub message: String,
}

#[derive(Debug, Clone)]
pub struct Allow {
    pub rule: String,
    pub file: String,
    /// 1-based.
    pub line: usize,
    pub reason: String,
}

/// Run every rule over the lexed files.  Returns the surviving findings
/// (post-suppression, including `bad-allow`/`unused-allow` meta
/// findings) and the parsed allow annotations.
pub fn audit(files: &[LexedFile]) -> (Vec<Finding>, Vec<Allow>) {
    let mut findings: Vec<Finding> = Vec::new();
    let mut allows: Vec<Allow> = Vec::new();

    for f in files {
        collect_allows(f, &mut allows, &mut findings);
    }

    for f in files {
        let conn = has_marker(f, MARKER_CONNECTION_FACING);
        let det = has_marker(f, MARKER_DETERMINISTIC);
        if conn {
            panic_free(f, &mut findings);
        }
        if det {
            determinism(f, &mut findings);
        }
        if has_marker(f, MARKER_LOCK_ORDERED) {
            lock_ordering(f, &mut findings);
        }
        safety_comments(f, &mut findings);
        atomics(f, &mut findings);
    }

    required_markers(files, &mut findings);
    cli_registry(files, &mut findings);

    dedup(&mut findings);
    let findings = suppress(findings, &allows);
    (findings, allows)
}

// ---------------------------------------------------------------------------
// token scanning helpers

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Byte offsets where `word` occurs in `hay` with non-identifier
/// characters (or string edges) on both sides.
fn word_positions(hay: &str, word: &str) -> Vec<usize> {
    let h = hay.as_bytes();
    let w = word.as_bytes();
    let mut out = Vec::new();
    if w.is_empty() || h.len() < w.len() {
        return out;
    }
    for i in 0..=h.len() - w.len() {
        if &h[i..i + w.len()] != w {
            continue;
        }
        let pre_ok = i == 0 || !is_ident_byte(h[i - 1]);
        let post = i + w.len();
        let post_ok = post >= h.len() || !is_ident_byte(h[post]);
        if pre_ok && post_ok {
            out.push(i);
        }
    }
    out
}

fn has_word(hay: &str, word: &str) -> bool {
    !word_positions(hay, word).is_empty()
}

/// The annotation at the START of a comment, if any: the comment text
/// (doc-comment `/`/`!` prefixes stripped) must begin with `audit:`.
/// Prose that merely mentions an annotation mid-sentence — like the
/// module docs of this very file, which the analyzer also scans — must
/// not opt a file into a rule scope or parse as an allow.
fn annotation(comment: &str) -> Option<&str> {
    let t = comment.trim_start_matches(['/', '!', ' ', '\t']);
    t.starts_with("audit:").then_some(t)
}

fn has_marker(f: &LexedFile, marker: &str) -> bool {
    f.lines
        .iter()
        .any(|l| annotation(&l.comment).is_some_and(|a| a.starts_with(marker)))
}

fn push(findings: &mut Vec<Finding>, rule: &str, file: &str, line0: usize, msg: String) {
    findings.push(Finding {
        rule: rule.to_string(),
        file: file.to_string(),
        line: line0 + 1,
        message: msg,
    });
}

/// One finding per (rule, file, line) is enough for the allow grammar;
/// drop duplicates from multiple hits on the same line.
fn dedup(findings: &mut Vec<Finding>) {
    findings.sort_by(|a, b| {
        (&a.file, a.line, &a.rule, &a.message).cmp(&(&b.file, b.line, &b.rule, &b.message))
    });
    findings.dedup_by(|a, b| a.rule == b.rule && a.file == b.file && a.line == b.line);
}

// ---------------------------------------------------------------------------
// allow annotations

fn collect_allows(f: &LexedFile, allows: &mut Vec<Allow>, findings: &mut Vec<Finding>) {
    for (i, line) in f.lines.iter().enumerate() {
        if f.is_test[i] {
            continue;
        }
        let Some(ann) = annotation(&line.comment) else { continue };
        let Some(rest) = ann.strip_prefix("audit:allow") else { continue };
        let parsed = parse_allow_tail(rest);
        match parsed {
            Ok((rule, reason)) => {
                if !RULE_IDS.contains(&rule.as_str()) {
                    push(
                        findings,
                        "bad-allow",
                        &f.rel,
                        i,
                        format!("audit:allow names unknown rule `{rule}`"),
                    );
                } else if reason.is_empty() {
                    push(
                        findings,
                        "bad-allow",
                        &f.rel,
                        i,
                        format!("audit:allow({rule}) has no reason — write one after `—`"),
                    );
                } else {
                    allows.push(Allow {
                        rule,
                        file: f.rel.clone(),
                        line: i + 1,
                        reason,
                    });
                }
            }
            Err(why) => {
                push(findings, "bad-allow", &f.rel, i, why.to_string());
            }
        }
    }
}

/// Parse the tail after `audit:allow`: `(<rule>) <sep> <reason>` where
/// `<sep>` is `—`, `--`, or `-`.
fn parse_allow_tail(rest: &str) -> Result<(String, String), &'static str> {
    let rest = rest.trim_start();
    let Some(stripped) = rest.strip_prefix('(') else {
        return Err("audit:allow must be written `audit:allow(<rule>) — <reason>`");
    };
    let Some(close) = stripped.find(')') else {
        return Err("audit:allow(<rule>) is missing the closing `)`");
    };
    let rule = stripped[..close].trim().to_string();
    let mut reason = stripped[close + 1..].trim_start();
    for sep in ["—", "--", "-"] {
        if let Some(r) = reason.strip_prefix(sep) {
            reason = r;
            break;
        }
    }
    Ok((rule, reason.trim().to_string()))
}

/// Drop findings covered by an allow on the same or the previous line;
/// report allows that cover nothing.
fn suppress(findings: Vec<Finding>, allows: &[Allow]) -> Vec<Finding> {
    let mut used = vec![false; allows.len()];
    let mut out = Vec::new();
    for fd in findings {
        let mut covered = false;
        for (k, a) in allows.iter().enumerate() {
            if a.rule == fd.rule
                && a.file == fd.file
                && (a.line == fd.line || a.line + 1 == fd.line)
            {
                used[k] = true;
                covered = true;
            }
        }
        if !covered {
            out.push(fd);
        }
    }
    for (k, a) in allows.iter().enumerate() {
        if !used[k] {
            out.push(Finding {
                rule: "unused-allow".to_string(),
                file: a.file.clone(),
                line: a.line,
                message: format!(
                    "audit:allow({}) matches no finding on this or the next line — remove it",
                    a.rule
                ),
            });
        }
    }
    out.sort_by(|a, b| (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule)));
    out
}

// ---------------------------------------------------------------------------
// rule: required markers

fn required_markers(files: &[LexedFile], findings: &mut Vec<Finding>) {
    for f in files {
        if REQUIRED_CONNECTION_FACING.contains(&f.rel.as_str())
            && !has_marker(f, MARKER_CONNECTION_FACING)
        {
            push(
                findings,
                "panic-free-net",
                &f.rel,
                0,
                "file must declare `// audit:connection-facing` (required scope)".to_string(),
            );
        }
        if REQUIRED_DETERMINISTIC.contains(&f.rel.as_str())
            && !has_marker(f, MARKER_DETERMINISTIC)
        {
            push(
                findings,
                "determinism",
                &f.rel,
                0,
                "file must declare `// audit:deterministic` (required scope)".to_string(),
            );
        }
        if REQUIRED_LOCK_ORDERED.contains(&f.rel.as_str())
            && !has_marker(f, MARKER_LOCK_ORDERED)
        {
            push(
                findings,
                "lock-ordering",
                &f.rel,
                0,
                "file must declare `// audit:lock-ordered` (required scope)".to_string(),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// rule: panic-free-net

const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

fn panic_free(f: &LexedFile, findings: &mut Vec<Finding>) {
    for (i, line) in f.lines.iter().enumerate() {
        if f.is_test[i] {
            continue;
        }
        let code = line.code.as_str();
        let b = code.as_bytes();
        for m in ["unwrap", "expect"] {
            for p in word_positions(code, m) {
                let dotted = p > 0 && b[p - 1] == b'.';
                let called = b.get(p + m.len()) == Some(&b'(');
                if dotted && called {
                    push(
                        findings,
                        "panic-free-net",
                        &f.rel,
                        i,
                        format!(".{m}() in connection-facing code — hostile input must not panic; return an error or use a lossless fallback"),
                    );
                }
            }
        }
        for m in PANIC_MACROS {
            for p in word_positions(code, m) {
                if b.get(p + m.len()) == Some(&b'!') {
                    push(
                        findings,
                        "panic-free-net",
                        &f.rel,
                        i,
                        format!("{m}! in connection-facing code — a hostile frame must never kill the server"),
                    );
                }
            }
        }
        for i_br in 1..b.len() {
            if b[i_br] != b'[' {
                continue;
            }
            let p = b[i_br - 1];
            if is_ident_byte(p) || p == b')' || p == b']' {
                push(
                    findings,
                    "panic-free-net",
                    &f.rel,
                    i,
                    "direct indexing in connection-facing code — use .get()/.chunks_exact()/zip so short input cannot panic".to_string(),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// rule: determinism

/// Tokens forbidden in `audit:deterministic` modules.  `HashMap`/`HashSet`
/// iteration order, wall clocks, and thread identity are the three ways a
/// bitwise thread-count-invariance test passes on sampled seeds but lies.
const NONDET_TOKENS: [&str; 6] = [
    "Instant::now",
    "SystemTime",
    "HashMap",
    "HashSet",
    "thread::current",
    "ThreadId",
];

fn determinism(f: &LexedFile, findings: &mut Vec<Finding>) {
    for (i, line) in f.lines.iter().enumerate() {
        if f.is_test[i] {
            continue;
        }
        for tok in NONDET_TOKENS {
            if has_word_path(&line.code, tok) {
                push(
                    findings,
                    "determinism",
                    &f.rel,
                    i,
                    format!("`{tok}` in an audit:deterministic module — output must be a pure function of inputs and seed"),
                );
            }
        }
    }
}

/// `word_positions` for possibly `::`-qualified tokens: boundaries are
/// checked on the first and last path segment only.
fn has_word_path(code: &str, tok: &str) -> bool {
    let b = code.as_bytes();
    let t = tok.as_bytes();
    if b.len() < t.len() {
        return false;
    }
    for i in 0..=b.len() - t.len() {
        if &b[i..i + t.len()] != t {
            continue;
        }
        let pre_ok = i == 0 || !is_ident_byte(b[i - 1]);
        let post = i + t.len();
        let post_ok = post >= b.len() || !is_ident_byte(b[post]);
        if pre_ok && post_ok {
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// rule: safety-comments

fn safety_comments(f: &LexedFile, findings: &mut Vec<Finding>) {
    for (i, line) in f.lines.iter().enumerate() {
        if f.is_test[i] {
            continue;
        }
        if !has_word(&line.code, "unsafe") {
            continue;
        }
        if !has_safety_rationale(&f.lines, i) {
            push(
                findings,
                "safety-comments",
                &f.rel,
                i,
                "unsafe without a `// SAFETY:` rationale — spell out the pointer-validity/length/feature argument".to_string(),
            );
        }
    }
}

/// A `// SAFETY:` comment counts if it is on the `unsafe` line itself or
/// on a directly preceding run of comment-only / attribute-only lines.
fn has_safety_rationale(lines: &[Line], i: usize) -> bool {
    if lines[i].comment.contains("SAFETY:") {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        let code = l.code.trim();
        if code.is_empty() {
            if l.comment.is_empty() {
                return false; // blank line ends the comment run
            }
            if l.comment.contains("SAFETY:") {
                return true;
            }
            continue; // comment-only line, keep walking
        }
        // Attribute-only lines (e.g. #[target_feature(...)]) are transparent.
        if code.starts_with("#[") && code.ends_with(']') {
            if l.comment.contains("SAFETY:") {
                return true;
            }
            continue;
        }
        return false;
    }
    false
}

// ---------------------------------------------------------------------------
// rule: atomics

fn atomics(f: &LexedFile, findings: &mut Vec<Finding>) {
    if ATOMICS_COUNTER_MODULES.contains(&f.rel.as_str()) {
        return;
    }
    for (i, line) in f.lines.iter().enumerate() {
        if f.is_test[i] {
            continue;
        }
        if has_word(&line.code, "Relaxed") {
            push(
                findings,
                "atomics",
                &f.rel,
                i,
                "Ordering::Relaxed outside the counter-module allowlist — justify with audit:allow(atomics) or strengthen the ordering".to_string(),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// rule: lock-ordering

/// How long an acquired guard lives, judged from the call site's
/// surrounding text on the same line.
#[derive(Clone, Copy)]
enum GuardLife {
    /// `let g = <acquire>;` — held to the end of the enclosing block.
    Scope,
    /// `<acquire> {` — an `if let` / `while let` / `match` guard, held
    /// for the block that opens right after the call.
    Block,
    /// Anything else (chained call, argument position, spans lines) —
    /// a statement temporary, released within its own statement.
    Temp,
}

struct LockSite {
    /// Byte offset on the line (start of the lock name, or of
    /// `lock_unpoisoned` for helper acquisitions).
    at: usize,
    /// Index into [`LOCK_ORDER`].
    idx: usize,
    life: GuardLife,
}

/// Track brace depth and held guards across the file; report any
/// acquisition of a lock at the same or an earlier [`LOCK_ORDER`]
/// position than one currently held.  Test regions are skipped whole
/// (they are brace-balanced, so the depth stays consistent).
fn lock_ordering(f: &LexedFile, findings: &mut Vec<Finding>) {
    // (lock index, brace depth at which the guard is held)
    let mut held: Vec<(usize, i32)> = Vec::new();
    let mut depth: i32 = 0;
    for (i, line) in f.lines.iter().enumerate() {
        if f.is_test[i] {
            continue;
        }
        let code = line.code.as_str();
        let sites = lock_sites(code);
        let mut next = 0usize;
        for (pos, &c) in code.as_bytes().iter().enumerate() {
            while next < sites.len() && sites[next].at == pos {
                let s = &sites[next];
                next += 1;
                for &(h, _) in &held {
                    if s.idx <= h {
                        push(
                            findings,
                            "lock-ordering",
                            &f.rel,
                            i,
                            format!(
                                "`{}` acquired while `{}` is held — the fixed acquisition order is {}",
                                LOCK_ORDER[s.idx],
                                LOCK_ORDER[h],
                                LOCK_ORDER.join(" -> ")
                            ),
                        );
                    }
                }
                match s.life {
                    GuardLife::Scope => held.push((s.idx, depth)),
                    GuardLife::Block => held.push((s.idx, depth + 1)),
                    GuardLife::Temp => {}
                }
            }
            match c {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    held.retain(|&(_, d)| d <= depth);
                }
                _ => {}
            }
        }
    }
}

/// Acquisition sites of registered locks on one line: direct
/// `NAME.lock(` calls and `lock_unpoisoned(...)` calls whose argument
/// names a registered lock.  Sorted by position.
fn lock_sites(code: &str) -> Vec<LockSite> {
    let b = code.as_bytes();
    let mut out: Vec<LockSite> = Vec::new();
    for (idx, name) in LOCK_ORDER.iter().enumerate() {
        for p in word_positions(code, name) {
            let after = p + name.len();
            if code[after..].starts_with(".lock(") {
                let open = after + ".lock(".len() - 1;
                out.push(LockSite { at: p, idx, life: guard_life(code, b, p, open) });
            }
        }
    }
    for p in word_positions(code, "lock_unpoisoned") {
        let open = p + "lock_unpoisoned".len();
        if b.get(open) != Some(&b'(') {
            continue;
        }
        let arg_end = matching_close(b, open).unwrap_or(b.len());
        let arg = &code[open + 1..arg_end];
        for (idx, name) in LOCK_ORDER.iter().enumerate() {
            if has_word(arg, name) {
                out.push(LockSite { at: p, idx, life: guard_life(code, b, p, open) });
            }
        }
    }
    out.sort_by_key(|s| s.at);
    out
}

/// Classify the guard's lifetime from what follows the call's closing
/// paren (`?` and whitespace are transparent): `;` after a `let` binds
/// a scope guard, `{` opens a guarded block, anything else is a
/// statement temporary.
fn guard_life(code: &str, b: &[u8], at: usize, open: usize) -> GuardLife {
    let Some(close) = matching_close(b, open) else {
        return GuardLife::Temp;
    };
    let mut j = close + 1;
    while j < b.len() && (b[j] == b'?' || b[j].is_ascii_whitespace()) {
        j += 1;
    }
    match b.get(j) {
        Some(b';') if has_word(&code[..at], "let") => GuardLife::Scope,
        Some(b'{') => GuardLife::Block,
        _ => GuardLife::Temp,
    }
}

/// Matching `)` for the `(` at `open`, on this line only.
fn matching_close(b: &[u8], open: usize) -> Option<usize> {
    let mut depth = 0i32;
    for (j, &c) in b.iter().enumerate().skip(open) {
        match c {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(j);
                }
            }
            _ => {}
        }
    }
    None
}

// ---------------------------------------------------------------------------
// rule: cli-registry

struct KeyAt {
    key: String,
    /// 1-based.
    line: usize,
}

/// Option-lookup methods on `Args`: (method name, is_flag).  The call
/// patterns (`.opt("`, …) are assembled at runtime from these names so
/// the table cannot match itself when the analyzer audits its own
/// source tree.
const LOOKUP_FNS: [(&str, bool); 5] = [
    ("opt", false),
    ("opt_or", false),
    ("opt_usize", false),
    ("opt_f64", false),
    ("has_flag", true),
];

/// The positional-lookup method on `Args` (registry: POSITIONAL_KEYS).
const POSITIONAL_LOOKUP_FN: &str = "pos";

fn cli_registry(files: &[LexedFile], findings: &mut Vec<Finding>) {
    let Some(cli) = files.iter().find(|f| f.rel.ends_with("cli/mod.rs")) else {
        return; // fixture trees without a CLI simply skip this rule
    };

    let value_keys = extract_key_array(cli, "VALUE_KEYS");
    let flag_keys = extract_key_array(cli, "FLAG_KEYS");
    if value_keys.is_none() {
        push(findings, "cli-registry", &cli.rel, 0, "VALUE_KEYS registry not found".to_string());
    }
    if flag_keys.is_none() {
        push(findings, "cli-registry", &cli.rel, 0, "FLAG_KEYS registry not found".to_string());
    }
    let value_keys = value_keys.unwrap_or_default();
    let flag_keys = flag_keys.unwrap_or_default();
    let registered =
        |k: &str| value_keys.iter().chain(&flag_keys).any(|e| e.key == k);

    // --key tokens in cli/mod.rs string literals (USAGE + error text).
    let mut usage: Vec<KeyAt> = Vec::new();
    for (i, line) in cli.lines.iter().enumerate() {
        if cli.is_test[i] {
            continue;
        }
        for key in dash_dash_tokens(&line.strings) {
            usage.push(KeyAt { key, line: i + 1 });
        }
    }

    // Literal option lookups anywhere in non-test code.
    let mut value_lookups: Vec<(KeyAt, String)> = Vec::new();
    let mut flag_lookups: Vec<(KeyAt, String)> = Vec::new();
    let mut pos_lookups: Vec<(KeyAt, String)> = Vec::new();
    let pos_pat = format!(".{POSITIONAL_LOOKUP_FN}(\"");
    for f in files {
        for (i, line) in f.lines.iter().enumerate() {
            if f.is_test[i] {
                continue;
            }
            for (name, is_flag) in LOOKUP_FNS {
                let pat = format!(".{name}(\"");
                for key in literal_args(&line.code_strings, &pat) {
                    let at = KeyAt { key, line: i + 1 };
                    if is_flag {
                        flag_lookups.push((at, f.rel.clone()));
                    } else {
                        value_lookups.push((at, f.rel.clone()));
                    }
                }
            }
            for key in literal_args(&line.code_strings, &pos_pat) {
                pos_lookups.push((KeyAt { key, line: i + 1 }, f.rel.clone()));
            }
        }
    }

    // Direction 1: every mention must be registered.
    for u in &usage {
        if !registered(&u.key) {
            push(
                findings,
                "cli-registry",
                &cli.rel,
                u.line - 1,
                format!("--{} appears in usage text but is not in VALUE_KEYS/FLAG_KEYS", u.key),
            );
        }
    }
    for (l, file) in &value_lookups {
        if !value_keys.iter().any(|e| e.key == l.key) {
            push(
                findings,
                "cli-registry",
                file,
                l.line - 1,
                format!("option lookup \"{}\" is not in VALUE_KEYS — unknown-key rejection would eat it", l.key),
            );
        }
    }
    for (l, file) in &flag_lookups {
        if !flag_keys.iter().any(|e| e.key == l.key) {
            push(
                findings,
                "cli-registry",
                file,
                l.line - 1,
                format!("flag lookup \"{}\" is not in FLAG_KEYS", l.key),
            );
        }
    }

    // Direction 2: every registered key must be mentioned somewhere.
    let mentioned = |k: &str| {
        usage.iter().any(|u| u.key == k)
            || value_lookups.iter().any(|(l, _)| l.key == k)
            || flag_lookups.iter().any(|(l, _)| l.key == k)
    };
    for e in value_keys.iter().chain(&flag_keys) {
        if !mentioned(&e.key) {
            push(
                findings,
                "cli-registry",
                &cli.rel,
                e.line - 1,
                format!("registered key \"{}\" appears in no usage text and no lookup — dead registry entry", e.key),
            );
        }
    }

    // Positional arguments: `Args::pos("key")` resolves through the
    // POSITIONAL_KEYS registry, and usage text names positionals by
    // their UPPERCASE placeholder (`mcma stats ADDR` <-> "addr").  The
    // registry is optional — trees without positionals skip all of this
    // — but once declared, both directions are enforced like options.
    let positional_keys = extract_key_array(cli, "POSITIONAL_KEYS").unwrap_or_default();
    let placeholders: Vec<String> = cli
        .lines
        .iter()
        .enumerate()
        .filter(|(i, _)| !cli.is_test[*i])
        .flat_map(|(_, line)| upper_tokens(&line.strings))
        .collect();
    for (l, file) in &pos_lookups {
        if !positional_keys.iter().any(|e| e.key == l.key) {
            push(
                findings,
                "cli-registry",
                file,
                l.line - 1,
                format!("positional lookup \"{}\" is not in POSITIONAL_KEYS — Args::pos would never find it", l.key),
            );
        }
    }
    for e in &positional_keys {
        let in_usage = placeholders.iter().any(|p| p == &e.key);
        let looked_up = pos_lookups.iter().any(|(l, _)| l.key == e.key);
        if !in_usage && !looked_up {
            push(
                findings,
                "cli-registry",
                &cli.rel,
                e.line - 1,
                format!("registered positional \"{}\" appears in no usage text (as its UPPERCASE placeholder) and no .pos() lookup — dead registry entry", e.key),
            );
        }
    }
}

/// ALL-CAPS placeholder tokens (A-Z 0-9 `_` `-`, at least two chars,
/// leading uppercase letter) in string content, lowercased — the USAGE
/// convention for naming positional arguments (`ADDR`, `HOST:PORT`
/// splits at the `:` into two tokens).
fn upper_tokens(strings: &str) -> Vec<String> {
    strings
        .split(|c: char| {
            !(c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_' || c == '-')
        })
        .filter(|t| t.len() >= 2 && t.starts_with(|c: char| c.is_ascii_uppercase()))
        .map(|t| t.to_ascii_lowercase())
        .collect()
}

/// Pull the string literals out of `const NAME: [&str; N] = [ ... ];`.
/// Keys contain no whitespace, so inside the array region every
/// whitespace-separated token of the `strings` view is one key.
fn extract_key_array(f: &LexedFile, name: &str) -> Option<Vec<KeyAt>> {
    let decl = (0..f.lines.len()).find(|&i| {
        !f.is_test[i]
            && has_word(&f.lines[i].code, name)
            && f.lines[i].code.contains("const")
    })?;
    let mut keys = Vec::new();
    let mut seen_eq = false;
    let mut depth: i32 = 0;
    let mut started = false;
    for j in decl..f.lines.len() {
        for &c in f.lines[j].code.as_bytes() {
            if !seen_eq {
                if c == b'=' {
                    seen_eq = true;
                }
                continue;
            }
            match c {
                b'[' => {
                    depth += 1;
                    started = true;
                }
                b']' => depth -= 1,
                _ => {}
            }
        }
        if started {
            for tok in f.lines[j].strings.split_whitespace() {
                keys.push(KeyAt { key: tok.to_string(), line: j + 1 });
            }
            if depth <= 0 {
                break;
            }
        }
    }
    Some(keys)
}

/// `--key` tokens in string-literal content: `--` not preceded by another
/// dash, followed by a lowercase letter, then `[a-z0-9-]*`.  Format-string
/// fragments like `--{k}` yield no token.
fn dash_dash_tokens(strings: &str) -> Vec<String> {
    let b = strings.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i + 2 < b.len() {
        if b[i] == b'-'
            && b[i + 1] == b'-'
            && (i == 0 || b[i - 1] != b'-')
            && b[i + 2].is_ascii_lowercase()
        {
            let mut j = i + 2;
            while j < b.len() && (b[j].is_ascii_lowercase() || b[j].is_ascii_digit() || b[j] == b'-')
            {
                j += 1;
            }
            let tok = &strings[i + 2..j];
            let tok = tok.trim_end_matches('-');
            if !tok.is_empty() {
                out.push(tok.to_string());
            }
            i = j;
        } else {
            i += 1;
        }
    }
    out
}

/// First string-literal argument of every `pat` call site on the line,
/// where `pat` ends with `("` (e.g. `.opt_usize("`).
fn literal_args(code_strings: &str, pat: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = code_strings;
    while let Some(at) = rest.find(pat) {
        let after = &rest[at + pat.len()..];
        if let Some(end) = after.find('"') {
            out.push(after[..end].to_string());
            rest = &after[end..];
        } else {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lex::lex;

    fn run_one(rel: &str, src: &str) -> (Vec<Finding>, Vec<Allow>) {
        audit(&[lex(rel, src)])
    }

    #[test]
    fn allow_suppresses_and_unused_is_reported() {
        let src = "// audit:connection-facing\n\
                   fn f(v: &[u8]) {\n\
                   // audit:allow(panic-free-net) — length asserted by caller\n\
                   let _ = v[0];\n\
                   // audit:allow(panic-free-net) — stale\n\
                   let _ = v.first();\n\
                   }\n";
        let (findings, allows) = run_one("x.rs", src);
        assert_eq!(allows.len(), 2);
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].rule, "unused-allow");
        assert_eq!(findings[0].line, 5);
    }

    #[test]
    fn bad_allow_grammar() {
        let src = "// audit:allow(not-a-rule) — whatever\n\
                   // audit:allow(atomics)\n";
        let (findings, _) = run_one("x.rs", src);
        let rules: Vec<_> = findings.iter().map(|f| f.rule.as_str()).collect();
        assert_eq!(rules, ["bad-allow", "bad-allow"]);
    }

    #[test]
    fn dash_dash_token_extraction() {
        assert_eq!(
            dash_dash_tokens("  --seed N   --closed-loop   --{k} ---x"),
            vec!["seed".to_string(), "closed-loop".to_string()]
        );
    }

    #[test]
    fn marker_mentioned_in_prose_does_not_opt_in() {
        // The analyzer scans its own source, whose docs NAME the markers
        // mid-sentence; only a comment STARTING with the annotation may
        // opt a file into a rule scope or parse as an allow.
        let src = "//! Scope markers (`// audit:connection-facing`) opt files in.\n\
                   //! Suppress with `// audit:allow(<rule>) — <reason>`.\n\
                   fn f(v: &[u8]) { let _ = v[0]; }\n";
        let (findings, allows) = run_one("x.rs", src);
        assert!(findings.is_empty(), "{findings:?}");
        assert!(allows.is_empty());
    }

    #[test]
    fn positional_registry_is_checked_both_ways() {
        let cli = "const VALUE_KEYS: [&str; 0] = [];\n\
                   const FLAG_KEYS: [&str; 0] = [];\n\
                   const POSITIONAL_KEYS: [&str; 2] = [\"addr\", \"phantom\"];\n\
                   pub const USAGE: &str = \"usage: mcma stats ADDR\";\n";
        let main = "pub fn run(args: &Args) {\n\
                    let _ = args.pos(\"addr\");\n\
                    let _ = args.pos(\"ghost\");\n\
                    }\n";
        let (findings, _) =
            audit(&[lex("cli/mod.rs", cli), lex("main.rs", main)]);
        let cli_hits: Vec<(String, usize)> = findings
            .iter()
            .map(|f| (f.file.clone(), f.line))
            .collect();
        // `addr` is fine (ADDR placeholder + lookup); `phantom` is a dead
        // registry entry; `ghost` is an unregistered lookup.
        assert_eq!(
            cli_hits,
            vec![("cli/mod.rs".to_string(), 3), ("main.rs".to_string(), 3)],
            "{findings:#?}"
        );
        assert!(findings.iter().all(|f| f.rule == "cli-registry"));
    }

    #[test]
    fn required_marker_missing_is_a_finding() {
        let (findings, _) = run_one("net/frame.rs", "fn f() {}\n");
        assert!(findings
            .iter()
            .any(|f| f.rule == "panic-free-net" && f.line == 1));
        // Lock-ordered files are pinned the same way.
        let (findings, _) = run_one("net/listener.rs", "fn f() {}\n");
        assert!(findings
            .iter()
            .any(|f| f.rule == "lock-ordering" && f.line == 1));
    }

    #[test]
    fn lock_ordering_flags_out_of_order_nesting() {
        let src = "// audit:lock-ordered\n\
                   fn in_order() {\n\
                   let q = lock_unpoisoned(&batch_rx);\n\
                   let mut reg = lock_unpoisoned(&registry);\n\
                   }\n\
                   fn out_of_order() {\n\
                   let mut reg = lock_unpoisoned(&registry);\n\
                   let q = lock_unpoisoned(&batch_rx);\n\
                   }\n";
        let (findings, _) = run_one("x.rs", src);
        assert_eq!(findings.len(), 1, "{findings:#?}");
        assert_eq!(findings[0].rule, "lock-ordering");
        assert_eq!(findings[0].line, 8);
        assert!(findings[0].message.contains("batch_rx"));
        assert!(findings[0].message.contains("registry"));
    }

    #[test]
    fn lock_ordering_releases_guards_at_scope_close() {
        let src = "// audit:lock-ordered\n\
                   fn f() {\n\
                   {\n\
                   let mut reg = lock_unpoisoned(&registry);\n\
                   }\n\
                   let q = lock_unpoisoned(&batch_rx);\n\
                   }\n";
        let (findings, _) = run_one("x.rs", src);
        assert!(findings.is_empty(), "{findings:#?}");
    }

    #[test]
    fn lock_ordering_tracks_direct_lock_calls_and_block_guards() {
        let src = "// audit:lock-ordered\n\
                   fn f() {\n\
                   if let Ok(g) = reader_threads.lock() {\n\
                   let r = lock_unpoisoned(&registry);\n\
                   }\n\
                   let r2 = lock_unpoisoned(&registry);\n\
                   }\n";
        let (findings, _) = run_one("x.rs", src);
        assert_eq!(findings.len(), 1, "{findings:#?}");
        assert_eq!(findings[0].line, 4);
        assert!(findings[0].message.contains("reader_threads"));
    }

    #[test]
    fn lock_ordering_ignores_statement_temporaries() {
        // A chained call releases the guard within its own statement, so
        // back-to-back temporaries in any order are fine.
        let src = "// audit:lock-ordered\n\
                   fn f() {\n\
                   lock_unpoisoned(&registry).insert(1, c);\n\
                   let msg = { lock_unpoisoned(&batch_rx).recv() };\n\
                   }\n";
        let (findings, _) = run_one("x.rs", src);
        assert!(findings.is_empty(), "{findings:#?}");
    }

    #[test]
    fn test_code_is_exempt() {
        let src = "// audit:connection-facing\n\
                   fn f() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   fn g(v: &[u8]) { v[0]; v.first().unwrap(); }\n\
                   }\n";
        let (findings, _) = run_one("x.rs", src);
        assert!(findings.is_empty(), "{findings:?}");
    }
}
