// fixture main: looks up a key missing from VALUE_KEYS.
pub fn run(args: &Args) {
    let _ = args.opt("perf-json");
    let _ = args.has_flag("help");
    let _ = args.pos("addr");
    let _ = args.pos("unregistered");
}
