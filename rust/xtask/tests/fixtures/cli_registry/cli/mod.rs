// fixture: the PR 7 `--perf-json` regression — documented and looked up
// but never registered, plus a dead `ghost` registry entry.
const VALUE_KEYS: [&str; 2] = ["bench", "seed"];
const FLAG_KEYS: [&str; 2] = ["help", "ghost"];

pub const USAGE: &str = "\
usage: mcma train --bench B [--seed S] [--perf-json PATH]
";

const POSITIONAL_KEYS: [&str; 2] = ["addr", "phantom"];

pub const USAGE2: &str = "\
usage: mcma stats ADDR [--seed S]
";
