// audit:deterministic — fixture: wall clock and hash order must be flagged
use std::collections::HashMap;
pub fn now_ms() -> u128 {
    let t = std::time::Instant::now();
    let _m: HashMap<u32, u32> = HashMap::new();
    t.elapsed().as_millis()
}
