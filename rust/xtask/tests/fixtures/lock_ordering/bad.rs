// audit:lock-ordered — fixture tree: out-of-order acquisitions seeded on purpose
fn ok_in_order() {
    let q = lock_unpoisoned(&batch_rx);
    let mut reg = lock_unpoisoned(&registry);
    reg.push(q);
}

fn bad_out_of_order() {
    let mut reg = lock_unpoisoned(&registry);
    let q = lock_unpoisoned(&batch_rx);
    reg.push(q);
}

fn ok_scope_closed() {
    {
        let mut reg = lock_unpoisoned(&registry);
        reg.clear();
    }
    let q = lock_unpoisoned(&batch_rx);
    q.recv();
}

fn bad_under_block_guard() {
    if let Ok(g) = reader_threads.lock() {
        let r = lock_unpoisoned(&registry);
        g.push(r);
    }
}

fn ok_temporaries() {
    lock_unpoisoned(&registry).insert(1, 2);
    lock_unpoisoned(&batch_rx).recv();
}
