// audit:allow(no-such-rule) — irrelevant
pub fn f() {}
// audit:allow(atomics)
pub fn g() {}
// audit:allow(determinism) — justified but nothing here
pub fn h() {}
