// audit:connection-facing — fixture: every panic path must be flagged
pub fn decode(v: &[u8]) -> u8 {
    let a = v[0];
    let b = v.first().unwrap();
    let c = v.get(1).expect("short");
    if v.len() > 9 { unreachable!() }
    a + b + c
}
