pub unsafe fn read_raw(p: *const u8) -> u8 {
    *p
}

pub fn call(p: *const u8) -> u8 {
    // SAFETY: caller guarantees p is valid for reads
    unsafe { read_raw(p) }
}

pub fn bad(p: *const u8) -> u8 {
    unsafe { read_raw(p) }
}
