use std::sync::atomic::{AtomicU64, Ordering};

pub fn bump(c: &AtomicU64) {
    c.fetch_add(1, Ordering::Relaxed);
}

pub fn bump_ok(c: &AtomicU64) {
    // audit:allow(atomics) — monotone counter, read only after join
    c.fetch_add(1, Ordering::Relaxed);
}
