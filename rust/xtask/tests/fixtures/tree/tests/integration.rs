// fixture: unsafe in an integration-test tree still needs a SAFETY note.
pub fn peek(v: &[u8]) -> u8 { unsafe { *v.as_ptr() } }
