// fixture-of-a-fixture: would be a finding if fixture trees were scanned.
pub fn f(v: &[u8]) -> u8 { unsafe { *v.as_ptr() } }
