// fixture: Relaxed outside the counter allowlist must fire in xtask/src too.
pub fn bump(c: &std::sync::atomic::AtomicU64) { c.fetch_add(1, std::sync::atomic::Ordering::Relaxed); }
