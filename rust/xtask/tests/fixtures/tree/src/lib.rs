// fixture: a clean library file — the src root must still be scanned.
pub fn ok() {}
