//! Integration tests: each audit rule fires on a seeded fixture
//! violation (exact rule id + line asserted, in the struct report AND
//! the JSON document), and the real `rust/src` tree is clean.

use std::path::{Path, PathBuf};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

fn audit(name: &str) -> xtask::Report {
    xtask::audit_dir(&fixture(name)).expect("fixture tree must scan")
}

/// `(file, line)` pairs for one rule, in report order.
fn hits(report: &xtask::Report, rule: &str) -> Vec<(String, usize)> {
    report
        .findings
        .iter()
        .filter(|f| f.rule == rule)
        .map(|f| (f.file.clone(), f.line))
        .collect()
}

fn assert_json_has(report: &xtask::Report, rule: &str, file: &str, line: usize) {
    let json = xtask::to_json(report);
    let needle = format!("\"rule\":\"{rule}\",\"file\":\"{file}\",\"line\":{line}");
    assert!(
        json.contains(&needle),
        "JSON report missing {needle}\n{json}"
    );
}

#[test]
fn panic_free_net_fires_on_each_panic_path() {
    let r = audit("panic_free_net");
    assert_eq!(
        hits(&r, "panic-free-net"),
        vec![
            ("bad.rs".to_string(), 3), // v[0]
            ("bad.rs".to_string(), 4), // .unwrap()
            ("bad.rs".to_string(), 5), // .expect()
            ("bad.rs".to_string(), 6), // unreachable!
        ]
    );
    assert_eq!(r.findings.len(), 4, "{:#?}", r.findings);
    assert_json_has(&r, "panic-free-net", "bad.rs", 4);
}

#[test]
fn determinism_fires_on_clock_and_hash_order() {
    let r = audit("determinism");
    assert_eq!(
        hits(&r, "determinism"),
        vec![
            ("bad.rs".to_string(), 2), // use ... HashMap
            ("bad.rs".to_string(), 4), // Instant::now
            ("bad.rs".to_string(), 5), // HashMap::new
        ]
    );
    assert_eq!(r.findings.len(), 3, "{:#?}", r.findings);
    assert_json_has(&r, "determinism", "bad.rs", 4);
}

#[test]
fn safety_comments_fires_only_without_rationale() {
    let r = audit("safety_comments");
    assert_eq!(
        hits(&r, "safety-comments"),
        vec![
            ("bad.rs".to_string(), 1),  // unsafe fn, no SAFETY
            ("bad.rs".to_string(), 11), // unsafe block, no SAFETY
        ]
    );
    assert_eq!(r.findings.len(), 2, "{:#?}", r.findings);
    assert_json_has(&r, "safety-comments", "bad.rs", 11);
}

#[test]
fn atomics_fires_unless_allowed() {
    let r = audit("atomics");
    assert_eq!(hits(&r, "atomics"), vec![("bad.rs".to_string(), 4)]);
    assert_eq!(r.findings.len(), 1, "{:#?}", r.findings);
    assert_eq!(r.allows.len(), 1);
    assert_eq!(r.allows[0].rule, "atomics");
    assert_eq!(r.allows[0].line, 8);
    assert!(r.allows[0].reason.contains("monotone counter"));
    assert_json_has(&r, "atomics", "bad.rs", 4);
}

#[test]
fn lock_ordering_fires_on_out_of_order_acquisition() {
    let r = audit("lock_ordering");
    assert_eq!(
        hits(&r, "lock-ordering"),
        vec![
            ("bad.rs".to_string(), 10), // batch_rx while registry held
            ("bad.rs".to_string(), 25), // registry while reader_threads held
        ]
    );
    assert_eq!(r.findings.len(), 2, "{:#?}", r.findings);
    assert_json_has(&r, "lock-ordering", "bad.rs", 10);
    assert_json_has(&r, "lock-ordering", "bad.rs", 25);
}

#[test]
fn cli_registry_catches_the_perf_json_class() {
    let r = audit("cli_registry");
    // Dead registry entries (`ghost` flag, `phantom` positional),
    // undocumented-but-used keys in both directions (`perf-json` in
    // USAGE and in a lookup, `unregistered` in a .pos() lookup).
    assert_eq!(
        hits(&r, "cli-registry"),
        vec![
            ("cli/mod.rs".to_string(), 4),  // dead "ghost" entry
            ("cli/mod.rs".to_string(), 7),  // --perf-json in USAGE, unregistered
            ("cli/mod.rs".to_string(), 10), // dead "phantom" positional
            ("main.rs".to_string(), 3),     // .opt("perf-json") unregistered
            ("main.rs".to_string(), 6),     // .pos("unregistered")
        ]
    );
    assert_eq!(r.findings.len(), 5, "{:#?}", r.findings);
    assert_json_has(&r, "cli-registry", "cli/mod.rs", 7);
    assert_json_has(&r, "cli-registry", "cli/mod.rs", 10);
    assert_json_has(&r, "cli-registry", "main.rs", 3);
    assert_json_has(&r, "cli-registry", "main.rs", 6);
}

/// `audit_tree` sweeps src + xtask/src + tests + benches (prefixed
/// rels), and deliberately never descends into `xtask/tests` — the
/// fixture trees there seed violations on purpose.
#[test]
fn audit_tree_scans_all_roots_but_not_fixture_trees() {
    let r = xtask::audit_tree(&fixture("tree")).expect("tree fixture must scan");
    assert_eq!(r.files_scanned, 3, "src + xtask/src + tests, NOT xtask/tests");
    assert_eq!(
        hits(&r, "atomics"),
        vec![("xtask/src/main.rs".to_string(), 2)]
    );
    assert_eq!(
        hits(&r, "safety-comments"),
        vec![("tests/integration.rs".to_string(), 2)]
    );
    assert_eq!(r.findings.len(), 2, "{:#?}", r.findings);
    assert_json_has(&r, "atomics", "xtask/src/main.rs", 2);
}

#[test]
fn allow_grammar_is_enforced() {
    let r = audit("allows");
    assert_eq!(
        hits(&r, "bad-allow"),
        vec![
            ("bad.rs".to_string(), 1), // unknown rule
            ("bad.rs".to_string(), 3), // missing reason
        ]
    );
    assert_eq!(hits(&r, "unused-allow"), vec![("bad.rs".to_string(), 5)]);
    assert_eq!(r.findings.len(), 3, "{:#?}", r.findings);
}

/// The real tree must stay clean: zero findings, and every allow that
/// suppressed something carries a written reason.
#[test]
fn repo_src_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("src");
    let r = xtask::audit_dir(&root).expect("rust/src must scan");
    assert!(r.files_scanned > 50, "suspiciously small tree: {}", r.files_scanned);
    assert!(
        r.findings.is_empty(),
        "mcma-audit found {} issue(s) in rust/src:\n{:#?}",
        r.findings.len(),
        r.findings
    );
    assert!(r.allows.iter().all(|a| !a.reason.trim().is_empty()));
}

/// The CI gate: the combined tree (library + the analyzer's own source
/// + integration tests + benches) is clean, exactly what the default
/// `cargo run -p xtask -- audit` invocation scans.
#[test]
fn repo_tree_is_clean() {
    let rust_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let r = xtask::audit_tree(&rust_dir).expect("rust tree must scan");
    assert!(r.files_scanned > 55, "suspiciously small tree: {}", r.files_scanned);
    assert!(
        r.findings.is_empty(),
        "mcma-audit found {} issue(s) in the rust tree:\n{:#?}",
        r.findings.len(),
        r.findings
    );
    assert!(r.allows.iter().all(|a| !a.reason.trim().is_empty()));
}
