//! Fig. 8: (a) speedup and (b) energy reduction, normalised to the
//! one-pass method — driven by the cycle-level NPU simulator over the
//! routing traces of Fig. 7.

use crate::bench_harness::Table;
use crate::config::Method;

use super::{fig7::Fig7, Context};

pub struct Fig8 {
    /// (bench, method) -> (speedup vs cpu, energy reduction vs cpu).
    pub raw: Vec<(String, Method, f64, f64)>,
}

pub fn run(_ctx: &Context, fig7: &Fig7) -> crate::Result<Fig8> {
    let raw = fig7
        .evals
        .iter()
        .map(|e| {
            (
                e.bench.clone(),
                e.method,
                e.sim.speedup_vs_cpu(),
                e.sim.energy_reduction_vs_cpu(),
            )
        })
        .collect();
    Ok(Fig8 { raw })
}

impl Fig8 {
    fn get(&self, bench: &str, m: Method) -> Option<(f64, f64)> {
        self.raw
            .iter()
            .find(|(b, mm, _, _)| b == bench && *mm == m)
            .map(|(_, _, s, e)| (*s, *e))
    }

    fn table(&self, ctx: &Context, title: &str, energy: bool) -> Table {
        let mut t = Table::new(
            title,
            &["benchmark", "one-pass", "iterative", "MCCA", "MCMA-compl", "MCMA-compet"],
        );
        for bench in ctx.man.bench_names_ordered() {
            let base = self
                .get(&bench, Method::OnePass)
                .map(|(s, e)| if energy { e } else { s })
                .unwrap_or(1.0)
                .max(1e-12);
            let mut row = vec![bench.clone()];
            for m in Method::ALL {
                row.push(match self.get(&bench, m) {
                    Some((s, e)) => {
                        let v = if energy { e } else { s };
                        format!("{:.2}x", v / base)
                    }
                    None => "-".into(),
                });
            }
            t.row(row);
        }
        t
    }

    pub fn table_a(&self, ctx: &Context) -> Table {
        self.table(ctx, "Fig 8(a): speedup normalised to one-pass", false)
    }

    pub fn table_b(&self, ctx: &Context) -> Table {
        self.table(ctx, "Fig 8(b): energy reduction normalised to one-pass", true)
    }

    /// Mean MCMA speedup / energy gain over one-pass (paper: ~1.23x, ~1.15x).
    /// Geometric mean — ratios-of-ratios are multiplicative, and benchmarks
    /// where one-pass barely invokes would otherwise dominate the average.
    pub fn mcma_mean_gains(&self, ctx: &Context) -> (f64, f64) {
        let mut s_log = 0.0;
        let mut e_log = 0.0;
        let mut n = 0.0;
        for bench in ctx.man.bench_names_ordered() {
            if let Some((s0, e0)) = self.get(&bench, Method::OnePass) {
                let best = [Method::McmaComplementary, Method::McmaCompetitive]
                    .into_iter()
                    .filter_map(|m| self.get(&bench, m))
                    .fold(None::<(f64, f64)>, |acc, v| match acc {
                        Some(a) if a.0 >= v.0 => Some(a),
                        _ => Some(v),
                    });
                if let Some((s, e)) = best {
                    s_log += (s / s0.max(1e-12)).max(1e-12).ln();
                    e_log += (e / e0.max(1e-12)).max(1e-12).ln();
                    n += 1.0;
                }
            }
        }
        if n == 0.0 {
            (1.0, 1.0)
        } else {
            ((s_log / n).exp(), (e_log / n).exp())
        }
    }
}
