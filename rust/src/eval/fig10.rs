//! Fig. 10: Bessel data distribution under MCMA — (a) which approximator
//! owns which region of the (nu, x) input plane, (b) per-approximator
//! error fields.  Rendered as ASCII occupancy grids (the paper's scatter
//! plots) plus per-approximator stats.

use std::sync::Arc;

use crate::bench_harness::Table;
use crate::config::Method;
use crate::coordinator::{Dispatcher, Route};

use super::Context;

pub const BENCH: &str = "bessel";
const GRID: usize = 20;

pub struct Fig10 {
    /// grids[k][gy][gx] = samples of approximator k in that input-space cell.
    pub grids: Vec<Vec<Vec<usize>>>,
    /// err_grids[k][gy][gx] = mean error of approximator k in that cell.
    pub err_grids: Vec<Vec<Vec<f64>>>,
    pub per_approx_counts: Vec<usize>,
    pub cpu_count: usize,
    pub method: Method,
}

pub fn run(ctx: &Context, method: Method) -> crate::Result<Fig10> {
    let bench = ctx.man.bench(BENCH)?.clone();
    let ds = ctx.dataset(BENCH)?;
    let bank = Arc::new(ctx.bank(&bench, &[method])?);
    let d = Dispatcher::new(&bench, &bank, method, ctx.cfg.exec)?;
    let out = d.run_dataset(&ds)?;
    let matrix = d.error_matrix(&ds)?;
    let n_approx = d.n_approx();

    let mut grids = vec![vec![vec![0usize; GRID]; GRID]; n_approx];
    let mut err_sum = vec![vec![vec![0.0f64; GRID]; GRID]; n_approx];
    let mut err_cnt = vec![vec![vec![0usize; GRID]; GRID]; n_approx];
    let mut per_approx_counts = vec![0usize; n_approx];
    let mut cpu_count = 0usize;

    for i in 0..ds.n {
        let x = ds.x_row(i);
        let gx = grid_index(x[1], bench.x_lo[1], bench.x_hi[1]);
        let gy = grid_index(x[0], bench.x_lo[0], bench.x_hi[0]);
        match out.plan.routes[i] {
            Route::Approx(k) => {
                grids[k][gy][gx] += 1;
                per_approx_counts[k] += 1;
            }
            Route::Cpu => cpu_count += 1,
        }
        for (k, row) in matrix.iter().enumerate() {
            err_sum[k][gy][gx] += row[i];
            err_cnt[k][gy][gx] += 1;
        }
    }

    let err_grids = err_sum
        .into_iter()
        .zip(err_cnt)
        .map(|(sums, cnts)| {
            sums.into_iter()
                .zip(cnts)
                .map(|(srow, crow)| {
                    srow.into_iter()
                        .zip(crow)
                        .map(|(s, c)| if c > 0 { s / c as f64 } else { 0.0 })
                        .collect()
                })
                .collect()
        })
        .collect();

    Ok(Fig10 { grids, err_grids, per_approx_counts, cpu_count, method })
}

fn grid_index(v: f32, lo: f32, hi: f32) -> usize {
    (((v - lo) / (hi - lo) * GRID as f32).floor() as i64).clamp(0, GRID as i64 - 1) as usize
}

impl Fig10 {
    /// ASCII occupancy map: one char per cell, the densest approximator's
    /// id (or '.' when empty / CPU-dominated).
    pub fn territory_map(&self) -> String {
        let mut s = String::new();
        s.push_str("Fig 10(a): approximator territories over (nu [rows], x [cols])\n");
        for gy in (0..GRID).rev() {
            s.push_str("  ");
            for gx in 0..GRID {
                let counts: Vec<usize> = self.grids.iter().map(|g| g[gy][gx]).collect();
                let best = counts
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, c)| **c)
                    .filter(|(_, c)| **c > 0)
                    .map(|(k, _)| k);
                s.push(match best {
                    Some(k) => char::from_digit(k as u32 + 1, 10).unwrap_or('?'),
                    None => '.',
                });
            }
            s.push('\n');
        }
        s
    }

    /// Error-field map for one approximator: log-bucketed mean error.
    pub fn error_map(&self, k: usize, bound: f64) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "Fig 10(b): mean error field of approximator A{} ('.': <bound, 'o': <2x, 'O': <4x, '#': worse)\n",
            k + 1
        ));
        for gy in (0..GRID).rev() {
            s.push_str("  ");
            for gx in 0..GRID {
                let e = self.err_grids[k][gy][gx];
                s.push(if e <= bound {
                    '.'
                } else if e <= 2.0 * bound {
                    'o'
                } else if e <= 4.0 * bound {
                    'O'
                } else {
                    '#'
                });
            }
            s.push('\n');
        }
        s
    }

    pub fn stats_table(&self) -> Table {
        let mut t = Table::new(
            "Fig 10: per-approximator territory sizes (bessel test set)",
            &["destination", "samples", "share"],
        );
        let total: usize = self.per_approx_counts.iter().sum::<usize>() + self.cpu_count;
        for (k, &c) in self.per_approx_counts.iter().enumerate() {
            t.row(vec![
                format!("A{}", k + 1),
                c.to_string(),
                crate::bench_harness::pct(c as f64 / total.max(1) as f64),
            ]);
        }
        t.row(vec![
            "CPU (nC)".into(),
            self.cpu_count.to_string(),
            crate::bench_harness::pct(self.cpu_count as f64 / total.max(1) as f64),
        ]);
        t
    }
}
