//! Fig. 11: distribution of samples along the approximation error, with
//! the AC / nAC / AnC / nAnC quadrant labels, for one-pass vs iterative vs
//! MCMA (Bessel).  Rendered as a text histogram per method.

use std::sync::Arc;

use crate::bench_harness::Table;
use crate::config::Method;
use crate::coordinator::{Dispatcher, EvalOutput};
use crate::util::stats;

use super::Context;

pub const BENCH: &str = "bessel";
const BINS: usize = 24;

pub struct MethodHist {
    pub method: Method,
    /// Histogram over err/bound in [0, 3): (invoked counts, rejected counts).
    pub invoked: Vec<usize>,
    pub rejected: Vec<usize>,
    pub quadrants: crate::coordinator::metrics::Quadrants,
    pub recall: f64,
}

pub struct Fig11 {
    pub methods: Vec<MethodHist>,
    pub bound: f64,
}

pub fn run(ctx: &Context) -> crate::Result<Fig11> {
    let bench = ctx.man.bench(BENCH)?.clone();
    let ds = ctx.dataset(BENCH)?;
    let wanted = [Method::OnePass, Method::Iterative, Method::McmaCompetitive];
    let bank = Arc::new(ctx.bank(&bench, &wanted)?);
    let mut methods = Vec::new();
    for m in wanted {
        let d = Dispatcher::new(&bench, &bank, m, ctx.cfg.exec)?;
        let out = d.run_dataset(&ds)?;
        methods.push(hist_for(&out, bench.error_bound, m));
    }
    Ok(Fig11 { methods, bound: bench.error_bound })
}

fn hist_for(out: &EvalOutput, bound: f64, method: Method) -> MethodHist {
    // Error axis: the error the sample's own (best) approximator yields,
    // normalised to the bound — this is the x-axis of the paper's figure.
    let norm: Vec<f64> = out.err_if_invoked.iter().map(|e| e / bound).collect();
    let mut invoked_vals = Vec::new();
    let mut rejected_vals = Vec::new();
    for (i, r) in out.plan.routes.iter().enumerate() {
        if r.is_approx() {
            invoked_vals.push(norm[i].min(2.999));
        } else {
            rejected_vals.push(norm[i].min(2.999));
        }
    }
    MethodHist {
        method,
        invoked: stats::histogram(&invoked_vals, 0.0, 3.0, BINS),
        rejected: stats::histogram(&rejected_vals, 0.0, 3.0, BINS),
        quadrants: out.metrics.quadrants,
        recall: out.metrics.recall(),
    }
}

impl Fig11 {
    pub fn render(&self) -> String {
        let mut s = String::new();
        for mh in &self.methods {
            s.push_str(&format!(
                "\nFig 11 [{}]: samples along error/bound ('|' = bound)\n",
                mh.method.label()
            ));
            let max = mh
                .invoked
                .iter()
                .chain(&mh.rejected)
                .copied()
                .max()
                .unwrap_or(1)
                .max(1);
            let bound_bin = BINS / 3; // err/bound == 1.0
            s.push_str("  invoked (C):  ");
            for (b, &c) in mh.invoked.iter().enumerate() {
                if b == bound_bin {
                    s.push('|');
                }
                s.push(density_char(c, max));
            }
            s.push('\n');
            s.push_str("  rejected(nC): ");
            for (b, &c) in mh.rejected.iter().enumerate() {
                if b == bound_bin {
                    s.push('|');
                }
                s.push(density_char(c, max));
            }
            s.push('\n');
        }
        s
    }

    pub fn quadrant_table(&self) -> Table {
        let mut t = Table::new(
            "Fig 11: quadrant counts (A = actually safe, C = classifier accepts)",
            &["method", "AC (TP)", "nAC (FP)", "AnC (FN)", "nAnC (TN)", "recall"],
        );
        for mh in &self.methods {
            t.row(vec![
                mh.method.label().to_string(),
                mh.quadrants.ac.to_string(),
                mh.quadrants.n_ac.to_string(),
                mh.quadrants.a_nc.to_string(),
                mh.quadrants.nanc.to_string(),
                format!("{:.3}", mh.recall),
            ]);
        }
        t
    }
}

fn density_char(c: usize, max: usize) -> char {
    const RAMP: [char; 7] = ['.', ':', '-', '=', '+', '*', '#'];
    if c == 0 {
        ' '
    } else {
        let idx = (c * (RAMP.len() - 1)).div_ceil(max).min(RAMP.len() - 1);
        RAMP[idx]
    }
}
