//! Eval drivers — one per paper figure (DESIGN.md per-experiment index).
//!
//! Each driver loads artifacts, runs the coordinator over the held-out test
//! set, and prints the same rows/series the paper reports.  The figure
//! benches (`rust/benches/fig*.rs`) and the `mcma figure` CLI subcommand
//! are thin wrappers over these.

pub mod fig10;
pub mod fig11;
pub mod fig7;
pub mod fig7c;
pub mod fig8;
pub mod fig9;
pub mod summary;

use std::sync::Arc;

use crate::config::{ExecMode, Method, RunConfig};
use crate::coordinator::{Dispatcher, EvalOutput};
use crate::formats::{BenchManifest, Dataset, Manifest};
use crate::npu::{NpuSim, SimResult};
use crate::runtime::{ModelBank, Runtime};

/// Shared state for all drivers: manifest + (optional) PJRT runtime.
pub struct Context {
    pub man: Manifest,
    pub rt: Option<Runtime>,
    pub cfg: RunConfig,
}

impl Context {
    /// Load artifacts; create the PJRT client only when needed.
    pub fn load(cfg: RunConfig) -> crate::Result<Self> {
        let man = Manifest::load(&crate::artifacts_dir())?;
        let rt = match cfg.exec {
            ExecMode::Pjrt => Some(Runtime::cpu()?),
            ExecMode::Native | ExecMode::NativeQ8 => None,
        };
        Ok(Context { man, rt, cfg })
    }

    pub fn bank(&self, bench: &BenchManifest, methods: &[Method]) -> crate::Result<ModelBank> {
        ModelBank::load(self.rt.as_ref(), &self.man, bench, methods, &self.man.batch_sizes)
    }

    pub fn dataset(&self, bench: &str) -> crate::Result<Dataset> {
        let ds = Dataset::load(&self.man.dataset_path(bench))?;
        Ok(if self.cfg.max_samples > 0 { ds.truncated(self.cfg.max_samples) } else { ds })
    }

    /// Methods that exist in this artifact tree for `bench`.
    pub fn available_methods(&self, bench: &BenchManifest) -> Vec<Method> {
        Method::ALL
            .into_iter()
            .filter(|m| bench.methods.iter().any(|k| k == m.key()))
            .collect()
    }
}

/// One (bench, method) evaluation: coordinator output + NPU simulation.
pub struct BenchMethodEval {
    pub bench: String,
    pub method: Method,
    pub out: EvalOutput,
    pub sim: SimResult,
}

/// Run the full coordinator + NPU sim for one (bench, method).
pub fn eval_one(
    ctx: &Context,
    bench: &BenchManifest,
    bank: &ModelBank,
    method: Method,
) -> crate::Result<BenchMethodEval> {
    let ds = ctx.dataset(&bench.name)?;
    let dispatcher = Dispatcher::new(bench, bank, method, ctx.cfg.exec)?;
    let out = dispatcher.run_dataset(&ds)?;
    let sim = simulate(ctx, bench, bank, method, &out)?;
    Ok(BenchMethodEval { bench: bench.name.clone(), method, out, sim })
}

/// NPU-simulate an already-computed routing trace.
pub fn simulate(
    ctx: &Context,
    bench: &BenchManifest,
    bank: &ModelBank,
    method: Method,
    out: &EvalOutput,
) -> crate::Result<SimResult> {
    let clf_topo = if method.is_mcma() {
        bench.clfn_topology.clone()
    } else {
        bench.clf2_topology.clone()
    };
    let n_approx = bank.n_approx(method);
    let approx_topos: Vec<Vec<usize>> =
        (0..n_approx).map(|_| bench.approx_topology.clone()).collect();
    // The cost model charges the datapath precision the execution engine
    // models, so fig8-style speedup/energy reflect quantization under
    // `--exec native-q8`.  CPU-path cost comes from the workload's actual
    // precise implementation: the registered function's op counts, or —
    // for oracle-less table workloads — the k-d tree lookup at the visit
    // count this very run measured (full-store bound when nothing took the
    // precise path).
    let sim = NpuSim::new(
        ctx.cfg.npu,
        &clf_topo,
        &approx_topos,
        crate::workload::precise_cost_cycles_measured(bench, out.precise_visits_per_query),
    )
    .with_precision(ctx.cfg.exec.precision());
    Ok(sim.simulate(&out.plan.routes, None))
}

/// Evaluate every requested method on one benchmark (shared by Figs. 7/8).
pub fn eval_bench(
    ctx: &Context,
    bench_name: &str,
    methods: &[Method],
) -> crate::Result<Vec<BenchMethodEval>> {
    let bench = ctx.man.bench(bench_name)?.clone();
    let methods: Vec<Method> = methods
        .iter()
        .copied()
        .filter(|m| bench.methods.iter().any(|k| k == m.key()))
        .collect();
    let bank = Arc::new(ctx.bank(&bench, &methods)?);
    let mut rows = Vec::new();
    for &m in &methods {
        rows.push(eval_one(ctx, &bench, &bank, m)?);
    }
    Ok(rows)
}
