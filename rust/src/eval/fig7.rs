//! Fig. 7(a) invocation and Fig. 7(b) normalised approximation error,
//! per benchmark x method.

use crate::bench_harness::{pct, Table};
use crate::config::Method;

use super::{BenchMethodEval, Context};

pub struct Fig7 {
    pub evals: Vec<BenchMethodEval>,
    pub methods: Vec<Method>,
}

pub fn run(ctx: &Context) -> crate::Result<Fig7> {
    let methods = Method::ALL.to_vec();
    let mut evals = Vec::new();
    for bench in ctx.man.bench_names_ordered() {
        evals.extend(super::eval_bench(ctx, &bench, &methods)?);
    }
    Ok(Fig7 { evals, methods })
}

impl Fig7 {
    fn cell(&self, bench: &str, m: Method, f: impl Fn(&BenchMethodEval) -> String) -> String {
        self.evals
            .iter()
            .find(|e| e.bench == bench && e.method == m)
            .map(f)
            .unwrap_or_else(|| "-".into())
    }

    pub fn table_a(&self, ctx: &Context) -> Table {
        let mut t = Table::new(
            "Fig 7(a): invocation of the approximator(s)",
            &["benchmark", "one-pass", "iterative", "MCCA", "MCMA-compl", "MCMA-compet"],
        );
        for bench in ctx.man.bench_names_ordered() {
            let mut row = vec![bench.clone()];
            for m in Method::ALL {
                row.push(self.cell(&bench, m, |e| pct(e.out.metrics.invocation())));
            }
            t.row(row);
        }
        t
    }

    pub fn table_b(&self, ctx: &Context) -> Table {
        let mut t = Table::new(
            "Fig 7(b): approximation error normalised to the error bound",
            &["benchmark", "one-pass", "iterative", "MCCA", "MCMA-compl", "MCMA-compet"],
        );
        for bench in ctx.man.bench_names_ordered() {
            let mut row = vec![bench.clone()];
            for m in Method::ALL {
                row.push(self.cell(&bench, m, |e| {
                    if e.out.metrics.invoked == 0 {
                        "n/a".into()
                    } else {
                        format!("{:.2}", e.out.metrics.rmse_over_bound)
                    }
                }));
            }
            t.row(row);
        }
        t
    }

    /// Paper headline: mean invocation gain of MCMA over one-pass.
    pub fn mcma_gain_over_one_pass(&self, ctx: &Context) -> (f64, f64) {
        let mut gain_sum = 0.0;
        let mut err_ratio_sum = 0.0;
        let mut n = 0.0;
        for bench in ctx.man.bench_names_ordered() {
            let get = |m: Method| {
                self.evals
                    .iter()
                    .find(|e| e.bench == bench && e.method == m)
            };
            if let Some(op) = get(Method::OnePass) {
                let best = [Method::McmaComplementary, Method::McmaCompetitive]
                    .into_iter()
                    .filter_map(get)
                    .max_by(|a, b| {
                        a.out
                            .metrics
                            .invocation()
                            .partial_cmp(&b.out.metrics.invocation())
                            .unwrap()
                    });
                if let Some(best) = best {
                    gain_sum += best.out.metrics.invocation() - op.out.metrics.invocation();
                    if op.out.metrics.rmse_invoked > 0.0 && best.out.metrics.invoked > 0 {
                        err_ratio_sum +=
                            1.0 - best.out.metrics.rmse_invoked / op.out.metrics.rmse_invoked;
                    }
                    n += 1.0;
                }
            }
        }
        if n == 0.0 {
            (0.0, 0.0)
        } else {
            (gain_sum / n, err_ratio_sum / n)
        }
    }
}
