//! Fig. 7(c): invocation vs error bound on Black-Scholes.
//!
//! The Python build retrains every method at scaled bounds
//! (`weights_bound_<scale>.bin`, scales 0.5/0.75/1.5/2 plus the default
//! 1.0) because the classifier's labels depend on the bound; this driver
//! evaluates each variant's invocation.

use std::sync::Arc;

use crate::bench_harness::{pct, Table};
use crate::config::Method;
use crate::coordinator::Dispatcher;
use crate::runtime::ModelBank;

use super::Context;

pub const BENCH: &str = "blackscholes";
pub const SCALES: [f64; 5] = [0.5, 0.75, 1.0, 1.5, 2.0];

pub struct Fig7c {
    /// (scale, method, invocation, rmse_over_bound)
    pub rows: Vec<(f64, Method, f64, f64)>,
}

fn weights_file_for(scale: f64) -> String {
    if (scale - 1.0).abs() < 1e-9 {
        "weights.bin".to_string()
    } else {
        // Python writes f"{scale:g}" with '.' -> 'p' (0.5 -> "0p5", 2.0 -> "2").
        let g = if scale.fract() == 0.0 {
            format!("{}", scale as i64)
        } else {
            format!("{scale}")
        };
        format!("weights_bound_{}.bin", g.replace('.', "p"))
    }
}

pub fn run(ctx: &Context) -> crate::Result<Fig7c> {
    let mut bench = ctx.man.bench(BENCH)?.clone();
    let ds = ctx.dataset(BENCH)?;
    let mut rows = Vec::new();
    for scale in SCALES {
        let path = ctx.man.root.join(BENCH).join(weights_file_for(scale));
        if !path.exists() {
            continue; // bound sweep not built in this artifact tree
        }
        bench.error_bound = ctx.man.bench(BENCH)?.error_bound * scale;
        let methods = Method::ALL.to_vec();
        let bank = Arc::new(ModelBank::load_with_weights(
            ctx.rt.as_ref(),
            &ctx.man,
            &bench,
            &methods,
            &ctx.man.batch_sizes,
            &path,
        )?);
        for m in methods {
            if !bank.has_method(m) {
                continue;
            }
            let d = Dispatcher::new(&bench, &bank, m, ctx.cfg.exec)?;
            let out = d.run_dataset(&ds)?;
            rows.push((scale, m, out.metrics.invocation(), out.metrics.rmse_over_bound));
        }
    }
    anyhow::ensure!(!rows.is_empty(), "no bound-sweep artifacts found (rebuild artifacts)");
    Ok(Fig7c { rows })
}

impl Fig7c {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fig 7(c): invocation vs error bound (blackscholes)",
            &["bound scale", "one-pass", "iterative", "MCCA", "MCMA-compl", "MCMA-compet"],
        );
        for scale in SCALES {
            let mut any = false;
            let mut row = vec![format!("{scale:.2}x")];
            for m in Method::ALL {
                let cell = self
                    .rows
                    .iter()
                    .find(|(s, mm, _, _)| (*s - scale).abs() < 1e-9 && *mm == m)
                    .map(|(_, _, inv, _)| {
                        any = true;
                        pct(*inv)
                    })
                    .unwrap_or_else(|| "-".into());
                row.push(cell);
            }
            if any {
                t.row(row);
            }
        }
        t
    }

    /// Invocation drop from the loosest to the tightest bound, per method
    /// (paper: MCMA's drop is the smallest).
    pub fn drop_per_method(&self) -> Vec<(Method, f64)> {
        Method::ALL
            .iter()
            .filter_map(|&m| {
                let at = |s: f64| {
                    self.rows
                        .iter()
                        .find(|(sc, mm, _, _)| (*sc - s).abs() < 1e-9 && *mm == m)
                        .map(|(_, _, inv, _)| *inv)
                };
                Some((m, at(2.0)? - at(0.5)?))
            })
            .collect()
    }
}
