//! §IV.B headline numbers: MCMA's mean invocation gain / error reduction
//! over one-pass and the mean speedup / energy-reduction ratios (paper:
//! +27% invocation, -10% error, ~1.23x speedup, ~1.15x energy) — plus two
//! scenario axes: the quantization axis (per-benchmark invocation-rate
//! deltas between the f32 native engine and its int8 twin) and the
//! training-provenance axis (Python-trained `weights.bin` vs the native
//! trainer's `weights_rust.bin`, both measured through the same serving
//! dispatcher).  Every table iterates the manifest in Fig. 6 order with
//! unknown names last, so custom `--data` (table-kind) workloads report
//! alongside the paper eight — their precise-path cost is the held-out
//! lookup scan and their rejected samples are served from held-out
//! labels (`workload::precise_cost_cycles`, `Dispatcher::run_dataset`).

use crate::bench_harness::{pct, Table};
use crate::config::{ExecMode, Method, Precision};
use crate::coordinator::Dispatcher;
use crate::npu::NpuSim;
use crate::runtime::ModelBank;

use super::{fig7, fig8, Context};

pub struct Summary {
    pub invocation_gain: f64,
    pub error_reduction: f64,
    pub speedup_ratio: f64,
    pub energy_ratio: f64,
}

pub fn run(ctx: &Context) -> crate::Result<Summary> {
    let f7 = fig7::run(ctx)?;
    let f8 = fig8::run(ctx, &f7)?;
    let (invocation_gain, error_reduction) = f7.mcma_gain_over_one_pass(ctx);
    let (speedup_ratio, energy_ratio) = f8.mcma_mean_gains(ctx);
    Ok(Summary { invocation_gain, error_reduction, speedup_ratio, energy_ratio })
}

/// One benchmark's f32-vs-int8 serving comparison.
pub struct QuantRow {
    pub bench: String,
    pub method: Method,
    pub invocation_f32: f64,
    pub invocation_q8: f64,
    pub rmse_over_bound_f32: f64,
    pub rmse_over_bound_q8: f64,
    pub energy_reduction_f32: f64,
    pub energy_reduction_q8: f64,
}

/// Quantization scenario axis: run every benchmark's best available MCMA
/// method through the f32 native engine AND its int8 quantized twin, and
/// report the invocation-rate delta (does reduced precision flip routing
/// decisions?) alongside the energy reduction each datapath earns — the
/// AXNet/QoS-Nets question of approximator quality under reduced
/// precision, answered per benchmark.
pub fn quantized_deltas(ctx: &Context) -> crate::Result<Vec<QuantRow>> {
    let mut rows = Vec::new();
    for name in ctx.man.bench_names_ordered() {
        let bench = ctx.man.bench(&name)?.clone();
        let method = [
            Method::McmaCompetitive,
            Method::McmaComplementary,
            Method::OnePass,
        ]
        .into_iter()
        .find(|m| bench.methods.iter().any(|k| k == m.key()));
        let Some(method) = method else { continue };
        let bank = ctx.bank(&bench, &[method])?;
        let ds = ctx.dataset(&name)?;
        let o32 = Dispatcher::new(&bench, &bank, method, ExecMode::Native)?.run_dataset(&ds)?;
        let o8 = Dispatcher::new(&bench, &bank, method, ExecMode::NativeQ8)?.run_dataset(&ds)?;

        let clf_topo =
            if method.is_mcma() { &bench.clfn_topology } else { &bench.clf2_topology };
        let approx_topos: Vec<Vec<usize>> =
            (0..bank.n_approx(method)).map(|_| bench.approx_topology.clone()).collect();
        // Each engine's sim charges the precise-path cost ITS OWN run
        // measured (routing can differ between f32 and int8, so the k-d
        // tree visit mix can too).
        let sim32 = NpuSim::new(
            ctx.cfg.npu,
            clf_topo,
            &approx_topos,
            crate::workload::precise_cost_cycles_measured(&bench, o32.precise_visits_per_query),
        );
        let e32 = sim32.simulate(&o32.plan.routes, None).energy_reduction_vs_cpu();
        let e8 = NpuSim::new(
            ctx.cfg.npu,
            clf_topo,
            &approx_topos,
            crate::workload::precise_cost_cycles_measured(&bench, o8.precise_visits_per_query),
        )
        .with_precision(Precision::Int8)
        .simulate(&o8.plan.routes, None)
        .energy_reduction_vs_cpu();

        rows.push(QuantRow {
            bench: name.clone(),
            method,
            invocation_f32: o32.metrics.invocation(),
            invocation_q8: o8.metrics.invocation(),
            rmse_over_bound_f32: o32.metrics.rmse_over_bound,
            rmse_over_bound_q8: o8.metrics.rmse_over_bound,
            energy_reduction_f32: e32,
            energy_reduction_q8: e8,
        });
    }
    Ok(rows)
}

/// Render [`quantized_deltas`] as a paper-style table.
pub fn quantized_table(rows: &[QuantRow]) -> Table {
    let mut t = Table::new(
        "Quantization axis: f32 vs int8 native engine, per benchmark",
        &["benchmark", "method", "inv f32", "inv int8", "Δ inv", "rmse/bound f32",
          "rmse/bound int8", "energy red. f32", "energy red. int8"],
    );
    for r in rows {
        t.row(vec![
            r.bench.clone(),
            r.method.label().into(),
            pct(r.invocation_f32),
            pct(r.invocation_q8),
            format!("{:+.1}pp", 100.0 * (r.invocation_q8 - r.invocation_f32)),
            format!("{:.2}", r.rmse_over_bound_f32),
            format!("{:.2}", r.rmse_over_bound_q8),
            format!("{:.3}x", r.energy_reduction_f32),
            format!("{:.3}x", r.energy_reduction_q8),
        ]);
    }
    t
}

/// One benchmark's fixed-threshold vs adaptive-margin QoS comparison.
pub struct QosDeltaRow {
    pub bench: String,
    pub method: Method,
    /// Quality target the controller held (the offline error bound).
    pub target: f64,
    pub invocation_argmax: f64,
    pub invocation_fixed: f64,
    pub invocation_adaptive: f64,
    /// The single conservative threshold the fixed baseline needs
    /// (`>= 2` means a breaker trip forced it fully precise).
    pub global_margin: f32,
    pub violations: u64,
    pub trips: u64,
}

/// Runtime-QoS scenario axis: replay the online quality loop
/// (`qos::simulate`) over every benchmark's held-out set at the OFFLINE
/// quality target (the manifest error bound), and compare the invocation
/// a single conservative global confidence threshold achieves against
/// adaptive per-class margins holding the same target.  The adaptive
/// column is >= the fixed column by construction (see `qos::sim`); the
/// gap is the per-class headroom the paper's nonuniform-error
/// observation predicts.
pub fn qos_deltas(ctx: &Context) -> crate::Result<Vec<QosDeltaRow>> {
    let mut rows = Vec::new();
    for name in ctx.man.bench_names_ordered() {
        let bench = ctx.man.bench(&name)?.clone();
        let method = [
            Method::McmaCompetitive,
            Method::McmaComplementary,
            Method::OnePass,
        ]
        .into_iter()
        .find(|m| bench.methods.iter().any(|k| k == m.key()));
        let Some(method) = method else { continue };
        let bank = ctx.bank(&bench, &[method])?;
        let ds = ctx.dataset(&name)?;
        // Offline runs can afford a dense shadow rate; target = the
        // benchmark's own error bound (the paper's quality guarantee).
        let qos = crate::qos::QosConfig {
            target: bench.error_bound,
            shadow_rate: 0.25,
            ..crate::qos::QosConfig::default()
        };
        let d = Dispatcher::new(&bench, &bank, method, ExecMode::Native)?;
        let sim = crate::qos::simulate(&d, &ds, &qos, 256)?;
        rows.push(QosDeltaRow {
            bench: name.clone(),
            method,
            target: qos.target,
            invocation_argmax: sim.invocation_argmax,
            invocation_fixed: sim.invocation_fixed,
            invocation_adaptive: sim.invocation_adaptive,
            global_margin: sim.global_margin,
            violations: sim.report.total_violations(),
            trips: sim.report.total_trips(),
        });
    }
    Ok(rows)
}

/// Render [`qos_deltas`] as a paper-style table.
pub fn qos_table(rows: &[QosDeltaRow]) -> Table {
    let mut t = Table::new(
        "Runtime QoS axis: fixed global threshold vs adaptive per-class \
         margins (target = error bound, p95)",
        &["benchmark", "method", "target", "inv argmax", "global τ", "inv fixed τ",
          "inv adaptive", "Δ adp-fix", "violations", "trips"],
    );
    for r in rows {
        t.row(vec![
            r.bench.clone(),
            r.method.label().into(),
            format!("{:.3}", r.target),
            pct(r.invocation_argmax),
            if r.global_margin >= 2.0 { "precise".into() } else { format!("{:.3}", r.global_margin) },
            pct(r.invocation_fixed),
            pct(r.invocation_adaptive),
            format!("{:+.1}pp", 100.0 * (r.invocation_adaptive - r.invocation_fixed)),
            r.violations.to_string(),
            r.trips.to_string(),
        ]);
    }
    t
}

/// One benchmark's Python-trained vs Rust-trained serving comparison.
pub struct RustTrainRow {
    pub bench: String,
    pub method: Method,
    /// `None` when that provenance's weights lack the method.
    pub invocation_py: Option<f64>,
    pub invocation_rust: f64,
    pub rmse_over_bound_py: Option<f64>,
    pub rmse_over_bound_rust: f64,
}

/// Training-provenance axis: every benchmark with a `weights_rust.bin`
/// (written by `mcma train`) is served through the SAME native dispatcher
/// twice — once from the Python-trained `weights.bin`, once from the
/// Rust-trained artifact — and the invocation rates are compared head to
/// head.  Empty when no Rust-trained artifacts exist.
pub fn rust_trained_deltas(ctx: &Context) -> crate::Result<Vec<RustTrainRow>> {
    let mut rows = Vec::new();
    for name in ctx.man.bench_names_ordered() {
        let rust_path = ctx.man.rust_weights_path(&name);
        if !rust_path.exists() {
            continue;
        }
        let bench = ctx.man.bench(&name)?.clone();
        let ds = ctx.dataset(&name)?;
        // Host-only banks (rt = None): this comparison always runs the
        // native engine regardless of the session's --exec, so it works
        // in PJRT-less environments too.
        let bank_rust =
            ModelBank::load_with_weights(None, &ctx.man, &bench, &[], &[], &rust_path)?;
        let method = [Method::McmaCompetitive, Method::McmaComplementary, Method::OnePass]
            .into_iter()
            .find(|m| bank_rust.has_method(*m));
        let Some(method) = method else { continue };
        let out_rust = Dispatcher::new(&bench, &bank_rust, method, ExecMode::Native)?
            .run_dataset(&ds)?;

        let py_path = ctx.man.weights_path(&name);
        // In a standalone Rust-built tree the trainer copies its own
        // weights to weights.bin to make the tree servable — byte-identical
        // files mean there is no Python-trained net to compare against, so
        // the py column stays "-" instead of faking a Δ 0.0pp match.
        let genuinely_python = py_path.exists()
            && std::fs::read(&py_path).ok() != std::fs::read(&rust_path).ok();
        let (invocation_py, rmse_over_bound_py) = if genuinely_python {
            let bank_py =
                ModelBank::load_with_weights(None, &ctx.man, &bench, &[], &[], &py_path)?;
            if bank_py.has_method(method) {
                let out_py = Dispatcher::new(&bench, &bank_py, method, ExecMode::Native)?
                    .run_dataset(&ds)?;
                (Some(out_py.metrics.invocation()), Some(out_py.metrics.rmse_over_bound))
            } else {
                (None, None)
            }
        } else {
            (None, None)
        };

        rows.push(RustTrainRow {
            bench: name.clone(),
            method,
            invocation_py,
            invocation_rust: out_rust.metrics.invocation(),
            rmse_over_bound_py,
            rmse_over_bound_rust: out_rust.metrics.rmse_over_bound,
        });
    }
    Ok(rows)
}

/// Render [`rust_trained_deltas`] as a paper-style table.
pub fn rust_trained_table(rows: &[RustTrainRow]) -> Table {
    let mut t = Table::new(
        "Training provenance: Python-trained vs Rust-trained, per benchmark",
        &["benchmark", "method", "inv py", "inv rust", "Δ inv",
          "rmse/bound py", "rmse/bound rust"],
    );
    for r in rows {
        let (inv_py, delta) = match r.invocation_py {
            Some(p) => (
                pct(p),
                format!("{:+.1}pp", 100.0 * (r.invocation_rust - p)),
            ),
            None => ("-".into(), "-".into()),
        };
        t.row(vec![
            r.bench.clone(),
            r.method.label().into(),
            inv_py,
            pct(r.invocation_rust),
            delta,
            r.rmse_over_bound_py
                .map(|v| format!("{v:.2}"))
                .unwrap_or_else(|| "-".into()),
            format!("{:.2}", r.rmse_over_bound_rust),
        ]);
    }
    t
}

impl Summary {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Headline (paper §IV.B): best-MCMA vs one-pass, averaged over benchmarks",
            &["metric", "paper", "measured"],
        );
        t.row(vec![
            "invocation gain".into(),
            "+27%".into(),
            format!("{:+.0}%", 100.0 * self.invocation_gain),
        ]);
        t.row(vec![
            "approximation-error reduction".into(),
            "-10%".into(),
            format!("{:+.0}%", -100.0 * self.error_reduction),
        ]);
        t.row(vec![
            "speedup vs one-pass".into(),
            "~1.23x".into(),
            format!("{:.2}x", self.speedup_ratio),
        ]);
        t.row(vec![
            "energy reduction vs one-pass".into(),
            "~1.15x".into(),
            format!("{:.2}x", self.energy_ratio),
        ]);
        t
    }
}
