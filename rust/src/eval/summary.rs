//! §IV.B headline numbers: MCMA's mean invocation gain / error reduction
//! over one-pass and the mean speedup / energy-reduction ratios (paper:
//! +27% invocation, -10% error, ~1.23x speedup, ~1.15x energy).

use crate::bench_harness::Table;

use super::{fig7, fig8, Context};

pub struct Summary {
    pub invocation_gain: f64,
    pub error_reduction: f64,
    pub speedup_ratio: f64,
    pub energy_ratio: f64,
}

pub fn run(ctx: &Context) -> crate::Result<Summary> {
    let f7 = fig7::run(ctx)?;
    let f8 = fig8::run(ctx, &f7)?;
    let (invocation_gain, error_reduction) = f7.mcma_gain_over_one_pass(ctx);
    let (speedup_ratio, energy_ratio) = f8.mcma_mean_gains(ctx);
    Ok(Summary { invocation_gain, error_reduction, speedup_ratio, energy_ratio })
}

impl Summary {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Headline (paper §IV.B): best-MCMA vs one-pass, averaged over benchmarks",
            &["metric", "paper", "measured"],
        );
        t.row(vec![
            "invocation gain".into(),
            "+27%".into(),
            format!("{:+.0}%", 100.0 * self.invocation_gain),
        ]);
        t.row(vec![
            "approximation-error reduction".into(),
            "-10%".into(),
            format!("{:+.0}%", -100.0 * self.error_reduction),
        ]);
        t.row(vec![
            "speedup vs one-pass".into(),
            "~1.23x".into(),
            format!("{:.2}x", self.speedup_ratio),
        ]);
        t.row(vec![
            "energy reduction vs one-pass".into(),
            "~1.15x".into(),
            format!("{:.2}x", self.energy_ratio),
        ]);
        t
    }
}
