//! §IV.B headline numbers: MCMA's mean invocation gain / error reduction
//! over one-pass and the mean speedup / energy-reduction ratios (paper:
//! +27% invocation, -10% error, ~1.23x speedup, ~1.15x energy) — plus the
//! quantization scenario axis: per-benchmark invocation-rate deltas
//! between the f32 native engine and its int8 twin.

use crate::bench_harness::{pct, Table};
use crate::config::{ExecMode, Method, Precision};
use crate::coordinator::Dispatcher;
use crate::npu::NpuSim;

use super::{fig7, fig8, Context};

pub struct Summary {
    pub invocation_gain: f64,
    pub error_reduction: f64,
    pub speedup_ratio: f64,
    pub energy_ratio: f64,
}

pub fn run(ctx: &Context) -> crate::Result<Summary> {
    let f7 = fig7::run(ctx)?;
    let f8 = fig8::run(ctx, &f7)?;
    let (invocation_gain, error_reduction) = f7.mcma_gain_over_one_pass(ctx);
    let (speedup_ratio, energy_ratio) = f8.mcma_mean_gains(ctx);
    Ok(Summary { invocation_gain, error_reduction, speedup_ratio, energy_ratio })
}

/// One benchmark's f32-vs-int8 serving comparison.
pub struct QuantRow {
    pub bench: String,
    pub method: Method,
    pub invocation_f32: f64,
    pub invocation_q8: f64,
    pub rmse_over_bound_f32: f64,
    pub rmse_over_bound_q8: f64,
    pub energy_reduction_f32: f64,
    pub energy_reduction_q8: f64,
}

/// Quantization scenario axis: run every benchmark's best available MCMA
/// method through the f32 native engine AND its int8 quantized twin, and
/// report the invocation-rate delta (does reduced precision flip routing
/// decisions?) alongside the energy reduction each datapath earns — the
/// AXNet/QoS-Nets question of approximator quality under reduced
/// precision, answered per benchmark.
pub fn quantized_deltas(ctx: &Context) -> crate::Result<Vec<QuantRow>> {
    let mut rows = Vec::new();
    for name in ctx.man.bench_names_ordered() {
        let bench = ctx.man.bench(&name)?.clone();
        let method = [
            Method::McmaCompetitive,
            Method::McmaComplementary,
            Method::OnePass,
        ]
        .into_iter()
        .find(|m| bench.methods.iter().any(|k| k == m.key()));
        let Some(method) = method else { continue };
        let bank = ctx.bank(&bench, &[method])?;
        let ds = ctx.dataset(&name)?;
        let o32 = Dispatcher::new(&bench, &bank, method, ExecMode::Native)?.run_dataset(&ds)?;
        let o8 = Dispatcher::new(&bench, &bank, method, ExecMode::NativeQ8)?.run_dataset(&ds)?;

        let benchfn = crate::benchmarks::by_name(&name)?;
        let clf_topo =
            if method.is_mcma() { &bench.clfn_topology } else { &bench.clf2_topology };
        let approx_topos: Vec<Vec<usize>> =
            (0..bank.n_approx(method)).map(|_| bench.approx_topology.clone()).collect();
        let sim = NpuSim::new(ctx.cfg.npu, clf_topo, &approx_topos, benchfn.cpu_cycles());
        let e32 = sim.simulate(&o32.plan.routes, None).energy_reduction_vs_cpu();
        let e8 = sim
            .with_precision(Precision::Int8)
            .simulate(&o8.plan.routes, None)
            .energy_reduction_vs_cpu();

        rows.push(QuantRow {
            bench: name.clone(),
            method,
            invocation_f32: o32.metrics.invocation(),
            invocation_q8: o8.metrics.invocation(),
            rmse_over_bound_f32: o32.metrics.rmse_over_bound,
            rmse_over_bound_q8: o8.metrics.rmse_over_bound,
            energy_reduction_f32: e32,
            energy_reduction_q8: e8,
        });
    }
    Ok(rows)
}

/// Render [`quantized_deltas`] as a paper-style table.
pub fn quantized_table(rows: &[QuantRow]) -> Table {
    let mut t = Table::new(
        "Quantization axis: f32 vs int8 native engine, per benchmark",
        &["benchmark", "method", "inv f32", "inv int8", "Δ inv", "rmse/bound f32",
          "rmse/bound int8", "energy red. f32", "energy red. int8"],
    );
    for r in rows {
        t.row(vec![
            r.bench.clone(),
            r.method.label().into(),
            pct(r.invocation_f32),
            pct(r.invocation_q8),
            format!("{:+.1}pp", 100.0 * (r.invocation_q8 - r.invocation_f32)),
            format!("{:.2}", r.rmse_over_bound_f32),
            format!("{:.2}", r.rmse_over_bound_q8),
            format!("{:.3}x", r.energy_reduction_f32),
            format!("{:.3}x", r.energy_reduction_q8),
        ]);
    }
    t
}

impl Summary {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Headline (paper §IV.B): best-MCMA vs one-pass, averaged over benchmarks",
            &["metric", "paper", "measured"],
        );
        t.row(vec![
            "invocation gain".into(),
            "+27%".into(),
            format!("{:+.0}%", 100.0 * self.invocation_gain),
        ]);
        t.row(vec![
            "approximation-error reduction".into(),
            "-10%".into(),
            format!("{:+.0}%", -100.0 * self.error_reduction),
        ]);
        t.row(vec![
            "speedup vs one-pass".into(),
            "~1.23x".into(),
            format!("{:.2}x", self.speedup_ratio),
        ]);
        t.row(vec![
            "energy reduction vs one-pass".into(),
            "~1.15x".into(),
            format!("{:.2}x", self.energy_ratio),
        ]);
        t
    }
}
