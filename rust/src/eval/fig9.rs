//! Fig. 9: invocation per training iteration, complementary vs competitive
//! (Bessel).
//!
//! Primary source: the build-time trajectories the Python trainer records
//! in `train_stats.json`.  Fallback: when that file is absent (a
//! standalone Rust-built tree), the native trainer's `RoundStats`
//! trajectory — written by `mcma train` to `train_stats_rust.json` in the
//! same `{bench: {method: [{invocation: ...}, ...]}}` schema — is read
//! instead, so the figure renders from either provenance.

use crate::bench_harness::{pct, Table};
use crate::util::json;

use super::Context;

pub struct Fig9 {
    /// method -> per-iteration invocation.
    pub series: Vec<(String, Vec<f64>)>,
    pub bench: String,
    /// Which stats file the series came from.
    pub source: &'static str,
}

/// Stats files probed in order; both use the same schema.
const SOURCES: [(&str, &str); 2] = [
    ("train_stats.json", "python"),
    ("train_stats_rust.json", "native RoundStats"),
];

pub fn run(ctx: &Context, bench: &str) -> crate::Result<Fig9> {
    let mut errors = Vec::new();
    for (file, source) in SOURCES {
        match from_stats_file(ctx, bench, file, source) {
            Ok(f) => return Ok(f),
            Err(e) => errors.push(format!("{e:#}")),
        }
    }
    // Both probes failed; report both causes (the python file existing
    // but lacking the bench is the informative one — don't mask it with
    // the expected absence of the fallback file).
    anyhow::bail!("no fig9 trajectory for {bench}: {}", errors.join("; "))
}

fn from_stats_file(
    ctx: &Context,
    bench: &str,
    file: &str,
    source: &'static str,
) -> crate::Result<Fig9> {
    let v = json::parse_file(&ctx.man.root.join(file))?;
    let b = v.req(bench)?;
    let mut series = Vec::new();
    for key in ["mcma_complementary", "mcma_competitive"] {
        if let Some(hist) = b.get(key).and_then(|h| h.as_arr()) {
            let invs: Vec<f64> = hist
                .iter()
                .filter_map(|it| it.get("invocation").and_then(json::Value::as_f64))
                .collect();
            series.push((key.to_string(), invs));
        }
    }
    anyhow::ensure!(!series.is_empty(), "no MCMA trajectories for {bench} in {file}");
    Ok(Fig9 { series, bench: bench.to_string(), source })
}

impl Fig9 {
    pub fn table(&self) -> Table {
        let iters = self.series.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
        let mut header = vec!["method".to_string()];
        header.extend((0..iters).map(|i| format!("iter {i}")));
        let mut t = Table::new(
            &format!(
                "Fig 9: invocation per training iteration ({}, {})",
                self.bench, self.source
            ),
            &header.iter().map(String::as_str).collect::<Vec<_>>(),
        );
        for (name, s) in &self.series {
            let mut row = vec![name.clone()];
            for i in 0..iters {
                row.push(s.get(i).map(|v| pct(*v)).unwrap_or_else(|| "-".into()));
            }
            t.row(row);
        }
        t
    }
}
