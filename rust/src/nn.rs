//! Pure-Rust MLP inference.
//!
//! Two roles: (1) cross-check the PJRT executables bit-for-bit-ish against
//! an independent implementation (integration tests + golden vectors from
//! the Python build), and (2) a fallback execution engine used by the
//! coordinator when `ExecMode::Native` is selected — useful for profiling
//! the L3 logic without PJRT in the loop, and as the perf baseline the
//! PJRT path is compared against in `benches/hotpath.rs`.
//!
//! Layout matches the artifacts: weights row-major `(fan_in, fan_out)`,
//! sigmoid hidden layers, linear output (the NPU PE activation scheme).

pub mod gemm;
pub mod qgemm;
pub mod simd;

pub use gemm::{GemmScratch, PackedMlp};
pub use gemm::{gemm_tiled, pack_tiles, pack_tiles_transposed, transpose_into};
pub use qgemm::{PackedMlpQ8, QGemmScratch};
pub use simd::Kernel;

/// Row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "matrix data length mismatch");
        Matrix { rows, cols, data }
    }

    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

/// One dense layer: `y = act(x W + b)`.
#[derive(Clone, Debug, PartialEq)]
pub struct Layer {
    pub w: Matrix,       // (fan_in, fan_out)
    pub b: Vec<f32>,     // (fan_out,)
}

/// Multilayer perceptron with sigmoid hidden layers and linear output.
#[derive(Clone, Debug, PartialEq)]
pub struct Mlp {
    pub layers: Vec<Layer>,
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

impl Mlp {
    pub fn new(layers: Vec<Layer>) -> Self {
        for pair in layers.windows(2) {
            assert_eq!(
                pair[0].w.cols, pair[1].w.rows,
                "layer fan-out must match next layer fan-in"
            );
        }
        Mlp { layers }
    }

    pub fn n_in(&self) -> usize {
        self.layers.first().map(|l| l.w.rows).unwrap_or(0)
    }

    pub fn n_out(&self) -> usize {
        self.layers.last().map(|l| l.w.cols).unwrap_or(0)
    }

    /// Topology as `[in, hidden..., out]`.
    pub fn topology(&self) -> Vec<usize> {
        let mut t: Vec<usize> = self.layers.iter().map(|l| l.w.rows).collect();
        t.push(self.n_out());
        t
    }

    /// Total parameter count (weights + biases).
    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.w.data.len() + l.b.len()).sum()
    }

    /// Forward one sample.
    pub fn forward1(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n_in());
        let mut h = x.to_vec();
        let last = self.layers.len() - 1;
        for (i, layer) in self.layers.iter().enumerate() {
            h = dense(&h, layer, i < last);
        }
        h
    }

    /// Forward a batch laid out row-major `(n, n_in)` into `(n, n_out)`.
    /// Scratch buffers are reused across rows — no allocation per sample
    /// beyond the output (§Perf L3: native fallback hot loop).
    pub fn forward_batch(&self, x: &[f32], n: usize) -> Vec<f32> {
        let n_in = self.n_in();
        assert_eq!(x.len(), n * n_in, "batch buffer size mismatch");
        let n_out = self.n_out();
        let mut out = vec![0.0f32; n * n_out];
        let widest = self.layers.iter().map(|l| l.w.cols.max(l.w.rows)).max().unwrap_or(0);
        let mut h = vec![0.0f32; widest];
        let mut h2 = vec![0.0f32; widest];
        let last = self.layers.len() - 1;
        for i in 0..n {
            let row = &x[i * n_in..(i + 1) * n_in];
            h[..n_in].copy_from_slice(row);
            let mut cur = n_in;
            for (li, layer) in self.layers.iter().enumerate() {
                debug_assert_eq!(cur, layer.w.rows);
                dense_into(&h[..cur], layer, li < last, &mut h2[..layer.w.cols]);
                std::mem::swap(&mut h, &mut h2);
                cur = layer.w.cols;
            }
            out[i * n_out..(i + 1) * n_out].copy_from_slice(&h[..n_out]);
        }
        out
    }

    /// Argmax class per row of a batched forward.
    pub fn classify_batch(&self, x: &[f32], n: usize) -> Vec<usize> {
        let logits = self.forward_batch(x, n);
        argmax_rows(&logits, n, self.n_out())
    }
}

fn dense(x: &[f32], layer: &Layer, sig: bool) -> Vec<f32> {
    let mut out = vec![0.0f32; layer.w.cols];
    dense_into(x, layer, sig, &mut out);
    out
}

#[inline]
fn dense_into(x: &[f32], layer: &Layer, sig: bool, out: &mut [f32]) {
    let cols = layer.w.cols;
    out.copy_from_slice(&layer.b);
    // Row-major W: accumulate x[r] * W[r, :] — streams W linearly (§Perf).
    for (r, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let wrow = layer.w.row(r);
        for c in 0..cols {
            out[c] += xv * wrow[c];
        }
    }
    if sig {
        for v in out.iter_mut() {
            *v = sigmoid(*v);
        }
    }
}

/// Row-wise argmax for a `(n, k)` row-major buffer.
pub fn argmax_rows(logits: &[f32], n: usize, k: usize) -> Vec<usize> {
    let mut out = Vec::new();
    argmax_rows_into(logits, n, k, &mut out);
    out
}

/// [`argmax_rows`] into a reusable buffer (cleared, capacity kept).
pub fn argmax_rows_into(logits: &[f32], n: usize, k: usize, out: &mut Vec<usize>) {
    assert_eq!(logits.len(), n * k);
    out.clear();
    out.extend((0..n).map(|i| {
        let row = &logits[i * k..(i + 1) * k];
        let mut best = 0;
        for j in 1..k {
            if row[j] > row[best] {
                best = j;
            }
        }
        best
    }));
}

/// Per-sample RMSE across output dims between two `(n, k)` buffers — the
/// error definition shared with `python/compile/model.py::per_sample_error`.
pub fn per_sample_rmse(pred: &[f32], truth: &[f32], n: usize, k: usize) -> Vec<f64> {
    assert_eq!(pred.len(), n * k);
    assert_eq!(truth.len(), n * k);
    (0..n)
        .map(|i| {
            let mut s = 0.0f64;
            for j in 0..k {
                let d = (pred[i * k + j] - truth[i * k + j]) as f64;
                s += d * d;
            }
            (s / k as f64).sqrt()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_mlp() -> Mlp {
        // 2 -> 2 -> 1, hand-computable.
        Mlp::new(vec![
            Layer { w: Matrix::new(2, 2, vec![1.0, 0.0, 0.0, 1.0]), b: vec![0.0, 0.0] },
            Layer { w: Matrix::new(2, 1, vec![1.0, -1.0]), b: vec![0.5] },
        ])
    }

    #[test]
    fn forward1_hand_checked() {
        let m = tiny_mlp();
        let y = m.forward1(&[0.0, 0.0]);
        // hidden = sigmoid([0,0]) = [0.5, 0.5]; out = 0.5 - 0.5 + 0.5 = 0.5
        assert!((y[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn forward_batch_matches_forward1() {
        let m = tiny_mlp();
        let xs = [0.1f32, -0.4, 2.0, 0.3, -1.0, 1.0];
        let batch = m.forward_batch(&xs, 3);
        for i in 0..3 {
            let single = m.forward1(&xs[i * 2..(i + 1) * 2]);
            assert_eq!(batch[i], single[0]);
        }
    }

    #[test]
    fn topology_and_params() {
        let m = tiny_mlp();
        assert_eq!(m.topology(), vec![2, 2, 1]);
        assert_eq!(m.n_params(), 4 + 2 + 2 + 1);
    }

    #[test]
    fn argmax_rows_ties_go_first() {
        assert_eq!(argmax_rows(&[1.0, 1.0, 0.0, 2.0], 2, 2), vec![0, 1]);
    }

    #[test]
    fn per_sample_rmse_hand_checked() {
        let e = per_sample_rmse(&[0.0, 0.0, 3.0, 4.0], &[0.0, 0.0, 0.0, 0.0], 2, 2);
        assert!((e[0] - 0.0).abs() < 1e-12);
        assert!((e[1] - (12.5f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "fan-out")]
    fn mismatched_layers_rejected() {
        Mlp::new(vec![
            Layer { w: Matrix::new(2, 3, vec![0.0; 6]), b: vec![0.0; 3] },
            Layer { w: Matrix::new(2, 1, vec![0.0; 2]), b: vec![0.0] },
        ]);
    }

    /// Property: the optimised row-major streaming forward equals a naive
    /// per-neuron dot-product implementation on random nets.
    #[test]
    fn prop_forward_matches_naive() {
        use crate::util::{prop, rng::Rng};
        prop::check(
            "mlp-forward-vs-naive",
            100,
            0x4E7,
            |r: &mut Rng| {
                let depth = 1 + r.below(3) as usize;
                let mut topo = vec![1 + r.below(12) as usize];
                for _ in 0..depth {
                    topo.push(1 + r.below(12) as usize);
                }
                let layers: Vec<Layer> = topo
                    .windows(2)
                    .map(|w| Layer {
                        w: Matrix::new(
                            w[0],
                            w[1],
                            prop::gens::matrix(r, w[0], w[1], -2.0, 2.0),
                        ),
                        b: prop::gens::vec_f32(r, w[1], -1.0, 1.0),
                    })
                    .collect();
                let n = 1 + r.below(20) as usize;
                let x = prop::gens::vec_f32(r, n * topo[0], -2.0, 2.0);
                (layers, x, n)
            },
            |(layers, x, n)| {
                let mlp = Mlp::new(layers.clone());
                let fast = mlp.forward_batch(x, *n);
                // Naive: per neuron dot product, column access pattern.
                let naive = {
                    let mut cur: Vec<Vec<f32>> = (0..*n)
                        .map(|i| x[i * mlp.n_in()..(i + 1) * mlp.n_in()].to_vec())
                        .collect();
                    let last = layers.len() - 1;
                    for (li, l) in layers.iter().enumerate() {
                        cur = cur
                            .iter()
                            .map(|h| {
                                (0..l.w.cols)
                                    .map(|c| {
                                        let mut s = l.b[c];
                                        for (r_, &hv) in h.iter().enumerate() {
                                            s += hv * l.w.at(r_, c);
                                        }
                                        if li < last { sigmoid(s) } else { s }
                                    })
                                    .collect()
                            })
                            .collect();
                    }
                    cur.concat()
                };
                prop::assert_close(&fast, &naive, 1e-5, 1e-5)
            },
        );
    }
}
