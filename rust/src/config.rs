//! Run + NPU configuration.
//!
//! The benchmark registry itself lives in `artifacts/manifest.json` (the
//! Python build is the source of truth for topologies and bounds); this
//! module holds everything the *runtime* chooses: execution mode, batching
//! policy, NPU microarchitecture parameters, and the method name mapping.

use std::str::FromStr;

/// Which engine executes MLP forwards on the hot path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// PJRT CPU client running the AOT-lowered HLO (the real configuration).
    Pjrt,
    /// Pure-Rust f32 packed-GEMM engine (`nn::gemm`).
    Native,
    /// Pure-Rust quantized engine (`nn::qgemm`): per-tensor symmetric int8
    /// weights/activations, i32 accumulation, requantize-on-store — the
    /// faithful model of the NPU's fixed-point MAC arrays, and the fastest
    /// serving floor on SIMD-capable hosts.
    NativeQ8,
}

impl ExecMode {
    /// Numeric precision of the MAC datapath this engine models.
    pub fn precision(self) -> Precision {
        match self {
            ExecMode::NativeQ8 => Precision::Int8,
            ExecMode::Pjrt | ExecMode::Native => Precision::F32,
        }
    }
}

impl FromStr for ExecMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "pjrt" => Ok(ExecMode::Pjrt),
            "native" => Ok(ExecMode::Native),
            "native-q8" | "native_q8" | "q8" => Ok(ExecMode::NativeQ8),
            _ => anyhow::bail!("unknown exec mode {s:?} (pjrt|native|native-q8)"),
        }
    }
}

/// Numeric precision of the NPU MAC datapath (and of the native engines
/// that model it).  Int8 follows the paper's fixed-point MAC arrays:
/// cheaper MACs, and 4 values packed per 32-bit bus/cache word.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Precision {
    #[default]
    F32,
    Int8,
}

impl Precision {
    /// Values moved per 32-bit bus/cache word at this precision.
    pub fn values_per_word(self) -> u64 {
        match self {
            Precision::F32 => 1,
            Precision::Int8 => 4,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }
}

/// The five training methods (artifact keys in `weights.bin`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    OnePass,
    Iterative,
    Mcca,
    McmaComplementary,
    McmaCompetitive,
}

impl Method {
    pub const ALL: [Method; 5] = [
        Method::OnePass,
        Method::Iterative,
        Method::Mcca,
        Method::McmaComplementary,
        Method::McmaCompetitive,
    ];

    /// Artifact key (matches `python/compile/train.py` method names).
    pub fn key(self) -> &'static str {
        match self {
            Method::OnePass => "one_pass",
            Method::Iterative => "iterative",
            Method::Mcca => "mcca",
            Method::McmaComplementary => "mcma_complementary",
            Method::McmaCompetitive => "mcma_competitive",
        }
    }

    /// Short display label (used in figure tables).
    pub fn label(self) -> &'static str {
        match self {
            Method::OnePass => "one-pass",
            Method::Iterative => "iterative",
            Method::Mcca => "MCCA",
            Method::McmaComplementary => "MCMA-compl",
            Method::McmaCompetitive => "MCMA-compet",
        }
    }

    pub fn is_mcma(self) -> bool {
        matches!(self, Method::McmaComplementary | Method::McmaCompetitive)
    }

    pub fn is_cascade(self) -> bool {
        self == Method::Mcca
    }
}

impl FromStr for Method {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Method::ALL
            .into_iter()
            .find(|m| m.key() == s || m.label() == s)
            .ok_or_else(|| anyhow::anyhow!("unknown method {s:?}"))
    }
}

/// Dynamic batching policy for the serving pipeline.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush when this many requests are pending (also the HLO batch size).
    pub max_batch: usize,
    /// Flush when the oldest pending request is this old.
    pub max_wait_us: u64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 256, max_wait_us: 2_000 }
    }
}

/// NPU microarchitecture parameters (defaults follow the NPU of
/// Esmaeilzadeh et al. [10]: 8 PEs, sigmoid LUT, weight buffers near MACs;
/// energy constants are order-of-magnitude 45 nm figures — see DESIGN.md).
#[derive(Clone, Copy, Debug)]
pub struct NpuConfig {
    /// Number of processing elements per tile.
    pub pes_per_tile: usize,
    /// Tiles in the NPU (classifier + approximator can map to tiles).
    pub n_tiles: usize,
    /// f32 MACs one PE retires per cycle.
    pub macs_per_pe_cycle: u64,
    /// Int8 MACs one PE retires per cycle (fixed-point arrays pack 4 narrow
    /// multipliers in roughly one f32 MAC's area — DianNao-style figures).
    pub q8_macs_per_pe_cycle: u64,
    /// Activation unit latency (cycles per neuron).
    pub act_latency: u64,
    /// Input/output FIFO transfer: values moved per cycle over the bus.
    pub bus_words_per_cycle: u64,
    /// Per-PE weight buffer capacity, in f32 words.
    pub weight_buffer_words: usize,
    /// Cache -> weight-buffer refill bandwidth, words per cycle.
    pub cache_refill_words_per_cycle: u64,
    /// NPU clock relative to CPU clock (paper NPU runs at core clock).
    pub clock_ratio: f64,
    /// Energy per f32 MAC (pJ).
    pub e_mac_pj: f64,
    /// Energy per int8 MAC (pJ) — narrow multipliers are ~4x cheaper at
    /// 45 nm (Horowitz ISSCC'14 orders of magnitude).
    pub e_mac_q8_pj: f64,
    /// Energy per word moved on the internal bus (pJ).
    pub e_bus_word_pj: f64,
    /// Energy per word refilled from on-chip cache (pJ).
    pub e_cache_word_pj: f64,
    /// CPU energy per cycle (pJ) — OoO core, ~0.5 W/GHz order.
    pub e_cpu_cycle_pj: f64,
    /// NPU static overhead per invocation (pJ).
    pub e_invoke_pj: f64,
}

impl Default for NpuConfig {
    fn default() -> Self {
        NpuConfig {
            pes_per_tile: 8,
            n_tiles: 2,
            macs_per_pe_cycle: 1,
            q8_macs_per_pe_cycle: 4,
            act_latency: 2,
            bus_words_per_cycle: 4,
            weight_buffer_words: 2048,
            cache_refill_words_per_cycle: 8,
            clock_ratio: 1.0,
            e_mac_pj: 1.2,
            e_mac_q8_pj: 0.3,
            e_bus_word_pj: 0.8,
            e_cache_word_pj: 2.0,
            e_cpu_cycle_pj: 400.0,
            e_invoke_pj: 60.0,
        }
    }
}

/// Everything a single evaluation/serving run needs.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub exec: ExecMode,
    pub batch: BatchPolicy,
    pub npu: NpuConfig,
    /// Cap on test samples (0 = use the whole artifact test set).
    pub max_samples: usize,
    /// Worker threads for parallel eval across benchmarks.
    pub threads: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            exec: ExecMode::Pjrt,
            batch: BatchPolicy::default(),
            npu: NpuConfig::default(),
            max_samples: 0,
            threads: crate::util::threadpool::default_parallelism(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_key_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::from_str(m.key()).unwrap(), m);
            assert_eq!(Method::from_str(m.label()).unwrap(), m);
        }
        assert!(Method::from_str("bogus").is_err());
    }

    #[test]
    fn exec_mode_parse() {
        assert_eq!(ExecMode::from_str("pjrt").unwrap(), ExecMode::Pjrt);
        assert_eq!(ExecMode::from_str("native").unwrap(), ExecMode::Native);
        assert_eq!(ExecMode::from_str("native-q8").unwrap(), ExecMode::NativeQ8);
        assert_eq!(ExecMode::from_str("native_q8").unwrap(), ExecMode::NativeQ8);
        assert!(ExecMode::from_str("gpu").is_err());
    }

    #[test]
    fn exec_mode_precision() {
        assert_eq!(ExecMode::Pjrt.precision(), Precision::F32);
        assert_eq!(ExecMode::Native.precision(), Precision::F32);
        assert_eq!(ExecMode::NativeQ8.precision(), Precision::Int8);
        assert_eq!(Precision::F32.values_per_word(), 1);
        assert_eq!(Precision::Int8.values_per_word(), 4);
    }

    #[test]
    fn defaults_sane() {
        let c = NpuConfig::default();
        assert!(c.pes_per_tile > 0 && c.e_cpu_cycle_pj > c.e_mac_pj);
        assert!(c.e_mac_q8_pj < c.e_mac_pj, "int8 MAC must be cheaper");
        assert!(c.q8_macs_per_pe_cycle >= c.macs_per_pe_cycle);
        assert_eq!(BatchPolicy::default().max_batch, 256);
    }
}
