//! Runtime-dispatched SIMD micro-kernels shared by the f32 ([`super::gemm`])
//! and int8 ([`super::qgemm`]) packed GEMM engines.
//!
//! The dispatch decision is made ONCE, at pack time ([`Kernel::detect`]),
//! and stored in the packed net — the hot loop pays no per-call feature
//! checks.  Three variants:
//!
//! * [`Kernel::Scalar`] — portable fallback, and the reference every SIMD
//!   variant is parity-tested against (exact for int8, where all math is
//!   integer; 1e-5 for f32, where FMA contracts the multiply-add).
//! * [`Kernel::Avx2`] — x86-64 with AVX2+FMA: one 256-bit register per
//!   `NR = 8`-wide accumulator row; `_mm256_fmadd_ps` for f32; for int8,
//!   `_mm256_madd_epi16` paired i16 multiply-accumulate (32 exact MACs per
//!   instruction — the maddubs-style widening trick, minus the unsigned
//!   saturation hazard).
//! * [`Kernel::Neon`] — aarch64: two `float32x4_t` per row (`vfmaq_n_f32`)
//!   for f32; de-interleaving `vld2_s8` loads + `vmlal_s16` widening MACs
//!   (exact, i16 products into i32 accumulators) for int8.
//!
//! Micro-kernel contract (f32): given the packed weight tile (`fan_in`
//! rows of `NR` contiguous columns) and `MR` sample rows starting at row
//! `i0` of a row-major activation panel with stride `fan_in`, return the
//! `MR x NR` accumulator block
//! `acc[r][j] = Σ_k x[(i0+r)*fan_in + k] * w[k*NR + j]`.
//!
//! The int8 tile is **pair-interleaved** (see [`q8_tile_len`]): tile row
//! `k2` holds the `2*NR` bytes `[w(2k2, j), w(2k2+1, j)]` for `j` in
//! `0..NR`, odd fan-in row and column tail zero-padded.  This feeds the
//! AVX2 paired-i16 MACs directly; the scalar and NEON variants walk the
//! same layout.  Integer accumulation is associative, so every variant
//! returns IDENTICAL i32 blocks.  Quantization, bias, activation and
//! stores stay in the (scalar, shared) callers.

use super::gemm::{MR, NR};

/// Which micro-kernel the packed engines run.  Selected once at pack time;
/// `with_kernel` on the packed nets overrides it for parity tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    Scalar,
    Avx2,
    Neon,
}

impl Kernel {
    /// Best kernel the current CPU supports.
    pub fn detect() -> Kernel {
        if avx2_available() {
            Kernel::Avx2
        } else if neon_available() {
            Kernel::Neon
        } else {
            Kernel::Scalar
        }
    }

    /// Is this variant runnable on the current CPU?  (Forcing an
    /// unavailable kernel would execute illegal instructions.)
    pub fn available(self) -> bool {
        match self {
            Kernel::Scalar => true,
            Kernel::Avx2 => avx2_available(),
            Kernel::Neon => neon_available(),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

#[cfg(target_arch = "aarch64")]
fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

#[cfg(not(target_arch = "aarch64"))]
fn neon_available() -> bool {
    false
}

/// f32 micro-tile: `acc[r][j] = Σ_k x[(i0+r)*fi + k] * w_tile[k*NR + j]`.
#[inline]
pub fn mr_tile_f32(
    kernel: Kernel,
    x: &[f32],
    i0: usize,
    fi: usize,
    w_tile: &[f32],
) -> [[f32; NR]; MR] {
    debug_assert!(w_tile.len() >= fi * NR);
    debug_assert!(x.len() >= (i0 + MR) * fi);
    match kernel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Kernel::Avx2 is only constructed when detect()/available()
        // confirmed AVX2+FMA (with_kernel asserts the same).
        Kernel::Avx2 => unsafe { mr_tile_f32_avx2(x, i0, fi, w_tile) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above, for NEON.
        Kernel::Neon => unsafe { mr_tile_f32_neon(x, i0, fi, w_tile) },
        _ => mr_tile_f32_scalar(x, i0, fi, w_tile),
    }
}

/// Bytes one pair-interleaved int8 weight tile occupies for fan-in `fi`:
/// `ceil(fi / 2)` pair rows of `2 * NR` bytes.
pub fn q8_tile_len(fi: usize) -> usize {
    fi.div_ceil(2) * 2 * NR
}

/// int8 micro-tile over a pair-interleaved weight tile, i32 accumulation —
/// bitwise identical across variants.
#[inline]
pub fn mr_tile_q8(
    kernel: Kernel,
    x: &[i8],
    i0: usize,
    fi: usize,
    w_tile: &[i8],
) -> [[i32; NR]; MR] {
    debug_assert!(w_tile.len() >= q8_tile_len(fi));
    debug_assert!(x.len() >= (i0 + MR) * fi);
    match kernel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see mr_tile_f32.
        Kernel::Avx2 => unsafe { mr_tile_q8_avx2(x, i0, fi, w_tile) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: see mr_tile_f32.
        Kernel::Neon => unsafe { mr_tile_q8_neon(x, i0, fi, w_tile) },
        _ => mr_tile_q8_scalar(x, i0, fi, w_tile),
    }
}

pub fn mr_tile_f32_scalar(x: &[f32], i0: usize, fi: usize, w_tile: &[f32]) -> [[f32; NR]; MR] {
    let mut acc = [[0.0f32; NR]; MR];
    for k in 0..fi {
        let wrow = &w_tile[k * NR..k * NR + NR];
        for r in 0..MR {
            let xv = x[(i0 + r) * fi + k];
            for j in 0..NR {
                acc[r][j] += xv * wrow[j];
            }
        }
    }
    acc
}

pub fn mr_tile_q8_scalar(x: &[i8], i0: usize, fi: usize, w_tile: &[i8]) -> [[i32; NR]; MR] {
    let mut acc = [[0i32; NR]; MR];
    let pairs = fi / 2;
    for k2 in 0..pairs {
        let wrow = &w_tile[k2 * 2 * NR..(k2 + 1) * 2 * NR];
        for r in 0..MR {
            let base = (i0 + r) * fi + 2 * k2;
            let x0 = x[base] as i32;
            let x1 = x[base + 1] as i32;
            for j in 0..NR {
                acc[r][j] += x0 * wrow[2 * j] as i32 + x1 * wrow[2 * j + 1] as i32;
            }
        }
    }
    if fi % 2 == 1 {
        // Final odd fan-in row; the interleaved partner weights are the
        // zero padding, so only the even slots contribute.
        let wrow = &w_tile[pairs * 2 * NR..(pairs + 1) * 2 * NR];
        for r in 0..MR {
            let x0 = x[(i0 + r) * fi + fi - 1] as i32;
            for j in 0..NR {
                acc[r][j] += x0 * wrow[2 * j] as i32;
            }
        }
    }
    acc
}

/// Single-row int8 dot over one pair-interleaved tile (panel tail rows).
pub fn row_tile_q8(xrow: &[i8], w_tile: &[i8]) -> [i32; NR] {
    let fi = xrow.len();
    debug_assert!(w_tile.len() >= q8_tile_len(fi));
    let mut acc = [0i32; NR];
    let pairs = fi / 2;
    for k2 in 0..pairs {
        let wrow = &w_tile[k2 * 2 * NR..(k2 + 1) * 2 * NR];
        let x0 = xrow[2 * k2] as i32;
        let x1 = xrow[2 * k2 + 1] as i32;
        for j in 0..NR {
            acc[j] += x0 * wrow[2 * j] as i32 + x1 * wrow[2 * j + 1] as i32;
        }
    }
    if fi % 2 == 1 {
        let wrow = &w_tile[pairs * 2 * NR..(pairs + 1) * 2 * NR];
        let x0 = xrow[fi - 1] as i32;
        for j in 0..NR {
            acc[j] += x0 * wrow[2 * j] as i32;
        }
    }
    acc
}

// SAFETY: callers (mr_tile_f32, which debug_asserts both bounds) pass
// `x.len() >= (i0 + MR) * fi` and `w_tile.len() >= fi * NR`.  Every
// `get_unchecked` index is `(i0 + r) * fi + k` with `r < MR`, `k < fi`,
// so it is `< (i0 + MR) * fi`; every 8-float load reads
// `w_tile[k * NR .. k * NR + 8]` with `NR == 8`, so it ends `<= fi * NR`.
// Loads/stores are the unaligned variants (`loadu`/`storeu`), so no
// alignment requirement; the `avx2`+`fma` target features hold because
// `Kernel::Avx2` is only constructed after runtime detection.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn mr_tile_f32_avx2(x: &[f32], i0: usize, fi: usize, w_tile: &[f32]) -> [[f32; NR]; MR] {
    use std::arch::x86_64::*;
    let mut acc = [_mm256_setzero_ps(); MR];
    for k in 0..fi {
        let w = _mm256_loadu_ps(w_tile.as_ptr().add(k * NR));
        for r in 0..MR {
            let xv = _mm256_set1_ps(*x.get_unchecked((i0 + r) * fi + k));
            acc[r] = _mm256_fmadd_ps(xv, w, acc[r]);
        }
    }
    let mut out = [[0.0f32; NR]; MR];
    for r in 0..MR {
        _mm256_storeu_ps(out[r].as_mut_ptr(), acc[r]);
    }
    out
}

// SAFETY: callers (mr_tile_q8, which debug_asserts both bounds) pass
// `x.len() >= (i0 + MR) * fi` and `w_tile.len() >= q8_tile_len(fi)
// = ceil(fi / 2) * 2 * NR`, i.e. ceil(fi / 2) pair rows of 16 bytes.
// Each 128-bit load reads pair row `k2 <= ceil(fi / 2) - 1` (the odd
// tail reads row `pairs = fi / 2`, which exists exactly because
// `ceil(fi / 2) = pairs + 1` for odd `fi`), so it stays in bounds.
// `get_unchecked` reads `(i0 + r) * fi + 2 * k2 (+1)`, bounded by
// `(i0 + r) * fi + fi - 1 < (i0 + MR) * fi`.  `_mm_loadu_si128` is the
// unaligned load; the `avx2` target feature holds because
// `Kernel::Avx2` is only constructed after runtime detection.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mr_tile_q8_avx2(x: &[i8], i0: usize, fi: usize, w_tile: &[i8]) -> [[i32; NR]; MR] {
    use std::arch::x86_64::*;
    let mut acc = [_mm256_setzero_si256(); MR];
    let pairs = fi / 2;
    for k2 in 0..pairs {
        // 16 interleaved bytes [w(k,j), w(k+1,j)]_j sign-extend to 16 i16
        // lanes; one vpmaddwd then computes x0*w(k,j) + x1*w(k+1,j) for
        // all 8 columns — 16 exact MACs per row per instruction (i16
        // products of |v| <= 127 cannot reach the i32 edge).
        let w8 = _mm_loadu_si128(w_tile.as_ptr().add(k2 * 2 * NR) as *const __m128i);
        let w16 = _mm256_cvtepi8_epi16(w8);
        for r in 0..MR {
            let base = (i0 + r) * fi + 2 * k2;
            let x0 = *x.get_unchecked(base) as u16 as i32;
            let x1 = *x.get_unchecked(base + 1) as u16 as i32;
            let xpair = _mm256_set1_epi32((x1 << 16) | x0);
            acc[r] = _mm256_add_epi32(acc[r], _mm256_madd_epi16(xpair, w16));
        }
    }
    if fi % 2 == 1 {
        // Odd fan-in tail: the interleaved partner lane is zero-padded.
        let w8 = _mm_loadu_si128(w_tile.as_ptr().add(pairs * 2 * NR) as *const __m128i);
        let w16 = _mm256_cvtepi8_epi16(w8);
        for r in 0..MR {
            let x0 = *x.get_unchecked((i0 + r) * fi + fi - 1) as u16 as i32;
            let xpair = _mm256_set1_epi32(x0);
            acc[r] = _mm256_add_epi32(acc[r], _mm256_madd_epi16(xpair, w16));
        }
    }
    let mut out = [[0i32; NR]; MR];
    for r in 0..MR {
        _mm256_storeu_si256(out[r].as_mut_ptr() as *mut __m256i, acc[r]);
    }
    out
}

// SAFETY: same contract as mr_tile_f32_avx2 — callers guarantee
// `x.len() >= (i0 + MR) * fi` and `w_tile.len() >= fi * NR`; the two
// 4-float `vld1q_f32` loads cover `w_tile[k * NR .. k * NR + 8]` which
// ends `<= fi * NR`, and `get_unchecked` indices stay
// `< (i0 + MR) * fi`.  NEON loads/stores have no alignment requirement
// here, and the `neon` target feature holds because `Kernel::Neon` is
// only constructed after runtime detection.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn mr_tile_f32_neon(x: &[f32], i0: usize, fi: usize, w_tile: &[f32]) -> [[f32; NR]; MR] {
    use std::arch::aarch64::*;
    let mut lo = [vdupq_n_f32(0.0); MR];
    let mut hi = [vdupq_n_f32(0.0); MR];
    for k in 0..fi {
        let wl = vld1q_f32(w_tile.as_ptr().add(k * NR));
        let wh = vld1q_f32(w_tile.as_ptr().add(k * NR + 4));
        for r in 0..MR {
            let xv = *x.get_unchecked((i0 + r) * fi + k);
            lo[r] = vfmaq_n_f32(lo[r], wl, xv);
            hi[r] = vfmaq_n_f32(hi[r], wh, xv);
        }
    }
    let mut out = [[0.0f32; NR]; MR];
    for r in 0..MR {
        vst1q_f32(out[r].as_mut_ptr(), lo[r]);
        vst1q_f32(out[r].as_mut_ptr().add(4), hi[r]);
    }
    out
}

// SAFETY: same contract as mr_tile_q8_avx2 — callers guarantee
// `x.len() >= (i0 + MR) * fi` and `w_tile.len() >= q8_tile_len(fi)`
// (ceil(fi / 2) pair rows of 16 bytes), so each 16-byte `vld2_s8`
// reads an existing pair row (the odd tail row included) and every
// `get_unchecked` index is `< (i0 + MR) * fi`.  `vld2_s8` has no
// alignment requirement, and the `neon` target feature holds because
// `Kernel::Neon` is only constructed after runtime detection.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn mr_tile_q8_neon(x: &[i8], i0: usize, fi: usize, w_tile: &[i8]) -> [[i32; NR]; MR] {
    use std::arch::aarch64::*;
    let mut lo = [vdupq_n_s32(0); MR];
    let mut hi = [vdupq_n_s32(0); MR];
    let pairs = fi / 2;
    for k2 in 0..pairs {
        // vld2 de-interleaves the pair tile row back into the k and k+1
        // weight vectors; widen to i16 once, then vmlal into the i32
        // accumulators — i16 x i16 products cannot overflow i32 here.
        let w = vld2_s8(w_tile.as_ptr().add(k2 * 2 * NR));
        let w0 = vmovl_s8(w.0);
        let w1 = vmovl_s8(w.1);
        let (w0l, w0h) = (vget_low_s16(w0), vget_high_s16(w0));
        let (w1l, w1h) = (vget_low_s16(w1), vget_high_s16(w1));
        for r in 0..MR {
            let base = (i0 + r) * fi + 2 * k2;
            let x0 = *x.get_unchecked(base) as i16;
            let x1 = *x.get_unchecked(base + 1) as i16;
            lo[r] = vmlal_n_s16(lo[r], w0l, x0);
            hi[r] = vmlal_n_s16(hi[r], w0h, x0);
            lo[r] = vmlal_n_s16(lo[r], w1l, x1);
            hi[r] = vmlal_n_s16(hi[r], w1h, x1);
        }
    }
    if fi % 2 == 1 {
        // Odd fan-in tail: only the even interleave slots carry weights.
        let w = vld2_s8(w_tile.as_ptr().add(pairs * 2 * NR));
        let w0 = vmovl_s8(w.0);
        let (w0l, w0h) = (vget_low_s16(w0), vget_high_s16(w0));
        for r in 0..MR {
            let x0 = *x.get_unchecked((i0 + r) * fi + fi - 1) as i16;
            lo[r] = vmlal_n_s16(lo[r], w0l, x0);
            hi[r] = vmlal_n_s16(hi[r], w0h, x0);
        }
    }
    let mut out = [[0i32; NR]; MR];
    for r in 0..MR {
        vst1q_s32(out[r].as_mut_ptr(), lo[r]);
        vst1q_s32(out[r].as_mut_ptr().add(4), hi[r]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn simd_variants() -> Vec<Kernel> {
        [Kernel::Avx2, Kernel::Neon]
            .into_iter()
            .filter(|k| k.available())
            .collect()
    }

    #[test]
    fn detect_is_available() {
        let k = Kernel::detect();
        assert!(k.available(), "detected kernel {k:?} must be runnable");
        assert!(Kernel::Scalar.available());
    }

    /// Pair-interleave a plain row-major `(fi, NR)` int8 weight block into
    /// the tile layout the q8 kernels consume.
    fn interleave_tile(w: &[i8], fi: usize) -> Vec<i8> {
        let mut t = vec![0i8; q8_tile_len(fi)];
        for k in 0..fi {
            for j in 0..NR {
                t[(k / 2) * 2 * NR + j * 2 + (k % 2)] = w[k * NR + j];
            }
        }
        t
    }

    /// SIMD-vs-scalar micro-tile parity: exact for int8 (also pinned
    /// against a naive plain-layout dot product, catching interleave
    /// bugs), 1e-5 for f32 (FMA contracts the multiply-add; accumulation
    /// order is identical).
    #[test]
    fn prop_microtile_simd_matches_scalar() {
        let variants = simd_variants();
        if variants.is_empty() {
            eprintln!("no SIMD variant on this CPU; scalar-only");
        }
        prop::check(
            "simd-microtile-parity",
            100,
            0x51D0,
            |r: &mut Rng| {
                let fi = 1 + r.below(48) as usize;
                let rows = MR + r.below(3) as usize;
                let x = prop::gens::vec_f32(r, rows * fi, -2.0, 2.0);
                let w = prop::gens::vec_f32(r, fi * NR, -2.0, 2.0);
                let xq: Vec<i8> = (0..rows * fi).map(|_| r.below(255) as i8).collect();
                let wq: Vec<i8> = (0..fi * NR).map(|_| r.below(255) as i8).collect();
                let i0 = r.below((rows - MR + 1) as u64) as usize;
                (fi, i0, x, w, xq, wq)
            },
            |(fi, i0, x, w, xq, wq)| {
                let (fi, i0) = (*fi, *i0);
                let f_ref = mr_tile_f32_scalar(x, i0, fi, w);
                let tile = interleave_tile(wq, fi);
                let q_ref = mr_tile_q8_scalar(xq, i0, fi, &tile);
                // Naive plain-layout oracle for the scalar interleaved walk.
                for r in 0..MR {
                    for j in 0..NR {
                        let want: i32 = (0..fi)
                            .map(|k| xq[(i0 + r) * fi + k] as i32 * wq[k * NR + j] as i32)
                            .sum();
                        if q_ref[r][j] != want {
                            return Err(format!(
                                "scalar interleaved walk wrong at ({r},{j}): {} vs {want}",
                                q_ref[r][j]
                            ));
                        }
                    }
                }
                // Tail-row helper agrees with the micro-tile's first row.
                let row = row_tile_q8(&xq[i0 * fi..(i0 + 1) * fi], &tile);
                if row != q_ref[0] {
                    return Err("row_tile_q8 diverges from micro-tile row 0".into());
                }
                for &k in &simd_variants() {
                    let f = mr_tile_f32(k, x, i0, fi, w);
                    for r in 0..MR {
                        prop::assert_close(&f[r], &f_ref[r], 1e-5, 1e-5)
                            .map_err(|e| format!("{} f32 row {r}: {e}", k.name()))?;
                    }
                    let q = mr_tile_q8(k, xq, i0, fi, &tile);
                    if q != q_ref {
                        return Err(format!("{} int8 tile diverges from scalar", k.name()));
                    }
                }
                Ok(())
            },
        );
    }

    /// The i32 accumulator cannot overflow for any realistic fan-in: the
    /// worst per-term magnitude is 127*127, leaving room for fan-in beyond
    /// 100k — far past any MLP here.  Pin the extreme case.
    #[test]
    fn q8_extremes_exact() {
        let fi = 1023; // odd: exercises the zero-padded tail pair too
        let x = vec![-127i8; (MR + 1) * fi];
        let w = interleave_tile(&vec![-127i8; fi * NR], fi);
        let acc = mr_tile_q8_scalar(&x, 1, fi, &w);
        assert_eq!(acc[0][0], 127 * 127 * fi as i32);
        for &k in &simd_variants() {
            assert_eq!(mr_tile_q8(k, &x, 1, fi, &w), acc, "{} extremes", k.name());
        }
    }
}
