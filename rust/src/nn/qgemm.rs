//! Quantized int8 packed GEMM engine (§Perf L3, the NPU-faithful path).
//!
//! The paper's NPU executes approximators on fixed-point MAC arrays;
//! [`PackedMlpQ8`] models that numerics on the host and is also the
//! fastest serving floor on SIMD-capable CPUs:
//!
//! * **Weights** are quantized per-tensor symmetric
//!   ([`QuantizedTensor`]: zero-point 0, scale = amax/127) at pack time
//!   and repacked into the same `NR`-wide column tiles as the f32 kernel.
//! * **Activations** are quantized dynamically per layer panel with the
//!   same symmetric scheme (one scalar amax pass, then rounding — always
//!   scalar, so every kernel variant sees identical int8 codes).
//! * **The dot product accumulates in i32** through the runtime-dispatched
//!   micro-kernels in [`super::simd`] (AVX2 `vpmaddwd` paired-i16 MACs
//!   over pair-interleaved tiles / NEON `vmlal_s16` / scalar) — exact in
//!   every variant, so scalar and SIMD forwards are bitwise identical.
//! * **Requantize-on-store**: each i32 accumulator is mapped back to f32
//!   with one fused scale `sx * sw`, the f32 bias is added, and the
//!   sigmoid (hidden layers) runs in f32 — matching the NPU's wide
//!   accumulator + activation-unit structure.
//!
//! Numerics: the int8 forward differs from the f32 path by a bounded
//! quantization error; `tests::prop_q8_within_derived_bound` derives the
//! layer-propagated bound (weight step, activation step, sigmoid's 1/4
//! Lipschitz constant) and pins the engine inside it.

use crate::formats::weights::{QuantizedLayerRecord, QuantizedMlpFile, QuantizedTensor};

use super::gemm::{MR, NR};
use super::simd::{self, Kernel};
use super::{sigmoid, Mlp};

/// One dense layer quantized + packed for the tiled int8 kernel.
#[derive(Clone, Debug)]
pub struct PackedLayerQ8 {
    pub fan_in: usize,
    pub fan_out: usize,
    /// `ceil(fan_out / NR)` column tiles.
    n_tiles: usize,
    /// Tile-major, PAIR-INTERLEAVED int8 weights (see `simd::q8_tile_len`):
    /// within tile `t`, byte `(k/2)*2*NR + j*2 + k%2` = Wq[k, t*NR + j],
    /// odd fan-in row and column tail zero-padded — the layout the paired
    /// i16 multiply-accumulate kernels consume directly.
    w: Vec<i8>,
    /// Per-tensor symmetric dequantization scale.
    w_scale: f32,
    /// f32 bias padded to `n_tiles * NR` (bias adds after requantization).
    b: Vec<f32>,
    /// Apply the sigmoid activation (hidden layers).
    sigmoid: bool,
}

impl PackedLayerQ8 {
    /// Pack one already-quantized layer record (the `MCQW` unit) into the
    /// pair-interleaved tile layout.
    fn pack(rec: &QuantizedLayerRecord, sig: bool) -> Self {
        let (fan_in, fan_out) = (rec.rows, rec.cols);
        let n_tiles = fan_out.div_ceil(NR);
        let tile_len = simd::q8_tile_len(fan_in);
        let mut packed = vec![0i8; n_tiles * tile_len];
        for t in 0..n_tiles {
            let c0 = t * NR;
            let width = NR.min(fan_out - c0);
            let tile = &mut packed[t * tile_len..(t + 1) * tile_len];
            for k in 0..fan_in {
                for j in 0..width {
                    tile[(k / 2) * 2 * NR + j * 2 + (k % 2)] =
                        rec.w.data[k * fan_out + c0 + j];
                }
            }
        }
        let mut bias = vec![0.0f32; n_tiles * NR];
        bias[..fan_out].copy_from_slice(&rec.b);
        PackedLayerQ8 {
            fan_in,
            fan_out,
            n_tiles,
            w: packed,
            w_scale: rec.w.scale,
            b: bias,
            sigmoid: sig,
        }
    }
}

/// Reusable buffers for the quantized layer chain: two f32 activation
/// panels (ping-pong, as in [`super::gemm::GemmScratch`]) plus the int8
/// panel the current layer's quantized activations land in.
#[derive(Debug, Default)]
pub struct QGemmScratch {
    a: Vec<f32>,
    b: Vec<f32>,
    xq: Vec<i8>,
}

impl QGemmScratch {
    pub fn new() -> Self {
        QGemmScratch::default()
    }

    /// Total capacity currently held (for allocation-stability tests).
    pub fn capacity(&self) -> usize {
        self.a.capacity() + self.b.capacity() + self.xq.capacity()
    }

    fn panel(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        &mut buf[..len]
    }
}

/// An [`Mlp`] quantized to int8 and repacked for the tiled batched kernel.
/// Quantize + pack once at load time, forward many times.
#[derive(Clone, Debug)]
pub struct PackedMlpQ8 {
    layers: Vec<PackedLayerQ8>,
    n_in: usize,
    n_out: usize,
    /// Widest layer output — sizes the intermediate panels.
    max_width: usize,
    /// Micro-kernel chosen at pack time (runtime CPU detection).
    kernel: Kernel,
}

impl PackedMlpQ8 {
    /// Quantize an f32 net and pack it (`ModelBank`'s twin-packing path).
    pub fn from_mlp(mlp: &Mlp) -> Self {
        Self::from_quantized(&QuantizedMlpFile::from_mlp(mlp))
    }

    /// Pack an already-quantized net — e.g. one loaded from an `MCQW`
    /// file — without touching f32 weights.
    pub fn from_quantized(qf: &QuantizedMlpFile) -> Self {
        let last = qf.layers.len().saturating_sub(1);
        let layers: Vec<PackedLayerQ8> = qf
            .layers
            .iter()
            .enumerate()
            .map(|(i, rec)| PackedLayerQ8::pack(rec, i < last))
            .collect();
        let max_width = layers.iter().map(|l| l.fan_out).max().unwrap_or(0);
        PackedMlpQ8 {
            n_in: layers.first().map(|l| l.fan_in).unwrap_or(0),
            n_out: layers.last().map(|l| l.fan_out).unwrap_or(0),
            layers,
            max_width,
            kernel: Kernel::detect(),
        }
    }

    /// Force a specific micro-kernel (parity tests, ablations).  Panics if
    /// the kernel is not runnable on this CPU.
    pub fn with_kernel(mut self, kernel: Kernel) -> Self {
        assert!(kernel.available(), "{} kernel unavailable on this CPU", kernel.name());
        self.kernel = kernel;
        self
    }

    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    pub fn n_in(&self) -> usize {
        self.n_in
    }

    pub fn n_out(&self) -> usize {
        self.n_out
    }

    /// Forward a row-major `(n, n_in)` f32 panel into `out` (`(n, n_out)`,
    /// resized by the caller), quantizing activations per layer.  Zero
    /// allocations once `scratch` is warm.
    pub fn forward_batch_to(
        &self,
        x: &[f32],
        n: usize,
        scratch: &mut QGemmScratch,
        out: &mut [f32],
    ) {
        assert_eq!(x.len(), n * self.n_in, "batch buffer size mismatch");
        assert_eq!(out.len(), n * self.n_out, "output buffer size mismatch");
        if self.layers.is_empty() {
            out.copy_from_slice(x);
            return;
        }
        if self.layers.len() == 1 {
            layer_forward_q8(&self.layers[0], x, n, &mut scratch.xq, out, self.kernel);
            return;
        }
        let panel_len = n * self.max_width;
        QGemmScratch::panel(&mut scratch.a, panel_len);
        QGemmScratch::panel(&mut scratch.b, panel_len);
        let pa = &mut scratch.a[..panel_len];
        let pb = &mut scratch.b[..panel_len];
        let xq = &mut scratch.xq;
        let last = self.layers.len() - 1;
        layer_forward_q8(&self.layers[0], x, n, xq, pa, self.kernel);
        let mut cur_is_a = true;
        for (i, layer) in self.layers.iter().enumerate().skip(1) {
            if i == last {
                let src: &[f32] = if cur_is_a { &*pa } else { &*pb };
                layer_forward_q8(layer, src, n, xq, out, self.kernel);
            } else if cur_is_a {
                layer_forward_q8(layer, &*pa, n, xq, &mut *pb, self.kernel);
                cur_is_a = false;
            } else {
                layer_forward_q8(layer, &*pb, n, xq, &mut *pa, self.kernel);
                cur_is_a = true;
            }
        }
    }

    /// Convenience allocating wrapper (offline paths, tests).
    pub fn forward_batch(&self, x: &[f32], n: usize) -> Vec<f32> {
        let mut scratch = QGemmScratch::new();
        let mut out = vec![0.0f32; n * self.n_out];
        self.forward_batch_to(x, n, &mut scratch, &mut out);
        out
    }
}

/// Quantize one `(n, fan_in)` f32 activation panel symmetrically into
/// `xq`; returns the dequantization scale.  Shares the exact rounding
/// routine with the weight quantizer ([`QuantizedTensor::quantize_into`])
/// and is always scalar, so every kernel variant consumes identical codes.
fn quantize_panel(x: &[f32], xq: &mut Vec<i8>) -> f32 {
    let sx = QuantizedTensor::scale_for(x);
    if xq.len() < x.len() {
        xq.resize(x.len(), 0);
    }
    QuantizedTensor::quantize_into(x, sx, &mut xq[..x.len()]);
    sx
}

/// One quantized layer over a whole activation panel:
/// `out[(n, fan_out)] = act(requant(xq[(n, fan_in)] . Wq) + b)`.
fn layer_forward_q8(
    layer: &PackedLayerQ8,
    x: &[f32],
    n: usize,
    xq: &mut Vec<i8>,
    out: &mut [f32],
    kernel: Kernel,
) {
    let fi = layer.fan_in;
    let fo = layer.fan_out;
    debug_assert!(x.len() >= n * fi);
    debug_assert!(out.len() >= n * fo);
    let sx = quantize_panel(&x[..n * fi], xq);
    // Fused requantization scale: i32 accumulator -> f32 pre-activation.
    let scale = sx * layer.w_scale;
    let xq = &xq[..n * fi];
    let tile_len = simd::q8_tile_len(fi);
    for t in 0..layer.n_tiles {
        let c0 = t * NR;
        let width = NR.min(fo - c0);
        let w_tile = &layer.w[t * tile_len..(t + 1) * tile_len];
        let b_tile = &layer.b[c0..c0 + NR];
        let mut i0 = 0;
        while i0 + MR <= n {
            let acc = simd::mr_tile_q8(kernel, xq, i0, fi, w_tile);
            for r in 0..MR {
                let row = &mut out[(i0 + r) * fo + c0..(i0 + r) * fo + c0 + width];
                for j in 0..width {
                    let v = acc[r][j] as f32 * scale + b_tile[j];
                    row[j] = if layer.sigmoid { sigmoid(v) } else { v };
                }
            }
            i0 += MR;
        }
        // Tail rows (n % MR) — scalar, same exact i32 accumulation.
        for i in i0..n {
            let acc = simd::row_tile_q8(&xq[i * fi..(i + 1) * fi], w_tile);
            let row = &mut out[i * fo + c0..i * fo + c0 + width];
            for j in 0..width {
                let v = acc[j] as f32 * scale + b_tile[j];
                row[j] = if layer.sigmoid { sigmoid(v) } else { v };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Layer, Matrix};
    use crate::util::{prop, rng::Rng};

    fn random_mlp(r: &mut Rng, topo: &[usize]) -> Mlp {
        prop::gens::mlp(r, topo, 2.0, 1.0)
    }

    /// Exact f32 reference for one layer (naive per-neuron dots).
    fn layer_ref(l: &Layer, x: &[f32], n: usize, sig: bool) -> Vec<f32> {
        let (fi, fo) = (l.w.rows, l.w.cols);
        let mut out = vec![0.0f32; n * fo];
        for i in 0..n {
            for c in 0..fo {
                let mut s = l.b[c];
                for k in 0..fi {
                    s += x[i * fi + k] * l.w.at(k, c);
                }
                out[i * fo + c] = if sig { sigmoid(s) } else { s };
            }
        }
        out
    }

    /// Conservative per-element quantization error bound, propagated layer
    /// by layer.  With `e` the incoming activation error, `sx`/`sw` the
    /// activation/weight quantization steps and `amax`/`wmax` the reference
    /// magnitudes, one dot term errs by at most
    /// `(e + sx/2)(wmax + sw/2) + amax * sw/2`; the sigmoid contracts by
    /// its Lipschitz constant 1/4.  A small slop absorbs f32 rounding and
    /// summation-order differences vs the scalar reference.
    fn q8_bound(mlp: &Mlp, x: &[f32], n: usize) -> f32 {
        let last = mlp.layers.len() - 1;
        let mut act: Vec<f32> = x.to_vec();
        let mut e = 0.0f32;
        for (li, l) in mlp.layers.iter().enumerate() {
            let amax = act.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let wmax = l.w.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let sw = QuantizedTensor::quantize(&l.w.data).scale;
            let sx = (amax + e) / 127.0;
            let fan_in = l.w.rows as f32;
            let dot = fan_in * ((e + 0.5 * sx) * (wmax + 0.5 * sw) + amax * 0.5 * sw);
            e = if li < last { 0.25 * dot } else { dot };
            e = e * 1.001 + 1e-5;
            act = layer_ref(l, &act, n, li < last);
        }
        e
    }

    #[test]
    fn q8_hand_checked_exact_case() {
        // Single linear layer, x = [1, 1], w = [1, -1], b = 0.5: both the
        // dot's terms quantize exactly (±127) and cancel, so the int8 path
        // reproduces 0.5 exactly.
        let mlp = Mlp::new(vec![Layer {
            w: Matrix::new(2, 1, vec![1.0, -1.0]),
            b: vec![0.5],
        }]);
        let q = PackedMlpQ8::from_mlp(&mlp);
        assert_eq!(q.n_in(), 2);
        assert_eq!(q.n_out(), 1);
        let y = q.forward_batch(&[1.0, 1.0], 1);
        assert_eq!(y[0], 0.5);
    }

    #[test]
    fn q8_handles_tile_tails() {
        let mut r = Rng::new(0x9E78);
        for fo in [1, 7, 8, 9, 16, 17] {
            let mlp = random_mlp(&mut r, &[5, fo, 3]);
            let q = PackedMlpQ8::from_mlp(&mlp);
            for n in 1..=9usize {
                let x = prop::gens::vec_f32(&mut r, n * 5, -2.0, 2.0);
                let fast = q.forward_batch(&x, n);
                let slow = mlp.forward_batch(&x, n);
                let bound = q8_bound(&mlp, &x, n);
                for (i, (a, b)) in fast.iter().zip(&slow).enumerate() {
                    assert!(
                        (a - b).abs() <= bound,
                        "fo={fo} n={n} elem {i}: {a} vs {b} (bound {bound})"
                    );
                }
            }
        }
    }

    /// Property: the int8 forward stays within the derived quantization
    /// error bound of the f32 scalar path on random topologies.
    #[test]
    fn prop_q8_within_derived_bound() {
        prop::check(
            "q8-vs-f32-error-bound",
            100,
            0x6E45,
            |r: &mut Rng| {
                let depth = 1 + r.below(3) as usize;
                let mut topo = vec![1 + r.below(24) as usize];
                for _ in 0..depth {
                    topo.push(1 + r.below(24) as usize);
                }
                let mlp = random_mlp(r, &topo);
                let n = 1 + r.below(40) as usize;
                let x = prop::gens::vec_f32(r, n * topo[0], -2.0, 2.0);
                (mlp, x, n)
            },
            |(mlp, x, n)| {
                let q = PackedMlpQ8::from_mlp(mlp);
                let fast = q.forward_batch(x, *n);
                let slow = mlp.forward_batch(x, *n);
                let bound = q8_bound(mlp, x, *n);
                if !bound.is_finite() {
                    return Err(format!("non-finite bound {bound}"));
                }
                for (i, (a, b)) in fast.iter().zip(&slow).enumerate() {
                    if (a - b).abs() > bound {
                        return Err(format!("elem {i}: {a} vs {b} exceeds bound {bound}"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Kernel parity: int8 accumulation is exact in every variant, and all
    /// post-accumulator math is identical scalar f32 code — so SIMD and
    /// scalar forwards must be BITWISE identical.
    #[test]
    fn simd_kernels_bitwise_match_scalar() {
        let mut r = Rng::new(0x51D2);
        let topos: [&[usize]; 3] = [&[6, 8, 8, 1], &[9, 17, 3], &[5, 7, 2]];
        for topo in topos {
            let mlp = random_mlp(&mut r, topo);
            let scalar = PackedMlpQ8::from_mlp(&mlp).with_kernel(Kernel::Scalar);
            for k in [Kernel::Avx2, Kernel::Neon] {
                if !k.available() {
                    continue;
                }
                let fast = PackedMlpQ8::from_mlp(&mlp).with_kernel(k);
                for n in [1usize, 4, 9, 33] {
                    let x = prop::gens::vec_f32(&mut r, n * topo[0], -2.0, 2.0);
                    assert_eq!(
                        fast.forward_batch(&x, n),
                        scalar.forward_batch(&x, n),
                        "{} kernel diverges bitwise (topo {topo:?}, n {n})",
                        k.name()
                    );
                }
            }
        }
    }

    /// The MCQW format is the pack path's native input: packing a net
    /// quantized-then-serialized-then-reloaded forwards bitwise
    /// identically to packing straight from f32.
    #[test]
    fn packing_from_mcqw_roundtrip_is_identical() {
        let mut r = Rng::new(0x0FF1);
        let mlp = random_mlp(&mut r, &[6, 8, 8, 1]);
        let bytes = QuantizedMlpFile::from_mlp(&mlp).to_bytes();
        let reloaded = QuantizedMlpFile::read(&mut bytes.as_slice()).unwrap();
        let direct = PackedMlpQ8::from_mlp(&mlp);
        let via_file = PackedMlpQ8::from_quantized(&reloaded);
        let x = prop::gens::vec_f32(&mut r, 9 * 6, -2.0, 2.0);
        assert_eq!(direct.forward_batch(&x, 9), via_file.forward_batch(&x, 9));
    }

    #[test]
    fn scratch_reusable_across_batch_sizes_and_nets() {
        let mut r = Rng::new(8);
        let m1 = random_mlp(&mut r, &[6, 8, 8, 1]);
        let m2 = random_mlp(&mut r, &[3, 12, 4]);
        let (q1, q2) = (PackedMlpQ8::from_mlp(&m1), PackedMlpQ8::from_mlp(&m2));
        let mut scratch = QGemmScratch::new();
        for n in [1usize, 5, 64, 3] {
            let x1 = prop::gens::vec_f32(&mut r, n * 6, -1.0, 1.0);
            let mut out1 = vec![0.0f32; n];
            q1.forward_batch_to(&x1, n, &mut scratch, &mut out1);
            assert_eq!(out1, q1.forward_batch(&x1, n), "scratch path diverges");
            let x2 = prop::gens::vec_f32(&mut r, n * 3, -1.0, 1.0);
            let mut out2 = vec![0.0f32; n * 4];
            q2.forward_batch_to(&x2, n, &mut scratch, &mut out2);
            assert_eq!(out2, q2.forward_batch(&x2, n), "scratch path diverges");
        }
        // Steady state: repeating the largest batch allocates nothing.
        let x: Vec<f32> = prop::gens::vec_f32(&mut r, 64 * 6, -1.0, 1.0);
        let mut out = vec![0.0f32; 64];
        q1.forward_batch_to(&x, 64, &mut scratch, &mut out);
        let warm = scratch.capacity();
        for _ in 0..4 {
            q1.forward_batch_to(&x, 64, &mut scratch, &mut out);
            assert_eq!(scratch.capacity(), warm, "q8 scratch grew");
        }
    }
}
