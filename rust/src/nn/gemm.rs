//! Batched, cache-blocked GEMM micro-kernel for MLP inference (§Perf L3).
//!
//! `Mlp::forward_batch` streams one sample at a time through a scalar GEMV,
//! so every weight matrix is re-read from memory per sample and the batch
//! dimension is wasted.  `PackedMlp` fixes both:
//!
//! * **Packing** — each layer's `(fan_in, fan_out)` row-major weights are
//!   repacked ONCE into column tiles of width [`NR`] (zero-padded), so the
//!   micro-kernel reads `NR` contiguous weights per fused multiply-add and
//!   LLVM autovectorizes the inner loop without gather instructions.
//! * **Register blocking** — the kernel processes [`MR`] samples x [`NR`]
//!   outputs per micro-tile, accumulating in a `[[f32; NR]; MR]` register
//!   block; each packed weight tile is then reused across the whole
//!   activation panel while it is cache-hot.
//! * **Panel ping-pong** — the layer chain runs over two reusable scratch
//!   panels ([`GemmScratch`]) instead of per-sample swap buffers, so a
//!   steady-state batch performs zero heap allocations.
//!
//! * **SIMD micro-kernels** — full `MR x NR` micro-tiles run through the
//!   runtime-dispatched kernels in [`super::simd`] (AVX2 / NEON / scalar),
//!   selected once at pack time; tail rows stay scalar.
//!
//! Numerics: accumulation over `fan_in` runs in the same ascending-k order
//! as the scalar path; only the bias add is reassociated (applied after the
//! dot product rather than before), so packed and scalar forwards agree to
//! f32 rounding (the property test below pins 1e-5; FMA contraction in the
//! SIMD variants stays inside the same tolerance).

// audit:deterministic — packed forward must match the scalar path bitwise.
use super::simd::{self, Kernel};
use super::{sigmoid, Mlp};

/// Column-tile width (outputs per micro-tile). A whole tile row is one
/// contiguous `NR`-float slice, sized for 256-bit SIMD lanes.
pub const NR: usize = 8;

/// Row block height (samples per micro-tile).
pub const MR: usize = 4;

/// One dense layer packed for the tiled kernel.
#[derive(Clone, Debug)]
pub struct PackedLayer {
    pub fan_in: usize,
    pub fan_out: usize,
    /// `ceil(fan_out / NR)` column tiles.
    n_tiles: usize,
    /// Tile-major weights: tile `t` holds `fan_in` rows of `NR` contiguous
    /// columns (`w[(t * fan_in + k) * NR + j]` = W[k, t*NR + j]), columns
    /// past `fan_out` zero-padded.
    w: Vec<f32>,
    /// Bias padded to `n_tiles * NR`.
    b: Vec<f32>,
    /// Apply the sigmoid activation (hidden layers).
    sigmoid: bool,
}

impl PackedLayer {
    fn pack(w: &super::Matrix, b: &[f32], sig: bool) -> Self {
        let (fan_in, fan_out) = (w.rows, w.cols);
        let n_tiles = fan_out.div_ceil(NR);
        let mut layer = PackedLayer {
            fan_in,
            fan_out,
            n_tiles,
            w: vec![0.0f32; n_tiles * fan_in * NR],
            b: vec![0.0f32; n_tiles * NR],
            sigmoid: sig,
        };
        layer.repack_from(w, b);
        layer
    }

    /// Re-copy `w`/`b` into the existing packed buffers (same shape) —
    /// no allocation.  The trainer calls this after every optimizer step.
    fn repack_from(&mut self, w: &super::Matrix, b: &[f32]) {
        assert_eq!((w.rows, w.cols), (self.fan_in, self.fan_out), "repack shape mismatch");
        assert_eq!(b.len(), self.fan_out, "repack bias length mismatch");
        let (fan_in, fan_out) = (self.fan_in, self.fan_out);
        for t in 0..self.n_tiles {
            let c0 = t * NR;
            let width = NR.min(fan_out - c0);
            for k in 0..fan_in {
                let src = &w.data[k * fan_out + c0..k * fan_out + c0 + width];
                let dst = &mut self.w[(t * fan_in + k) * NR..(t * fan_in + k) * NR + width];
                dst.copy_from_slice(src);
            }
        }
        self.b[..fan_out].copy_from_slice(b);
    }
}

/// Reusable activation panels for the layer chain. One scratch serves any
/// batch size / topology: panels grow to the high-water mark and stay.
#[derive(Debug, Default)]
pub struct GemmScratch {
    a: Vec<f32>,
    b: Vec<f32>,
}

impl GemmScratch {
    pub fn new() -> Self {
        GemmScratch::default()
    }

    /// Total capacity currently held (for allocation-stability tests).
    pub fn capacity(&self) -> usize {
        self.a.capacity() + self.b.capacity()
    }

    fn panel(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        &mut buf[..len]
    }
}

/// An [`Mlp`] repacked for the tiled batched kernel. Pack once at load
/// time, forward many times.
#[derive(Clone, Debug)]
pub struct PackedMlp {
    layers: Vec<PackedLayer>,
    n_in: usize,
    n_out: usize,
    /// Widest layer output — sizes the intermediate panels.
    max_width: usize,
    /// Micro-kernel chosen at pack time (runtime CPU detection).
    kernel: Kernel,
}

impl PackedMlp {
    pub fn from_mlp(mlp: &Mlp) -> Self {
        let last = mlp.layers.len().saturating_sub(1);
        let layers: Vec<PackedLayer> = mlp
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| PackedLayer::pack(&l.w, &l.b, i < last))
            .collect();
        let max_width = layers.iter().map(|l| l.fan_out).max().unwrap_or(0);
        PackedMlp {
            layers,
            n_in: mlp.n_in(),
            n_out: mlp.n_out(),
            max_width,
            kernel: Kernel::detect(),
        }
    }

    /// Force a specific micro-kernel (parity tests, ablations).  Panics if
    /// the kernel is not runnable on this CPU.
    pub fn with_kernel(mut self, kernel: Kernel) -> Self {
        self.set_kernel(kernel);
        self
    }

    /// In-place variant of [`Self::with_kernel`] — the trainer forces its
    /// packed twin onto a kernel without rebuilding it.
    pub fn set_kernel(&mut self, kernel: Kernel) {
        assert!(kernel.available(), "{} kernel unavailable on this CPU", kernel.name());
        self.kernel = kernel;
    }

    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    pub fn n_in(&self) -> usize {
        self.n_in
    }

    pub fn n_out(&self) -> usize {
        self.n_out
    }

    /// Forward a row-major `(n, n_in)` panel into `out` (`(n, n_out)`,
    /// resized by the caller). Zero allocations once `scratch` is warm.
    pub fn forward_batch_to(
        &self,
        x: &[f32],
        n: usize,
        scratch: &mut GemmScratch,
        out: &mut [f32],
    ) {
        assert_eq!(x.len(), n * self.n_in, "batch buffer size mismatch");
        assert_eq!(out.len(), n * self.n_out, "output buffer size mismatch");
        if self.layers.is_empty() {
            out.copy_from_slice(x);
            return;
        }
        if self.layers.len() == 1 {
            layer_forward(&self.layers[0], x, n, out, self.kernel);
            return;
        }
        // Ping-pong intermediates through the two reusable scratch panels;
        // the final layer writes straight into `out`.
        let panel_len = n * self.max_width;
        GemmScratch::panel(&mut scratch.a, panel_len);
        GemmScratch::panel(&mut scratch.b, panel_len);
        let pa = &mut scratch.a[..panel_len];
        let pb = &mut scratch.b[..panel_len];
        let last = self.layers.len() - 1;
        layer_forward(&self.layers[0], x, n, pa, self.kernel);
        let mut cur_is_a = true;
        for (i, layer) in self.layers.iter().enumerate().skip(1) {
            if i == last {
                let src: &[f32] = if cur_is_a { &*pa } else { &*pb };
                layer_forward(layer, src, n, out, self.kernel);
            } else if cur_is_a {
                layer_forward(layer, &*pa, n, &mut *pb, self.kernel);
                cur_is_a = false;
            } else {
                layer_forward(layer, &*pb, n, &mut *pa, self.kernel);
                cur_is_a = true;
            }
        }
    }

    /// Convenience allocating wrapper (offline paths, tests).
    pub fn forward_batch(&self, x: &[f32], n: usize) -> Vec<f32> {
        let mut scratch = GemmScratch::new();
        let mut out = vec![0.0f32; n * self.n_out];
        self.forward_batch_to(x, n, &mut scratch, &mut out);
        out
    }

    /// Re-pack from `mlp` (same topology) into the existing buffers — no
    /// allocation.  Lets the backprop trainer keep routing its minibatch
    /// forward passes through this tiled kernel while the weights change
    /// every optimizer step.
    pub fn repack_from(&mut self, mlp: &Mlp) {
        assert_eq!(self.layers.len(), mlp.layers.len(), "repack layer count mismatch");
        for (pl, l) in self.layers.iter_mut().zip(&mlp.layers) {
            pl.repack_from(&l.w, &l.b);
        }
    }

    /// Forward a `(n, n_in)` panel, storing EVERY layer's post-activation
    /// output panel in `acts` (`acts[l]` is `(n, fan_out_l)`) — the
    /// activation cache backprop consumes.  Buffers in `acts` are resized
    /// in place and reused across calls.
    pub fn forward_collect(&self, x: &[f32], n: usize, acts: &mut Vec<Vec<f32>>) {
        assert!(!self.layers.is_empty(), "forward_collect needs >= 1 layer");
        assert_eq!(x.len(), n * self.n_in, "batch buffer size mismatch");
        acts.resize_with(self.layers.len(), Vec::new);
        for (i, layer) in self.layers.iter().enumerate() {
            let len = n * layer.fan_out;
            if acts[i].len() != len {
                acts[i].resize(len, 0.0);
            }
        }
        for i in 0..self.layers.len() {
            // Split-borrow: the source panel is the previous entry (or x).
            let (done, rest) = acts.split_at_mut(i);
            let src: &[f32] = if i == 0 { x } else { &done[i - 1] };
            layer_forward(&self.layers[i], src, n, &mut rest[0], self.kernel);
        }
    }
}

/// One packed layer over a whole activation panel:
/// `out[(n, fan_out)] = act(x[(n, fan_in)] . W + b)`.
fn layer_forward(layer: &PackedLayer, x: &[f32], n: usize, out: &mut [f32], kernel: Kernel) {
    let fi = layer.fan_in;
    let fo = layer.fan_out;
    debug_assert!(x.len() >= n * fi);
    debug_assert!(out.len() >= n * fo);
    for t in 0..layer.n_tiles {
        let c0 = t * NR;
        let width = NR.min(fo - c0);
        let w_tile = &layer.w[t * fi * NR..(t + 1) * fi * NR];
        let b_tile = &layer.b[c0..c0 + NR];
        // Full MR-row micro-tiles run the dispatched SIMD micro-kernel:
        // MR x NR accumulators live in registers, the k-loop streams one
        // NR-wide packed weight row per iteration.
        let mut i0 = 0;
        while i0 + MR <= n {
            let acc = simd::mr_tile_f32(kernel, x, i0, fi, w_tile);
            for r in 0..MR {
                let row = &mut out[(i0 + r) * fo + c0..(i0 + r) * fo + c0 + width];
                for j in 0..width {
                    let v = acc[r][j] + b_tile[j];
                    row[j] = if layer.sigmoid { sigmoid(v) } else { v };
                }
            }
            i0 += MR;
        }
        // Tail rows (n % MR).
        for i in i0..n {
            let mut acc = [0.0f32; NR];
            let xrow = &x[i * fi..(i + 1) * fi];
            for (k, &xv) in xrow.iter().enumerate() {
                let wrow = &w_tile[k * NR..k * NR + NR];
                for j in 0..NR {
                    acc[j] += xv * wrow[j];
                }
            }
            let row = &mut out[i * fo + c0..i * fo + c0 + width];
            for j in 0..width {
                let v = acc[j] + b_tile[j];
                row[j] = if layer.sigmoid { sigmoid(v) } else { v };
            }
        }
    }
}

/// Pack a row-major `(rows, cols)` matrix into [`NR`]-wide column tiles —
/// the same layout [`PackedLayer`] uses for weights
/// (`out[(t * rows + k) * NR + j] = src[k * cols + t*NR + j]`, columns past
/// `cols` zero-padded).  `out` is clear-resized, so a reused buffer keeps
/// its capacity but never leaks stale values into the padding.
///
/// The backward pass packs the delta panel with this to drive the
/// `dW = a_prevᵀ · δ` GEMM through the same micro-kernels as the forward.
pub fn pack_tiles(src: &[f32], rows: usize, cols: usize, out: &mut Vec<f32>) {
    debug_assert!(src.len() >= rows * cols);
    let n_tiles = cols.div_ceil(NR);
    out.clear();
    out.resize(n_tiles * rows * NR, 0.0);
    for t in 0..n_tiles {
        let c0 = t * NR;
        let width = NR.min(cols - c0);
        for k in 0..rows {
            let dst = &mut out[(t * rows + k) * NR..(t * rows + k) * NR + width];
            dst.copy_from_slice(&src[k * cols + c0..k * cols + c0 + width]);
        }
    }
}

/// Pack the TRANSPOSE of a row-major `(rows, cols)` matrix into [`NR`]-wide
/// column tiles: the result tiles the `(cols, rows)` matrix `srcᵀ`, i.e.
/// `out[(t * cols + k) * NR + j] = src[(t*NR + j) * cols + k]`.
///
/// This is the `Wᵀ` layout the backward pass needs for
/// `δ_prev = δ · Wᵀ`: contraction runs over `fan_out` (= `cols` of the
/// stored weight matrix) and the tile columns are `fan_in` rows of `W`.
pub fn pack_tiles_transposed(src: &[f32], rows: usize, cols: usize, out: &mut Vec<f32>) {
    debug_assert!(src.len() >= rows * cols);
    let n_tiles = rows.div_ceil(NR);
    out.clear();
    out.resize(n_tiles * cols * NR, 0.0);
    for t in 0..n_tiles {
        let r0 = t * NR;
        let width = NR.min(rows - r0);
        for k in 0..cols {
            for j in 0..width {
                out[(t * cols + k) * NR + j] = src[(r0 + j) * cols + k];
            }
        }
    }
}

/// Transpose a row-major `(rows, cols)` panel into `out` (`(cols, rows)`
/// row-major, clear-resized).  The backward pass transposes the previous
/// layer's activation panel once per minibatch so `dW = a_prevᵀ · δ`
/// becomes a plain row-major GEMM for [`gemm_tiled`].
pub fn transpose_into(src: &[f32], rows: usize, cols: usize, out: &mut Vec<f32>) {
    debug_assert!(src.len() >= rows * cols);
    out.clear();
    out.resize(rows * cols, 0.0);
    for i in 0..rows {
        for j in 0..cols {
            out[j * rows + i] = src[i * cols + j];
        }
    }
}

/// Bare tiled GEMM over a pre-packed right-hand side:
/// `out[(m, n_cols)] = x[(m, k_dim)] · T` where `T` is `(k_dim, n_cols)`
/// packed by [`pack_tiles`] / [`pack_tiles_transposed`].  No bias, no
/// activation — this is [`layer_forward`]'s blocking (full `MR`-row
/// micro-tiles through the dispatched SIMD kernel, scalar tail rows)
/// exposed for the training-side delta GEMMs.
///
/// Numerics: accumulation over `k_dim` is ascending-k in every variant, so
/// with [`Kernel::Scalar`] the result is bitwise identical to the naive
/// triple loop in the same order; SIMD variants differ only by FMA
/// contraction (same bound as the forward-path parity tests).
pub fn gemm_tiled(
    kernel: Kernel,
    x: &[f32],
    m: usize,
    k_dim: usize,
    tiles: &[f32],
    n_cols: usize,
    out: &mut [f32],
) {
    let n_tiles = n_cols.div_ceil(NR);
    debug_assert!(x.len() >= m * k_dim);
    debug_assert!(tiles.len() >= n_tiles * k_dim * NR);
    debug_assert!(out.len() >= m * n_cols);
    for t in 0..n_tiles {
        let c0 = t * NR;
        let width = NR.min(n_cols - c0);
        let w_tile = &tiles[t * k_dim * NR..(t + 1) * k_dim * NR];
        let mut i0 = 0;
        while i0 + MR <= m {
            let acc = simd::mr_tile_f32(kernel, x, i0, k_dim, w_tile);
            for r in 0..MR {
                out[(i0 + r) * n_cols + c0..(i0 + r) * n_cols + c0 + width]
                    .copy_from_slice(&acc[r][..width]);
            }
            i0 += MR;
        }
        for i in i0..m {
            let mut acc = [0.0f32; NR];
            let xrow = &x[i * k_dim..(i + 1) * k_dim];
            for (k, &xv) in xrow.iter().enumerate() {
                let wrow = &w_tile[k * NR..k * NR + NR];
                for j in 0..NR {
                    acc[j] += xv * wrow[j];
                }
            }
            out[i * n_cols + c0..i * n_cols + c0 + width].copy_from_slice(&acc[..width]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Layer, Matrix};
    use crate::util::{prop, rng::Rng};

    fn random_mlp(r: &mut Rng, topo: &[usize]) -> Mlp {
        prop::gens::mlp(r, topo, 2.0, 1.0)
    }

    #[test]
    fn packed_matches_forward1_hand_checked() {
        let mlp = Mlp::new(vec![
            Layer { w: Matrix::new(2, 2, vec![1.0, 0.0, 0.0, 1.0]), b: vec![0.0, 0.0] },
            Layer { w: Matrix::new(2, 1, vec![1.0, -1.0]), b: vec![0.5] },
        ]);
        let packed = PackedMlp::from_mlp(&mlp);
        let y = packed.forward_batch(&[0.0, 0.0], 1);
        assert!((y[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn packed_handles_tile_tails() {
        // Dimensions straddling the NR=8 / MR=4 boundaries: 7, 8, 9 wide
        // layers and 1..=9 row batches all must agree with the scalar path.
        let mut r = Rng::new(0x9E77);
        for fo in [1, 7, 8, 9, 16, 17] {
            let mlp = random_mlp(&mut r, &[5, fo, 3]);
            for n in 1..=9usize {
                let x = prop::gens::vec_f32(&mut r, n * 5, -2.0, 2.0);
                let fast = PackedMlp::from_mlp(&mlp).forward_batch(&x, n);
                let slow = mlp.forward_batch(&x, n);
                prop::assert_close(&fast, &slow, 1e-5, 1e-5).unwrap();
            }
        }
    }

    #[test]
    fn scratch_reusable_across_batch_sizes_and_nets() {
        let mut r = Rng::new(7);
        let m1 = random_mlp(&mut r, &[6, 8, 8, 1]);
        let m2 = random_mlp(&mut r, &[3, 12, 4]);
        let (p1, p2) = (PackedMlp::from_mlp(&m1), PackedMlp::from_mlp(&m2));
        let mut scratch = GemmScratch::new();
        for n in [1usize, 5, 64, 3] {
            let x1 = prop::gens::vec_f32(&mut r, n * 6, -1.0, 1.0);
            let mut out1 = vec![0.0f32; n];
            p1.forward_batch_to(&x1, n, &mut scratch, &mut out1);
            prop::assert_close(&out1, &m1.forward_batch(&x1, n), 1e-5, 1e-5).unwrap();
            let x2 = prop::gens::vec_f32(&mut r, n * 3, -1.0, 1.0);
            let mut out2 = vec![0.0f32; n * 4];
            p2.forward_batch_to(&x2, n, &mut scratch, &mut out2);
            prop::assert_close(&out2, &m2.forward_batch(&x2, n), 1e-5, 1e-5).unwrap();
        }
    }

    /// `repack_from` reuses buffers and produces a net forwarding bitwise
    /// identically to a fresh pack of the same weights; `forward_collect`'s
    /// final panel is bitwise the plain forward.
    #[test]
    fn repack_and_collect_match_fresh_pack() {
        let mut r = Rng::new(0x7217);
        let m1 = random_mlp(&mut r, &[5, 7, 6, 2]);
        let m2 = random_mlp(&mut r, &[5, 7, 6, 2]);
        let mut packed = PackedMlp::from_mlp(&m1);
        packed.repack_from(&m2);
        let fresh = PackedMlp::from_mlp(&m2).with_kernel(packed.kernel());
        let x = prop::gens::vec_f32(&mut r, 9 * 5, -2.0, 2.0);
        assert_eq!(packed.forward_batch(&x, 9), fresh.forward_batch(&x, 9));

        let mut acts: Vec<Vec<f32>> = Vec::new();
        packed.forward_collect(&x, 9, &mut acts);
        assert_eq!(acts.len(), 3);
        assert_eq!(acts[0].len(), 9 * 7);
        assert_eq!(acts[1].len(), 9 * 6);
        assert_eq!(acts[2], packed.forward_batch(&x, 9), "final panel diverges");
        // Hidden panels are post-sigmoid: inside [0, 1] (f32 saturates the
        // open interval's endpoints for |z| beyond ~17).
        assert!(acts[0].iter().chain(&acts[1]).all(|&v| (0.0..=1.0).contains(&v)));
        // Reuse with a smaller batch resizes in place and stays correct.
        packed.forward_collect(&x[..2 * 5], 2, &mut acts);
        assert_eq!(acts[2], packed.forward_batch(&x[..2 * 5], 2));
    }

    /// Kernel parity: every SIMD variant runnable on this CPU agrees with
    /// the forced-scalar packed kernel to 1e-5 (FMA contraction is the only
    /// numeric difference; accumulation order is identical).
    #[test]
    fn simd_kernels_match_scalar_forward() {
        let mut r = Rng::new(0x51D1);
        let topos: [&[usize]; 3] = [&[6, 8, 8, 1], &[9, 17, 3], &[5, 7, 2]];
        for topo in topos {
            let mlp = random_mlp(&mut r, topo);
            let scalar = PackedMlp::from_mlp(&mlp).with_kernel(Kernel::Scalar);
            for k in [Kernel::Avx2, Kernel::Neon] {
                if !k.available() {
                    continue;
                }
                let fast = PackedMlp::from_mlp(&mlp).with_kernel(k);
                for n in [1usize, 4, 9, 33] {
                    let x = prop::gens::vec_f32(&mut r, n * topo[0], -2.0, 2.0);
                    prop::assert_close(
                        &fast.forward_batch(&x, n),
                        &scalar.forward_batch(&x, n),
                        1e-5,
                        1e-5,
                    )
                    .unwrap_or_else(|e| panic!("{} vs scalar: {e}", k.name()));
                }
            }
        }
    }

    /// Property: the packed tiled GEMM equals the scalar streaming forward
    /// (itself pinned against a naive per-neuron oracle in
    /// `nn::tests::prop_forward_matches_naive`) on random topologies.
    #[test]
    fn prop_packed_forward_matches_streaming() {
        prop::check(
            "packed-gemm-vs-streaming",
            100,
            0x6E44,
            |r: &mut Rng| {
                let depth = 1 + r.below(3) as usize;
                let mut topo = vec![1 + r.below(24) as usize];
                for _ in 0..depth {
                    topo.push(1 + r.below(24) as usize);
                }
                let mlp = random_mlp(r, &topo);
                let n = 1 + r.below(40) as usize;
                let x = prop::gens::vec_f32(r, n * topo[0], -2.0, 2.0);
                (mlp, x, n)
            },
            |(mlp, x, n)| {
                let packed = PackedMlp::from_mlp(mlp);
                let fast = packed.forward_batch(x, *n);
                let slow = mlp.forward_batch(x, *n);
                prop::assert_close(&fast, &slow, 1e-5, 1e-5)
            },
        );
    }

    /// Reference GEMM in the exact accumulation order `gemm_tiled`'s scalar
    /// kernel uses (ascending k), for bitwise comparison.
    fn naive_gemm(x: &[f32], m: usize, kd: usize, w: &[f32], n_cols: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n_cols];
        for i in 0..m {
            for j in 0..n_cols {
                let mut acc = 0.0f32;
                for k in 0..kd {
                    acc += x[i * kd + k] * w[k * n_cols + j];
                }
                out[i * n_cols + j] = acc;
            }
        }
        out
    }

    /// `pack_tiles` + `gemm_tiled` (scalar kernel) is bitwise the naive
    /// ascending-k triple loop, across MR/NR boundary shapes; SIMD kernels
    /// agree within the forward-path FMA tolerance.  `pack_tiles_transposed`
    /// computes against the transpose, and `transpose_into` round-trips.
    #[test]
    fn gemm_tiled_matches_naive_and_transpose() {
        let mut r = Rng::new(0xF1E1);
        for (m, kd, n_cols) in [(1usize, 1usize, 1usize), (4, 3, 8), (5, 7, 9), (13, 16, 17)] {
            let x = prop::gens::vec_f32(&mut r, m * kd, -2.0, 2.0);
            let w = prop::gens::vec_f32(&mut r, kd * n_cols, -2.0, 2.0);
            let mut tiles = Vec::new();
            pack_tiles(&w, kd, n_cols, &mut tiles);
            let mut out = vec![0.0f32; m * n_cols];
            gemm_tiled(Kernel::Scalar, &x, m, kd, &tiles, n_cols, &mut out);
            assert_eq!(out, naive_gemm(&x, m, kd, &w, n_cols), "{m}x{kd}x{n_cols}");
            for k in [Kernel::Avx2, Kernel::Neon] {
                if !k.available() {
                    continue;
                }
                let mut fast = vec![0.0f32; m * n_cols];
                gemm_tiled(k, &x, m, kd, &tiles, n_cols, &mut fast);
                prop::assert_close(&fast, &out, 1e-5, 1e-5)
                    .unwrap_or_else(|e| panic!("{} {m}x{kd}x{n_cols}: {e}", k.name()));
            }

            // Transposed packing: x(m,kd) . wᵀ where w is (n_cols, kd)
            // stored row-major — contraction over w's columns.
            let wt_src = prop::gens::vec_f32(&mut r, n_cols * kd, -2.0, 2.0);
            let mut t_tiles = Vec::new();
            pack_tiles_transposed(&wt_src, n_cols, kd, &mut t_tiles);
            let mut wt = Vec::new();
            transpose_into(&wt_src, n_cols, kd, &mut wt);
            let mut out_t = vec![0.0f32; m * n_cols];
            gemm_tiled(Kernel::Scalar, &x, m, kd, &t_tiles, n_cols, &mut out_t);
            assert_eq!(out_t, naive_gemm(&x, m, kd, &wt, n_cols), "transposed pack");
        }
        // Buffer reuse across shrinking shapes must not leak stale padding.
        let mut tiles = Vec::new();
        pack_tiles(&[1.0; 64], 8, 8, &mut tiles);
        pack_tiles(&[2.0, 3.0], 1, 2, &mut tiles);
        assert_eq!(&tiles[..NR], &[2.0, 3.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }
}
