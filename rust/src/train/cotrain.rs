//! The paper's co-training method (§III.C, Fig. 9), natively.
//!
//! ```text
//!            ┌───────────────── warmup: one base approximator ────────────────┐
//!            │                                                                │
//!            ▼                                                                │
//!   error-driven seed partition (quantiles of base error)                     │
//!            │                                                                │
//!   ┌────────┴─ round r ──────────────────────────────────────────────────┐   │
//!   │ 1. each A_k trains `approx_epochs` on its partition  (threadpool)   │   │
//!   │ 2. error matrix E[k][i] over the WHOLE set — packed GEMM forwards   │   │
//!   │    sharded as (net, fixed 512-row block) jobs across the pool       │   │
//!   │ 3. sample i -> argmin_k E[k][i]; bound violated -> reject class nC  │   │
//!   │ 4. multiclass classifier retrains on the refined labels             │   │
//!   │ 5. measured invocation; |Δ| < tol twice -> converged                │   │
//!   └──────────────────────────────────────────────────────────────────────┘
//! ```
//!
//! Two allocation schemes (paper §III.C):
//!
//! * **Competitive** — approximators bid with their own error, samples
//!   move to whichever approximator serves them best (argmin-error
//!   auction), and the classifier chases the refined partition —
//!   invocation climbs until the partition stabilises.
//! * **Complementary** — a hand-down chain: `A_0` trains on everything;
//!   the samples it fails (error above the bound) are handed to `A_1`,
//!   whose rejects go to `A_2`, and so on — each approximator specialises
//!   on exactly the region its predecessors could not cover.  Labels are
//!   first-fit along the chain (lowest `k` meeting the bound; none ⇒ the
//!   reject class), exported under the paper's `mcma_complementary` key.
//!
//! `k = 1` degenerates to the paper's iterative single-approximator
//! method (safe/unsafe relabelling each round) under either scheme, which
//! is exactly the baseline the acceptance comparison wants.

// audit:deterministic — same seed + any thread count = same partition.
use crate::nn::{self, Mlp, PackedMlp};
use crate::util::rng::Rng;
use crate::util::threadpool;

use super::backprop::{one_hot_into, Loss, TrainConfig, Trainer};
use super::data::TrainData;

/// How rejected samples are (re)allocated across approximators each
/// round (see the module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Scheme {
    /// Argmin-error auction (the paper's `mcma_competitive`).
    #[default]
    Competitive,
    /// Hand-down chain: each approximator trains on its predecessors'
    /// rejects (the paper's `mcma_complementary`).
    Complementary,
}

impl Scheme {
    /// Artifact method key this scheme's nets are exported under.
    pub fn method_key(self) -> &'static str {
        match self {
            Scheme::Competitive => "mcma_competitive",
            Scheme::Complementary => "mcma_complementary",
        }
    }
}

impl std::str::FromStr for Scheme {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "competitive" | "mcma_competitive" => Ok(Scheme::Competitive),
            "complementary" | "mcma_complementary" => Ok(Scheme::Complementary),
            _ => anyhow::bail!("unknown scheme {s:?} (competitive|complementary)"),
        }
    }
}

/// Co-training hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct CotrainConfig {
    /// Number of approximators (classifier gets `k + 1` classes).
    pub k: usize,
    /// Allocation scheme (competitive auction vs complementary chain).
    pub scheme: Scheme,
    /// Maximum partition-refinement rounds.
    pub rounds: usize,
    /// Epochs for the warmup base approximator.
    pub warmup_epochs: usize,
    /// Epochs per approximator per round.
    pub approx_epochs: usize,
    /// Classifier epochs per round.
    pub clf_epochs: usize,
    /// Error bound defining the reject class.
    pub error_bound: f64,
    pub seed: u64,
    /// Worker threads for per-approximator round work (0 = all cores).
    pub threads: usize,
    /// Approximator trainer hyperparameters (loss forced to MSE).
    pub approx: TrainConfig,
    /// Classifier trainer hyperparameters (loss forced to cross-entropy).
    pub clf: TrainConfig,
    /// Convergence tolerance on round-over-round invocation delta.
    pub tol: f64,
}

impl Default for CotrainConfig {
    fn default() -> Self {
        CotrainConfig {
            k: 4,
            scheme: Scheme::Competitive,
            rounds: 6,
            warmup_epochs: 20,
            approx_epochs: 20,
            clf_epochs: 20,
            error_bound: 0.05,
            seed: 7,
            threads: 0,
            approx: TrainConfig::default(),
            clf: TrainConfig { loss: Loss::SoftmaxCrossEntropy, ..TrainConfig::default() },
            tol: 0.005,
        }
    }
}

/// Per-round trajectory (the native analogue of Fig. 9's series).
#[derive(Clone, Copy, Debug)]
pub struct RoundStats {
    pub round: usize,
    /// Fraction of samples whose BEST approximator meets the bound (the
    /// partition's potential invocation).
    pub assign_invocation: f64,
    /// Fraction the trained classifier actually routes to an approximator
    /// (the measured invocation the paper reports).
    pub clf_invocation: f64,
    /// Mean of the per-sample minimum error.
    pub mean_min_err: f64,
    /// Samples whose argmin approximator changed this round.
    pub reassigned: usize,
    /// Wall-clock of the whole round (train + error matrix + relabel +
    /// classifier), milliseconds — the number `BENCH_train.json` tracks.
    pub wall_ms: f64,
}

/// Co-training result: nets in the exact shape `MethodWeights` stores.
#[derive(Clone, Debug)]
pub struct Cotrained {
    pub classifier: Mlp,
    pub approximators: Vec<Mlp>,
    pub clf_classes: usize,
    pub history: Vec<RoundStats>,
}

/// Row-block height for the sharded whole-set forwards.  FIXED (never
/// derived from the core count) so the shard boundaries — and therefore the
/// MR-blocked kernel's tail rows inside each shard — are identical on every
/// machine: per-row results don't depend on the partition, but keeping the
/// partition machine-independent makes that invariant trivially auditable.
const ERR_BLOCK_ROWS: usize = 512;

/// Per-sample RMSE of every net over the whole set through the packed
/// kernel, sharded across the pool as `(net, row-block)` jobs — a
/// K-approximator round scores in ~1/cores of the serial wall-clock.
///
/// Bit-deterministic across thread counts: each row's forward touches only
/// its own block, blocks are fixed-size ([`ERR_BLOCK_ROWS`]), and
/// `parallel_map` preserves job order, so the assembled matrix is the same
/// no matter how jobs land on workers.
fn error_matrix(mlps: &[&Mlp], data: &TrainData, threads: usize) -> Vec<Vec<f64>> {
    if mlps.is_empty() || data.n == 0 {
        return vec![Vec::new(); mlps.len()];
    }
    let packed: Vec<PackedMlp> = mlps.iter().map(|m| PackedMlp::from_mlp(m)).collect();
    let blocks = data.n.div_ceil(ERR_BLOCK_ROWS);
    let jobs: Vec<(usize, usize)> =
        (0..mlps.len()).flat_map(|k| (0..blocks).map(move |b| (k, b))).collect();
    let shards = threadpool::parallel_map(&jobs, threads, |&(k, b)| {
        let lo = b * ERR_BLOCK_ROWS;
        let hi = ((b + 1) * ERR_BLOCK_ROWS).min(data.n);
        let rows = hi - lo;
        let pred = packed[k].forward_batch(&data.x_norm[lo * data.d_in..hi * data.d_in], rows);
        nn::per_sample_rmse(
            &pred,
            &data.y_norm[lo * data.d_out..hi * data.d_out],
            rows,
            data.d_out,
        )
    });
    // Jobs are k-major with ascending blocks, and parallel_map preserves
    // order — concatenation reassembles each row left-to-right.
    let mut mat: Vec<Vec<f64>> = (0..mlps.len()).map(|_| Vec::with_capacity(data.n)).collect();
    for (&(k, _), shard) in jobs.iter().zip(shards) {
        mat[k].extend(shard);
    }
    mat
}

/// Add small uniform noise to every weight — breaks the symmetry of the
/// cloned warmup net so the K seeds specialise apart.
fn jitter(mlp: &mut Mlp, rng: &mut Rng, amp: f64) {
    for l in &mut mlp.layers {
        for w in &mut l.w.data {
            *w += rng.uniform(-amp, amp) as f32;
        }
    }
}

/// Run the co-training loop over `data`.  `approx_topo` shapes every
/// approximator (topology-identical, as the paper trains them);
/// `clf_topo`'s final width must be `cfg.k + 1`.
pub fn cotrain(
    data: &TrainData,
    approx_topo: &[usize],
    clf_topo: &[usize],
    cfg: &CotrainConfig,
) -> Cotrained {
    assert!(cfg.k >= 1, "need at least one approximator");
    assert_eq!(
        *clf_topo.last().unwrap(),
        cfg.k + 1,
        "classifier output width must be k+1"
    );
    assert_eq!(approx_topo[0], data.d_in);
    assert_eq!(*approx_topo.last().unwrap(), data.d_out);
    let threads = if cfg.threads == 0 {
        threadpool::default_parallelism()
    } else {
        cfg.threads
    };
    let approx_cfg = TrainConfig { loss: Loss::Mse, ..cfg.approx };
    let clf_cfg = TrainConfig { loss: Loss::SoftmaxCrossEntropy, ..cfg.clf };
    let (x, y, n) = (&data.x_norm[..], &data.y_norm[..], data.n);
    let all: Vec<usize> = (0..n).collect();
    let mut rng = Rng::new(cfg.seed);

    // Warmup: one base approximator over everything.
    let mut base = Trainer::new(approx_topo, approx_cfg, cfg.seed ^ 0xBA5E);
    for _ in 0..cfg.warmup_epochs {
        base.train_epoch(x, y, data.d_in, data.d_out, &all, &mut rng);
    }

    // Error-driven seeding from the base net's per-sample error:
    // * competitive — K quantile bands, each seed approximator owns one
    //   difficulty band;
    // * complementary — a hand-down chain from the start: A_0 keeps
    //   everything, A_k starts from the hardest (K-k)/K suffix (the
    //   samples its predecessors are least likely to cover).
    let base_err = error_matrix(&[&base.mlp], data, threads)
        .pop()
        .expect("single-net error matrix");
    let mut order = all.clone();
    order.sort_by(|&a, &b| base_err[a].partial_cmp(&base_err[b]).unwrap());
    let mut groups: Vec<Vec<usize>> = match cfg.scheme {
        Scheme::Competitive => {
            let group_sz = n.div_ceil(cfg.k);
            let mut g: Vec<Vec<usize>> =
                order.chunks(group_sz.max(1)).map(|c| c.to_vec()).collect();
            g.resize(cfg.k, Vec::new());
            g
        }
        Scheme::Complementary => (0..cfg.k)
            .map(|kk| {
                if kk == 0 {
                    all.clone()
                } else {
                    order[n * kk / cfg.k..].to_vec()
                }
            })
            .collect(),
    };

    let mut trainers: Vec<Trainer> = (0..cfg.k)
        .map(|kk| {
            let mut t = base.clone();
            if kk > 0 {
                jitter(&mut t.mlp, &mut Rng::new(cfg.seed ^ (0x117E + kk as u64)), 0.05);
            }
            t
        })
        .collect();
    let mut clf = Trainer::new(clf_topo, clf_cfg, cfg.seed ^ 0xC1F);

    let mut labels = vec![cfg.k; n];
    let mut onehot: Vec<f32> = Vec::new();
    let mut history: Vec<RoundStats> = Vec::new();
    let mut prev_inv = f64::NAN;
    // Consecutive sub-tolerance invocation deltas; converged at 2 (a
    // single calm round can be coincidence while the partition churns).
    let mut calm = 0usize;

    for round in 0..cfg.rounds.max(1) {
        // audit:allow(determinism) — wall-clock feeds RoundStats reporting only.
        let round_start = std::time::Instant::now();
        // 1. Train each approximator on its partition — one pool job per
        // net, each carrying its own epoch-shuffle seed so the result is
        // deterministic regardless of thread count.
        let jobs: Vec<(Trainer, Vec<usize>, u64)> = trainers
            .into_iter()
            .zip(groups.iter())
            .map(|(t, g)| (t, g.clone(), rng.next_u64()))
            .collect();
        trainers = threadpool::parallel_map(&jobs, threads, |(t, idx, epoch_seed)| {
            let mut t = t.clone();
            let mut r = Rng::new(*epoch_seed);
            for _ in 0..cfg.approx_epochs {
                t.train_epoch(x, y, data.d_in, data.d_out, idx, &mut r);
            }
            t
        });
        // 2. Score every net on the WHOLE set: (net, fixed row-block) jobs
        // shard the K full-set forwards across the pool even when K is
        // smaller than the core count.
        let mlps: Vec<&Mlp> = trainers.iter().map(|t| &t.mlp).collect();
        let errmat = error_matrix(&mlps, data, threads);

        // 3. Relabel every sample — competitive: argmin-error auction;
        // complementary: first approximator along the chain that meets
        // the bound.  Either way a sample nobody covers becomes the
        // reject class nC, and min-error stats track the same quantity.
        let mut reassigned = 0usize;
        let mut under_bound = 0usize;
        let mut err_sum = 0.0f64;
        for i in 0..n {
            let (mut bk, mut be) = (0usize, errmat[0][i]);
            for (kk, row) in errmat.iter().enumerate().skip(1) {
                if row[i] < be {
                    be = row[i];
                    bk = kk;
                }
            }
            err_sum += be;
            let covered = be <= cfg.error_bound;
            if covered {
                under_bound += 1;
            }
            let c = match (cfg.scheme, covered) {
                (_, false) => cfg.k,
                (Scheme::Competitive, true) => bk,
                (Scheme::Complementary, true) => (0..cfg.k)
                    .find(|&kk| errmat[kk][i] <= cfg.error_bound)
                    .unwrap_or(cfg.k),
            };
            if labels[i] != c {
                reassigned += 1;
            }
            labels[i] = c;
        }
        match cfg.scheme {
            Scheme::Competitive => {
                // Groups follow the refined labels 1:1.
                for g in &mut groups {
                    g.clear();
                }
                for (i, &c) in labels.iter().enumerate() {
                    if c < cfg.k {
                        groups[c].push(i);
                    }
                }
                // Rescue starved approximators: hand an empty group the
                // hardest samples (largest min-error) so its capacity
                // attacks the uncovered region instead of idling.
                let starving: Vec<usize> =
                    (0..cfg.k).filter(|&kk| groups[kk].is_empty()).collect();
                if !starving.is_empty() {
                    let mut hardest = all.clone();
                    hardest.sort_by(|&a, &b| {
                        let ea =
                            errmat.iter().map(|r| r[a]).fold(f64::INFINITY, f64::min);
                        let eb =
                            errmat.iter().map(|r| r[b]).fold(f64::INFINITY, f64::min);
                        eb.partial_cmp(&ea).unwrap()
                    });
                    let share = (n / (4 * cfg.k)).max(8).min(n);
                    for (j, kk) in starving.into_iter().enumerate() {
                        let lo = (j * share).min(n);
                        let hi = ((j + 1) * share).min(n);
                        groups[kk] = hardest[lo..hi].to_vec();
                    }
                }
            }
            Scheme::Complementary => {
                // Hand-down chain: A_0 keeps everything; A_{k+1} trains on
                // exactly the samples A_0..A_k all fail.  Uncovered
                // samples stay in every later group — they keep being
                // handed down, which is what grows coverage round over
                // round.  No starvation rescue: an empty tail group means
                // the chain already covers everything upstream of it.
                let mut rejected = all.clone();
                for kk in 0..cfg.k {
                    groups[kk] = rejected.clone();
                    rejected.retain(|&i| errmat[kk][i] > cfg.error_bound);
                }
            }
        }

        // 4. Classifier chases the refined labels.
        one_hot_into(&labels, cfg.k + 1, &mut onehot);
        for _ in 0..cfg.clf_epochs {
            clf.train_epoch(x, &onehot, data.d_in, cfg.k + 1, &all, &mut rng);
        }

        // 5. Measured invocation under the trained classifier.
        let clf_packed = PackedMlp::from_mlp(&clf.mlp);
        let logits = clf_packed.forward_batch(x, n);
        let pred = nn::argmax_rows(&logits, n, cfg.k + 1);
        let clf_invocation =
            pred.iter().filter(|&&c| c < cfg.k).count() as f64 / n.max(1) as f64;

        let stats = RoundStats {
            round,
            assign_invocation: under_bound as f64 / n.max(1) as f64,
            clf_invocation,
            mean_min_err: err_sum / n.max(1) as f64,
            reassigned,
            wall_ms: round_start.elapsed().as_secs_f64() * 1e3,
        };
        history.push(stats);
        if round >= 1 && (clf_invocation - prev_inv).abs() < cfg.tol {
            calm += 1;
            if calm >= 2 {
                break;
            }
        } else {
            calm = 0;
        }
        prev_inv = clf_invocation;
    }

    Cotrained {
        classifier: clf.mlp,
        approximators: trainers.into_iter().map(|t| t.mlp).collect(),
        clf_classes: cfg.k + 1,
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic two-cluster workload: the output slope in x1 FLIPS sign
    /// across the x0 = 0.5 boundary, so one tiny approximator struggles to
    /// cover both clusters while two specialised ones cover them exactly.
    fn two_cluster_data(n: usize, seed: u64) -> TrainData {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(n * 2);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let x0 = rng.uniform(0.0, 1.0) as f32;
            let x1 = rng.uniform(0.0, 1.0) as f32;
            let v = if x0 < 0.5 { 0.15 + 0.3 * x1 } else { 0.85 - 0.3 * x1 };
            x.push(x0);
            x.push(x1);
            y.push(v);
        }
        TrainData { n, d_in: 2, d_out: 1, x_raw: x.clone(), x_norm: x, y_norm: y }
    }

    fn cfg(k: usize) -> CotrainConfig {
        CotrainConfig {
            k,
            scheme: Scheme::Competitive,
            rounds: 5,
            warmup_epochs: 30,
            approx_epochs: 30,
            clf_epochs: 30,
            error_bound: 0.06,
            seed: 0x2C,
            threads: 2,
            approx: TrainConfig { lr: 0.02, batch: 32, ..TrainConfig::default() },
            clf: TrainConfig {
                lr: 0.02,
                batch: 32,
                loss: Loss::SoftmaxCrossEntropy,
                ..TrainConfig::default()
            },
            tol: 0.004,
        }
    }

    /// Partition refinement converges on the 2-cluster function: K=2
    /// reaches a high-invocation stable partition, at least matching the
    /// K=1 baseline under the identical epoch budget, and the amount of
    /// reassignment shrinks as the partition settles.
    #[test]
    fn two_cluster_partition_refinement_converges() {
        let data = two_cluster_data(600, 0xDA7A);
        let k2 = cotrain(&data, &[2, 4, 1], &[2, 8, 3], &cfg(2));
        let k1 = cotrain(&data, &[2, 4, 1], &[2, 8, 2], &cfg(1));

        assert_eq!(k2.approximators.len(), 2);
        assert_eq!(k2.clf_classes, 3);
        assert!(!k2.history.is_empty() && k2.history.len() <= 5);

        let last2 = k2.history.last().unwrap();
        let last1 = k1.history.last().unwrap();
        for h in k2.history.iter().chain(&k1.history) {
            assert!((0.0..=1.0).contains(&h.assign_invocation));
            assert!((0.0..=1.0).contains(&h.clf_invocation));
            assert!(h.mean_min_err.is_finite());
        }
        // Two specialised approximators cover (nearly) everything…
        assert!(
            last2.assign_invocation >= 0.75,
            "K=2 assignment invocation too low: {}",
            last2.assign_invocation
        );
        // …and never lose to the single-net baseline (same budget).
        assert!(
            last2.assign_invocation >= last1.assign_invocation - 0.05,
            "K=2 ({}) fell behind K=1 ({})",
            last2.assign_invocation,
            last1.assign_invocation
        );
        // The classifier tracks the partition (boundary is a single axis
        // split — easily learnable).
        assert!(
            last2.clf_invocation >= 0.5,
            "classifier invocation too low: {}",
            last2.clf_invocation
        );
        // Refinement settles: the last round moves fewer samples than the
        // first post-seed round did.
        let first = &k2.history[0];
        assert!(
            last2.reassigned <= first.reassigned,
            "partition still churning: {} -> {}",
            first.reassigned,
            last2.reassigned
        );
    }

    /// Thread count must not change the result: per-job RNG streams make
    /// the round loop deterministic, so 1-thread and 4-thread runs agree.
    #[test]
    fn cotrain_deterministic_across_thread_counts() {
        let data = two_cluster_data(200, 0x5EED);
        let mut a_cfg = cfg(2);
        a_cfg.rounds = 2;
        a_cfg.warmup_epochs = 5;
        a_cfg.approx_epochs = 5;
        a_cfg.clf_epochs = 5;
        let mut b_cfg = a_cfg;
        a_cfg.threads = 1;
        b_cfg.threads = 4;
        let a = cotrain(&data, &[2, 4, 1], &[2, 6, 3], &a_cfg);
        let b = cotrain(&data, &[2, 4, 1], &[2, 6, 3], &b_cfg);
        assert_eq!(a.classifier, b.classifier, "classifier depends on thread count");
        assert_eq!(a.approximators, b.approximators, "approximators depend on thread count");
        assert_eq!(a.history.len(), b.history.len());
    }

    /// Complementary K=2 convergence on the two-cluster workload: the
    /// chain (A_0 on everything, A_1 on A_0's rejects) reaches a
    /// high-coverage stable allocation, the classifier tracks the
    /// first-fit labels, and churn settles.
    #[test]
    fn complementary_chain_converges_k2() {
        let data = two_cluster_data(600, 0xDA7A);
        let mut c = cfg(2);
        c.scheme = Scheme::Complementary;
        let out = cotrain(&data, &[2, 4, 1], &[2, 8, 3], &c);
        assert_eq!(out.approximators.len(), 2);
        assert_eq!(out.clf_classes, 3);
        assert!(!out.history.is_empty() && out.history.len() <= 5);
        for h in &out.history {
            assert!((0.0..=1.0).contains(&h.assign_invocation));
            assert!((0.0..=1.0).contains(&h.clf_invocation));
            assert!(h.mean_min_err.is_finite());
        }
        let last = out.history.last().unwrap();
        assert!(
            last.assign_invocation >= 0.75,
            "complementary chain coverage too low: {}",
            last.assign_invocation
        );
        assert!(
            last.clf_invocation >= 0.5,
            "classifier lost the chain labels: {}",
            last.clf_invocation
        );
        let first = &out.history[0];
        assert!(
            last.reassigned <= first.reassigned,
            "chain allocation still churning: {} -> {}",
            first.reassigned,
            last.reassigned
        );
    }

    /// The complementary loop is thread-count deterministic too (same
    /// per-job RNG stream discipline as the competitive scheme).
    #[test]
    fn complementary_deterministic_across_thread_counts() {
        let data = two_cluster_data(200, 0x5EED);
        let mut a_cfg = cfg(2);
        a_cfg.scheme = Scheme::Complementary;
        a_cfg.rounds = 2;
        a_cfg.warmup_epochs = 5;
        a_cfg.approx_epochs = 5;
        a_cfg.clf_epochs = 5;
        let mut b_cfg = a_cfg;
        a_cfg.threads = 1;
        b_cfg.threads = 4;
        let a = cotrain(&data, &[2, 4, 1], &[2, 6, 3], &a_cfg);
        let b = cotrain(&data, &[2, 4, 1], &[2, 6, 3], &b_cfg);
        assert_eq!(a.classifier, b.classifier);
        assert_eq!(a.approximators, b.approximators);
    }

    /// The sharded error matrix is bitwise the serial per-net computation,
    /// across thread counts and ragged block boundaries (n = 1300 is two
    /// full 512-row blocks plus a 276-row tail, per net).
    #[test]
    fn error_matrix_sharding_is_bitwise_deterministic() {
        let data = two_cluster_data(1300, 0xE44);
        let mut rng = Rng::new(0xE45);
        let nets: Vec<Mlp> = (0..3)
            .map(|_| super::super::backprop::xavier_mlp(&[2, 5, 1], &mut rng))
            .collect();
        let refs: Vec<&Mlp> = nets.iter().collect();
        // Serial reference: one whole-set packed forward per net.
        let serial: Vec<Vec<f64>> = nets
            .iter()
            .map(|m| {
                let pred = PackedMlp::from_mlp(m).forward_batch(&data.x_norm, data.n);
                nn::per_sample_rmse(&pred, &data.y_norm, data.n, data.d_out)
            })
            .collect();
        for threads in [1usize, 3, 4] {
            let mat = error_matrix(&refs, &data, threads);
            assert_eq!(mat, serial, "threads={threads}");
        }
        // Degenerate shapes don't panic and keep the row-per-net contract.
        assert_eq!(error_matrix(&[], &data, 4).len(), 0);
        let empty = TrainData {
            n: 0,
            d_in: 2,
            d_out: 1,
            x_raw: vec![],
            x_norm: vec![],
            y_norm: vec![],
        };
        assert_eq!(error_matrix(&refs, &empty, 4), vec![Vec::new(); 3]);
    }

    /// Round wall-clock lands in the stats and is sane.
    #[test]
    fn round_stats_carry_wall_clock() {
        let data = two_cluster_data(150, 3);
        let mut c = cfg(1);
        c.rounds = 2;
        c.warmup_epochs = 2;
        c.approx_epochs = 2;
        c.clf_epochs = 2;
        let out = cotrain(&data, &[2, 4, 1], &[2, 6, 2], &c);
        assert!(out.history.iter().all(|h| h.wall_ms.is_finite() && h.wall_ms >= 0.0));
    }

    #[test]
    fn scheme_keys_and_parse() {
        use std::str::FromStr;
        assert_eq!(Scheme::Competitive.method_key(), "mcma_competitive");
        assert_eq!(Scheme::Complementary.method_key(), "mcma_complementary");
        assert_eq!(Scheme::from_str("competitive").unwrap(), Scheme::Competitive);
        assert_eq!(
            Scheme::from_str("mcma_complementary").unwrap(),
            Scheme::Complementary
        );
        assert!(Scheme::from_str("auction").is_err());
        assert_eq!(Scheme::default(), Scheme::Competitive);
    }

    /// `k = 1` degenerates to the iterative safe/unsafe method: a binary
    /// classifier and exactly one approximator, in `MethodWeights` shape.
    #[test]
    fn k1_is_binary_baseline() {
        let data = two_cluster_data(150, 3);
        let mut c = cfg(1);
        c.rounds = 2;
        c.warmup_epochs = 5;
        c.approx_epochs = 5;
        c.clf_epochs = 5;
        let out = cotrain(&data, &[2, 4, 1], &[2, 6, 2], &c);
        assert_eq!(out.approximators.len(), 1);
        assert_eq!(out.clf_classes, 2);
        assert_eq!(out.classifier.n_out(), 2);
    }
}
