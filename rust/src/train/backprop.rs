//! Minibatch backprop trainer for the crate's MLP topology (sigmoid hidden
//! layers, linear output — the NPU PE activation scheme `nn` serves).
//!
//! The forward pass of every minibatch runs through the tiled packed-GEMM
//! kernel (`nn::gemm::PackedMlp`): the trainer re-packs the current weights
//! into the packed net's existing buffers after each optimizer step
//! (`PackedMlp::repack_from`, no allocation) and collects per-layer
//! activation panels with `forward_collect` — the same register-blocked
//! micro-kernels the serving path uses, so training throughput rides the
//! SIMD dispatch for free.  The backward pass is the classic dense chain:
//!
//! ```text
//! δ_L = ∂loss/∂z_L                    (MSE: 2(a-y)/(nk); CE: softmax(a)-y)
//! δ_l = (δ_{l+1} W_{l+1}ᵀ) ⊙ a_l(1-a_l)        (sigmoid derivative)
//! ∂W_l = a_{l-1}ᵀ δ_l      ∂b_l = Σ_rows δ_l
//! ```
//!
//! with Adam (bias-corrected) updates.  Both losses drive the same
//! machinery: `Mse` trains approximators on normalised targets,
//! `SoftmaxCrossEntropy` trains the multiclass classifier on one-hot
//! labels (linear logits at serve time match: routing argmaxes raw logits,
//! and softmax is monotone in them).
//!
//! The backward pass is kernelized like the forward: both delta GEMMs run
//! through the same dispatched MR x NR micro-kernels
//! ([`crate::nn::gemm_tiled`]).  `∂W = a_prevᵀ δ` transposes the cached
//! activation panel and tile-packs δ (M = fan_in, K = samples,
//! N = fan_out); `δ_prev = δ Wᵀ` tile-packs the transposed weights
//! (M = samples, K = fan_out, N = fan_in) and applies the sigmoid
//! derivative elementwise afterwards.  Accumulation order is ascending-k
//! in every variant — identical to the scalar triple loops this replaced —
//! so the forced-scalar kernel is bitwise the naive backward
//! (`scalar_backward_matches_naive_bitwise` below), SIMD kernels differ
//! only by FMA contraction, and gradients stay bit-deterministic across
//! thread counts (nothing here depends on the pool).

// audit:deterministic — same seed must give bit-identical weights.
use crate::nn::{
    gemm_tiled, pack_tiles, pack_tiles_transposed, transpose_into, Kernel, Layer, Matrix, Mlp,
    PackedMlp,
};
use crate::util::rng::Rng;

/// Training objective.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Loss {
    /// Mean squared error over all outputs (approximators).
    Mse,
    /// Softmax cross-entropy against one-hot rows (the classifier).
    SoftmaxCrossEntropy,
}

/// Optimizer + minibatch hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// L2 weight decay added to the weight gradient (not biases).
    pub l2: f32,
    pub batch: usize,
    pub loss: Loss,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            lr: 0.01,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            l2: 0.0,
            batch: 32,
            loss: Loss::Mse,
        }
    }
}

/// Xavier/Glorot-uniform MLP init over `topo = [in, hidden..., out]`.
pub fn xavier_mlp(topo: &[usize], rng: &mut Rng) -> Mlp {
    assert!(topo.len() >= 2, "topology needs at least in+out");
    let layers: Vec<Layer> = topo
        .windows(2)
        .map(|w| {
            let (fi, fo) = (w[0], w[1]);
            let amp = (6.0 / (fi + fo) as f64).sqrt();
            Layer {
                w: Matrix::new(
                    fi,
                    fo,
                    (0..fi * fo).map(|_| rng.uniform(-amp, amp) as f32).collect(),
                ),
                b: vec![0.0; fo],
            }
        })
        .collect();
    Mlp::new(layers)
}

/// Write one-hot rows for `labels` (values in `0..k`) into `out`
/// (`(n, k)` row-major, resized in place).
pub fn one_hot_into(labels: &[usize], k: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(labels.len() * k, 0.0);
    for (i, &c) in labels.iter().enumerate() {
        assert!(c < k, "label {c} out of range for {k} classes");
        out[i * k + c] = 1.0;
    }
}

/// Adam-optimised minibatch trainer owning one [`Mlp`].
#[derive(Clone, Debug)]
pub struct Trainer {
    pub mlp: Mlp,
    pub cfg: TrainConfig,
    /// Packed twin of `mlp` — re-packed (no allocation) after every step;
    /// all batch forwards go through its tiled kernel.
    packed: PackedMlp,
    /// Adam first/second moments, per layer, laid out `[w..., b...]`.
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Adam timestep.
    t: u64,
    /// Per-layer gradient buffers, same `[w..., b...]` layout (reused).
    g: Vec<Vec<f32>>,
    /// Per-layer post-activation panels from the last forward (reused).
    acts: Vec<Vec<f32>>,
    /// Backprop delta ping-pong panels (reused).
    delta: Vec<f32>,
    delta_prev: Vec<f32>,
    /// Backward-GEMM scratch (reused): transposed activation panel,
    /// tile-packed delta panel, tile-packed transposed weights.
    at: Vec<f32>,
    dtiles: Vec<f32>,
    wt_tiles: Vec<f32>,
    /// Minibatch gather buffers for `train_epoch` (reused).
    bx: Vec<f32>,
    by: Vec<f32>,
    order: Vec<usize>,
}

impl Trainer {
    pub fn new(topo: &[usize], cfg: TrainConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        Self::from_mlp(xavier_mlp(topo, &mut rng), cfg)
    }

    pub fn from_mlp(mlp: Mlp, cfg: TrainConfig) -> Self {
        let shapes: Vec<usize> =
            mlp.layers.iter().map(|l| l.w.data.len() + l.b.len()).collect();
        let zeros = |s: &[usize]| s.iter().map(|&n| vec![0.0f32; n]).collect::<Vec<_>>();
        let packed = PackedMlp::from_mlp(&mlp);
        Trainer {
            packed,
            m: zeros(&shapes),
            v: zeros(&shapes),
            g: zeros(&shapes),
            t: 0,
            acts: Vec::new(),
            delta: Vec::new(),
            delta_prev: Vec::new(),
            at: Vec::new(),
            dtiles: Vec::new(),
            wt_tiles: Vec::new(),
            bx: Vec::new(),
            by: Vec::new(),
            order: Vec::new(),
            mlp,
            cfg,
        }
    }

    /// Force both the forward pack and the backward delta GEMMs onto a
    /// specific micro-kernel (parity tests, ablations).  Panics if the
    /// kernel is not runnable on this CPU.
    pub fn with_kernel(mut self, kernel: Kernel) -> Self {
        self.packed.set_kernel(kernel);
        self
    }

    /// The micro-kernel every forward AND backward GEMM runs through.
    pub fn kernel(&self) -> Kernel {
        self.packed.kernel()
    }

    pub fn n_in(&self) -> usize {
        self.mlp.n_in()
    }

    pub fn n_out(&self) -> usize {
        self.mlp.n_out()
    }

    /// Forward `(n, n_in)` through the packed kernel and return the loss
    /// against `y` (`(n, n_out)`); no gradient, no update.
    pub fn loss_of(&mut self, x: &[f32], y: &[f32], n: usize) -> f64 {
        self.packed.repack_from(&self.mlp);
        self.packed.forward_collect(x, n, &mut self.acts);
        let out = self.acts.last().expect("mlp has layers");
        loss_value(self.cfg.loss, out, y, n, self.mlp.n_out())
    }

    /// One minibatch step: forward (packed kernel), backward, Adam update.
    /// Returns the pre-update loss.
    pub fn train_step(&mut self, x: &[f32], y: &[f32], n: usize) -> f64 {
        let loss = self.grads(x, y, n);
        self.adam_apply();
        loss
    }

    /// One epoch over the rows of `x`/`y` selected by `idx`, in a freshly
    /// shuffled order, chunked into `cfg.batch`-row minibatches.  Returns
    /// the mean minibatch loss.
    pub fn train_epoch(
        &mut self,
        x: &[f32],
        y: &[f32],
        d_in: usize,
        d_out: usize,
        idx: &[usize],
        rng: &mut Rng,
    ) -> f64 {
        assert_eq!(d_in, self.n_in());
        assert_eq!(d_out, self.n_out());
        if idx.is_empty() {
            return 0.0;
        }
        self.order.clear();
        self.order.extend_from_slice(idx);
        let mut order = std::mem::take(&mut self.order);
        rng.shuffle(&mut order);
        let bsz = self.cfg.batch.max(1);
        let mut loss_sum = 0.0;
        let mut batches = 0.0;
        for chunk in order.chunks(bsz) {
            let mut bx = std::mem::take(&mut self.bx);
            let mut by = std::mem::take(&mut self.by);
            bx.clear();
            by.clear();
            for &i in chunk {
                bx.extend_from_slice(&x[i * d_in..(i + 1) * d_in]);
                by.extend_from_slice(&y[i * d_out..(i + 1) * d_out]);
            }
            loss_sum += self.train_step(&bx, &by, chunk.len());
            batches += 1.0;
            self.bx = bx;
            self.by = by;
        }
        self.order = order;
        loss_sum / batches
    }

    /// Forward + backward: fills `self.g` with per-layer gradients in the
    /// `[w..., b...]` layout and returns the loss.  No parameter update.
    /// Public so parity tests and the `BENCH_train.json` recorder can time
    /// forward+backward without touching the optimizer state.
    pub fn grads(&mut self, x: &[f32], y: &[f32], n: usize) -> f64 {
        let d_out = self.mlp.n_out();
        assert_eq!(x.len(), n * self.mlp.n_in(), "x size mismatch");
        assert_eq!(y.len(), n * d_out, "y size mismatch");
        self.packed.repack_from(&self.mlp);
        self.packed.forward_collect(x, n, &mut self.acts);
        let last = self.mlp.layers.len() - 1;
        let out = &self.acts[last];
        let loss = loss_value(self.cfg.loss, out, y, n, d_out);

        // Output delta = ∂loss/∂z_L (linear output layer: z = a).
        self.delta.clear();
        self.delta.resize(n * d_out, 0.0);
        match self.cfg.loss {
            Loss::Mse => {
                let scale = 2.0 / (n * d_out) as f32;
                for (d, (&a, &t)) in self.delta.iter_mut().zip(out.iter().zip(y)) {
                    *d = scale * (a - t);
                }
            }
            Loss::SoftmaxCrossEntropy => {
                let inv_n = 1.0 / n as f32;
                for i in 0..n {
                    let row = &out[i * d_out..(i + 1) * d_out];
                    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                    let denom: f32 = row.iter().map(|&v| (v - max).exp()).sum();
                    for c in 0..d_out {
                        let p = (row[c] - max).exp() / denom;
                        self.delta[i * d_out + c] = (p - y[i * d_out + c]) * inv_n;
                    }
                }
            }
        }

        let kernel = self.packed.kernel();
        for l in (0..self.mlp.layers.len()).rev() {
            let layer = &self.mlp.layers[l];
            let (fi, fo) = (layer.w.rows, layer.w.cols);
            let a_prev: &[f32] = if l == 0 { x } else { &self.acts[l - 1] };
            let delta = &self.delta[..n * fo];
            let g = &mut self.g[l];
            let (gw, gb) = g.split_at_mut(fi * fo);
            // ∂W = a_prevᵀ δ through the same MR x NR micro-kernels as the
            // forward: transpose the cached activation panel, tile-pack δ,
            // run the bare GEMM (M = fan_in, K = samples, N = fan_out).
            // Ascending-k accumulation = ascending samples, the order the
            // scalar triple loop used.
            transpose_into(a_prev, n, fi, &mut self.at);
            pack_tiles(delta, n, fo, &mut self.dtiles);
            gemm_tiled(kernel, &self.at, fi, n, &self.dtiles, fo, gw);
            // ∂b = column sums of δ (O(n·fo), stays scalar).
            gb.fill(0.0);
            for i in 0..n {
                let drow = &delta[i * fo..(i + 1) * fo];
                for c in 0..fo {
                    gb[c] += drow[c];
                }
            }
            if self.cfg.l2 > 0.0 {
                for (gv, &wv) in gw.iter_mut().zip(&layer.w.data) {
                    *gv += self.cfg.l2 * wv;
                }
            }
            // δ_{l-1} = (δ Wᵀ) ⊙ σ'(a_{l-1}), using the pre-update W:
            // tile-pack Wᵀ (contraction over fan_out) for the same kernel
            // (M = samples, K = fan_out, N = fan_in), then apply the
            // sigmoid derivative elementwise.
            if l > 0 {
                pack_tiles_transposed(&layer.w.data, fi, fo, &mut self.wt_tiles);
                self.delta_prev.clear();
                self.delta_prev.resize(n * fi, 0.0);
                gemm_tiled(kernel, delta, n, fo, &self.wt_tiles, fi, &mut self.delta_prev);
                for (p, &a) in self.delta_prev.iter_mut().zip(&a_prev[..n * fi]) {
                    *p *= a * (1.0 - a);
                }
                std::mem::swap(&mut self.delta, &mut self.delta_prev);
            }
        }
        loss
    }

    /// Bias-corrected Adam over every layer's `[w..., b...]` vector.
    fn adam_apply(&mut self) {
        self.t += 1;
        let TrainConfig { lr, beta1, beta2, eps, .. } = self.cfg;
        let corr1 = 1.0 - beta1.powi(self.t.min(1 << 30) as i32);
        let corr2 = 1.0 - beta2.powi(self.t.min(1 << 30) as i32);
        let step = lr * corr2.sqrt() / corr1;
        for (l, layer) in self.mlp.layers.iter_mut().enumerate() {
            let g = &self.g[l];
            let m = &mut self.m[l];
            let v = &mut self.v[l];
            let params = layer.w.data.iter_mut().chain(layer.b.iter_mut());
            for (j, p) in params.enumerate() {
                let gj = g[j];
                m[j] = beta1 * m[j] + (1.0 - beta1) * gj;
                v[j] = beta2 * v[j] + (1.0 - beta2) * gj * gj;
                *p -= step * m[j] / (v[j].sqrt() + eps);
            }
        }
    }
}

/// Loss over an output panel (f64 accumulation).
fn loss_value(loss: Loss, out: &[f32], y: &[f32], n: usize, k: usize) -> f64 {
    match loss {
        Loss::Mse => {
            let mut s = 0.0f64;
            for (&a, &t) in out.iter().zip(y) {
                let d = (a - t) as f64;
                s += d * d;
            }
            s / (n * k) as f64
        }
        Loss::SoftmaxCrossEntropy => {
            let mut s = 0.0f64;
            for i in 0..n {
                let row = &out[i * k..(i + 1) * k];
                let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
                let lse: f64 =
                    max + row.iter().map(|&v| ((v as f64) - max).exp()).sum::<f64>().ln();
                let dot: f64 = row
                    .iter()
                    .zip(&y[i * k..(i + 1) * k])
                    .map(|(&a, &t)| a as f64 * t as f64)
                    .sum();
                s += lse - dot;
            }
            s / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fd_data(loss: Loss) -> (Trainer, Vec<f32>, Vec<f32>, usize) {
        let mut rng = Rng::new(0x6E4D);
        let topo = [2usize, 3, 2];
        let t = Trainer::new(&topo, TrainConfig { loss, l2: 0.0, ..Default::default() }, 42);
        let n = 6usize;
        let x: Vec<f32> = (0..n * 2).map(|_| rng.uniform(-1.5, 1.5) as f32).collect();
        let y: Vec<f32> = match loss {
            Loss::Mse => (0..n * 2).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
            Loss::SoftmaxCrossEntropy => {
                let labels: Vec<usize> = (0..n).map(|_| rng.below(2) as usize).collect();
                let mut oh = Vec::new();
                one_hot_into(&labels, 2, &mut oh);
                oh
            }
        };
        (t, x, y, n)
    }

    /// Analytic gradients match central finite differences of the loss for
    /// BOTH objectives, on every weight and bias of a tiny MLP.
    #[test]
    fn gradients_match_finite_differences() {
        for loss in [Loss::Mse, Loss::SoftmaxCrossEntropy] {
            let (mut t, x, y, n) = fd_data(loss);
            let _ = t.grads(&x, &y, n);
            let analytic = t.g.clone();
            let eps = 5e-3f32;
            for l in 0..t.mlp.layers.len() {
                let nw = t.mlp.layers[l].w.data.len();
                let nparam = nw + t.mlp.layers[l].b.len();
                for j in 0..nparam {
                    let read = |t: &Trainer| {
                        let layer = &t.mlp.layers[l];
                        if j < nw { layer.w.data[j] } else { layer.b[j - nw] }
                    };
                    let write = |t: &mut Trainer, v: f32| {
                        let layer = &mut t.mlp.layers[l];
                        if j < nw {
                            layer.w.data[j] = v;
                        } else {
                            layer.b[j - nw] = v;
                        }
                    };
                    let orig = read(&t);
                    write(&mut t, orig + eps);
                    let hi = t.loss_of(&x, &y, n);
                    write(&mut t, orig - eps);
                    let lo = t.loss_of(&x, &y, n);
                    write(&mut t, orig);
                    let fd = ((hi - lo) / (2.0 * eps as f64)) as f32;
                    let an = analytic[l][j];
                    assert!(
                        (fd - an).abs() <= 2e-3 + 0.03 * an.abs(),
                        "{loss:?} layer {l} param {j}: fd {fd} vs analytic {an}"
                    );
                }
            }
        }
    }

    /// Adam on a pure linear layer recovers a linear map almost exactly.
    #[test]
    fn linear_regression_converges() {
        let mut rng = Rng::new(0x11EA);
        let n = 64usize;
        let x: Vec<f32> = (0..n * 2).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let y: Vec<f32> =
            (0..n).map(|i| 0.3 * x[i * 2] - 0.2 * x[i * 2 + 1] + 0.1).collect();
        let mut t = Trainer::new(
            &[2, 1],
            TrainConfig { lr: 0.05, batch: 16, ..Default::default() },
            3,
        );
        let idx: Vec<usize> = (0..n).collect();
        let first = t.loss_of(&x, &y, n);
        for _ in 0..300 {
            t.train_epoch(&x, &y, 2, 1, &idx, &mut rng);
        }
        let last = t.loss_of(&x, &y, n);
        assert!(last < 1e-4, "did not converge: {first} -> {last}");
        assert!((t.mlp.layers[0].w.data[0] - 0.3).abs() < 0.02);
        assert!((t.mlp.layers[0].b[0] - 0.1).abs() < 0.02);
    }

    /// Cross-entropy training separates a trivially separable 2-class set
    /// (argmax accuracy, the serving-time routing rule).
    #[test]
    fn classifier_learns_separable_classes() {
        let mut rng = Rng::new(0xC1A5);
        let n = 200usize;
        let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let labels: Vec<usize> = x.iter().map(|&v| usize::from(v > 0.0)).collect();
        let mut y = Vec::new();
        one_hot_into(&labels, 2, &mut y);
        let mut t = Trainer::new(
            &[1, 8, 2],
            TrainConfig { loss: Loss::SoftmaxCrossEntropy, lr: 0.05, ..Default::default() },
            9,
        );
        let idx: Vec<usize> = (0..n).collect();
        for _ in 0..60 {
            t.train_epoch(&x, &y, 1, 2, &idx, &mut rng);
        }
        let pred = t.mlp.classify_batch(&x, n);
        let acc =
            pred.iter().zip(&labels).filter(|(p, l)| p == l).count() as f64 / n as f64;
        assert!(acc > 0.95, "classifier accuracy {acc}");
    }

    /// The pre-kernelization scalar backward — per-element ascending-sample
    /// accumulation for ∂W, ascending-fan-out dot products for δ_prev —
    /// reconstructed as an oracle from the trainer's cached activation
    /// panels (filled by the `grads` call under test).
    fn naive_backward(t: &Trainer, x: &[f32], y: &[f32], n: usize) -> Vec<Vec<f32>> {
        let mlp = &t.mlp;
        let d_out = mlp.n_out();
        let out = &t.acts[mlp.layers.len() - 1];
        let mut delta = vec![0.0f32; n * d_out];
        match t.cfg.loss {
            Loss::Mse => {
                let scale = 2.0 / (n * d_out) as f32;
                for (d, (&a, &tv)) in delta.iter_mut().zip(out.iter().zip(y)) {
                    *d = scale * (a - tv);
                }
            }
            Loss::SoftmaxCrossEntropy => {
                let inv_n = 1.0 / n as f32;
                for i in 0..n {
                    let row = &out[i * d_out..(i + 1) * d_out];
                    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                    let denom: f32 = row.iter().map(|&v| (v - max).exp()).sum();
                    for c in 0..d_out {
                        let p = (row[c] - max).exp() / denom;
                        delta[i * d_out + c] = (p - y[i * d_out + c]) * inv_n;
                    }
                }
            }
        }
        let mut g: Vec<Vec<f32>> = mlp
            .layers
            .iter()
            .map(|l| vec![0.0f32; l.w.data.len() + l.b.len()])
            .collect();
        for l in (0..mlp.layers.len()).rev() {
            let layer = &mlp.layers[l];
            let (fi, fo) = (layer.w.rows, layer.w.cols);
            let a_prev: &[f32] = if l == 0 { x } else { &t.acts[l - 1] };
            let (gw, gb) = g[l].split_at_mut(fi * fo);
            for r in 0..fi {
                for c in 0..fo {
                    let mut acc = 0.0f32;
                    for i in 0..n {
                        acc += a_prev[i * fi + r] * delta[i * fo + c];
                    }
                    gw[r * fo + c] = acc;
                }
            }
            for i in 0..n {
                for c in 0..fo {
                    gb[c] += delta[i * fo + c];
                }
            }
            if t.cfg.l2 > 0.0 {
                for (gv, &wv) in gw.iter_mut().zip(&layer.w.data) {
                    *gv += t.cfg.l2 * wv;
                }
            }
            if l > 0 {
                let mut prev = vec![0.0f32; n * fi];
                for i in 0..n {
                    for r in 0..fi {
                        let mut s = 0.0f32;
                        for c in 0..fo {
                            s += delta[i * fo + c] * layer.w.data[r * fo + c];
                        }
                        let a = a_prev[i * fi + r];
                        prev[i * fi + r] = s * (a * (1.0 - a));
                    }
                }
                delta = prev;
            }
        }
        g
    }

    fn parity_case(loss: Loss, topo: &[usize], n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let d_in = topo[0];
        let d_out = *topo.last().unwrap();
        let x: Vec<f32> = (0..n * d_in).map(|_| rng.uniform(-1.5, 1.5) as f32).collect();
        let y: Vec<f32> = match loss {
            Loss::Mse => (0..n * d_out).map(|_| rng.uniform(-1.0, 1.0) as f32).collect(),
            Loss::SoftmaxCrossEntropy => {
                let labels: Vec<usize> =
                    (0..n).map(|_| rng.below(d_out as u64) as usize).collect();
                let mut oh = Vec::new();
                one_hot_into(&labels, d_out, &mut oh);
                oh
            }
        };
        (x, y)
    }

    /// With the scalar micro-kernel, the tiled backward is BITWISE the
    /// naive scalar backward — accumulation order is unchanged, so
    /// kernelization cannot drift the training trajectory.  Shapes straddle
    /// the MR=4 / NR=8 boundaries (tail rows, partial tiles) and both
    /// losses / the L2 path are exercised.
    #[test]
    fn scalar_backward_matches_naive_bitwise() {
        for (loss, l2) in [(Loss::Mse, 0.0f32), (Loss::Mse, 1e-3), (Loss::SoftmaxCrossEntropy, 0.0)]
        {
            for (topo, n) in [(&[5usize, 9, 3][..], 7usize), (&[2, 3, 2][..], 6), (&[4, 8, 8, 2][..], 9)]
            {
                let cfg = TrainConfig { loss, l2, ..Default::default() };
                let mut t = Trainer::new(topo, cfg, 0xBACC).with_kernel(Kernel::Scalar);
                let (x, y) = parity_case(loss, topo, n, 0x5EED ^ n as u64);
                let _ = t.grads(&x, &y, n);
                let naive = naive_backward(&t, &x, &y, n);
                assert_eq!(t.g, naive, "{loss:?} l2={l2} topo={topo:?} n={n}");
            }
        }
    }

    /// SIMD backward kernels agree with the forced-scalar backward within a
    /// bound derived from the layer chain: the forward panels agree to
    /// ~1e-5 (pinned by `nn::gemm` parity tests), the backward GEMMs add
    /// only FMA contraction (≤ ε per k-step), and each layer multiplies by
    /// bounded activations (|a(1-a)| ≤ 1/4) — so per-element error stays
    /// within a small multiple of the gradient scale per layer hop.
    #[test]
    fn simd_backward_within_derived_bounds() {
        use crate::util::prop;
        for k in [Kernel::Avx2, Kernel::Neon] {
            if !k.available() {
                continue;
            }
            let topo = [6usize, 16, 9, 2];
            let n = 13;
            let cfg = TrainConfig::default();
            let mut scalar = Trainer::new(&topo, cfg, 0x51BD).with_kernel(Kernel::Scalar);
            let mut fast = Trainer::new(&topo, cfg, 0x51BD).with_kernel(k);
            let (x, y) = parity_case(Loss::Mse, &topo, n, 0xD1FF);
            let _ = scalar.grads(&x, &y, n);
            let _ = fast.grads(&x, &y, n);
            for l in 0..topo.len() - 1 {
                // Layer-propagated bound: gradient magnitudes shrink with
                // depth, so scale the tolerance to this layer's own range.
                let scale = scalar.g[l].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let atol = 1e-3 * scale.max(1e-6);
                prop::assert_close(&fast.g[l], &scalar.g[l], atol, 1e-3)
                    .unwrap_or_else(|e| panic!("{} layer {l}: {e}", k.name()));
            }
        }
    }

    /// Along the ACTUAL optimization trajectory, the scalar-kernel
    /// gradients equal the naive backward bitwise at every step — by
    /// induction the whole Adam weight trajectory is bitwise the
    /// pre-kernelization one.
    #[test]
    fn adam_trajectory_bitwise_vs_naive() {
        let topo = [3usize, 7, 2];
        let n = 6;
        let mut t =
            Trainer::new(&topo, TrainConfig::default(), 0xADA3).with_kernel(Kernel::Scalar);
        let (x, y) = parity_case(Loss::Mse, &topo, n, 0x7A7A);
        for step in 0..5 {
            let _ = t.grads(&x, &y, n);
            let naive = naive_backward(&t, &x, &y, n);
            assert_eq!(t.g, naive, "gradient diverged from naive at step {step}");
            t.adam_apply();
            assert!(t.mlp.layers.iter().all(|l| l.w.data.iter().all(|v| v.is_finite())));
        }
    }

    #[test]
    fn one_hot_layout() {
        let mut out = Vec::new();
        one_hot_into(&[1, 0, 2], 3, &mut out);
        assert_eq!(out, vec![0.0, 1.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn xavier_topology_and_scale() {
        let mut rng = Rng::new(5);
        let m = xavier_mlp(&[4, 7, 2], &mut rng);
        assert_eq!(m.topology(), vec![4, 7, 2]);
        let amp = (6.0f64 / 11.0).sqrt() as f32;
        assert!(m.layers[0].w.data.iter().all(|w| w.abs() <= amp + 1e-6));
        assert!(m.layers[0].b.iter().all(|&b| b == 0.0));
    }
}
