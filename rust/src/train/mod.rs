//! Native co-training subsystem — the paper's *training* contribution,
//! in-repo (no Python side-channel).
//!
//! `python/compile/train.py` used to be the only way to produce the MCMW
//! weight artifacts this crate serves; that capped scenario diversity at
//! whatever was pre-exported.  This module closes the loop:
//!
//! * [`backprop`] — minibatch SGD/Adam backprop for the crate's MLP
//!   topology, with every batch forward routed through the tiled packed
//!   GEMM kernel (`nn::gemm`);
//! * [`cotrain`] — the paper's co-training loop: seed K topology-identical
//!   approximators on an error-driven partition, reallocate samples every
//!   round (competitive argmin auction or the complementary hand-down
//!   chain), retrain the multiclass classifier on the refined labels
//!   until invocation converges;
//! * [`data`] — re-exports of the workload-source synthesis
//!   (`crate::workload`): registered benchmark generators AND
//!   user-supplied CSV/TSV tables, including manifest derivation when no
//!   Python-built artifact tree exists;
//! * [`train_bench`] — the `mcma train` entrypoint: co-train K
//!   approximators AND a K=1 baseline under the same epoch budget, measure
//!   both through the real serving dispatcher on a held-out set, and
//!   export MCMW/MCQW/MCMD artifacts plus a manifest that `ModelBank` and
//!   every eval driver load unchanged — from a registered benchmark
//!   (`--bench`) or from nothing but a data file (`--data foo.csv`).

pub mod backprop;
pub mod cotrain;
pub mod data;

pub use backprop::{one_hot_into, xavier_mlp, Loss, TrainConfig, Trainer};
pub use cotrain::{cotrain, Cotrained, CotrainConfig, RoundStats, Scheme};
pub use data::{derive_bench_manifest, sample_data, TrainData};

// audit:deterministic — artifact trees must be reproducible run to run.
// audit:allow(determinism) — serializers sort HashMap keys before writing.
use std::collections::HashMap;
use std::path::PathBuf;

use crate::bench_harness::{pct, Table};
use crate::config::{ExecMode, Method};
use crate::coordinator::Dispatcher;
use crate::formats::weights::MethodWeights;
use crate::formats::{Manifest, QuantizedMlpFile, WeightsFile, WorkloadKind};
use crate::runtime::ModelBank;
use crate::workload::{SyntheticSource, TableSource, WorkloadSource};

/// `mcma train` options (CLI surface).
#[derive(Clone, Debug)]
pub struct TrainOptions {
    /// Registered benchmark to train (`--bench`); empty when `data` is
    /// set.
    pub bench: String,
    /// CSV/TSV file defining a table workload (`--data`); mutually
    /// exclusive with `bench`.
    pub data: Option<PathBuf>,
    /// Trailing label columns of the data file (`--d-out`; required with
    /// `data`).
    pub d_out: usize,
    /// Held-out fraction of table rows (`--holdout`), the split the
    /// oracle-less eval/QoS paths verify against.
    pub holdout: f64,
    /// Co-training allocation scheme (`--scheme competitive|complementary`).
    pub scheme: Scheme,
    /// Number of approximators for the MCMA net (K=1 baseline always runs
    /// alongside under the same budget).
    pub k: usize,
    /// Training samples to synthesise (held-out test set is samples/4);
    /// for table workloads, a cap on the rows actually used.
    pub samples: usize,
    /// Maximum co-training rounds.
    pub rounds: usize,
    /// Epochs per net per round (and for the warmup).
    pub epochs: usize,
    pub seed: u64,
    pub lr: f64,
    /// Override the manifest/default error bound.
    pub error_bound: Option<f64>,
    /// Artifact tree to write into (created if absent).
    pub out_dir: PathBuf,
    /// Threads for per-approximator round work (0 = all cores).
    pub threads: usize,
    /// Where to write the training perf report (forward and
    /// forward+backward samples/sec, round wall-clock, precise-lookup
    /// visits/query).  `None` skips the recorder entirely — the unit-test
    /// default via explicit override; the CLI default is
    /// `BENCH_train.json` at the repo root.
    pub perf_json: Option<PathBuf>,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            bench: String::new(),
            data: None,
            d_out: 0,
            holdout: 0.25,
            scheme: Scheme::Competitive,
            k: 4,
            samples: 4000,
            rounds: 6,
            epochs: 20,
            seed: 7,
            lr: 0.01,
            error_bound: None,
            out_dir: crate::artifacts_dir(),
            threads: 0,
            perf_json: Some(crate::bench_harness::bench_json_path("BENCH_train.json")),
        }
    }
}

impl TrainOptions {
    /// Build the workload source these options describe: a registered
    /// benchmark (`--bench`) or a CSV/TSV table (`--data`).
    pub fn source(&self) -> crate::Result<Box<dyn WorkloadSource>> {
        match &self.data {
            Some(path) => {
                anyhow::ensure!(
                    self.bench.is_empty(),
                    "--bench and --data are mutually exclusive"
                );
                anyhow::ensure!(
                    self.d_out >= 1,
                    "--data requires --d-out N (the trailing label columns)"
                );
                let src = TableSource::load(path, self.d_out, self.holdout)?;
                anyhow::ensure!(
                    crate::benchmarks::by_name(src.name()).is_err(),
                    "workload name {:?} collides with a registered benchmark — \
                     rename the data file",
                    src.name()
                );
                Ok(Box::new(src))
            }
            None => {
                anyhow::ensure!(
                    !self.bench.is_empty(),
                    "either --bench or --data is required"
                );
                Ok(Box::new(SyntheticSource::by_name(&self.bench)?))
            }
        }
    }
}

/// What `train_bench` measured and wrote.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub bench: String,
    pub k: usize,
    /// The MCMA method trained (`mcma_competitive` or
    /// `mcma_complementary`, per `TrainOptions::scheme`).
    pub method: Method,
    pub error_bound: f64,
    /// Serving invocation of the K-approximator MCMA net on held-out data
    /// (measured through the real `Dispatcher`, native engine).
    pub invocation_k: f64,
    /// Same measurement for the K=1 baseline trained under the identical
    /// epoch budget.
    pub invocation_base: f64,
    pub rmse_over_bound_k: f64,
    pub rmse_over_bound_base: f64,
    pub history: Vec<RoundStats>,
    pub out_dir: PathBuf,
    /// Files written, relative to `out_dir`.
    pub wrote: Vec<String>,
    /// Absolute path of the perf report, when one was written.
    pub perf_json: Option<PathBuf>,
}

impl TrainReport {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Rust co-training: {} (bound {:.3}, held-out serving measurement)",
                self.bench, self.error_bound
            ),
            &["method", "invocation", "rmse/bound"],
        );
        t.row(vec![
            format!("{} K={}", self.method.label(), self.k),
            pct(self.invocation_k),
            format!("{:.2}", self.rmse_over_bound_k),
        ]);
        t.row(vec![
            "one-pass K=1".into(),
            pct(self.invocation_base),
            format!("{:.2}", self.rmse_over_bound_base),
        ]);
        t
    }

    pub fn print(&self) {
        self.table().print();
        println!("\nco-training trajectory (Fig. 9 analogue):");
        for h in &self.history {
            println!(
                "  round {}: invocation {} (partition potential {}), mean min-err {:.4}, {} reassigned",
                h.round,
                pct(h.clf_invocation),
                pct(h.assign_invocation),
                h.mean_min_err,
                h.reassigned
            );
        }
        println!(
            "\ninvocation gain over K=1 baseline: {:+.1} pp",
            100.0 * (self.invocation_k - self.invocation_base)
        );
        for f in &self.wrote {
            println!("wrote {}", self.out_dir.join(f).display());
        }
        if let Some(p) = &self.perf_json {
            println!("wrote {}", p.display());
        }
    }
}

/// Serialise round trajectories into `train_stats_rust.json` at the tree
/// root — the native analogue of the Python trainer's `train_stats.json`,
/// same `{bench: {method: [{invocation: ...}, ...]}}` schema, which
/// `mcma figure 9` falls back to when the Python file is absent.
/// Existing entries for OTHER benchmarks are preserved (merge-upsert).
fn save_round_stats(
    out_dir: &std::path::Path,
    bench: &str,
    histories: &[(&str, &[RoundStats])],
) -> crate::Result<()> {
    use crate::util::json::{self, Value};
    let path = out_dir.join("train_stats_rust.json");
    let mut doc = match json::parse_file(&path) {
        Ok(Value::Obj(kvs)) => kvs,
        _ => Vec::new(),
    };
    let entry = Value::Obj(
        histories
            .iter()
            .map(|(method, hist)| {
                (
                    method.to_string(),
                    Value::Arr(
                        hist.iter()
                            .map(|h| {
                                Value::Obj(vec![
                                    ("round".into(), Value::Num(h.round as f64)),
                                    ("invocation".into(), Value::Num(h.clf_invocation)),
                                    (
                                        "assign_invocation".into(),
                                        Value::Num(h.assign_invocation),
                                    ),
                                    ("mean_min_err".into(), Value::Num(h.mean_min_err)),
                                    ("reassigned".into(), Value::Num(h.reassigned as f64)),
                                    ("wall_ms".into(), Value::Num(h.wall_ms)),
                                ])
                            })
                            .collect(),
                    ),
                )
            })
            .collect(),
    );
    match doc.iter_mut().find(|(k, _)| k == bench) {
        Some(slot) => slot.1 = entry,
        None => doc.push((bench.to_string(), entry)),
    }
    std::fs::write(&path, json::write(&Value::Obj(doc)))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
    Ok(())
}

/// Write the training perf report (`BENCH_train.json`): forward and
/// forward+backward samples/sec through the kernelized trainer, the
/// co-training rounds' wall-clock replayed from this run's history, and
/// the precise-fallback lookup's k-d-tree vs linear-scan timing with the
/// tree's measured visits/query.  Hard-errors if the k-d tree and the
/// linear scan disagree on the seeded query slab — the report must never
/// record the speed of a broken index.
fn write_train_perf(
    path: &std::path::Path,
    bench: &crate::formats::BenchManifest,
    train: &TrainData,
    test_ds: &crate::formats::Dataset,
    history: &[RoundStats],
) -> crate::Result<()> {
    use crate::bench_harness::{Recorder, Timing};
    use crate::util::stats;
    use crate::workload::NearestLookup;
    use std::time::Duration;

    let mut rec = Recorder::new();
    let budget = Duration::from_millis(60);

    // Forward / forward+backward throughput on a fixed training slab
    // through the same kernelized Trainer co-training uses.
    let rows = train.n.min(256);
    let x = &train.x_norm[..rows * train.d_in];
    let y = &train.y_norm[..rows * train.d_out];
    let mut t = Trainer::new(&bench.approx_topology, TrainConfig::default(), 0x7e57);
    rec.bench_rows(&format!("train forward x{rows}"), budget, rows as u64, || {
        std::hint::black_box(t.loss_of(x, y, rows));
    });
    rec.bench_rows(&format!("train forward+backward x{rows}"), budget, rows as u64, || {
        std::hint::black_box(t.grads(x, y, rows));
    });

    // Co-training round wall-clock, replayed from this run's own history
    // (rows = training-set size, so rows/sec reads as samples per
    // round-second).
    let wall_ns: Vec<f64> = history.iter().map(|h| h.wall_ms * 1e6).collect();
    if !wall_ns.is_empty() {
        let timing = Timing {
            name: format!("cotrain round wall x{}", train.n),
            iters: wall_ns.len() as u64,
            mean_ns: stats::mean(&wall_ns),
            p50_ns: stats::percentile(&wall_ns, 50.0),
            p95_ns: stats::percentile(&wall_ns, 95.0),
            p99_ns: stats::percentile(&wall_ns, 99.0),
            std_ns: stats::std_dev(&wall_ns),
            rows: Some(train.n as u64),
        };
        timing.print();
        rec.timings.push(timing);
    }

    // Precise-fallback lookup over the held-out store: the k-d tree must
    // agree with the linear scan on every seeded query before its speed
    // is worth recording.
    let lookup = NearestLookup::from_dataset(bench, test_ds);
    if !lookup.is_empty() {
        let queries: Vec<&[f32]> = (0..train.n.min(256))
            .map(|i| &train.x_raw[i * train.d_in..(i + 1) * train.d_in])
            .collect();
        for q in &queries {
            let (tree, scan) = (lookup.nearest(q), lookup.nearest_scan(q));
            anyhow::ensure!(
                tree == scan,
                "k-d tree disagrees with linear scan (tree {tree}, scan {scan}) — \
                 refusing to write {}",
                path.display()
            );
        }
        let (q0, v0) = lookup.query_stats();
        rec.bench(&format!("precise lookup kd-tree x{}", queries.len()), budget, || {
            for q in &queries {
                std::hint::black_box(lookup.nearest(q));
            }
        });
        let (q1, v1) = lookup.query_stats();
        rec.bench(&format!("precise lookup linear scan x{}", queries.len()), budget, || {
            for q in &queries {
                std::hint::black_box(lookup.nearest_scan(q));
            }
        });
        if q1 > q0 {
            rec.extra("lookup_visits_per_query", (v1 - v0) as f64 / (q1 - q0) as f64);
        }
        rec.extra("lookup_store_rows", lookup.len() as f64);
        rec.extra("lookup_scan_agree", 1.0);
    }

    rec.write_json("train", path)
}

/// Method keys of a weights file, in `Method::ALL` display order
/// (unknown keys last) — the manifest's servable-method list.
fn method_keys(wf: &WeightsFile) -> Vec<String> {
    let mut keys: Vec<String> = wf.methods.keys().cloned().collect();
    keys.sort_by_key(|k| {
        Method::ALL
            .iter()
            .position(|m| m.key() == k.as_str())
            .unwrap_or(Method::ALL.len())
    });
    keys
}

/// Classifier topology for `k` approximators: the manifest's classifier
/// hidden sizes with the output width forced to `k + 1` (2 = the binary
/// baseline shape).
fn clf_topo(bench: &crate::formats::BenchManifest, k: usize) -> Vec<usize> {
    let mut t = if k == 1 {
        bench.clf2_topology.clone()
    } else {
        bench.clfn_topology.clone()
    };
    *t.last_mut().expect("classifier topology non-empty") = k + 1;
    t
}

/// Co-train a workload natively (registered benchmark via `--bench`, data
/// file via `--data`) and export a servable artifact tree.  See the
/// module docs for the full pipeline.
pub fn train_bench(opts: &TrainOptions) -> crate::Result<TrainReport> {
    anyhow::ensure!(opts.k >= 1, "--k must be >= 1");
    anyhow::ensure!(opts.samples >= 64, "--samples must be >= 64");
    let source = opts.source()?;
    let name = source.name().to_string();
    let is_table = source.kind() == WorkloadKind::Table;
    let mcma_key = opts.scheme.method_key();
    let mcma_method = match opts.scheme {
        Scheme::Competitive => Method::McmaCompetitive,
        Scheme::Complementary => Method::McmaComplementary,
    };

    // Benchmark spec: reuse an existing manifest entry (out dir first,
    // then the ambient artifact tree) or derive one from the source
    // itself.  A table entry is only reusable while its source digest
    // matches — retraining from a changed data file re-derives bounds and
    // rebuilds the tree (the old nets no longer describe the data).
    let existing = Manifest::load(&opts.out_dir)
        .ok()
        .or_else(|| Manifest::load(&crate::artifacts_dir()).ok());
    let existing_entry = existing.as_ref().and_then(|m| m.bench(&name).ok().cloned());
    // Dimensions must match too: the same CSV re-trained with a different
    // `--d-out` is a different workload shape, and a stale entry's
    // normalisation bounds would index out of range.
    let reusable = existing_entry.filter(|e| {
        e.kind == source.kind()
            && e.n_in == source.d_in()
            && e.n_out == source.d_out()
            && (!is_table || e.source_digest == source.digest())
    });
    let reused_entry = reusable.is_some();
    let mut bench = reusable
        .unwrap_or_else(|| source.derive_manifest(opts.k, opts.error_bound, opts.seed));
    if let Some(b) = opts.error_bound {
        bench.error_bound = b;
    }

    // Classifier topologies: K+1 outputs for MCMA, 2 for the baseline.
    let clf_topo_k = clf_topo(&bench, opts.k);
    let clf_topo_1 = clf_topo(&bench, 1);

    let (train, test) =
        source.datasets(&bench, opts.samples, (opts.samples / 4).max(64), opts.seed)?;
    anyhow::ensure!(
        train.n >= 8 && test.n >= 1,
        "workload too small after the train/held-out split: {} train / {} \
         held-out rows",
        train.n,
        test.n
    );

    let cfg_for = |k: usize, scheme: Scheme| CotrainConfig {
        k,
        scheme,
        rounds: opts.rounds,
        warmup_epochs: opts.epochs,
        approx_epochs: opts.epochs,
        clf_epochs: opts.epochs,
        error_bound: bench.error_bound,
        seed: opts.seed,
        threads: opts.threads,
        approx: TrainConfig { lr: opts.lr as f32, ..TrainConfig::default() },
        clf: TrainConfig {
            lr: opts.lr as f32,
            loss: Loss::SoftmaxCrossEntropy,
            ..TrainConfig::default()
        },
        tol: 0.005,
    };
    let multi = cotrain::cotrain(
        &train,
        &bench.approx_topology,
        &clf_topo_k,
        &cfg_for(opts.k, opts.scheme),
    );
    // The K=1 baseline is the paper's one-pass method; the allocation
    // scheme only matters for K >= 2, so it always runs competitive.
    let single = cotrain::cotrain(
        &train,
        &bench.approx_topology,
        &clf_topo_1,
        &cfg_for(1, Scheme::Competitive),
    );

    // audit:allow(determinism) — keys are sorted at serialization time.
    let mut methods = HashMap::new();
    methods.insert(
        "one_pass".to_string(),
        MethodWeights {
            method: "one_pass".into(),
            cascade: false,
            clf_classes: 2,
            classifiers: vec![single.classifier.clone()],
            approximators: single.approximators.clone(),
        },
    );
    methods.insert(
        mcma_key.to_string(),
        MethodWeights {
            method: mcma_key.into(),
            cascade: false,
            clf_classes: opts.k + 1,
            classifiers: vec![multi.classifier.clone()],
            approximators: multi.approximators.clone(),
        },
    );
    let wf = WeightsFile { methods };

    // Measure both nets through the REAL serving path (native engine) on
    // held-out data — the invocation number the paper reports.  Table
    // workloads have no runtime oracle; `run_dataset` serves their
    // rejected samples from the held-out labels themselves.
    let test_ds = test.to_dataset();
    let bank = ModelBank::from_host(&bench.name, wf.clone());
    let out_k =
        Dispatcher::new(&bench, &bank, mcma_method, ExecMode::Native)?.run_dataset(&test_ds)?;
    let out_1 = Dispatcher::new(&bench, &bank, Method::OnePass, ExecMode::Native)?
        .run_dataset(&test_ds)?;

    // Export the artifact tree.
    let bench_dir = opts.out_dir.join(&bench.name);
    std::fs::create_dir_all(&bench_dir)
        .map_err(|e| anyhow::anyhow!("creating {}: {e}", bench_dir.display()))?;
    let mut wrote = Vec::new();

    wf.save(&bench_dir.join("weights_rust.bin"))?;
    wrote.push(format!("{}/weights_rust.bin", bench.name));
    // Standalone tree (no Python build): make it directly servable.  A
    // table tree is ALWAYS rust-native — there is no Python provenance to
    // preserve, and a digest change means the old nets/labels are stale —
    // so its weights.bin and test.bin are rewritten unconditionally.
    let wrote_weights = is_table || !bench_dir.join("weights.bin").exists();
    if wrote_weights {
        wf.save(&bench_dir.join("weights.bin"))?;
        wrote.push(format!("{}/weights.bin", bench.name));
    }
    if is_table || !bench_dir.join("test.bin").exists() {
        test_ds.save(&bench_dir.join("test.bin"))?;
        wrote.push(format!("{}/test.bin", bench.name));
    }
    for (i, a) in multi.approximators.iter().enumerate() {
        let fname = format!("approx_rust_k{}_{i}.mcqw", opts.k);
        QuantizedMlpFile::from_mlp(a).save(&bench_dir.join(&fname))?;
        wrote.push(format!("{}/{fname}", bench.name));
    }

    // The entry's `methods` list is what eval/summary pick serving
    // methods from, so it must describe what the tree's weights.bin
    // ACTUALLY contains — not merely which schemes were ever trained.
    // If this run rewrote weights.bin the answer is `wf`'s keys; if an
    // existing weights.bin was preserved (Python or earlier Rust tree),
    // re-read its method set (this run's nets live only in
    // weights_rust.bin, which `mcma summary` compares separately).
    let servable_methods: Vec<String> = if wrote_weights {
        method_keys(&wf)
    } else {
        WeightsFile::load(&bench_dir.join("weights.bin"))
            .map(|w| method_keys(&w))
            .unwrap_or_else(|_| method_keys(&wf))
    };

    let mut man = Manifest::load(&opts.out_dir).unwrap_or_else(|_| Manifest {
        n_approx: opts.k,
        batch_sizes: vec![1, 256],
        // audit:allow(determinism) — manifest writer sorts benchmark names.
        benchmarks: HashMap::new(),
        root: opts.out_dir.clone(),
    });
    match man.benchmarks.get_mut(&bench.name) {
        Some(entry) if !is_table && reused_entry => {
            // The tree already describes this benchmark (e.g. a
            // Python-built manifest whose topologies/bounds still describe
            // weights.bin and the compiled HLO) — do NOT rewrite its
            // shared fields, only reconcile the servable-method list.
            // The Rust-trained nets carry their own shapes inside
            // weights_rust.bin; the native serving path never consults the
            // manifest topologies.
            entry.methods = servable_methods;
        }
        _ => {
            bench.train_n = train.n;
            bench.test_n = test.n;
            if opts.k > 1 {
                bench.clfn_topology = clf_topo_k;
            }
            bench.methods = servable_methods;
            man.upsert_bench(bench.clone());
        }
    }
    man.save_to(&opts.out_dir)?;
    wrote.push("manifest.json".into());

    // Native Fig. 9 trajectory (the `mcma figure 9` fallback source).
    save_round_stats(
        &opts.out_dir,
        &bench.name,
        &[
            (mcma_key, multi.history.as_slice()),
            ("one_pass", single.history.as_slice()),
        ],
    )?;
    wrote.push("train_stats_rust.json".into());

    if let Some(path) = &opts.perf_json {
        write_train_perf(path, &bench, &train, &test_ds, &multi.history)?;
    }

    Ok(TrainReport {
        bench: bench.name,
        k: opts.k,
        method: mcma_method,
        error_bound: bench.error_bound,
        invocation_k: out_k.metrics.invocation(),
        invocation_base: out_1.metrics.invocation(),
        rmse_over_bound_k: out_k.metrics.rmse_over_bound,
        rmse_over_bound_base: out_1.metrics.rmse_over_bound,
        history: multi.history,
        out_dir: opts.out_dir.clone(),
        wrote,
        perf_json: opts.perf_json.clone(),
    })
}
