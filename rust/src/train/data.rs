//! Training-set synthesis — moved behind the workload subsystem.
//!
//! The benchmark-function synthesis that lived here now implements the
//! [`crate::workload::WorkloadSource`] trait for registered benchmarks
//! (`crate::workload::synthetic`), next to the table-file source that
//! opens arbitrary CSV/TSV workloads.  These re-exports keep the
//! historical `train::data` paths working; the streams are unchanged, so
//! same-seed datasets are bit-identical across the move.

// audit:deterministic — same-seed datasets are bit-identical (see above).
pub use crate::workload::{derive_bench_manifest, sample_data, TrainData};
