//! # MCMA — invocation-driven neural approximate computing
//!
//! Reproduction of *"Invocation-driven Neural Approximate Computing with a
//! Multiclass-Classifier and Multiple Approximators"* (ICCAD 2018).
//!
//! This crate is Layer 3 of the three-layer stack: the **coordinator** that
//! owns the request path.  Python/JAX/Pallas run once at build time
//! (`make artifacts`) to train the classifier + approximators and lower
//! their forward passes to HLO text; this crate loads those artifacts via
//! the PJRT CPU client and serves requests with **no Python anywhere on the
//! hot path**.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`util`] — substrates for crates unavailable offline: RNG, JSON,
//!   thread pool, stats, a property-test harness.
//! * [`config`] — benchmark registry and run/NPU configuration.
//! * [`formats`] — readers for the binary artifacts written by
//!   `python/compile/formats.py`.
//! * [`nn`] — pure-Rust MLP inference (cross-checks PJRT numerics, serves
//!   as a fallback execution engine).
//! * [`benchmarks`] — the eight PRECISE target functions (the "CPU" path).
//! * [`runtime`] — PJRT wrapper: load HLO text, compile, execute.
//! * [`coordinator`] — the paper's contribution at run time: dynamic
//!   batcher, multiclass router, MCCA cascade, weight-switch cache,
//!   dispatcher, threaded pipeline server, metrics.
//! * [`npu`] — cycle-level NPU simulator + energy model (Fig. 8).
//! * [`obs`] — live observability: lock-free stage-histogram metrics
//!   registry, sampled span journal, and the snapshot payload behind
//!   the in-band STATS scrape and `mcma stats`.
//! * [`net`] — TCP serving front-end: length-prefixed binary frames,
//!   per-connection reader threads over the existing submit path, a
//!   response pump with exact dead-client accounting, and the seeded
//!   closed/open-loop load generator behind `mcma bench-load`.
//! * [`qos`] — online quality control: deterministic shadow sampling of
//!   approximated requests against the precise function, per-class
//!   windowed error estimation, and an adaptive per-class invocation
//!   controller (margins + hysteresis + circuit breaker) the server
//!   hosts at serve time.
//! * [`train`] — native co-training: minibatch backprop through the packed
//!   GEMM kernels, the paper's partition-refinement loop (competitive AND
//!   complementary allocation), and MCMW/MCQW/MCMD artifact export — no
//!   Python anywhere in the train loop either.
//! * [`workload`] — workload sources as first-class objects: the
//!   registered synthetic benchmarks, user-supplied CSV/TSV tables
//!   (schema inference + deterministic train/held-out split), and the
//!   oracle-less precise proxy (held-out nearest-record lookup / reject)
//!   that lets table workloads train, serve and QoS-verify with no
//!   precise function at runtime.
//! * [`eval`] — one driver per paper figure.
//! * [`bench_harness`] — timing harness for `cargo bench` (criterion
//!   substitute).

// Kernel code walks parallel packed buffers by index (the loop shape IS
// the tile math), and the cost/energy tables are long argument lists by
// nature — these pedantic lints fight the domain idiom.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity
)]

pub mod bench_harness;
pub mod benchmarks;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod formats;
pub mod net;
pub mod nn;
pub mod npu;
pub mod obs;
pub mod qos;
pub mod runtime;
pub mod train;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Root of the artifact tree (overridable via `MCMA_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("MCMA_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
