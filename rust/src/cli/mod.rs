//! Minimal CLI argument parser (clap substitute): subcommand + positional
//! arguments + `--key value` / `--key=value` options + `--flag` booleans.
//!
//! Every option must be REGISTERED (in [`VALUE_KEYS`] or [`FLAG_KEYS`]):
//! an unknown `--key` is a hard error.  Previously an unknown value option
//! was silently treated as a flag and its value leaked into the
//! positionals — `mcma eval --samplse 100` would quietly evaluate the full
//! test set with a stray positional `100`.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positionals: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

/// Option keys that take a value.
const VALUE_KEYS: [&str; 46] = [
    // shared / eval / serve / npu-sim
    "bench", "method", "exec", "samples", "requests", "batch", "wait-us",
    "case", "n", "seed",
    // train
    "k", "rounds", "epochs", "lr", "bound", "out", "threads", "perf-json",
    // data-defined (table) workloads
    "data", "d-out", "holdout", "scheme", "precise-fallback",
    // serve/summary QoS loop
    "qos-target", "qos-quantile", "qos-shadow", "qos-window", "qos-seed",
    // network serving (`serve --listen`) + load harness (`bench-load`)
    "listen", "duration", "batch-max", "batch-wait-us",
    "addr", "rate", "closed-loop", "mix", "csv", "json",
    // observability (`serve` writers + `stats` scraper)
    "watch", "trace-json", "metrics-json", "metrics-interval-s",
    // exposition + SLO monitor (`serve --metrics-listen`, `bench-load`
    // cross-check, `trace` converter reuses trace-json/out above)
    "metrics-listen", "slo-p99-us", "slo-error-budget", "metrics-addr",
];

/// Positional argument names, in the order subcommands consume them via
/// [`Args::pos`].  Registration (plus an UPPERCASE placeholder in
/// [`USAGE`]) is what lets `mcma-audit`'s cli-registry rule track
/// positionals the same way it tracks `--key` options — `mcma stats
/// ADDR` needs no allow comments.
const POSITIONAL_KEYS: [&str; 1] = ["addr"];

/// Boolean flags (present/absent, no value).  Every key here must be
/// documented in [`USAGE`] or looked up via `has_flag` — `mcma-audit`'s
/// cli-registry rule flags dead keys (`verbose` and `force` were removed
/// once the audit showed nothing consumed them).
const FLAG_KEYS: [&str; 2] = ["help", "qos-warm"];

impl Args {
    /// Parse `std::env::args()`-style tokens (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> crate::Result<Self> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                // `--key=value` form.
                if let Some((k, v)) = key.split_once('=') {
                    anyhow::ensure!(
                        VALUE_KEYS.contains(&k),
                        "unknown option --{k} (run `mcma help` for usage)"
                    );
                    args.options.insert(k.to_string(), v.to_string());
                } else if VALUE_KEYS.contains(&key) {
                    let val = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("--{key} expects a value"))?;
                    args.options.insert(key.to_string(), val);
                } else if FLAG_KEYS.contains(&key) {
                    args.flags.push(key.to_string());
                } else {
                    anyhow::bail!("unknown option --{key} (run `mcma help` for usage)");
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positionals.push(tok);
            }
        }
        Ok(args)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> crate::Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> crate::Result<f64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Positional argument by registered name (see [`POSITIONAL_KEYS`]):
    /// the value at the name's registered index, if given.  Subcommands
    /// with bespoke positional grammars (e.g. `figure 7a`) keep indexing
    /// `positionals` directly.
    pub fn pos(&self, name: &str) -> Option<&str> {
        let i = POSITIONAL_KEYS.iter().position(|k| *k == name)?;
        self.positionals.get(i).map(String::as_str)
    }
}

pub const USAGE: &str = "\
mcma — invocation-driven neural approximate computing (ICCAD'18 reproduction)

USAGE:
  mcma <subcommand> [options]

SUBCOMMANDS:
  list-benchmarks                 show the benchmark suite (paper Fig. 6)
  figure <7a|7b|7c|8a|8b|9|10|11|all>
                                  regenerate a paper figure as a table
  summary                         §IV.B headline numbers vs the paper
                                  (+ Rust-vs-Python training comparison when
                                  `weights_rust.bin` artifacts exist)
  report                          full evaluation as JSON (plotting / CI)
  eval   --bench B --method M     run one (benchmark, method) evaluation
  serve  --bench B --method M     run the online serving pipeline demo
         [--requests N] [--batch N] [--wait-us U]
         [--qos-target T]            enable the online QoS loop: hold the
         [--qos-quantile Q=0.95]     per-class Q-quantile of the shadow-
         [--qos-shadow R=0.05]       observed error at or below T by
         [--qos-window N=256]        adapting per-class margins (circuit
         [--qos-seed S]              breaker on sustained violation)
         [--qos-warm]                seed margins from an offline replay of
                                     the held-out set (no argmax cold start)
         [--precise-fallback lookup|reject]
                                     table workloads only: serve rejected
                                     requests from the nearest held-out
                                     record (default) or fail them
         [--listen ADDR]             serve over TCP (length-prefixed binary
         [--duration SEC]            frames) instead of the in-process demo
         [--batch-max N]             traffic; adaptive micro-batching
         [--batch-wait-us U]         coalesces GEMM-shaped batches under
                                     load, drops to low-latency singles
                                     when idle.  --duration 0 = until killed
         [--trace-json PATH]         drain the sampled span journal (JSON
                                     lines) to PATH at shutdown
         [--metrics-json PATH]       write the live metrics snapshot to
         [--metrics-interval-s N]    PATH every N seconds (default 5)
         [--metrics-listen ADDR]     OpenMetrics text exposition over HTTP:
                                     GET /metrics (Prometheus scrape) and
                                     GET /healthz (200 ok / 503 on breach)
         [--slo-p99-us N]            SLO burn-rate monitor: delivered-e2e
         [--slo-error-budget F]      p99 target in µs and the error budget
                                     fraction (default 0.001); a fast+slow
                                     window breach flips /healthz to 503
  stats  ADDR | --addr HOST:PORT    scrape a running `serve --listen`
         [--watch SECS] [--json PATH] server in-band (STATS frame): stage
                                     waterfall percentiles, route/QoS
                                     counters; --watch re-scrapes every
                                     SECS and prints per-interval rates
                                     (delta/s + interval percentiles);
                                     --json dumps the raw snapshot
  trace  --trace-json PATH          convert a drained span journal (JSON
         [--out PATH]                lines, from `serve --trace-json`) to
                                     Chrome/Perfetto trace-event JSON on
                                     stdout or --out; open in
                                     ui.perfetto.dev
  bench-load --addr HOST:PORT       seeded load generator against a live
         [--seed S] [--duration SEC] `mcma serve --listen` socket:
         [--rate R | --closed-loop N] open-loop Poisson at R req/s or
         [--mix W0,W1 | C:W,...]     closed-loop with N in flight; --mix
         [--requests N]              weights request classes (equal shards
         [--bench B]                 of the held-out set); --requests caps
         [--qos-target T]            total sent (same seed + same cap =
         [--csv PATH] [--json PATH]  identical sequence).  Writes the
         [--metrics-addr ADDR]       per-request CSV + BENCH_serve.json;
                                     --metrics-addr cross-checks the HTTP
                                     /metrics exposition against the
                                     in-band STATS snapshot after the run
  train  --bench B | --data F.csv co-train K approximators + multiclass
         [--d-out N] [--holdout H]   classifier natively (no Python) and
         [--k K] [--scheme S]        export MCMW/MCQW artifacts ModelBank
         [--samples N] [--rounds R]  serves; also trains a K=1 baseline
         [--epochs E] [--lr X]       under the same budget for comparison.
         [--bound B] [--seed S]      --data opens an arbitrary CSV/TSV
         [--out DIR] [--threads T]   workload: the last --d-out columns are
         [--perf-json PATH|none]     labels, --holdout (0.25) rows are held
                                     out for eval + oracle-less QoS.
                                     --scheme competitive|complementary
                                     picks the co-training allocation;
                                     --perf-json redirects/skips the
                                     BENCH_train.json perf report
  npu-sim --bench B --method M    NPU cycle simulation + buffer-case ablation
         [--case 1|2|3]

COMMON OPTIONS:
  --exec pjrt|native|native-q8    execution engine (default pjrt);
                                  native-q8 = int8 quantized SIMD engine
  --samples N                     cap test samples (default: full test set)
  --help                          print this message and exit

ENVIRONMENT:
  MCMA_ARTIFACTS                  artifact tree (default: ./artifacts)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = parse("eval --bench sobel --method mcma_competitive --exec native");
        assert_eq!(a.subcommand.as_deref(), Some("eval"));
        assert_eq!(a.opt("bench"), Some("sobel"));
        assert_eq!(a.opt("method"), Some("mcma_competitive"));
        assert_eq!(a.opt_or("exec", "pjrt"), "native");
    }

    #[test]
    fn positionals_after_subcommand() {
        let a = parse("figure 7a");
        assert_eq!(a.subcommand.as_deref(), Some("figure"));
        assert_eq!(a.positionals, vec!["7a"]);
    }

    #[test]
    fn flags_vs_value_options() {
        let a = parse("serve --qos-warm --samples 100");
        assert!(a.has_flag("qos-warm"));
        assert!(!a.has_flag("help"));
        assert_eq!(a.opt_usize("samples", 0).unwrap(), 100);
        assert!(parse("eval --help").has_flag("help"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(["eval".into(), "--bench".into()]).is_err());
    }

    #[test]
    fn bad_integer_is_error() {
        let a = parse("eval --samples abc");
        assert!(a.opt_usize("samples", 0).is_err());
    }

    /// The old parser silently turned a misspelled value option into a
    /// flag and let its value leak into the positionals; now any
    /// unregistered `--key` is a hard error.
    #[test]
    fn unknown_option_is_hard_error() {
        let e = Args::parse(["eval".into(), "--samplse".into(), "100".into()]);
        assert!(e.is_err());
        assert!(e.unwrap_err().to_string().contains("--samplse"));
        assert!(Args::parse(["train".into(), "--bogus=3".into()]).is_err());
    }

    #[test]
    fn train_options_registered() {
        let a = parse(
            "train --bench bessel --k 4 --samples 2000 --rounds 5 --epochs 10 \
             --lr 0.02 --bound 0.04 --out /tmp/x --threads 2 --seed 9",
        );
        assert_eq!(a.opt_usize("k", 1).unwrap(), 4);
        assert_eq!(a.opt_usize("rounds", 0).unwrap(), 5);
        assert_eq!(a.opt_usize("epochs", 0).unwrap(), 10);
        assert!((a.opt_f64("lr", 0.0).unwrap() - 0.02).abs() < 1e-12);
        assert!((a.opt_f64("bound", 0.0).unwrap() - 0.04).abs() < 1e-12);
        assert_eq!(a.opt("out"), Some("/tmp/x"));
        assert_eq!(a.opt_usize("threads", 0).unwrap(), 2);
    }

    #[test]
    fn qos_options_registered() {
        let a = parse(
            "serve --bench fft --qos-target 0.1 --qos-quantile 0.9 \
             --qos-shadow 0.25 --qos-window 128 --qos-seed 99",
        );
        assert!((a.opt_f64("qos-target", 0.0).unwrap() - 0.1).abs() < 1e-12);
        assert!((a.opt_f64("qos-quantile", 0.0).unwrap() - 0.9).abs() < 1e-12);
        assert!((a.opt_f64("qos-shadow", 0.0).unwrap() - 0.25).abs() < 1e-12);
        assert_eq!(a.opt_usize("qos-window", 0).unwrap(), 128);
        assert_eq!(a.opt_usize("qos-seed", 0).unwrap(), 99);
        assert!(Args::parse(["serve".into(), "--qos-tgt".into(), "1".into()]).is_err());
    }

    #[test]
    fn table_workload_options_registered() {
        let a = parse(
            "train --data /tmp/w.csv --d-out 2 --holdout 0.3 --scheme complementary",
        );
        assert_eq!(a.opt("data"), Some("/tmp/w.csv"));
        assert_eq!(a.opt_usize("d-out", 0).unwrap(), 2);
        assert!((a.opt_f64("holdout", 0.0).unwrap() - 0.3).abs() < 1e-12);
        assert_eq!(a.opt("scheme"), Some("complementary"));
        let b = parse("serve --bench w --precise-fallback reject --qos-warm");
        assert_eq!(b.opt("precise-fallback"), Some("reject"));
        assert!(b.has_flag("qos-warm"));
        assert!(Args::parse(["train".into(), "--dout".into(), "2".into()]).is_err());
    }

    #[test]
    fn net_serve_and_bench_load_options_registered() {
        let a = parse(
            "serve --bench fft --listen 127.0.0.1:0 --duration 5 \
             --batch-max 64 --batch-wait-us 500",
        );
        assert_eq!(a.opt("listen"), Some("127.0.0.1:0"));
        assert_eq!(a.opt_usize("duration", 0).unwrap(), 5);
        assert_eq!(a.opt_usize("batch-max", 0).unwrap(), 64);
        assert_eq!(a.opt_usize("batch-wait-us", 0).unwrap(), 500);
        let b = parse(
            "bench-load --addr 127.0.0.1:7090 --seed 7 --duration 3 \
             --closed-loop 32 --mix 3,1 --requests 500 --csv /tmp/load.csv \
             --json /tmp/BENCH_serve.json --qos-target 1.0",
        );
        assert_eq!(b.subcommand.as_deref(), Some("bench-load"));
        assert_eq!(b.opt("addr"), Some("127.0.0.1:7090"));
        assert_eq!(b.opt_usize("closed-loop", 0).unwrap(), 32);
        assert_eq!(b.opt("mix"), Some("3,1"));
        assert_eq!(b.opt("csv"), Some("/tmp/load.csv"));
        let c = parse("bench-load --rate 2000");
        assert!((c.opt_f64("rate", 0.0).unwrap() - 2000.0).abs() < 1e-12);
        // --perf-json is registered (it appears in USAGE and CI).
        let d = parse("train --bench fft --perf-json /tmp/BENCH_train.json");
        assert_eq!(d.opt("perf-json"), Some("/tmp/BENCH_train.json"));
    }

    #[test]
    fn observability_options_registered() {
        let a = parse(
            "serve --bench fft --listen 127.0.0.1:0 --trace-json /tmp/trace.jsonl \
             --metrics-json /tmp/m.json --metrics-interval-s 2",
        );
        assert_eq!(a.opt("trace-json"), Some("/tmp/trace.jsonl"));
        assert_eq!(a.opt("metrics-json"), Some("/tmp/m.json"));
        assert_eq!(a.opt_usize("metrics-interval-s", 5).unwrap(), 2);
        let b = parse("stats --addr 127.0.0.1:7090 --watch 2");
        assert_eq!(b.subcommand.as_deref(), Some("stats"));
        assert_eq!(b.opt("addr"), Some("127.0.0.1:7090"));
        assert_eq!(b.opt_usize("watch", 0).unwrap(), 2);
    }

    #[test]
    fn exposition_and_slo_options_registered() {
        let a = parse(
            "serve --bench fft --listen 127.0.0.1:0 --metrics-listen 127.0.0.1:0 \
             --slo-p99-us 20000 --slo-error-budget 0.01",
        );
        assert_eq!(a.opt("metrics-listen"), Some("127.0.0.1:0"));
        assert_eq!(a.opt_usize("slo-p99-us", 0).unwrap(), 20_000);
        assert!((a.opt_f64("slo-error-budget", 0.0).unwrap() - 0.01).abs() < 1e-12);
        let b = parse("bench-load --addr 127.0.0.1:7090 --metrics-addr 127.0.0.1:9090");
        assert_eq!(b.opt("metrics-addr"), Some("127.0.0.1:9090"));
        let c = parse("trace --trace-json /tmp/t.jsonl --out /tmp/t.json");
        assert_eq!(c.subcommand.as_deref(), Some("trace"));
        assert_eq!(c.opt("trace-json"), Some("/tmp/t.jsonl"));
        assert_eq!(c.opt("out"), Some("/tmp/t.json"));
    }

    #[test]
    fn registered_positional_lookup() {
        let a = parse("stats 127.0.0.1:7090");
        assert_eq!(a.pos("addr"), Some("127.0.0.1:7090"));
        let b = parse("stats");
        assert_eq!(b.pos("addr"), None);
        // Unregistered names never resolve, whatever was typed.
        assert_eq!(a.pos("figure"), None);
    }

    #[test]
    fn key_equals_value_form() {
        let a = parse("train --bench=fft --k=3");
        assert_eq!(a.opt("bench"), Some("fft"));
        assert_eq!(a.opt_usize("k", 1).unwrap(), 3);
    }

    #[test]
    fn opt_f64_default_and_error() {
        let a = parse("train --bench fft");
        assert_eq!(a.opt_f64("lr", 0.5).unwrap(), 0.5);
        let b = parse("train --lr nope");
        assert!(b.opt_f64("lr", 0.0).is_err());
    }
}
