//! Minimal CLI argument parser (clap substitute): subcommand + positional
//! arguments + `--key value` options + `--flag` booleans.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positionals: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

/// Which option keys take a value (everything else after `--` is a flag).
const VALUE_KEYS: [&str; 10] = [
    "bench", "method", "exec", "samples", "requests", "batch", "wait-us",
    "case", "n", "seed",
];

impl Args {
    /// Parse `std::env::args()`-style tokens (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> crate::Result<Self> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if VALUE_KEYS.contains(&key) {
                    let val = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("--{key} expects a value"))?;
                    args.options.insert(key.to_string(), val);
                } else {
                    args.flags.push(key.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positionals.push(tok);
            }
        }
        Ok(args)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> crate::Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

pub const USAGE: &str = "\
mcma — invocation-driven neural approximate computing (ICCAD'18 reproduction)

USAGE:
  mcma <subcommand> [options]

SUBCOMMANDS:
  list-benchmarks                 show the benchmark suite (paper Fig. 6)
  figure <7a|7b|7c|8a|8b|9|10|11|all>
                                  regenerate a paper figure as a table
  summary                         §IV.B headline numbers vs the paper
  report                          full evaluation as JSON (plotting / CI)
  eval   --bench B --method M     run one (benchmark, method) evaluation
  serve  --bench B --method M     run the online serving pipeline demo
         [--requests N] [--batch N] [--wait-us U]
  npu-sim --bench B --method M    NPU cycle simulation + buffer-case ablation
         [--case 1|2|3]

COMMON OPTIONS:
  --exec pjrt|native|native-q8    execution engine (default pjrt);
                                  native-q8 = int8 quantized SIMD engine
  --samples N                     cap test samples (default: full test set)

ENVIRONMENT:
  MCMA_ARTIFACTS                  artifact tree (default: ./artifacts)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = parse("eval --bench sobel --method mcma_competitive --exec native");
        assert_eq!(a.subcommand.as_deref(), Some("eval"));
        assert_eq!(a.opt("bench"), Some("sobel"));
        assert_eq!(a.opt("method"), Some("mcma_competitive"));
        assert_eq!(a.opt_or("exec", "pjrt"), "native");
    }

    #[test]
    fn positionals_after_subcommand() {
        let a = parse("figure 7a");
        assert_eq!(a.subcommand.as_deref(), Some("figure"));
        assert_eq!(a.positionals, vec!["7a"]);
    }

    #[test]
    fn flags_vs_value_options() {
        let a = parse("eval --verbose --samples 100");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.opt_usize("samples", 0).unwrap(), 100);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(["eval".into(), "--bench".into()]).is_err());
    }

    #[test]
    fn bad_integer_is_error() {
        let a = parse("eval --samples abc");
        assert!(a.opt_usize("samples", 0).is_err());
    }
}
