//! OpenMetrics / Prometheus text exposition of the [`Registry`]
//! snapshot — the body behind `GET /metrics` on
//! `serve --metrics-listen ADDR` (`net/http.rs`).
//!
//! Rendering rules (Prometheus text format 0.0.4, OpenMetrics-
//! compatible):
//!
//! * counters get the `_total` suffix (`mcma_submitted_total`);
//! * gauges are bare (`mcma_inflight`);
//! * every [`Hist64`] renders as a cumulative-`le` histogram family:
//!   one `_bucket{le="..."}` series per populated log2 bucket with the
//!   bucket's inclusive upper bound as the `le` value, a final
//!   `le="+Inf"` bucket equal to `_count`, plus `_sum`/`_count`;
//! * per-route / per-class / per-tag series carry label sets
//!   (`mcma_route_execute_us_bucket{class="1",le="127"}`);
//! * label values escape `\`, `"` and newline per the spec.
//!
//! The exposition is rendered from the same atomics as the in-band
//! `KIND_STATS` JSON snapshot, so every counter shared between the two
//! agrees up to scrape-interleaving (pinned by the `tests/net_serve.rs`
//! consistency e2e and the `bench-load` cross-check).

use super::metrics::{Hist64, HistSnapshot, Registry, OBS_ROUTE_CLASSES};
use super::slo::SloMonitor;
use super::Obs;

/// Content-Type header value for the exposition body.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

/// Escape a label value: `\` -> `\\`, `"` -> `\"`, newline -> `\n`.
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Exposition value formatting: integers print without a decimal point
/// (the JSON writer's convention), everything else as shortest-roundtrip.
fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// `# HELP` + `# TYPE` header for one metric family.
fn head(out: &mut String, name: &str, kind: &str, help: &str) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    out.push_str(help);
    out.push_str("\n# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(kind);
    out.push('\n');
}

/// One sample line.  `labels` is either empty or a rendered
/// `key="value"` list WITHOUT braces (`class="1"`).
fn sample(out: &mut String, name: &str, labels: &str, v: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        out.push_str(labels);
        out.push('}');
    }
    out.push(' ');
    out.push_str(&fmt_value(v));
    out.push('\n');
}

/// Header + sample for a label-less single-series family.
fn scalar(out: &mut String, name: &str, kind: &str, help: &str, v: f64) {
    head(out, name, kind, help);
    sample(out, name, "", v);
}

/// Cumulative-`le` histogram series for one (family, label) pair.  The
/// header is the caller's job so multi-label families (route classes)
/// emit it once.
fn hist_series(out: &mut String, name: &str, label: &str, s: &HistSnapshot) {
    let bucket_name = format!("{name}_bucket");
    let mut cum = 0u64;
    for (i, &c) in s.buckets.iter().enumerate() {
        if c == 0 {
            continue;
        }
        cum += c;
        let le = Hist64::bucket_hi(i);
        let labels = if label.is_empty() {
            format!("le=\"{le}\"")
        } else {
            format!("{label},le=\"{le}\"")
        };
        sample(out, &bucket_name, &labels, cum as f64);
    }
    let inf = if label.is_empty() {
        "le=\"+Inf\"".to_string()
    } else {
        format!("{label},le=\"+Inf\"")
    };
    sample(out, &bucket_name, &inf, s.count as f64);
    sample(out, &format!("{name}_sum"), label, s.sum as f64);
    sample(out, &format!("{name}_count"), label, s.count as f64);
}

/// Header + series for a label-less histogram family.
fn hist(out: &mut String, name: &str, help: &str, s: &HistSnapshot) {
    head(out, name, "histogram", help);
    hist_series(out, name, "", s);
}

/// Render the full exposition.  `slo` adds the burn-rate families when
/// the monitor is configured.
pub fn render(obs: &Obs, slo: Option<&SloMonitor>) -> String {
    let r: &Registry = &obs.metrics;
    let mut out = String::with_capacity(8192);

    scalar(
        &mut out,
        "mcma_uptime_seconds",
        "gauge",
        "Seconds since serve start.",
        r.uptime_s(),
    );
    head(
        &mut out,
        "mcma_exec_mode_info",
        "gauge",
        "Execution engine serving approximator GEMMs (constant 1).",
    );
    sample(
        &mut out,
        "mcma_exec_mode_info",
        &format!("mode=\"{}\"", escape_label(&r.exec_mode())),
        1.0,
    );

    // Counter plane: one `_total` family per registry counter, same
    // names as the KIND_STATS `counters` object.
    let counters: [(&str, u64, &str); 16] = [
        ("accepted_conns", r.accepted_conns.get(), "TCP connections accepted."),
        ("closed_conns", r.closed_conns.get(), "TCP connections closed."),
        ("frames_in", r.frames_in.get(), "Well-formed request frames decoded."),
        ("malformed_frames", r.malformed_frames.get(), "Connections killed for protocol violations."),
        ("stats_requests", r.stats_requests.get(), "In-band STATS scrapes answered."),
        ("submitted", r.submitted.get(), "Requests entering the pipeline."),
        ("dispatched", r.dispatched.get(), "Responses dispatched by workers."),
        ("delivered", r.delivered.get(), "Responses written to client sockets."),
        ("delivery_failures", r.delivery_failures.get(), "Responses owed to dead clients."),
        ("route_invoked_rows", r.route_invoked_rows.get(), "Rows served by approximators."),
        ("route_cpu_rows", r.route_cpu_rows.get(), "Rows served by the precise path."),
        ("margin_moves", r.margin_moves.get(), "QoS margin adjustments."),
        ("breaker_trips", r.breaker_trips.get(), "QoS circuit-breaker opens."),
        ("breaker_resets", r.breaker_resets.get(), "QoS circuit-breaker closes."),
        ("shadow_drops", r.shadow_drops.get(), "Shadow observations lost to backpressure."),
        ("slo_breaches", r.slo_breaches.get(), "Healthy -> breached SLO transitions."),
    ];
    for (name, v, help) in counters {
        scalar(&mut out, &format!("mcma_{name}_total"), "counter", help, v as f64);
    }

    // Gauge plane.
    let gauges: [(&str, f64, &str); 4] = [
        ("inflight", r.inflight.get() as f64, "Requests submitted but not yet dispatched."),
        ("batch_queue_depth", r.batch_queue_depth.get() as f64, "Rows waiting in the batcher."),
        ("open_breakers", r.open_breakers.get() as f64, "QoS breakers currently open."),
        ("qos_enabled", r.qos_enabled.get() as f64, "1 when the QoS controller is active."),
    ];
    for (name, v, help) in gauges {
        scalar(&mut out, &format!("mcma_{name}"), "gauge", help, v);
    }

    // Per-class QoS margins.
    head(
        &mut out,
        "mcma_qos_margin",
        "gauge",
        "Per-class routing confidence margin.",
    );
    for (k, g) in r.qos_margins.iter().enumerate() {
        sample(&mut out, "mcma_qos_margin", &format!("class=\"{k}\""), g.get() as f64);
    }

    // Per-tag request counts + overflow.
    head(
        &mut out,
        "mcma_tag_requests_total",
        "counter",
        "Frames per tenant tag (fixed-slot table).",
    );
    for (tag, count) in r.tags.snapshot() {
        sample(
            &mut out,
            "mcma_tag_requests_total",
            &format!("tag=\"{tag}\""),
            count as f64,
        );
    }
    scalar(
        &mut out,
        "mcma_tag_overflow_total",
        "counter",
        "Frames whose tag found no free slot.",
        r.tags.overflow() as f64,
    );

    // Trace journal health.
    scalar(
        &mut out,
        "mcma_trace_buffered",
        "gauge",
        "Span-journal events awaiting drain.",
        obs.journal.len() as f64,
    );
    scalar(
        &mut out,
        "mcma_trace_dropped_total",
        "counter",
        "Span-journal events evicted by the bounded ring.",
        obs.journal.dropped() as f64,
    );

    // Stage waterfall histograms (µs; log2 buckets — the `le` bounds
    // are each bucket's inclusive upper bound).
    let stages: [(&str, HistSnapshot, &str); 9] = [
        ("stage_decode_us", r.stage_decode.snapshot(), "Frame decode + submit."),
        ("stage_queue_us", r.stage_queue.snapshot(), "Submit -> batcher enqueue."),
        ("stage_batch_us", r.stage_batch.snapshot(), "Batcher enqueue -> worker receipt."),
        ("stage_execute_us", r.stage_execute.snapshot(), "Whole-batch classify/route/execute."),
        ("stage_fallback_us", r.stage_fallback.snapshot(), "Precise/lookup CPU path per batch."),
        ("stage_shadow_us", r.stage_shadow.snapshot(), "QoS shadow verification per observation."),
        ("stage_pump_us", r.stage_pump.snapshot(), "Worker dispatch -> client socket write."),
        ("e2e_dispatch_us", r.e2e_dispatch.snapshot(), "Submit -> response dispatched."),
        ("e2e_delivered_us", r.e2e_delivered.snapshot(), "Submit -> bytes on the client socket."),
    ];
    for (name, s, help) in &stages {
        hist(&mut out, &format!("mcma_{name}"), help, s);
    }

    // Per-route-class execute latency (only classes that ran).
    head(
        &mut out,
        "mcma_route_execute_us",
        "histogram",
        "Per-route-class GEMM execute latency.",
    );
    for k in 0..OBS_ROUTE_CLASSES {
        let s = r.route_execute_snapshot(k);
        if s.count == 0 {
            continue;
        }
        hist_series(&mut out, "mcma_route_execute_us", &format!("class=\"{k}\""), &s);
    }

    // SLO plane (present only when `--slo-p99-us` configured a monitor).
    if let Some(m) = slo {
        let (burn_short, burn_long) = m.burns();
        scalar(
            &mut out,
            "mcma_slo_healthy",
            "gauge",
            "1 while within budget; 0 during a breach (healthz mirrors this).",
            if m.healthy() { 1.0 } else { 0.0 },
        );
        head(
            &mut out,
            "mcma_slo_burn_rate",
            "gauge",
            "Windowed error-budget spend rate (1 = sustainable).",
        );
        sample(&mut out, "mcma_slo_burn_rate", "window=\"short\"", burn_short);
        sample(&mut out, "mcma_slo_burn_rate", "window=\"long\"", burn_long);
        scalar(
            &mut out,
            "mcma_slo_p99_target_us",
            "gauge",
            "Delivered-latency target.",
            m.config().p99_target_us as f64,
        );
        scalar(
            &mut out,
            "mcma_slo_error_budget",
            "gauge",
            "Fraction of requests allowed over target.",
            m.config().error_budget,
        );
    }

    out.push_str("# EOF\n");
    out
}

/// Parse exposition text back into `(series, value)` pairs, where
/// `series` is the metric name with its rendered label set
/// (`mcma_submitted_total`, `mcma_qos_margin{class="1"}`).  Used by the
/// format tests and the `bench-load` `/metrics`-vs-STATS cross-check.
pub fn parse_text(text: &str) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some((series, value)) = line.rsplit_once(' ') {
            if let Ok(v) = value.parse::<f64>() {
                out.push((series.to_string(), v));
            }
        }
    }
    out
}

/// Value of one series in parsed exposition output, if present.
pub fn series_value(parsed: &[(String, f64)], series: &str) -> Option<f64> {
    parsed.iter().find(|(n, _)| n == series).map(|(_, v)| *v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Event, Obs};

    /// Obs handle with a deterministic, fully-known population.
    fn seeded_obs() -> Obs {
        let obs = Obs::new(1, 1.0);
        let r = &obs.metrics;
        r.set_exec_mode("native");
        r.submitted.add(5);
        r.dispatched.add(5);
        r.delivered.add(4);
        r.delivery_failures.inc();
        for v in [0u64, 1, 1, 5, 1000] {
            r.stage_queue.record(v);
        }
        r.record_route_execute(1, 90);
        r.qos_margins[1].set(0.25);
        r.tags.record(3);
        r.tags.record(3);
        obs.journal.push(Event::ShadowDrop { at_us: 1 });
        obs
    }

    /// The golden exposition for [`seeded_obs`] (uptime line excluded —
    /// it is the one wall-clock-dependent sample).  Every format claim
    /// in the module docs is pinned here: `_total` suffixes, cumulative
    /// `le` bounds at the log2 buckets' inclusive upper bounds, label
    /// sets, `+Inf` = `_count`, and the trailing `# EOF`.
    const GOLDEN: &str = "\
# HELP mcma_exec_mode_info Execution engine serving approximator GEMMs (constant 1).
# TYPE mcma_exec_mode_info gauge
mcma_exec_mode_info{mode=\"native\"} 1
# HELP mcma_accepted_conns_total TCP connections accepted.
# TYPE mcma_accepted_conns_total counter
mcma_accepted_conns_total 0
# HELP mcma_closed_conns_total TCP connections closed.
# TYPE mcma_closed_conns_total counter
mcma_closed_conns_total 0
# HELP mcma_frames_in_total Well-formed request frames decoded.
# TYPE mcma_frames_in_total counter
mcma_frames_in_total 0
# HELP mcma_malformed_frames_total Connections killed for protocol violations.
# TYPE mcma_malformed_frames_total counter
mcma_malformed_frames_total 0
# HELP mcma_stats_requests_total In-band STATS scrapes answered.
# TYPE mcma_stats_requests_total counter
mcma_stats_requests_total 0
# HELP mcma_submitted_total Requests entering the pipeline.
# TYPE mcma_submitted_total counter
mcma_submitted_total 5
# HELP mcma_dispatched_total Responses dispatched by workers.
# TYPE mcma_dispatched_total counter
mcma_dispatched_total 5
# HELP mcma_delivered_total Responses written to client sockets.
# TYPE mcma_delivered_total counter
mcma_delivered_total 4
# HELP mcma_delivery_failures_total Responses owed to dead clients.
# TYPE mcma_delivery_failures_total counter
mcma_delivery_failures_total 1
# HELP mcma_route_invoked_rows_total Rows served by approximators.
# TYPE mcma_route_invoked_rows_total counter
mcma_route_invoked_rows_total 0
# HELP mcma_route_cpu_rows_total Rows served by the precise path.
# TYPE mcma_route_cpu_rows_total counter
mcma_route_cpu_rows_total 0
# HELP mcma_margin_moves_total QoS margin adjustments.
# TYPE mcma_margin_moves_total counter
mcma_margin_moves_total 0
# HELP mcma_breaker_trips_total QoS circuit-breaker opens.
# TYPE mcma_breaker_trips_total counter
mcma_breaker_trips_total 0
# HELP mcma_breaker_resets_total QoS circuit-breaker closes.
# TYPE mcma_breaker_resets_total counter
mcma_breaker_resets_total 0
# HELP mcma_shadow_drops_total Shadow observations lost to backpressure.
# TYPE mcma_shadow_drops_total counter
mcma_shadow_drops_total 0
# HELP mcma_slo_breaches_total Healthy -> breached SLO transitions.
# TYPE mcma_slo_breaches_total counter
mcma_slo_breaches_total 0
# HELP mcma_inflight Requests submitted but not yet dispatched.
# TYPE mcma_inflight gauge
mcma_inflight 0
# HELP mcma_batch_queue_depth Rows waiting in the batcher.
# TYPE mcma_batch_queue_depth gauge
mcma_batch_queue_depth 0
# HELP mcma_open_breakers QoS breakers currently open.
# TYPE mcma_open_breakers gauge
mcma_open_breakers 0
# HELP mcma_qos_enabled 1 when the QoS controller is active.
# TYPE mcma_qos_enabled gauge
mcma_qos_enabled 0
# HELP mcma_qos_margin Per-class routing confidence margin.
# TYPE mcma_qos_margin gauge
mcma_qos_margin{class=\"0\"} 0
mcma_qos_margin{class=\"1\"} 0.25
mcma_qos_margin{class=\"2\"} 0
mcma_qos_margin{class=\"3\"} 0
mcma_qos_margin{class=\"4\"} 0
mcma_qos_margin{class=\"5\"} 0
mcma_qos_margin{class=\"6\"} 0
mcma_qos_margin{class=\"7\"} 0
# HELP mcma_tag_requests_total Frames per tenant tag (fixed-slot table).
# TYPE mcma_tag_requests_total counter
mcma_tag_requests_total{tag=\"3\"} 2
# HELP mcma_tag_overflow_total Frames whose tag found no free slot.
# TYPE mcma_tag_overflow_total counter
mcma_tag_overflow_total 0
# HELP mcma_trace_buffered Span-journal events awaiting drain.
# TYPE mcma_trace_buffered gauge
mcma_trace_buffered 1
# HELP mcma_trace_dropped_total Span-journal events evicted by the bounded ring.
# TYPE mcma_trace_dropped_total counter
mcma_trace_dropped_total 0
# HELP mcma_stage_decode_us Frame decode + submit.
# TYPE mcma_stage_decode_us histogram
mcma_stage_decode_us_bucket{le=\"+Inf\"} 0
mcma_stage_decode_us_sum 0
mcma_stage_decode_us_count 0
# HELP mcma_stage_queue_us Submit -> batcher enqueue.
# TYPE mcma_stage_queue_us histogram
mcma_stage_queue_us_bucket{le=\"0\"} 1
mcma_stage_queue_us_bucket{le=\"1\"} 3
mcma_stage_queue_us_bucket{le=\"7\"} 4
mcma_stage_queue_us_bucket{le=\"1023\"} 5
mcma_stage_queue_us_bucket{le=\"+Inf\"} 5
mcma_stage_queue_us_sum 1007
mcma_stage_queue_us_count 5
# HELP mcma_stage_batch_us Batcher enqueue -> worker receipt.
# TYPE mcma_stage_batch_us histogram
mcma_stage_batch_us_bucket{le=\"+Inf\"} 0
mcma_stage_batch_us_sum 0
mcma_stage_batch_us_count 0
# HELP mcma_stage_execute_us Whole-batch classify/route/execute.
# TYPE mcma_stage_execute_us histogram
mcma_stage_execute_us_bucket{le=\"+Inf\"} 0
mcma_stage_execute_us_sum 0
mcma_stage_execute_us_count 0
# HELP mcma_stage_fallback_us Precise/lookup CPU path per batch.
# TYPE mcma_stage_fallback_us histogram
mcma_stage_fallback_us_bucket{le=\"+Inf\"} 0
mcma_stage_fallback_us_sum 0
mcma_stage_fallback_us_count 0
# HELP mcma_stage_shadow_us QoS shadow verification per observation.
# TYPE mcma_stage_shadow_us histogram
mcma_stage_shadow_us_bucket{le=\"+Inf\"} 0
mcma_stage_shadow_us_sum 0
mcma_stage_shadow_us_count 0
# HELP mcma_stage_pump_us Worker dispatch -> client socket write.
# TYPE mcma_stage_pump_us histogram
mcma_stage_pump_us_bucket{le=\"+Inf\"} 0
mcma_stage_pump_us_sum 0
mcma_stage_pump_us_count 0
# HELP mcma_e2e_dispatch_us Submit -> response dispatched.
# TYPE mcma_e2e_dispatch_us histogram
mcma_e2e_dispatch_us_bucket{le=\"+Inf\"} 0
mcma_e2e_dispatch_us_sum 0
mcma_e2e_dispatch_us_count 0
# HELP mcma_e2e_delivered_us Submit -> bytes on the client socket.
# TYPE mcma_e2e_delivered_us histogram
mcma_e2e_delivered_us_bucket{le=\"+Inf\"} 0
mcma_e2e_delivered_us_sum 0
mcma_e2e_delivered_us_count 0
# HELP mcma_route_execute_us Per-route-class GEMM execute latency.
# TYPE mcma_route_execute_us histogram
mcma_route_execute_us_bucket{class=\"1\",le=\"127\"} 1
mcma_route_execute_us_bucket{class=\"1\",le=\"+Inf\"} 1
mcma_route_execute_us_sum{class=\"1\"} 90
mcma_route_execute_us_count{class=\"1\"} 1
# EOF
";

    #[test]
    fn golden_exposition() {
        let obs = seeded_obs();
        let text = render(&obs, None);
        // Drop the one wall-clock-dependent family (uptime: HELP, TYPE
        // and sample are the first three lines).
        let got: Vec<&str> = text
            .lines()
            .filter(|l| !l.contains("mcma_uptime_seconds"))
            .collect();
        let want: Vec<&str> = GOLDEN.lines().collect();
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert_eq!(g, w, "exposition line {i} diverged");
        }
        assert_eq!(got.len(), want.len(), "exposition length diverged");
    }

    #[test]
    fn label_values_are_escaped() {
        let obs = Obs::new(1, 1.0);
        obs.metrics.set_exec_mode("na\"ti\\ve\nx");
        let text = render(&obs, None);
        assert!(
            text.contains("mcma_exec_mode_info{mode=\"na\\\"ti\\\\ve\\nx\"} 1"),
            "{text}"
        );
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\\b\"c\nd"), "a\\\\b\\\"c\\nd");
    }

    /// `le` buckets must be cumulative and monotone, the `+Inf` bucket
    /// must equal `_count`, and the per-bucket deltas must sum to it.
    #[test]
    fn histogram_buckets_are_cumulative_and_sum_to_count() {
        let obs = Obs::new(1, 1.0);
        let mut rng = crate::util::rng::Rng::new(0xBEEF);
        for _ in 0..5_000 {
            obs.metrics.e2e_delivered.record(rng.below(1 << 22));
        }
        let text = render(&obs, None);
        let mut prev_le = -1.0f64;
        let mut prev_cum = 0.0f64;
        let mut inf = None;
        for (series, v) in parse_text(&text) {
            let Some(rest) = series.strip_prefix("mcma_e2e_delivered_us_bucket{le=\"") else {
                continue;
            };
            let le = rest.trim_end_matches("\"}");
            if le == "+Inf" {
                inf = Some(v);
                continue;
            }
            let le: f64 = le.parse().expect("numeric le bound");
            assert!(le > prev_le, "le bounds must increase: {le} after {prev_le}");
            assert!(v >= prev_cum, "bucket series must be cumulative");
            prev_le = le;
            prev_cum = v;
        }
        let parsed = parse_text(&text);
        let count = series_value(&parsed, "mcma_e2e_delivered_us_count").unwrap();
        assert_eq!(count, 5000.0);
        assert_eq!(inf, Some(count), "+Inf bucket must equal _count");
        assert_eq!(prev_cum, count, "last finite bucket holds every sample here");
    }

    #[test]
    fn slo_families_render_when_configured() {
        use crate::obs::slo::{SloConfig, SloMonitor};
        let obs = Obs::new(1, 1.0);
        let slo = SloMonitor::new(SloConfig::new(1_000, 0.01));
        slo.tick(1_000_000, 100, 0);
        let text = render(&obs, Some(&slo));
        let parsed = parse_text(&text);
        assert_eq!(series_value(&parsed, "mcma_slo_healthy"), Some(1.0));
        assert_eq!(series_value(&parsed, "mcma_slo_p99_target_us"), Some(1000.0));
        assert_eq!(series_value(&parsed, "mcma_slo_error_budget"), Some(0.01));
        assert_eq!(
            series_value(&parsed, "mcma_slo_burn_rate{window=\"short\"}"),
            Some(0.0)
        );
        // Absent without a monitor.
        assert!(!render(&obs, None).contains("mcma_slo_healthy"));
    }

    #[test]
    fn every_family_has_a_type_line_and_counters_end_in_total() {
        let text = render(&seeded_obs(), None);
        let mut typed: Vec<(String, String)> = Vec::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                if let Some((name, kind)) = rest.split_once(' ') {
                    typed.push((name.to_string(), kind.to_string()));
                }
            }
        }
        for (series, _) in parse_text(&text) {
            let name = series.split('{').next().unwrap_or(&series);
            let family = typed.iter().find(|(n, k)| {
                name == *n
                    || (k == "histogram"
                        && (name == format!("{n}_bucket")
                            || name == format!("{n}_sum")
                            || name == format!("{n}_count")))
            });
            let (_, kind) = family.unwrap_or_else(|| panic!("no # TYPE for {series}"));
            if kind == "counter" {
                assert!(name.ends_with("_total"), "counter {name} must end in _total");
            }
        }
    }
}
