// audit:deterministic — the conversion is a pure function of the input
// journal text: no clocks, no hash-ordered containers, so the exported
// trace is byte-identical for a given drain.
//! Chrome trace-event export (`mcma trace`): converts the span journal's
//! JSON-lines drain (`serve --trace-json PATH`) into the trace-event
//! array format that `ui.perfetto.dev` and `chrome://tracing` open
//! directly.
//!
//! Mapping:
//!
//! * each sampled request span becomes three contiguous `ph:"X"`
//!   duration events — `queue` → `batch` → `execute` — reconstructed
//!   backwards from the dispatch timestamp (`at_us`) and the recorded
//!   stage durations, on one track (`tid`) per client connection
//!   (the high 32 bits of the request id, the `net/frame.rs` id split);
//! * a `delivered` event adds the `pump` slice ending at delivery;
//! * QoS control-plane events (margin moves, breaker transitions,
//!   shadow drops) and SLO breach transitions become `ph:"i"` instant
//!   events on the control track (`tid` 0), carrying their class in
//!   `args` so Perfetto's query layer can facet on it;
//! * `ph:"M"` metadata events name the process and every track.
//!
//! Timestamps are already microseconds since serve start — exactly the
//! trace-event `ts` unit — so no rescaling happens.

use std::collections::BTreeSet;

use crate::util::json::{self, Value};

/// Control-plane track id (QoS + SLO instants).
const CONTROL_TID: u64 = 0;

fn num(n: u64) -> Value {
    Value::Num(n as f64)
}

/// One `ph:"X"` complete-duration event.
fn duration(name: &str, ts: u64, dur: u64, tid: u64, args: Value) -> Value {
    json::obj(vec![
        ("name", Value::Str(name.to_string())),
        ("cat", Value::Str("request".to_string())),
        ("ph", Value::Str("X".to_string())),
        ("ts", num(ts)),
        ("dur", num(dur)),
        ("pid", num(1)),
        ("tid", num(tid)),
        ("args", args),
    ])
}

/// One `ph:"i"` instant event on the control track (global scope so the
/// marker line spans every track in the viewer).
fn instant(name: &str, ts: u64, args: Value) -> Value {
    json::obj(vec![
        ("name", Value::Str(name.to_string())),
        ("cat", Value::Str("control".to_string())),
        ("ph", Value::Str("i".to_string())),
        ("s", Value::Str("g".to_string())),
        ("ts", num(ts)),
        ("pid", num(1)),
        ("tid", num(CONTROL_TID)),
        ("args", args),
    ])
}

/// One `ph:"M"` metadata event.
fn metadata(name: &str, tid: u64, label: &str) -> Value {
    json::obj(vec![
        ("name", Value::Str(name.to_string())),
        ("ph", Value::Str("M".to_string())),
        ("pid", num(1)),
        ("tid", num(tid)),
        (
            "args",
            json::obj(vec![("name", Value::Str(label.to_string()))]),
        ),
    ])
}

fn field_u64(v: &Value, key: &str) -> crate::Result<u64> {
    let n = v
        .req(key)?
        .as_f64()
        .ok_or_else(|| anyhow::anyhow!("field {key:?} is not a number"))?;
    anyhow::ensure!(n >= 0.0, "field {key:?} is negative");
    Ok(n as u64)
}

fn field_f64(v: &Value, key: &str) -> crate::Result<f64> {
    v.req(key)?
        .as_f64()
        .ok_or_else(|| anyhow::anyhow!("field {key:?} is not a number"))
}

/// Convert one journal drain (newline-delimited event JSON) into a
/// Chrome trace-event array.  Unknown event types are skipped (forward
/// compatibility); malformed lines fail with their line number.
pub fn convert(jsonl: &str) -> crate::Result<Value> {
    let mut events: Vec<Value> = Vec::new();
    let mut conn_tids: BTreeSet<u64> = BTreeSet::new();
    let mut control_events = 0usize;

    for (i, line) in jsonl.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let lineno = i + 1;
        let v = json::parse(line)
            .map_err(|e| anyhow::anyhow!("journal line {lineno}: {e}"))?;
        let kind = v.get("type").and_then(Value::as_str).unwrap_or("");
        let result = match kind {
            "span" => span_events(&v, &mut events, &mut conn_tids),
            "delivered" => delivered_event(&v, &mut events, &mut conn_tids),
            "margin" => {
                control_events += 1;
                margin_event(&v, &mut events)
            }
            "breaker" => {
                control_events += 1;
                breaker_event(&v, &mut events)
            }
            "shadow_drop" => {
                control_events += 1;
                field_u64(&v, "at_us").map(|at| {
                    events.push(instant("shadow-drop", at, json::obj(vec![])));
                })
            }
            "slo" => {
                control_events += 1;
                slo_event(&v, &mut events)
            }
            // Unknown kinds from newer journals: skip, don't fail.
            _ => Ok(()),
        };
        result.map_err(|e| anyhow::anyhow!("journal line {lineno}: {e}"))?;
    }

    let mut out: Vec<Value> = Vec::new();
    out.push(metadata("process_name", CONTROL_TID, "mcma serve"));
    if control_events > 0 {
        out.push(metadata("thread_name", CONTROL_TID, "qos/slo control"));
    }
    for &tid in &conn_tids {
        out.push(metadata("thread_name", tid, &format!("conn-{tid}")));
    }
    out.extend(events);
    Ok(Value::Arr(out))
}

/// A span's stage stack, reconstructed backwards from dispatch:
/// `execute` ends at `at_us`, `batch` ends where `execute` starts,
/// `queue` ends where `batch` starts — contiguous by construction.
fn span_events(
    v: &Value,
    events: &mut Vec<Value>,
    conn_tids: &mut BTreeSet<u64>,
) -> crate::Result<()> {
    let id = field_u64(v, "id")?;
    let route = field_f64(v, "route")?;
    let queue_us = field_u64(v, "queue_us")?;
    let batch_us = field_u64(v, "batch_us")?;
    let exec_us = field_u64(v, "exec_us")?;
    let at_us = field_u64(v, "at_us")?;
    let tid = id >> 32;
    conn_tids.insert(tid);

    let exec_start = at_us.saturating_sub(exec_us);
    let batch_start = exec_start.saturating_sub(batch_us);
    let queue_start = batch_start.saturating_sub(queue_us);
    let args = json::obj(vec![("id", num(id)), ("route", Value::Num(route))]);
    events.push(duration("queue", queue_start, batch_start - queue_start, tid, args.clone()));
    events.push(duration("batch", batch_start, exec_start - batch_start, tid, args.clone()));
    events.push(duration("execute", exec_start, at_us - exec_start, tid, args));
    Ok(())
}

fn delivered_event(
    v: &Value,
    events: &mut Vec<Value>,
    conn_tids: &mut BTreeSet<u64>,
) -> crate::Result<()> {
    let id = field_u64(v, "id")?;
    let pump_us = field_u64(v, "pump_us")?;
    let e2e_us = field_u64(v, "e2e_us")?;
    let at_us = field_u64(v, "at_us")?;
    let tid = id >> 32;
    conn_tids.insert(tid);
    let start = at_us.saturating_sub(pump_us);
    let args = json::obj(vec![("id", num(id)), ("e2e_us", num(e2e_us))]);
    events.push(duration("pump", start, at_us - start, tid, args));
    Ok(())
}

fn margin_event(v: &Value, events: &mut Vec<Value>) -> crate::Result<()> {
    let class = field_u64(v, "class")?;
    let from = field_f64(v, "from")?;
    let to = field_f64(v, "to")?;
    let at_us = field_u64(v, "at_us")?;
    let args = json::obj(vec![
        ("class", num(class)),
        ("from", Value::Num(from)),
        ("to", Value::Num(to)),
    ]);
    events.push(instant("margin-move", at_us, args));
    Ok(())
}

fn breaker_event(v: &Value, events: &mut Vec<Value>) -> crate::Result<()> {
    let class = field_u64(v, "class")?;
    let open = v
        .req("open")?
        .as_bool()
        .ok_or_else(|| anyhow::anyhow!("field \"open\" is not a bool"))?;
    let at_us = field_u64(v, "at_us")?;
    let name = if open { "breaker-open" } else { "breaker-close" };
    events.push(instant(name, at_us, json::obj(vec![("class", num(class))])));
    Ok(())
}

fn slo_event(v: &Value, events: &mut Vec<Value>) -> crate::Result<()> {
    let breached = v
        .req("breached")?
        .as_bool()
        .ok_or_else(|| anyhow::anyhow!("field \"breached\" is not a bool"))?;
    let burn_short = field_f64(v, "burn_short")?;
    let burn_long = field_f64(v, "burn_long")?;
    let at_us = field_u64(v, "at_us")?;
    let name = if breached { "slo-breach" } else { "slo-recover" };
    let args = json::obj(vec![
        ("burn_short", Value::Num(burn_short)),
        ("burn_long", Value::Num(burn_long)),
    ]);
    events.push(instant(name, at_us, args));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Event, Journal};

    /// Journal drain with two connections' spans and every control kind.
    fn sample_drain() -> String {
        let j = Journal::new(1, 1.0, 64);
        let id_a = (3u64 << 32) | 7; // conn 3
        let id_b = (5u64 << 32) | 1; // conn 5
        j.push(Event::Span {
            id: id_a,
            route: 2,
            queue_us: 10,
            batch_us: 20,
            exec_us: 30,
            e2e_us: 60,
            at_us: 1_000,
        });
        j.push(Event::Delivered { id: id_a, pump_us: 5, e2e_us: 65, at_us: 1_005 });
        j.push(Event::Span {
            id: id_b,
            route: -1,
            queue_us: 1,
            batch_us: 2,
            exec_us: 3,
            e2e_us: 6,
            at_us: 2_000,
        });
        j.push(Event::MarginMove { class: 4, from: 0.0, to: 0.05, at_us: 1_500 });
        j.push(Event::Breaker { class: 4, open: true, at_us: 1_600 });
        j.push(Event::ShadowDrop { at_us: 1_700 });
        j.push(Event::Slo { breached: true, burn_short: 20.0, burn_long: 3.0, at_us: 1_800 });
        j.drain_json_lines()
    }

    fn events_of<'a>(arr: &'a [Value], ph: &str) -> Vec<&'a Value> {
        arr.iter()
            .filter(|e| e.get("ph").and_then(Value::as_str) == Some(ph))
            .collect()
    }

    #[test]
    fn exports_a_valid_trace_event_array() {
        let v = convert(&sample_drain()).expect("conversion succeeds");
        // Roundtrips through the writer as a bare JSON array.
        let reparsed = json::parse(&json::write(&v)).expect("valid JSON");
        let arr = reparsed.as_arr().expect("top level is an array");
        assert!(!arr.is_empty());
        for e in arr {
            let ph = e.get("ph").and_then(Value::as_str).expect("ph");
            assert!(["X", "i", "M"].contains(&ph), "unexpected ph {ph}");
            assert!(e.get("pid").and_then(Value::as_f64).is_some());
            assert!(e.get("tid").and_then(Value::as_f64).is_some());
            if ph != "M" {
                assert!(e.get("ts").and_then(Value::as_f64).is_some());
            }
            if ph == "X" {
                assert!(e.get("dur").and_then(Value::as_f64).is_some());
            }
        }
        // Tracks got named: process + control + conns 3 and 5.
        let meta = events_of(arr, "M");
        let labels: Vec<&str> = meta
            .iter()
            .filter_map(|e| e.get("args")?.get("name")?.as_str())
            .collect();
        assert!(labels.contains(&"mcma serve"));
        assert!(labels.contains(&"qos/slo control"));
        assert!(labels.contains(&"conn-3"));
        assert!(labels.contains(&"conn-5"));
    }

    /// Every sampled id yields a contiguous, non-overlapping
    /// queue → batch → execute (→ pump) stack on its connection track.
    #[test]
    fn stage_stacks_are_contiguous_and_non_overlapping() {
        let v = convert(&sample_drain()).unwrap();
        let arr = v.as_arr().unwrap();
        let ids: Vec<u64> = vec![(3u64 << 32) | 7, (5u64 << 32) | 1];
        for id in ids {
            let mut slices: Vec<(String, u64, u64)> = events_of(arr, "X")
                .iter()
                .filter(|e| {
                    e.get("args")
                        .and_then(|a| a.get("id"))
                        .and_then(Value::as_f64)
                        == Some(id as f64)
                })
                .map(|e| {
                    let name = e.get("name").unwrap().as_str().unwrap().to_string();
                    let ts = e.get("ts").unwrap().as_f64().unwrap() as u64;
                    let dur = e.get("dur").unwrap().as_f64().unwrap() as u64;
                    (name, ts, dur)
                })
                .collect();
            slices.sort_by_key(|&(_, ts, _)| ts);
            assert!(slices.len() >= 3, "span stack for id {id}");
            for pair in slices.windows(2) {
                let (_, ts0, dur0) = &pair[0];
                let (_, ts1, _) = &pair[1];
                assert!(ts0 + dur0 <= *ts1, "overlap in {slices:?}");
            }
            // The first three stages are exactly contiguous.
            let names: Vec<&str> = slices.iter().take(3).map(|(n, _, _)| n.as_str()).collect();
            assert_eq!(names, ["queue", "batch", "execute"]);
            for pair in slices.windows(2).take(2) {
                assert_eq!(pair[0].1 + pair[0].2, pair[1].1, "gap in {slices:?}");
            }
            // The stack ends at the recorded dispatch timestamp.
            let (_, ts, dur) = &slices[2];
            assert!(*ts + *dur == 1_000 || *ts + *dur == 2_000);
        }
        // Tracks are per-connection.
        let tids: BTreeSet<u64> = events_of(arr, "X")
            .iter()
            .map(|e| e.get("tid").unwrap().as_f64().unwrap() as u64)
            .collect();
        assert_eq!(tids, BTreeSet::from([3, 5]));
    }

    #[test]
    fn instants_carry_the_class_label() {
        let v = convert(&sample_drain()).unwrap();
        let arr = v.as_arr().unwrap();
        let instants = events_of(arr, "i");
        assert_eq!(instants.len(), 4);
        for e in &instants {
            assert_eq!(e.get("s").and_then(Value::as_str), Some("g"));
            assert_eq!(e.get("tid").and_then(Value::as_f64), Some(0.0));
        }
        let by_name = |n: &str| {
            instants
                .iter()
                .find(|e| e.get("name").and_then(Value::as_str) == Some(n))
                .unwrap_or_else(|| panic!("missing instant {n}"))
        };
        let margin = by_name("margin-move");
        assert_eq!(margin.get("args").unwrap().get("class").unwrap().as_f64(), Some(4.0));
        let breaker = by_name("breaker-open");
        assert_eq!(breaker.get("args").unwrap().get("class").unwrap().as_f64(), Some(4.0));
        let slo = by_name("slo-breach");
        assert_eq!(slo.get("args").unwrap().get("burn_short").unwrap().as_f64(), Some(20.0));
        by_name("shadow-drop");
    }

    #[test]
    fn malformed_lines_fail_with_their_line_number() {
        let bad = "{\"type\":\"shadow_drop\",\"at_us\":1}\nnot json\n";
        let err = convert(bad).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        // Missing fields also name the line.
        let missing = "{\"type\":\"span\",\"id\":1}";
        let err = convert(missing).unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn unknown_event_kinds_are_skipped() {
        let mixed = "{\"type\":\"future_kind\",\"x\":1}\n{\"type\":\"shadow_drop\",\"at_us\":9}\n";
        let v = convert(mixed).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(events_of(arr, "i").len(), 1);
    }

    #[test]
    fn empty_drain_still_yields_a_valid_array() {
        let v = convert("").unwrap();
        let arr = v.as_arr().unwrap();
        // Just the process metadata.
        assert_eq!(arr.len(), 1);
    }
}
