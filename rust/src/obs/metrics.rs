//! Lock-free metrics: counters, gauges and log2-bucketed latency
//! histograms ([`Hist64`]), composed into the fixed-shape [`Registry`]
//! every serving thread shares.
//!
//! Everything here is wait-free on the record path: one to three
//! `fetch_add`s per event, no locks, no allocation.  All atomics are
//! `Relaxed` — these are monotone counters whose snapshots feed reports,
//! never synchronisation (the same contract as `coordinator/metrics.rs`;
//! both modules are allowlisted by the `atomics` audit rule).  A snapshot
//! may therefore tear by a few in-flight records across cells; quantiles
//! are bucket-bounded anyway, so the tear sits below the measurement's
//! own resolution.
//!
//! ## Histogram semantics
//!
//! [`Hist64`] buckets a `u64` sample (microseconds on every stage
//! histogram) by bit width: bucket 0 holds exact zeros, bucket `i >= 1`
//! holds `[2^(i-1), 2^i - 1]`, and bucket 63 absorbs everything from
//! `2^62` up.  Quantiles interpolate linearly inside the landing bucket,
//! so a reported pXX is **bucket-bounded**: the true quantile lies in the
//! same power-of-two bucket, i.e. within 2x of the reported value (the
//! bucket bounds themselves are exact).  Snapshots merge cellwise —
//! associative and commutative, so per-thread histograms fold in any
//! order.

use std::sync::atomic::{AtomicI64, AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::{self, Value};

/// Monotone event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-writer-wins instantaneous value (queue depth, open breakers).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// f32 gauge stored as bits (the QoS margins in the scrape) — same
/// publish discipline as `coordinator::server`'s margin atomics.
#[derive(Debug, Default)]
pub struct GaugeF32(AtomicU32);

impl GaugeF32 {
    pub fn set(&self, v: f32) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f32 {
        f32::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Log2-bucketed histogram: 64 atomic cells + count + sum (for means).
#[derive(Debug)]
pub struct Hist64 {
    buckets: [AtomicU64; 64],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Hist64 {
    fn default() -> Self {
        Hist64::new()
    }
}

impl Hist64 {
    pub fn new() -> Self {
        Hist64 {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Bucket for value `v`: 0 for 0, else its bit width (clamped to 63).
    #[inline]
    pub fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            (64 - v.leading_zeros() as usize).min(63)
        }
    }

    /// Inclusive lower bound of bucket `i`.
    pub fn bucket_lo(i: usize) -> u64 {
        if i == 0 {
            0
        } else {
            1u64 << (i - 1)
        }
    }

    /// Inclusive upper bound of bucket `i`.
    pub fn bucket_hi(i: usize) -> u64 {
        match i {
            0 => 0,
            63 => u64::MAX,
            i => (1u64 << i) - 1,
        }
    }

    /// Record one sample — three relaxed adds, wait-free.
    #[inline]
    pub fn record(&self, v: u64) {
        if let Some(cell) = self.buckets.get(Self::bucket_index(v)) {
            cell.fetch_add(1, Ordering::Relaxed);
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data copy of a [`Hist64`] — mergeable, serialisable.
#[derive(Clone, Copy, Debug)]
pub struct HistSnapshot {
    pub buckets: [u64; 64],
    pub count: u64,
    pub sum: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot { buckets: [0; 64], count: 0, sum: 0 }
    }
}

impl HistSnapshot {
    /// Cellwise sum — associative and commutative.
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += *b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile `p` in `[0, 100]`, linearly interpolated inside the
    /// landing bucket (bucket-bounded; see module docs).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (p / 100.0).clamp(0.0, 1.0) * self.count as f64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if (cum + c) as f64 >= rank {
                let lo = Hist64::bucket_lo(i) as f64;
                let hi = Hist64::bucket_hi(i) as f64;
                let frac = ((rank - cum as f64) / c as f64).clamp(0.0, 1.0);
                return lo + frac * (hi - lo);
            }
            cum += c;
        }
        Hist64::bucket_hi(63) as f64
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p90(&self) -> f64 {
        self.percentile(90.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn p999(&self) -> f64 {
        self.percentile(99.9)
    }

    /// Samples strictly above `v`, linearly interpolated inside the
    /// bucket that straddles it — the same bucket-bounded contract as
    /// [`Self::percentile`].  Feeds the SLO monitor's "bad event" count
    /// (delivered requests over the latency target).
    pub fn count_over(&self, v: u64) -> u64 {
        let mut over = 0.0f64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let lo = Hist64::bucket_lo(i);
            let hi = Hist64::bucket_hi(i);
            if lo > v {
                over += c as f64;
            } else if hi > v {
                // `v` splits this bucket; assume uniform occupancy.
                let width = (hi - lo) as f64 + 1.0;
                over += ((hi - v) as f64 / width) * c as f64;
            }
        }
        over.round().min(self.count as f64) as u64
    }

    /// Upper bound of the highest populated bucket.
    pub fn max_bound(&self) -> u64 {
        self.buckets
            .iter()
            .enumerate()
            .rev()
            .find(|(_, &c)| c > 0)
            .map(|(i, _)| Hist64::bucket_hi(i))
            .unwrap_or(0)
    }

    /// Compact JSON: summary quantiles + sparse `[bucket, count]` pairs.
    pub fn to_json(&self) -> Value {
        let buckets: Vec<Value> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| Value::Arr(vec![Value::Num(i as f64), Value::Num(c as f64)]))
            .collect();
        json::obj(vec![
            ("count", Value::Num(self.count as f64)),
            ("sum_us", Value::Num(self.sum as f64)),
            ("mean_us", Value::Num(self.mean())),
            ("p50_us", Value::Num(self.p50())),
            ("p90_us", Value::Num(self.p90())),
            ("p99_us", Value::Num(self.p99())),
            ("p999_us", Value::Num(self.p999())),
            ("max_us", Value::Num(self.max_bound() as f64)),
            ("buckets", Value::Arr(buckets)),
        ])
    }
}

/// Number of fixed per-tenant-tag slots in [`TagTable`].
pub const TAG_SLOTS: usize = 16;

/// Fixed-slot per-tenant-tag request counts: [`TAG_SLOTS`] CAS-registered
/// slots + an overflow counter, so the hot path stays allocation- and
/// lock-free no matter how many distinct tags clients send.
#[derive(Debug)]
pub struct TagTable {
    /// `(tag + 1, count)`; a slot key of 0 means empty (tag 0 is valid).
    slots: [(AtomicU64, AtomicU64); TAG_SLOTS],
    overflow: AtomicU64,
}

impl Default for TagTable {
    fn default() -> Self {
        TagTable::new()
    }
}

impl TagTable {
    pub fn new() -> Self {
        TagTable {
            slots: std::array::from_fn(|_| (AtomicU64::new(0), AtomicU64::new(0))),
            overflow: AtomicU64::new(0),
        }
    }

    /// Count one request for `tag`, claiming a free slot on first sight.
    pub fn record(&self, tag: u16) {
        let key = tag as u64 + 1;
        for (slot_key, count) in self.slots.iter() {
            let cur = slot_key.load(Ordering::Relaxed);
            if cur == key {
                count.fetch_add(1, Ordering::Relaxed);
                return;
            }
            if cur == 0 {
                // Claim the empty slot; if another thread won the race
                // with the SAME tag the slot is still ours to count in.
                match slot_key.compare_exchange(
                    0,
                    key,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        count.fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                    Err(winner) if winner == key => {
                        count.fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                    Err(_) => continue,
                }
            }
        }
        self.overflow.fetch_add(1, Ordering::Relaxed);
    }

    /// `(tag, count)` for every claimed slot, in slot order.
    pub fn snapshot(&self) -> Vec<(u16, u64)> {
        self.slots
            .iter()
            .filter_map(|(slot_key, count)| {
                let key = slot_key.load(Ordering::Relaxed);
                (key != 0).then(|| ((key - 1) as u16, count.load(Ordering::Relaxed)))
            })
            .collect()
    }

    /// Requests whose tag found no free slot (counted, never lost).
    pub fn overflow(&self) -> u64 {
        self.overflow.load(Ordering::Relaxed)
    }
}

/// Per-route-class execute histograms the registry carries.  Routes
/// `k >= OBS_ROUTE_CLASSES` fold into the last slot so the registry's
/// shape is fixed regardless of the served method's approximator count
/// (every paper method has well under 8 classes).
pub const OBS_ROUTE_CLASSES: usize = 8;

/// The fixed-shape metrics registry every serving thread shares.
///
/// Stage histograms decompose one request's life into a waterfall, all
/// in microseconds on the monotonic clock:
///
/// * `stage_decode`    — reader thread: frame decode + submit call;
/// * `stage_queue`     — submit → batcher enqueue (ingress channel hop);
/// * `stage_batch`     — batcher enqueue → dispatch-worker receipt
///   (coalescing wait + batch channel hop);
/// * `stage_execute`   — whole-batch classify/route/execute (recorded
///   once per row so stage quantiles compose with the e2e ones);
/// * `route_execute[k]`— per-route-class GEMM forward (one sample per
///   executed group, batch-level; `exec_mode` says f32 vs int8);
/// * `stage_fallback`  — precise/lookup CPU path (one sample per batch
///   that had rejects);
/// * `stage_shadow`    — QoS shadow verification per observation (off
///   the request path);
/// * `stage_pump`      — worker dispatch → client socket write;
/// * `e2e_dispatch`    — submit → response dispatched (the served
///   latency, `Response::latency_us`);
/// * `e2e_delivered`   — submit → bytes written to the client; only
///   successful deliveries are recorded, so dead clients can't skew it
///   (failures land in `delivery_failures` instead).
///
/// `queue + batch + execute` sums to `e2e_dispatch` per request (up to
/// clock-read skew), and `e2e_dispatch + pump` to `e2e_delivered` —
/// stage quantiles are therefore consistent with the end-to-end ones
/// within the documented bucket error.
#[derive(Debug)]
pub struct Registry {
    t0: Instant,
    exec_mode: Mutex<String>,

    // Connection / frame plane.
    pub accepted_conns: Counter,
    pub closed_conns: Counter,
    pub frames_in: Counter,
    pub malformed_frames: Counter,
    pub stats_requests: Counter,

    // Request plane.
    pub submitted: Counter,
    pub dispatched: Counter,
    pub delivered: Counter,
    pub delivery_failures: Counter,
    pub route_invoked_rows: Counter,
    pub route_cpu_rows: Counter,

    // QoS decision plane.
    pub margin_moves: Counter,
    pub breaker_trips: Counter,
    pub breaker_resets: Counter,
    pub shadow_drops: Counter,

    // SLO plane (incremented by the serve-side burn-rate glue on each
    // healthy -> breached transition; see `obs/slo.rs`).
    pub slo_breaches: Counter,

    pub inflight: Gauge,
    pub batch_queue_depth: Gauge,
    pub open_breakers: Gauge,
    pub qos_enabled: Gauge,

    pub stage_decode: Hist64,
    pub stage_queue: Hist64,
    pub stage_batch: Hist64,
    pub stage_execute: Hist64,
    pub stage_fallback: Hist64,
    pub stage_shadow: Hist64,
    pub stage_pump: Hist64,
    pub e2e_dispatch: Hist64,
    pub e2e_delivered: Hist64,
    route_execute: [Hist64; OBS_ROUTE_CLASSES],

    pub qos_margins: [GaugeF32; OBS_ROUTE_CLASSES],
    pub tags: TagTable,
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Registry {
            t0: Instant::now(),
            exec_mode: Mutex::new(String::new()),
            accepted_conns: Counter::default(),
            closed_conns: Counter::default(),
            frames_in: Counter::default(),
            malformed_frames: Counter::default(),
            stats_requests: Counter::default(),
            submitted: Counter::default(),
            dispatched: Counter::default(),
            delivered: Counter::default(),
            delivery_failures: Counter::default(),
            route_invoked_rows: Counter::default(),
            route_cpu_rows: Counter::default(),
            margin_moves: Counter::default(),
            breaker_trips: Counter::default(),
            breaker_resets: Counter::default(),
            shadow_drops: Counter::default(),
            slo_breaches: Counter::default(),
            inflight: Gauge::default(),
            batch_queue_depth: Gauge::default(),
            open_breakers: Gauge::default(),
            qos_enabled: Gauge::default(),
            stage_decode: Hist64::new(),
            stage_queue: Hist64::new(),
            stage_batch: Hist64::new(),
            stage_execute: Hist64::new(),
            stage_fallback: Hist64::new(),
            stage_shadow: Hist64::new(),
            stage_pump: Hist64::new(),
            e2e_dispatch: Hist64::new(),
            e2e_delivered: Hist64::new(),
            route_execute: std::array::from_fn(|_| Hist64::new()),
            qos_margins: std::array::from_fn(|_| GaugeF32::default()),
            tags: TagTable::new(),
        }
    }

    /// Seconds since the registry was created (serve start).
    pub fn uptime_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Label the execution engine for the scrape ("native", "native-q8",
    /// "pjrt") — distinguishes f32 from int8 GEMM in `route_execute`.
    pub fn set_exec_mode(&self, mode: &str) {
        if let Ok(mut g) = self.exec_mode.lock() {
            *g = mode.to_string();
        }
    }

    /// Current execution-engine label (empty until [`Self::set_exec_mode`]).
    pub fn exec_mode(&self) -> String {
        self.exec_mode.lock().map(|g| g.clone()).unwrap_or_default()
    }

    /// One per-route-class GEMM execute sample (class folds into the
    /// last slot past [`OBS_ROUTE_CLASSES`]).
    pub fn record_route_execute(&self, k: usize, us: u64) {
        if let Some(h) = self.route_execute.get(k.min(OBS_ROUTE_CLASSES - 1)) {
            h.record(us);
        }
    }

    pub fn route_execute_snapshot(&self, k: usize) -> HistSnapshot {
        self.route_execute
            .get(k)
            .map(|h| h.snapshot())
            .unwrap_or_default()
    }

    /// Full JSON snapshot — the STATS scrape body (minus journal health,
    /// which [`crate::obs::Obs::snapshot_json`] appends).
    pub fn snapshot_json(&self) -> Value {
        fn num(n: u64) -> Value {
            Value::Num(n as f64)
        }
        let counters = json::obj(vec![
            ("accepted_conns", num(self.accepted_conns.get())),
            ("closed_conns", num(self.closed_conns.get())),
            ("frames_in", num(self.frames_in.get())),
            ("malformed_frames", num(self.malformed_frames.get())),
            ("stats_requests", num(self.stats_requests.get())),
            ("submitted", num(self.submitted.get())),
            ("dispatched", num(self.dispatched.get())),
            ("delivered", num(self.delivered.get())),
            ("delivery_failures", num(self.delivery_failures.get())),
            ("route_invoked_rows", num(self.route_invoked_rows.get())),
            ("route_cpu_rows", num(self.route_cpu_rows.get())),
            ("margin_moves", num(self.margin_moves.get())),
            ("breaker_trips", num(self.breaker_trips.get())),
            ("breaker_resets", num(self.breaker_resets.get())),
            ("shadow_drops", num(self.shadow_drops.get())),
            ("slo_breaches", num(self.slo_breaches.get())),
        ]);
        let gauges = json::obj(vec![
            ("inflight", Value::Num(self.inflight.get() as f64)),
            ("batch_queue_depth", Value::Num(self.batch_queue_depth.get() as f64)),
            ("open_breakers", Value::Num(self.open_breakers.get() as f64)),
            ("qos_enabled", Value::Num(self.qos_enabled.get() as f64)),
        ]);
        let stages = json::obj(vec![
            ("decode", self.stage_decode.snapshot().to_json()),
            ("queue", self.stage_queue.snapshot().to_json()),
            ("batch", self.stage_batch.snapshot().to_json()),
            ("execute", self.stage_execute.snapshot().to_json()),
            ("fallback", self.stage_fallback.snapshot().to_json()),
            ("shadow_verify", self.stage_shadow.snapshot().to_json()),
            ("pump", self.stage_pump.snapshot().to_json()),
            ("e2e_dispatch", self.e2e_dispatch.snapshot().to_json()),
            ("e2e_delivered", self.e2e_delivered.snapshot().to_json()),
        ]);
        let route_execute: Vec<Value> = self
            .route_execute
            .iter()
            .enumerate()
            .filter(|(_, h)| h.snapshot().count > 0)
            .map(|(k, h)| {
                Value::Arr(vec![Value::Num(k as f64), h.snapshot().to_json()])
            })
            .collect();
        let margins: Vec<Value> = self
            .qos_margins
            .iter()
            .map(|g| Value::Num(g.get() as f64))
            .collect();
        let tags: Vec<Value> = self
            .tags
            .snapshot()
            .into_iter()
            .map(|(tag, count)| {
                Value::Arr(vec![Value::Num(tag as f64), Value::Num(count as f64)])
            })
            .collect();
        let exec_mode = self
            .exec_mode
            .lock()
            .map(|g| g.clone())
            .unwrap_or_default();
        json::obj(vec![
            ("schema", Value::Num(1.0)),
            ("uptime_s", Value::Num(self.uptime_s())),
            ("exec_mode", Value::Str(exec_mode)),
            ("counters", counters),
            ("gauges", gauges),
            ("qos_margins", Value::Arr(margins)),
            ("stages", stages),
            ("route_execute", Value::Arr(route_execute)),
            ("tags", Value::Arr(tags)),
            ("tag_overflow", num(self.tags.overflow())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::threadpool;

    #[test]
    fn bucket_index_edges() {
        assert_eq!(Hist64::bucket_index(0), 0);
        assert_eq!(Hist64::bucket_index(1), 1);
        assert_eq!(Hist64::bucket_index(2), 2);
        assert_eq!(Hist64::bucket_index(3), 2);
        assert_eq!(Hist64::bucket_index(4), 3);
        assert_eq!(Hist64::bucket_index(1023), 10);
        assert_eq!(Hist64::bucket_index(1024), 11);
        assert_eq!(Hist64::bucket_index(u64::MAX), 63);
        // Every value sits inside its bucket's [lo, hi] range.
        for v in [0u64, 1, 2, 3, 7, 8, 100, 4096, 1 << 40, u64::MAX] {
            let i = Hist64::bucket_index(v);
            assert!(Hist64::bucket_lo(i) <= v && v <= Hist64::bucket_hi(i), "v={v}");
        }
    }

    #[test]
    fn record_and_snapshot_totals() {
        let h = Hist64::new();
        for v in [0u64, 1, 1, 5, 1000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 1007);
        assert_eq!(s.buckets[0], 1); // the zero
        assert_eq!(s.buckets[1], 2); // the ones
        assert!((s.mean() - 201.4).abs() < 1e-9);
    }

    /// The hist quantile must land in the same (or an adjacent) log2
    /// bucket as the exact sorted quantile — the documented bound.
    #[test]
    fn percentile_is_bucket_bounded_vs_exact_sort() {
        let mut rng = Rng::new(0xC0FFEE);
        let h = Hist64::new();
        let mut vals: Vec<u64> = (0..10_000)
            .map(|_| (rng.lognormal(5.0, 1.5) as u64).min(1 << 40))
            .collect();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_unstable();
        let s = h.snapshot();
        for &p in &[10.0, 50.0, 90.0, 99.0, 99.9] {
            let rank = ((p / 100.0) * vals.len() as f64).ceil() as usize;
            let exact = vals[rank.clamp(1, vals.len()) - 1];
            let got = s.percentile(p) as u64;
            let (bi_exact, bi_got) =
                (Hist64::bucket_index(exact), Hist64::bucket_index(got));
            assert!(
                bi_exact.abs_diff(bi_got) <= 1,
                "p{p}: exact {exact} (bucket {bi_exact}) vs hist {got} (bucket {bi_got})"
            );
        }
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let mut rng = Rng::new(42);
        let parts: Vec<HistSnapshot> = (0..3)
            .map(|_| {
                let h = Hist64::new();
                for _ in 0..500 {
                    h.record(rng.below(1 << 20));
                }
                h.snapshot()
            })
            .collect();
        // (a + b) + c == a + (b + c) == (c + a) + b, cell for cell.
        let mut ab_c = parts[0];
        ab_c.merge(&parts[1]);
        ab_c.merge(&parts[2]);
        let mut bc = parts[1];
        bc.merge(&parts[2]);
        let mut a_bc = parts[0];
        a_bc.merge(&bc);
        let mut ca_b = parts[2];
        ca_b.merge(&parts[0]);
        ca_b.merge(&parts[1]);
        for s in [&a_bc, &ca_b] {
            assert_eq!(ab_c.buckets, s.buckets);
            assert_eq!(ab_c.count, s.count);
            assert_eq!(ab_c.sum, s.sum);
        }
        assert_eq!(ab_c.count, 1500);
    }

    /// Concurrent recorders through the thread pool lose nothing: the
    /// final snapshot equals the single-threaded reference.
    #[test]
    fn concurrent_recorders_are_consistent() {
        let h = Hist64::new();
        let chunks: Vec<u64> = (0..8).collect();
        threadpool::parallel_map(&chunks, 4, |&c| {
            let mut rng = Rng::new(0xAB0 + c);
            for _ in 0..5_000 {
                h.record(rng.below(1 << 30));
            }
        });
        let got = h.snapshot();
        let reference = Hist64::new();
        for &c in &chunks {
            let mut rng = Rng::new(0xAB0 + c);
            for _ in 0..5_000 {
                reference.record(rng.below(1 << 30));
            }
        }
        let want = reference.snapshot();
        assert_eq!(got.count, 40_000);
        assert_eq!(got.buckets, want.buckets);
        assert_eq!(got.sum, want.sum);
    }

    #[test]
    fn count_over_is_bucket_bounded() {
        let h = Hist64::new();
        for v in [0u64, 1, 10, 100, 1000, 10_000] {
            h.record(v);
        }
        let s = h.snapshot();
        // Above the top sample: nothing. Below the bottom: everything.
        assert_eq!(s.count_over(1 << 20), 0);
        assert_eq!(s.count_over(0), 5); // strict: the zero itself is not over
        // A threshold above a whole bucket counts everything beyond it;
        // 511 sits above buckets 0..=9, so only 1000 and 10000 remain.
        assert_eq!(s.count_over(511), 2);
        // Never exceeds the total, and interpolation stays within count.
        for t in [0u64, 1, 5, 99, 512, 9999, u64::MAX] {
            assert!(s.count_over(t) <= s.count);
        }
        assert_eq!(HistSnapshot::default().count_over(0), 0);
    }

    #[test]
    fn empty_hist_is_all_zero() {
        let s = Hist64::new().snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert_eq!(s.max_bound(), 0);
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn tag_table_claims_counts_and_overflows() {
        let t = TagTable::new();
        t.record(0); // tag 0 is representable (key = tag + 1)
        t.record(0);
        t.record(7);
        assert_eq!(t.snapshot(), vec![(0, 2), (7, 1)]);
        assert_eq!(t.overflow(), 0);
        for tag in 100..100 + TAG_SLOTS as u16 {
            t.record(tag);
        }
        // Two slots were taken by tags 0 and 7, so the last two new tags
        // overflowed instead of evicting anyone.
        assert_eq!(t.snapshot().len(), TAG_SLOTS);
        assert_eq!(t.overflow(), 2);
    }

    #[test]
    fn counters_and_gauges() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::default();
        g.set(3);
        g.add(-5);
        assert_eq!(g.get(), -2);
        let f = GaugeF32::default();
        f.set(0.25);
        assert_eq!(f.get(), 0.25);
    }

    #[test]
    fn registry_snapshot_roundtrips_through_json() {
        let r = Registry::new();
        r.set_exec_mode("native");
        r.submitted.add(10);
        r.stage_queue.record(5);
        r.e2e_dispatch.record(120);
        r.record_route_execute(1, 90);
        r.record_route_execute(99, 90); // folds into the last slot
        r.qos_margins[1].set(0.5);
        r.tags.record(3);
        let text = json::write(&r.snapshot_json());
        let v = json::parse(&text).expect("snapshot JSON parses");
        assert_eq!(v.get("schema").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("exec_mode").unwrap().as_str(), Some("native"));
        let counters = v.get("counters").unwrap();
        assert_eq!(counters.get("submitted").unwrap().as_f64(), Some(10.0));
        let stages = v.get("stages").unwrap();
        assert_eq!(
            stages.get("queue").unwrap().get("count").unwrap().as_f64(),
            Some(1.0)
        );
        let re = v.get("route_execute").unwrap().as_arr().unwrap();
        assert_eq!(re.len(), 2); // class 1 + the fold slot
        let margins = v.get("qos_margins").unwrap().as_arr().unwrap();
        assert_eq!(margins[1].as_f64(), Some(0.5));
        assert_eq!(v.get("tags").unwrap().as_arr().unwrap().len(), 1);
    }
}
