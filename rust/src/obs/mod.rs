//! Live observability for the serving pipeline — dependency-free.
//!
//! Three parts (see ISSUE/README "Observability"):
//!
//! * [`metrics`] — a fixed-shape, lock-free [`Registry`] of counters,
//!   gauges and log2-bucketed [`Hist64`] latency histograms, one per
//!   named pipeline stage, so a request's end-to-end latency decomposes
//!   into a waterfall (decode → queue → batch → execute → pump);
//! * [`trace`] — a bounded [`Journal`] of structured events (sampled
//!   request spans + QoS decision events), drainable as JSON lines via
//!   `mcma serve --trace-json PATH`;
//! * the in-band STATS scrape: `net/frame.rs` defines `KIND_STATS`, the
//!   response pump answers it with [`Obs::snapshot_json`], and
//!   `mcma stats --addr HOST:PORT` pretty-prints it live.
//!
//! The consumption ring on top (same dependency-free discipline):
//!
//! * [`expo`] — OpenMetrics text rendering of the registry snapshot,
//!   served over `GET /metrics` by `net/http.rs`
//!   (`serve --metrics-listen ADDR`);
//! * [`chrome`] — `mcma trace`: journal drain → Chrome trace-event
//!   JSON for `ui.perfetto.dev`;
//! * [`slo`] — tick-driven multi-window SLO burn-rate monitor
//!   (`serve --slo-p99-us N --slo-error-budget F`) feeding `/healthz`,
//!   `slo_breaches_total` and journal instant events.
//!
//! The registry is shared by reference everywhere (readers, batcher,
//! dispatch workers, the QoS thread, the response pump); recording is
//! wait-free so the hot path never queues behind an observer.

pub mod chrome;
pub mod expo;
pub mod metrics;
pub mod slo;
pub mod trace;

pub use metrics::{
    Counter, Gauge, GaugeF32, Hist64, HistSnapshot, Registry, TagTable,
    OBS_ROUTE_CLASSES, TAG_SLOTS,
};
pub use slo::{SloConfig, SloMonitor, SloTick};
pub use trace::{Event, Journal, TraceSampler, DEFAULT_CAP};

use std::sync::Arc;

use crate::util::json::Value;

/// The shared handle every pipeline thread carries: metrics + journal.
#[derive(Clone)]
pub struct Obs {
    pub metrics: Arc<Registry>,
    pub journal: Arc<Journal>,
}

impl Obs {
    /// Fresh registry + journal.  `trace_seed`/`trace_rate` seed the
    /// span sampler (same id-hash discipline as shadow sampling).
    pub fn new(trace_seed: u64, trace_rate: f64) -> Self {
        Obs {
            metrics: Arc::new(Registry::new()),
            journal: Arc::new(Journal::new(trace_seed, trace_rate, DEFAULT_CAP)),
        }
    }

    /// The STATS scrape body: registry snapshot + journal health.
    pub fn snapshot_json(&self) -> Value {
        let mut v = self.metrics.snapshot_json();
        if let Value::Obj(kvs) = &mut v {
            kvs.push((
                "trace".to_string(),
                crate::util::json::obj(vec![
                    ("buffered", Value::Num(self.journal.len() as f64)),
                    ("dropped", Value::Num(self.journal.dropped() as f64)),
                ]),
            ));
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_snapshot_includes_trace_health() {
        let obs = Obs::new(1, 1.0);
        obs.journal.push(Event::ShadowDrop { at_us: 1 });
        let v = obs.snapshot_json();
        let trace = v.get("trace").expect("trace section");
        assert_eq!(trace.get("buffered").unwrap().as_f64(), Some(1.0));
        assert_eq!(trace.get("dropped").unwrap().as_f64(), Some(0.0));
        assert!(v.get("stages").is_some());
    }
}
