//! Sampled span journal: a bounded ring of structured pipeline events,
//! drainable as JSON lines (`mcma serve --trace-json PATH`).
//!
//! Request spans are sampled by [`TraceSampler`] — the same pure
//! `(seed, id)` SplitMix64 hash discipline as
//! [`crate::qos::ShadowSampler`], with a different mixing constant so the
//! traced set and the shadow-verified set are independent samples.  The
//! decision depends only on the request id, so the traced set is
//! bit-identical across worker counts, batch shapes and arrival orders.
//! QoS decision events (margin moves, breaker transitions, shadow drops)
//! are rare control-plane events and are always journalled.
//!
//! The ring is bounded: when full, the oldest event is dropped and
//! counted (`dropped`), never blocking a pipeline thread for more than
//! one short mutex hold.  Timestamps are microseconds since the
//! journal's epoch (serve start) on the monotonic clock.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::{self, Value};
use crate::util::rng::splitmix64;

/// Default ring capacity (events).
pub const DEFAULT_CAP: usize = 4096;

/// Mixing constant for the trace sampler — deliberately distinct from
/// the shadow sampler's multiplier so `pick` disagrees between the two.
const TRACE_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// Stateless seeded sampler; `Copy` so every thread carries its own.
/// Mirrors [`crate::qos::ShadowSampler`]: pure in `(seed, id)`.
#[derive(Clone, Copy, Debug)]
pub struct TraceSampler {
    seed: u64,
    threshold: u64,
    all: bool,
}

impl TraceSampler {
    pub fn new(seed: u64, rate: f64) -> Self {
        let rate = rate.clamp(0.0, 1.0);
        TraceSampler {
            seed,
            // f64 -> u64 `as` saturates, so rate = 1.0 maps to u64::MAX;
            // the `all` flag closes the one-in-2^64 gap exactly.
            threshold: (rate * u64::MAX as f64) as u64,
            all: rate >= 1.0,
        }
    }

    /// Should request `id` be traced?  Pure in `(seed, id)`.
    #[inline]
    pub fn pick(&self, id: u64) -> bool {
        self.all || splitmix64(self.seed ^ id.wrapping_mul(TRACE_MIX)) < self.threshold
    }
}

/// One structured journal entry.  `at_us` is microseconds since the
/// journal's epoch on the monotonic clock.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// One sampled request's stage decomposition, recorded at dispatch.
    /// `route` is the approximator class, or -1 for the precise path.
    Span {
        id: u64,
        route: i64,
        queue_us: u64,
        batch_us: u64,
        exec_us: u64,
        e2e_us: u64,
        at_us: u64,
    },
    /// Client delivery of a sampled response (the pump stage).
    Delivered { id: u64, pump_us: u64, e2e_us: u64, at_us: u64 },
    /// The QoS controller moved a class margin.
    MarginMove { class: usize, from: f32, to: f32, at_us: u64 },
    /// A class circuit breaker opened (`open = true`) or closed again.
    Breaker { class: usize, open: bool, at_us: u64 },
    /// A shadow observation was lost to queue backpressure.
    ShadowDrop { at_us: u64 },
    /// The SLO burn-rate monitor entered (`breached = true`) or left a
    /// breach (see `obs/slo.rs`); burns are the windowed budget-spend
    /// rates at the transition tick.
    Slo { breached: bool, burn_short: f64, burn_long: f64, at_us: u64 },
}

impl Event {
    /// One JSON object per event, discriminated by `"type"`.
    pub fn to_json(&self) -> Value {
        fn num(n: u64) -> Value {
            Value::Num(n as f64)
        }
        match self {
            Event::Span { id, route, queue_us, batch_us, exec_us, e2e_us, at_us } => {
                json::obj(vec![
                    ("type", Value::Str("span".into())),
                    ("id", num(*id)),
                    ("route", Value::Num(*route as f64)),
                    ("queue_us", num(*queue_us)),
                    ("batch_us", num(*batch_us)),
                    ("exec_us", num(*exec_us)),
                    ("e2e_us", num(*e2e_us)),
                    ("at_us", num(*at_us)),
                ])
            }
            Event::Delivered { id, pump_us, e2e_us, at_us } => json::obj(vec![
                ("type", Value::Str("delivered".into())),
                ("id", num(*id)),
                ("pump_us", num(*pump_us)),
                ("e2e_us", num(*e2e_us)),
                ("at_us", num(*at_us)),
            ]),
            Event::MarginMove { class, from, to, at_us } => json::obj(vec![
                ("type", Value::Str("margin".into())),
                ("class", num(*class as u64)),
                ("from", Value::Num(*from as f64)),
                ("to", Value::Num(*to as f64)),
                ("at_us", num(*at_us)),
            ]),
            Event::Breaker { class, open, at_us } => json::obj(vec![
                ("type", Value::Str("breaker".into())),
                ("class", num(*class as u64)),
                ("open", Value::Bool(*open)),
                ("at_us", num(*at_us)),
            ]),
            Event::ShadowDrop { at_us } => json::obj(vec![
                ("type", Value::Str("shadow_drop".into())),
                ("at_us", num(*at_us)),
            ]),
            Event::Slo { breached, burn_short, burn_long, at_us } => json::obj(vec![
                ("type", Value::Str("slo".into())),
                ("breached", Value::Bool(*breached)),
                ("burn_short", Value::Num(*burn_short)),
                ("burn_long", Value::Num(*burn_long)),
                ("at_us", num(*at_us)),
            ]),
        }
    }
}

struct Ring {
    buf: VecDeque<Event>,
    dropped: u64,
}

/// Bounded, mutex-guarded event ring shared by every pipeline thread.
pub struct Journal {
    t0: Instant,
    cap: usize,
    sampler: TraceSampler,
    ring: Mutex<Ring>,
}

impl Journal {
    pub fn new(seed: u64, rate: f64, cap: usize) -> Self {
        Journal {
            t0: Instant::now(),
            cap: cap.max(1),
            sampler: TraceSampler::new(seed, rate),
            ring: Mutex::new(Ring { buf: VecDeque::new(), dropped: 0 }),
        }
    }

    /// The journal's request sampler (copy it into worker threads).
    pub fn sampler(&self) -> TraceSampler {
        self.sampler
    }

    /// Is request `id` in the traced sample?
    #[inline]
    pub fn sampled(&self, id: u64) -> bool {
        self.sampler.pick(id)
    }

    /// Microseconds since the journal's epoch (monotonic).
    pub fn now_us(&self) -> u64 {
        self.t0.elapsed().as_micros() as u64
    }

    /// Append one event, dropping (and counting) the oldest when full.
    pub fn push(&self, ev: Event) {
        if let Ok(mut g) = self.ring.lock() {
            if g.buf.len() >= self.cap {
                g.buf.pop_front();
                g.dropped += 1;
            }
            g.buf.push_back(ev);
        }
    }

    pub fn len(&self) -> usize {
        self.ring.lock().map(|g| g.buf.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events evicted because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.ring.lock().map(|g| g.dropped).unwrap_or(0)
    }

    /// Drain every buffered event as newline-delimited JSON (oldest
    /// first).  The ring is left empty; `dropped` keeps accumulating.
    pub fn drain_json_lines(&self) -> String {
        let events: Vec<Event> = match self.ring.lock() {
            Ok(mut g) => g.buf.drain(..).collect(),
            Err(_) => Vec::new(),
        };
        let mut out = String::new();
        for ev in &events {
            out.push_str(&json::write(&ev.to_json()));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let j = Journal::new(1, 1.0, 8);
        for i in 0..18u64 {
            j.push(Event::ShadowDrop { at_us: i });
        }
        assert_eq!(j.len(), 8);
        assert_eq!(j.dropped(), 10);
        let lines = j.drain_json_lines();
        assert_eq!(lines.lines().count(), 8);
        assert!(j.is_empty());
        // Oldest got evicted: the first surviving event is at_us = 10.
        let first = json::parse(lines.lines().next().unwrap()).unwrap();
        assert_eq!(first.get("at_us").unwrap().as_f64(), Some(10.0));
        assert_eq!(j.dropped(), 10); // draining doesn't reset the count
    }

    /// The traced set is a pure function of (seed, id): partitioning the
    /// id space across any number of workers yields the same picks — the
    /// worker-count invariance the shadow sampler pins, with a different
    /// mixing constant.
    #[test]
    fn sampler_is_worker_count_invariant() {
        let s = TraceSampler::new(0x7ACE, 0.2);
        let forward: Vec<u64> = (0..4096).filter(|&id| s.pick(id)).collect();
        // "Three workers": ids striped by residue, each reversed.
        let mut striped: Vec<u64> = (0u64..3)
            .flat_map(|r| (0..4096).rev().filter(move |id| id % 3 == r))
            .filter(|&id| s.pick(id))
            .collect();
        striped.sort_unstable();
        assert_eq!(forward, striped);
        assert!(!forward.is_empty() && forward.len() < 4096);
    }

    #[test]
    fn sampler_differs_from_shadow_sampler_on_same_seed() {
        let trace = TraceSampler::new(0x5AD0, 0.3);
        let shadow = crate::qos::ShadowSampler::new(0x5AD0, 0.3);
        let same = (0..4096u64)
            .filter(|&id| trace.pick(id) == shadow.pick(id))
            .count();
        assert!(same < 4096, "trace and shadow samples must be independent");
    }

    #[test]
    fn sampler_edge_rates() {
        let never = TraceSampler::new(9, 0.0);
        let always = TraceSampler::new(9, 1.0);
        for id in 0..512 {
            assert!(!never.pick(id));
            assert!(always.pick(id));
        }
    }

    #[test]
    fn events_serialise_with_type_tags() {
        let evs = [
            Event::Span {
                id: 7,
                route: -1,
                queue_us: 1,
                batch_us: 2,
                exec_us: 3,
                e2e_us: 6,
                at_us: 99,
            },
            Event::Delivered { id: 7, pump_us: 4, e2e_us: 10, at_us: 100 },
            Event::MarginMove { class: 1, from: 0.0, to: 0.05, at_us: 101 },
            Event::Breaker { class: 1, open: true, at_us: 102 },
            Event::ShadowDrop { at_us: 103 },
            Event::Slo { breached: true, burn_short: 20.0, burn_long: 3.5, at_us: 104 },
        ];
        let types: Vec<String> = evs
            .iter()
            .map(|e| {
                let v = json::parse(&json::write(&e.to_json())).unwrap();
                v.get("type").unwrap().as_str().unwrap().to_string()
            })
            .collect();
        assert_eq!(types, ["span", "delivered", "margin", "breaker", "shadow_drop", "slo"]);
        let span = json::parse(&json::write(&evs[0].to_json())).unwrap();
        assert_eq!(span.get("route").unwrap().as_f64(), Some(-1.0));
        assert_eq!(span.get("e2e_us").unwrap().as_f64(), Some(6.0));
    }
}
