// audit:deterministic — every tick is a pure function of the injected
// clock and the cumulative counts it is handed; the module never reads
// a wall clock, so breach trajectories replay bit-identically in tests.
//! Multi-window SLO burn-rate monitor (`serve --slo-p99-us N
//! --slo-error-budget F`).
//!
//! The classic burn-rate formulation: the operator grants an **error
//! budget** — a fraction `F` of requests allowed to be *bad* (delivered
//! over the latency target, or hit by a quality-loss event).  The
//! **burn rate** over a window is `(bad / total) / F`: 1.0 means the
//! budget is being spent exactly at the sustainable rate, 14 means the
//! whole budget would be gone in 1/14th of the SLO period.  A breach
//! requires BOTH windows to burn hot — the short window (5 m) proves
//! the problem is happening *now*, the long window (1 h) proves it is
//! sustained rather than a blip — the standard multi-window guard
//! against paging on a single slow batch.
//!
//! The monitor is tick-driven: the serve glue (or a test) feeds it
//! `(now_us, total, bad)` cumulative observations; the monitor keeps a
//! bounded ring of samples and differences them at the window edges.
//! Breaches flip `/healthz` to 503 (via [`SloMonitor::healthy`]),
//! increment `slo_breaches_total`, and journal an
//! [`crate::obs::Event::Slo`] instant event — all three driven by the
//! [`SloTick`] transition report so this module stays free of registry
//! and clock dependencies.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::util::lock_unpoisoned;

/// SLO targets and window geometry.  `new` applies the standard 5 m /
/// 1 h multi-window, fast-burn 14 / slow-burn 2 defaults.
#[derive(Clone, Copy, Debug)]
pub struct SloConfig {
    /// Delivered-latency target: a request counts against the budget
    /// when its submit -> delivered latency exceeds this.
    pub p99_target_us: u64,
    /// Fraction of requests allowed to be bad (> 0).
    pub error_budget: f64,
    /// Short ("is it happening now") window, microseconds.
    pub short_window_us: u64,
    /// Long ("is it sustained") window, microseconds.
    pub long_window_us: u64,
    /// Burn threshold the short window must exceed.
    pub fast_burn: f64,
    /// Burn threshold the long window must exceed.
    pub slow_burn: f64,
}

impl SloConfig {
    pub fn new(p99_target_us: u64, error_budget: f64) -> Self {
        SloConfig {
            p99_target_us,
            error_budget,
            short_window_us: 5 * 60 * 1_000_000,
            long_window_us: 3_600 * 1_000_000,
            fast_burn: 14.0,
            slow_burn: 2.0,
        }
    }

    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.p99_target_us > 0, "--slo-p99-us must be positive");
        anyhow::ensure!(
            self.error_budget > 0.0 && self.error_budget <= 1.0,
            "--slo-error-budget must be in (0, 1], got {}",
            self.error_budget
        );
        anyhow::ensure!(
            self.short_window_us > 0 && self.short_window_us <= self.long_window_us,
            "SLO windows must satisfy 0 < short <= long"
        );
        Ok(())
    }
}

/// What one tick decided — the caller acts on `changed` (journal event,
/// breach counter) and serves `breached` from `/healthz`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloTick {
    pub breached: bool,
    /// True when this tick transitioned healthy <-> breached.
    pub changed: bool,
    pub burn_short: f64,
    pub burn_long: f64,
}

/// One cumulative observation: counts as of `at_us`.
#[derive(Clone, Copy, Debug)]
struct Sample {
    at_us: u64,
    total: u64,
    bad: u64,
}

struct Inner {
    samples: VecDeque<Sample>,
    breached: bool,
    last_burn_short: f64,
    last_burn_long: f64,
}

/// Tick-driven multi-window burn-rate evaluator.  Shared behind `Arc`
/// by the serve tick thread and the `/healthz`//metrics responders.
pub struct SloMonitor {
    cfg: SloConfig,
    inner: Mutex<Inner>,
}

impl SloMonitor {
    pub fn new(cfg: SloConfig) -> Self {
        SloMonitor {
            cfg,
            inner: Mutex::new(Inner {
                samples: VecDeque::new(),
                breached: false,
                last_burn_short: 0.0,
                last_burn_long: 0.0,
            }),
        }
    }

    pub fn config(&self) -> &SloConfig {
        &self.cfg
    }

    /// `/healthz` state: true until a breach, true again after recovery.
    pub fn healthy(&self) -> bool {
        !lock_unpoisoned(&self.inner).breached
    }

    /// `(burn_short, burn_long)` as of the latest tick (for exposition).
    pub fn burns(&self) -> (f64, f64) {
        let g = lock_unpoisoned(&self.inner);
        (g.last_burn_short, g.last_burn_long)
    }

    /// Feed one cumulative observation: `total` requests delivered and
    /// `bad` budget-consuming events as of the injected clock `now_us`.
    /// Both counts are cumulative (monotone); the monitor differences
    /// them at the window edges itself.
    pub fn tick(&self, now_us: u64, total: u64, bad: u64) -> SloTick {
        let mut g = lock_unpoisoned(&self.inner);
        g.samples.push_back(Sample { at_us: now_us, total, bad });
        // Retain one sample at or before the long-window edge as the
        // baseline; everything older carries no extra information.
        let edge = now_us.saturating_sub(self.cfg.long_window_us);
        while g.samples.len() > 2 {
            let second = match g.samples.get(1) {
                Some(s) => *s,
                None => break,
            };
            if second.at_us > edge {
                break;
            }
            g.samples.pop_front();
        }
        let now = Sample { at_us: now_us, total, bad };
        let burn_short = self.window_burn(&g.samples, now, self.cfg.short_window_us);
        let burn_long = self.window_burn(&g.samples, now, self.cfg.long_window_us);
        let breached = burn_short >= self.cfg.fast_burn && burn_long >= self.cfg.slow_burn;
        let changed = breached != g.breached;
        g.breached = breached;
        g.last_burn_short = burn_short;
        g.last_burn_long = burn_long;
        SloTick { breached, changed, burn_short, burn_long }
    }

    /// Burn over the trailing `window_us`: the bad-fraction of the
    /// requests delivered inside the window, divided by the budget.
    /// The baseline is the newest sample at or before the window edge;
    /// with a short history the whole history is the window (standard
    /// warm-up behaviour: no special-casing, just a smaller window).
    fn window_burn(&self, samples: &VecDeque<Sample>, now: Sample, window_us: u64) -> f64 {
        let edge = now.at_us.saturating_sub(window_us);
        let mut base = Sample { at_us: 0, total: 0, bad: 0 };
        for s in samples {
            if s.at_us <= edge {
                base = *s;
            } else {
                break;
            }
        }
        let d_total = now.total.saturating_sub(base.total);
        let d_bad = now.bad.saturating_sub(base.bad);
        if d_total == 0 {
            return 0.0;
        }
        (d_bad as f64 / d_total as f64) / self.cfg.error_budget.max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: u64 = 1_000_000; // one second in µs

    fn fast_cfg() -> SloConfig {
        SloConfig {
            short_window_us: 10 * S,
            long_window_us: 60 * S,
            fast_burn: 10.0,
            slow_burn: 2.0,
            ..SloConfig::new(1_000, 0.01)
        }
    }

    #[test]
    fn config_validation() {
        assert!(SloConfig::new(1_000, 0.001).validate().is_ok());
        assert!(SloConfig::new(0, 0.001).validate().is_err());
        assert!(SloConfig::new(1_000, 0.0).validate().is_err());
        assert!(SloConfig::new(1_000, 1.5).validate().is_err());
        let mut bad = SloConfig::new(1_000, 0.01);
        bad.short_window_us = bad.long_window_us + 1;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn healthy_traffic_never_breaches() {
        let m = SloMonitor::new(fast_cfg());
        // 1% budget, 0.1% observed bad rate -> burn 0.1 on both windows.
        for i in 1..=120u64 {
            let t = m.tick(i * S, i * 1000, i);
            assert!(!t.breached, "tick {i}: {t:?}");
            assert!(!t.changed);
        }
        assert!(m.healthy());
    }

    #[test]
    fn sustained_badness_breaches_then_recovers() {
        let m = SloMonitor::new(fast_cfg());
        // Warm up healthy: 1k req/s, ~0 bad.
        let mut total = 0u64;
        let mut bad = 0u64;
        let mut now = 0u64;
        for _ in 0..30 {
            now += S;
            total += 1000;
            let t = m.tick(now, total, bad);
            assert!(!t.breached);
        }
        // 50% of requests go bad: burn = 0.5 / 0.01 = 50 on the short
        // window immediately; the long window (which still includes the
        // clean warm-up) catches up within a few ticks.
        let mut breach_tick = None;
        for i in 0..20 {
            now += S;
            total += 1000;
            bad += 500;
            let t = m.tick(now, total, bad);
            if t.breached && breach_tick.is_none() {
                breach_tick = Some(i);
                assert!(t.changed);
                assert!(t.burn_short >= 10.0, "{t:?}");
                assert!(t.burn_long >= 2.0, "{t:?}");
            }
        }
        assert!(breach_tick.is_some(), "sustained 50x burn must breach");
        assert!(!m.healthy());
        let (bs, bl) = m.burns();
        assert!(bs > 10.0 && bl > 2.0);
        // Traffic turns clean again: the short window drains within its
        // 10 s span and the breach clears (changed fires exactly once).
        let mut cleared = 0;
        for _ in 0..30 {
            now += S;
            total += 1000;
            let t = m.tick(now, total, bad);
            if t.changed {
                cleared += 1;
                assert!(!t.breached);
            }
        }
        assert_eq!(cleared, 1);
        assert!(m.healthy());
    }

    #[test]
    fn short_blip_does_not_breach_the_long_window() {
        let cfg = SloConfig {
            short_window_us: 5 * S,
            long_window_us: 300 * S,
            fast_burn: 10.0,
            slow_burn: 5.0,
            ..SloConfig::new(1_000, 0.01)
        };
        let m = SloMonitor::new(cfg);
        let mut total = 0u64;
        let mut bad = 0u64;
        let mut now = 0u64;
        // 100 s of clean traffic, then a single 2 s spike of 100% bad.
        for _ in 0..100 {
            now += S;
            total += 1000;
            m.tick(now, total, bad);
        }
        for _ in 0..2 {
            now += S;
            total += 1000;
            bad += 1000;
            let t = m.tick(now, total, bad);
            // Short window burns at 100 (>10) but the long window has
            // 100 s of clean history diluting the spike below 5.
            assert!(t.burn_short >= 10.0);
            assert!(t.burn_long < 5.0, "{t:?}");
            assert!(!t.breached);
        }
        assert!(m.healthy());
    }

    #[test]
    fn no_traffic_means_zero_burn() {
        let m = SloMonitor::new(fast_cfg());
        let t = m.tick(S, 0, 0);
        assert_eq!(t, SloTick { breached: false, changed: false, burn_short: 0.0, burn_long: 0.0 });
    }

    #[test]
    fn sample_ring_stays_bounded() {
        let m = SloMonitor::new(fast_cfg());
        for i in 1..=10_000u64 {
            m.tick(i * S, i, 0);
        }
        // 60 s long window at 1 tick/s -> ~62 samples retained.
        let g = lock_unpoisoned(&m.inner);
        assert!(g.samples.len() < 70, "{}", g.samples.len());
    }
}
