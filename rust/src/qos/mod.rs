//! Online QoS subsystem — closes the quality loop at serve time.
//!
//! The paper's guarantee ("maximize invocation subject to an error bound")
//! is enforced offline: the classifier's routing is frozen at train time
//! and the serving pipeline never observes the quality it actually
//! delivers.  This module adds the missing control plane over the existing
//! data plane:
//!
//! ```text
//!                 requests ──► Batcher ──► Dispatcher (margins m_k) ──► responses
//!                                              │ invoked samples
//!                       ShadowSampler.pick(id) │ (deterministic id hash)
//!                                              ▼
//!                   precise BenchFn ──► per-class ErrorWindow (quantile/EWMA)
//!                                              │ every tick_every obs
//!                                              ▼
//!        Controller: q_k > target  ⇒ m_k += step   (tighten, count violation)
//!                    q_k < 0.7·tgt ⇒ m_k -= step/2 (relax; hysteresis band holds)
//!                    sustained violation ⇒ circuit breaker ⇒ class k precise
//!                                              │
//!                                              ▼ publish (atomic f32 bits)
//!                              per-class margin overrides read by the router
//! ```
//!
//! * [`shadow`] — stateless, seeded hash sampler: whether request `id` is
//!   shadow-verified is a pure function of `(seed, id)`, so the sampled
//!   set is bit-identical across worker counts and batch shapes;
//! * [`estimator`] — per-class windowed error statistics (ring-buffer
//!   quantile + EWMA) so drift ages out of the estimate;
//! * [`controller`] — the adaptive invocation controller: per-class
//!   confidence margins with hysteresis and a trip/half-open/closed
//!   circuit breaker, published to the hot path as relaxed atomics;
//! * [`sim`] — offline replay of the whole loop over a
//!   `formats::Dataset`, powering the `mcma summary` fixed-vs-adaptive
//!   table and the determinism/monotonicity tests.
//!
//! Errors are per-sample RMSE in normalised output space — the same
//! metric `coordinator::metrics` scores offline runs with, so `--qos-target`
//! is directly comparable to the manifest's `error_bound`.
//!
//! The "precise BenchFn" box generalises to a
//! [`crate::workload::PreciseProxy`]: for data-defined (table) workloads
//! no precise function exists at runtime, so shadow verification scores
//! against the HELD-OUT labels (nearest-record proxy over `test.bin`) —
//! margins, hysteresis and breaker semantics are unchanged.  Margins can
//! also be warm-started from an offline replay of the held-out set
//! (`QosConfig::warm_start`, `mcma serve --qos-warm`) instead of
//! cold-starting at argmax.

pub mod controller;
pub mod estimator;
pub mod shadow;
pub mod sim;

pub use controller::{Controller, QosReport, MARGIN_PRECISE};
pub use estimator::ErrorWindow;
pub use shadow::ShadowSampler;
pub use sim::{simulate, QosSimResult};

/// Configuration of the online QoS loop (`mcma serve --qos-*`).
#[derive(Clone, Copy, Debug)]
pub struct QosConfig {
    /// Quality target: the controller holds the per-class error quantile
    /// at or below this value (same normalised-RMSE scale as the
    /// manifest's `error_bound`).
    pub target: f64,
    /// Which quantile of the shadow-observed error is controlled
    /// (0.95 = "p95 rel-err ≤ target").
    pub quantile: f64,
    /// Fraction of *approximated* requests re-run through the precise
    /// `BenchFn` for ground truth (off the request hot path).
    pub shadow_rate: f64,
    /// Seed of the deterministic shadow sampler.
    pub seed: u64,
    /// Per-class sliding window length for the error estimator.
    pub window: usize,
    /// Minimum shadow observations in a class's window before the
    /// controller adjusts that class (no evidence, no movement).
    pub min_obs: usize,
    /// Shadow observations between control ticks.
    pub tick_every: u64,
    /// Margin increment on a violating tick; relaxation uses `step / 2`
    /// so the controller backs off slower than it tightens.
    pub step: f32,
    /// Relax only when the observed quantile falls below
    /// `relax_frac * target`; between that and `target` the margin holds
    /// (the hysteresis dead band).
    pub relax_frac: f64,
    /// Consecutive violating ticks before the circuit breaker trips the
    /// class to the precise path.
    pub breaker_trip: u32,
    /// Ticks a tripped class stays forced-precise before a half-open
    /// retry at `margin_max`.
    pub breaker_cooldown: u32,
    /// Margin ceiling while the breaker is closed.  A class pinned at
    /// the ceiling that keeps violating still accrues consecutive
    /// violations and trips the breaker after `breaker_trip` ticks.
    pub margin_max: f32,
    /// Warm-start per-class margins from an offline replay of the
    /// held-out set ([`sim::simulate`]) when the server spawns, instead
    /// of cold-starting every margin at 0 (pure argmax) and spending the
    /// first live ticks re-learning what the held-out data already shows
    /// (`mcma serve --qos-warm`).
    pub warm_start: bool,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            target: 0.1,
            quantile: 0.95,
            shadow_rate: 0.05,
            seed: 0x5AD0,
            window: 256,
            min_obs: 32,
            tick_every: 64,
            step: 0.05,
            relax_frac: 0.7,
            breaker_trip: 4,
            breaker_cooldown: 8,
            margin_max: 0.98,
            warm_start: false,
        }
    }
}

impl QosConfig {
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.target > 0.0, "--qos-target must be > 0");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.quantile),
            "--qos-quantile must be in [0, 1]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.shadow_rate),
            "--qos-shadow must be in [0, 1]"
        );
        anyhow::ensure!(self.window >= 2, "--qos-window must be >= 2");
        anyhow::ensure!(self.min_obs >= 1, "qos min_obs must be >= 1");
        anyhow::ensure!(self.tick_every >= 1, "qos tick_every must be >= 1");
        anyhow::ensure!(self.step > 0.0, "qos step must be > 0");
        anyhow::ensure!(
            (0.0..1.0).contains(&self.relax_frac),
            "qos relax_frac must be in [0, 1)"
        );
        anyhow::ensure!(self.breaker_trip >= 1, "qos breaker_trip must be >= 1");
        anyhow::ensure!(
            (0.0..1.0).contains(&self.margin_max),
            "qos margin_max must be in [0, 1)"
        );
        Ok(())
    }
}

/// Per-sample RMSE between two normalised output rows (the quality metric
/// shadow observations are scored with; allocation-free).
pub fn row_rmse(served: &[f32], precise: &[f32]) -> f64 {
    debug_assert_eq!(served.len(), precise.len());
    if served.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for (a, b) in served.iter().zip(precise) {
        let d = *a as f64 - *b as f64;
        acc += d * d;
    }
    (acc / served.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_validates() {
        QosConfig::default().validate().unwrap();
    }

    #[test]
    fn bad_configs_rejected() {
        let mut c = QosConfig { target: 0.0, ..QosConfig::default() };
        assert!(c.validate().is_err());
        c = QosConfig { quantile: 1.5, ..QosConfig::default() };
        assert!(c.validate().is_err());
        c = QosConfig { shadow_rate: -0.1, ..QosConfig::default() };
        assert!(c.validate().is_err());
        c = QosConfig { margin_max: 1.0, ..QosConfig::default() };
        assert!(c.validate().is_err());
        c = QosConfig { window: 1, ..QosConfig::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn row_rmse_matches_hand_calc() {
        assert_eq!(row_rmse(&[], &[]), 0.0);
        let e = row_rmse(&[1.0, 2.0], &[1.0, 0.0]);
        assert!((e - (4.0f64 / 2.0).sqrt()).abs() < 1e-12);
        // Agrees with the batch metric used offline.
        let batch = crate::nn::per_sample_rmse(&[1.0, 2.0], &[1.0, 0.0], 1, 2);
        assert!((e - batch[0]).abs() < 1e-12);
    }
}
