//! Offline replay of the QoS loop — the whole shadow-sample → estimate →
//! adapt cycle run over a held-out [`Dataset`] in arrival-order batches.
//!
//! This is how the fixed-vs-adaptive question is answered measurably
//! (`mcma summary`): stream the test set through the dispatcher with the
//! controller adapting per-class margins, then evaluate two fixed
//! baselines on the identical data:
//!
//! * **argmax** — the paper's routing, margins all zero;
//! * **fixed global threshold** — ONE conservative confidence threshold,
//!   set to the tightest margin ANY class needed at ANY point of the
//!   adaptive run (what a single static knob must use to protect the
//!   worst class).
//!
//! Because the per-sample argmax class and confidence do not depend on
//! margins (margins only demote to the precise path), every sample the
//! adaptive run demotes is also demoted under the global threshold —
//! so `invocation_adaptive >= invocation_fixed` holds structurally, and
//! the gap IS the per-class headroom the paper's nonuniform-error
//! observation predicts.
//!
//! The whole replay is bit-deterministic for a fixed seed across thread
//! counts: batches are processed sequentially, the f32 native forward is
//! chunking-exact, and shadow selection is a pure id hash.

// audit:deterministic — replay must be reproducible for summary tables.
use crate::coordinator::{Dispatcher, Route, RoutePlan, Scratch};
use crate::formats::Dataset;
use crate::workload::PreciseProxy;

use super::controller::{Controller, QosReport};
use super::shadow::ShadowSampler;
use super::{row_rmse, QosConfig};

/// Outcome of one adaptive replay plus its fixed baselines.
#[derive(Clone, Debug)]
pub struct QosSimResult {
    pub bench: String,
    pub method: String,
    pub n: usize,
    pub batch: usize,
    /// Invocation under pure argmax routing (margins all zero).
    pub invocation_argmax: f64,
    /// Invocation under the single conservative global threshold.
    pub invocation_fixed: f64,
    /// Invocation actually achieved by the adaptive controller over the
    /// stream (including its cold start and any breaker excursions).
    pub invocation_adaptive: f64,
    /// The global threshold the fixed baseline had to use: the peak
    /// effective margin any class reached during the adaptive run
    /// ([`super::MARGIN_PRECISE`] if a breaker ever tripped).
    pub global_margin: f32,
    pub final_margins: Vec<f32>,
    pub report: QosReport,
}

impl QosSimResult {
    /// Adaptive-minus-fixed invocation gap (≥ 0 by construction).
    pub fn headroom(&self) -> f64 {
        self.invocation_adaptive - self.invocation_fixed
    }
}

/// Whole-set invocation under one (possibly margin-overridden) plan.
fn plan_invocation(
    d: &Dispatcher,
    x_norm: &[f32],
    n: usize,
    margins: Option<&[f32]>,
) -> crate::Result<f64> {
    let mut plan = RoutePlan::default();
    let mut scratch = Scratch::new();
    d.plan_with_margins_into(x_norm, n, margins, &mut plan, &mut scratch)?;
    Ok(plan.invocation())
}

/// Replay the QoS loop over `ds` through `d` in `batch`-row arrival-order
/// batches (see module docs).
pub fn simulate(
    d: &Dispatcher,
    ds: &Dataset,
    qos: &QosConfig,
    batch: usize,
) -> crate::Result<QosSimResult> {
    qos.validate()?;
    anyhow::ensure!(batch >= 1, "qos sim batch must be >= 1");
    anyhow::ensure!(ds.n > 0, "qos sim needs a non-empty dataset");

    let (d_in, d_out) = (d.bench.n_in, d.bench.n_out);
    let n_approx = d.n_approx();
    let x_norm = d.normalize(&ds.x_raw, ds.n);

    // Oracle-less workloads: rejected samples are served from the
    // dataset's own labels (exact on held-out replay), mirroring
    // `run_dataset` — shadow errors are scored against `ds.y_row`
    // either way, so the replay never needs a precise function.
    let lookup;
    let proxy = if d.has_runtime_oracle() {
        None
    } else {
        lookup = PreciseProxy::lookup_from(d.bench, ds);
        Some(&lookup)
    };

    let sampler = ShadowSampler::new(qos.seed, qos.shadow_rate);
    let mut ctrl = Controller::new(*qos, n_approx);
    let mut margins: Vec<f32> = Vec::new();
    ctrl.margins_into(&mut margins);
    let mut peak = margins.clone();

    let mut plan = RoutePlan::default();
    let mut scratch = Scratch::new();
    let mut y: Vec<f32> = Vec::new();
    let mut invoked = 0u64;
    let mut invoked_per_class = vec![0u64; n_approx];

    let mut i = 0usize;
    while i < ds.n {
        let bn = batch.min(ds.n - i);
        let xb = &x_norm[i * d_in..(i + bn) * d_in];
        let rawb = &ds.x_raw[i * d_in..(i + bn) * d_in];
        d.plan_with_margins_into(xb, bn, Some(&margins), &mut plan, &mut scratch)?;
        d.execute_plan_with_proxy_into(&plan, xb, rawb, bn, proxy, &mut y, &mut scratch)?;
        for (j, r) in plan.routes.iter().enumerate() {
            if let Route::Approx(k) = r {
                invoked += 1;
                invoked_per_class[*k] += 1;
                // The global sample index doubles as the request id, so
                // the shadow set is identical no matter the batch size.
                if sampler.pick((i + j) as u64) {
                    let err =
                        row_rmse(&y[j * d_out..(j + 1) * d_out], ds.y_row(i + j));
                    ctrl.observe(*k, err);
                }
            }
        }
        if ctrl.maybe_tick() {
            ctrl.margins_into(&mut margins);
            for (p, m) in peak.iter_mut().zip(&margins) {
                *p = p.max(*m);
            }
        }
        i += bn;
    }

    let global_margin = peak.iter().copied().fold(0.0f32, f32::max);

    // Fixed baselines over the identical data, whole-set plans.
    let invocation_argmax = plan_invocation(d, &x_norm, ds.n, None)?;
    let fixed = vec![global_margin; n_approx];
    let invocation_fixed = plan_invocation(d, &x_norm, ds.n, Some(&fixed))?;

    let mut final_margins = Vec::new();
    ctrl.margins_into(&mut final_margins);
    Ok(QosSimResult {
        bench: d.bench.name.clone(),
        method: d.method.key().to_string(),
        n: ds.n,
        batch,
        invocation_argmax,
        invocation_fixed,
        invocation_adaptive: invoked as f64 / ds.n as f64,
        global_margin,
        final_margins,
        // Shadow counts fall back to the window's lifetime totals (the
        // sim ingests single-threaded, so they are exact).
        report: ctrl.report(None, Some(&invoked_per_class)),
    })
}
