//! The adaptive invocation controller: per-class confidence margins held
//! against a quality target, with hysteresis and a circuit breaker.
//!
//! A class's *margin* `m_k ∈ [0, margin_max]` is the minimum classifier
//! softmax confidence a sample must reach to be served by approximator
//! `k`; below it the sample is demoted to the precise CPU path
//! (`router::apply_margins`).  `m_k = 0` is the paper's pure-argmax
//! routing.  The control law per tick, per class with enough windowed
//! evidence AND at least one observation since it was last judged (a
//! stale window is never re-judged just because other classes keep
//! driving ticks):
//!
//! * observed quantile `q_k > target`           → tighten: `m_k += step`
//!   (a *violation*; `breaker_trip` consecutive ones trip the breaker);
//! * `q_k < relax_frac · target`                → relax: `m_k -= step/2`;
//! * in between                                 → hold (hysteresis band).
//!
//! Tightening is twice as fast as relaxing and the dead band keeps the
//! margin from oscillating around the target.  The circuit breaker is the
//! hard quality backstop: a class that keeps violating is forced fully
//! precise ([`MARGIN_PRECISE`]), cools down, then retries half-open at
//! `margin_max` — one more violating tick re-trips it, one clean tick
//! closes it.
//!
//! The controller itself is single-threaded plain state (it lives on the
//! server's QoS thread or in the offline simulator); only the *published*
//! margins cross threads, as relaxed atomic f32 bits.

use crate::bench_harness::Table;

use super::estimator::ErrorWindow;
use super::QosConfig;

/// Margin that no softmax confidence can reach (probabilities are ≤ 1):
/// publishing it forces every sample of that class to the precise path.
pub const MARGIN_PRECISE: f32 = 2.0;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Breaker {
    Closed,
    /// Forced precise for `cooldown_left` more ticks.
    Open { cooldown_left: u32 },
    /// Probing at `margin_max`: one violating tick re-trips, one clean
    /// tick closes.
    HalfOpen,
}

#[derive(Clone, Debug)]
struct ClassState {
    margin: f32,
    window: ErrorWindow,
    breaker: Breaker,
    consec_violations: u32,
    /// Total violating ticks (lifetime).
    violations: u64,
    trips: u64,
    /// Quantile computed at the most recent tick with enough evidence.
    last_q: f64,
    /// Observations since this class was last judged.  A tick re-judges
    /// a class only when this is non-zero — an unchanged stale window
    /// must not accrue repeated violations (or repeated relaxation)
    /// just because OTHER classes keep driving ticks.
    fresh_obs: u64,
}

/// Per-class snapshot for reports (`ServerReport` / `mcma serve`).
#[derive(Clone, Debug)]
pub struct ClassQos {
    pub class: usize,
    /// Effective margin (== [`MARGIN_PRECISE`] while the breaker is open).
    pub margin: f32,
    /// Samples this class served (from the shared per-route counters
    /// when available, else 0).
    pub invoked: u64,
    pub shadow_n: u64,
    pub window_n: usize,
    /// Error quantile at the last evidence-backed tick.
    pub observed_q: f64,
    pub ewma: f64,
    pub violations: u64,
    pub trips: u64,
    pub breaker_open: bool,
}

/// Controller outcome summary.
#[derive(Clone, Debug)]
pub struct QosReport {
    pub target: f64,
    pub quantile: f64,
    pub shadow_rate: f64,
    pub ticks: u64,
    /// Shadow-selected observations dropped because the (bounded)
    /// observation queue was full — the server fills this in; 0 for the
    /// offline replay.
    pub shadow_dropped: u64,
    /// Margins were seeded from an offline held-out replay
    /// ([`Controller::seed_margins`]) instead of cold-starting at argmax.
    pub warm_started: bool,
    pub classes: Vec<ClassQos>,
}

impl QosReport {
    pub fn total_shadow(&self) -> u64 {
        self.classes.iter().map(|c| c.shadow_n).sum()
    }

    pub fn total_violations(&self) -> u64 {
        self.classes.iter().map(|c| c.violations).sum()
    }

    pub fn total_trips(&self) -> u64 {
        self.classes.iter().map(|c| c.trips).sum()
    }

    /// Per-class table for `mcma serve`.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "QoS: p{:.0} err target {:.4} (shadow {:.1}%, {} ticks)",
                self.quantile * 100.0,
                self.target,
                self.shadow_rate * 100.0,
                self.ticks
            ),
            &["class", "margin", "invoked", "shadow n", "window", "observed q",
              "ewma", "violations", "trips", "breaker"],
        );
        for c in &self.classes {
            t.row(vec![
                format!("A{}", c.class),
                if c.margin >= MARGIN_PRECISE { "precise".into() } else { format!("{:.3}", c.margin) },
                c.invoked.to_string(),
                c.shadow_n.to_string(),
                c.window_n.to_string(),
                format!("{:.4}", c.observed_q),
                format!("{:.4}", c.ewma),
                c.violations.to_string(),
                c.trips.to_string(),
                if c.breaker_open { "OPEN".into() } else { "closed".into() },
            ]);
        }
        t
    }
}

/// Adaptive per-class invocation controller (see module docs).
#[derive(Clone, Debug)]
pub struct Controller {
    cfg: QosConfig,
    classes: Vec<ClassState>,
    obs_since_tick: u64,
    ticks: u64,
    warm_started: bool,
}

impl Controller {
    pub fn new(cfg: QosConfig, n_approx: usize) -> Self {
        let classes = (0..n_approx.max(1))
            .map(|_| ClassState {
                margin: 0.0,
                window: ErrorWindow::new(cfg.window.max(2)),
                breaker: Breaker::Closed,
                consec_violations: 0,
                violations: 0,
                trips: 0,
                last_q: 0.0,
                fresh_obs: 0,
            })
            .collect();
        Controller { cfg, classes, obs_since_tick: 0, ticks: 0, warm_started: false }
    }

    /// Seed per-class margins from an offline replay's final margins
    /// (`mcma serve --qos-warm`): the controller starts where the
    /// held-out data says it would end up, instead of at pure argmax.
    /// A replay margin of [`MARGIN_PRECISE`] (its breaker tripped) seeds
    /// at `margin_max` — breaker state is live-evidence-only, so the
    /// trip/half-open/closed semantics are unchanged; margins keep
    /// adapting from the seeded point exactly as from a cold start.
    pub fn seed_margins(&mut self, margins: &[f32]) {
        for (c, &m) in self.classes.iter_mut().zip(margins) {
            c.margin = if m >= MARGIN_PRECISE {
                self.cfg.margin_max
            } else {
                m.clamp(0.0, self.cfg.margin_max)
            };
        }
        self.warm_started = true;
    }

    pub fn n_classes(&self) -> usize {
        self.classes.len()
    }

    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    pub fn config(&self) -> &QosConfig {
        &self.cfg
    }

    /// Record one shadow observation: the served-vs-precise error of a
    /// sample approximator `class` answered.
    ///
    /// A non-finite error (a diverged net emitting NaN/inf) IS a quality
    /// failure, so it is recorded as the worst finite error rather than
    /// poisoning the window's quantile sort or the EWMA.
    pub fn observe(&mut self, class: usize, err: f64) {
        let err = if err.is_finite() { err } else { f64::MAX };
        if let Some(c) = self.classes.get_mut(class) {
            c.window.push(err);
            c.fresh_obs += 1;
            self.obs_since_tick += 1;
        }
    }

    /// Is any class's breaker currently open?  The server uses this to
    /// drive cooldown ticks from wall-clock when forced-precise classes
    /// produce no shadow observations (which would otherwise leave the
    /// breaker open forever).
    pub fn any_breaker_open(&self) -> bool {
        self.classes.iter().any(|c| matches!(c.breaker, Breaker::Open { .. }))
    }

    /// Effective margin of one class right now.
    pub fn margin(&self, class: usize) -> f32 {
        match self.classes[class].breaker {
            Breaker::Open { .. } => MARGIN_PRECISE,
            _ => self.classes[class].margin,
        }
    }

    /// Write every effective margin into a reused buffer (what the server
    /// publishes to the shared atomics after a tick).
    pub fn margins_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.extend((0..self.classes.len()).map(|k| self.margin(k)));
    }

    /// Run a control tick if `tick_every` observations accumulated since
    /// the last one.  Returns whether a tick ran (margins may have moved).
    pub fn maybe_tick(&mut self) -> bool {
        if self.obs_since_tick >= self.cfg.tick_every {
            self.tick();
            true
        } else {
            false
        }
    }

    /// One control-law step over every class (see module docs).
    pub fn tick(&mut self) {
        self.ticks += 1;
        self.obs_since_tick = 0;
        let cfg = self.cfg;
        for c in &mut self.classes {
            if let Breaker::Open { cooldown_left } = c.breaker {
                if cooldown_left > 1 {
                    c.breaker = Breaker::Open { cooldown_left: cooldown_left - 1 };
                } else {
                    // Half-open probe: admit only the most confident
                    // traffic and demand fresh evidence.
                    c.breaker = Breaker::HalfOpen;
                    c.margin = cfg.margin_max;
                    c.window.clear();
                    c.consec_violations = 0;
                    c.fresh_obs = 0;
                }
                continue;
            }
            if c.window.len() < cfg.min_obs || c.fresh_obs == 0 {
                continue; // no (new) evidence: hold, never re-judge stale
            }
            c.fresh_obs = 0;
            let q = c.window.quantile(cfg.quantile);
            c.last_q = q;
            if q > cfg.target {
                c.violations += 1;
                c.consec_violations += 1;
                let trip_at = match c.breaker {
                    Breaker::HalfOpen => 1,
                    _ => cfg.breaker_trip,
                };
                if c.consec_violations >= trip_at {
                    c.breaker = Breaker::Open { cooldown_left: cfg.breaker_cooldown.max(1) };
                    c.trips += 1;
                    c.consec_violations = 0;
                    c.window.clear();
                    c.fresh_obs = 0;
                } else {
                    c.margin = (c.margin + cfg.step).min(cfg.margin_max);
                }
            } else {
                c.consec_violations = 0;
                if c.breaker == Breaker::HalfOpen {
                    c.breaker = Breaker::Closed; // clean probe: recovered
                }
                if q < cfg.relax_frac * cfg.target {
                    c.margin = (c.margin - cfg.step * 0.5).max(0.0);
                }
                // else: hysteresis dead band — hold.
            }
        }
    }

    /// Snapshot for reporting.  `shadow_counts[k]` / `invoked_counts[k]`,
    /// when provided, carry the per-class shadow/invocation counters the
    /// server aggregates (`coordinator::metrics::ClassCounters`);
    /// otherwise shadow falls back to the window's lifetime total and
    /// invoked to 0.
    pub fn report(
        &mut self,
        shadow_counts: Option<&[u64]>,
        invoked_counts: Option<&[u64]>,
    ) -> QosReport {
        let (quantile, target, shadow_rate) =
            (self.cfg.quantile, self.cfg.target, self.cfg.shadow_rate);
        let classes = self
            .classes
            .iter_mut()
            .enumerate()
            .map(|(k, c)| ClassQos {
                class: k,
                margin: match c.breaker {
                    Breaker::Open { .. } => MARGIN_PRECISE,
                    _ => c.margin,
                },
                invoked: invoked_counts
                    .and_then(|s| s.get(k).copied())
                    .unwrap_or(0),
                shadow_n: shadow_counts
                    .and_then(|s| s.get(k).copied())
                    .unwrap_or_else(|| c.window.total()),
                window_n: c.window.len(),
                observed_q: if c.window.is_empty() { c.last_q } else { c.window.quantile(quantile) },
                ewma: c.window.ewma(),
                violations: c.violations,
                trips: c.trips,
                breaker_open: matches!(c.breaker, Breaker::Open { .. }),
            })
            .collect();
        QosReport {
            target,
            quantile,
            shadow_rate,
            ticks: self.ticks,
            shadow_dropped: 0,
            warm_started: self.warm_started,
            classes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> QosConfig {
        QosConfig {
            target: 0.1,
            quantile: 0.95,
            window: 64,
            min_obs: 8,
            tick_every: 16,
            step: 0.1,
            relax_frac: 0.7,
            breaker_trip: 3,
            breaker_cooldown: 2,
            margin_max: 0.9,
            ..QosConfig::default()
        }
    }

    fn feed(ctrl: &mut Controller, class: usize, err: f64, n: usize) {
        for _ in 0..n {
            ctrl.observe(class, err);
        }
    }

    #[test]
    fn no_evidence_no_movement() {
        let mut ctrl = Controller::new(cfg(), 2);
        feed(&mut ctrl, 0, 5.0, 4); // below min_obs
        ctrl.tick();
        assert_eq!(ctrl.margin(0), 0.0);
        assert_eq!(ctrl.report(None, None).total_violations(), 0);
    }

    #[test]
    fn violation_tightens_and_band_holds() {
        let mut ctrl = Controller::new(cfg(), 1);
        feed(&mut ctrl, 0, 0.5, 16);
        ctrl.tick();
        assert!((ctrl.margin(0) - 0.1).abs() < 1e-6, "one step up");
        // Refill the window inside the hysteresis band [0.07, 0.1]: hold.
        for _ in 0..64 {
            ctrl.observe(0, 0.08);
        }
        ctrl.tick();
        assert!((ctrl.margin(0) - 0.1).abs() < 1e-6, "dead band must hold");
        // Well under relax_frac * target: relax by step/2.
        for _ in 0..64 {
            ctrl.observe(0, 0.01);
        }
        ctrl.tick();
        assert!((ctrl.margin(0) - 0.05).abs() < 1e-6, "relax is half-speed");
    }

    #[test]
    fn maybe_tick_cadence() {
        let mut ctrl = Controller::new(cfg(), 1);
        feed(&mut ctrl, 0, 0.01, 15);
        assert!(!ctrl.maybe_tick());
        feed(&mut ctrl, 0, 0.01, 1);
        assert!(ctrl.maybe_tick());
        assert_eq!(ctrl.ticks(), 1);
        assert!(!ctrl.maybe_tick(), "counter reset after tick");
    }

    #[test]
    fn breaker_trips_cools_probes_recovers() {
        let mut ctrl = Controller::new(cfg(), 1);
        // 3 consecutive violating ticks -> trip.
        for _ in 0..3 {
            feed(&mut ctrl, 0, 1.0, 16);
            ctrl.tick();
        }
        assert_eq!(ctrl.margin(0), MARGIN_PRECISE, "breaker open forces precise");
        let r = ctrl.report(None, None);
        assert_eq!(r.total_trips(), 1);
        assert!(r.classes[0].breaker_open);
        // Cooldown (2 ticks), then half-open at margin_max.
        ctrl.tick();
        assert_eq!(ctrl.margin(0), MARGIN_PRECISE);
        ctrl.tick();
        assert!((ctrl.margin(0) - 0.9).abs() < 1e-6, "half-open probes at margin_max");
        // Clean probe closes the breaker and normal relaxation resumes.
        feed(&mut ctrl, 0, 0.01, 16);
        ctrl.tick();
        assert!(!ctrl.report(None, None).classes[0].breaker_open);
        assert!(ctrl.margin(0) < 0.9);
    }

    #[test]
    fn half_open_retrip_is_immediate() {
        let mut ctrl = Controller::new(cfg(), 1);
        for _ in 0..3 {
            feed(&mut ctrl, 0, 1.0, 16);
            ctrl.tick();
        }
        ctrl.tick(); // cooldown 2 -> 1
        ctrl.tick(); // half-open
        feed(&mut ctrl, 0, 1.0, 16);
        ctrl.tick(); // single violating probe re-trips
        assert_eq!(ctrl.margin(0), MARGIN_PRECISE);
        assert_eq!(ctrl.report(None, None).total_trips(), 2);
    }

    /// Open-loop monotonicity: on the SAME observation stream, a tighter
    /// target never yields a smaller margin at any tick — which is what
    /// makes "tighter target ⇒ invocation never increases" hold when the
    /// margins are applied to a fixed logit set.  The breaker is disabled
    /// here (its window clears would desynchronise the evidence the two
    /// controllers compare; a tripped class forces MARGIN_PRECISE, which
    /// is trivially monotone and covered by the breaker tests).
    #[test]
    fn margins_monotone_in_target_open_loop() {
        let mut rng = crate::util::rng::Rng::new(0xA11CE);
        let stream: Vec<(usize, f64)> = (0..4000)
            .map(|_| (rng.below(3) as usize, rng.lognormal(-3.0, 0.8)))
            .collect();
        // p95 of the stream is ~0.19, so these targets span always-raise,
        // mixed, mostly-hold and always-relax regimes.
        let targets = [0.05, 0.15, 0.25, 0.5];
        let mut trajectories: Vec<Vec<Vec<f32>>> = Vec::new();
        for &t in &targets {
            let mut ctrl = Controller::new(
                QosConfig { target: t, breaker_trip: u32::MAX, ..cfg() },
                3,
            );
            let mut per_tick = Vec::new();
            for &(k, e) in &stream {
                ctrl.observe(k, e);
                if ctrl.maybe_tick() {
                    let mut m = Vec::new();
                    ctrl.margins_into(&mut m);
                    per_tick.push(m);
                }
            }
            trajectories.push(per_tick);
        }
        for w in trajectories.windows(2) {
            let (tight, loose) = (&w[0], &w[1]);
            assert_eq!(tight.len(), loose.len());
            for (mt, ml) in tight.iter().zip(loose) {
                for (a, b) in mt.iter().zip(ml) {
                    assert!(
                        a >= b,
                        "tighter target produced a looser margin: {a} < {b}"
                    );
                }
            }
        }
    }

    /// A diverged net emitting NaN/inf must register as a worst-case
    /// violation, not panic the quantile sort.
    #[test]
    fn non_finite_errors_count_as_violations() {
        let mut ctrl = Controller::new(cfg(), 1);
        for _ in 0..8 {
            ctrl.observe(0, f64::NAN);
        }
        for _ in 0..8 {
            ctrl.observe(0, f64::INFINITY);
        }
        ctrl.tick(); // must not panic
        assert_eq!(ctrl.report(None, None).total_violations(), 1);
        assert!(ctrl.margin(0) > 0.0, "non-finite errors must tighten");
    }

    /// A class whose window received nothing new is never re-judged:
    /// other classes driving ticks must not let identical stale evidence
    /// accrue repeated violations (and eventually a bogus breaker trip).
    #[test]
    fn stale_window_never_rejudged() {
        let mut ctrl = Controller::new(cfg(), 2);
        feed(&mut ctrl, 0, 1.0, 16);
        ctrl.tick();
        assert_eq!(ctrl.report(None, None).classes[0].violations, 1);
        let m = ctrl.margin(0);
        // Ten more ticks driven purely by class 1 traffic.
        for _ in 0..10 {
            feed(&mut ctrl, 1, 0.01, 16);
            ctrl.tick();
        }
        let r = ctrl.report(None, None);
        assert_eq!(r.classes[0].violations, 1, "stale window was re-judged");
        assert_eq!(r.classes[0].trips, 0);
        assert_eq!(ctrl.margin(0), m, "margin moved on no new evidence");
    }

    /// Warm-started margins are clamped into [0, margin_max], a tripped
    /// replay class seeds at margin_max (never with an open breaker), the
    /// report records the warm start, and the control law keeps adapting
    /// from the seeded point.
    #[test]
    fn seed_margins_warm_start() {
        let mut ctrl = Controller::new(cfg(), 3);
        ctrl.seed_margins(&[0.3, MARGIN_PRECISE, 5.0]);
        assert!((ctrl.margin(0) - 0.3).abs() < 1e-6);
        assert!((ctrl.margin(1) - 0.9).abs() < 1e-6, "tripped class seeds at margin_max");
        assert!((ctrl.margin(2) - 0.9).abs() < 1e-6, "overshoot clamps to margin_max");
        let r = ctrl.report(None, None);
        assert!(r.warm_started);
        assert!(!r.classes.iter().any(|c| c.breaker_open), "seeding never opens a breaker");
        // Clean evidence relaxes from the seeded point at the normal rate.
        feed(&mut ctrl, 0, 0.01, 16);
        ctrl.tick();
        assert!((ctrl.margin(0) - 0.25).abs() < 1e-6);
        // A cold controller reports warm_started = false.
        assert!(!Controller::new(cfg(), 1).report(None, None).warm_started);
    }

    #[test]
    fn any_breaker_open_tracks_state() {
        let mut ctrl = Controller::new(cfg(), 2);
        assert!(!ctrl.any_breaker_open());
        for _ in 0..3 {
            feed(&mut ctrl, 0, 1.0, 16);
            ctrl.tick();
        }
        assert!(ctrl.any_breaker_open());
        ctrl.tick(); // cooldown 2 -> 1
        ctrl.tick(); // half-open: no longer Open
        assert!(!ctrl.any_breaker_open());
    }

    #[test]
    fn report_reflects_counters_when_given() {
        let mut ctrl = Controller::new(cfg(), 2);
        feed(&mut ctrl, 0, 0.01, 5);
        let r = ctrl.report(Some(&[123, 456]), Some(&[1000, 2000]));
        assert_eq!(r.classes[0].shadow_n, 123);
        assert_eq!(r.classes[1].shadow_n, 456);
        assert_eq!(r.classes[0].invoked, 1000);
        assert_eq!(r.classes[1].invoked, 2000);
        let r2 = ctrl.report(None, None);
        assert_eq!(r2.classes[0].shadow_n, 5, "falls back to window totals");
        // Table renders without panicking and names every class.
        assert_eq!(r.table().rows.len(), 2);
    }
}
