//! Deterministic shadow sampling: which approximated requests get
//! re-verified against the precise function.
//!
//! The decision is a pure function of `(seed, request id)` — a SplitMix64
//! finalizer hashed against a rate threshold — NOT a stateful RNG stream.
//! That makes the sampled set bit-identical no matter how requests are
//! batched, which dispatch worker handles them, or in what order they
//! arrive: the determinism the QoS acceptance tests pin across thread
//! counts.  It is also unbiased per request (each id is an independent
//! Bernoulli draw at `rate`), so the per-class error estimate is an
//! unbiased sample of the errors actually served.

/// Stateless seeded sampler; `Copy` so every dispatch worker carries its
/// own by value (no sharing, no locks).
#[derive(Clone, Copy, Debug)]
pub struct ShadowSampler {
    seed: u64,
    /// `pick` iff `hash(seed, id) < threshold`; `u64::MAX` means "always"
    /// (the `rate >= 1.0` case is handled exactly via `all`).
    threshold: u64,
    all: bool,
}

use crate::util::rng::splitmix64;

impl ShadowSampler {
    pub fn new(seed: u64, rate: f64) -> Self {
        let rate = rate.clamp(0.0, 1.0);
        ShadowSampler {
            seed,
            // f64 -> u64 `as` saturates, so rate = 1.0 maps to u64::MAX;
            // the `all` flag closes the one-in-2^64 gap exactly.
            threshold: (rate * u64::MAX as f64) as u64,
            all: rate >= 1.0,
        }
    }

    /// Should request `id` be shadow-verified?  Pure in `(seed, id)`.
    #[inline]
    pub fn pick(&self, id: u64) -> bool {
        self.all
            || splitmix64(self.seed ^ id.wrapping_mul(0xA24B_AED4_963E_E407))
                < self.threshold
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed_and_id() {
        let a = ShadowSampler::new(7, 0.1);
        let b = ShadowSampler::new(7, 0.1);
        for id in 0..10_000 {
            assert_eq!(a.pick(id), b.pick(id));
        }
    }

    /// The sampled set is a function of ids only — partitioning the id
    /// space across any number of workers, in any order, yields the same
    /// picks (the thread-count determinism the server relies on).
    #[test]
    fn order_and_partition_invariant() {
        let s = ShadowSampler::new(0x5AD0, 0.2);
        let forward: Vec<u64> = (0..4096).filter(|&id| s.pick(id)).collect();
        // "Two workers": evens then odds, reversed.
        let mut interleaved: Vec<u64> = (0..4096)
            .rev()
            .filter(|id| id % 2 == 0)
            .chain((0..4096).rev().filter(|id| id % 2 == 1))
            .filter(|&id| s.pick(id))
            .collect();
        interleaved.sort_unstable();
        assert_eq!(forward, interleaved);
    }

    #[test]
    fn rate_is_approximately_honoured() {
        for &rate in &[0.01, 0.05, 0.25, 0.5] {
            let s = ShadowSampler::new(42, rate);
            let n = 100_000u64;
            let hits = (0..n).filter(|&id| s.pick(id)).count() as f64;
            let got = hits / n as f64;
            assert!(
                (got - rate).abs() < 0.01,
                "rate {rate}: sampled {got}"
            );
        }
    }

    #[test]
    fn edge_rates() {
        let never = ShadowSampler::new(1, 0.0);
        let always = ShadowSampler::new(1, 1.0);
        for id in 0..1000 {
            assert!(!never.pick(id));
            assert!(always.pick(id));
        }
    }

    #[test]
    fn different_seeds_sample_different_sets() {
        let a = ShadowSampler::new(1, 0.5);
        let b = ShadowSampler::new(2, 0.5);
        let pa: Vec<bool> = (0..64).map(|id| a.pick(id)).collect();
        let pb: Vec<bool> = (0..64).map(|id| b.pick(id)).collect();
        assert_ne!(pa, pb);
    }
}
