//! Online per-class error estimation: a fixed-capacity sliding window of
//! the most recent shadow-observed errors, plus an EWMA for a smoothed
//! central tendency.
//!
//! The window (not a lifetime accumulator) is what makes the controller
//! drift-aware: if the input distribution moves and an approximator's
//! error regime changes, old observations age out after `capacity` more
//! arrivals and the quantile reflects the new regime.  Quantiles are
//! computed on demand (controller tick, off the request hot path) by
//! sorting into a reused scratch buffer — no allocation in steady state.

use crate::util::stats;

/// Sliding window of recent error observations for ONE class.
#[derive(Clone, Debug)]
pub struct ErrorWindow {
    buf: Vec<f64>,
    capacity: usize,
    head: usize,
    len: usize,
    /// Lifetime observation count (never resets on `clear`).
    total: u64,
    ewma: f64,
    alpha: f64,
    /// Reused by `quantile` so ticks allocate nothing once warm.
    scratch: Vec<f64>,
}

impl ErrorWindow {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        ErrorWindow {
            buf: Vec::with_capacity(capacity),
            capacity,
            head: 0,
            len: 0,
            total: 0,
            ewma: 0.0,
            // ~window-length memory for the smoothed mean.
            alpha: 2.0 / (capacity as f64 + 1.0),
            scratch: Vec::with_capacity(capacity),
        }
    }

    pub fn push(&mut self, err: f64) {
        if self.buf.len() < self.capacity {
            self.buf.push(err);
        } else {
            self.buf[self.head] = err;
        }
        self.head = (self.head + 1) % self.capacity;
        self.len = (self.len + 1).min(self.capacity);
        self.ewma = if self.total == 0 {
            err
        } else {
            self.ewma + self.alpha * (err - self.ewma)
        };
        self.total += 1;
    }

    /// Observations currently in the window.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Lifetime observations (survives `clear`).
    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn ewma(&self) -> f64 {
        self.ewma
    }

    /// Linear-interpolated quantile of the CURRENT window, `q` in [0, 1].
    /// 0 for an empty window.  `&mut` only for the reused sort scratch.
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        self.scratch.clear();
        self.scratch.extend_from_slice(&self.buf[..self.len]);
        self.scratch
            .sort_by(|a, b| a.partial_cmp(b).expect("error observations are finite"));
        stats::percentile_sorted(&self.scratch, q * 100.0)
    }

    /// Drop the windowed contents (breaker recovery starts from fresh
    /// evidence); the lifetime `total` and EWMA survive.
    pub fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_matches_batch_percentile() {
        let mut w = ErrorWindow::new(128);
        let xs: Vec<f64> = (0..100).map(|i| ((i * 37) % 100) as f64 / 100.0).collect();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.quantile(0.95) - stats::percentile(&xs, 95.0)).abs() < 1e-12);
        assert!((w.quantile(0.5) - stats::percentile(&xs, 50.0)).abs() < 1e-12);
        assert_eq!(w.len(), 100);
        assert_eq!(w.total(), 100);
    }

    /// Old observations age out: after `capacity` pushes from a new
    /// regime, the quantile reflects ONLY the new regime.
    #[test]
    fn window_evicts_old_regime() {
        let mut w = ErrorWindow::new(16);
        for _ in 0..16 {
            w.push(1.0);
        }
        assert!(w.quantile(0.95) > 0.99);
        for _ in 0..16 {
            w.push(0.01);
        }
        assert!(w.quantile(0.95) < 0.02, "old regime still visible");
        assert_eq!(w.len(), 16);
        assert_eq!(w.total(), 32);
    }

    #[test]
    fn partial_fill_ring_wrap() {
        let mut w = ErrorWindow::new(4);
        assert_eq!(w.quantile(0.5), 0.0);
        w.push(3.0);
        assert_eq!(w.quantile(0.5), 3.0);
        for x in [1.0, 2.0, 4.0, 5.0, 6.0] {
            w.push(x);
        }
        // Window now holds the last 4: {2, 4, 5, 6}.
        assert_eq!(w.len(), 4);
        assert_eq!(w.quantile(0.0), 2.0);
        assert_eq!(w.quantile(1.0), 6.0);
    }

    #[test]
    fn ewma_tracks_level() {
        let mut w = ErrorWindow::new(32);
        for _ in 0..200 {
            w.push(0.5);
        }
        assert!((w.ewma() - 0.5).abs() < 1e-9);
        for _ in 0..200 {
            w.push(1.5);
        }
        assert!((w.ewma() - 1.5).abs() < 0.01);
    }

    #[test]
    fn clear_keeps_lifetime_total() {
        let mut w = ErrorWindow::new(8);
        for _ in 0..5 {
            w.push(1.0);
        }
        w.clear();
        assert_eq!(w.len(), 0);
        assert_eq!(w.total(), 5);
        assert_eq!(w.quantile(0.95), 0.0);
        w.push(2.0);
        assert_eq!(w.len(), 1);
        assert_eq!(w.total(), 6);
    }
}
