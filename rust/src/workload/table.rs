//! Table workload source — a dependency-free CSV/TSV reader with schema
//! inference, plus the deterministic train/held-out split that makes a
//! data file trainable.
//!
//! ## File contract (see `rust/README.md` "Bring your own workload")
//!
//! * Delimiter: inferred from the first data line — tab ⇒ TSV, else CSV.
//! * Header: if any cell of the first non-comment line fails numeric
//!   parsing the line is treated as a header; otherwise it is data.
//! * Empty lines and lines starting with `#` are skipped.
//! * Every row must have the same column count; the LAST `d_out` columns
//!   are the outputs (labels), the rest are inputs.
//! * Every cell must parse as a finite number — NaN/inf and ragged rows
//!   are hard errors diagnosed with their 1-based line (and column)
//!   numbers.
//!
//! The split into train/held-out rows is a seeded Fisher–Yates shuffle
//! over row indices (`util::rng` stream, salted) — a pure function of
//! `(file contents, holdout fraction, seed)`, independent of thread count
//! and machine, so re-training is reproducible and the held-out labels
//! the oracle-less QoS loop verifies against never leak into training.

use std::path::Path;

use crate::formats::{BenchManifest, WorkloadKind};
use crate::util::rng::Rng;

use super::{pad_bounds, TrainData, WorkloadSource};

/// Seed salt for the train/held-out split stream (distinct from every
/// trainer stream so reordering rows never aliases an epoch shuffle).
const SPLIT_SALT: u64 = 0x5B17_7AB1;

/// Minimum training rows the split must leave (matches the trainer's own
/// floor in `train::train_bench`): fewer make minibatch SGD meaningless.
const MIN_TRAIN_ROWS: usize = 8;

/// Rows held out of `n` at fraction `holdout` (at least 1, never all).
fn holdout_count(n: usize, holdout: f64) -> usize {
    ((n as f64 * holdout).ceil() as usize).clamp(1, n - 1)
}

/// A parsed numeric table: raw inputs and raw outputs, row-aligned.
#[derive(Clone, Debug)]
pub struct TableData {
    /// Workload name (file stem, sanitised to `[A-Za-z0-9_-]`).
    pub name: String,
    pub n: usize,
    pub d_in: usize,
    pub d_out: usize,
    /// Row-major `(n, d_in)` raw inputs.
    pub x_raw: Vec<f32>,
    /// Row-major `(n, d_out)` RAW outputs (normalisation happens against
    /// the derived manifest bounds, exactly like the synthetic path).
    pub y_raw: Vec<f32>,
    /// Hex FNV-1a 64 digest of the source bytes (manifest `source_digest`).
    pub digest: String,
    /// Column names from the header row (synthesised `c0..` without one).
    pub columns: Vec<String>,
    pub had_header: bool,
    pub delimiter: char,
}

/// FNV-1a 64 over raw bytes, rendered as lowercase hex.
pub fn fnv1a_hex(bytes: &[u8]) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    format!("{h:016x}")
}

/// File stem reduced to manifest-safe characters.
fn sanitize_name(path: &Path) -> String {
    let stem = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "workload".into());
    let cleaned: String = stem
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .collect();
    if cleaned.is_empty() { "workload".into() } else { cleaned }
}

impl TableData {
    /// Read + parse a CSV/TSV file; the trailing `d_out` columns are the
    /// labels.
    pub fn load(path: &Path, d_out: usize) -> crate::Result<Self> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let text = String::from_utf8(bytes.clone())
            .map_err(|_| anyhow::anyhow!("{}: not valid UTF-8", path.display()))?;
        let origin = path.display().to_string();
        let mut t = Self::parse(&text, d_out, &origin)?;
        t.name = sanitize_name(path);
        t.digest = fnv1a_hex(&bytes);
        Ok(t)
    }

    /// Parse table text (`origin` labels diagnostics, e.g. the file path).
    pub fn parse(text: &str, d_out: usize, origin: &str) -> crate::Result<Self> {
        anyhow::ensure!(d_out >= 1, "--d-out must be >= 1");

        // 1-based line numbers over PHYSICAL lines so diagnostics point at
        // the row the user sees in an editor.
        let mut rows: Vec<(usize, &str)> = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim_end_matches('\r');
            let trimmed = line.trim();
            if trimmed.is_empty() || trimmed.starts_with('#') {
                continue;
            }
            rows.push((i + 1, line));
        }
        anyhow::ensure!(!rows.is_empty(), "{origin}: no data rows");

        let delimiter = if rows[0].1.contains('\t') { '\t' } else { ',' };
        let split = |line: &str| -> Vec<String> {
            line.split(delimiter).map(|c| c.trim().to_string()).collect()
        };

        // Header inference: any non-numeric cell on the first line makes
        // it a header (a fully-numeric header row is indistinguishable
        // from data and is treated as data).
        let first_cells = split(rows[0].1);
        let n_cols = first_cells.len();
        anyhow::ensure!(
            n_cols > d_out,
            "{origin}: {n_cols} column(s) but --d-out {d_out} — need at \
             least one input column"
        );
        let had_header = first_cells.iter().any(|c| c.parse::<f32>().is_err());
        let columns = if had_header {
            first_cells
        } else {
            (0..n_cols).map(|i| format!("c{i}")).collect()
        };
        let data_rows = if had_header { &rows[1..] } else { &rows[..] };

        let d_in = n_cols - d_out;
        let mut x_raw = Vec::with_capacity(data_rows.len() * d_in);
        let mut y_raw = Vec::with_capacity(data_rows.len() * d_out);
        for &(lineno, line) in data_rows {
            let cells = split(line);
            anyhow::ensure!(
                cells.len() == n_cols,
                "{origin}:{lineno}: expected {n_cols} columns, got {} (ragged row)",
                cells.len()
            );
            for (col, cell) in cells.iter().enumerate() {
                let v: f32 = cell.parse().map_err(|_| {
                    anyhow::anyhow!(
                        "{origin}:{lineno}, column {}: cannot parse {cell:?} as a number",
                        col + 1
                    )
                })?;
                anyhow::ensure!(
                    v.is_finite(),
                    "{origin}:{lineno}, column {}: non-finite value {cell:?}",
                    col + 1
                );
                if col < d_in {
                    x_raw.push(v);
                } else {
                    y_raw.push(v);
                }
            }
        }
        let n = data_rows.len();
        anyhow::ensure!(
            n >= 8,
            "{origin}: only {n} data row(s) — need at least 8 (and enough \
             to leave {MIN_TRAIN_ROWS} training rows after the held-out \
             split)"
        );

        Ok(TableData {
            name: "workload".into(),
            n,
            d_in,
            d_out,
            x_raw,
            y_raw,
            digest: fnv1a_hex(text.as_bytes()),
            columns,
            had_header,
            delimiter,
        })
    }

    fn x_row(&self, i: usize) -> &[f32] {
        &self.x_raw[i * self.d_in..(i + 1) * self.d_in]
    }

    fn y_row(&self, i: usize) -> &[f32] {
        &self.y_raw[i * self.d_out..(i + 1) * self.d_out]
    }
}

/// A trainable workload defined entirely by a data file.
pub struct TableSource {
    data: TableData,
    /// Fraction of rows held out for evaluation/QoS verification.
    holdout: f64,
}

impl TableSource {
    pub fn load(path: &Path, d_out: usize, holdout: f64) -> crate::Result<Self> {
        Self::from_data(TableData::load(path, d_out)?, holdout)
    }

    pub fn from_data(data: TableData, holdout: f64) -> crate::Result<Self> {
        anyhow::ensure!(
            (0.05..=0.5).contains(&holdout),
            "--holdout must be in [0.05, 0.5], got {holdout}"
        );
        // Validate the split up front with an actionable minimum, instead
        // of letting the trainer fail later with a bare row count.
        let n_train = data.n - holdout_count(data.n, holdout);
        anyhow::ensure!(
            n_train >= MIN_TRAIN_ROWS,
            "{}: {} data row(s) leave only {n_train} training row(s) after \
             the {:.0}% held-out split — need at least {} training rows \
             (add rows or lower --holdout)",
            data.name,
            data.n,
            holdout * 100.0,
            MIN_TRAIN_ROWS
        );
        Ok(TableSource { data, holdout })
    }

    pub fn table(&self) -> &TableData {
        &self.data
    }

    /// The deterministic row split: `(train_indices, held_out_indices)`,
    /// disjoint and covering every row.  A pure function of
    /// `(n, holdout, seed)` — thread count and machine never enter.
    pub fn split_indices(&self, seed: u64) -> (Vec<usize>, Vec<usize>) {
        let n = self.data.n;
        let mut order: Vec<usize> = (0..n).collect();
        Rng::new(seed ^ SPLIT_SALT).shuffle(&mut order);
        let n_hold = holdout_count(n, self.holdout);
        let held = order[..n_hold].to_vec();
        let train = order[n_hold..].to_vec();
        (train, held)
    }

    /// Build a [`TrainData`] from a row-index slice, normalised via `man`.
    fn slice(&self, man: &BenchManifest, idx: &[usize]) -> TrainData {
        let (d_in, d_out) = (self.data.d_in, self.data.d_out);
        let n = idx.len();
        let mut x_raw = Vec::with_capacity(n * d_in);
        let mut x_norm = vec![0.0f32; n * d_in];
        let mut y_norm = vec![0.0f32; n * d_out];
        let mut y_f64 = vec![0.0f64; d_out];
        for (j, &i) in idx.iter().enumerate() {
            let xr = self.data.x_row(i);
            x_raw.extend_from_slice(xr);
            man.normalize_x_into(xr, &mut x_norm[j * d_in..(j + 1) * d_in]);
            for (d, &v) in self.data.y_row(i).iter().enumerate() {
                y_f64[d] = v as f64;
            }
            man.normalize_y_into(&y_f64, &mut y_norm[j * d_out..(j + 1) * d_out]);
        }
        TrainData { n, d_in, d_out, x_raw, x_norm, y_norm }
    }

    /// Data-derived default error bound: a twentieth of the mean
    /// normalised output interquartile range, clamped to [0.01, 0.1].
    /// Wide-spread outputs earn a looser bound than near-constant ones —
    /// the analogue of the paper's per-benchmark hand-chosen bounds —
    /// while `--bound` still overrides.
    fn derive_error_bound(&self, y_lo: &[f32], y_hi: &[f32]) -> f64 {
        let (n, d_out) = (self.data.n, self.data.d_out);
        let mut iqr_sum = 0.0f64;
        let mut vals = vec![0.0f32; n];
        for d in 0..d_out {
            let scale = y_hi[d] - y_lo[d];
            for i in 0..n {
                vals[i] = (self.data.y_raw[i * d_out + d] - y_lo[d]) / scale;
            }
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let q = |p: f64| vals[((n - 1) as f64 * p).round() as usize] as f64;
            iqr_sum += q(0.75) - q(0.25);
        }
        (0.05 * iqr_sum / d_out as f64).clamp(0.01, 0.1)
    }
}

impl WorkloadSource for TableSource {
    fn name(&self) -> &str {
        &self.data.name
    }

    fn kind(&self) -> WorkloadKind {
        WorkloadKind::Table
    }

    fn d_in(&self) -> usize {
        self.data.d_in
    }

    fn d_out(&self) -> usize {
        self.data.d_out
    }

    fn digest(&self) -> String {
        self.data.digest.clone()
    }

    fn derive_manifest(&self, k: usize, error_bound: Option<f64>, _seed: u64) -> BenchManifest {
        let (d_in, d_out) = (self.data.d_in, self.data.d_out);
        // Normalisation bounds come from the data itself: per-column
        // min/max over every row, padded like the synthetic probe.
        let mut x_lo = vec![f32::INFINITY; d_in];
        let mut x_hi = vec![f32::NEG_INFINITY; d_in];
        let mut y_lo = vec![f32::INFINITY; d_out];
        let mut y_hi = vec![f32::NEG_INFINITY; d_out];
        for i in 0..self.data.n {
            for (d, &v) in self.data.x_row(i).iter().enumerate() {
                x_lo[d] = x_lo[d].min(v);
                x_hi[d] = x_hi[d].max(v);
            }
            for (d, &v) in self.data.y_row(i).iter().enumerate() {
                y_lo[d] = y_lo[d].min(v);
                y_hi[d] = y_hi[d].max(v);
            }
        }
        for d in 0..d_in {
            let (lo, hi) = pad_bounds(x_lo[d], x_hi[d]);
            x_lo[d] = lo;
            x_hi[d] = hi;
        }
        for d in 0..d_out {
            let (lo, hi) = pad_bounds(y_lo[d], y_hi[d]);
            y_lo[d] = lo;
            y_hi[d] = hi;
        }
        let error_bound = error_bound.unwrap_or_else(|| self.derive_error_bound(&y_lo, &y_hi));

        // Topology heuristic: hidden width grows with the input width
        // (clamped to the paper's Fig. 6 envelope) so wide tables get
        // proportionally more capacity than the 2-input benchmarks.
        let h = (2 * d_in).clamp(8, 32);
        BenchManifest {
            name: self.data.name.clone(),
            domain: "user-table".to_string(),
            kind: WorkloadKind::Table,
            source_digest: self.data.digest.clone(),
            n_in: d_in,
            n_out: d_out,
            approx_topology: vec![d_in, h, h, d_out],
            clf2_topology: vec![d_in, h, 2],
            clfn_topology: vec![d_in, (2 * h).min(48), k + 1],
            x_lo,
            x_hi,
            y_lo,
            y_hi,
            error_bound,
            train_n: 0,
            test_n: 0,
            methods: Vec::new(),
            mcca_pairs: 0,
        }
    }

    fn datasets(
        &self,
        man: &BenchManifest,
        n_train: usize,
        n_test: usize,
        seed: u64,
    ) -> crate::Result<(TrainData, TrainData)> {
        let (mut train_idx, mut held_idx) = self.split_indices(seed);
        // Caps keep tiny-budget runs tiny; the split itself is fixed, so
        // the held-out rows never migrate into training across budgets.
        train_idx.truncate(n_train.max(1));
        held_idx.truncate(n_test.max(1));
        Ok((self.slice(man, &train_idx), self.slice(man, &held_idx)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CSV_HEADER: &str = "\
x0,x1,y
0.0,0.0,0.10
0.1,0.5,0.25
0.2,1.0,0.40
0.9,0.0,0.85
1.0,0.5,0.70
0.8,1.0,0.55
0.4,0.2,0.21
0.6,0.8,0.61
0.3,0.3,0.28
0.7,0.7,0.64
0.5,0.1,0.48
0.2,0.6,0.33
";

    #[test]
    fn parses_header_csv() {
        let t = TableData::parse(CSV_HEADER, 1, "mem.csv").unwrap();
        assert!(t.had_header);
        assert_eq!(t.delimiter, ',');
        assert_eq!((t.n, t.d_in, t.d_out), (12, 2, 1));
        assert_eq!(t.columns, vec!["x0", "x1", "y"]);
        assert_eq!(t.x_row(1), &[0.1, 0.5]);
        assert_eq!(t.y_row(1), &[0.25]);
        assert_eq!(t.digest.len(), 16, "digest must be 16 hex chars");
    }

    #[test]
    fn parses_headerless_and_comments() {
        let text = "# a comment\n1,2,3\n\n4,5,6\n7,8,9\n1,1,1\n2,2,2\n3,3,3\n4,4,4\n5,5,5\n";
        let t = TableData::parse(text, 1, "mem.csv").unwrap();
        assert!(!t.had_header);
        assert_eq!(t.columns, vec!["c0", "c1", "c2"]);
        assert_eq!((t.n, t.d_in, t.d_out), (8, 2, 1));
        assert_eq!(t.x_row(0), &[1.0, 2.0]);
        assert_eq!(t.y_row(0), &[3.0]);
    }

    #[test]
    fn infers_tsv_and_d_out_split() {
        let text = "a\tb\tc\td\n1\t2\t3\t4\n5\t6\t7\t8\n1\t1\t1\t1\n2\t2\t2\t2\n\
                    3\t3\t3\t3\n4\t4\t4\t4\n5\t5\t5\t5\n6\t6\t6\t6\n";
        let t = TableData::parse(text, 2, "mem.tsv").unwrap();
        assert_eq!(t.delimiter, '\t');
        assert_eq!((t.d_in, t.d_out), (2, 2));
        assert_eq!(t.y_row(0), &[3.0, 4.0]);
    }

    #[test]
    fn ragged_row_diagnosed_with_line_number() {
        let mut text = String::from(CSV_HEADER);
        text.push_str("0.5,0.5\n"); // line 14: one column short
        let e = TableData::parse(&text, 1, "bad.csv").unwrap_err().to_string();
        assert!(e.contains("bad.csv:14"), "missing line number: {e}");
        assert!(e.contains("ragged"), "missing ragged diagnosis: {e}");
    }

    #[test]
    fn non_numeric_cell_diagnosed_with_line_and_column() {
        let mut text = String::from(CSV_HEADER);
        text.push_str("0.5,oops,0.5\n");
        let e = TableData::parse(&text, 1, "bad.csv").unwrap_err().to_string();
        assert!(e.contains("bad.csv:14, column 2"), "bad location: {e}");
        assert!(e.contains("oops"), "must quote the cell: {e}");
    }

    #[test]
    fn non_finite_cell_rejected() {
        let mut text = String::from(CSV_HEADER);
        text.push_str("0.5,NaN,0.5\n");
        let e = TableData::parse(&text, 1, "bad.csv").unwrap_err().to_string();
        assert!(e.contains("non-finite"), "{e}");
        assert!(e.contains(":14"), "{e}");
        let mut text2 = String::from(CSV_HEADER);
        text2.push_str("inf,0.5,0.5\n");
        assert!(TableData::parse(&text2, 1, "bad.csv").is_err());
    }

    #[test]
    fn too_few_rows_and_bad_d_out_rejected() {
        let e = TableData::parse("1,2\n3,4\n", 1, "tiny.csv").unwrap_err().to_string();
        assert!(e.contains("at least 8"), "{e}");
        let e = TableData::parse(CSV_HEADER, 3, "mem.csv").unwrap_err().to_string();
        assert!(e.contains("--d-out"), "{e}");
        assert!(TableData::parse(CSV_HEADER, 0, "mem.csv").is_err());
    }

    #[test]
    fn digest_tracks_content() {
        let a = TableData::parse(CSV_HEADER, 1, "a.csv").unwrap();
        let b = TableData::parse(CSV_HEADER, 1, "b.csv").unwrap();
        assert_eq!(a.digest, b.digest, "digest is content-only");
        let mut text = String::from(CSV_HEADER);
        text.push_str("0.5,0.5,0.5\n");
        let c = TableData::parse(&text, 1, "a.csv").unwrap();
        assert_ne!(a.digest, c.digest);
    }

    fn source() -> TableSource {
        TableSource::from_data(TableData::parse(CSV_HEADER, 1, "mem.csv").unwrap(), 0.25)
            .unwrap()
    }

    #[test]
    fn split_is_deterministic_disjoint_and_covering() {
        let s = source();
        let (tr1, te1) = s.split_indices(9);
        let (tr2, te2) = s.split_indices(9);
        assert_eq!(tr1, tr2, "split must be a pure function of the seed");
        assert_eq!(te1, te2);
        let (tr3, _) = s.split_indices(10);
        assert_ne!(tr1, tr3, "different seeds should split differently");

        let mut all: Vec<usize> = tr1.iter().chain(&te1).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..12).collect::<Vec<_>>(), "split must partition rows");
        assert_eq!(te1.len(), 3, "ceil(12 * 0.25) held out");
    }

    /// The split minimum is enforced at load time with an actionable
    /// message — not deferred to a bare row-count error in the trainer.
    #[test]
    fn too_few_training_rows_after_split_rejected() {
        // 9 rows at 25% holdout leave 6 training rows (< 8).
        let text = "1,2,3\n4,5,6\n7,8,9\n1,1,1\n2,2,2\n3,3,3\n4,4,4\n5,5,5\n6,6,6\n";
        let data = TableData::parse(text, 1, "mem.csv").unwrap();
        let e = TableSource::from_data(data.clone(), 0.25).unwrap_err().to_string();
        assert!(e.contains("training row"), "{e}");
        assert!(e.contains("--holdout"), "must suggest the fix: {e}");
        // A smaller holdout on the same data is fine (ceil(9*0.1)=1 held).
        assert!(TableSource::from_data(data, 0.1).is_ok());
    }

    #[test]
    fn derived_manifest_normalises_data_into_unit_box() {
        let s = source();
        let man = s.derive_manifest(2, None, 1);
        assert_eq!(man.kind, WorkloadKind::Table);
        assert_eq!(man.source_digest, s.digest());
        assert_eq!(man.approx_topology, vec![2, 8, 8, 1]);
        assert_eq!(*man.clfn_topology.last().unwrap(), 3);
        assert!((0.01..=0.1).contains(&man.error_bound), "{}", man.error_bound);

        let (train, test) = s.datasets(&man, 100, 100, 7).unwrap();
        assert_eq!(train.n + test.n, 12);
        for v in train.x_norm.iter().chain(&train.y_norm).chain(&test.y_norm) {
            assert!((0.0..=1.0).contains(v), "normalised value {v} out of range");
        }
        // Explicit bound overrides the data-derived one.
        let man2 = s.derive_manifest(2, Some(0.42), 1);
        assert_eq!(man2.error_bound, 0.42);
    }

    #[test]
    fn dataset_caps_respect_split() {
        let s = source();
        let man = s.derive_manifest(2, None, 1);
        let (full_train, full_test) = s.datasets(&man, 100, 100, 3).unwrap();
        let (capped_train, capped_test) = s.datasets(&man, 4, 2, 3).unwrap();
        assert_eq!(capped_train.n, 4);
        assert_eq!(capped_test.n, 2);
        // Caps are a prefix of the same split — held-out rows never
        // migrate into training across budgets.
        assert_eq!(&full_train.x_raw[..4 * 2], &capped_train.x_raw[..]);
        assert_eq!(&full_test.x_raw[..2 * 2], &capped_test.x_raw[..]);
    }

    #[test]
    fn fnv_digest_known_vector() {
        // FNV-1a 64 of the empty string is the offset basis.
        assert_eq!(fnv1a_hex(b""), "cbf29ce484222325");
        assert_eq!(fnv1a_hex(b"a"), "af63dc4c8601ec8c");
    }
}
