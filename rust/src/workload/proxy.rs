//! The precise-path proxy — what the dispatcher's CPU fallback and the
//! QoS shadow verifier call when a "precise" answer is needed.
//!
//! For the paper's registered benchmarks the proxy IS the precise
//! function.  For table workloads no closed-form oracle exists at
//! runtime, so the proxy is either a nearest-record lookup over the
//! held-out store ([`NearestLookup`]) — exact on held-out replay (eval,
//! QoS shadow verification), nearest-neighbour interpolation on unseen
//! inputs — or a configurable reject-with-error for serving setups that
//! would rather fail a request than serve an interpolated answer.

use std::sync::Arc;

use crate::benchmarks::{self, BenchFn};
use crate::formats::{BenchManifest, Dataset, WorkloadKind};

/// Nearest-record store: raw input rows with their normalised labels.
/// Distance is squared L2 in NORMALISED input space (per-dimension
/// `1/(hi-lo)` scaling), so wide raw columns don't dominate the metric.
pub struct NearestLookup {
    n: usize,
    d_in: usize,
    d_out: usize,
    x_raw: Vec<f32>,
    y_norm: Vec<f32>,
    inv_scale: Vec<f32>,
}

impl NearestLookup {
    pub fn from_dataset(bench: &BenchManifest, ds: &Dataset) -> Self {
        assert_eq!(ds.d_in, bench.n_in, "lookup store/bench input dims disagree");
        assert_eq!(ds.d_out, bench.n_out);
        assert!(ds.n > 0, "lookup store must be non-empty");
        let inv_scale = (0..bench.n_in)
            .map(|d| {
                let r = bench.x_hi[d] - bench.x_lo[d];
                if r > 0.0 { 1.0 / r } else { 0.0 }
            })
            .collect();
        NearestLookup {
            n: ds.n,
            d_in: ds.d_in,
            d_out: ds.d_out,
            x_raw: ds.x_raw.clone(),
            y_norm: ds.y_norm.clone(),
            inv_scale,
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Copy the label of the nearest stored record into `out`
    /// (normalised space).  Linear scan — allocation-free, O(n · d_in);
    /// the store is a held-out set (hundreds–thousands of rows), and the
    /// cost model charges the precise path accordingly
    /// ([`super::precise_cost_cycles`]).
    pub fn lookup_into(&self, x_raw: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x_raw.len(), self.d_in);
        debug_assert_eq!(out.len(), self.d_out);
        let (mut best_i, mut best_d) = (0usize, f64::INFINITY);
        for i in 0..self.n {
            let row = &self.x_raw[i * self.d_in..(i + 1) * self.d_in];
            let mut dist = 0.0f64;
            for d in 0..self.d_in {
                let diff = ((x_raw[d] - row[d]) * self.inv_scale[d]) as f64;
                dist += diff * diff;
                if dist >= best_d {
                    break; // early-out: already worse than the best
                }
            }
            if dist < best_d {
                best_d = dist;
                best_i = i;
            }
        }
        out.copy_from_slice(&self.y_norm[best_i * self.d_out..(best_i + 1) * self.d_out]);
    }
}

/// The precise path behind the dispatcher and the QoS shadow verifier.
pub enum PreciseProxy {
    /// A registered precise benchmark function (synthetic workloads).
    Function(Box<dyn BenchFn>),
    /// Held-out nearest-record lookup (table workloads: eval and the
    /// default serve fallback).  `Arc` so a multi-worker server shares
    /// ONE store instead of one copy per dispatch thread.
    Lookup(Arc<NearestLookup>),
    /// No oracle configured: any precise-path sample is a hard error
    /// (table workloads served with `--precise-fallback reject`).
    Reject,
}

impl PreciseProxy {
    /// The default proxy for a manifest entry: the registered function
    /// for synthetic workloads (unknown names are an error, as before),
    /// `Reject` for table workloads until the caller installs a lookup.
    pub fn for_bench(bench: &BenchManifest) -> crate::Result<Self> {
        match bench.kind {
            WorkloadKind::Synthetic => {
                Ok(PreciseProxy::Function(benchmarks::by_name(&bench.name)?))
            }
            WorkloadKind::Table => Ok(PreciseProxy::Reject),
        }
    }

    /// Held-out lookup proxy over a dataset (table workloads).
    pub fn lookup_from(bench: &BenchManifest, ds: &Dataset) -> Self {
        PreciseProxy::Lookup(Arc::new(NearestLookup::from_dataset(bench, ds)))
    }

    pub fn is_reject(&self) -> bool {
        matches!(self, PreciseProxy::Reject)
    }

    /// Produce the precise answer for one raw input row, in NORMALISED
    /// output space.  `raw_scratch` is a caller-owned `d_out`-sized f64
    /// buffer (kept out of the hot path's allocations).
    pub fn serve_norm_into(
        &self,
        bench: &BenchManifest,
        x_raw: &[f32],
        raw_scratch: &mut [f64],
        out: &mut [f32],
    ) -> crate::Result<()> {
        match self {
            PreciseProxy::Function(f) => {
                f.eval(x_raw, raw_scratch);
                bench.normalize_y_into(raw_scratch, out);
                Ok(())
            }
            PreciseProxy::Lookup(l) => {
                l.lookup_into(x_raw, out);
                Ok(())
            }
            PreciseProxy::Reject => anyhow::bail!(
                "workload {:?} has no runtime oracle: a request was routed to \
                 the precise path but the precise fallback is configured to \
                 reject (serve with the held-out lookup proxy, or tighten \
                 training so the classifier stops rejecting)",
                bench.name
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::WorkloadKind;

    fn bench(kind: WorkloadKind) -> BenchManifest {
        BenchManifest {
            name: "t".into(),
            domain: "test".into(),
            kind,
            source_digest: String::new(),
            n_in: 2,
            n_out: 1,
            approx_topology: vec![2, 4, 1],
            clf2_topology: vec![2, 4, 2],
            clfn_topology: vec![2, 4, 3],
            x_lo: vec![0.0, 0.0],
            x_hi: vec![1.0, 10.0],
            y_lo: vec![0.0],
            y_hi: vec![1.0],
            error_bound: 0.05,
            train_n: 0,
            test_n: 0,
            methods: vec![],
            mcca_pairs: 0,
        }
    }

    fn store() -> Dataset {
        Dataset {
            n: 3,
            d_in: 2,
            d_out: 1,
            x_raw: vec![0.0, 0.0, 0.5, 5.0, 1.0, 10.0],
            y_norm: vec![0.1, 0.5, 0.9],
        }
    }

    #[test]
    fn lookup_exact_and_nearest() {
        let b = bench(WorkloadKind::Table);
        let l = NearestLookup::from_dataset(&b, &store());
        assert_eq!(l.len(), 3);
        let mut out = [0.0f32; 1];
        // Exact record hit.
        l.lookup_into(&[0.5, 5.0], &mut out);
        assert_eq!(out, [0.5]);
        // Nearest record under scaled distance: (0.9, 9.0) is closest to
        // the third row.
        l.lookup_into(&[0.9, 9.0], &mut out);
        assert_eq!(out, [0.9]);
        // Scaling matters: raw distance would make the second dimension
        // dominate; with 1/(hi-lo) scaling, (0.05, 4.9) sits next to the
        // middle record, not the first.
        l.lookup_into(&[0.45, 4.0], &mut out);
        assert_eq!(out, [0.5]);
    }

    #[test]
    fn for_bench_kind_dispatch() {
        let syn = bench(WorkloadKind::Synthetic);
        // Unknown synthetic name stays a hard error (old behaviour).
        assert!(PreciseProxy::for_bench(&syn).is_err());
        let mut real = syn.clone();
        real.name = "sobel".into();
        real.n_in = 9;
        real.x_lo = vec![0.0; 9];
        real.x_hi = vec![1.0; 9];
        assert!(matches!(
            PreciseProxy::for_bench(&real).unwrap(),
            PreciseProxy::Function(_)
        ));
        let tab = bench(WorkloadKind::Table);
        assert!(PreciseProxy::for_bench(&tab).unwrap().is_reject());
    }

    #[test]
    fn reject_is_a_hard_error_with_workload_name() {
        let b = bench(WorkloadKind::Table);
        let p = PreciseProxy::Reject;
        let mut raw = [0.0f64; 1];
        let mut out = [0.0f32; 1];
        let e = p
            .serve_norm_into(&b, &[0.0, 0.0], &mut raw, &mut out)
            .unwrap_err()
            .to_string();
        assert!(e.contains("no runtime oracle"), "{e}");
        assert!(e.contains("\"t\""), "error must name the workload: {e}");
    }

    #[test]
    fn lookup_proxy_serves_held_out_labels() {
        let b = bench(WorkloadKind::Table);
        let ds = store();
        let p = PreciseProxy::lookup_from(&b, &ds);
        let mut raw = [0.0f64; 1];
        let mut out = [0.0f32; 1];
        for i in 0..ds.n {
            p.serve_norm_into(&b, ds.x_row(i), &mut raw, &mut out).unwrap();
            assert_eq!(out[0], ds.y_norm[i], "held-out replay must be exact");
        }
    }
}
