//! The precise-path proxy — what the dispatcher's CPU fallback and the
//! QoS shadow verifier call when a "precise" answer is needed.
//!
//! For the paper's registered benchmarks the proxy IS the precise
//! function.  For table workloads no closed-form oracle exists at
//! runtime, so the proxy is either a nearest-record lookup over the
//! held-out store ([`NearestLookup`]) — exact on held-out replay (eval,
//! QoS shadow verification), nearest-neighbour interpolation on unseen
//! inputs — or a configurable reject-with-error for serving setups that
//! would rather fail a request than serve an interpolated answer.
//!
//! The lookup is a bucketed k-d tree, built once at load over the scaled
//! input space and queried allocation-free: best-first descent into the
//! query's side of each splitting plane, pruning the far side only when
//! its plane distance PROVABLY exceeds the best candidate.  Results are
//! bitwise identical to the exhaustive scan ([`NearestLookup::nearest_scan`],
//! kept as the test oracle): identical per-record metric (ascending-
//! dimension f64 accumulation of `((q - r) * inv_scale)²`) and
//! deterministic tie-breaking (equal distances resolve to the LOWEST
//! record index, so equality at a splitting plane never prunes).  Visit
//! counters feed the NPU cost model the MEASURED sublinear cost of the
//! precise path ([`super::precise_cost_cycles_measured`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::benchmarks::{self, BenchFn};
use crate::formats::{BenchManifest, Dataset, WorkloadKind};

/// Records per leaf bucket.  Small enough that a leaf scan stays in
/// registers/L1, big enough that the tree (and its pointer chasing) is
/// ~n/8 nodes rather than n.
const LEAF_SIZE: usize = 8;

/// k-d tree node, arena-allocated (`Vec<KdNode>`, `u32` child indices).
#[derive(Clone, Copy, Debug)]
enum KdNode {
    /// Records `perm[start..end]` — scanned exhaustively on visit.
    Leaf { start: u32, end: u32 },
    /// Splitting plane: `left` holds records with coordinate ≤ `split`
    /// along `dim` (ties split deterministically by record index), `right`
    /// those with coordinate ≥ `split`.
    Split { dim: u32, split: f32, left: u32, right: u32 },
}

/// Nearest-record store: raw input rows with their normalised labels.
/// Distance is squared L2 in NORMALISED input space (per-dimension
/// `1/(hi-lo)` scaling), so wide raw columns don't dominate the metric.
pub struct NearestLookup {
    n: usize,
    d_in: usize,
    d_out: usize,
    x_raw: Vec<f32>,
    y_norm: Vec<f32>,
    inv_scale: Vec<f32>,
    /// k-d tree over the scaled inputs: node arena, leaf permutation and
    /// root index.  Built once in [`Self::from_dataset`].
    nodes: Vec<KdNode>,
    perm: Vec<u32>,
    root: u32,
    /// Query instrumentation (relaxed atomics — `&self` queries from many
    /// server workers).  `visited` counts records whose distance was
    /// (partially) evaluated; the ratio is the measured per-query cost the
    /// NPU model charges.
    queries: AtomicU64,
    visited: AtomicU64,
}

/// Build one subtree over `perm[lo..hi]`, returning its arena index.
/// Deterministic: split dimension is the widest SCALED spread, the median
/// is selected under a total order on `(coordinate, record index)`, and
/// zero-spread ranges (all records identical under the metric) collapse to
/// a single leaf regardless of size.
fn build_node(
    nodes: &mut Vec<KdNode>,
    perm: &mut [u32],
    lo: usize,
    hi: usize,
    x: &[f32],
    d_in: usize,
    inv_scale: &[f32],
) -> u32 {
    let len = hi - lo;
    let mut split_dim = None;
    if len > LEAF_SIZE {
        let mut best_spread = 0.0f32;
        for d in 0..d_in {
            let (mut mn, mut mx) = (f32::INFINITY, f32::NEG_INFINITY);
            for &p in &perm[lo..hi] {
                let v = x[p as usize * d_in + d];
                mn = mn.min(v);
                mx = mx.max(v);
            }
            let spread = (mx - mn) * inv_scale[d];
            if spread > best_spread {
                best_spread = spread;
                split_dim = Some(d);
            }
        }
    }
    let Some(dim) = split_dim else {
        nodes.push(KdNode::Leaf { start: lo as u32, end: hi as u32 });
        return (nodes.len() - 1) as u32;
    };
    let mid = len / 2;
    perm[lo..hi].select_nth_unstable_by(mid, |&a, &b| {
        let va = x[a as usize * d_in + dim];
        let vb = x[b as usize * d_in + dim];
        va.total_cmp(&vb).then(a.cmp(&b))
    });
    let split = x[perm[lo + mid] as usize * d_in + dim];
    let left = build_node(nodes, perm, lo, lo + mid, x, d_in, inv_scale);
    let right = build_node(nodes, perm, lo + mid, hi, x, d_in, inv_scale);
    nodes.push(KdNode::Split { dim: dim as u32, split, left, right });
    (nodes.len() - 1) as u32
}

impl NearestLookup {
    pub fn from_dataset(bench: &BenchManifest, ds: &Dataset) -> Self {
        assert_eq!(ds.d_in, bench.n_in, "lookup store/bench input dims disagree");
        assert_eq!(ds.d_out, bench.n_out);
        assert!(ds.n > 0, "lookup store must be non-empty");
        let inv_scale: Vec<f32> = (0..bench.n_in)
            .map(|d| {
                let r = bench.x_hi[d] - bench.x_lo[d];
                if r > 0.0 { 1.0 / r } else { 0.0 }
            })
            .collect();
        let mut perm: Vec<u32> = (0..ds.n as u32).collect();
        let mut nodes: Vec<KdNode> = Vec::with_capacity(2 * ds.n.div_ceil(LEAF_SIZE));
        let root =
            build_node(&mut nodes, &mut perm, 0, ds.n, &ds.x_raw, ds.d_in, &inv_scale);
        NearestLookup {
            n: ds.n,
            d_in: ds.d_in,
            d_out: ds.d_out,
            x_raw: ds.x_raw.clone(),
            y_norm: ds.y_norm.clone(),
            inv_scale,
            nodes,
            perm,
            root,
            queries: AtomicU64::new(0),
            visited: AtomicU64::new(0),
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// `(queries answered, records visited)` so far — the cost-model input.
    pub fn query_stats(&self) -> (u64, u64) {
        // audit:allow(atomics) — monotone stats counters; readers tolerate lag.
        (self.queries.load(Ordering::Relaxed), self.visited.load(Ordering::Relaxed))
    }

    /// Mean records visited per query, if any query has run.  This is the
    /// measured sublinear cost [`super::precise_cost_cycles_measured`]
    /// charges instead of the full-scan estimate.
    pub fn visits_per_query(&self) -> Option<f64> {
        // audit:allow(atomics) — cost-model average; a stale read only lags it.
        let q = self.queries.load(Ordering::Relaxed);
        if q == 0 {
            return None;
        }
        // audit:allow(atomics) — pairs with the `queries` read above; approximate by design.
        Some(self.visited.load(Ordering::Relaxed) as f64 / q as f64)
    }

    /// Accumulate record `i`'s scaled squared distance to `q`, updating
    /// `best = (distance, index)` under the tie rule "equal distance keeps
    /// the LOWER index".
    ///
    /// The bound check is hoisted: a record that loses index ties (`i >
    /// best_i`) is dead the moment its partial sum REACHES `best_d` — and
    /// when `best_d` is already 0 (exact duplicate found) it is rejected
    /// before any per-dimension work, so a degenerate all-equal store
    /// costs O(1) per record instead of O(d).  A record that would win the
    /// tie is only dead strictly ABOVE `best_d`.
    #[inline]
    fn consider(&self, i: usize, q: &[f32], best: &mut (f64, usize)) {
        let (best_d, best_i) = *best;
        let loses_ties = i > best_i;
        if loses_ties && best_d == 0.0 {
            return;
        }
        let row = &self.x_raw[i * self.d_in..(i + 1) * self.d_in];
        let mut dist = 0.0f64;
        for d in 0..self.d_in {
            let diff = ((q[d] - row[d]) * self.inv_scale[d]) as f64;
            dist += diff * diff;
            if dist > best_d || (loses_ties && dist >= best_d) {
                return;
            }
        }
        // dist < best_d, or dist == best_d with i < best_i: i wins.
        *best = (dist, i);
    }

    /// Best-first descent; `visited` counts `consider` calls.
    fn search(&self, node: u32, q: &[f32], best: &mut (f64, usize), visited: &mut u64) {
        match self.nodes[node as usize] {
            KdNode::Leaf { start, end } => {
                for &p in &self.perm[start as usize..end as usize] {
                    *visited += 1;
                    self.consider(p as usize, q, best);
                }
            }
            KdNode::Split { dim, split, left, right } => {
                let d = dim as usize;
                let (near, far) =
                    if q[d] < split { (left, right) } else { (right, left) };
                self.search(near, q, best, visited);
                // Plane distance, in the exact arithmetic of the per-record
                // metric (f32 product cast to f64, squared in f64) so the
                // lower bound is sound for the scan's own rounding.  Prune
                // only on STRICTLY greater: an equal-distance record beyond
                // the plane could still win the index tie.
                let diff = ((q[d] - split) * self.inv_scale[d]) as f64;
                if diff * diff <= best.0 {
                    self.search(far, q, best, visited);
                }
            }
        }
    }

    /// Index of the nearest stored record (lowest index on ties) via the
    /// k-d tree.  Allocation-free; updates the visit counters.
    pub fn nearest(&self, x_raw: &[f32]) -> usize {
        debug_assert_eq!(x_raw.len(), self.d_in);
        let mut best = (f64::INFINITY, usize::MAX);
        let mut visited = 0u64;
        self.search(self.root, x_raw, &mut best, &mut visited);
        // audit:allow(atomics) — monotone visit counters; no ordering with data.
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.visited.fetch_add(visited, Ordering::Relaxed); // audit:allow(atomics) — same counter pair.
        best.1
    }

    /// Exhaustive linear scan under the identical metric and tie rule —
    /// the reference [`Self::nearest`] is pinned against (equivalence
    /// property tests and the `mcma train` seeded self-check).  Does not
    /// touch the visit counters.
    pub fn nearest_scan(&self, x_raw: &[f32]) -> usize {
        debug_assert_eq!(x_raw.len(), self.d_in);
        let mut best = (f64::INFINITY, usize::MAX);
        for i in 0..self.n {
            self.consider(i, x_raw, &mut best);
        }
        best.1
    }

    /// Copy the label of the nearest stored record into `out`
    /// (normalised space).  k-d tree query — allocation-free, measured
    /// sublinear visits; the cost model charges the precise path the
    /// observed ratio ([`super::precise_cost_cycles_measured`]).
    pub fn lookup_into(&self, x_raw: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.d_out);
        let best_i = self.nearest(x_raw);
        out.copy_from_slice(&self.y_norm[best_i * self.d_out..(best_i + 1) * self.d_out]);
    }
}

/// The precise path behind the dispatcher and the QoS shadow verifier.
pub enum PreciseProxy {
    /// A registered precise benchmark function (synthetic workloads).
    Function(Box<dyn BenchFn>),
    /// Held-out nearest-record lookup (table workloads: eval and the
    /// default serve fallback).  `Arc` so a multi-worker server shares
    /// ONE store instead of one copy per dispatch thread.
    Lookup(Arc<NearestLookup>),
    /// No oracle configured: any precise-path sample is a hard error
    /// (table workloads served with `--precise-fallback reject`).
    Reject,
}

impl PreciseProxy {
    /// The default proxy for a manifest entry: the registered function
    /// for synthetic workloads (unknown names are an error, as before),
    /// `Reject` for table workloads until the caller installs a lookup.
    pub fn for_bench(bench: &BenchManifest) -> crate::Result<Self> {
        match bench.kind {
            WorkloadKind::Synthetic => {
                Ok(PreciseProxy::Function(benchmarks::by_name(&bench.name)?))
            }
            WorkloadKind::Table => Ok(PreciseProxy::Reject),
        }
    }

    /// Held-out lookup proxy over a dataset (table workloads).
    pub fn lookup_from(bench: &BenchManifest, ds: &Dataset) -> Self {
        PreciseProxy::Lookup(Arc::new(NearestLookup::from_dataset(bench, ds)))
    }

    pub fn is_reject(&self) -> bool {
        matches!(self, PreciseProxy::Reject)
    }

    /// The lookup store behind this proxy, if that's what it is — the
    /// dispatcher reads its visit counters to report measured precise-path
    /// cost ([`super::precise_cost_cycles_measured`]).
    pub fn lookup(&self) -> Option<&NearestLookup> {
        match self {
            PreciseProxy::Lookup(l) => Some(l),
            _ => None,
        }
    }

    /// Produce the precise answer for one raw input row, in NORMALISED
    /// output space.  `raw_scratch` is a caller-owned `d_out`-sized f64
    /// buffer (kept out of the hot path's allocations).
    pub fn serve_norm_into(
        &self,
        bench: &BenchManifest,
        x_raw: &[f32],
        raw_scratch: &mut [f64],
        out: &mut [f32],
    ) -> crate::Result<()> {
        match self {
            PreciseProxy::Function(f) => {
                f.eval(x_raw, raw_scratch);
                bench.normalize_y_into(raw_scratch, out);
                Ok(())
            }
            PreciseProxy::Lookup(l) => {
                l.lookup_into(x_raw, out);
                Ok(())
            }
            PreciseProxy::Reject => anyhow::bail!(
                "workload {:?} has no runtime oracle: a request was routed to \
                 the precise path but the precise fallback is configured to \
                 reject (serve with the held-out lookup proxy, or tighten \
                 training so the classifier stops rejecting)",
                bench.name
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::WorkloadKind;

    fn bench(kind: WorkloadKind) -> BenchManifest {
        BenchManifest {
            name: "t".into(),
            domain: "test".into(),
            kind,
            source_digest: String::new(),
            n_in: 2,
            n_out: 1,
            approx_topology: vec![2, 4, 1],
            clf2_topology: vec![2, 4, 2],
            clfn_topology: vec![2, 4, 3],
            x_lo: vec![0.0, 0.0],
            x_hi: vec![1.0, 10.0],
            y_lo: vec![0.0],
            y_hi: vec![1.0],
            error_bound: 0.05,
            train_n: 0,
            test_n: 0,
            methods: vec![],
            mcca_pairs: 0,
        }
    }

    fn store() -> Dataset {
        Dataset {
            n: 3,
            d_in: 2,
            d_out: 1,
            x_raw: vec![0.0, 0.0, 0.5, 5.0, 1.0, 10.0],
            y_norm: vec![0.1, 0.5, 0.9],
        }
    }

    #[test]
    fn lookup_exact_and_nearest() {
        let b = bench(WorkloadKind::Table);
        let l = NearestLookup::from_dataset(&b, &store());
        assert_eq!(l.len(), 3);
        let mut out = [0.0f32; 1];
        // Exact record hit.
        l.lookup_into(&[0.5, 5.0], &mut out);
        assert_eq!(out, [0.5]);
        // Nearest record under scaled distance: (0.9, 9.0) is closest to
        // the third row.
        l.lookup_into(&[0.9, 9.0], &mut out);
        assert_eq!(out, [0.9]);
        // Scaling matters: raw distance would make the second dimension
        // dominate; with 1/(hi-lo) scaling, (0.05, 4.9) sits next to the
        // middle record, not the first.
        l.lookup_into(&[0.45, 4.0], &mut out);
        assert_eq!(out, [0.5]);
    }

    #[test]
    fn for_bench_kind_dispatch() {
        let syn = bench(WorkloadKind::Synthetic);
        // Unknown synthetic name stays a hard error (old behaviour).
        assert!(PreciseProxy::for_bench(&syn).is_err());
        let mut real = syn.clone();
        real.name = "sobel".into();
        real.n_in = 9;
        real.x_lo = vec![0.0; 9];
        real.x_hi = vec![1.0; 9];
        assert!(matches!(
            PreciseProxy::for_bench(&real).unwrap(),
            PreciseProxy::Function(_)
        ));
        let tab = bench(WorkloadKind::Table);
        assert!(PreciseProxy::for_bench(&tab).unwrap().is_reject());
    }

    #[test]
    fn reject_is_a_hard_error_with_workload_name() {
        let b = bench(WorkloadKind::Table);
        let p = PreciseProxy::Reject;
        let mut raw = [0.0f64; 1];
        let mut out = [0.0f32; 1];
        let e = p
            .serve_norm_into(&b, &[0.0, 0.0], &mut raw, &mut out)
            .unwrap_err()
            .to_string();
        assert!(e.contains("no runtime oracle"), "{e}");
        assert!(e.contains("\"t\""), "error must name the workload: {e}");
    }

    #[test]
    fn lookup_proxy_serves_held_out_labels() {
        let b = bench(WorkloadKind::Table);
        let ds = store();
        let p = PreciseProxy::lookup_from(&b, &ds);
        let mut raw = [0.0f64; 1];
        let mut out = [0.0f32; 1];
        for i in 0..ds.n {
            p.serve_norm_into(&b, ds.x_row(i), &mut raw, &mut out).unwrap();
            assert_eq!(out[0], ds.y_norm[i], "held-out replay must be exact");
        }
    }

    use crate::util::rng::Rng;

    /// Manifest with `d` input dims over `[0, 1]` (dim 1, when present,
    /// deliberately degenerate: `hi == lo` ⇒ `inv_scale == 0`, so that
    /// axis is invisible to the metric).
    fn bench_d(d: usize, degenerate_axis: bool) -> BenchManifest {
        let mut b = bench(WorkloadKind::Table);
        b.n_in = d;
        b.x_lo = vec![0.0; d];
        b.x_hi = vec![1.0; d];
        if degenerate_axis && d > 1 {
            b.x_hi[1] = 0.0;
        }
        b
    }

    fn random_store(r: &mut Rng, n: usize, d: usize, duplicates: bool) -> Dataset {
        let mut x_raw: Vec<f32> = (0..n * d).map(|_| r.uniform(0.0, 1.0) as f32).collect();
        if duplicates {
            // Force exact duplicate points (including of row 0) so ties are
            // real, not just close calls.
            for i in (0..n).step_by(3) {
                let src = if i % 2 == 0 { 0 } else { i / 2 };
                let row: Vec<f32> = x_raw[src * d..(src + 1) * d].to_vec();
                x_raw[i * d..(i + 1) * d].copy_from_slice(&row);
            }
        }
        let y_norm: Vec<f32> = (0..n).map(|i| i as f32 / n as f32).collect();
        Dataset { n, d_in: d, d_out: 1, x_raw, y_norm }
    }

    /// k-d tree vs exhaustive scan: bitwise-identical record INDEX (not
    /// just label) on random tables, duplicate-heavy tables, and tables
    /// with a metric-degenerate axis — across dimensionalities straddling
    /// the leaf size and store sizes from sub-leaf to multi-level.
    #[test]
    fn prop_kdtree_matches_linear_scan_exactly() {
        crate::util::prop::check(
            "kdtree-vs-scan",
            60,
            0x7D7E,
            |r: &mut Rng| {
                let d = 1 + r.below(6) as usize;
                let n = 1 + r.below(300) as usize;
                let duplicates = r.below(2) == 0;
                let degenerate = r.below(3) == 0;
                let q_n = 1 + r.below(40) as usize;
                let mut queries: Vec<f32> =
                    (0..q_n * d).map(|_| r.uniform(-0.2, 1.2) as f32).collect();
                let store = random_store(r, n, d, duplicates);
                // Half the queries replay exact store rows (distance-zero
                // ties are the adversarial case).
                for qi in 0..q_n / 2 {
                    let src = r.below(n as u64) as usize;
                    queries[qi * d..(qi + 1) * d]
                        .copy_from_slice(&store.x_raw[src * d..(src + 1) * d]);
                }
                (bench_d(d, degenerate), store, queries, d)
            },
            |(man, store, queries, d)| {
                let l = NearestLookup::from_dataset(man, store);
                for q in queries.chunks(*d) {
                    let tree = l.nearest(q);
                    let scan = l.nearest_scan(q);
                    if tree != scan {
                        return Err(format!("tree {tree} != scan {scan} for query {q:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Tie-breaking regression: the LOWEST record index wins, and the
    /// winner is stable across query order (no hidden state).  An
    /// all-equal store is also the degenerate case the hoisted early-out
    /// targets: every query must still resolve to record 0.
    #[test]
    fn tie_breaks_to_lowest_index_stably() {
        let b = bench_d(3, false);
        // Store of 40 identical points.
        let all_equal = Dataset {
            n: 40,
            d_in: 3,
            d_out: 1,
            x_raw: [0.25f32, 0.5, 0.75].repeat(40),
            y_norm: (0..40).map(|i| i as f32).collect(),
        };
        let l = NearestLookup::from_dataset(&b, &all_equal);
        let queries: [[f32; 3]; 3] =
            [[0.25, 0.5, 0.75], [0.9, 0.9, 0.9], [0.0, 0.0, 0.0]];
        // Forward, reversed, and interleaved query orders all agree.
        for order in [[0usize, 1, 2], [2, 1, 0], [1, 0, 2]] {
            for qi in order {
                assert_eq!(l.nearest(&queries[qi]), 0, "query {qi} lost the tie");
                assert_eq!(l.nearest_scan(&queries[qi]), 0);
            }
        }
        // Two equidistant distinct records: query midway between rows 2
        // and 5 (same point stored twice) must return 2.
        let mut two = random_store(&mut Rng::new(9), 8, 2, false);
        let dup: Vec<f32> = two.x_raw[2 * 2..3 * 2].to_vec();
        two.x_raw[5 * 2..6 * 2].copy_from_slice(&dup);
        let b2 = bench_d(2, false);
        let l2 = NearestLookup::from_dataset(&b2, &two);
        assert_eq!(l2.nearest(&dup), 2);
        assert_eq!(l2.nearest_scan(&dup), 2);
    }

    /// Visit counters: exact-duplicate queries on a spread-out store visit
    /// far fewer records than the store holds (the sublinearity the cost
    /// model charges), and the stats accumulate across queries.
    #[test]
    fn visit_counters_measure_sublinear_queries() {
        let mut r = Rng::new(0x715);
        let n = 2048;
        let store = random_store(&mut r, n, 2, false);
        let b = bench_d(2, false);
        let l = NearestLookup::from_dataset(&b, &store);
        assert_eq!(l.query_stats(), (0, 0));
        assert_eq!(l.visits_per_query(), None);
        let q = 256usize;
        for i in 0..q {
            l.nearest(&store.x_raw[i * 2..(i + 1) * 2]);
        }
        let (queries, visited) = l.query_stats();
        assert_eq!(queries, q as u64);
        let vpq = l.visits_per_query().unwrap();
        assert!((vpq - visited as f64 / q as f64).abs() < 1e-12);
        assert!(
            vpq < n as f64 / 4.0,
            "k-d tree visited {vpq} of {n} records per query — not sublinear"
        );
    }
}
