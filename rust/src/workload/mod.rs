//! Workload-source subsystem — "a workload" as a first-class object.
//!
//! The paper's MCMA architecture is workload-agnostic: any function with a
//! tolerable quality loss can be partitioned across multiple approximators.
//! Historically this reproduction could only open the eight registered
//! [`crate::benchmarks::BenchFn`]s; this module abstracts where training
//! data and ground truth come from, so `mcma train --data foo.csv` opens
//! an arbitrary CSV/TSV-defined workload through the exact same pipeline
//! (co-train → MCMW/MCQW/MCMD export → `ModelBank` → `Dispatcher` →
//! `Server`) as a paper benchmark.
//!
//! * [`WorkloadSource`] — the trait: dimensions, manifest derivation
//!   (normalisation bounds, topology heuristics, error bound) and the
//!   deterministic train/held-out split;
//! * [`SyntheticSource`] — wraps a registered precise benchmark function
//!   (the `train::data` synthesis moved behind it, stream-compatible);
//! * [`TableSource`] — a dependency-free CSV/TSV reader with schema
//!   inference, header handling and NaN/ragged-row diagnostics;
//! * [`PreciseProxy`] — the oracle-less serving story: for `Table`
//!   workloads no precise function exists at runtime, so the dispatcher's
//!   precise fallback routes through a held-out nearest-record lookup
//!   ([`NearestLookup`]) or a configurable reject-with-error, and the QoS
//!   shadow loop verifies against held-out labels instead of re-executing
//!   the precise function.

pub mod proxy;
pub mod synthetic;
pub mod table;

pub use proxy::{NearestLookup, PreciseProxy};
pub use synthetic::{derive_bench_manifest, sample_data, SyntheticSource};
pub use table::{TableData, TableSource};

use crate::formats::{BenchManifest, Dataset, WorkloadKind};

/// One sampled (or sliced) training/test set, kept in both raw and
/// normalised space: raw feeds the precise-CPU path and `test.bin` export,
/// normalised feeds the trainers.
#[derive(Clone, Debug)]
pub struct TrainData {
    pub n: usize,
    pub d_in: usize,
    pub d_out: usize,
    /// Row-major `(n, d_in)` raw inputs.
    pub x_raw: Vec<f32>,
    /// Row-major `(n, d_in)` normalised inputs.
    pub x_norm: Vec<f32>,
    /// Row-major `(n, d_out)` normalised precise outputs.
    pub y_norm: Vec<f32>,
}

impl TrainData {
    /// Convert to the on-disk dataset shape (`test.bin` export, eval
    /// drivers).
    pub fn to_dataset(&self) -> Dataset {
        Dataset {
            n: self.n,
            d_in: self.d_in,
            d_out: self.d_out,
            x_raw: self.x_raw.clone(),
            y_norm: self.y_norm.clone(),
        }
    }
}

/// Where a trainable workload's samples and ground truth come from.
///
/// Implementations must be deterministic in `seed`: the same source +
/// seed always yields bit-identical datasets, regardless of thread count
/// or machine.
pub trait WorkloadSource: Send + Sync {
    /// Workload name — the manifest key and artifact directory name.
    fn name(&self) -> &str;

    fn kind(&self) -> WorkloadKind;

    fn d_in(&self) -> usize;

    fn d_out(&self) -> usize;

    /// Content digest of the source (hex FNV-1a 64 of the data file for
    /// tables; empty for synthetic generators).
    fn digest(&self) -> String;

    /// Derive a manifest entry from the source itself: normalisation
    /// bounds, default topologies sized to the workload's width, and —
    /// when `error_bound` is `None` — an error bound derived from the
    /// data.
    fn derive_manifest(&self, k: usize, error_bound: Option<f64>, seed: u64) -> BenchManifest;

    /// Produce the training set (≤ `n_train` rows) and the held-out test
    /// set (≤ `n_test` rows).  For table sources the two are DISJOINT
    /// row subsets under a deterministic seeded split; for synthetic
    /// sources they are independent generator draws.
    fn datasets(
        &self,
        man: &BenchManifest,
        n_train: usize,
        n_test: usize,
        seed: u64,
    ) -> crate::Result<(TrainData, TrainData)>;
}

/// Estimated CPU cost (cycles) of one precise evaluation for the NPU
/// speedup/energy model, with no measured lookup cost available — the
/// conservative full-store bound.  See
/// [`precise_cost_cycles_measured`] for the measured-visits variant the
/// eval paths prefer.
pub fn precise_cost_cycles(bench: &BenchManifest) -> u64 {
    precise_cost_cycles_measured(bench, None)
}

/// CPU cost (cycles) of one precise evaluation for the NPU speedup/energy
/// model.  Registered synthetic benchmarks report their derived op counts.
/// Table workloads have no closed-form function, so the precise path is
/// modelled as its actual runtime implementation — the k-d tree
/// nearest-record lookup over the held-out store ([`NearestLookup`]):
/// when a run measured the tree's mean visited records per query
/// (`visits_per_query`, from [`NearestLookup::visits_per_query`]), that
/// sublinear count is charged (`n_in` lanes per visited record, 4-wide
/// SIMD, plus dispatch overhead); otherwise the conservative full-scan
/// bound over all `test_n` records applies.
pub fn precise_cost_cycles_measured(
    bench: &BenchManifest,
    visits_per_query: Option<f64>,
) -> u64 {
    if bench.kind == WorkloadKind::Synthetic {
        if let Ok(f) = crate::benchmarks::by_name(&bench.name) {
            return f.cpu_cycles();
        }
    }
    let full = bench.test_n.max(64) as u64;
    let records = match visits_per_query {
        // At least one record is always visited; never charge MORE than
        // the full-scan bound (the estimate's own floor included).
        Some(v) if v.is_finite() && v >= 1.0 => (v.ceil() as u64).min(full),
        _ => full,
    };
    let per_record = (bench.n_in as u64 + 2).div_ceil(4);
    500 + records * per_record
}

/// Shared bound-padding helper: widen a probed `[lo, hi]` range by 1% so
/// fresh draws stay inside, with a degenerate-dimension fallback that
/// keeps `(v - lo) / (hi - lo)` finite.
pub(crate) fn pad_bounds(lo: f32, hi: f32) -> (f32, f32) {
    let range = hi - lo;
    if range > 0.0 {
        (lo - 0.01 * range, hi + 0.01 * range)
    } else {
        (lo - 0.5, lo + 0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precise_cost_registered_vs_table() {
        let man = crate::workload::synthetic::SyntheticSource::by_name("sobel")
            .unwrap()
            .derive_manifest(2, None, 1);
        let registered = precise_cost_cycles(&man);
        assert_eq!(
            registered,
            crate::benchmarks::by_name("sobel").unwrap().cpu_cycles()
        );

        let mut table_man = man.clone();
        table_man.kind = WorkloadKind::Table;
        table_man.test_n = 1000;
        let scan = precise_cost_cycles(&table_man);
        // 9 inputs -> ceil(11/4) = 3 lanes-cycles per record.
        assert_eq!(scan, 500 + 1000 * 3);
        // More records => costlier precise path.
        table_man.test_n = 4000;
        assert!(precise_cost_cycles(&table_man) > scan);

        // Measured sublinear visits are charged instead of the full scan…
        table_man.test_n = 1000;
        assert_eq!(precise_cost_cycles_measured(&table_man, Some(12.2)), 500 + 13 * 3);
        // …clamped to [1 record, full-scan bound], garbage ignored.
        assert_eq!(precise_cost_cycles_measured(&table_man, Some(1e12)), scan);
        assert_eq!(precise_cost_cycles_measured(&table_man, Some(0.0)), scan);
        assert_eq!(precise_cost_cycles_measured(&table_man, Some(f64::NAN)), scan);
        assert_eq!(precise_cost_cycles_measured(&table_man, None), scan);
        // Synthetic benches ignore the measurement entirely.
        assert_eq!(precise_cost_cycles_measured(&man, Some(5.0)), registered);
    }

    #[test]
    fn pad_bounds_widens_and_handles_degenerate() {
        let (lo, hi) = pad_bounds(0.0, 1.0);
        assert!(lo < 0.0 && hi > 1.0);
        let (lo, hi) = pad_bounds(3.0, 3.0);
        assert!(hi - lo > 0.5, "degenerate dim must widen");
    }
}
