//! Synthetic workload source — the precise benchmark functions wrapped
//! behind [`WorkloadSource`].
//!
//! The Python pipeline sampled workloads at build time and froze them into
//! `test.bin`; this module replays the same recipe natively — draw raw
//! inputs from a benchmark's own generator ([`BenchFn::gen_into`]), run the
//! precise function, normalise both sides with the manifest bounds — so
//! `mcma train` can open a registered workload with no pre-exported
//! artifacts at all.  (This synthesis lived in `train::data` before the
//! workload subsystem existed; the streams are unchanged, so same-seed
//! datasets are bit-identical across the move.)

use crate::benchmarks::{self, BenchFn};
use crate::formats::{BenchManifest, WorkloadKind};
use crate::util::rng::Rng;

use super::{pad_bounds, TrainData, WorkloadSource};

/// A workload backed by a registered precise benchmark function.
pub struct SyntheticSource {
    benchfn: Box<dyn BenchFn>,
}

impl SyntheticSource {
    pub fn by_name(name: &str) -> crate::Result<Self> {
        Ok(SyntheticSource { benchfn: benchmarks::by_name(name)? })
    }

    pub fn benchfn(&self) -> &dyn BenchFn {
        self.benchfn.as_ref()
    }
}

impl WorkloadSource for SyntheticSource {
    fn name(&self) -> &str {
        self.benchfn.name()
    }

    fn kind(&self) -> WorkloadKind {
        WorkloadKind::Synthetic
    }

    fn d_in(&self) -> usize {
        self.benchfn.n_in()
    }

    fn d_out(&self) -> usize {
        self.benchfn.n_out()
    }

    fn digest(&self) -> String {
        String::new()
    }

    fn derive_manifest(&self, k: usize, error_bound: Option<f64>, seed: u64) -> BenchManifest {
        derive_bench_manifest(
            self.benchfn.as_ref(),
            k,
            error_bound.unwrap_or(0.05),
            2000,
            seed,
        )
    }

    fn datasets(
        &self,
        man: &BenchManifest,
        n_train: usize,
        n_test: usize,
        seed: u64,
    ) -> crate::Result<(TrainData, TrainData)> {
        // Seed salts match the pre-subsystem `train_bench` streams so
        // existing trained trees reproduce bit-for-bit.
        let train = sample_data(self.benchfn.as_ref(), man, n_train, seed ^ 0x7EA1);
        let test = sample_data(self.benchfn.as_ref(), man, n_test, seed ^ 0x7E57);
        Ok((train, test))
    }
}

/// Draw `n` samples from the benchmark's input distribution and label them
/// with the precise function, normalised via `man`'s bounds.
pub fn sample_data(benchfn: &dyn BenchFn, man: &BenchManifest, n: usize, seed: u64) -> TrainData {
    let (d_in, d_out) = (benchfn.n_in(), benchfn.n_out());
    assert_eq!(d_in, man.n_in, "manifest/benchfn input dims disagree");
    assert_eq!(d_out, man.n_out, "manifest/benchfn output dims disagree");
    let mut rng = Rng::new(seed);
    let mut x_raw = vec![0.0f32; n * d_in];
    let mut x_norm = vec![0.0f32; n * d_in];
    let mut y_norm = vec![0.0f32; n * d_out];
    let mut raw_out = vec![0.0f64; d_out];
    for i in 0..n {
        let xr = &mut x_raw[i * d_in..(i + 1) * d_in];
        benchfn.gen_into(&mut rng, xr);
        benchfn.eval(xr, &mut raw_out);
        man.normalize_x_into(xr, &mut x_norm[i * d_in..(i + 1) * d_in]);
        man.normalize_y_into(&raw_out, &mut y_norm[i * d_out..(i + 1) * d_out]);
    }
    TrainData { n, d_in, d_out, x_raw, x_norm, y_norm }
}

/// Derive a standalone manifest entry for a benchmark with no Python-built
/// artifacts: probe `n_probe` generator samples for normalisation bounds
/// (padded 1% so the test draw stays inside) and install default
/// topologies sized like the paper's Fig. 6 nets.
pub fn derive_bench_manifest(
    benchfn: &dyn BenchFn,
    k: usize,
    error_bound: f64,
    n_probe: usize,
    seed: u64,
) -> BenchManifest {
    let (d_in, d_out) = (benchfn.n_in(), benchfn.n_out());
    let mut rng = Rng::new(seed ^ 0xB0B5);
    let mut x = vec![0.0f32; d_in];
    let mut y = vec![0.0f64; d_out];
    let mut x_lo = vec![f32::INFINITY; d_in];
    let mut x_hi = vec![f32::NEG_INFINITY; d_in];
    let mut y_lo = vec![f64::INFINITY; d_out];
    let mut y_hi = vec![f64::NEG_INFINITY; d_out];
    for _ in 0..n_probe.max(64) {
        benchfn.gen_into(&mut rng, &mut x);
        benchfn.eval(&x, &mut y);
        for d in 0..d_in {
            x_lo[d] = x_lo[d].min(x[d]);
            x_hi[d] = x_hi[d].max(x[d]);
        }
        for d in 0..d_out {
            y_lo[d] = y_lo[d].min(y[d]);
            y_hi[d] = y_hi[d].max(y[d]);
        }
    }
    for d in 0..d_in {
        let (lo, hi) = pad_bounds(x_lo[d], x_hi[d]);
        x_lo[d] = lo;
        x_hi[d] = hi;
    }
    let (mut y_lo_f, mut y_hi_f) = (vec![0.0f32; d_out], vec![0.0f32; d_out]);
    for d in 0..d_out {
        let (lo, hi) = pad_bounds(y_lo[d] as f32, y_hi[d] as f32);
        y_lo_f[d] = lo;
        y_hi_f[d] = hi;
    }
    BenchManifest {
        name: benchfn.name().to_string(),
        domain: "rust-trained".to_string(),
        kind: WorkloadKind::Synthetic,
        source_digest: String::new(),
        n_in: d_in,
        n_out: d_out,
        approx_topology: vec![d_in, 8, 8, d_out],
        clf2_topology: vec![d_in, 8, 2],
        clfn_topology: vec![d_in, 16, k + 1],
        x_lo,
        x_hi,
        y_lo: y_lo_f,
        y_hi: y_hi_f,
        error_bound,
        train_n: 0,
        test_n: 0,
        methods: vec!["one_pass".into(), "mcma_competitive".into()],
        mcca_pairs: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_manifest_is_valid_and_samples_fit_bounds() {
        let benchfn = benchmarks::by_name("blackscholes").unwrap();
        let man = derive_bench_manifest(benchfn.as_ref(), 3, 0.05, 500, 1);
        assert_eq!(man.n_in, benchfn.n_in());
        assert_eq!(man.n_out, benchfn.n_out());
        assert_eq!(man.kind, WorkloadKind::Synthetic);
        assert_eq!(*man.clfn_topology.last().unwrap(), 4);
        for d in 0..man.n_in {
            assert!(man.x_hi[d] > man.x_lo[d], "dim {d} has empty range");
        }
        for d in 0..man.n_out {
            assert!(man.y_hi[d] > man.y_lo[d]);
        }

        let data = sample_data(benchfn.as_ref(), &man, 200, 2);
        assert_eq!(data.x_raw.len(), 200 * man.n_in);
        assert_eq!(data.y_norm.len(), 200 * man.n_out);
        // A same-seed re-probe bounds the normalised values near [0, 1];
        // fresh draws can poke slightly past the probe's envelope, so only
        // sanity-check the bulk.
        let inside = data
            .x_norm
            .iter()
            .filter(|&&v| (-0.5..=1.5).contains(&v))
            .count();
        assert!(inside as f64 >= 0.99 * data.x_norm.len() as f64);
        assert!(data.y_norm.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn sample_data_is_deterministic_per_seed() {
        let benchfn = benchmarks::by_name("sobel").unwrap();
        let man = derive_bench_manifest(benchfn.as_ref(), 2, 0.05, 200, 7);
        let a = sample_data(benchfn.as_ref(), &man, 50, 9);
        let b = sample_data(benchfn.as_ref(), &man, 50, 9);
        assert_eq!(a.x_raw, b.x_raw);
        assert_eq!(a.y_norm, b.y_norm);
    }

    #[test]
    fn to_dataset_roundtrip_shape() {
        let benchfn = benchmarks::by_name("kmeans").unwrap();
        let man = derive_bench_manifest(benchfn.as_ref(), 2, 0.05, 100, 3);
        let data = sample_data(benchfn.as_ref(), &man, 32, 4);
        let ds = data.to_dataset();
        assert_eq!((ds.n, ds.d_in, ds.d_out), (32, man.n_in, man.n_out));
        assert_eq!(ds.x_raw, data.x_raw);
    }

    /// The trait impl reuses the exact seed salts `train_bench` used
    /// before the workload subsystem existed, so same-seed datasets stay
    /// bit-identical across the refactor.
    #[test]
    fn source_datasets_match_legacy_streams() {
        let src = SyntheticSource::by_name("sobel").unwrap();
        let man = src.derive_manifest(2, None, 7);
        let (train, test) = src.datasets(&man, 100, 25, 7).unwrap();
        let legacy_train = sample_data(src.benchfn(), &man, 100, 7 ^ 0x7EA1);
        let legacy_test = sample_data(src.benchfn(), &man, 25, 7 ^ 0x7E57);
        assert_eq!(train.x_raw, legacy_train.x_raw);
        assert_eq!(train.y_norm, legacy_train.y_norm);
        assert_eq!(test.x_raw, legacy_test.x_raw);
        assert_eq!(src.digest(), "");
    }
}
