//! Routing: classifier outputs -> per-sample destinations.
//!
//! For MCMA the multiclass classifier's argmax picks the approximator (the
//! paper's "the approximator with the highest confidence consumes the input
//! sample"); class `n` is the reject class `nC` -> precise CPU.  For binary
//! methods class 0 = safe -> the single approximator.  MCCA cascades binary
//! stages; a sample rejected by stage k moves to stage k+1 (§III.B).

/// Destination of one sample.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Route {
    /// Served by approximator `k` on the NPU.
    Approx(usize),
    /// Rejected by the classifier(s); precise CPU computation.
    Cpu,
}

impl Route {
    pub fn is_approx(self) -> bool {
        matches!(self, Route::Approx(_))
    }
}

/// Routes for a batch plus the index groups the dispatcher executes.
#[derive(Clone, Debug, Default)]
pub struct RoutePlan {
    /// Per-sample destination, arrival order.
    pub routes: Vec<Route>,
    /// `groups[k]` = sample indices routed to approximator k.
    pub groups: Vec<Vec<usize>>,
    /// Sample indices routed to the CPU.
    pub cpu: Vec<usize>,
}

impl RoutePlan {
    pub fn invocation(&self) -> f64 {
        if self.routes.is_empty() {
            return 0.0;
        }
        let inv = self.routes.iter().filter(|r| r.is_approx()).count();
        inv as f64 / self.routes.len() as f64
    }

    /// Routes in the class-sorted order `execute_plan` actually runs the
    /// batch: approximator groups in index order, then the CPU group.
    /// This is the §III.D Case-3 trace under route-sorted execution — at
    /// most one weight refill per approximator per batch, versus up to one
    /// per consecutive class change in arrival order.
    pub fn execution_order_routes(&self) -> Vec<Route> {
        let mut out = Vec::with_capacity(self.routes.len());
        for (k, g) in self.groups.iter().enumerate() {
            out.extend(std::iter::repeat(Route::Approx(k)).take(g.len()));
        }
        out.extend(std::iter::repeat(Route::Cpu).take(self.cpu.len()));
        out
    }

    /// Clear for reuse with `n_approx` groups, keeping every allocation
    /// (the dispatcher's zero-allocation steady state relies on this).
    pub fn reset(&mut self, n_approx: usize) {
        self.routes.clear();
        self.cpu.clear();
        self.groups.truncate(n_approx);
        for g in &mut self.groups {
            g.clear();
        }
        if self.groups.len() < n_approx {
            self.groups.resize_with(n_approx, Vec::new);
        }
    }
}

/// Build a plan from per-sample class ids.
///
/// `n_approx` approximators exist; class `>= n_approx` (or, for binary
/// classifiers with `n_approx == 1`, class 1) means CPU.
pub fn plan_routes(classes: &[usize], n_approx: usize) -> RoutePlan {
    let mut plan = RoutePlan::default();
    plan_routes_into(classes, n_approx, &mut plan);
    plan
}

/// [`plan_routes`] into a reusable plan (reset, allocations kept).
pub fn plan_routes_into(classes: &[usize], n_approx: usize, plan: &mut RoutePlan) {
    plan.reset(n_approx);
    plan.routes.reserve(classes.len());
    for (i, &c) in classes.iter().enumerate() {
        if c < n_approx {
            plan.groups[c].push(i);
            plan.routes.push(Route::Approx(c));
        } else {
            plan.cpu.push(i);
            plan.routes.push(Route::Cpu);
        }
    }
}

/// Softmax probability of class `c` for one logit row (max-subtracted for
/// stability).  Shared by the confidence policy, the per-class QoS
/// margins, and the offline QoS replay.
pub fn softmax_prob(logits: &[f32], c: usize) -> f32 {
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let denom: f32 = logits.iter().map(|&v| (v - max).exp()).sum();
    (logits[c] - max).exp() / denom
}

/// Per-class margin overrides (the QoS controller's actuator): a sample
/// currently classed `c < n_approx` is demoted to the reject class
/// `n_approx` (precise CPU) when its softmax confidence for `c` falls
/// below `margins[c]`.  Margin 0 keeps the paper's pure-argmax routing
/// for that class; a margin no probability can reach
/// (`qos::MARGIN_PRECISE`) forces the whole class precise.  Demotion is
/// monotone: raising any margin can only shrink the invoked set.
pub fn apply_margins(
    logits: &[f32],
    n_classes: usize,
    n_approx: usize,
    margins: &[f32],
    classes: &mut [usize],
) {
    assert!(
        margins.len() >= n_approx,
        "need a margin per approximator class ({} < {n_approx})",
        margins.len()
    );
    for (i, c) in classes.iter_mut().enumerate() {
        if *c < n_approx {
            let m = margins[*c];
            if m > 0.0 {
                let row = &logits[i * n_classes..(i + 1) * n_classes];
                if softmax_prob(row, *c) < m {
                    *c = n_approx;
                }
            }
        }
    }
}

/// Merge a cascade stage's accept decisions into an existing plan:
/// `remaining` holds the sample indices this stage saw (in order), `accept`
/// their binary outcomes; accepted samples are routed to approximator
/// `stage`, the rest flow to the next stage.  Returns the still-unrouted
/// indices.
pub fn cascade_stage(
    plan: &mut RoutePlan,
    remaining: &[usize],
    accept: &[bool],
    stage: usize,
) -> Vec<usize> {
    assert_eq!(remaining.len(), accept.len());
    let mut next = Vec::new();
    for (&idx, &ok) in remaining.iter().zip(accept) {
        if ok {
            plan.routes[idx] = Route::Approx(stage);
            plan.groups[stage].push(idx);
        } else {
            next.push(idx);
        }
    }
    next
}

/// An all-CPU plan of length `n` with `stages` approximator slots
/// (cascade starting point).
pub fn all_cpu_plan(n: usize, stages: usize) -> RoutePlan {
    RoutePlan {
        routes: vec![Route::Cpu; n],
        groups: vec![Vec::new(); stages],
        cpu: (0..n).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn plan_partitions_samples() {
        let plan = plan_routes(&[0, 2, 3, 1, 0, 3], 3);
        assert_eq!(plan.groups[0], vec![0, 4]);
        assert_eq!(plan.groups[1], vec![3]);
        assert_eq!(plan.groups[2], vec![1]);
        assert_eq!(plan.cpu, vec![2, 5]);
        assert_eq!(plan.routes[1], Route::Approx(2));
        assert!((plan.invocation() - 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn binary_convention_class0_safe() {
        let plan = plan_routes(&[0, 1, 0], 1);
        assert_eq!(plan.routes, vec![Route::Approx(0), Route::Cpu, Route::Approx(0)]);
    }

    #[test]
    fn softmax_prob_basic() {
        let p0 = softmax_prob(&[2.0, 0.0], 0);
        let p1 = softmax_prob(&[2.0, 0.0], 1);
        assert!((p0 + p1 - 1.0).abs() < 1e-6);
        assert!(p0 > 0.85 && p0 < 0.9); // sigmoid(2) ~ 0.8808
    }

    #[test]
    fn softmax_prob_stable_for_large_logits() {
        let p = softmax_prob(&[1000.0, 999.0, -1000.0], 0);
        assert!(p.is_finite() && p > 0.7);
    }

    /// Per-class margins demote exactly the low-confidence accepts of the
    /// classes whose margin they fail, leave other classes alone, and a
    /// zero margin is a no-op.
    #[test]
    fn margins_demote_per_class() {
        // 3 classes (2 approximators + reject), 4 samples.
        // Sample confidences for their argmax class:
        //   s0 -> class 0 with ~0.88, s1 -> class 1 with ~0.88,
        //   s2 -> class 0 with ~0.58, s3 -> reject already.
        let logits = [
            2.0, 0.0, 0.0, //
            0.0, 2.0, 0.0, //
            0.5, 0.0, 0.2, //
            0.0, 0.0, 3.0, //
        ];
        let base = crate::nn::argmax_rows(&logits, 4, 3);
        assert_eq!(base, vec![0, 1, 0, 2]);

        let mut classes = base.clone();
        apply_margins(&logits, 3, 2, &[0.0, 0.0], &mut classes);
        assert_eq!(classes, base, "zero margins change nothing");

        // Class 0 requires 0.7 confidence: s2 (0.58) demotes, s0 stays.
        let mut classes = base.clone();
        apply_margins(&logits, 3, 2, &[0.7, 0.0], &mut classes);
        assert_eq!(classes, vec![0, 1, 2, 2]);

        // An unreachable margin forces class 1 fully precise.
        let mut classes = base.clone();
        apply_margins(&logits, 3, 2, &[0.0, 2.0], &mut classes);
        assert_eq!(classes, vec![0, 2, 0, 2]);
    }

    /// Property: margin demotion is monotone — pointwise-higher margins
    /// never invoke a sample the lower margins rejected.
    #[test]
    fn prop_margins_monotone() {
        prop::check(
            "margins-monotone",
            200,
            0x9A61,
            |r: &mut Rng| {
                let n = 1 + r.below(60) as usize;
                let n_approx = 1 + r.below(3) as usize;
                let n_classes = n_approx + 1;
                let logits: Vec<f32> =
                    (0..n * n_classes).map(|_| r.uniform(-3.0, 3.0) as f32).collect();
                let lo: Vec<f32> =
                    (0..n_approx).map(|_| r.uniform(0.0, 0.9) as f32).collect();
                let hi: Vec<f32> =
                    lo.iter().map(|&m| m + r.uniform(0.0, 0.5) as f32).collect();
                (logits, n_approx, lo, hi)
            },
            |(logits, n_approx, lo, hi)| {
                let n_classes = n_approx + 1;
                let n = logits.len() / n_classes;
                let base = crate::nn::argmax_rows(logits, n, n_classes);
                let mut c_lo = base.clone();
                let mut c_hi = base.clone();
                apply_margins(logits, n_classes, *n_approx, lo, &mut c_lo);
                apply_margins(logits, n_classes, *n_approx, hi, &mut c_hi);
                for i in 0..n {
                    let inv_lo = c_lo[i] < *n_approx;
                    let inv_hi = c_hi[i] < *n_approx;
                    if inv_hi && !inv_lo {
                        return Err(format!(
                            "sample {i} invoked under tighter margins only"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// Property: the class-sorted execution trace is a permutation of the
    /// arrival trace (same route multiset) and is non-decreasing in class,
    /// so a §III.D Case-3 weight cache refills at most once per
    /// approximator per batch.
    #[test]
    fn prop_execution_order_is_sorted_permutation() {
        use crate::config::NpuConfig;
        use crate::coordinator::weight_cache::{BufferCase, WeightCache};
        prop::check(
            "execution-order-routes",
            200,
            0x50F7,
            |r: &mut Rng| {
                let n = r.below(300) as usize;
                let n_approx = 1 + r.below(4) as usize;
                let classes: Vec<usize> =
                    (0..n).map(|_| r.below(n_approx as u64 + 2) as usize).collect();
                (classes, n_approx)
            },
            |(classes, n_approx)| {
                let plan = plan_routes(classes, *n_approx);
                let sorted = plan.execution_order_routes();
                if sorted.len() != plan.routes.len() {
                    return Err("length changed".into());
                }
                // Same multiset: count per destination.
                let count = |rs: &[Route]| {
                    let mut c = vec![0usize; n_approx + 1];
                    for r in rs {
                        match r {
                            Route::Approx(k) => c[*k] += 1,
                            Route::Cpu => c[*n_approx] += 1,
                        }
                    }
                    c
                };
                if count(&sorted) != count(&plan.routes) {
                    return Err("not a permutation".into());
                }
                // Case-3 cache over the sorted trace: <= 1 refill per
                // approximator.
                let npu = NpuConfig {
                    weight_buffer_words: 200,
                    pes_per_tile: 1,
                    ..Default::default()
                };
                let mut wc = WeightCache::new(&npu, vec![160; *n_approx]);
                wc.force_case(BufferCase::OneResident);
                for r in &sorted {
                    if let Route::Approx(k) = r {
                        wc.access(*k);
                    }
                }
                if wc.switches > *n_approx as u64 {
                    return Err(format!(
                        "sorted trace paid {} switches for {n_approx} approximators",
                        wc.switches
                    ));
                }
                Ok(())
            },
        );
    }

    /// Property: every sample appears in exactly one group (routing is a
    /// partition) and group membership agrees with `routes`.
    #[test]
    fn prop_routing_is_partition() {
        prop::check(
            "routing-partition",
            200,
            0xC0FFEE,
            |r: &mut Rng| {
                let n = r.below(400) as usize;
                let n_approx = 1 + r.below(4) as usize;
                let classes: Vec<usize> =
                    (0..n).map(|_| r.below(n_approx as u64 + 2) as usize).collect();
                (classes, n_approx)
            },
            |(classes, n_approx)| {
                let plan = plan_routes(classes, *n_approx);
                let mut seen = vec![0usize; classes.len()];
                for g in &plan.groups {
                    for &i in g {
                        seen[i] += 1;
                    }
                }
                for &i in &plan.cpu {
                    seen[i] += 1;
                }
                if seen.iter().any(|&c| c != 1) {
                    return Err("not a partition".into());
                }
                for (k, g) in plan.groups.iter().enumerate() {
                    for &i in g {
                        if plan.routes[i] != Route::Approx(k) {
                            return Err(format!("group {k} disagrees with route[{i}]"));
                        }
                    }
                }
                for &i in &plan.cpu {
                    if plan.routes[i] != Route::Cpu {
                        return Err(format!("cpu group disagrees with route[{i}]"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Property: cascading preserves the partition invariant and never
    /// routes a sample twice.
    #[test]
    fn prop_cascade_partition() {
        prop::check(
            "cascade-partition",
            200,
            0xBEEF,
            |r: &mut Rng| {
                let n = r.below(200) as usize;
                let stages = 1 + r.below(3) as usize;
                let accepts: Vec<Vec<bool>> =
                    (0..stages).map(|_| (0..n).map(|_| r.bool(0.4)).collect()).collect();
                (n, accepts)
            },
            |(n, accepts)| {
                let stages = accepts.len();
                let mut plan = all_cpu_plan(*n, stages);
                plan.cpu.clear();
                let mut remaining: Vec<usize> = (0..*n).collect();
                for (s, acc) in accepts.iter().enumerate() {
                    let stage_acc: Vec<bool> =
                        remaining.iter().map(|&i| acc[i]).collect();
                    remaining = cascade_stage(&mut plan, &remaining, &stage_acc, s);
                }
                plan.cpu = remaining;
                let mut seen = vec![0usize; *n];
                for g in &plan.groups {
                    for &i in g {
                        seen[i] += 1;
                    }
                }
                for &i in &plan.cpu {
                    seen[i] += 1;
                }
                if seen.iter().any(|&c| c != 1) {
                    return Err("cascade not a partition".into());
                }
                // Earlier stages get priority: a sample accepted by stage 0
                // must be in group 0 regardless of later stages.
                for i in 0..*n {
                    if accepts[0][i] && plan.routes[i] != Route::Approx(0) {
                        return Err(format!("stage priority violated at {i}"));
                    }
                }
                Ok(())
            },
        );
    }
}
