//! Weight-switch model — paper §III.D "Weight switch among different
//! approximators", Cases 1-3.
//!
//! The NPU keeps approximator weights in per-PE buffers near the MACs.
//! With multiple approximators sharing one physical array, consecutive
//! samples routed to *different* approximators may force a refill from the
//! on-chip cache:
//!
//! * **Case 1** — the buffers hold ALL approximators' weights: switching is
//!   a register-select, zero extra cycles (the paper's "within a cycle").
//! * **Case 2** — one approximator doesn't even fit: weights stream layer
//!   by layer for every sample anyway; switching adds nothing.
//! * **Case 3** — one fits, all don't: a switch reloads the incoming
//!   approximator's weights from cache (`words / refill_bw` cycles).
//!
//! This module tracks residency and charges switch cycles; it is consumed
//! by the NPU simulator and surfaced in the ablation benches.

use crate::config::NpuConfig;

/// Which §III.D case a (buffer size, net sizes) combination lands in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BufferCase {
    AllResident,
    StreamAlways,
    OneResident,
}

/// Runtime weight-residency tracker for one NPU array.
#[derive(Clone, Debug)]
pub struct WeightCache {
    case: BufferCase,
    /// Approximator currently resident (Case 3 only).
    resident: Option<usize>,
    /// Per-approximator weight words (refill cost).
    words: Vec<usize>,
    refill_bw: u64,
    /// Counters.
    pub switches: u64,
    pub refill_cycles: u64,
    pub accesses: u64,
}

impl WeightCache {
    /// Classify the case from the per-approximator weight word counts and
    /// the per-PE buffer capacity (aggregated across the tile's PEs).
    pub fn new(npu: &NpuConfig, weight_words: Vec<usize>) -> Self {
        let capacity = npu.weight_buffer_words * npu.pes_per_tile;
        let total: usize = weight_words.iter().sum();
        let largest = weight_words.iter().copied().max().unwrap_or(0);
        let case = if total <= capacity {
            BufferCase::AllResident
        } else if largest > capacity {
            BufferCase::StreamAlways
        } else {
            BufferCase::OneResident
        };
        WeightCache {
            case,
            resident: None,
            words: weight_words,
            refill_bw: npu.cache_refill_words_per_cycle.max(1),
            switches: 0,
            refill_cycles: 0,
            accesses: 0,
        }
    }

    pub fn case(&self) -> BufferCase {
        self.case
    }

    /// Force a specific case (ablation benches).
    pub fn force_case(&mut self, case: BufferCase) {
        self.case = case;
        self.resident = None;
    }

    /// Record that approximator `k` serves the next sample; returns the
    /// extra cycles this access pays for weight movement.
    pub fn access(&mut self, k: usize) -> u64 {
        self.accesses += 1;
        match self.case {
            BufferCase::AllResident => 0,
            BufferCase::StreamAlways => {
                // Streaming cost is charged by the PE pipeline itself (the
                // weights pass through the buffer regardless of switches).
                0
            }
            BufferCase::OneResident => {
                if self.resident == Some(k) {
                    0
                } else {
                    self.resident = Some(k);
                    self.switches += 1;
                    let cyc = (self.words[k] as u64).div_ceil(self.refill_bw);
                    self.refill_cycles += cyc;
                    cyc
                }
            }
        }
    }

    /// Fraction of accesses that caused a refill.
    pub fn switch_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.switches as f64 / self.accesses as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn npu(buffer_words: usize) -> NpuConfig {
        NpuConfig { weight_buffer_words: buffer_words, pes_per_tile: 1, ..Default::default() }
    }

    #[test]
    fn case_classification() {
        // 3 approximators of 100 words each.
        assert_eq!(WeightCache::new(&npu(400), vec![100; 3]).case(), BufferCase::AllResident);
        assert_eq!(WeightCache::new(&npu(150), vec![100; 3]).case(), BufferCase::OneResident);
        assert_eq!(WeightCache::new(&npu(50), vec![100; 3]).case(), BufferCase::StreamAlways);
    }

    #[test]
    fn case1_switches_free() {
        let mut wc = WeightCache::new(&npu(1000), vec![100; 3]);
        assert_eq!(wc.access(0) + wc.access(1) + wc.access(2), 0);
        assert_eq!(wc.switches, 0);
    }

    #[test]
    fn case3_charges_on_change_only() {
        let mut wc = WeightCache::new(&npu(150), vec![128; 3]);
        let c0 = wc.access(0); // cold: refill
        let c1 = wc.access(0); // hit
        let c2 = wc.access(1); // switch
        assert!(c0 > 0);
        assert_eq!(c1, 0);
        assert_eq!(c2, 128u64.div_ceil(8));
        assert_eq!(wc.switches, 2);
        assert!((wc.switch_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    /// Property: refill cycles are exactly (#switches x per-switch cost)
    /// when all approximators are the same size, and switches never exceed
    /// accesses (state-machine sanity under random access streams).
    #[test]
    fn prop_case3_accounting() {
        prop::check(
            "weight-cache-accounting",
            200,
            0xCAFE,
            |r: &mut Rng| {
                let n_approx = 1 + r.below(4) as usize;
                let stream: Vec<usize> =
                    (0..r.below(500) as usize).map(|_| r.below(n_approx as u64) as usize).collect();
                (n_approx, stream)
            },
            |(n_approx, stream)| {
                let mut wc = WeightCache::new(&npu(200), vec![160; *n_approx]);
                wc.force_case(BufferCase::OneResident);
                let mut expected_switches = 0u64;
                let mut last = None;
                for &k in stream {
                    wc.access(k);
                    if last != Some(k) {
                        expected_switches += 1;
                        last = Some(k);
                    }
                }
                if wc.switches != expected_switches {
                    return Err(format!("switches {} != {expected_switches}", wc.switches));
                }
                let per = 160u64.div_ceil(8);
                if wc.refill_cycles != expected_switches * per {
                    return Err("refill cycles mismatch".into());
                }
                if wc.switches > wc.accesses {
                    return Err("more switches than accesses".into());
                }
                Ok(())
            },
        );
    }
}
