//! Layer-3 coordinator — the paper's system contribution at run time.
//!
//! The MCMA execution model (paper §III.C-D, Fig. 4-5):
//!
//! ```text
//! requests ─► Batcher ─► classifier (PJRT, batched) ─► argmax class
//!                ├─ class k < n ─► per-approximator queue ─► WeightCache.switch(k)
//!                │                     └► approximator k (PJRT) ─► respond
//!                └─ class nC   ─► precise CPU path (benchmarks::*) ─► respond
//! ```
//!
//! `Dispatcher` is the synchronous engine (offline eval + the server's
//! worker); `server` wraps it in a threaded pipeline with dynamic batching;
//! `WeightCache` models the NPU weight-buffer residency cases of §III.D;
//! `metrics` accumulates the quantities every figure is built from.

pub mod batcher;
pub mod dispatcher;
pub mod metrics;
pub mod router;
pub mod server;
pub mod weight_cache;

pub use batcher::{Batch, Batcher, BatcherStats, IDLE_WAIT_DIV, MR};
pub use dispatcher::{Dispatcher, EvalOutput, RouterPolicy, Scratch};
pub use metrics::{ClassCounters, LatencyStats, PerRouteReport, RouteClassStats, RunMetrics};
pub use router::{plan_routes, Route, RoutePlan};
pub use server::{Response, Server, ServerConfig, ServerReport, Submitter, TableFallback};
pub use weight_cache::{BufferCase, WeightCache};
