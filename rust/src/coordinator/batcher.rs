//! Dynamic batcher: accumulate requests until the batch fills or the oldest
//! request exceeds its age budget (size-or-timeout policy, the same shape
//! vLLM-style servers use).  The offline eval path slices datasets directly;
//! this is the online server's ingress stage.
//!
//! ## Adaptive GEMM-shaped micro-batching (§Perf net)
//!
//! Two refinements make the batcher feed the packed kernel at its efficient
//! panel sizes under load while keeping idle traffic low-latency:
//!
//! 1. **MR alignment** — whenever a size- or age-triggered flush would take
//!    more than [`MR`] rows, the batch is rounded DOWN to a multiple of
//!    `MR` (the register-block height of `nn::gemm`), leaving the youngest
//!    remainder queued.  Full panels skip the kernel's partial-tile tail,
//!    and the remainder's own age budget still bounds its latency.  Fewer
//!    than `MR` pending rows flush as-is — the low-latency single path.
//! 2. **Load-adaptive age budget** — the effective wait is the configured
//!    `max_wait_us` only while the batcher is actually coalescing (EWMA of
//!    recent flush sizes ≥ `MR`); in the idle regime the budget drops to
//!    `max_wait_us / `[`IDLE_WAIT_DIV`], so a lone request is not held the
//!    full coalescing window waiting for peers that never come.
//!
//! Both decisions are pure functions of the push/poll call sequence (the
//! EWMA is integer arithmetic over flushed sizes; no wall-clock enters the
//! *formation* logic, only the flush *trigger*), so tests can pin exactly
//! which requests land in which batch for a given arrival order.

// audit:deterministic — batch formation takes `now` from the caller so
// tests replay identical timelines; only latency metadata touches clocks.
use std::time::{Duration, Instant};

use crate::config::BatchPolicy;

/// Register-block height of the packed GEMM kernel (`nn::gemm`): batches
/// are rounded down to multiples of this under load so every tile row of
/// the activation panel is full.
pub const MR: usize = 4;

/// Idle-regime divisor for the age budget: when recent flushes average
/// fewer than [`MR`] rows, requests wait at most `max_wait_us /
/// IDLE_WAIT_DIV` before dispatch instead of the full coalescing window.
pub const IDLE_WAIT_DIV: u64 = 16;

/// One queued request: opaque id + raw input row.
#[derive(Clone, Debug)]
pub struct Pending {
    pub id: u64,
    pub x_raw: Vec<f32>,
    /// When the caller submitted the request (carried through so the
    /// dispatch side can split queue-wait from batch-formation time).
    pub submitted: Instant,
    pub enqueued: Instant,
}

/// A flushed batch.
#[derive(Clone, Debug)]
pub struct Batch {
    pub ids: Vec<u64>,
    /// Row-major `(n, d_in)` raw inputs.
    pub x_raw: Vec<f32>,
    pub n: usize,
    /// Per-row submit stamps (same order as `ids`).
    pub submitted: Vec<Instant>,
    pub enqueued: Vec<Instant>,
}

/// Counters the batcher thread hands back at shutdown: flush-trigger
/// split plus the dispatched batch-size histogram (`size_hist[n]` = how
/// many batches of exactly `n` rows were dispatched) — the observable
/// that micro-batch coalescing is actually forming GEMM-shaped batches.
#[derive(Clone, Debug, Default)]
pub struct BatcherStats {
    pub flushes_full: u64,
    pub flushes_timeout: u64,
    /// Indexed by batch size (0 unused); length `max_batch + 1`.
    pub size_hist: Vec<u64>,
}

impl BatcherStats {
    /// Batches dispatched with more than one row (coalescing evidence).
    pub fn multi_row_batches(&self) -> u64 {
        self.size_hist.iter().skip(2).sum()
    }
}

/// Size-or-age dynamic batcher with MR-aligned coalescing.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    d_in: usize,
    queue: Vec<Pending>,
    pub flushes_full: u64,
    pub flushes_timeout: u64,
    /// Dispatched batch-size histogram (`size_hist[n]` = batches of n rows).
    size_hist: Vec<u64>,
    /// EWMA of flushed batch sizes in 1/16 units, alpha = 1/4 — integer
    /// arithmetic so the load-regime decision is exactly reproducible from
    /// the flush history alone.  Starts at 16 (= size 1, the idle regime).
    ewma_size_x16: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy, d_in: usize) -> Self {
        Batcher {
            policy,
            d_in,
            queue: Vec::new(),
            flushes_full: 0,
            flushes_timeout: 0,
            size_hist: vec![0; policy.max_batch + 1],
            ewma_size_x16: 16,
        }
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// The age budget currently in force: the configured `max_wait_us`
    /// while coalescing (recent flushes average ≥ [`MR`] rows), else the
    /// short idle budget.  Pure function of the flush-size history.
    pub fn effective_wait_us(&self) -> u64 {
        if self.ewma_size_x16 >= 16 * MR as u64 {
            self.policy.max_wait_us
        } else {
            self.policy.max_wait_us / IDLE_WAIT_DIV
        }
    }

    /// Enqueue; returns a full batch if this push filled it.  `submitted`
    /// is the caller's submit stamp, carried through to the batch so the
    /// observability plane can decompose queue vs batch-formation time.
    pub fn push(&mut self, id: u64, x_raw: Vec<f32>, submitted: Instant) -> Option<Batch> {
        assert_eq!(x_raw.len(), self.d_in, "request dimensionality mismatch");
        // audit:allow(determinism) — enqueue stamp is latency metadata; batch formation uses the caller-supplied `now`.
        self.queue.push(Pending { id, x_raw, submitted, enqueued: Instant::now() });
        if self.queue.len() >= self.policy.max_batch {
            self.flushes_full += 1;
            return Some(self.flush(true));
        }
        None
    }

    /// Flush if the oldest request has waited past the (adaptive) age
    /// budget.
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        let oldest = self.queue.first()?.enqueued;
        if now.duration_since(oldest) >= Duration::from_micros(self.effective_wait_us()) {
            self.flushes_timeout += 1;
            Some(self.flush(true))
        } else {
            None
        }
    }

    /// Unconditional flush (shutdown drain; no MR rounding — everything
    /// left goes out).  Empty queue -> None.
    pub fn drain(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.flush(false))
        }
    }

    /// Consume the batcher into its shutdown stats.
    pub fn into_stats(self) -> BatcherStats {
        BatcherStats {
            flushes_full: self.flushes_full,
            flushes_timeout: self.flushes_timeout,
            size_hist: self.size_hist,
        }
    }

    fn flush(&mut self, round_to_mr: bool) -> Batch {
        let mut n = self.queue.len().min(self.policy.max_batch);
        // GEMM-shaped coalescing: above one register block, take whole
        // blocks only; the (younger) remainder keeps its arrival times
        // and flushes on its own age or the next fill.
        if round_to_mr && n > MR {
            n -= n % MR;
        }
        if n < self.size_hist.len() {
            self.size_hist[n] += 1;
        }
        self.ewma_size_x16 = self.ewma_size_x16 - self.ewma_size_x16 / 4 + 4 * n as u64;
        let taken: Vec<Pending> = self.queue.drain(..n).collect();
        let mut x = Vec::with_capacity(n * self.d_in);
        let mut ids = Vec::with_capacity(n);
        let mut sub = Vec::with_capacity(n);
        let mut enq = Vec::with_capacity(n);
        for p in taken {
            ids.push(p.id);
            sub.push(p.submitted);
            enq.push(p.enqueued);
            x.extend_from_slice(&p.x_raw);
        }
        Batch { ids, x_raw: x, n, submitted: sub, enqueued: enq }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn policy(max_batch: usize, max_wait_us: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait_us }
    }

    #[test]
    fn fills_at_max_batch() {
        let mut b = Batcher::new(policy(3, 1_000_000), 2);
        assert!(b.push(0, vec![0.0; 2], Instant::now()).is_none());
        assert!(b.push(1, vec![0.0; 2], Instant::now()).is_none());
        let batch = b.push(2, vec![0.0; 2], Instant::now()).expect("should flush");
        assert_eq!(batch.n, 3);
        assert_eq!(batch.ids, vec![0, 1, 2]);
        assert_eq!(batch.x_raw.len(), 6);
        assert_eq!(b.pending(), 0);
        assert_eq!(b.flushes_full, 1);
    }

    #[test]
    fn timeout_flushes_partial() {
        let mut b = Batcher::new(policy(100, 0), 1);
        b.push(7, vec![1.0], Instant::now());
        let batch = b.poll(Instant::now()).expect("age 0 flushes immediately");
        assert_eq!(batch.ids, vec![7]);
        assert_eq!(b.flushes_timeout, 1);
        assert!(b.poll(Instant::now()).is_none(), "empty queue never flushes");
    }

    #[test]
    fn drain_returns_leftovers() {
        let mut b = Batcher::new(policy(10, 1_000_000), 1);
        assert!(b.drain().is_none());
        b.push(1, vec![0.5], Instant::now());
        b.push(2, vec![0.6], Instant::now());
        let batch = b.drain().unwrap();
        assert_eq!(batch.n, 2);
        assert_eq!(batch.x_raw, vec![0.5, 0.6]);
    }

    /// Micro-batch formation is a pure function of the push/poll call
    /// sequence: a timeout flush of 10 pending rows takes exactly the 8
    /// oldest (two full MR blocks), leaves the 2 youngest queued, and the
    /// drain picks those up un-rounded — pinned batch by batch.
    #[test]
    fn mr_rounding_is_deterministic_for_arrival_order() {
        let mut b = Batcher::new(policy(64, 0), 1);
        for id in 0..10u64 {
            assert!(b.push(id, vec![id as f32], Instant::now()).is_none());
        }
        let first = b.poll(Instant::now()).expect("age 0 flushes");
        assert_eq!(first.n, 8, "10 pending round down to two MR blocks");
        assert_eq!(first.ids, (0..8).collect::<Vec<u64>>());
        assert_eq!(b.pending(), 2, "youngest remainder stays queued");
        // The remainder is below MR: it flushes whole (low-latency path).
        let rest = b.poll(Instant::now()).expect("remainder flushes");
        assert_eq!(rest.ids, vec![8, 9]);
        // Exactly MR pending is already GEMM-shaped: no rounding.
        for id in 10..14u64 {
            b.push(id, vec![id as f32], Instant::now());
        }
        assert_eq!(b.poll(Instant::now()).unwrap().n, 4);
        let stats = b.into_stats();
        assert_eq!(stats.size_hist[8], 1);
        assert_eq!(stats.size_hist[2], 1);
        assert_eq!(stats.size_hist[4], 1);
        assert_eq!(stats.multi_row_batches(), 3);
    }

    /// A full-size flush whose `max_batch` is not MR-aligned also rounds
    /// down, keeping every dispatched panel GEMM-shaped under load.
    #[test]
    fn full_flush_rounds_to_mr() {
        let mut b = Batcher::new(policy(10, 1_000_000), 1);
        let mut got = None;
        for id in 0..10u64 {
            if let Some(batch) = b.push(id, vec![0.0], Instant::now()) {
                got = Some(batch);
            }
        }
        let batch = got.expect("size trigger at 10 pending");
        assert_eq!(batch.n, 8, "10-row fill rounds to two MR blocks");
        assert_eq!(b.pending(), 2);
    }

    /// The age budget adapts to load: idle flush history (singles) keeps
    /// the short budget; sustained GEMM-shaped flushes engage the full
    /// coalescing window; going idle again decays back.  The regime is a
    /// pure function of the flushed sizes — asserted without any clock.
    #[test]
    fn effective_wait_tracks_load_regime() {
        let mut b = Batcher::new(policy(64, 1600), 1);
        assert_eq!(b.effective_wait_us(), 100, "cold start is the idle regime");
        // Polling with a fabricated far-future `now` always exceeds the
        // age budget: flushes go through the real timeout path without
        // the test ever sleeping.
        let later = || Instant::now() + Duration::from_secs(1);
        // Singles keep it idle.
        for id in 0..3u64 {
            b.push(id, vec![0.0], Instant::now());
            assert!(b.poll(later()).is_some());
            assert_eq!(b.effective_wait_us(), 100);
        }
        // A run of 8-row batches pushes the EWMA past MR: full budget.
        for round in 0..4u64 {
            for id in 0..8u64 {
                b.push(100 + round * 8 + id, vec![0.0], Instant::now());
            }
            assert!(b.poll(later()).is_some());
        }
        assert_eq!(b.effective_wait_us(), 1600, "coalescing regime engages");
        // Singles again: decays back to the idle budget.
        for id in 0..12u64 {
            b.push(1000 + id, vec![0.0], Instant::now());
            assert!(b.poll(later()).is_some());
        }
        assert_eq!(b.effective_wait_us(), 100, "idle regime re-engages");
    }

    /// Property: no request is lost or duplicated and arrival order is
    /// preserved across any interleaving of push/poll/drain — including
    /// the MR-rounded flushes that leave remainders queued.
    #[test]
    fn prop_batcher_conserves_requests() {
        prop::check(
            "batcher-conservation",
            150,
            0xBA7C4,
            |r: &mut Rng| {
                let max_batch = 1 + r.below(8) as usize;
                let n = r.below(200) as usize;
                let polls: Vec<bool> = (0..n).map(|_| r.bool(0.2)).collect();
                (max_batch, polls)
            },
            |(max_batch, polls)| {
                let mut b = Batcher::new(policy(*max_batch, 0), 1);
                let mut got: Vec<u64> = Vec::new();
                for (i, &do_poll) in polls.iter().enumerate() {
                    if let Some(batch) = b.push(i as u64, vec![i as f32], Instant::now()) {
                        got.extend(&batch.ids);
                    }
                    if do_poll {
                        if let Some(batch) = b.poll(Instant::now()) {
                            got.extend(&batch.ids);
                        }
                    }
                }
                while let Some(batch) = b.drain() {
                    got.extend(&batch.ids);
                }
                let want: Vec<u64> = (0..polls.len() as u64).collect();
                if got != want {
                    return Err(format!("ids out of order or lost: {got:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    #[should_panic(expected = "dimensionality")]
    fn rejects_wrong_width() {
        let mut b = Batcher::new(policy(4, 0), 3);
        b.push(0, vec![0.0; 2], Instant::now());
    }
}
