//! Dynamic batcher: accumulate requests until the batch fills or the oldest
//! request exceeds its age budget (size-or-timeout policy, the same shape
//! vLLM-style servers use).  The offline eval path slices datasets directly;
//! this is the online server's ingress stage.

use std::time::{Duration, Instant};

use crate::config::BatchPolicy;

/// One queued request: opaque id + raw input row.
#[derive(Clone, Debug)]
pub struct Pending {
    pub id: u64,
    pub x_raw: Vec<f32>,
    pub enqueued: Instant,
}

/// A flushed batch.
#[derive(Clone, Debug)]
pub struct Batch {
    pub ids: Vec<u64>,
    /// Row-major `(n, d_in)` raw inputs.
    pub x_raw: Vec<f32>,
    pub n: usize,
    pub enqueued: Vec<Instant>,
}

/// Size-or-age dynamic batcher.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    d_in: usize,
    queue: Vec<Pending>,
    pub flushes_full: u64,
    pub flushes_timeout: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy, d_in: usize) -> Self {
        Batcher { policy, d_in, queue: Vec::new(), flushes_full: 0, flushes_timeout: 0 }
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Enqueue; returns a full batch if this push filled it.
    pub fn push(&mut self, id: u64, x_raw: Vec<f32>) -> Option<Batch> {
        assert_eq!(x_raw.len(), self.d_in, "request dimensionality mismatch");
        self.queue.push(Pending { id, x_raw, enqueued: Instant::now() });
        if self.queue.len() >= self.policy.max_batch {
            self.flushes_full += 1;
            return Some(self.flush());
        }
        None
    }

    /// Flush if the oldest request has waited past the age budget.
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        let oldest = self.queue.first()?.enqueued;
        if now.duration_since(oldest) >= Duration::from_micros(self.policy.max_wait_us) {
            self.flushes_timeout += 1;
            Some(self.flush())
        } else {
            None
        }
    }

    /// Unconditional flush (shutdown drain). Empty queue -> None.
    pub fn drain(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.flush())
        }
    }

    fn flush(&mut self) -> Batch {
        let n = self.queue.len().min(self.policy.max_batch);
        let taken: Vec<Pending> = self.queue.drain(..n).collect();
        let mut x = Vec::with_capacity(n * self.d_in);
        let mut ids = Vec::with_capacity(n);
        let mut enq = Vec::with_capacity(n);
        for p in taken {
            ids.push(p.id);
            enq.push(p.enqueued);
            x.extend_from_slice(&p.x_raw);
        }
        Batch { ids, x_raw: x, n, enqueued: enq }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn policy(max_batch: usize, max_wait_us: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait_us }
    }

    #[test]
    fn fills_at_max_batch() {
        let mut b = Batcher::new(policy(3, 1_000_000), 2);
        assert!(b.push(0, vec![0.0; 2]).is_none());
        assert!(b.push(1, vec![0.0; 2]).is_none());
        let batch = b.push(2, vec![0.0; 2]).expect("should flush");
        assert_eq!(batch.n, 3);
        assert_eq!(batch.ids, vec![0, 1, 2]);
        assert_eq!(batch.x_raw.len(), 6);
        assert_eq!(b.pending(), 0);
        assert_eq!(b.flushes_full, 1);
    }

    #[test]
    fn timeout_flushes_partial() {
        let mut b = Batcher::new(policy(100, 0), 1);
        b.push(7, vec![1.0]);
        let batch = b.poll(Instant::now()).expect("age 0 flushes immediately");
        assert_eq!(batch.ids, vec![7]);
        assert_eq!(b.flushes_timeout, 1);
        assert!(b.poll(Instant::now()).is_none(), "empty queue never flushes");
    }

    #[test]
    fn drain_returns_leftovers() {
        let mut b = Batcher::new(policy(10, 1_000_000), 1);
        assert!(b.drain().is_none());
        b.push(1, vec![0.5]);
        b.push(2, vec![0.6]);
        let batch = b.drain().unwrap();
        assert_eq!(batch.n, 2);
        assert_eq!(batch.x_raw, vec![0.5, 0.6]);
    }

    /// Property: no request is lost or duplicated and arrival order is
    /// preserved across any interleaving of push/poll/drain.
    #[test]
    fn prop_batcher_conserves_requests() {
        prop::check(
            "batcher-conservation",
            150,
            0xBA7C4,
            |r: &mut Rng| {
                let max_batch = 1 + r.below(8) as usize;
                let n = r.below(200) as usize;
                let polls: Vec<bool> = (0..n).map(|_| r.bool(0.2)).collect();
                (max_batch, polls)
            },
            |(max_batch, polls)| {
                let mut b = Batcher::new(policy(*max_batch, 0), 1);
                let mut got: Vec<u64> = Vec::new();
                for (i, &do_poll) in polls.iter().enumerate() {
                    if let Some(batch) = b.push(i as u64, vec![i as f32]) {
                        got.extend(&batch.ids);
                    }
                    if do_poll {
                        if let Some(batch) = b.poll(Instant::now()) {
                            got.extend(&batch.ids);
                        }
                    }
                }
                while let Some(batch) = b.drain() {
                    got.extend(&batch.ids);
                }
                let want: Vec<u64> = (0..polls.len() as u64).collect();
                if got != want {
                    return Err(format!("ids out of order or lost: {got:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    #[should_panic(expected = "dimensionality")]
    fn rejects_wrong_width() {
        let mut b = Batcher::new(policy(4, 0), 3);
        b.push(0, vec![0.0; 2]);
    }
}
