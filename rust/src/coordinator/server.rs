//! Threaded serving pipeline (tokio substitute: dedicated threads + mpsc).
//!
//! ```text
//! caller ──send──► ingress channel ──► batcher thread ──► batch channel
//!                                                              │
//! caller ◄──recv── egress channel ◄── dispatch worker(s) ◄─────┘
//! ```
//!
//! The batcher thread owns the `Batcher` (size-or-timeout policy); dispatch
//! workers own a `Dispatcher` each and execute classify/route/execute.
//! Responses carry per-request latency; `ServerReport` aggregates
//! throughput, latency percentiles and routing statistics.  This is the
//! end-to-end driver `examples/serve_pipeline.rs` exercises.
//!
//! ## Online QoS (optional, `ServerConfig::qos`)
//!
//! With a [`QosConfig`], the server closes the quality loop at serve time:
//! workers shadow-select approximated responses by deterministic id hash
//! and hand them to a dedicated `mcma-qos` thread, which re-runs the
//! precise `BenchFn`, feeds per-class error windows, and runs the adaptive
//! margin controller ([`crate::qos::Controller`]).  Updated per-class
//! margins are published as relaxed atomic f32 bits; workers re-read them
//! once per batch — the request hot path itself never computes errors,
//! never locks, and stays zero-allocation apart from the (rate-limited)
//! shadow payload copies, which are of the same nature as the response
//! payloads.

// audit:connection-facing — network readers feed this pipeline; a
// hostile request must never panic a worker or the batcher thread.
// audit:lock-ordered — shared mutexes follow the fixed acquisition
// order batch_rx -> registry -> reader_threads; mcma-audit reports any
// out-of-order nesting in this file.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::config::{BatchPolicy, ExecMode, Method};
use crate::formats::{BenchManifest, Dataset, Manifest, WeightsFile, WorkloadKind};
use crate::obs::{Event, Obs};
use crate::qos::{Controller, QosConfig, QosReport, ShadowSampler, MARGIN_PRECISE};
use crate::runtime::{ModelBank, Runtime};
use crate::util::lock_unpoisoned;
use crate::workload::{NearestLookup, PreciseProxy};

use super::batcher::{Batcher, BatcherStats};
use super::dispatcher::Dispatcher;
use super::metrics::{ClassCounters, LatencyStats, PerRouteReport};
use super::router::Route;

/// A request into the pipeline.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub x_raw: Vec<f32>,
    pub submitted: Instant,
}

/// A response out of the pipeline.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Normalised-space output actually served.
    pub y: Vec<f32>,
    pub route: Route,
    /// Submit → dispatch latency (the SERVED latency: when the worker
    /// handed the response to egress, measured from `Request::submitted`).
    /// Client-delivery time is a separate measurement — the response pump
    /// records submit → delivered into `obs` only for writes that
    /// actually reached the socket, so a dead client can't skew it.
    pub latency_us: f64,
    /// When the request entered the pipeline — lets the delivery side
    /// compute submit → delivered without re-deriving it from
    /// `latency_us`.
    pub submitted: Instant,
    /// How many rows shared this request's dispatch batch — the
    /// micro-batching observable, carried per-response so socket clients
    /// (and `bench-load`) can build the batch-size histogram end to end.
    pub batch_n: u32,
}

/// What a TABLE workload's dispatch workers do when the classifier
/// rejects a request to the precise path — no oracle exists at runtime
/// (`mcma serve --precise-fallback`).  Ignored for synthetic workloads,
/// whose precise function is always available.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TableFallback {
    /// Serve the label of the nearest held-out record (the default: every
    /// request gets an answer; rejected ones are nearest-neighbour
    /// interpolations instead of NN outputs).
    #[default]
    Lookup,
    /// Reject-with-error: fail the batch rather than serve an
    /// interpolated answer (the strict mode; undelivered responses are
    /// accounted as lost, see `LostGuard`).
    Reject,
}

impl std::str::FromStr for TableFallback {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "lookup" => Ok(TableFallback::Lookup),
            "reject" => Ok(TableFallback::Reject),
            _ => anyhow::bail!("unknown precise fallback {s:?} (lookup|reject)"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub policy: BatchPolicy,
    pub method: Method,
    pub exec: ExecMode,
    /// Dispatch workers.  Each owns an independent PJRT runtime + model
    /// bank (PJRT handles are thread-local by construction here), pulling
    /// batches from a shared queue — scale-out for multi-core boxes.
    pub workers: usize,
    /// Online quality control (`None` = the classic fixed-routing server).
    pub qos: Option<QosConfig>,
    /// Precise-path behaviour for oracle-less table workloads.
    pub table_fallback: TableFallback,
}

impl ServerConfig {
    pub fn new(policy: BatchPolicy, method: Method, exec: ExecMode) -> Self {
        ServerConfig {
            policy,
            method,
            exec,
            workers: 1,
            qos: None,
            table_fallback: TableFallback::default(),
        }
    }

    /// Builder-style QoS enablement.
    pub fn with_qos(mut self, qos: QosConfig) -> Self {
        self.qos = Some(qos);
        self
    }
}

/// Aggregate report after `shutdown()`.
#[derive(Clone, Debug)]
pub struct ServerReport {
    pub served: u64,
    pub invoked: u64,
    pub cpu: u64,
    pub wall: Duration,
    pub latency: LatencyStats,
    pub flushes_full: u64,
    pub flushes_timeout: u64,
    pub batches: u64,
    /// Dispatched batch-size histogram from the batcher
    /// (`batch_hist[n]` = batches of exactly `n` rows; index 0 unused).
    pub batch_hist: Vec<u64>,
    /// Per-approximator-class (and CPU) response counts + latency.
    pub per_route: PerRouteReport,
    /// QoS controller outcome (present iff `ServerConfig::qos` was set).
    pub qos: Option<QosReport>,
}

impl ServerReport {
    pub fn throughput_rps(&self) -> f64 {
        if self.wall.as_secs_f64() == 0.0 {
            0.0
        } else {
            self.served as f64 / self.wall.as_secs_f64()
        }
    }

    pub fn invocation(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.invoked as f64 / self.served as f64
        }
    }
}

enum BatchMsg {
    Work(super::batcher::Batch),
    Stop,
}

/// Counts the responses a dispatch worker still OWES for the batch in
/// flight.  Dropped with a non-zero count — an error `?`-return or a panic
/// unwinding through `process_batch_into` — the shortfall lands in the
/// shared `lost` counter, so `shutdown`'s drain stops waiting for
/// responses that can never arrive (the same drop-guard discipline
/// `util::threadpool::PendingGuard` uses for its pending count).
struct LostGuard<'a> {
    lost: &'a AtomicU64,
    remaining: u64,
    /// In-flight gauge to release the shortfall from (None in unit
    /// tests); kept exact even when a worker dies mid-batch.
    inflight: Option<&'a crate::obs::Gauge>,
}

impl Drop for LostGuard<'_> {
    fn drop(&mut self) {
        if self.remaining > 0 {
            self.lost.fetch_add(self.remaining, Ordering::Release);
            if let Some(g) = self.inflight {
                g.add(-(self.remaining as i64));
            }
        }
    }
}

/// Bound on queued shadow observations.  The QoS thread re-runs the
/// PRECISE function per observation; when it falls behind, workers drop
/// further observations (counted in `ClassCounters::shadow_dropped`)
/// instead of queueing unbounded memory or ever blocking dispatch.
const SHADOW_QUEUE_CAP: usize = 1024;

/// Fraction of request ids whose spans land in the trace journal.  The
/// pick is the same pure `(seed, id)` hash discipline as shadow
/// sampling (different mixing constant), so the traced set is
/// worker-count invariant.
const DEFAULT_TRACE_RATE: f64 = 0.02;

/// How long the QoS thread waits for an observation before checking
/// whether an open circuit breaker needs a wall-clock cooldown tick
/// (forced-precise classes generate no observations, so their recovery
/// cannot be observation-driven).
const BREAKER_IDLE_TICK: Duration = Duration::from_millis(50);

/// One shadow-selected response on its way to the QoS thread: everything
/// needed to score the served value against the precise function.
struct ShadowObs {
    class: usize,
    x_raw: Vec<f32>,
    y_served: Vec<f32>,
}

/// Margins published by the QoS thread, read by every dispatch worker
/// once per batch.  f32 bit patterns in relaxed atomics: the controller
/// is the only writer, readers tolerate tearing-free staleness of one
/// batch, and the hot path never locks.
struct QosShared {
    margins: Vec<AtomicU32>,
}

impl QosShared {
    fn new(n_approx: usize) -> Self {
        QosShared {
            margins: (0..n_approx).map(|_| AtomicU32::new(0.0f32.to_bits())).collect(),
        }
    }

    fn publish(&self, margins: &[f32]) {
        for (slot, m) in self.margins.iter().zip(margins) {
            // audit:allow(atomics) — single-writer f32-bits publish; workers tolerate one-batch staleness
            slot.store(m.to_bits(), Ordering::Relaxed);
        }
    }

    fn load_into(&self, out: &mut Vec<f32>) {
        out.clear();
        // audit:allow(atomics) — margin snapshot; one-batch staleness is the design (see module docs)
        out.extend(self.margins.iter().map(|s| f32::from_bits(s.load(Ordering::Relaxed))));
    }
}

/// Handle to the running pipeline.
pub struct Server {
    ingress: mpsc::Sender<Option<Request>>,
    egress: mpsc::Receiver<Response>,
    batcher_thread: Option<thread::JoinHandle<BatcherStats>>,
    worker_threads: Vec<thread::JoinHandle<crate::Result<u64>>>,
    /// QoS controller thread (spawned iff `ServerConfig::qos`); joined
    /// after the workers so the observation channel is closed by then.
    qos_thread: Option<thread::JoinHandle<crate::Result<QosReport>>>,
    started: Instant,
    /// Requests accepted so far; `shutdown` drains exactly
    /// `submitted - already_collected - lost` responses instead of
    /// spinning on a fixed timeout after the last one.  Shared with every
    /// [`Submitter`] handed to network reader threads.
    submitted: Arc<AtomicU64>,
    /// Responses workers failed to deliver (panic or error mid-batch),
    /// maintained by [`LostGuard`] so the drain never waits for them.
    lost: Arc<AtomicU64>,
    /// Live observability: stage-histogram registry + span journal,
    /// shared with every pipeline thread (and, via [`Server::obs`], with
    /// the network front-end's readers and response pump).
    obs: Obs,
}

/// Cloneable ingress handle for threads that submit requests without
/// owning the `Server` (one per network reader thread).  The egress
/// `Receiver` is `!Sync`, so the `Server` itself cannot be shared; a
/// `Submitter` carries only the ingress sender plus the shared
/// submitted counter, keeping `shutdown`'s exact drain accounting
/// intact no matter which thread accepted the request.
#[derive(Clone)]
pub struct Submitter {
    ingress: mpsc::Sender<Option<Request>>,
    submitted: Arc<AtomicU64>,
    metrics: Arc<crate::obs::Registry>,
}

impl Submitter {
    /// Submit one request (non-blocking); mirrors [`Server::submit`].
    pub fn submit(&self, id: u64, x_raw: Vec<f32>) -> crate::Result<()> {
        self.ingress
            .send(Some(Request { id, x_raw, submitted: Instant::now() }))
            .map_err(|_| anyhow::anyhow!("server ingress closed"))?;
        // audit:allow(atomics) — monotone counter; the mpsc send above orders it against the drain
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.metrics.submitted.inc();
        self.metrics.inflight.add(1);
        Ok(())
    }

    /// Requests submitted so far across ALL submitters of this server.
    pub fn submitted(&self) -> u64 {
        // audit:allow(atomics) — monotone counter polled by the drain; re-read every iteration
        self.submitted.load(Ordering::Relaxed)
    }
}

impl Server {
    /// Spawn the pipeline.
    ///
    /// PJRT handles are not `Send` (the underlying client is `Rc`-based),
    /// so the dispatch worker constructs its OWN `Runtime` + `ModelBank`
    /// inside the thread from the manifest — nothing device-side ever
    /// crosses a thread boundary.
    pub fn spawn(
        man: Arc<Manifest>,
        bench: Arc<BenchManifest>,
        cfg: ServerConfig,
    ) -> crate::Result<Self> {
        let (in_tx, in_rx) = mpsc::channel::<Option<Request>>();
        let (batch_tx, batch_rx) = mpsc::channel::<BatchMsg>();
        let (out_tx, out_rx) = mpsc::channel::<Response>();
        let batch_rx = Arc::new(std::sync::Mutex::new(batch_rx));
        // Workers re-broadcast Stop so every sibling wakes and exits.
        let stop_tx = batch_tx.clone();

        let d_in = bench.n_in;
        let policy = cfg.policy;

        // Observability plane: shared registry + sampled span journal.
        // Trace sampling reuses the QoS seed when present so one seed
        // pins both deterministic samples.
        let trace_seed = cfg.qos.as_ref().map(|q| q.seed).unwrap_or(0x0B5E_0B5E);
        let obs = Obs::new(trace_seed, DEFAULT_TRACE_RATE);
        obs.metrics.set_exec_mode(match cfg.exec {
            ExecMode::Native => "native",
            ExecMode::NativeQ8 => "native-q8",
            ExecMode::Pjrt => "pjrt",
        });
        obs.metrics.qos_enabled.set(cfg.qos.is_some() as i64);

        let batcher_metrics = Arc::clone(&obs.metrics);
        let batcher_thread = thread::Builder::new()
            .name("mcma-batcher".into())
            .spawn(move || {
                let mut batcher = Batcher::new(policy, d_in);
                loop {
                    batcher_metrics.batch_queue_depth.set(batcher.pending() as i64);
                    // The tick tracks the batcher's ADAPTIVE age budget
                    // (idle regime: max_wait/16), so a lone request is
                    // re-polled — and dispatched — on the short idle
                    // schedule instead of sleeping out half the full
                    // coalescing window.
                    let tick =
                        Duration::from_micros((batcher.effective_wait_us() / 2).max(50));
                    match in_rx.recv_timeout(tick) {
                        Ok(Some(req)) => {
                            if let Some(b) = batcher.push(req.id, req.x_raw, req.submitted) {
                                let _ = batch_tx.send(BatchMsg::Work(b));
                            }
                            // Age check must ALSO run on the arrival path:
                            // a steady stream with interarrival < tick
                            // would otherwise starve the timeout branch and
                            // batches would only ever flush when full.
                            if let Some(b) = batcher.poll(Instant::now()) {
                                let _ = batch_tx.send(BatchMsg::Work(b));
                            }
                        }
                        Ok(None) => {
                            // Shutdown: drain leftovers, signal stop.
                            while let Some(b) = batcher.drain() {
                                let _ = batch_tx.send(BatchMsg::Work(b));
                            }
                            let _ = batch_tx.send(BatchMsg::Stop);
                            return batcher.into_stats();
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            if let Some(b) = batcher.poll(Instant::now()) {
                                let _ = batch_tx.send(BatchMsg::Work(b));
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            let _ = batch_tx.send(BatchMsg::Stop);
                            return batcher.into_stats();
                        }
                    }
                }
            })?;

        // QoS plumbing (only built when enabled — the classic server
        // pays nothing): shared margin atomics, the per-class counter
        // block shared by workers (routing) and the QoS thread (shadow
        // accounting), the BOUNDED shadow-observation channel, and the
        // stateless per-worker sampler.  The approximator count comes
        // from the same weights file the workers' model banks load.
        let (qos_shared, counters, sampler, obs_tx, obs_rx, n_approx) = match &cfg.qos {
            Some(q) => {
                q.validate()?;
                anyhow::ensure!(
                    cfg.method != Method::Mcca,
                    "QoS margins are confidence-based and do not apply to \
                     the MCCA cascade"
                );
                let n_approx = WeightsFile::load(&man.weights_path(&bench.name))?
                    .get(cfg.method.key())?
                    .approximators
                    .len();
                // Bounded: the consumer re-runs the PRECISE function per
                // observation, which can be far slower than serving.  On
                // backlog the workers drop the observation (counted) —
                // the estimator sees a thinner sample, never a stalled
                // dispatch thread or unbounded memory.
                let (tx, rx) = mpsc::sync_channel::<ShadowObs>(SHADOW_QUEUE_CAP);
                (
                    Some(Arc::new(QosShared::new(n_approx))),
                    Some(Arc::new(ClassCounters::new(n_approx))),
                    Some(ShadowSampler::new(q.seed, q.shadow_rate)),
                    Some(tx),
                    Some(rx),
                    n_approx,
                )
            }
            None => (None, None, None, None, None, 0),
        };

        // Table workloads: the held-out store backs the precise fallback,
        // the QoS shadow verifier and the warm-start replay — load it
        // ONCE and share it; workers clone an `Arc`, not the data.
        let table_store: Option<(Arc<Dataset>, Arc<NearestLookup>)> = match bench.kind {
            WorkloadKind::Table
                if cfg.table_fallback == TableFallback::Lookup || cfg.qos.is_some() =>
            {
                let ds = Arc::new(Dataset::load(&man.dataset_path(&bench.name))?);
                let lookup = Arc::new(NearestLookup::from_dataset(&bench, &ds));
                Some((ds, lookup))
            }
            _ => None,
        };

        let lost = Arc::new(AtomicU64::new(0));
        let mut worker_threads = Vec::new();
        for w in 0..cfg.workers.max(1) {
            let man = Arc::clone(&man);
            let bench = Arc::clone(&bench);
            let batch_rx = Arc::clone(&batch_rx);
            let out_tx = out_tx.clone();
            let stop_tx = stop_tx.clone();
            let lost = Arc::clone(&lost);
            let counters = counters.clone();
            let qos_shared = qos_shared.clone();
            let obs_tx = obs_tx.clone();
            let table_lookup = table_store.as_ref().map(|(_, l)| Arc::clone(l));
            let cfg = cfg.clone();
            let obs = obs.clone();
            worker_threads.push(
                thread::Builder::new()
                    .name(format!("mcma-dispatch-{w}"))
                    .spawn(move || -> crate::Result<u64> {
                        // Build all device state thread-locally (see spawn
                        // docs): PJRT handles never cross threads.
                        let rt = match cfg.exec {
                            ExecMode::Pjrt => Some(Runtime::cpu()?),
                            ExecMode::Native | ExecMode::NativeQ8 => None,
                        };
                        let bank = ModelBank::load(
                            rt.as_ref(),
                            &man,
                            &bench,
                            &[cfg.method],
                            &man.batch_sizes,
                        )?;
                        let dispatcher =
                            Dispatcher::new(&bench, &bank, cfg.method, cfg.exec)?;
                        // Oracle-less table workloads: install the
                        // configured precise fallback — the shared
                        // held-out nearest-record lookup (default) or
                        // keep the hard reject.  Synthetic workloads
                        // already carry their registered function.
                        let dispatcher = match (&table_lookup, cfg.table_fallback) {
                            (Some(lookup), TableFallback::Lookup) => dispatcher
                                .with_precise_proxy(PreciseProxy::Lookup(Arc::clone(lookup))),
                            _ => dispatcher,
                        };
                        // Per-class execute + precise-fallback timing lands
                        // in the shared registry straight from the
                        // dispatcher's inner loops.
                        let dispatcher = dispatcher.with_obs(Arc::clone(&obs.metrics));
                        let tracer = obs.journal.sampler();
                        let mut batches = 0u64;
                        let d_in = bench.n_in;
                        let d_out = bench.n_out;
                        // Worker-owned hot-path arena: plan, outputs and
                        // every intermediate buffer are reused across
                        // batches — steady state allocates nothing per
                        // batch beyond the response payloads.
                        let mut scratch = super::dispatcher::Scratch::new();
                        let mut plan = super::router::RoutePlan::default();
                        let mut y: Vec<f32> = Vec::new();
                        // Per-batch snapshot of the published QoS margins
                        // (reused buffer; one relaxed load per class).
                        let mut margins: Vec<f32> = Vec::new();
                        loop {
                            let msg = { lock_unpoisoned(&batch_rx).recv() };
                            match msg {
                                Ok(BatchMsg::Work(batch)) => {
                                    batches += 1;
                                    // Every id in this batch is owed a
                                    // response; whatever is still unsent
                                    // when the guard drops (error return,
                                    // panic unwind) is counted as lost.
                                    let mut guard = LostGuard {
                                        lost: &lost,
                                        remaining: batch.ids.len() as u64,
                                        inflight: Some(&obs.metrics.inflight),
                                    };
                                    let margin_view = match &qos_shared {
                                        Some(sh) => {
                                            sh.load_into(&mut margins);
                                            Some(margins.as_slice())
                                        }
                                        None => None,
                                    };
                                    let recv_now = Instant::now();
                                    dispatcher.process_batch_with_margins_into(
                                        &batch,
                                        margin_view,
                                        &mut plan,
                                        &mut y,
                                        &mut scratch,
                                    )?;
                                    let now = Instant::now();
                                    // Execute time is batch-level; it is
                                    // recorded once PER ROW below so every
                                    // stage histogram has the same count
                                    // and the waterfall sums row-wise.
                                    let exec_us =
                                        now.duration_since(recv_now).as_micros() as u64;
                                    // Lockstep iteration instead of indexed
                                    // access: a ragged plan/output length can
                                    // only truncate (and be counted lost),
                                    // never panic the worker.
                                    let rows = batch
                                        .ids
                                        .iter()
                                        .zip(y.chunks_exact(d_out.max(1)))
                                        .zip(plan.routes.iter())
                                        .zip(batch.enqueued.iter())
                                        .zip(batch.submitted.iter());
                                    for ((((&id, y_row), &route), &enq), &sub) in rows {
                                        // duration_since saturates to zero,
                                        // so stage stamps read on different
                                        // threads can never panic here.
                                        let queue_us =
                                            enq.duration_since(sub).as_micros() as u64;
                                        let batch_us =
                                            recv_now.duration_since(enq).as_micros() as u64;
                                        let e2e_us =
                                            now.duration_since(sub).as_micros() as u64;
                                        obs.metrics.stage_queue.record(queue_us);
                                        obs.metrics.stage_batch.record(batch_us);
                                        obs.metrics.stage_execute.record(exec_us);
                                        obs.metrics.e2e_dispatch.record(e2e_us);
                                        obs.metrics.dispatched.inc();
                                        obs.metrics.inflight.add(-1);
                                        match route {
                                            Route::Approx(_) => {
                                                obs.metrics.route_invoked_rows.inc()
                                            }
                                            Route::Cpu => obs.metrics.route_cpu_rows.inc(),
                                        }
                                        if tracer.pick(id) {
                                            obs.journal.push(Event::Span {
                                                id,
                                                route: match route {
                                                    Route::Approx(k) => k as i64,
                                                    Route::Cpu => -1,
                                                },
                                                queue_us,
                                                batch_us,
                                                exec_us,
                                                e2e_us,
                                                at_us: obs.journal.now_us(),
                                            });
                                        }
                                        let _ = out_tx.send(Response {
                                            id,
                                            y: y_row.to_vec(),
                                            route,
                                            latency_us: now
                                                .duration_since(sub)
                                                .as_secs_f64()
                                                * 1e6,
                                            submitted: sub,
                                            batch_n: batch.n as u32,
                                        });
                                        guard.remaining -= 1;
                                    }
                                    debug_assert_eq!(guard.remaining, 0);
                                    if let Some(c) = &counters {
                                        c.record_plan(&plan);
                                    }
                                    // Shadow selection AFTER the responses
                                    // left: the id-hash pick is the only
                                    // per-sample QoS cost on this thread.
                                    // `try_send` never blocks dispatch; a
                                    // full queue drops the observation
                                    // (counted).
                                    if let (Some(tx), Some(s), Some(c)) =
                                        (&obs_tx, &sampler, &counters)
                                    {
                                        let shadow_rows = batch
                                            .ids
                                            .iter()
                                            .zip(plan.routes.iter())
                                            .zip(batch.x_raw.chunks_exact(d_in.max(1)))
                                            .zip(y.chunks_exact(d_out.max(1)));
                                        for (((&id, &route), x_row), y_row) in shadow_rows {
                                            if let Route::Approx(k) = route {
                                                if s.pick(id) {
                                                    let sob = ShadowObs {
                                                        class: k,
                                                        x_raw: x_row.to_vec(),
                                                        y_served: y_row.to_vec(),
                                                    };
                                                    if tx.try_send(sob).is_err() {
                                                        c.record_shadow_dropped();
                                                        obs.metrics.shadow_drops.inc();
                                                        obs.journal.push(Event::ShadowDrop {
                                                            at_us: obs.journal.now_us(),
                                                        });
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                                Ok(BatchMsg::Stop) | Err(_) => {
                                    let _ = stop_tx.send(BatchMsg::Stop);
                                    return Ok(batches);
                                }
                            }
                        }
                    })?,
            );
        }

        // Only workers hold observation senders now, so the QoS thread's
        // recv loop ends exactly when the last worker exits.
        drop(obs_tx);

        // The QoS thread: ground-truth verification, error estimation and
        // the control law all live here — never on a dispatch worker.
        let qos_thread = match (cfg.qos, obs_rx, &qos_shared, &counters) {
            (Some(q), Some(obs_rx), Some(shared), Some(counters)) => {
                let man = Arc::clone(&man);
                let bench = Arc::clone(&bench);
                let shared = Arc::clone(shared);
                let counters = Arc::clone(counters);
                let method = cfg.method;
                let table_store = table_store.clone();
                let qobs = obs.clone();
                Some(
                    thread::Builder::new()
                        .name("mcma-qos".into())
                        .spawn(move || -> crate::Result<QosReport> {
                            // Ground truth for shadow verification: the
                            // registered precise function for synthetic
                            // workloads; for table workloads (no oracle
                            // at runtime) the HELD-OUT labels — traffic
                            // drawn from the held-out set verifies
                            // against its own recorded labels, unseen
                            // inputs against their nearest held-out
                            // record (shared store, loaded once at
                            // spawn).  Breaker semantics are unchanged.
                            let proxy = match &table_store {
                                Some((_, lookup)) => {
                                    PreciseProxy::Lookup(Arc::clone(lookup))
                                }
                                None => PreciseProxy::Function(
                                    crate::benchmarks::by_name(&bench.name)?,
                                ),
                            };
                            let mut ctrl = Controller::new(q, n_approx);
                            let mut margins: Vec<f32> = Vec::new();
                            // Last margins mirrored to the obs plane —
                            // diffed on every publish to emit margin-move
                            // and breaker-transition events.
                            let mut prev_margins: Vec<f32> = vec![0.0; n_approx];
                            if q.warm_start {
                                // Seed margins from the offline replay of
                                // the held-out set instead of cold-starting
                                // at argmax.  Best-effort: a tree without
                                // test.bin (or a failed replay) falls back
                                // to the cold start it replaces.
                                let held_out =
                                    table_store.as_ref().map(|(ds, _)| ds.as_ref());
                                match warm_start_margins(&man, &bench, method, &q, held_out)
                                {
                                    Ok(Some(m)) => {
                                        ctrl.seed_margins(&m);
                                        ctrl.margins_into(&mut margins);
                                        shared.publish(&margins);
                                        note_qos_publish(&qobs, &prev_margins, &margins);
                                        prev_margins.clone_from(&margins);
                                    }
                                    Ok(None) => eprintln!(
                                        "mcma-qos: no held-out test.bin — \
                                         cold-starting margins"
                                    ),
                                    Err(e) => eprintln!(
                                        "mcma-qos: warm-start replay failed \
                                         ({e:#}) — cold-starting margins"
                                    ),
                                }
                            }
                            let mut raw = vec![0.0f64; bench.n_out];
                            let mut y_precise = vec![0.0f32; bench.n_out];
                            loop {
                                match obs_rx.recv_timeout(BREAKER_IDLE_TICK) {
                                    Ok(sob) => {
                                        let t_shadow = Instant::now();
                                        proxy.serve_norm_into(
                                            &bench,
                                            &sob.x_raw,
                                            &mut raw,
                                            &mut y_precise,
                                        )?;
                                        qobs.metrics
                                            .stage_shadow
                                            .record(t_shadow.elapsed().as_micros() as u64);
                                        let err =
                                            crate::qos::row_rmse(&sob.y_served, &y_precise);
                                        counters.record_shadow(sob.class);
                                        ctrl.observe(sob.class, err);
                                        if ctrl.maybe_tick() {
                                            ctrl.margins_into(&mut margins);
                                            shared.publish(&margins);
                                            note_qos_publish(
                                                &qobs,
                                                &prev_margins,
                                                &margins,
                                            );
                                            prev_margins.clone_from(&margins);
                                        }
                                    }
                                    Err(mpsc::RecvTimeoutError::Timeout) => {
                                        // An open breaker suppresses the very
                                        // observations that drive ticks (its
                                        // class is forced precise), so its
                                        // cooldown must elapse on wall-clock
                                        // or it would stay open forever.
                                        // Idle ticks judge only classes with
                                        // fresh observations; with none in
                                        // flight they purely advance breaker
                                        // cooldowns.
                                        if ctrl.any_breaker_open() {
                                            ctrl.tick();
                                            ctrl.margins_into(&mut margins);
                                            shared.publish(&margins);
                                            note_qos_publish(
                                                &qobs,
                                                &prev_margins,
                                                &margins,
                                            );
                                            prev_margins.clone_from(&margins);
                                        }
                                    }
                                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                                }
                            }
                            let mut report = ctrl.report(
                                Some(&counters.snapshot_shadow()),
                                Some(&counters.snapshot_invoked()),
                            );
                            report.shadow_dropped = counters.shadow_dropped();
                            Ok(report)
                        })?,
                )
            }
            _ => None,
        };

        Ok(Server {
            ingress: in_tx,
            egress: out_rx,
            batcher_thread: Some(batcher_thread),
            worker_threads,
            qos_thread,
            started: Instant::now(),
            submitted: Arc::new(AtomicU64::new(0)),
            lost,
            obs,
        })
    }

    /// Submit one request (non-blocking).
    pub fn submit(&self, id: u64, x_raw: Vec<f32>) -> crate::Result<()> {
        self.ingress
            .send(Some(Request { id, x_raw, submitted: Instant::now() }))
            .map_err(|_| anyhow::anyhow!("server ingress closed"))?;
        // audit:allow(atomics) — monotone counter; the mpsc send above orders it against the drain
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.obs.metrics.submitted.inc();
        self.obs.metrics.inflight.add(1);
        Ok(())
    }

    /// A cloneable ingress handle sharing this server's submit counter —
    /// hand one to each network reader thread (see [`Submitter`]).
    pub fn submitter(&self) -> Submitter {
        Submitter {
            ingress: self.ingress.clone(),
            submitted: Arc::clone(&self.submitted),
            metrics: Arc::clone(&self.obs.metrics),
        }
    }

    /// The pipeline's observability handle (metrics registry + span
    /// journal) — cloneable; the network front-end's readers and response
    /// pump record into the same plane the STATS scrape snapshots.
    pub fn obs(&self) -> Obs {
        self.obs.clone()
    }

    /// Receive one response (blocking with timeout).
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        self.egress.recv_timeout(timeout).ok()
    }

    /// Stop accepting, drain, join, and report.
    pub fn shutdown(mut self, mut collected: Vec<Response>) -> crate::Result<ServerReport> {
        let _ = self.ingress.send(None);
        // Drain exactly the outstanding responses: submitted minus already
        // received minus the ones workers reported lost (drop-guard on an
        // error return or panic mid-batch, see `LostGuard`).  `lost` is
        // re-read every iteration so a worker failing DURING the drain
        // releases it immediately instead of stranding it on the timeout.
        // The 2 s budget stays only as a last-resort net for responses
        // that vanish without being counted (e.g. a worker wedged before
        // its batch was guarded); it resets on progress, so a healthy
        // shutdown never waits on it.
        // audit:allow(atomics) — submitters are done by shutdown; the 2 s net below covers any straggler
        let submitted = self.submitted.load(Ordering::Relaxed);
        let mut deadline = Instant::now() + Duration::from_millis(2000);
        loop {
            let lost = self.lost.load(Ordering::Acquire);
            let outstanding =
                submitted.saturating_sub(collected.len() as u64).saturating_sub(lost);
            if outstanding == 0 {
                break;
            }
            match self.egress.recv_timeout(Duration::from_millis(50)) {
                Ok(r) => {
                    collected.push(r);
                    deadline = Instant::now() + Duration::from_millis(2000);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if Instant::now() >= deadline {
                        break;
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        let batcher_stats = self
            .batcher_thread
            .take()
            .ok_or_else(|| anyhow::anyhow!("batcher thread already joined"))?
            .join()
            .map_err(|_| anyhow::anyhow!("batcher thread panicked"))?;
        let mut batches = 0u64;
        for t in self.worker_threads.drain(..) {
            batches += t
                .join()
                .map_err(|_| anyhow::anyhow!("dispatch thread panicked"))??;
        }
        // Workers have exited, so every shadow-observation sender is gone
        // and the QoS thread's recv loop has drained; join it for the
        // controller's final report.
        let qos = match self.qos_thread.take() {
            Some(h) => Some(
                h.join()
                    .map_err(|_| anyhow::anyhow!("qos thread panicked"))??,
            ),
            None => None,
        };
        let wall = self.started.elapsed();
        let mut latency = LatencyStats::default();
        let mut per_route = PerRouteReport::default();
        for r in &collected {
            latency.push(r.latency_us);
            per_route.push(r.route, r.latency_us);
        }
        Ok(ServerReport {
            served: collected.len() as u64,
            invoked: per_route.invoked(),
            cpu: per_route.cpu.count,
            wall,
            latency,
            flushes_full: batcher_stats.flushes_full,
            flushes_timeout: batcher_stats.flushes_timeout,
            batches,
            batch_hist: batcher_stats.size_hist,
            per_route,
            qos,
        })
    }
}

/// Offline replay for `--qos-warm`: run the full QoS loop over the tree's
/// held-out `test.bin` through a native-engine dispatcher and return the
/// replay's final per-class margins.  `held_out` reuses an
/// already-loaded dataset (the table store); otherwise `test.bin` is
/// read from disk.  `Ok(None)` when the tree has no held-out set to
/// replay.  Always native (host weights are always loaded), so it works
/// under any serving `--exec`.
fn warm_start_margins(
    man: &Manifest,
    bench: &BenchManifest,
    method: Method,
    qos: &QosConfig,
    held_out: Option<&Dataset>,
) -> crate::Result<Option<Vec<f32>>> {
    let loaded;
    let ds = match held_out {
        Some(ds) => ds,
        None => {
            let path = man.dataset_path(&bench.name);
            if !path.exists() {
                return Ok(None);
            }
            loaded = Dataset::load(&path)?;
            &loaded
        }
    };
    let bank = ModelBank::load(None, man, bench, &[], &[])?;
    let d = Dispatcher::new(bench, &bank, method, ExecMode::Native)?;
    let mut replay_cfg = *qos;
    replay_cfg.warm_start = false;
    let sim = crate::qos::simulate(&d, ds, &replay_cfg, 256)?;
    Ok(Some(sim.final_margins))
}

/// Mirror one controller publish into the observability plane: per-class
/// margin gauges, margin-move / breaker counters, journal events, and
/// the open-breaker gauge.  A class forced precise publishes
/// [`MARGIN_PRECISE`] — that sentinel is how breaker transitions are
/// recognised here without reaching into controller internals.  Classes
/// beyond [`crate::obs::OBS_ROUTE_CLASSES`] still produce events; only
/// the fixed gauge array truncates.
fn note_qos_publish(obs: &Obs, prev: &[f32], cur: &[f32]) {
    let at_us = obs.journal.now_us();
    for (class, (&old, &new)) in prev.iter().zip(cur.iter()).enumerate() {
        if old == new {
            continue;
        }
        let was_open = old >= MARGIN_PRECISE;
        let is_open = new >= MARGIN_PRECISE;
        if is_open && !was_open {
            obs.metrics.breaker_trips.inc();
            obs.journal.push(Event::Breaker { class, open: true, at_us });
        } else if was_open && !is_open {
            obs.metrics.breaker_resets.inc();
            obs.journal.push(Event::Breaker { class, open: false, at_us });
        } else {
            obs.metrics.margin_moves.inc();
            obs.journal.push(Event::MarginMove { class, from: old, to: new, at_us });
        }
    }
    for (slot, &m) in obs.metrics.qos_margins.iter().zip(cur.iter()) {
        slot.set(m);
    }
    let open = cur.iter().filter(|&&m| m >= MARGIN_PRECISE).count();
    obs.metrics.open_breakers.set(open as i64);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The guard releases exactly the unsent remainder — on normal drop,
    /// on early drop (error-return path), and on panic unwind — and
    /// releases nothing once every response was sent.
    #[test]
    fn lost_guard_accounts_unsent_responses() {
        let lost = AtomicU64::new(0);

        // Fully-sent batch: no loss.
        {
            let mut g = LostGuard { lost: &lost, remaining: 3, inflight: None };
            for _ in 0..3 {
                g.remaining -= 1;
            }
        }
        assert_eq!(lost.load(Ordering::Acquire), 0);

        // Error return after 1 of 4 responses: 3 lost.
        {
            let mut g = LostGuard { lost: &lost, remaining: 4, inflight: None };
            g.remaining -= 1;
        }
        assert_eq!(lost.load(Ordering::Acquire), 3);

        // Panic unwind mid-batch still releases the count.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = LostGuard { lost: &lost, remaining: 5, inflight: None };
            g.remaining -= 2;
            panic!("worker panic (expected in test)");
        }));
        assert!(r.is_err());
        assert_eq!(lost.load(Ordering::Acquire), 6);
    }
}
