//! Threaded serving pipeline (tokio substitute: dedicated threads + mpsc).
//!
//! ```text
//! caller ──send──► ingress channel ──► batcher thread ──► batch channel
//!                                                              │
//! caller ◄──recv── egress channel ◄── dispatch worker(s) ◄─────┘
//! ```
//!
//! The batcher thread owns the `Batcher` (size-or-timeout policy); dispatch
//! workers own a `Dispatcher` each and execute classify/route/execute.
//! Responses carry per-request latency; `ServerReport` aggregates
//! throughput, latency percentiles and routing statistics.  This is the
//! end-to-end driver `examples/serve_pipeline.rs` exercises.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::config::{BatchPolicy, ExecMode, Method};
use crate::formats::{BenchManifest, Manifest};
use crate::runtime::{ModelBank, Runtime};

use super::batcher::Batcher;
use super::dispatcher::Dispatcher;
use super::metrics::LatencyStats;
use super::router::Route;

/// A request into the pipeline.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub x_raw: Vec<f32>,
    pub submitted: Instant,
}

/// A response out of the pipeline.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Normalised-space output actually served.
    pub y: Vec<f32>,
    pub route: Route,
    pub latency_us: f64,
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub policy: BatchPolicy,
    pub method: Method,
    pub exec: ExecMode,
    /// Dispatch workers.  Each owns an independent PJRT runtime + model
    /// bank (PJRT handles are thread-local by construction here), pulling
    /// batches from a shared queue — scale-out for multi-core boxes.
    pub workers: usize,
}

impl ServerConfig {
    pub fn new(policy: BatchPolicy, method: Method, exec: ExecMode) -> Self {
        ServerConfig { policy, method, exec, workers: 1 }
    }
}

/// Aggregate report after `shutdown()`.
#[derive(Clone, Debug)]
pub struct ServerReport {
    pub served: u64,
    pub invoked: u64,
    pub cpu: u64,
    pub wall: Duration,
    pub latency: LatencyStats,
    pub flushes_full: u64,
    pub flushes_timeout: u64,
    pub batches: u64,
}

impl ServerReport {
    pub fn throughput_rps(&self) -> f64 {
        if self.wall.as_secs_f64() == 0.0 {
            0.0
        } else {
            self.served as f64 / self.wall.as_secs_f64()
        }
    }

    pub fn invocation(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.invoked as f64 / self.served as f64
        }
    }
}

enum BatchMsg {
    Work(super::batcher::Batch),
    Stop,
}

/// Counts the responses a dispatch worker still OWES for the batch in
/// flight.  Dropped with a non-zero count — an error `?`-return or a panic
/// unwinding through `process_batch_into` — the shortfall lands in the
/// shared `lost` counter, so `shutdown`'s drain stops waiting for
/// responses that can never arrive (the same drop-guard discipline
/// `util::threadpool::PendingGuard` uses for its pending count).
struct LostGuard<'a> {
    lost: &'a AtomicU64,
    remaining: u64,
}

impl Drop for LostGuard<'_> {
    fn drop(&mut self) {
        if self.remaining > 0 {
            self.lost.fetch_add(self.remaining, Ordering::Release);
        }
    }
}

/// Handle to the running pipeline.
pub struct Server {
    ingress: mpsc::Sender<Option<Request>>,
    egress: mpsc::Receiver<Response>,
    batcher_thread: Option<thread::JoinHandle<(u64, u64)>>,
    worker_threads: Vec<thread::JoinHandle<crate::Result<u64>>>,
    started: Instant,
    /// Requests accepted so far; `shutdown` drains exactly
    /// `submitted - already_collected - lost` responses instead of
    /// spinning on a fixed timeout after the last one.
    submitted: AtomicU64,
    /// Responses workers failed to deliver (panic or error mid-batch),
    /// maintained by [`LostGuard`] so the drain never waits for them.
    lost: Arc<AtomicU64>,
}

impl Server {
    /// Spawn the pipeline.
    ///
    /// PJRT handles are not `Send` (the underlying client is `Rc`-based),
    /// so the dispatch worker constructs its OWN `Runtime` + `ModelBank`
    /// inside the thread from the manifest — nothing device-side ever
    /// crosses a thread boundary.
    pub fn spawn(
        man: Arc<Manifest>,
        bench: Arc<BenchManifest>,
        cfg: ServerConfig,
    ) -> crate::Result<Self> {
        let (in_tx, in_rx) = mpsc::channel::<Option<Request>>();
        let (batch_tx, batch_rx) = mpsc::channel::<BatchMsg>();
        let (out_tx, out_rx) = mpsc::channel::<Response>();
        let batch_rx = Arc::new(std::sync::Mutex::new(batch_rx));
        // Workers re-broadcast Stop so every sibling wakes and exits.
        let stop_tx = batch_tx.clone();

        let d_in = bench.n_in;
        let policy = cfg.policy;

        let batcher_thread = thread::Builder::new()
            .name("mcma-batcher".into())
            .spawn(move || {
                let mut batcher = Batcher::new(policy, d_in);
                let tick = Duration::from_micros((policy.max_wait_us / 2).max(50));
                loop {
                    match in_rx.recv_timeout(tick) {
                        Ok(Some(req)) => {
                            if let Some(b) = batcher.push(req.id, req.x_raw) {
                                let _ = batch_tx.send(BatchMsg::Work(b));
                            }
                            // Age check must ALSO run on the arrival path:
                            // a steady stream with interarrival < tick
                            // would otherwise starve the timeout branch and
                            // batches would only ever flush when full.
                            if let Some(b) = batcher.poll(Instant::now()) {
                                let _ = batch_tx.send(BatchMsg::Work(b));
                            }
                        }
                        Ok(None) => {
                            // Shutdown: drain leftovers, signal stop.
                            while let Some(b) = batcher.drain() {
                                let _ = batch_tx.send(BatchMsg::Work(b));
                            }
                            let _ = batch_tx.send(BatchMsg::Stop);
                            return (batcher.flushes_full, batcher.flushes_timeout);
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            if let Some(b) = batcher.poll(Instant::now()) {
                                let _ = batch_tx.send(BatchMsg::Work(b));
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            let _ = batch_tx.send(BatchMsg::Stop);
                            return (batcher.flushes_full, batcher.flushes_timeout);
                        }
                    }
                }
            })?;

        let lost = Arc::new(AtomicU64::new(0));
        let mut worker_threads = Vec::new();
        for w in 0..cfg.workers.max(1) {
            let man = Arc::clone(&man);
            let bench = Arc::clone(&bench);
            let batch_rx = Arc::clone(&batch_rx);
            let out_tx = out_tx.clone();
            let stop_tx = stop_tx.clone();
            let lost = Arc::clone(&lost);
            let cfg = cfg.clone();
            worker_threads.push(
                thread::Builder::new()
                    .name(format!("mcma-dispatch-{w}"))
                    .spawn(move || -> crate::Result<u64> {
                        // Build all device state thread-locally (see spawn
                        // docs): PJRT handles never cross threads.
                        let rt = match cfg.exec {
                            ExecMode::Pjrt => Some(Runtime::cpu()?),
                            ExecMode::Native | ExecMode::NativeQ8 => None,
                        };
                        let bank = ModelBank::load(
                            rt.as_ref(),
                            &man,
                            &bench,
                            &[cfg.method],
                            &man.batch_sizes,
                        )?;
                        let dispatcher =
                            Dispatcher::new(&bench, &bank, cfg.method, cfg.exec)?;
                        let mut batches = 0u64;
                        let d_out = bench.n_out;
                        // Worker-owned hot-path arena: plan, outputs and
                        // every intermediate buffer are reused across
                        // batches — steady state allocates nothing per
                        // batch beyond the response payloads.
                        let mut scratch = super::dispatcher::Scratch::new();
                        let mut plan = super::router::RoutePlan::default();
                        let mut y: Vec<f32> = Vec::new();
                        loop {
                            let msg = { batch_rx.lock().unwrap().recv() };
                            match msg {
                                Ok(BatchMsg::Work(batch)) => {
                                    batches += 1;
                                    // Every id in this batch is owed a
                                    // response; whatever is still unsent
                                    // when the guard drops (error return,
                                    // panic unwind) is counted as lost.
                                    let mut guard = LostGuard {
                                        lost: &lost,
                                        remaining: batch.ids.len() as u64,
                                    };
                                    dispatcher.process_batch_into(
                                        &batch,
                                        &mut plan,
                                        &mut y,
                                        &mut scratch,
                                    )?;
                                    let now = Instant::now();
                                    for (j, &id) in batch.ids.iter().enumerate() {
                                        let _ = out_tx.send(Response {
                                            id,
                                            y: y[j * d_out..(j + 1) * d_out].to_vec(),
                                            route: plan.routes[j],
                                            latency_us: now
                                                .duration_since(batch.enqueued[j])
                                                .as_secs_f64()
                                                * 1e6,
                                        });
                                        guard.remaining -= 1;
                                    }
                                    debug_assert_eq!(guard.remaining, 0);
                                }
                                Ok(BatchMsg::Stop) | Err(_) => {
                                    let _ = stop_tx.send(BatchMsg::Stop);
                                    return Ok(batches);
                                }
                            }
                        }
                    })?,
            );
        }

        Ok(Server {
            ingress: in_tx,
            egress: out_rx,
            batcher_thread: Some(batcher_thread),
            worker_threads,
            started: Instant::now(),
            submitted: AtomicU64::new(0),
            lost,
        })
    }

    /// Submit one request (non-blocking).
    pub fn submit(&self, id: u64, x_raw: Vec<f32>) -> crate::Result<()> {
        self.ingress
            .send(Some(Request { id, x_raw, submitted: Instant::now() }))
            .map_err(|_| anyhow::anyhow!("server ingress closed"))?;
        self.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Receive one response (blocking with timeout).
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        self.egress.recv_timeout(timeout).ok()
    }

    /// Stop accepting, drain, join, and report.
    pub fn shutdown(mut self, mut collected: Vec<Response>) -> crate::Result<ServerReport> {
        let _ = self.ingress.send(None);
        // Drain exactly the outstanding responses: submitted minus already
        // received minus the ones workers reported lost (drop-guard on an
        // error return or panic mid-batch, see `LostGuard`).  `lost` is
        // re-read every iteration so a worker failing DURING the drain
        // releases it immediately instead of stranding it on the timeout.
        // The 2 s budget stays only as a last-resort net for responses
        // that vanish without being counted (e.g. a worker wedged before
        // its batch was guarded); it resets on progress, so a healthy
        // shutdown never waits on it.
        let submitted = self.submitted.load(Ordering::Relaxed);
        let mut deadline = Instant::now() + Duration::from_millis(2000);
        loop {
            let lost = self.lost.load(Ordering::Acquire);
            let outstanding =
                submitted.saturating_sub(collected.len() as u64).saturating_sub(lost);
            if outstanding == 0 {
                break;
            }
            match self.egress.recv_timeout(Duration::from_millis(50)) {
                Ok(r) => {
                    collected.push(r);
                    deadline = Instant::now() + Duration::from_millis(2000);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if Instant::now() >= deadline {
                        break;
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        let (full, timeout) = self
            .batcher_thread
            .take()
            .unwrap()
            .join()
            .map_err(|_| anyhow::anyhow!("batcher thread panicked"))?;
        let mut batches = 0u64;
        for t in self.worker_threads.drain(..) {
            batches += t
                .join()
                .map_err(|_| anyhow::anyhow!("dispatch thread panicked"))??;
        }
        let wall = self.started.elapsed();
        let mut latency = LatencyStats::default();
        let mut invoked = 0u64;
        let mut cpu = 0u64;
        for r in &collected {
            latency.push(r.latency_us);
            match r.route {
                Route::Approx(_) => invoked += 1,
                Route::Cpu => cpu += 1,
            }
        }
        Ok(ServerReport {
            served: collected.len() as u64,
            invoked,
            cpu,
            wall,
            latency,
            flushes_full: full,
            flushes_timeout: timeout,
            batches,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The guard releases exactly the unsent remainder — on normal drop,
    /// on early drop (error-return path), and on panic unwind — and
    /// releases nothing once every response was sent.
    #[test]
    fn lost_guard_accounts_unsent_responses() {
        let lost = AtomicU64::new(0);

        // Fully-sent batch: no loss.
        {
            let mut g = LostGuard { lost: &lost, remaining: 3 };
            for _ in 0..3 {
                g.remaining -= 1;
            }
        }
        assert_eq!(lost.load(Ordering::Acquire), 0);

        // Error return after 1 of 4 responses: 3 lost.
        {
            let mut g = LostGuard { lost: &lost, remaining: 4 };
            g.remaining -= 1;
        }
        assert_eq!(lost.load(Ordering::Acquire), 3);

        // Panic unwind mid-batch still releases the count.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = LostGuard { lost: &lost, remaining: 5 };
            g.remaining -= 2;
            panic!("worker panic (expected in test)");
        }));
        assert!(r.is_err());
        assert_eq!(lost.load(Ordering::Acquire), 6);
    }
}
