//! Run metrics — the quantities every figure is computed from.
//!
//! Definitions (shared with `python/compile/train.py`):
//! * **invocation** — fraction of samples the classifier routes to any
//!   approximator (the paper's headline metric);
//! * **error / RMSE** — RMSE (normalised output space) over the *invoked*
//!   samples only; the paper reports it normalised to the error bound;
//! * **true invocation** — invoked AND actually under the bound (the "AC"
//!   true positives of Fig. 11).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::stats;

use super::router::{Route, RoutePlan};

/// Confusion-style quadrant counts of Fig. 11 (A = actually safe,
/// C = classifier accepts).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Quadrants {
    pub ac: usize,   // true positive: invoked & under bound
    pub n_ac: usize, // false positive: invoked & over bound (nAC)
    pub a_nc: usize, // false negative: rejected but was safe (AnC)
    pub nanc: usize, // true negative
}

/// Aggregate metrics for one (benchmark, method) run.
#[derive(Clone, Debug)]
pub struct RunMetrics {
    pub bench: String,
    pub method: String,
    pub n: usize,
    pub invoked: usize,
    pub per_class: Vec<usize>,
    pub cpu_count: usize,
    /// RMSE over invoked samples (normalised space).
    pub rmse_invoked: f64,
    /// rmse_invoked / error_bound (the paper's Fig. 7b y-axis).
    pub rmse_over_bound: f64,
    pub quadrants: Quadrants,
    /// Weight-switch statistics from the dispatcher's WeightCache.
    pub weight_switches: u64,
    pub weight_refill_cycles: u64,
}

impl RunMetrics {
    /// Build from per-sample routes and errors.
    ///
    /// `err[i]` is sample i's RMSE vs the precise output in normalised
    /// space, computed against the *approximator that served it* (0 for
    /// CPU-served samples, which are exact); `err_if_invoked[i]` is the
    /// error the sample WOULD have under its best approximator — used for
    /// the A/nA split of rejected samples (Fig. 11's AnC category).
    pub fn from_routes(
        bench: &str,
        method: &str,
        routes: &[Route],
        err: &[f64],
        err_if_invoked: &[f64],
        bound: f64,
        n_approx: usize,
    ) -> Self {
        assert_eq!(routes.len(), err.len());
        assert_eq!(routes.len(), err_if_invoked.len());
        let mut per_class = vec![0usize; n_approx];
        let mut cpu_count = 0usize;
        let mut invoked_errs = Vec::new();
        let mut q = Quadrants::default();
        for (i, r) in routes.iter().enumerate() {
            match r {
                Route::Approx(k) => {
                    per_class[*k] += 1;
                    invoked_errs.push(err[i]);
                    if err[i] <= bound {
                        q.ac += 1;
                    } else {
                        q.n_ac += 1;
                    }
                }
                Route::Cpu => {
                    cpu_count += 1;
                    if err_if_invoked[i] <= bound {
                        q.a_nc += 1;
                    } else {
                        q.nanc += 1;
                    }
                }
            }
        }
        let rmse = stats::rms(&invoked_errs);
        RunMetrics {
            bench: bench.to_string(),
            method: method.to_string(),
            n: routes.len(),
            invoked: routes.len() - cpu_count,
            per_class,
            cpu_count,
            rmse_invoked: rmse,
            rmse_over_bound: if bound > 0.0 { rmse / bound } else { 0.0 },
            quadrants: q,
            weight_switches: 0,
            weight_refill_cycles: 0,
        }
    }

    pub fn invocation(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.invoked as f64 / self.n as f64
        }
    }

    pub fn true_invocation(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.quadrants.ac as f64 / self.n as f64
        }
    }

    /// Classifier recall on safe samples (paper: "high recall" of MCMA).
    pub fn recall(&self) -> f64 {
        let safe = self.quadrants.ac + self.quadrants.a_nc;
        if safe == 0 {
            0.0
        } else {
            self.quadrants.ac as f64 / safe as f64
        }
    }
}

/// Live per-route counters shared across the server's dispatch workers
/// and the QoS thread (lock-free; relaxed adds once per batch, not per
/// sample).  `invoked[k]` counts samples served by approximator `k`,
/// `cpu` the precise-path rejects, `shadow[k]` the shadow observations
/// the QoS controller ingested for class `k`.  Snapshots feed both
/// `ServerReport`'s per-route section and the controller's own report.
#[derive(Debug)]
pub struct ClassCounters {
    invoked: Vec<AtomicU64>,
    cpu: AtomicU64,
    shadow: Vec<AtomicU64>,
    /// Shadow-selected observations dropped because the bounded
    /// observation queue was full (the estimator saw a thinner sample,
    /// not a biased one — drops are backpressure, not selection).
    shadow_dropped: AtomicU64,
}

impl ClassCounters {
    pub fn new(n_approx: usize) -> Self {
        ClassCounters {
            invoked: (0..n_approx).map(|_| AtomicU64::new(0)).collect(),
            cpu: AtomicU64::new(0),
            shadow: (0..n_approx).map(|_| AtomicU64::new(0)).collect(),
            shadow_dropped: AtomicU64::new(0),
        }
    }

    pub fn n_approx(&self) -> usize {
        self.invoked.len()
    }

    /// Account one routed batch (a handful of adds per batch, off the
    /// per-sample path).
    pub fn record_plan(&self, plan: &RoutePlan) {
        for (k, g) in plan.groups.iter().enumerate() {
            if !g.is_empty() {
                if let Some(c) = self.invoked.get(k) {
                    c.fetch_add(g.len() as u64, Ordering::Relaxed);
                }
            }
        }
        if !plan.cpu.is_empty() {
            self.cpu.fetch_add(plan.cpu.len() as u64, Ordering::Relaxed);
        }
    }

    /// Account one shadow observation for class `k`.
    pub fn record_shadow(&self, k: usize) {
        if let Some(c) = self.shadow.get(k) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Account one shadow observation lost to queue backpressure.
    pub fn record_shadow_dropped(&self) {
        self.shadow_dropped.fetch_add(1, Ordering::Relaxed);
    }

    pub fn shadow_dropped(&self) -> u64 {
        self.shadow_dropped.load(Ordering::Relaxed)
    }

    pub fn snapshot_invoked(&self) -> Vec<u64> {
        self.invoked.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    pub fn snapshot_shadow(&self) -> Vec<u64> {
        self.shadow.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    pub fn cpu(&self) -> u64 {
        self.cpu.load(Ordering::Relaxed)
    }
}

/// One route destination's share of a serving run: response count +
/// latency distribution.
#[derive(Clone, Debug, Default)]
pub struct RouteClassStats {
    pub count: u64,
    pub latency: LatencyStats,
}

/// Per-route (per-approximator-class + CPU) breakdown of a serving run,
/// aggregated into `ServerReport` at shutdown — the per-class view the
/// global `served`/`invoked` numbers hide.
#[derive(Clone, Debug, Default)]
pub struct PerRouteReport {
    /// Indexed by approximator class; grown on demand.
    pub classes: Vec<RouteClassStats>,
    pub cpu: RouteClassStats,
}

impl PerRouteReport {
    pub fn push(&mut self, route: Route, latency_us: f64) {
        let slot = match route {
            Route::Approx(k) => {
                if self.classes.len() <= k {
                    self.classes.resize_with(k + 1, RouteClassStats::default);
                }
                &mut self.classes[k]
            }
            Route::Cpu => &mut self.cpu,
        };
        slot.count += 1;
        slot.latency.push(latency_us);
    }

    pub fn total(&self) -> u64 {
        self.cpu.count + self.classes.iter().map(|c| c.count).sum::<u64>()
    }

    pub fn invoked(&self) -> u64 {
        self.classes.iter().map(|c| c.count).sum()
    }
}

/// Latency aggregates for the online server (microseconds).
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    pub samples: Vec<f64>,
}

impl LatencyStats {
    pub fn push(&mut self, us: f64) {
        self.samples.push(us);
    }

    pub fn p50(&self) -> f64 {
        stats::percentile(&self.samples, 50.0)
    }

    pub fn p95(&self) -> f64 {
        stats::percentile(&self.samples, 95.0)
    }

    pub fn p99(&self) -> f64 {
        stats::percentile(&self.samples, 99.0)
    }

    pub fn p999(&self) -> f64 {
        stats::percentile(&self.samples, 99.9)
    }

    /// Arbitrary percentile (linear interpolation), `p` in `[0, 100]`.
    pub fn percentile(&self, p: f64) -> f64 {
        stats::percentile(&self.samples, p)
    }

    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadrants_and_rates() {
        let routes = [Route::Approx(0), Route::Approx(1), Route::Cpu, Route::Cpu];
        let err = [0.01, 0.20, 0.0, 0.0];
        let err_if = [0.01, 0.20, 0.02, 0.50];
        let m = RunMetrics::from_routes("b", "m", &routes, &err, &err_if, 0.05, 2);
        assert_eq!(m.quadrants, Quadrants { ac: 1, n_ac: 1, a_nc: 1, nanc: 1 });
        assert_eq!(m.invocation(), 0.5);
        assert_eq!(m.true_invocation(), 0.25);
        assert_eq!(m.recall(), 0.5);
        assert_eq!(m.per_class, vec![1, 1]);
        let want = ((0.01f64.powi(2) + 0.2f64.powi(2)) / 2.0).sqrt();
        assert!((m.rmse_invoked - want).abs() < 1e-12);
        assert!((m.rmse_over_bound - want / 0.05).abs() < 1e-12);
    }

    #[test]
    fn empty_run_is_zero() {
        let m = RunMetrics::from_routes("b", "m", &[], &[], &[], 0.05, 1);
        assert_eq!(m.invocation(), 0.0);
        assert_eq!(m.rmse_invoked, 0.0);
    }

    #[test]
    fn class_counters_record_plans_and_shadows() {
        let c = ClassCounters::new(2);
        let plan = super::super::router::plan_routes(&[0, 1, 2, 0, 1, 1], 2);
        c.record_plan(&plan);
        c.record_plan(&plan);
        assert_eq!(c.snapshot_invoked(), vec![4, 6]);
        assert_eq!(c.cpu(), 2);
        c.record_shadow(1);
        c.record_shadow(1);
        c.record_shadow(9); // out of range: ignored, not a panic
        assert_eq!(c.snapshot_shadow(), vec![0, 2]);
        assert_eq!(c.n_approx(), 2);
        assert_eq!(c.shadow_dropped(), 0);
        c.record_shadow_dropped();
        assert_eq!(c.shadow_dropped(), 1);
    }

    #[test]
    fn per_route_report_partitions_responses() {
        let mut r = PerRouteReport::default();
        r.push(Route::Approx(0), 10.0);
        r.push(Route::Approx(2), 20.0); // grows past the gap
        r.push(Route::Cpu, 30.0);
        r.push(Route::Approx(0), 40.0);
        assert_eq!(r.classes.len(), 3);
        assert_eq!(r.classes[0].count, 2);
        assert_eq!(r.classes[1].count, 0);
        assert_eq!(r.classes[2].count, 1);
        assert_eq!(r.cpu.count, 1);
        assert_eq!(r.total(), 4);
        assert_eq!(r.invoked(), 3);
        assert!((r.classes[0].latency.mean() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn latency_percentiles() {
        let mut l = LatencyStats::default();
        for i in 1..=100 {
            l.push(i as f64);
        }
        assert!((l.p50() - 50.5).abs() < 1.0);
        assert!(l.p99() > 98.0);
        assert!((l.mean() - 50.5).abs() < 1e-9);
    }
}
