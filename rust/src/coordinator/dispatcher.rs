//! The dispatcher: classify -> route -> execute (approximators on the
//! PJRT "NPU", rejects on the precise CPU path).
//!
//! One `Dispatcher` serves one (benchmark, method) pair.  It is the
//! synchronous core used both by the offline eval drivers (whole-dataset
//! runs for the figures) and by the online `Server` (per-batch).
//!
//! ## Hot-path memory discipline (§Perf L3)
//!
//! Every per-batch buffer — normalised inputs, classifier logits, gather
//! panels, GEMM activation panels, served outputs — lives in a caller-owned
//! [`Scratch`] arena and a reusable [`RoutePlan`].  The `*_into` methods
//! (`normalize_into`, `plan_into`, `execute_plan_into`,
//! `process_batch_into`) clear-and-refill those buffers, so a steady-state
//! batch performs **zero heap allocations**; the original allocating
//! methods remain as thin wrappers for offline/one-shot callers.  Native
//! forwards run through the bank's pre-packed tiled GEMM nets
//! (`nn::gemm::PackedMlp`), and whole-dataset native batches shard across
//! cores via `util::threadpool::parallel_map`.

use crate::config::{ExecMode, Method};
use crate::formats::{BenchManifest, Dataset};
use crate::nn::{self, GemmScratch, PackedMlp, PackedMlpQ8, QGemmScratch};
use crate::runtime::{ModelBank, Role};
use crate::util::threadpool;
use crate::workload::PreciseProxy;

use super::batcher::Batch;
use super::metrics::RunMetrics;
use super::router::{self, Route, RoutePlan};
use super::weight_cache::WeightCache;

/// Native batches at least this tall are sharded across cores; below it a
/// single core's tiled kernel wins (thread fan-out costs more than it
/// saves on a 256-row serving batch).
const NATIVE_PAR_MIN_ROWS: usize = 2048;

/// Full offline evaluation result for one (benchmark, method, dataset).
pub struct EvalOutput {
    pub plan: RoutePlan,
    /// Per-sample error of the value actually served (0 for CPU-served).
    pub err: Vec<f64>,
    /// Per-sample error under the method's best approximator — defines the
    /// "actually safe" (A) split for rejected samples (Fig. 11).
    pub err_if_invoked: Vec<f64>,
    /// Served outputs, row-major `(n, d_out)` normalised space.
    pub y_served: Vec<f32>,
    pub metrics: RunMetrics,
    pub weight_cache: WeightCache,
    /// Mean k-d tree records visited per precise-path query during THIS
    /// run, when the precise path was a [`crate::workload::NearestLookup`]
    /// and at least one sample took it.  Feeds
    /// [`crate::workload::precise_cost_cycles_measured`] so the NPU model
    /// charges the measured sublinear lookup cost, not a full-scan bound.
    pub precise_visits_per_query: Option<f64>,
}

/// Routing policy — how classifier outputs become destinations.
///
/// `Argmax` is the paper's MCMA ("the approximator with the highest
/// confidence consumes the input sample").  The other two are extensions
/// evaluated in `benches/ablations.rs`:
/// * `Confidence(t)` — route to the argmax approximator only when its
///   softmax probability exceeds `t`, else CPU: trades invocation for
///   quality with no retraining (a runtime quality knob the paper's §II.A
///   related work tunes statically).
/// * `Oracle` — route by the true lowest-error approximator (requires
///   ground truth; upper-bounds what any classifier could achieve and
///   quantifies the remaining classifier headroom).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RouterPolicy {
    Argmax,
    Confidence(f32),
    Oracle,
}

/// Reusable per-batch buffers for the dispatch hot path.  One `Scratch`
/// per dispatching thread; buffers grow to the workload's high-water mark
/// and then stop allocating.
#[derive(Debug, Default)]
pub struct Scratch {
    /// Normalised inputs (`process_batch_into`).
    x_norm: Vec<f32>,
    /// Classifier logits.
    logits: Vec<f32>,
    /// Per-sample argmax classes.
    classes: Vec<usize>,
    /// Gathered rows for one route group.
    gather: Vec<f32>,
    /// Forward output for one route group.
    group_out: Vec<f32>,
    /// Raw (denormalised) precise output for one sample.
    raw_out: Vec<f64>,
    /// Activation panels for the tiled GEMM layer chain.
    gemm: GemmScratch,
    /// Quantized-panel + activation buffers for the int8 engine.
    qgemm: QGemmScratch,
}

impl Scratch {
    pub fn new() -> Self {
        Scratch::default()
    }

    /// Capacities of every internal buffer.  The zero-allocation steady
    /// state is observable as this signature going flat across batches.
    pub fn capacity_signature(&self) -> Vec<usize> {
        vec![
            self.x_norm.capacity(),
            self.logits.capacity(),
            self.classes.capacity(),
            self.gather.capacity(),
            self.group_out.capacity(),
            self.raw_out.capacity(),
            self.gemm.capacity(),
            self.qgemm.capacity(),
        ]
    }
}

/// Synchronous classify/route/execute engine for one (bench, method).
pub struct Dispatcher<'a> {
    pub bench: &'a BenchManifest,
    pub bank: &'a ModelBank,
    /// The precise path: the registered benchmark function for synthetic
    /// workloads; a held-out nearest-record lookup or reject-with-error
    /// for table workloads (no oracle exists at runtime — see
    /// `crate::workload::PreciseProxy`).
    pub precise: PreciseProxy,
    pub method: Method,
    pub exec: ExecMode,
    pub npu_cfg: crate::config::NpuConfig,
    pub policy: RouterPolicy,
    /// Model the NPU executing each batch class-sorted (groups in index
    /// order, then CPU) instead of in arrival order, collapsing §III.D
    /// Case-3 weight refills to at most one per approximator per batch.
    /// The native engines already execute group-by-group; this flag makes
    /// the weight-switch accounting follow the same order.
    pub route_sorted: bool,
    /// Live metrics sink (the serving pipeline installs one via
    /// [`Self::with_obs`]): per-route-class execute timing and precise-
    /// fallback timing land here straight from the execute loops.  `None`
    /// (offline eval, tests) records nothing and never reads the clock.
    pub obs: Option<std::sync::Arc<crate::obs::Registry>>,
}

impl<'a> Dispatcher<'a> {
    pub fn new(
        bench: &'a BenchManifest,
        bank: &'a ModelBank,
        method: Method,
        exec: ExecMode,
    ) -> crate::Result<Self> {
        anyhow::ensure!(
            bank.has_method(method),
            "artifacts for {} lack method {}",
            bench.name,
            method.key()
        );
        Ok(Dispatcher {
            bench,
            bank,
            precise: PreciseProxy::for_bench(bench)?,
            method,
            exec,
            npu_cfg: crate::config::NpuConfig::default(),
            policy: RouterPolicy::Argmax,
            route_sorted: false,
            obs: None,
        })
    }

    /// Builder-style routing-policy override (extensions; see RouterPolicy).
    pub fn with_policy(mut self, policy: RouterPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Builder-style precise-path override — how a table workload's
    /// server installs the held-out lookup proxy (or keeps the default
    /// reject-with-error).
    pub fn with_precise_proxy(mut self, proxy: PreciseProxy) -> Self {
        self.precise = proxy;
        self
    }

    /// Does this dispatcher have a real runtime oracle (a registered
    /// precise function or an installed lookup store)?  `false` means any
    /// precise-routed sample is a hard error until a proxy is installed;
    /// whole-dataset paths substitute the dataset's own labels instead.
    pub fn has_runtime_oracle(&self) -> bool {
        !self.precise.is_reject()
    }

    /// Builder-style route-sorted execution toggle (see `route_sorted`).
    pub fn with_route_sorted(mut self, sorted: bool) -> Self {
        self.route_sorted = sorted;
        self
    }

    /// Builder-style live-metrics sink (see the `obs` field).
    pub fn with_obs(mut self, obs: std::sync::Arc<crate::obs::Registry>) -> Self {
        self.obs = Some(obs);
        self
    }

    /// Number of approximators this method has.
    pub fn n_approx(&self) -> usize {
        self.bank.n_approx(self.method)
    }

    /// Normalise a raw-input batch into NN space.
    pub fn normalize(&self, x_raw: &[f32], n: usize) -> Vec<f32> {
        let mut out = Vec::new();
        self.normalize_into(x_raw, n, &mut out);
        out
    }

    /// [`Self::normalize`] into a reusable buffer (cleared, capacity kept).
    pub fn normalize_into(&self, x_raw: &[f32], n: usize, out: &mut Vec<f32>) {
        let d = self.bench.n_in;
        out.clear();
        out.resize(n * d, 0.0);
        for i in 0..n {
            self.bench
                .normalize_x_into(&x_raw[i * d..(i + 1) * d], &mut out[i * d..(i + 1) * d]);
        }
    }

    /// Forward `n` rows through (role, idx), batched through the chosen
    /// engine.  Chunks through the largest compiled batch on PJRT.
    pub fn forward(
        &self,
        role: Role,
        idx: usize,
        x_norm: &[f32],
        n: usize,
    ) -> crate::Result<Vec<f32>> {
        let mut gemm = GemmScratch::new();
        let mut qgemm = QGemmScratch::new();
        let mut out = Vec::new();
        self.forward_into(role, idx, x_norm, n, &mut gemm, &mut qgemm, &mut out)?;
        Ok(out)
    }

    /// [`Self::forward`] into reusable buffers.  Native mode runs the
    /// pre-packed tiled GEMM engine (f32 or the int8 quantized twin,
    /// sharded across cores for tall panels); PJRT chunks through the
    /// largest compiled batch.
    #[allow(clippy::too_many_arguments)]
    fn forward_into(
        &self,
        role: Role,
        idx: usize,
        x_norm: &[f32],
        n: usize,
        gemm: &mut GemmScratch,
        qgemm: &mut QGemmScratch,
        out: &mut Vec<f32>,
    ) -> crate::Result<()> {
        match self.exec {
            ExecMode::Native => {
                let packed = self.bank.host_packed(self.method, role, idx)?;
                out.clear();
                out.resize(n * packed.n_out(), 0.0);
                let threads = threadpool::default_parallelism();
                if n >= NATIVE_PAR_MIN_ROWS && threads > 1 {
                    forward_native_parallel(packed, x_norm, n, threads, out);
                } else {
                    packed.forward_batch_to(x_norm, n, gemm, out);
                }
                Ok(())
            }
            ExecMode::NativeQ8 => {
                let packed = self.bank.host_packed_q8(self.method, role, idx)?;
                out.clear();
                out.resize(n * packed.n_out(), 0.0);
                // Tall panels ALWAYS take the fixed-block sharded path
                // (even on one core): activation scales are per panel, so
                // the block split must depend only on n — never on the
                // machine's core count — for reproducible q8 outputs.
                if n >= NATIVE_PAR_MIN_ROWS {
                    let threads = threadpool::default_parallelism();
                    forward_native_parallel_q8(packed, x_norm, n, threads, out);
                } else {
                    packed.forward_batch_to(x_norm, n, qgemm, out);
                }
                Ok(())
            }
            ExecMode::Pjrt => {
                let d_in = x_norm.len() / n.max(1);
                let b = self.bank.best_batch(role, n);
                let exe = self.bank.exe(role, b)?;
                let weights = self.bank.weight_set(self.method, role, idx)?;
                out.clear();
                out.reserve(n * exe.n_out);
                let mut i = 0;
                while i < n {
                    let take = (n - i).min(b);
                    let chunk = &x_norm[i * d_in..(i + take) * d_in];
                    out.extend(exe.run(chunk, take, weights)?);
                    i += take;
                }
                Ok(())
            }
        }
    }

    /// Classify a normalised batch into routes.
    pub fn plan(&self, x_norm: &[f32], n: usize) -> crate::Result<RoutePlan> {
        let mut plan = RoutePlan::default();
        let mut scratch = Scratch::new();
        self.plan_into(x_norm, n, &mut plan, &mut scratch)?;
        Ok(plan)
    }

    /// [`Self::plan`] into a reusable plan + scratch (allocation-free in
    /// steady state for the non-cascade methods; MCCA's stage gathers
    /// still allocate).
    pub fn plan_into(
        &self,
        x_norm: &[f32],
        n: usize,
        plan: &mut RoutePlan,
        scratch: &mut Scratch,
    ) -> crate::Result<()> {
        self.plan_with_margins_into(x_norm, n, None, plan, scratch)
    }

    /// [`Self::plan_into`] with optional per-class confidence margins —
    /// the QoS controller's entry into routing.  `margins[k]` is the
    /// minimum softmax confidence approximator `k` requires
    /// (`router::apply_margins`); `None` (or all zeros) is the paper's
    /// pure-argmax routing.  Margins compose with the static
    /// `RouterPolicy::Confidence` threshold: a sample must clear both.
    pub fn plan_with_margins_into(
        &self,
        x_norm: &[f32],
        n: usize,
        margins: Option<&[f32]>,
        plan: &mut RoutePlan,
        scratch: &mut Scratch,
    ) -> crate::Result<()> {
        match self.method {
            Method::Mcca => {
                anyhow::ensure!(
                    margins.is_none(),
                    "per-class QoS margins are confidence-based and do not \
                     apply to the MCCA cascade"
                );
                *plan = self.plan_cascade(x_norm, n)?;
                Ok(())
            }
            m => {
                let (role, n_classes) = if m.is_mcma() {
                    (Role::ClfN, self.bank.host_mlp(m, Role::ClfN, 0)?.n_out())
                } else {
                    (Role::Clf2, 2)
                };
                let Scratch { logits, classes, gemm, qgemm, .. } = scratch;
                self.forward_into(role, 0, x_norm, n, gemm, qgemm, logits)?;
                nn::argmax_rows_into(logits, n, n_classes, classes);
                let n_approx = if m.is_mcma() { n_classes - 1 } else { 1 };
                if let RouterPolicy::Confidence(tau) = self.policy {
                    // Demote low-confidence accepts to the CPU class.
                    for (i, c) in classes.iter_mut().enumerate() {
                        if *c < n_approx {
                            let row = &logits[i * n_classes..(i + 1) * n_classes];
                            if router::softmax_prob(row, *c) < tau {
                                *c = n_approx; // nC
                            }
                        }
                    }
                }
                if let Some(margins) = margins {
                    router::apply_margins(logits, n_classes, n_approx, margins, classes);
                }
                router::plan_routes_into(classes, n_approx, plan);
                Ok(())
            }
        }
    }

    /// Oracle routing (extension): assign each sample to its true
    /// lowest-error approximator, CPU when even the best violates the
    /// bound.  Upper-bounds any classifier.
    pub fn plan_oracle(&self, ds: &Dataset) -> crate::Result<RoutePlan> {
        let matrix = self.error_matrix(ds)?;
        Ok(self.oracle_plan_from_matrix(&matrix, ds.n))
    }

    /// Oracle plan from an already-computed per-approximator error matrix.
    fn oracle_plan_from_matrix(&self, matrix: &[Vec<f64>], n: usize) -> RoutePlan {
        let n_approx = self.n_approx();
        let classes: Vec<usize> = (0..n)
            .map(|i| {
                let (mut best_k, mut best_e) = (0usize, f64::INFINITY);
                for (k, row) in matrix.iter().enumerate() {
                    if row[i] < best_e {
                        best_e = row[i];
                        best_k = k;
                    }
                }
                if best_e <= self.bench.error_bound { best_k } else { n_approx }
            })
            .collect();
        router::plan_routes(&classes, n_approx)
    }

    /// MCCA: cascade of binary stages (paper §III.B / Fig. 3b).
    fn plan_cascade(&self, x_norm: &[f32], n: usize) -> crate::Result<RoutePlan> {
        let d = self.bench.n_in;
        let stages = self.bank.host.get(self.method.key())?.classifiers.len();
        let mut plan = router::all_cpu_plan(n, stages);
        plan.cpu.clear();
        let mut remaining: Vec<usize> = (0..n).collect();
        for s in 0..stages {
            if remaining.is_empty() {
                break;
            }
            // Gather the still-unrouted rows into a dense buffer.
            let mut xs = Vec::with_capacity(remaining.len() * d);
            for &i in &remaining {
                xs.extend_from_slice(&x_norm[i * d..(i + 1) * d]);
            }
            let logits = self.forward(Role::Clf2, s, &xs, remaining.len())?;
            let classes = nn::argmax_rows(&logits, remaining.len(), 2);
            let accept: Vec<bool> = classes.iter().map(|&c| c == 0).collect();
            remaining = router::cascade_stage(&mut plan, &remaining, &accept, s);
        }
        plan.cpu = remaining;
        Ok(plan)
    }

    /// Execute a routed plan: approximators per group, precise CPU for the
    /// rest.  Returns served outputs `(n, d_out)` in normalised space.
    pub fn execute_plan(
        &self,
        plan: &RoutePlan,
        x_norm: &[f32],
        x_raw: &[f32],
        n: usize,
    ) -> crate::Result<Vec<f32>> {
        let mut y = Vec::new();
        let mut scratch = Scratch::new();
        self.execute_plan_into(plan, x_norm, x_raw, n, &mut y, &mut scratch)?;
        Ok(y)
    }

    /// [`Self::execute_plan`] into reusable buffers — the serving hot path.
    /// Gather panels, group outputs and GEMM panels all come from
    /// `scratch`; zero heap allocations once warm.
    pub fn execute_plan_into(
        &self,
        plan: &RoutePlan,
        x_norm: &[f32],
        x_raw: &[f32],
        n: usize,
        y: &mut Vec<f32>,
        scratch: &mut Scratch,
    ) -> crate::Result<()> {
        self.execute_plan_with_proxy_into(plan, x_norm, x_raw, n, None, y, scratch)
    }

    /// [`Self::execute_plan_into`] with a precise-proxy override for the
    /// CPU path (`None` = this dispatcher's own proxy).  Whole-dataset
    /// callers that hold ground-truth labels (offline eval, the QoS
    /// replay) use this to serve rejected samples from the dataset itself
    /// when the workload has no runtime oracle.
    pub fn execute_plan_with_proxy_into(
        &self,
        plan: &RoutePlan,
        x_norm: &[f32],
        x_raw: &[f32],
        n: usize,
        proxy: Option<&PreciseProxy>,
        y: &mut Vec<f32>,
        scratch: &mut Scratch,
    ) -> crate::Result<()> {
        let precise = proxy.unwrap_or(&self.precise);
        let d_in = self.bench.n_in;
        let d_out = self.bench.n_out;
        y.clear();
        y.resize(n * d_out, 0.0);

        let Scratch { gather, group_out, gemm, qgemm, raw_out, .. } = scratch;
        for (k, group) in plan.groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            gather.clear();
            gather.reserve(group.len() * d_in);
            for &i in group.iter() {
                gather.extend_from_slice(&x_norm[i * d_in..(i + 1) * d_in]);
            }
            // Clock reads gated on the sink: offline eval pays nothing.
            let t0 = self.obs.as_ref().map(|_| std::time::Instant::now());
            self.forward_into(Role::Approx, k, gather, group.len(), gemm, qgemm, group_out)?;
            if let (Some(obs), Some(t0)) = (&self.obs, t0) {
                obs.record_route_execute(k, t0.elapsed().as_micros() as u64);
            }
            for (j, &i) in group.iter().enumerate() {
                y[i * d_out..(i + 1) * d_out]
                    .copy_from_slice(&group_out[j * d_out..(j + 1) * d_out]);
            }
        }

        // Precise CPU path for rejected samples (through the proxy: the
        // registered function, a held-out lookup, or a hard reject).
        raw_out.clear();
        raw_out.resize(d_out, 0.0);
        let t_cpu = match &self.obs {
            Some(_) if !plan.cpu.is_empty() => Some(std::time::Instant::now()),
            _ => None,
        };
        for &i in &plan.cpu {
            precise.serve_norm_into(
                self.bench,
                &x_raw[i * d_in..(i + 1) * d_in],
                raw_out,
                &mut y[i * d_out..(i + 1) * d_out],
            )?;
        }
        if let (Some(obs), Some(t_cpu)) = (&self.obs, t_cpu) {
            obs.stage_fallback.record(t_cpu.elapsed().as_micros() as u64);
        }
        Ok(())
    }

    /// Per-approximator error of EVERY sample (rows: approximator, cols:
    /// sample) — feeds Figs. 10/11 and the `err_if_invoked` split.
    pub fn error_matrix(&self, ds: &Dataset) -> crate::Result<Vec<Vec<f64>>> {
        let x_norm = self.normalize(&ds.x_raw, ds.n);
        self.error_matrix_norm(ds, &x_norm)
    }

    /// [`Self::error_matrix`] over an already-normalised input panel —
    /// lets `run_dataset` normalise the dataset exactly once.
    pub fn error_matrix_norm(
        &self,
        ds: &Dataset,
        x_norm: &[f32],
    ) -> crate::Result<Vec<Vec<f64>>> {
        let mut rows = Vec::with_capacity(self.n_approx());
        for k in 0..self.n_approx() {
            let pred = self.forward(Role::Approx, k, x_norm, ds.n)?;
            rows.push(nn::per_sample_rmse(&pred, &ds.y_norm, ds.n, self.bench.n_out));
        }
        Ok(rows)
    }

    /// Whole-dataset offline evaluation (the engine behind every figure).
    ///
    /// Normalises the dataset once and computes the per-approximator error
    /// matrix once, sharing both between routing (Oracle policy), serving
    /// and the `err_if_invoked` split.
    pub fn run_dataset(&self, ds: &Dataset) -> crate::Result<EvalOutput> {
        let mut scratch = Scratch::new();
        let x_norm = self.normalize(&ds.x_raw, ds.n);

        // "Would-be" error for every sample: min over this method's
        // approximators (defines the A/nA ground-truth split).
        let matrix = self.error_matrix_norm(ds, &x_norm)?;
        let err_if_invoked: Vec<f64> = (0..ds.n)
            .map(|i| {
                matrix
                    .iter()
                    .map(|row| row[i])
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();

        let mut plan = RoutePlan::default();
        if self.policy == RouterPolicy::Oracle {
            plan = self.oracle_plan_from_matrix(&matrix, ds.n);
        } else {
            self.plan_into(&x_norm, ds.n, &mut plan, &mut scratch)?;
        }
        // Oracle-less workloads serve rejected samples from the dataset's
        // own labels (a nearest-record lookup over `ds` is exact on its
        // own rows) — the same "CPU-served is precise by construction"
        // semantics the registered functions give.
        let lookup;
        let proxy = if self.has_runtime_oracle() {
            None
        } else {
            lookup = PreciseProxy::lookup_from(self.bench, ds);
            Some(&lookup)
        };
        // Snapshot the active lookup's visit counters around the run so the
        // measured per-query cost covers exactly THIS dataset's precise
        // traffic (the store may be shared with other runs).
        let active_lookup = proxy.unwrap_or(&self.precise).lookup();
        let stats_before = active_lookup.map(|l| l.query_stats());
        let mut y_served = Vec::new();
        self.execute_plan_with_proxy_into(
            &plan,
            &x_norm,
            &ds.x_raw,
            ds.n,
            proxy,
            &mut y_served,
            &mut scratch,
        )?;
        let precise_visits_per_query = match (active_lookup, stats_before) {
            (Some(l), Some((q0, v0))) => {
                let (q1, v1) = l.query_stats();
                (q1 > q0).then(|| (v1 - v0) as f64 / (q1 - q0) as f64)
            }
            _ => None,
        };

        // Errors of served values; CPU-served are exact by construction
        // (same precise function), so their served error is 0.
        let served_err_all =
            nn::per_sample_rmse(&y_served, &ds.y_norm, ds.n, self.bench.n_out);
        let err: Vec<f64> = plan
            .routes
            .iter()
            .zip(&served_err_all)
            .map(|(r, &e)| if r.is_approx() { e } else { 0.0 })
            .collect();

        // Weight-switch accounting over the invocation trace: arrival order
        // by default; class-sorted (the order `execute_plan` actually runs
        // groups) when `route_sorted` is on, collapsing Case-3 refills to
        // at most one per approximator per batch.  Residency is charged in
        // f32-word units at the engine's precision (int8 weights occupy a
        // quarter word each — the same rule `NpuSim::simulate` applies).
        let vpw = self.exec.precision().values_per_word() as usize;
        let weight_words: Vec<usize> = (0..self.n_approx())
            .map(|k| {
                self.bank
                    .host_mlp(self.method, Role::Approx, k)
                    .map(|m| m.n_params().div_ceil(vpw))
                    .unwrap_or(0)
            })
            .collect();
        let mut wc = WeightCache::new(&self.npu_cfg, weight_words);
        if self.route_sorted {
            for r in plan.execution_order_routes() {
                if let Route::Approx(k) = r {
                    wc.access(k);
                }
            }
        } else {
            for r in &plan.routes {
                if let Route::Approx(k) = r {
                    wc.access(*k);
                }
            }
        }

        let mut metrics = RunMetrics::from_routes(
            &self.bench.name,
            self.method.key(),
            &plan.routes,
            &err,
            &err_if_invoked,
            self.bench.error_bound,
            self.n_approx(),
        );
        metrics.weight_switches = wc.switches;
        metrics.weight_refill_cycles = wc.refill_cycles;

        Ok(EvalOutput {
            plan,
            err,
            err_if_invoked,
            y_served,
            metrics,
            weight_cache: wc,
            precise_visits_per_query,
        })
    }

    /// Online path: route + execute one dynamic batch (no ground-truth
    /// error computation — the server doesn't know the answer).
    pub fn process_batch(&self, batch: &Batch) -> crate::Result<(RoutePlan, Vec<f32>)> {
        let mut plan = RoutePlan::default();
        let mut y = Vec::new();
        let mut scratch = Scratch::new();
        self.process_batch_into(batch, &mut plan, &mut y, &mut scratch)?;
        Ok((plan, y))
    }

    /// [`Self::process_batch`] into caller-owned buffers — the server's
    /// per-batch unit.  Zero heap allocations in steady state: the plan,
    /// outputs and every intermediate live in `plan`/`y`/`scratch`.
    pub fn process_batch_into(
        &self,
        batch: &Batch,
        plan: &mut RoutePlan,
        y: &mut Vec<f32>,
        scratch: &mut Scratch,
    ) -> crate::Result<()> {
        self.process_batch_with_margins_into(batch, None, plan, y, scratch)
    }

    /// [`Self::process_batch_into`] with per-class QoS margin overrides
    /// (see [`Self::plan_with_margins_into`]).  Same zero-allocation
    /// steady state — the margins slice is caller-owned and only read.
    pub fn process_batch_with_margins_into(
        &self,
        batch: &Batch,
        margins: Option<&[f32]>,
        plan: &mut RoutePlan,
        y: &mut Vec<f32>,
        scratch: &mut Scratch,
    ) -> crate::Result<()> {
        // Take the normalised panel out of the arena so `scratch` can be
        // reborrowed by the stages below; put it back even on error.
        let mut x_norm = std::mem::take(&mut scratch.x_norm);
        self.normalize_into(&batch.x_raw, batch.n, &mut x_norm);
        let mut result =
            self.plan_with_margins_into(&x_norm, batch.n, margins, plan, scratch);
        if result.is_ok() {
            result =
                self.execute_plan_into(plan, &x_norm, &batch.x_raw, batch.n, y, scratch);
        }
        scratch.x_norm = x_norm;
        result
    }
}

/// Shard a tall native panel across cores in `rows_per`-row chunks,
/// results stitched back in order.  `fwd` forwards one chunk — each
/// engine plugs in its packed net with a chunk-local scratch.
#[allow(clippy::too_many_arguments)]
fn forward_native_parallel_with<F>(
    d_in: usize,
    d_out: usize,
    x: &[f32],
    n: usize,
    rows_per: usize,
    threads: usize,
    out: &mut [f32],
    fwd: F,
) where
    F: Fn(&[f32], usize, &mut [f32]) + Sync,
{
    let chunks: Vec<(usize, usize)> = (0..n)
        .step_by(rows_per.max(1))
        .map(|start| (start, rows_per.min(n - start)))
        .collect();
    let parts = threadpool::parallel_map(&chunks, threads.max(1), |&(start, len)| {
        let mut part = vec![0.0f32; len * d_out];
        fwd(&x[start * d_in..(start + len) * d_in], len, &mut part);
        part
    });
    for (&(start, len), part) in chunks.iter().zip(&parts) {
        out[start * d_out..(start + len) * d_out].copy_from_slice(part);
    }
}

fn forward_native_parallel(
    packed: &PackedMlp,
    x: &[f32],
    n: usize,
    threads: usize,
    out: &mut [f32],
) {
    // f32 forwards are chunking-exact, so chunks can follow the core count.
    forward_native_parallel_with(
        packed.n_in(),
        packed.n_out(),
        x,
        n,
        n.div_ceil(threads),
        threads,
        out,
        |chunk, len, part| {
            packed.forward_batch_to(chunk, len, &mut GemmScratch::new(), part);
        },
    );
}

/// [`forward_native_parallel`] for the int8 engine.  Each chunk quantizes
/// its own activation panels (per-panel dynamic scales), so the split uses
/// FIXED [`NATIVE_PAR_MIN_ROWS`]-row blocks — a function of n only, never
/// of the core count — keeping q8 outputs bit-reproducible across
/// machines.  Blockwise scales differ from whole-panel scales by at most
/// a fraction of a quantization step, inside the property-tested bound.
fn forward_native_parallel_q8(
    packed: &PackedMlpQ8,
    x: &[f32],
    n: usize,
    threads: usize,
    out: &mut [f32],
) {
    forward_native_parallel_with(
        packed.n_in(),
        packed.n_out(),
        x,
        n,
        NATIVE_PAR_MIN_ROWS,
        threads,
        out,
        |chunk, len, part| {
            packed.forward_batch_to(chunk, len, &mut QGemmScratch::new(), part);
        },
    );
}

// `softmax_prob` lives in `router` (shared with the QoS margin actuator);
// its unit tests moved there with it.
