//! The dispatcher: classify -> route -> execute (approximators on the
//! PJRT "NPU", rejects on the precise CPU path).
//!
//! One `Dispatcher` serves one (benchmark, method) pair.  It is the
//! synchronous core used both by the offline eval drivers (whole-dataset
//! runs for the figures) and by the online `Server` (per-batch).

use crate::benchmarks::{self, BenchFn};
use crate::config::{ExecMode, Method};
use crate::formats::{BenchManifest, Dataset};
use crate::nn;
use crate::runtime::{ModelBank, Role};

use super::batcher::Batch;
use super::metrics::RunMetrics;
use super::router::{self, Route, RoutePlan};
use super::weight_cache::WeightCache;

/// Full offline evaluation result for one (benchmark, method, dataset).
pub struct EvalOutput {
    pub plan: RoutePlan,
    /// Per-sample error of the value actually served (0 for CPU-served).
    pub err: Vec<f64>,
    /// Per-sample error under the method's best approximator — defines the
    /// "actually safe" (A) split for rejected samples (Fig. 11).
    pub err_if_invoked: Vec<f64>,
    /// Served outputs, row-major `(n, d_out)` normalised space.
    pub y_served: Vec<f32>,
    pub metrics: RunMetrics,
    pub weight_cache: WeightCache,
}

/// Routing policy — how classifier outputs become destinations.
///
/// `Argmax` is the paper's MCMA ("the approximator with the highest
/// confidence consumes the input sample").  The other two are extensions
/// evaluated in `benches/ablations.rs`:
/// * `Confidence(t)` — route to the argmax approximator only when its
///   softmax probability exceeds `t`, else CPU: trades invocation for
///   quality with no retraining (a runtime quality knob the paper's §II.A
///   related work tunes statically).
/// * `Oracle` — route by the true lowest-error approximator (requires
///   ground truth; upper-bounds what any classifier could achieve and
///   quantifies the remaining classifier headroom).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RouterPolicy {
    Argmax,
    Confidence(f32),
    Oracle,
}

/// Synchronous classify/route/execute engine for one (bench, method).
pub struct Dispatcher<'a> {
    pub bench: &'a BenchManifest,
    pub bank: &'a ModelBank,
    pub benchfn: Box<dyn BenchFn>,
    pub method: Method,
    pub exec: ExecMode,
    pub npu_cfg: crate::config::NpuConfig,
    pub policy: RouterPolicy,
}

impl<'a> Dispatcher<'a> {
    pub fn new(
        bench: &'a BenchManifest,
        bank: &'a ModelBank,
        method: Method,
        exec: ExecMode,
    ) -> crate::Result<Self> {
        anyhow::ensure!(
            bank.has_method(method),
            "artifacts for {} lack method {}",
            bench.name,
            method.key()
        );
        Ok(Dispatcher {
            bench,
            bank,
            benchfn: benchmarks::by_name(&bench.name)?,
            method,
            exec,
            npu_cfg: crate::config::NpuConfig::default(),
            policy: RouterPolicy::Argmax,
        })
    }

    /// Builder-style routing-policy override (extensions; see RouterPolicy).
    pub fn with_policy(mut self, policy: RouterPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Number of approximators this method has.
    pub fn n_approx(&self) -> usize {
        self.bank.n_approx(self.method)
    }

    /// Normalise a raw-input batch into NN space.
    pub fn normalize(&self, x_raw: &[f32], n: usize) -> Vec<f32> {
        let d = self.bench.n_in;
        let mut out = vec![0.0f32; n * d];
        for i in 0..n {
            self.bench
                .normalize_x_into(&x_raw[i * d..(i + 1) * d], &mut out[i * d..(i + 1) * d]);
        }
        out
    }

    /// Forward `n` rows through (role, idx), batched through the chosen
    /// engine.  Chunks through the largest compiled batch on PJRT.
    pub fn forward(
        &self,
        role: Role,
        idx: usize,
        x_norm: &[f32],
        n: usize,
    ) -> crate::Result<Vec<f32>> {
        match self.exec {
            ExecMode::Native => {
                let mlp = self.bank.host_mlp(self.method, role, idx)?;
                Ok(mlp.forward_batch(x_norm, n))
            }
            ExecMode::Pjrt => {
                let d_in = x_norm.len() / n.max(1);
                let b = self.bank.best_batch(role, n);
                let exe = self.bank.exe(role, b)?;
                let weights = self.bank.weight_set(self.method, role, idx)?;
                let mut out = Vec::with_capacity(n * exe.n_out);
                let mut i = 0;
                while i < n {
                    let take = (n - i).min(b);
                    let chunk = &x_norm[i * d_in..(i + take) * d_in];
                    out.extend(exe.run(chunk, take, weights)?);
                    i += take;
                }
                Ok(out)
            }
        }
    }

    /// Classify a normalised batch into routes.
    pub fn plan(&self, x_norm: &[f32], n: usize) -> crate::Result<RoutePlan> {
        match self.method {
            Method::Mcca => self.plan_cascade(x_norm, n),
            m => {
                let (role, n_classes) = if m.is_mcma() {
                    (Role::ClfN, self.bank.host_mlp(m, Role::ClfN, 0)?.n_out())
                } else {
                    (Role::Clf2, 2)
                };
                let logits = self.forward(role, 0, x_norm, n)?;
                let mut classes = nn::argmax_rows(&logits, n, n_classes);
                let n_approx = if m.is_mcma() { n_classes - 1 } else { 1 };
                if let RouterPolicy::Confidence(tau) = self.policy {
                    // Demote low-confidence accepts to the CPU class.
                    for (i, c) in classes.iter_mut().enumerate() {
                        if *c < n_approx {
                            let row = &logits[i * n_classes..(i + 1) * n_classes];
                            if softmax_prob(row, *c) < tau {
                                *c = n_approx; // nC
                            }
                        }
                    }
                }
                Ok(router::plan_routes(&classes, n_approx))
            }
        }
    }

    /// Oracle routing (extension): assign each sample to its true
    /// lowest-error approximator, CPU when even the best violates the
    /// bound.  Upper-bounds any classifier.
    pub fn plan_oracle(&self, ds: &Dataset) -> crate::Result<RoutePlan> {
        let matrix = self.error_matrix(ds)?;
        let n_approx = self.n_approx();
        let classes: Vec<usize> = (0..ds.n)
            .map(|i| {
                let (mut best_k, mut best_e) = (0usize, f64::INFINITY);
                for (k, row) in matrix.iter().enumerate() {
                    if row[i] < best_e {
                        best_e = row[i];
                        best_k = k;
                    }
                }
                if best_e <= self.bench.error_bound { best_k } else { n_approx }
            })
            .collect();
        Ok(router::plan_routes(&classes, n_approx))
    }

    /// MCCA: cascade of binary stages (paper §III.B / Fig. 3b).
    fn plan_cascade(&self, x_norm: &[f32], n: usize) -> crate::Result<RoutePlan> {
        let d = self.bench.n_in;
        let stages = self.bank.host.get(self.method.key())?.classifiers.len();
        let mut plan = router::all_cpu_plan(n, stages);
        plan.cpu.clear();
        let mut remaining: Vec<usize> = (0..n).collect();
        for s in 0..stages {
            if remaining.is_empty() {
                break;
            }
            // Gather the still-unrouted rows into a dense buffer.
            let mut xs = Vec::with_capacity(remaining.len() * d);
            for &i in &remaining {
                xs.extend_from_slice(&x_norm[i * d..(i + 1) * d]);
            }
            let logits = self.forward(Role::Clf2, s, &xs, remaining.len())?;
            let classes = nn::argmax_rows(&logits, remaining.len(), 2);
            let accept: Vec<bool> = classes.iter().map(|&c| c == 0).collect();
            remaining = router::cascade_stage(&mut plan, &remaining, &accept, s);
        }
        plan.cpu = remaining;
        Ok(plan)
    }

    /// Execute a routed plan: approximators per group, precise CPU for the
    /// rest.  Returns served outputs `(n, d_out)` in normalised space.
    pub fn execute_plan(
        &self,
        plan: &RoutePlan,
        x_norm: &[f32],
        x_raw: &[f32],
        n: usize,
    ) -> crate::Result<Vec<f32>> {
        let d_in = self.bench.n_in;
        let d_out = self.bench.n_out;
        let mut y = vec![0.0f32; n * d_out];

        for (k, group) in plan.groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let mut xs = Vec::with_capacity(group.len() * d_in);
            for &i in group {
                xs.extend_from_slice(&x_norm[i * d_in..(i + 1) * d_in]);
            }
            let out = self.forward(Role::Approx, k, &xs, group.len())?;
            for (j, &i) in group.iter().enumerate() {
                y[i * d_out..(i + 1) * d_out]
                    .copy_from_slice(&out[j * d_out..(j + 1) * d_out]);
            }
        }

        // Precise CPU path for rejected samples.
        let mut raw_out = vec![0.0f64; d_out];
        for &i in &plan.cpu {
            self.benchfn.eval(&x_raw[i * d_in..(i + 1) * d_in], &mut raw_out);
            self.bench
                .normalize_y_into(&raw_out, &mut y[i * d_out..(i + 1) * d_out]);
        }
        Ok(y)
    }

    /// Per-approximator error of EVERY sample (rows: approximator, cols:
    /// sample) — feeds Figs. 10/11 and the `err_if_invoked` split.
    pub fn error_matrix(&self, ds: &Dataset) -> crate::Result<Vec<Vec<f64>>> {
        let x_norm = self.normalize(&ds.x_raw, ds.n);
        let mut rows = Vec::with_capacity(self.n_approx());
        for k in 0..self.n_approx() {
            let pred = self.forward(Role::Approx, k, &x_norm, ds.n)?;
            rows.push(nn::per_sample_rmse(&pred, &ds.y_norm, ds.n, self.bench.n_out));
        }
        Ok(rows)
    }

    /// Whole-dataset offline evaluation (the engine behind every figure).
    pub fn run_dataset(&self, ds: &Dataset) -> crate::Result<EvalOutput> {
        let x_norm = self.normalize(&ds.x_raw, ds.n);
        let plan = if self.policy == RouterPolicy::Oracle {
            self.plan_oracle(ds)?
        } else {
            self.plan(&x_norm, ds.n)?
        };
        let y_served = self.execute_plan(&plan, &x_norm, &ds.x_raw, ds.n)?;

        // Errors of served values; CPU-served are exact by construction
        // (same precise function), so their served error is 0.
        let served_err_all =
            nn::per_sample_rmse(&y_served, &ds.y_norm, ds.n, self.bench.n_out);
        let err: Vec<f64> = plan
            .routes
            .iter()
            .zip(&served_err_all)
            .map(|(r, &e)| if r.is_approx() { e } else { 0.0 })
            .collect();

        // "Would-be" error for every sample: min over this method's
        // approximators (defines the A/nA ground-truth split).
        let matrix = self.error_matrix(ds)?;
        let err_if_invoked: Vec<f64> = (0..ds.n)
            .map(|i| {
                matrix
                    .iter()
                    .map(|row| row[i])
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();

        // Weight-switch accounting over the arrival-order invocation trace.
        let weight_words: Vec<usize> = (0..self.n_approx())
            .map(|k| {
                self.bank
                    .host_mlp(self.method, Role::Approx, k)
                    .map(|m| m.n_params())
                    .unwrap_or(0)
            })
            .collect();
        let mut wc = WeightCache::new(&self.npu_cfg, weight_words);
        for r in &plan.routes {
            if let Route::Approx(k) = r {
                wc.access(*k);
            }
        }

        let mut metrics = RunMetrics::from_routes(
            &self.bench.name,
            self.method.key(),
            &plan.routes,
            &err,
            &err_if_invoked,
            self.bench.error_bound,
            self.n_approx(),
        );
        metrics.weight_switches = wc.switches;
        metrics.weight_refill_cycles = wc.refill_cycles;

        Ok(EvalOutput { plan, err, err_if_invoked, y_served, metrics, weight_cache: wc })
    }

    /// Online path: route + execute one dynamic batch (no ground-truth
    /// error computation — the server doesn't know the answer).
    pub fn process_batch(&self, batch: &Batch) -> crate::Result<(RoutePlan, Vec<f32>)> {
        let x_norm = self.normalize(&batch.x_raw, batch.n);
        let plan = self.plan(&x_norm, batch.n)?;
        let y = self.execute_plan(&plan, &x_norm, &batch.x_raw, batch.n)?;
        Ok((plan, y))
    }
}

/// Softmax probability of class `c` for one logit row.
fn softmax_prob(logits: &[f32], c: usize) -> f32 {
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let denom: f32 = logits.iter().map(|&v| (v - max).exp()).sum();
    (logits[c] - max).exp() / denom
}

#[cfg(test)]
mod tests {
    use super::softmax_prob;

    #[test]
    fn softmax_prob_basic() {
        let p0 = softmax_prob(&[2.0, 0.0], 0);
        let p1 = softmax_prob(&[2.0, 0.0], 1);
        assert!((p0 + p1 - 1.0).abs() < 1e-6);
        assert!(p0 > 0.85 && p0 < 0.9); // sigmoid(2) ~ 0.8808
    }

    #[test]
    fn softmax_prob_stable_for_large_logits() {
        let p = softmax_prob(&[1000.0, 999.0, -1000.0], 0);
        assert!(p.is_finite() && p > 0.7);
    }
}
