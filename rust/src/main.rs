//! `mcma` — leader entrypoint / CLI for the MCMA reproduction.
//!
//! See `cli::USAGE` (or run with no arguments) for subcommands.  Python is
//! never touched here: all models were AOT-lowered at `make artifacts`.

use std::str::FromStr;
use std::sync::Arc;
use std::time::Instant;

use mcma::bench_harness::{pct, Table};
use mcma::cli::{Args, USAGE};
use mcma::config::{BatchPolicy, ExecMode, Method, RunConfig};
use mcma::coordinator::{BufferCase, Server, ServerConfig};
use mcma::eval::{self, Context};
use mcma::util::rng::Rng;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run_config(args: &Args) -> mcma::Result<RunConfig> {
    Ok(RunConfig {
        exec: ExecMode::from_str(&args.opt_or("exec", "pjrt"))?,
        max_samples: args.opt_usize("samples", 0)?,
        ..RunConfig::default()
    })
}

fn run(args: Args) -> mcma::Result<()> {
    if args.has_flag("help") {
        println!("{USAGE}");
        return Ok(());
    }
    match args.subcommand.as_deref() {
        None | Some("help") => {
            println!("{USAGE}");
            Ok(())
        }
        Some("list-benchmarks") => list_benchmarks(&args),
        Some("figure") => figure(&args),
        Some("summary") => {
            let ctx = Context::load(run_config(&args)?)?;
            eval::summary::run(&ctx)?.table().print();
            let rows = eval::summary::quantized_deltas(&ctx)?;
            eval::summary::quantized_table(&rows).print();
            // Fixed-vs-adaptive invocation under the online QoS loop.
            let qos_rows = eval::summary::qos_deltas(&ctx)?;
            eval::summary::qos_table(&qos_rows).print();
            // Python-trained vs Rust-trained comparison (only when `mcma
            // train` has written weights_rust.bin artifacts).
            let rust_rows = eval::summary::rust_trained_deltas(&ctx)?;
            if !rust_rows.is_empty() {
                eval::summary::rust_trained_table(&rust_rows).print();
            }
            Ok(())
        }
        Some("eval") => eval_cmd(&args),
        Some("serve") => serve_cmd(&args),
        Some("stats") => stats_cmd(&args),
        Some("trace") => trace_cmd(&args),
        Some("bench-load") => bench_load_cmd(&args),
        Some("train") => train_cmd(&args),
        Some("npu-sim") => npu_sim_cmd(&args),
        Some("report") => report_cmd(&args),
        Some(other) => anyhow::bail!("unknown subcommand {other:?}\n\n{USAGE}"),
    }
}

/// Machine-readable dump of the whole evaluation (Fig 7/8 data) as JSON on
/// stdout — for plotting scripts and CI regression tracking.
fn report_cmd(args: &Args) -> mcma::Result<()> {
    use mcma::util::json::{obj, Value};
    let ctx = Context::load(run_config(args)?)?;
    let f7 = eval::fig7::run(&ctx)?;
    let mut benches = Vec::new();
    for e in &f7.evals {
        let m = &e.out.metrics;
        benches.push(Value::Obj(vec![
            ("bench".into(), Value::Str(e.bench.clone())),
            ("method".into(), Value::Str(m.method.clone())),
            ("n".into(), Value::Num(m.n as f64)),
            ("invocation".into(), Value::Num(m.invocation())),
            ("true_invocation".into(), Value::Num(m.true_invocation())),
            ("rmse_invoked".into(), Value::Num(m.rmse_invoked)),
            ("rmse_over_bound".into(), Value::Num(m.rmse_over_bound)),
            ("recall".into(), Value::Num(m.recall())),
            ("weight_switches".into(), Value::Num(m.weight_switches as f64)),
            ("speedup_vs_cpu".into(), Value::Num(e.sim.speedup_vs_cpu())),
            (
                "energy_reduction_vs_cpu".into(),
                Value::Num(e.sim.energy_reduction_vs_cpu()),
            ),
        ]));
    }
    let f8 = eval::fig8::run(&ctx, &f7)?;
    let (inv_gain, err_red) = f7.mcma_gain_over_one_pass(&ctx);
    let (speedup, energy) = f8.mcma_mean_gains(&ctx);
    let doc = obj(vec![
        ("schema".into(), Value::Num(1.0)),
        ("results".into(), Value::Arr(benches)),
        (
            "headline".into(),
            obj(vec![
                ("invocation_gain", Value::Num(inv_gain)),
                ("error_reduction", Value::Num(err_red)),
                ("speedup_vs_one_pass", Value::Num(speedup)),
                ("energy_vs_one_pass", Value::Num(energy)),
            ]),
        ),
    ]);
    println!("{}", mcma::util::json::write(&doc));
    Ok(())
}

fn list_benchmarks(args: &Args) -> mcma::Result<()> {
    let ctx = Context::load(RunConfig { exec: ExecMode::Native, ..run_config(args)? })?;
    let mut t = Table::new(
        "Benchmark suite (paper Fig. 6 + custom workloads)",
        &["#", "benchmark", "domain", "kind", "test n", "approximator", "classifier", "bound"],
    );
    for (i, name) in ctx.man.bench_names_ordered().iter().enumerate() {
        let b = ctx.man.bench(name)?;
        t.row(vec![
            (i + 1).to_string(),
            b.name.clone(),
            b.domain.clone(),
            b.kind.key().to_string(),
            b.test_n.to_string(),
            topo(&b.approx_topology),
            format!("{} ({})", topo(&b.clf2_topology), topo(&b.clfn_topology)),
            format!("{:.3}", b.error_bound),
        ]);
    }
    t.print();
    Ok(())
}

fn topo(t: &[usize]) -> String {
    t.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("->")
}

fn figure(args: &Args) -> mcma::Result<()> {
    let which = args.positionals.first().map(String::as_str).unwrap_or("all");
    let ctx = Context::load(run_config(args)?)?;
    let wants = |k: &str| which == "all" || which == k;

    if wants("7a") || wants("7b") || wants("8a") || wants("8b") {
        let f7 = eval::fig7::run(&ctx)?;
        if wants("7a") {
            f7.table_a(&ctx).print();
        }
        if wants("7b") {
            f7.table_b(&ctx).print();
        }
        if wants("8a") || wants("8b") {
            let f8 = eval::fig8::run(&ctx, &f7)?;
            if wants("8a") {
                f8.table_a(&ctx).print();
            }
            if wants("8b") {
                f8.table_b(&ctx).print();
            }
        }
    }
    if wants("7c") {
        eval::fig7c::run(&ctx)?.table().print();
    }
    if wants("9") {
        // Default to the paper's Bessel run; `--bench` retargets (e.g. at
        // a standalone Rust-trained tree with a different benchmark).
        eval::fig9::run(&ctx, &args.opt_or("bench", "bessel"))?.table().print();
    }
    if wants("10") {
        let f10 = eval::fig10::run(&ctx, Method::McmaCompetitive)?;
        f10.stats_table().print();
        println!("\n{}", f10.territory_map());
        let bound = ctx.man.bench("bessel")?.error_bound;
        for k in 0..f10.grids.len() {
            println!("{}", f10.error_map(k, bound));
        }
    }
    if wants("11") {
        let f11 = eval::fig11::run(&ctx)?;
        f11.quadrant_table().print();
        println!("{}", f11.render());
    }
    if which == "all" {
        eval::summary::run(&ctx)?.table().print();
    }
    Ok(())
}

fn eval_cmd(args: &Args) -> mcma::Result<()> {
    let bench = args
        .opt("bench")
        .ok_or_else(|| anyhow::anyhow!("--bench required"))?;
    let method = Method::from_str(&args.opt_or("method", "mcma_competitive"))?;
    let ctx = Context::load(run_config(args)?)?;
    let t0 = Instant::now();
    let rows = eval::eval_bench(&ctx, bench, &[method])?;
    for e in rows {
        let m = &e.out.metrics;
        println!("benchmark        : {}", e.bench);
        println!("method           : {}", e.method.label());
        println!("samples          : {}", m.n);
        println!("invocation       : {}", pct(m.invocation()));
        println!("true invocation  : {}", pct(m.true_invocation()));
        println!("rmse (invoked)   : {:.5}", m.rmse_invoked);
        println!("rmse / bound     : {:.3}", m.rmse_over_bound);
        println!("recall           : {:.3}", m.recall());
        println!("per-class counts : {:?} + {} cpu", m.per_class, m.cpu_count);
        println!("weight switches  : {}", m.weight_switches);
        println!("npu speedup vs cpu-only     : {:.2}x", e.sim.speedup_vs_cpu());
        println!("npu energy reduction vs cpu : {:.2}x", e.sim.energy_reduction_vs_cpu());
    }
    println!("wall time        : {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    Ok(())
}

/// `--qos-*` flags -> controller config (`None` without `--qos-target`).
fn qos_config(args: &Args) -> mcma::Result<Option<mcma::qos::QosConfig>> {
    let Some(target) = args.opt("qos-target") else { return Ok(None) };
    let target: f64 = target
        .parse()
        .map_err(|_| anyhow::anyhow!("--qos-target expects a number, got {target:?}"))?;
    let defaults = mcma::qos::QosConfig::default();
    let qos = mcma::qos::QosConfig {
        target,
        quantile: args.opt_f64("qos-quantile", defaults.quantile)?,
        shadow_rate: args.opt_f64("qos-shadow", defaults.shadow_rate)?,
        window: args.opt_usize("qos-window", defaults.window)?,
        seed: args.opt_usize("qos-seed", defaults.seed as usize)? as u64,
        warm_start: args.has_flag("qos-warm"),
        ..defaults
    };
    qos.validate()?;
    Ok(Some(qos))
}

fn serve_cmd(args: &Args) -> mcma::Result<()> {
    let bench_name = args
        .opt("bench")
        .ok_or_else(|| anyhow::anyhow!("--bench required"))?;
    let method = Method::from_str(&args.opt_or("method", "mcma_competitive"))?;
    let n_requests = args.opt_usize("requests", 5_000)?;
    let cfg = run_config(args)?;
    let qos = qos_config(args)?;
    // `--batch-max`/`--batch-wait-us` are the canonical micro-batching
    // knobs; the older `--batch`/`--wait-us` spellings keep working.
    let policy = BatchPolicy {
        max_batch: args.opt_usize("batch-max", args.opt_usize("batch", 256)?)?,
        max_wait_us: args
            .opt_usize("batch-wait-us", args.opt_usize("wait-us", 2_000)?)?
            as u64,
    };

    let man = Arc::new(mcma::formats::Manifest::load(&mcma::artifacts_dir())?);
    let bench = Arc::new(man.bench(bench_name)?.clone());
    // Traffic source: synthetic workloads draw from the registered input
    // generator; table workloads have none, so traffic replays random
    // rows of the held-out set (whose labels the QoS shadow loop then
    // verifies against).
    let benchfn = match bench.kind {
        mcma::formats::WorkloadKind::Synthetic => Some(mcma::benchmarks::by_name(bench_name)?),
        mcma::formats::WorkloadKind::Table => None,
    };
    let rows = match bench.kind {
        mcma::formats::WorkloadKind::Table => {
            Some(mcma::formats::Dataset::load(&man.dataset_path(bench_name))?)
        }
        _ => None,
    };

    let server = Server::spawn(
        Arc::clone(&man),
        Arc::clone(&bench),
        {
            let mut sc = ServerConfig::new(policy, method, cfg.exec);
            sc.workers = args.opt_usize("n", 1)?;
            sc.qos = qos;
            sc.table_fallback = mcma::coordinator::TableFallback::from_str(
                &args.opt_or("precise-fallback", "lookup"),
            )?;
            sc
        },
    )?;

    // Observability writers (`--metrics-json` overwrites a snapshot
    // every `--metrics-interval-s`; `--trace-json` appends the drained
    // span journal as JSON lines).  The handle is taken before the net
    // front-end consumes the server; the detached writer thread keeps
    // the files fresh even on the serve-forever path, and the explicit
    // flushes below cover the clean-shutdown paths.
    let obs = server.obs();
    let trace_json = args.opt("trace-json").map(std::path::PathBuf::from);
    let metrics_json = args.opt("metrics-json").map(std::path::PathBuf::from);
    let metrics_interval = args.opt_usize("metrics-interval-s", 5)?.max(1) as u64;
    if metrics_json.is_some() || trace_json.is_some() {
        let obs = obs.clone();
        let metrics_json = metrics_json.clone();
        let trace_json = trace_json.clone();
        std::thread::Builder::new()
            .name("mcma-obs-writer".into())
            .spawn(move || loop {
                std::thread::sleep(std::time::Duration::from_secs(metrics_interval));
                write_observability(&obs, metrics_json.as_deref(), trace_json.as_deref());
            })?;
    }

    // `--slo-p99-us N` (+ `--slo-error-budget F`): multi-window SLO
    // burn-rate monitor over the delivered-latency histogram.  A
    // detached 1 s tick thread feeds it cumulative counts: `bad` =
    // deliveries over the latency target plus breaker trips (the two
    // budget-consuming events).  Transitions bump `slo_breaches_total`
    // and journal an instant event, so the Perfetto export shows the
    // breach window against the request tracks.
    let slo = match args.opt("slo-p99-us") {
        None => None,
        Some(_) => {
            let cfg = mcma::obs::SloConfig::new(
                args.opt_usize("slo-p99-us", 0)? as u64,
                args.opt_f64("slo-error-budget", 0.001)?,
            );
            cfg.validate()?;
            let slo = Arc::new(mcma::obs::SloMonitor::new(cfg));
            let obs = obs.clone();
            let mon = Arc::clone(&slo);
            std::thread::Builder::new()
                .name("mcma-slo-tick".into())
                .spawn(move || loop {
                    std::thread::sleep(std::time::Duration::from_secs(1));
                    let delivered = obs.metrics.e2e_delivered.snapshot();
                    let bad = delivered.count_over(mon.config().p99_target_us)
                        + obs.metrics.breaker_trips.get();
                    let t = mon.tick(obs.journal.now_us(), delivered.count, bad);
                    if t.changed {
                        if t.breached {
                            obs.metrics.slo_breaches.inc();
                        }
                        obs.journal.push(mcma::obs::Event::Slo {
                            breached: t.breached,
                            burn_short: t.burn_short,
                            burn_long: t.burn_long,
                            at_us: obs.journal.now_us(),
                        });
                    }
                })?;
            Some(slo)
        }
    };

    // `--metrics-listen ADDR`: OpenMetrics text exposition over HTTP —
    // `GET /metrics` for Prometheus-style scrapes, `GET /healthz` for
    // load balancers (503 while the SLO monitor reports a breach).  The
    // handle is held for the life of the serve so the accept loop stays
    // up on every exit path below.
    let _metrics_http = match args.opt("metrics-listen") {
        None => None,
        Some(addr) => {
            let srv = mcma::net::MetricsServer::spawn(obs.clone(), slo.clone(), addr)?;
            println!("metrics on http://{}/metrics", srv.local_addr());
            Some(srv)
        }
    };

    // `--listen ADDR`: serve over TCP (length-prefixed binary frames)
    // instead of generating in-process demo traffic.  `--duration 0`
    // (the default) serves until the process is killed.
    if let Some(listen) = args.opt("listen") {
        let net = mcma::net::NetServer::spawn(server, listen, 0, bench.n_in)?;
        let duration = args.opt_usize("duration", 0)? as u64;
        println!("listening on {} (bench {bench_name})", net.local_addr());
        if duration == 0 {
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        std::thread::sleep(std::time::Duration::from_secs(duration));
        let net_report = net.shutdown()?;
        write_observability(&obs, metrics_json.as_deref(), trace_json.as_deref());
        println!("connections      : {} accepted ({} killed malformed)",
                 net_report.accepted, net_report.malformed);
        println!("delivery failed  : {} (responses owed to dead clients)",
                 net_report.delivery_failed);
        print_server_report(&net_report.server);
        return Ok(());
    }

    let mut rng = Rng::new(42);
    let mut x = vec![0.0f32; bench.n_in];
    for id in 0..n_requests as u64 {
        match (&benchfn, &rows) {
            (Some(g), _) => g.gen_into(&mut rng, &mut x),
            (None, Some(ds)) => {
                x.copy_from_slice(ds.x_row(rng.below(ds.n as u64) as usize))
            }
            (None, None) => unreachable!("table workload without a held-out set"),
        }
        server.submit(id, x.clone())?;
    }
    let report = server.shutdown(Vec::new())?;
    write_observability(&obs, metrics_json.as_deref(), trace_json.as_deref());
    print_server_report(&report);
    anyhow::ensure!(report.served as usize == n_requests, "dropped requests");
    Ok(())
}

/// Flush the live observability state: snapshot JSON (overwritten in
/// place — readers always see a complete recent document) and newly
/// journaled trace events (appended as JSON lines; the drain is
/// destructive, so each event lands in the file exactly once).
/// Best-effort: a full disk must not kill a serving process.
fn write_observability(
    obs: &mcma::obs::Obs,
    metrics: Option<&std::path::Path>,
    trace: Option<&std::path::Path>,
) {
    if let Some(p) = metrics {
        let json = mcma::util::json::write(&obs.snapshot_json());
        if let Err(e) = std::fs::write(p, json) {
            eprintln!("warning: writing {}: {e}", p.display());
        }
    }
    if let Some(p) = trace {
        let lines = obs.journal.drain_json_lines();
        if lines.is_empty() {
            return;
        }
        use std::io::Write as _;
        let appended = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(p)
            .and_then(|mut f| f.write_all(lines.as_bytes()));
        if let Err(e) = appended {
            eprintln!("warning: appending {}: {e}", p.display());
        }
    }
}

/// `mcma stats`: scrape a running `serve --listen` server through the
/// in-band STATS frame and print its stage waterfall.  The address is
/// positional (`mcma stats 127.0.0.1:7090`) or `--addr`; `--watch SECS`
/// re-scrapes until interrupted; `--json PATH` also dumps each raw
/// snapshot for tooling.
fn stats_cmd(args: &Args) -> mcma::Result<()> {
    let addr = args
        .pos("addr")
        .or_else(|| args.opt("addr"))
        .ok_or_else(|| {
            anyhow::anyhow!("address required: `mcma stats HOST:PORT` (or --addr HOST:PORT)")
        })?
        .to_string();
    let watch = args.opt_usize("watch", 0)? as u64;
    let json_path = args.opt("json").map(std::path::PathBuf::from);
    let mut prev: Option<(mcma::util::json::Value, Instant)> = None;
    loop {
        let snap = mcma::net::load::scrape_stats(&addr, 0)?;
        let at = Instant::now();
        print_stats_snapshot(&snap);
        // `--watch` interval view: everything above is cumulative since
        // server start; this differences consecutive scrapes into
        // per-second rates and interval-local percentiles.
        if let Some((old, t0)) = &prev {
            print_interval_rates(old, &snap, at.duration_since(*t0).as_secs_f64());
        }
        if let Some(p) = &json_path {
            std::fs::write(p, mcma::util::json::write(&snap))
                .map_err(|e| anyhow::anyhow!("writing {}: {e}", p.display()))?;
            println!("wrote {}", p.display());
        }
        if watch == 0 {
            return Ok(());
        }
        prev = Some((snap, at));
        std::thread::sleep(std::time::Duration::from_secs(watch));
        println!();
    }
}

/// Rebuild a [`mcma::obs::HistSnapshot`] from the sparse
/// `[bucket, count]` pairs a STATS snapshot carries for each stage, so
/// two scrapes can be differenced bucketwise into an interval-local
/// histogram with real percentiles (not deltas of percentiles, which
/// are meaningless).
fn hist_from_stats_json(h: &mcma::util::json::Value) -> mcma::obs::HistSnapshot {
    let mut s = mcma::obs::HistSnapshot::default();
    s.count = h.get("count").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    s.sum = h.get("sum_us").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
    for pair in h.get("buckets").and_then(|v| v.as_arr()).unwrap_or(&[]) {
        let Some(pair) = pair.as_arr() else { continue };
        let (Some(i), Some(c)) = (
            pair.first().and_then(|v| v.as_f64()),
            pair.get(1).and_then(|v| v.as_f64()),
        ) else {
            continue;
        };
        let i = i as usize;
        if let Some(slot) = s.buckets.get_mut(i) {
            *slot = c as u64;
        }
    }
    s
}

/// The `--watch` per-interval block: delta/sec for the headline
/// counters plus interval p50/p99 for the hot stage histograms,
/// computed by differencing the two scrapes' sparse log2 buckets.
fn print_interval_rates(prev: &mcma::util::json::Value, cur: &mcma::util::json::Value, dt_s: f64) {
    let dt = dt_s.max(1e-9);
    let counter = |snap: &mcma::util::json::Value, key: &str| -> f64 {
        snap.get("counters")
            .and_then(|v| v.get(key))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
    };
    let rate = |key: &str| (counter(cur, key) - counter(prev, key)).max(0.0) / dt;
    println!(
        "interval ({dt_s:.1} s)   : {:.0} submitted/s, {:.0} delivered/s, {:.0} frames/s, {:.1} failures/s",
        rate("submitted"),
        rate("delivered"),
        rate("frames_in"),
        rate("delivery_failures"),
    );
    for stage in ["queue", "execute", "e2e_delivered"] {
        let get = |snap: &mcma::util::json::Value| {
            snap.get("stages").and_then(|v| v.get(stage)).map(hist_from_stats_json)
        };
        let (Some(a), Some(b)) = (get(prev), get(cur)) else { continue };
        let mut d = mcma::obs::HistSnapshot::default();
        for i in 0..d.buckets.len() {
            d.buckets[i] = b.buckets[i].saturating_sub(a.buckets[i]);
        }
        d.count = b.count.saturating_sub(a.count);
        d.sum = b.sum.saturating_sub(a.sum);
        if d.count == 0 {
            continue;
        }
        println!(
            "interval {stage:<12}: {} samples, p50 {:.0} µs, p99 {:.0} µs",
            d.count,
            d.p50(),
            d.p99(),
        );
    }
}

/// `mcma trace`: convert a drained span journal (the JSON-lines file
/// `serve --trace-json PATH` appends) into Chrome trace-event JSON for
/// ui.perfetto.dev / chrome://tracing.  Live drain story: point this at
/// the same file a running serve keeps appending — the converter reads
/// whatever has been flushed so far.  `--out PATH` writes the document;
/// without it the JSON goes to stdout.
fn trace_cmd(args: &Args) -> mcma::Result<()> {
    let path = args.opt("trace-json").ok_or_else(|| {
        anyhow::anyhow!("--trace-json PATH required (the journal drain from `serve --trace-json`)")
    })?;
    let jsonl = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let doc = mcma::obs::chrome::convert(&jsonl)?;
    let rendered = mcma::util::json::write(&doc);
    match args.opt("out") {
        Some(p) => {
            std::fs::write(p, &rendered)
                .map_err(|e| anyhow::anyhow!("writing {p}: {e}"))?;
            let events = doc.as_arr().map(|a| a.len()).unwrap_or(0);
            println!("wrote {p} ({events} trace events)");
        }
        None => println!("{rendered}"),
    }
    Ok(())
}

/// Render one STATS snapshot: headline counters, the stage waterfall,
/// per-route-class execute latency, and the QoS margin/breaker state.
/// Stage histograms are log2-bucketed, so printed percentiles carry at
/// most 2x bucket error (see README "Observability").
fn print_stats_snapshot(snap: &mcma::util::json::Value) {
    let f = |path: &[&str]| -> f64 {
        let mut cur = snap;
        for key in path {
            match cur.get(key) {
                Some(v) => cur = v,
                None => return 0.0,
            }
        }
        cur.as_f64().unwrap_or(0.0)
    };
    println!(
        "uptime           : {:.1} s (exec {})",
        f(&["uptime_s"]),
        snap.get("exec_mode").and_then(|v| v.as_str()).unwrap_or("?")
    );
    println!(
        "connections      : {:.0} accepted, {:.0} closed, {:.0} malformed frames, {:.0} stats scrapes",
        f(&["counters", "accepted_conns"]),
        f(&["counters", "closed_conns"]),
        f(&["counters", "malformed_frames"]),
        f(&["counters", "stats_requests"]),
    );
    println!(
        "requests         : {:.0} submitted -> {:.0} dispatched -> {:.0} delivered ({:.0} delivery failures)",
        f(&["counters", "submitted"]),
        f(&["counters", "dispatched"]),
        f(&["counters", "delivered"]),
        f(&["counters", "delivery_failures"]),
    );
    println!(
        "rows             : {:.0} invoked (approximated), {:.0} cpu-precise",
        f(&["counters", "route_invoked_rows"]),
        f(&["counters", "route_cpu_rows"]),
    );
    println!(
        "inflight / queue : {:.0} / {:.0}",
        f(&["gauges", "inflight"]),
        f(&["gauges", "batch_queue_depth"]),
    );

    let mut t = Table::new(
        "Stage waterfall (µs; log2 buckets — percentiles within 2x)",
        &["stage", "count", "p50", "p90", "p99", "mean"],
    );
    for name in [
        "decode",
        "queue",
        "batch",
        "execute",
        "fallback",
        "shadow_verify",
        "pump",
        "e2e_dispatch",
        "e2e_delivered",
    ] {
        let h = |k: &str| f(&["stages", name, k]);
        if h("count") == 0.0 {
            continue;
        }
        t.row(vec![
            name.to_string(),
            format!("{:.0}", h("count")),
            format!("{:.0}", h("p50_us")),
            format!("{:.0}", h("p90_us")),
            format!("{:.0}", h("p99_us")),
            format!("{:.0}", h("mean_us")),
        ]);
    }
    t.print();

    // Per-route-class GEMM execute latency (only classes that ran).
    let routes = snap.get("route_execute").and_then(|v| v.as_arr()).unwrap_or(&[]);
    for entry in routes {
        let Some(pair) = entry.as_arr() else { continue };
        let (Some(k), Some(h)) = (pair.first().and_then(|v| v.as_f64()), pair.get(1)) else {
            continue;
        };
        let g = |key: &str| h.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
        println!(
            "route A{k:.0} execute : {:.0} batches, p50 {:.0} µs, p99 {:.0} µs",
            g("count"),
            g("p50_us"),
            g("p99_us"),
        );
    }

    if f(&["gauges", "qos_enabled"]) > 0.0 {
        let margins: Vec<String> = snap
            .get("qos_margins")
            .and_then(|v| v.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(|v| format!("{:.3}", v.as_f64().unwrap_or(0.0)))
            .collect();
        println!(
            "qos              : margins [{}], {:.0} open breakers",
            margins.join(" "),
            f(&["gauges", "open_breakers"]),
        );
        println!(
            "qos churn        : {:.0} margin moves, {:.0} trips, {:.0} resets, {:.0} shadow drops",
            f(&["counters", "margin_moves"]),
            f(&["counters", "breaker_trips"]),
            f(&["counters", "breaker_resets"]),
            f(&["counters", "shadow_drops"]),
        );
    }
    println!(
        "trace journal    : {:.0} buffered, {:.0} dropped",
        f(&["trace", "buffered"]),
        f(&["trace", "dropped"]),
    );
}

/// Shared report printer for the in-process and `--listen` serve paths.
fn print_server_report(report: &mcma::coordinator::ServerReport) {
    println!("served           : {}", report.served);
    println!("throughput       : {:.0} req/s", report.throughput_rps());
    println!("invocation       : {}", pct(report.invocation()));
    println!("batches          : {} (full {}, timeout {})",
             report.batches, report.flushes_full, report.flushes_timeout);
    println!("latency p50/p95/p99 : {:.0} / {:.0} / {:.0} µs",
             report.latency.p50(), report.latency.p95(), report.latency.p99());
    println!("batch sizes      : {}", fmt_hist(&report.batch_hist));
    // Per-route breakdown (per-class invocation + latency counters).
    let mut rt = Table::new(
        "Per-route counters",
        &["route", "served", "share", "latency p50 µs", "p95 µs"],
    );
    for (k, c) in report.per_route.classes.iter().enumerate() {
        rt.row(vec![
            format!("A{k}"),
            c.count.to_string(),
            pct(c.count as f64 / report.served.max(1) as f64),
            format!("{:.0}", c.latency.p50()),
            format!("{:.0}", c.latency.p95()),
        ]);
    }
    rt.row(vec![
        "cpu".into(),
        report.per_route.cpu.count.to_string(),
        pct(report.per_route.cpu.count as f64 / report.served.max(1) as f64),
        format!("{:.0}", report.per_route.cpu.latency.p50()),
        format!("{:.0}", report.per_route.cpu.latency.p95()),
    ]);
    rt.print();
    if let Some(q) = &report.qos {
        q.table().print();
        println!("qos margins        : {}",
                 if q.warm_started { "warm-started from offline replay" }
                 else { "cold start (argmax)" });
        println!("qos shadow samples : {} ({} dropped to backpressure)",
                 q.total_shadow(), q.shadow_dropped);
        println!("qos ticks          : {}", q.ticks);
        println!("qos violations     : {} (breaker trips {})",
                 q.total_violations(), q.total_trips());
    }
}

/// `size:count` pairs for the non-empty batch-size histogram buckets.
fn fmt_hist(hist: &[u64]) -> String {
    let pairs: Vec<String> = hist
        .iter()
        .enumerate()
        .filter(|(_, c)| **c > 0)
        .map(|(n, c)| format!("{n}x{c}"))
        .collect();
    if pairs.is_empty() { "-".into() } else { pairs.join(" ") }
}

/// `--mix` parser: positional weights (`3,1`) or `CLASS:W` pairs
/// (`0:3,1:1`; classes not named get weight 0).
fn parse_mix(s: &str) -> mcma::Result<Vec<f64>> {
    let mut out: Vec<f64> = Vec::new();
    for (i, part) in s.split(',').enumerate() {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.split_once(':') {
            Some((c, w)) => {
                let c: usize = c.trim().parse().map_err(|_| {
                    anyhow::anyhow!("--mix class {c:?} is not an integer")
                })?;
                let w: f64 = w.trim().parse().map_err(|_| {
                    anyhow::anyhow!("--mix weight {w:?} is not a number")
                })?;
                if out.len() <= c {
                    out.resize(c + 1, 0.0);
                }
                out[c] += w;
            }
            None => {
                let w: f64 = part.parse().map_err(|_| {
                    anyhow::anyhow!("--mix weight {part:?} is not a number")
                })?;
                if out.len() <= i {
                    out.resize(i + 1, 0.0);
                }
                out[i] += w;
            }
        }
    }
    anyhow::ensure!(
        !out.is_empty() && out.iter().sum::<f64>() > 0.0,
        "--mix needs at least one positive weight"
    );
    Ok(out)
}

/// `mcma bench-load`: seeded closed/open-loop load generation against a
/// live `mcma serve --listen` socket.  Emits the per-request CSV and the
/// `BENCH_serve.json` perf report (same `Recorder` schema as
/// BENCH_hotpath/BENCH_train — the cross-PR serving trajectory).
fn bench_load_cmd(args: &Args) -> mcma::Result<()> {
    let addr = args
        .opt("addr")
        .ok_or_else(|| anyhow::anyhow!("--addr HOST:PORT required"))?;
    let bench_name = args
        .opt("bench")
        .ok_or_else(|| anyhow::anyhow!("--bench required (held-out row + label source)"))?;
    let man = mcma::formats::Manifest::load(&mcma::artifacts_dir())?;
    let bench = man.bench(bench_name)?.clone();
    let held_out = Arc::new(mcma::formats::Dataset::load(&man.dataset_path(bench_name))?);

    let arrival = match (args.opt("rate"), args.opt("closed-loop")) {
        (Some(_), Some(_)) => {
            anyhow::bail!("--rate and --closed-loop are mutually exclusive")
        }
        (Some(_), None) => mcma::net::Arrival::OpenLoop {
            rate_hz: args.opt_f64("rate", 1_000.0)?,
        },
        (None, _) => mcma::net::Arrival::ClosedLoop {
            inflight: args.opt_usize("closed-loop", 32)?,
        },
    };
    let cfg = mcma::net::LoadConfig {
        addr: addr.to_string(),
        seed: args.opt_usize("seed", 7)? as u64,
        duration: std::time::Duration::from_secs(args.opt_usize("duration", 10)? as u64),
        max_requests: match args.opt_usize("requests", 0)? {
            0 => None,
            n => Some(n as u64),
        },
        arrival,
        mix: parse_mix(&args.opt_or("mix", "1"))?,
        tag: 0,
        qos_target: args.opt_f64("qos-target", bench.error_bound)?,
    };
    let report = mcma::net::load::run_load(&cfg, &held_out)?;
    anyhow::ensure!(report.received > 0, "no responses received from {addr}");

    println!("sent / received  : {} / {}", report.sent, report.received);
    println!("rows/sec         : {:.0}", report.rows_per_sec());
    println!(
        "latency p50/p99/p999 : {:.0} / {:.0} / {:.0} µs",
        report.latency.p50(),
        report.latency.p99(),
        report.latency.p999()
    );
    println!("batch sizes      : {}", fmt_hist(&report.batch_hist));
    let routes: Vec<String> = report
        .per_route
        .classes
        .iter()
        .enumerate()
        .filter(|(_, c)| c.count > 0)
        .map(|(k, c)| format!("A{k}:{}", c.count))
        .collect();
    println!(
        "routes           : {} cpu:{}",
        if routes.is_empty() { "-".into() } else { routes.join(" ") },
        report.per_route.cpu.count
    );
    println!(
        "violations       : {} (target {:.4})",
        report.violations, cfg.qos_target
    );
    if let Some(stages) = report.stats_snapshot.as_ref().and_then(|s| s.get("stages")) {
        let p50 = |name: &str| {
            stages
                .get(name)
                .and_then(|h| h.get("p50_us"))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        };
        println!(
            "server waterfall : queue {:.0} + batch {:.0} + execute {:.0} + pump {:.0} µs (p50, mid-run scrape)",
            p50("queue"),
            p50("batch"),
            p50("execute"),
            p50("pump"),
        );
    }

    // `--metrics-addr ADDR`: cross-check the HTTP OpenMetrics
    // exposition against the in-band STATS snapshot once the run is
    // done.  Request-plane counters are quiescent between the two
    // scrapes (the load loop has drained), so they must agree exactly;
    // connection-plane counters keep moving with our own scrapes, so
    // the exposition may only run ahead of the earlier STATS read,
    // never behind it.
    if let Some(maddr) = args.opt("metrics-addr") {
        let stats = mcma::net::load::scrape_stats(addr, 0)?;
        let (status, body) = mcma::net::http_get(maddr, "/metrics")?;
        anyhow::ensure!(status == 200, "GET /metrics on {maddr} returned {status}");
        let parsed = mcma::obs::expo::parse_text(&body);
        let expo = |series: &str| {
            mcma::obs::expo::series_value(&parsed, series)
                .ok_or_else(|| anyhow::anyhow!("/metrics is missing series {series}"))
        };
        let stat = |key: &str| {
            stats
                .get("counters")
                .and_then(|v| v.get(key))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        };
        for key in [
            "submitted",
            "dispatched",
            "delivered",
            "delivery_failures",
            "route_invoked_rows",
            "route_cpu_rows",
            "malformed_frames",
        ] {
            let e = expo(&format!("mcma_{key}_total"))?;
            let s = stat(key);
            anyhow::ensure!(
                e == s,
                "exposition disagrees with STATS on {key}: /metrics {e} vs in-band {s}"
            );
        }
        for key in ["accepted_conns", "frames_in", "stats_requests"] {
            let e = expo(&format!("mcma_{key}_total"))?;
            let s = stat(key);
            anyhow::ensure!(
                e >= s,
                "exposition ran behind STATS on {key}: /metrics {e} vs in-band {s}"
            );
        }
        println!("metrics check    : /metrics on {maddr} agrees with the in-band STATS snapshot");
    }

    let csv_path = match args.opt("csv") {
        Some("none") => None,
        Some(p) => Some(std::path::PathBuf::from(p)),
        None => Some(mcma::bench_harness::bench_json_path("BENCH_serve.csv")),
    };
    if let Some(p) = csv_path {
        report.write_csv(&p)?;
        println!("wrote {} ({} rows)", p.display(), report.records.len());
    }
    let json_path = match args.opt("json") {
        Some("none") => None,
        Some(p) => Some(std::path::PathBuf::from(p)),
        None => Some(mcma::bench_harness::bench_json_path("BENCH_serve.json")),
    };
    if let Some(p) = json_path {
        let mut rec = mcma::bench_harness::Recorder::new();
        let ns: Vec<f64> = report.latency.samples.iter().map(|us| us * 1e3).collect();
        rec.timings.push(mcma::bench_harness::timing_from_samples(
            &format!("bench-load serve latency x{}", report.received),
            &ns,
            Some(1),
        ));
        rec.extra("rows_per_sec", report.rows_per_sec());
        rec.extra("sent", report.sent as f64);
        rec.extra("received", report.received as f64);
        rec.extra("p50_us", report.latency.p50());
        rec.extra("p95_us", report.latency.p95());
        rec.extra("p99_us", report.latency.p99());
        rec.extra("p999_us", report.latency.p999());
        rec.extra("mean_us", report.latency.mean());
        rec.extra("violations", report.violations as f64);
        rec.extra("qos_target", cfg.qos_target);
        rec.extra("multi_row_responses", report.multi_row_responses() as f64);
        rec.extra("route_cpu_count", report.per_route.cpu.count as f64);
        for (k, c) in report.per_route.classes.iter().enumerate() {
            rec.extra(&format!("route_a{k}_count"), c.count as f64);
        }
        for (n, c) in report.batch_hist.iter().enumerate() {
            if *c > 0 {
                rec.extra(&format!("batch_hist_{n}"), *c as f64);
            }
        }
        for (c, n) in report.per_class_sent.iter().enumerate() {
            rec.extra(&format!("mix_class_{c}_sent"), *n as f64);
        }
        // Server-side stage waterfall from the mid-run STATS scrape:
        // decomposes the client-observed e2e latency above into the
        // pipeline stages, so the cross-PR BENCH_serve trajectory can
        // attribute regressions to a stage rather than to "serving".
        if let Some(stages) =
            report.stats_snapshot.as_ref().and_then(|s| s.get("stages"))
        {
            for stage in [
                "decode",
                "queue",
                "batch",
                "execute",
                "fallback",
                "pump",
                "e2e_dispatch",
                "e2e_delivered",
            ] {
                let Some(h) = stages.get(stage) else { continue };
                for q in ["count", "p50_us", "p99_us", "mean_us"] {
                    if let Some(x) = h.get(q).and_then(|v| v.as_f64()) {
                        rec.extra(&format!("stage_{stage}_{q}"), x);
                    }
                }
            }
        }
        rec.write_json("mcma-serve-load", &p)?;
    }
    Ok(())
}

/// Co-train a workload natively (`mcma train --bench B --k K` for a
/// registered benchmark, `mcma train --data foo.csv --d-out N --k K` for
/// an arbitrary CSV/TSV workload) and export MCMW/MCQW artifacts
/// `ModelBank` serves; prints the K-vs-baseline held-out invocation
/// comparison and the round trajectory.
fn train_cmd(args: &Args) -> mcma::Result<()> {
    let bench = args.opt("bench");
    let data = args.opt("data");
    anyhow::ensure!(
        bench.is_some() || data.is_some(),
        "either --bench B or --data FILE is required"
    );
    let opts = mcma::train::TrainOptions {
        bench: bench.unwrap_or("").to_string(),
        data: data.map(std::path::PathBuf::from),
        d_out: args.opt_usize("d-out", 0)?,
        holdout: args.opt_f64("holdout", 0.25)?,
        scheme: mcma::train::Scheme::from_str(&args.opt_or("scheme", "competitive"))?,
        k: args.opt_usize("k", 4)?,
        samples: args.opt_usize("samples", 4000)?,
        rounds: args.opt_usize("rounds", 6)?,
        epochs: args.opt_usize("epochs", 20)?,
        seed: args.opt_usize("seed", 7)? as u64,
        lr: args.opt_f64("lr", 0.01)?,
        error_bound: args
            .opt("bound")
            .map(|v| {
                v.parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("--bound expects a number, got {v:?}"))
            })
            .transpose()?,
        out_dir: args
            .opt("out")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(mcma::artifacts_dir),
        threads: args.opt_usize("threads", 0)?,
        // `--perf-json PATH` redirects the perf report, `--perf-json none`
        // skips it; default is BENCH_train.json at the repo root.
        perf_json: match args.opt("perf-json") {
            Some("none") => None,
            Some(p) => Some(std::path::PathBuf::from(p)),
            None => Some(mcma::bench_harness::bench_json_path("BENCH_train.json")),
        },
    };
    let t0 = Instant::now();
    let report = mcma::train::train_bench(&opts)?;
    report.print();
    println!("wall time        : {:.1} s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn npu_sim_cmd(args: &Args) -> mcma::Result<()> {
    let bench_name = args
        .opt("bench")
        .ok_or_else(|| anyhow::anyhow!("--bench required"))?;
    let method = Method::from_str(&args.opt_or("method", "mcma_competitive"))?;
    let ctx = Context::load(run_config(args)?)?;
    let bench = ctx.man.bench(bench_name)?.clone();
    let bank = ctx.bank(&bench, &[method])?;
    let e = eval::eval_one(&ctx, &bench, &bank, method)?;

    let force = match args.opt("case") {
        Some("1") => Some(BufferCase::AllResident),
        Some("2") => Some(BufferCase::StreamAlways),
        Some("3") => Some(BufferCase::OneResident),
        Some(other) => anyhow::bail!("--case must be 1|2|3, got {other}"),
        None => None,
    };
    let clf_topo = if method.is_mcma() { &bench.clfn_topology } else { &bench.clf2_topology };
    let approx_topos: Vec<Vec<usize>> =
        (0..bank.n_approx(method)).map(|_| bench.approx_topology.clone()).collect();
    let sim = mcma::npu::NpuSim::new(
        ctx.cfg.npu,
        clf_topo,
        &approx_topos,
        mcma::workload::precise_cost_cycles_measured(&bench, e.out.precise_visits_per_query),
    );
    let r = sim.simulate(&e.out.plan.routes, force);

    println!("benchmark / method : {} / {}", bench_name, method.label());
    println!("buffer case        : {:?}", force);
    println!("samples            : {}", r.n);
    println!("cycles (approx)    : {:.0}", r.cycles);
    println!("cycles (cpu-only)  : {:.0}", r.cycles_cpu_only);
    println!("  classifier       : {:.0}", r.cycles_classifier);
    println!("  approximators    : {:.0}", r.cycles_approx);
    println!("  cpu fallback     : {:.0}", r.cycles_cpu_fallback);
    println!("  weight switches  : {:.0} ({} switches)", r.cycles_weight_switch, r.weight_switches);
    println!("speedup vs cpu     : {:.3}x", r.speedup_vs_cpu());
    println!("energy reduction   : {:.3}x", r.energy_reduction_vs_cpu());
    Ok(())
}
