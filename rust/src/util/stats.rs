//! Descriptive statistics used by metrics, eval drivers and the bench
//! harness: mean/std, RMSE, percentiles, fixed-width histograms.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Root-mean-square of the values themselves (errors go in, RMSE comes out).
pub fn rms(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        (xs.iter().map(|x| x * x).sum::<f64>() / xs.len() as f64).sqrt()
    }
}

/// Linear-interpolated percentile, `p` in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Fixed-width histogram over `[lo, hi)`; values outside clamp to end bins.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let b = (((x - lo) / w).floor() as i64).clamp(0, bins as i64 - 1) as usize;
        h[b] += 1;
    }
    h
}

/// Welford online accumulator (used in hot loops to avoid buffering).
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, sum_sq: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.sum_sq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    pub fn std_dev(&self) -> f64 {
        if self.n < 2 { 0.0 } else { (self.m2 / self.n as f64).sqrt() }
    }

    pub fn rms(&self) -> f64 {
        if self.n == 0 { 0.0 } else { (self.sum_sq / self.n as f64).sqrt() }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.118033988).abs() < 1e-9);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn rms_matches_hand_calc() {
        assert!((rms(&[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(rms(&[]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn histogram_clamps_outliers() {
        let h = histogram(&[-1.0, 0.05, 0.15, 0.95, 2.0], 0.0, 1.0, 10);
        assert_eq!(h[0], 2); // -1.0 clamps into bin 0
        assert_eq!(h[1], 1);
        assert_eq!(h[9], 2); // 0.95 and 2.0
        assert_eq!(h.iter().sum::<usize>(), 5);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.std_dev() - std_dev(&xs)).abs() < 1e-9);
        assert!((o.rms() - rms(&xs)).abs() < 1e-12);
        assert_eq!(o.count(), 1000);
    }
}
