//! Minimal JSON parser + writer (serde_json substitute).
//!
//! Covers the full JSON grammar we produce and consume (`manifest.json`,
//! `train_stats.json`, `golden.json`, metric reports).  Numbers are f64,
//! objects preserve insertion order.  Errors carry byte offsets.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Like `get` but returns an error naming the missing key.
    pub fn req(&self, key: &str) -> crate::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key {key:?} in JSON object"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Convenience: numeric array -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Value::as_f64).collect()
    }

    /// Convenience: numeric array -> Vec<f32>.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        Some(self.as_f64_vec()?.into_iter().map(|x| x as f32).collect())
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        Some(self.as_f64_vec()?.into_iter().map(|x| x as usize).collect())
    }
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

/// Parse the JSON file at `path`.
pub fn parse_file(path: &std::path::Path) -> crate::Result<Value> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.i += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.i = self.i.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            kvs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(kvs)),
                _ => {
                    self.i = self.i.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut vals = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(vals));
        }
        loop {
            vals.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(vals)),
                _ => {
                    self.i = self.i.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad surrogate"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        s.push(ch);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control char")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        self.i = start + len;
                        if self.i > self.b.len() {
                            return Err(self.err("truncated UTF-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        s.push_str(chunk);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

/// Serialise a value to compact JSON.
pub fn write(v: &Value) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

fn write_into(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => write_str(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Value::Obj(kvs) => {
            out.push('{');
            for (i, (k, x)) in kvs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_into(x, out);
            }
            out.push('}');
        }
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers for report emission.
pub fn obj(kvs: Vec<(&str, Value)>) -> Value {
    Value::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(xs: &[f64]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
}

#[allow(dead_code)]
fn _unused(_: &BTreeMap<String, Value>) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" -12.5e2 ").unwrap(), Value::Num(-1250.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_f64().unwrap(), 2.0);
        assert_eq!(a[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn parses_utf8_passthrough() {
        assert_eq!(parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01x").is_err());
        assert!(parse("\"abc").is_err());
        assert!(parse("{}extra").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3],"b":{"c":true,"d":null},"s":"q\"uote"}"#;
        let v = parse(src).unwrap();
        let out = write(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn error_offsets_point_at_problem() {
        let e = parse("[1, oops]").unwrap_err();
        assert_eq!(e.offset, 4);
    }

    #[test]
    fn f64_vec_helper() {
        let v = parse("[1, 2, 3.5]").unwrap();
        assert_eq!(v.as_f64_vec().unwrap(), vec![1.0, 2.0, 3.5]);
        assert_eq!(parse("[1, \"x\"]").unwrap().as_f64_vec(), None);
    }
}
