//! Infrastructure substrates.
//!
//! The offline crate cache has no `rand`, `serde`, `tokio`, `criterion` or
//! `proptest`; these modules stand in for them (see DESIGN.md
//! "Substitutions").  Everything here is tested in its own module and used
//! across the coordinator, the NPU simulator and the eval drivers.

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
