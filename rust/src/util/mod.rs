//! Infrastructure substrates.
//!
//! The offline crate cache has no `rand`, `serde`, `tokio`, `criterion` or
//! `proptest`; these modules stand in for them (see DESIGN.md
//! "Substitutions").  Everything here is tested in its own module and used
//! across the coordinator, the NPU simulator and the eval drivers.

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;

use std::sync::{Mutex, MutexGuard};

/// Lock a mutex, recovering the guard if another thread panicked while
/// holding it.  The connection-facing paths use this instead of
/// `.lock().unwrap()`: one poisoned registry entry must not cascade into
/// killing the accept loop (the data under our mutexes stays consistent
/// under panic — every critical section is a single insert/remove/push).
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_unpoisoned_recovers_after_holder_panic() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "lock should be poisoned");
        assert_eq!(*lock_unpoisoned(&m), 7);
    }
}

