//! Deterministic pseudo-random numbers: SplitMix64 seeding + xoshiro256**.
//!
//! Used by workload generators, the property-test harness and the serving
//! examples.  Not cryptographic.  Algorithms follow Blackman & Vigna
//! (<https://prng.di.unimi.it/>); SplitMix64 expands a 64-bit seed into the
//! 256-bit xoshiro state so nearby seeds give unrelated streams.

/// One SplitMix64 step: advance-by-golden-gamma + finalizer.  Also used
/// standalone as a stateless hash (`qos::ShadowSampler`).
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        // Stream-identical to the classic "advance then finalize" form:
        // splitmix64(x) = finalize(x + gamma), so hashing the CURRENT
        // state and then advancing yields the same outputs.
        let mut sm = seed;
        let mut next_sm = || {
            let z = splitmix64(sm);
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            z
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s }
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // 128-bit multiply trick; bias < 2^-64, irrelevant for workloads.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal with parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Bernoulli with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix64_is_pure_and_mixes() {
        assert_eq!(splitmix64(42), splitmix64(42));
        assert_ne!(splitmix64(0), splitmix64(1));
        // The finalizer must not fix zero (a common weak-hash failure).
        assert_ne!(splitmix64(0), 0);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_respects_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let v = r.uniform(-3.0, 5.5);
            assert!((-3.0..5.5).contains(&v));
        }
    }

    #[test]
    fn below_covers_all_residues() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
