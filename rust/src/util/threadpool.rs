//! Tiny fixed-size thread pool + scoped parallel map (tokio/rayon stand-in).
//!
//! The coordinator pipeline uses dedicated threads with mpsc channels
//! (`coordinator::server`); this pool covers embarrassingly-parallel eval
//! work (per-benchmark figure regeneration).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Jobs are FIFO. Dropping the pool joins workers.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                thread::spawn(move || loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => {
                            job();
                            queued.fetch_sub(1, Ordering::SeqCst);
                        }
                        Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, queued }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.tx.as_ref().unwrap().send(Box::new(f)).unwrap();
    }

    /// Busy-wait (with yield) until all submitted jobs finished.
    pub fn wait_idle(&self) {
        while self.queued.load(Ordering::SeqCst) > 0 {
            thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Parallel map preserving order, using scoped threads (no 'static bound).
/// Spawns `min(items, max_threads)` threads working over an atomic cursor.
pub fn parallel_map<T, R, F>(items: &[T], max_threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = max_threads.max(1).min(n);
    let cursor = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                // Each index is written exactly once; the mutex only guards
                // the &mut aliasing, contention is one lock per item.
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    out.into_iter().map(|x| x.unwrap()).collect()
}

/// Number of worker threads to default to (leave a core for the OS).
pub fn default_parallelism() -> usize {
    thread::available_parallelism().map(|n| n.get().saturating_sub(1).max(1)).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drop_joins() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop joins
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..500).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<u64> = parallel_map(&Vec::<u64>::new(), 4, |&x| x);
        assert!(out.is_empty());
    }
}
