//! Tiny fixed-size thread pool + scoped parallel map (tokio/rayon stand-in).
//!
//! The coordinator pipeline uses dedicated threads with mpsc channels
//! (`coordinator::server`); this pool covers embarrassingly-parallel eval
//! work (per-benchmark figure regeneration) and the dispatcher's native
//! batch sharding.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Pending-job count + the condvar `wait_idle` parks on.
struct PoolState {
    pending: Mutex<usize>,
    idle: Condvar,
}

/// Decrements the pending count when dropped — panic-safe: a job that
/// unwinds still releases its count, so `wait_idle` cannot deadlock.
struct PendingGuard<'a>(&'a PoolState);

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        let mut pending = self.0.pending.lock().unwrap();
        *pending -= 1;
        if *pending == 0 {
            self.0.idle.notify_all();
        }
    }
}

/// Fixed-size worker pool. Jobs are FIFO. Dropping the pool joins workers.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    state: Arc<PoolState>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let state = Arc::new(PoolState { pending: Mutex::new(0), idle: Condvar::new() });
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let state = Arc::clone(&state);
                thread::spawn(move || loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => {
                            let _guard = PendingGuard(&state);
                            // Contain job panics so the worker (and the
                            // pool's capacity) survives them.
                            let _ = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(job),
                            );
                        }
                        Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, state }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        *self.state.pending.lock().unwrap() += 1;
        self.tx.as_ref().unwrap().send(Box::new(f)).unwrap();
    }

    /// Block until all submitted jobs finished (condvar wait, no spinning).
    pub fn wait_idle(&self) {
        let mut pending = self.state.pending.lock().unwrap();
        while *pending > 0 {
            pending = self.state.idle.wait(pending).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Parallel map preserving order, using scoped threads (no 'static bound).
/// Spawns `min(items, max_threads)` threads over an atomic chunk cursor;
/// each thread computes a whole chunk locally and publishes it under ONE
/// short lock, so slot-mutex contention is per-chunk, not per-item.
pub fn parallel_map<T, R, F>(items: &[T], max_threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = max_threads.max(1).min(n);
    if threads == 1 {
        return items.iter().map(f).collect();
    }
    // Chunks small enough to load-balance uneven work across threads, big
    // enough that the write-back lock is cold.
    let chunk = (n / (threads * 8)).max(1);
    let cursor = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = cursor.fetch_add(chunk, Ordering::SeqCst);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                let mut local: Vec<R> = Vec::with_capacity(end - start);
                for item in &items[start..end] {
                    local.push(f(item));
                }
                // One lock per finished chunk; each index written once.
                let mut guard = slots.lock().unwrap();
                for (j, r) in local.into_iter().enumerate() {
                    guard[start + j] = Some(r);
                }
            });
        }
    });
    out.into_iter().map(|x| x.unwrap()).collect()
}

/// Number of worker threads to default to (leave a core for the OS).
/// Cached: `available_parallelism` is a syscall and this gates the
/// dispatcher's native forward on every batch.
pub fn default_parallelism() -> usize {
    static CACHED: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CACHED.get_or_init(|| {
        thread::available_parallelism().map(|n| n.get().saturating_sub(1).max(1)).unwrap_or(4)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drop_joins() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop joins
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn wait_idle_survives_panicking_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for i in 0..8 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                if i % 2 == 0 {
                    panic!("job panic (expected in test)");
                }
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // Must terminate even though half the jobs panicked...
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 4);
        // ...and the workers must still be alive for new work.
        for _ in 0..4 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..500).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_uneven_work_and_threads() {
        // Chunked scheduling must still cover every index when n is not a
        // multiple of the chunk size or thread count.
        for n in [1usize, 3, 7, 63, 100] {
            let items: Vec<u64> = (0..n as u64).collect();
            let out = parallel_map(&items, 5, |&x| x + 1);
            assert_eq!(out, items.iter().map(|x| x + 1).collect::<Vec<_>>());
        }
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<u64> = parallel_map(&Vec::<u64>::new(), 4, |&x| x);
        assert!(out.is_empty());
    }
}
